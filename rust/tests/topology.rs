//! Hierarchical-topology suite (DESIGN.md §7): group-map validation,
//! WAN-vs-total byte accounting flat vs hierarchical, the theory comm
//! estimate against the measured `CommLedger` on both presets, and the
//! golden seam digest pinning the flat-topology record stream across
//! schedulers and thread counts (the pre/post-decomposition anchor).

mod common;

use adloco::cluster::{assign_workers, Topology};
use adloco::comm::{CommLedger, CommScope};
use adloco::config::{presets, Config, SchedulerKind, TopologyKind};
use adloco::coordinator::RunResult;
use adloco::engine::build_engine;
use adloco::theory::{estimate_ledger, MergePlanStep, TopoShape};
use common::{digest, run};
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// config validation of group maps
// ---------------------------------------------------------------------------

#[test]
fn malformed_group_maps_are_rejected() {
    let base = presets::hierarchical_mit();
    base.validate().unwrap();

    let mut cfg = base.clone();
    cfg.cluster.groups.clear();
    assert!(cfg.validate().is_err(), "hierarchical without groups must fail");

    let mut cfg = base.clone();
    cfg.cluster.groups = vec![vec![0, 1, 2, 3], vec![]];
    assert!(cfg.validate().is_err(), "empty group must fail");

    let mut cfg = base.clone();
    cfg.cluster.groups = vec![vec![0, 1, 2], vec![2, 3]];
    assert!(cfg.validate().is_err(), "node (worker) in two groups must fail");

    let mut cfg = base.clone();
    cfg.cluster.groups = vec![vec![0, 1], vec![3]];
    assert!(cfg.validate().is_err(), "unassigned node must fail");

    // the flat twin ignores the group map entirely
    let mut cfg = base.clone();
    cfg.cluster.topology = TopologyKind::Flat;
    cfg.cluster.groups = vec![vec![7, 8]];
    cfg.validate().unwrap();
}

// ---------------------------------------------------------------------------
// WAN bytes: hierarchical strictly below flat, both matching theory
// ---------------------------------------------------------------------------

/// Per-trainer sync shapes + home groups from the preset's round-robin
/// placement (the same `assign_workers` walk the coordinator performs).
fn sync_shapes(cfg: &Config) -> (Vec<TopoShape>, Vec<usize>) {
    let k = cfg.algo.num_trainers;
    let m = cfg.algo.workers_per_trainer;
    let placement = assign_workers(k * m, cfg.cluster.nodes.len());
    let topo = Topology::compile(&cfg.cluster);
    let mut shapes = Vec::with_capacity(k);
    let mut homes = Vec::with_capacity(k);
    for i in 0..k {
        let nodes: Vec<usize> = (0..m).map(|j| placement[i * m + j]).collect();
        homes.push(topo.group_of(nodes[0]));
        if topo.is_hierarchical() {
            let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
            for &n in &nodes {
                *counts.entry(topo.group_of(n)).or_insert(0) += 1;
            }
            shapes.push(TopoShape::Hier { parts: counts.values().copied().collect() });
        } else {
            shapes.push(TopoShape::Flat { m });
        }
    }
    (shapes, homes)
}

/// Run one preset and assert the theory estimate reproduces its ledger
/// exactly (static cluster => the closed forms are not approximations).
fn assert_theory_matches(cfg: Config) -> (RunResult, CommLedger) {
    let param_bytes = (build_engine(&cfg).unwrap().param_count() * 4) as u64;
    let (shapes, homes) = sync_shapes(&cfg);
    let hierarchical = cfg.cluster.topology == TopologyKind::Hierarchical;
    let outer_steps = cfg.algo.outer_steps as u64;
    let name = cfg.name.clone();
    let (r, rec, ledger) = run(cfg);
    let merges: Vec<MergePlanStep> = rec
        .merges
        .iter()
        .map(|m| MergePlanStep {
            outer_step: m.outer_step,
            removed: m.merged.clone(),
            representative: m.representative,
        })
        .collect();
    let est = estimate_ledger(outer_steps, &shapes, &homes, hierarchical, &merges, param_bytes);
    assert_eq!(est.events, ledger.count(), "{name}: predicted event count");
    assert_eq!(est.total_bytes, ledger.total_bytes(), "{name}: predicted total bytes");
    assert_eq!(est.wan_bytes, ledger.wan_bytes(), "{name}: predicted WAN bytes");
    assert_eq!(r.comm_bytes, ledger.total_bytes());
    assert_eq!(r.wan_comm_bytes, ledger.wan_bytes());
    (r, ledger)
}

#[test]
fn hierarchical_mit_wan_bytes_strictly_below_flat_and_match_theory() {
    // the hierarchical preset ...
    let hier = presets::hierarchical_mit();
    // ... and its flat twin on the same hetero nodes/schedule
    let mut flat = presets::hierarchical_mit();
    flat.name = "hierarchical_mit_flat".into();
    flat.cluster.topology = TopologyKind::Flat;

    let (rh, ledger_h) = assert_theory_matches(hier);
    let (rf, ledger_f) = assert_theory_matches(flat);

    assert_eq!(
        rf.wan_comm_bytes, rf.comm_bytes,
        "flat: the single network is the WAN — every byte counts"
    );
    assert!(
        rh.wan_comm_bytes < rf.wan_comm_bytes,
        "hierarchical must move bytes off the WAN: {} vs {}",
        rh.wan_comm_bytes,
        rf.wan_comm_bytes
    );
    // in this preset every trainer's workers share a group, so outer
    // syncs never touch the WAN; only cross-group merges may
    let wan_syncs = ledger_h
        .events
        .iter()
        .filter(|e| e.scope == CommScope::Wan)
        .filter(|e| e.kind == adloco::comm::CommKind::OuterSync)
        .count();
    assert_eq!(wan_syncs, 0, "worker reduces stay intra-group");
    assert!(ledger_f.count() > 0);
}

#[test]
fn topology_aware_selection_prefers_intra_group_merges() {
    let (_, rec, ledger) = run(presets::hierarchical_mit());
    assert!(!rec.merges.is_empty(), "the preset must merge");
    // groups are {t0,t2} and {t1,t3}: the first merges must be
    // intra-group pairs, recorded as Intra gather events
    let intra_merge_bytes: u64 = ledger
        .events
        .iter()
        .filter(|e| e.kind == adloco::comm::CommKind::Merge)
        .filter(|e| e.scope == CommScope::Intra)
        .map(|e| e.bytes)
        .sum();
    assert!(
        intra_merge_bytes > 0,
        "at least one merge must consolidate inside a node group"
    );
}

// ---------------------------------------------------------------------------
// golden seams: flat AND hierarchical record streams across schedulers
// and thread counts (digest serialization lives in tests/common/mod.rs,
// frozen so these pins survive field additions)
// ---------------------------------------------------------------------------

/// Pin one config's digest across the lockstep walk, the serial event
/// scheduler and the 4-thread runtime, plus an optional absolute-bits
/// fixture (`GOLDEN_WRITE=1` creates it on a reference machine).
fn assert_golden_seam(mk: impl Fn(SchedulerKind, usize) -> Config, fixture_name: &str) {
    let digest_of = |cfg: Config| {
        let (r, rec, ledger) = run(cfg);
        digest(&r, &rec, &ledger)
    };
    let lockstep = digest_of(mk(SchedulerKind::Lockstep, 1));
    let event = digest_of(mk(SchedulerKind::Event, 1));
    let parallel = digest_of(mk(SchedulerKind::Event, 4));
    assert_eq!(lockstep, event, "{fixture_name}: lockstep vs event digest");
    assert_eq!(event, parallel, "{fixture_name}: serial vs 4-thread digest");

    let fixture = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join(format!("tests/fixtures/{fixture_name}.txt"));
    if std::env::var("GOLDEN_WRITE").as_deref() == Ok("1") {
        std::fs::create_dir_all(fixture.parent().unwrap()).unwrap();
        std::fs::write(&fixture, &lockstep).unwrap();
    } else if fixture.exists() {
        let pinned = std::fs::read_to_string(&fixture).unwrap();
        assert_eq!(
            pinned.trim(),
            lockstep,
            "{fixture_name}: record stream drifted from the pinned golden"
        );
    }
}

/// The flat-topology seam anchor: the same config must digest
/// identically through the lockstep walk, the serial event scheduler
/// and the 4-thread parallel runtime — the refactor seam leaves no
/// trace in any record stream. A fixture file, when present (or
/// `GOLDEN_WRITE=1` to create it on a reference machine), addition-
/// ally pins the absolute bits across commits; it is not committed by
/// default because libm differences across platforms can legally move
/// the low bits (the cross-scheduler/thread equality always holds).
#[test]
fn flat_golden_digest_across_schedulers_and_threads() {
    let mk = |sched: SchedulerKind, threads: usize| {
        let mut cfg = presets::mock_default();
        cfg.name = "flat_golden".into();
        cfg.algo.outer_steps = 6;
        cfg.algo.inner_steps = 15;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.merge.frequency = 2;
        cfg.run.eval_every = 5;
        cfg.run.scheduler = sched;
        cfg.run.threads = threads;
        cfg
    };
    assert_golden_seam(mk, "flat_golden");
}

/// SAT4: the *hierarchical* record stream is pinned the same way the
/// flat one always was — intra/WAN phase ordering, topology-aware merge
/// selection and the two-tier barrier arithmetic must digest
/// identically through the lockstep walk, the serial event scheduler
/// and the 4-thread runtime (the preset is static, so lockstep can
/// legally drive it).
#[test]
fn hierarchical_golden_digest_across_schedulers_and_threads() {
    let mk = |sched: SchedulerKind, threads: usize| {
        let mut cfg = presets::hierarchical_mit();
        cfg.name = "hier_golden".into();
        cfg.algo.outer_steps = 6;
        cfg.run.scheduler = sched;
        cfg.run.threads = threads;
        cfg
    };
    assert_golden_seam(mk, "hier_golden");
}
