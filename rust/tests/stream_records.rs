//! Streaming record flush (`run.stream_records`): the streamed JSONL
//! must be byte-identical to what the buffered writer produces for the
//! same run, on both schedulers, with the `.steps.part` segment cleaned
//! up and the in-RAM step buffer actually drained.

use adloco::config::{presets, Config, SchedulerKind};
use adloco::coordinator::{resolve_policy, run_experiment, Coordinator};
use adloco::engine::build_engine;

fn quick_cfg(name: &str, scheduler: SchedulerKind) -> Config {
    let mut cfg = presets::quick();
    cfg.name = name.into();
    cfg.run.scheduler = scheduler;
    cfg
}

fn run_into(dir: &std::path::Path, mut cfg: Config) -> (Vec<u8>, Vec<u8>) {
    std::fs::remove_dir_all(dir).ok();
    cfg.out_dir = Some(dir.to_str().unwrap().to_string());
    let name = cfg.name.clone();
    run_experiment(cfg).unwrap();
    let jsonl = std::fs::read(dir.join(format!("{name}.jsonl"))).unwrap();
    let csv = std::fs::read(dir.join(format!("{name}.csv"))).unwrap();
    (jsonl, csv)
}

fn streamed_matches_buffered(scheduler: SchedulerKind) {
    let tag = scheduler.as_str();
    let base = std::env::temp_dir().join(format!("adloco_stream_{tag}"));

    let buffered = run_into(&base.join("buffered"), quick_cfg("sr", scheduler));

    let mut cfg = quick_cfg("sr", scheduler);
    cfg.run.stream_records = true;
    let streamed_dir = base.join("streamed");
    let streamed = run_into(&streamed_dir, cfg);

    assert_eq!(
        buffered.0, streamed.0,
        "{tag}: streamed JSONL must be byte-identical to the buffered writer"
    );
    assert_eq!(buffered.1, streamed.1, "{tag}: eval CSV must match");
    assert!(
        !streamed_dir.join("sr.jsonl.steps.part").exists(),
        "{tag}: segment file must be removed after reassembly"
    );
}

#[test]
fn streamed_jsonl_is_byte_identical_lockstep() {
    streamed_matches_buffered(SchedulerKind::Lockstep);
}

#[test]
fn streamed_jsonl_is_byte_identical_event() {
    streamed_matches_buffered(SchedulerKind::Event);
}

/// `fleet_trace` defaults to the streaming writer (the fleet preset is
/// exactly where the buffered recorder's open tail hurts) — and the
/// streamed bytes still match buffered on a shrunk fleet schedule, the
/// same reduction `benches/fig6_scale.rs --smoke` runs at scale.
#[test]
fn fleet_trace_defaults_to_streaming_and_stays_byte_identical() {
    assert!(
        presets::fleet_trace().run.stream_records,
        "fleet_trace must default to run.stream_records = on"
    );

    let shrink = || {
        let mut cfg = presets::fleet_trace();
        cfg.name = "ft_small".into();
        cfg.algo.outer_steps = 3;
        cfg.algo.inner_steps = 4;
        cfg.engine = adloco::config::EngineConfig::Mock { dim: 64, noise: 1.0, condition: 10.0 };
        cfg.algo.batching.adaptive = false;
        cfg.algo.fixed_batch = 4;
        cfg.run.eval_batches = 1;
        cfg.data.val_sequences = 64;
        cfg
    };
    let base = std::env::temp_dir().join("adloco_stream_fleet");

    let mut buffered_cfg = shrink();
    buffered_cfg.run.stream_records = false;
    let buffered = run_into(&base.join("buffered"), buffered_cfg);

    let streamed_dir = base.join("streamed");
    let streamed = run_into(&streamed_dir, shrink()); // preset default: streaming on

    assert_eq!(
        buffered.0, streamed.0,
        "fleet_trace: streamed JSONL must be byte-identical to buffered"
    );
    assert_eq!(buffered.1, streamed.1, "fleet_trace: eval CSV must match");
    assert!(!streamed_dir.join("ft_small.jsonl.steps.part").exists());
}

#[test]
fn streaming_drains_ram_and_preserves_aggregates() {
    let dir = std::env::temp_dir().join("adloco_stream_direct");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    // buffered reference for the aggregate
    let cfg = resolve_policy(&quick_cfg("sr_direct", SchedulerKind::Lockstep));
    let engine = build_engine(&cfg).unwrap();
    let mut buffered = Coordinator::new(cfg, engine).unwrap();
    buffered.run().unwrap();
    let want_mean = buffered.recorder.mean_batch();
    let total_steps = buffered.recorder.steps.len() as u64;
    assert!(total_steps > 0, "quick preset must record steps");

    // streamed run: steps leave RAM every round, aggregates survive
    let cfg = resolve_policy(&quick_cfg("sr_direct", SchedulerKind::Lockstep));
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    let path = dir.join("sr_direct.jsonl");
    coord.enable_record_streaming(path.to_str().unwrap()).unwrap();
    coord.run().unwrap();
    coord.finish_record_streaming().unwrap();

    assert!(coord.recorder.steps.is_empty(), "streamed steps must leave RAM");
    assert_eq!(coord.recorder.drained_steps, total_steps);
    // batch sizes are integers, so the per-round partial sums are exact
    // and the folded mean equals the buffered one bit for bit
    assert_eq!(
        coord.recorder.mean_batch().to_bits(),
        want_mean.to_bits(),
        "mean_batch must fold drained aggregates exactly"
    );
    assert!(path.exists());
    assert!(!dir.join("sr_direct.jsonl.steps.part").exists());
}
