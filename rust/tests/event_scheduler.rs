//! Event-scheduler regression anchor: on static clusters the
//! discrete-event run loop must reproduce the lockstep reference walk
//! bit-for-bit — same `CommLedger` (counts, bytes, participants,
//! `at_inner_step`s, timestamps), same `RunResult`, same record streams —
//! for the quickstart and adloco_vs_diloco configurations and across a
//! randomized config sweep. Plus behavioural tests for the dynamic
//! scenarios (stragglers, churn re-sharding, link shifts) that only the
//! event scheduler can express.

use adloco::config::{presets, ChurnWindow, Config, LinkShift, Method, SchedulerKind};
use adloco::coordinator::{resolve_policy, Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;
use adloco::simulator::{CommKind, CommLedger};
use adloco::util::Rng;

fn run(cfg: Config) -> (RunResult, Recorder, CommLedger) {
    let engine = build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let r = c.run().unwrap();
    (r, c.recorder.clone(), c.ledger().clone())
}

/// Run `cfg` under both schedulers and assert full bitwise agreement.
fn assert_schedulers_agree(mut cfg: Config) {
    assert!(
        cfg.cluster.scenario.is_static(),
        "bit-identity only holds for static scenarios"
    );
    cfg.run.scheduler = SchedulerKind::Lockstep;
    let (ra, reca, leda) = run(cfg.clone());
    cfg.run.scheduler = SchedulerKind::Event;
    let (rb, recb, ledb) = run(cfg.clone());
    let name = &cfg.name;

    // ---- communication ledger: the paper's C(N) observable -------------
    assert_eq!(leda.count(), ledb.count(), "{name}: ledger count");
    assert_eq!(leda.total_bytes(), ledb.total_bytes(), "{name}: ledger bytes");
    for (i, (a, b)) in leda.events.iter().zip(ledb.events.iter()).enumerate() {
        assert_eq!(a.kind, b.kind, "{name}: event {i} kind");
        assert_eq!(a.bytes, b.bytes, "{name}: event {i} bytes");
        assert_eq!(a.participants, b.participants, "{name}: event {i} participants");
        assert_eq!(a.at_inner_step, b.at_inner_step, "{name}: event {i} at_inner_step");
        assert_eq!(
            a.at_virtual_s.to_bits(),
            b.at_virtual_s.to_bits(),
            "{name}: event {i} timestamp ({} vs {})",
            a.at_virtual_s,
            b.at_virtual_s
        );
    }

    // ---- run summary ----------------------------------------------------
    assert_eq!(ra.total_samples, rb.total_samples, "{name}: samples");
    assert_eq!(ra.total_inner_steps, rb.total_inner_steps, "{name}: steps");
    assert_eq!(ra.trainers_left, rb.trainers_left, "{name}: trainers");
    assert_eq!(ra.comm_count, rb.comm_count, "{name}: comms");
    assert_eq!(ra.comm_bytes, rb.comm_bytes, "{name}: comm bytes");
    assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits(), "{name}: best ppl");
    assert_eq!(ra.final_ppl.to_bits(), rb.final_ppl.to_bits(), "{name}: final ppl");
    assert_eq!(
        ra.virtual_time_s.to_bits(),
        rb.virtual_time_s.to_bits(),
        "{name}: virtual time"
    );
    assert_eq!(
        ra.total_idle_s.to_bits(),
        rb.total_idle_s.to_bits(),
        "{name}: idle time"
    );

    // ---- full record streams --------------------------------------------
    assert_eq!(reca.steps.len(), recb.steps.len(), "{name}: step records");
    for (a, b) in reca.steps.iter().zip(recb.steps.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer, a.worker, a.batch, a.accum_steps),
            (b.global_step, b.outer_step, b.trainer, b.worker, b.batch, b.accum_steps),
            "{name}: step identity"
        );
        assert_eq!(a.requested_batch, b.requested_batch, "{name}: requested batch");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{name}: step loss");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{name}: step time"
        );
    }
    assert_eq!(reca.evals.len(), recb.evals.len(), "{name}: eval records");
    for (a, b) in reca.evals.iter().zip(recb.evals.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer, a.comm_count, a.comm_bytes),
            (b.global_step, b.outer_step, b.trainer, b.comm_count, b.comm_bytes),
            "{name}: eval identity"
        );
        assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits(), "{name}: eval ppl");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{name}: eval time"
        );
    }
    assert_eq!(reca.merges.len(), recb.merges.len(), "{name}: merges");
    for (a, b) in reca.merges.iter().zip(recb.merges.iter()) {
        assert_eq!(a.merged, b.merged, "{name}: merged set");
        assert_eq!(a.representative, b.representative, "{name}: representative");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{name}: merge time"
        );
    }
}

/// The quickstart example's configuration (examples/quickstart.rs).
fn quickstart_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "quickstart".into();
    cfg.algo.outer_steps = 8;
    cfg.algo.inner_steps = 20;
    cfg.algo.workers_per_trainer = 2;
    cfg.apply_override("algo.batching.eta=0.8").unwrap();
    cfg.apply_override("algo.merge.frequency=3").unwrap();
    cfg
}

/// The adloco_vs_diloco example's algorithm shape. The example itself
/// runs the XLA tiny profile; artifacts are not guaranteed here, so the
/// same coordination schedule runs on the mock substrate (the scheduler
/// equivalence being tested is engine-agnostic).
fn adloco_vs_diloco_cfg(method: Method) -> Config {
    let mut cfg = presets::xla_tiny();
    cfg.engine = adloco::config::EngineConfig::Mock { dim: 400, noise: 1.0, condition: 10.0 };
    cfg.name = format!("avd_{}", method.as_str());
    cfg.algo.method = method;
    cfg.algo.outer_steps = 4;
    cfg.algo.inner_steps = 15;
    cfg.algo.num_trainers = 3;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.merge.frequency = 2;
    cfg.algo.fixed_batch = 4;
    cfg.algo.lr_inner = 1e-3;
    cfg.run.eval_every = 5;
    cfg.run.eval_batches = 1;
    resolve_policy(&cfg)
}

#[test]
fn event_matches_lockstep_on_quickstart() {
    assert_schedulers_agree(quickstart_cfg());
}

#[test]
fn event_matches_lockstep_on_adloco_vs_diloco() {
    for method in [Method::AdLoCo, Method::DiLoCo] {
        assert_schedulers_agree(adloco_vs_diloco_cfg(method));
    }
}

#[test]
fn event_matches_lockstep_across_random_configs() {
    // hand-rolled property sweep in the style of tests/properties.rs
    let mut rng = Rng::new(0xE7E27);
    for case in 0..8 {
        let mut cfg = presets::quick();
        cfg.name = format!("prop_sched_{case}");
        cfg.seed = rng.next_u64();
        cfg.algo.num_trainers = 1 + rng.below(4) as usize;
        cfg.algo.workers_per_trainer = 1 + rng.below(3) as usize;
        cfg.algo.inner_steps = 2 + rng.below(8) as usize;
        cfg.algo.outer_steps = 1 + rng.below(4) as usize;
        cfg.algo.merge.enabled = rng.f64() < 0.7;
        cfg.algo.merge.w = 1 + rng.below(4) as usize;
        cfg.algo.merge.frequency = 1 + rng.below(3) as usize;
        cfg.algo.switch.enabled = rng.f64() < 0.7;
        cfg.algo.batching.adaptive = rng.f64() < 0.8;
        cfg.algo.batching.max_request = 64;
        cfg.run.eval_every = 1 + rng.below(4) as usize;
        cfg.run.max_inner_steps = if rng.f64() < 0.3 { 5 } else { 0 };
        // heterogeneous speeds stress the event ordering without breaking
        // the static-cluster guarantee
        for (i, n) in cfg.cluster.nodes.iter_mut().enumerate() {
            n.speed = 1.0 + i as f64 * 0.5;
        }
        cfg.validate().unwrap();
        assert_schedulers_agree(cfg);
    }
}

#[test]
fn stragglers_are_deterministic_and_stretch_time() {
    let mk = |prob: f64, seed: u64| {
        let mut cfg = quickstart_cfg();
        cfg.name = format!("straggle_{prob}_{seed}");
        cfg.seed = seed;
        cfg.run.scheduler = SchedulerKind::Event;
        cfg.cluster.scenario.straggler_prob = prob;
        cfg.cluster.scenario.straggler_min = 2.0;
        cfg.cluster.scenario.straggler_max = 5.0;
        cfg
    };
    // determinism: identical seeds -> identical runs
    let (r1, _, l1) = run(mk(0.3, 9));
    let (r2, _, l2) = run(mk(0.3, 9));
    assert_eq!(r1.virtual_time_s.to_bits(), r2.virtual_time_s.to_bits());
    assert_eq!(l1.count(), l2.count());
    for (a, b) in l1.events.iter().zip(l2.events.iter()) {
        assert_eq!(a.at_virtual_s.to_bits(), b.at_virtual_s.to_bits());
    }
    // stragglers stretch wall-clock but not the sample schedule
    let (r0, _, _) = run(mk(0.0, 9));
    assert!(r1.virtual_time_s > r0.virtual_time_s);
    assert_eq!(r1.total_samples, r0.total_samples);
    // ...and they widen barrier waits (idle time)
    assert!(
        r1.total_idle_s > r0.total_idle_s,
        "straggler idle {} <= static idle {}",
        r1.total_idle_s,
        r0.total_idle_s
    );
}

#[test]
fn churn_resharding_keeps_syncing_with_survivors() {
    // One trainer, three workers on three nodes; node 1 is preempted over
    // a mid-run window. While it is down, outer syncs must run with 2
    // participants (the survivors, fed by the re-split shard) and the
    // preemption must be accounted in the utilization table.
    let mut cfg = presets::quick();
    cfg.name = "churn_reshard".into();
    cfg.algo.num_trainers = 1;
    cfg.algo.workers_per_trainer = 3;
    cfg.algo.merge.enabled = false;
    cfg.algo.outer_steps = 8;
    cfg.algo.inner_steps = 6;
    cfg.run.eval_every = 0;
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.cluster.scenario.churn.push(ChurnWindow { node: 1, from_s: 0.02, until_s: 0.25 });
    cfg.validate().unwrap();

    let (r, rec, ledger) = run(cfg);
    assert!(r.best_ppl.is_finite());
    let participant_counts: Vec<usize> = ledger
        .events
        .iter()
        .filter(|e| e.kind == CommKind::OuterSync)
        .map(|e| e.participants)
        .collect();
    assert!(
        participant_counts.iter().any(|&p| p == 2),
        "no sync ran with the 2 survivors: {participant_counts:?}"
    );
    assert!(
        participant_counts.iter().any(|&p| p == 3),
        "the preempted worker never rejoined: {participant_counts:?}"
    );
    let preempted: f64 = rec.utilization.iter().map(|u| u.preempted_s).sum();
    assert!(preempted > 0.0, "downtime must appear in the utilization table");
    // worker on node 1 carries the preemption
    let w1 = rec.utilization.iter().find(|u| u.node == 1).unwrap();
    assert!(w1.preempted_s > 0.0);
}

#[test]
fn link_shift_slows_syncs_while_active() {
    // Collapsing one participating link's bandwidth must make outer syncs
    // during the shift window take longer than the same syncs at full
    // bandwidth.
    let mk = |shifted: bool| {
        let mut cfg = presets::quick();
        cfg.name = if shifted { "link_slow" } else { "link_fast" }.into();
        cfg.algo.num_trainers = 1;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.merge.enabled = false;
        cfg.algo.batching.adaptive = false; // fixed schedule on both arms
        cfg.algo.outer_steps = 4;
        cfg.algo.inner_steps = 5;
        cfg.run.eval_every = 0;
        cfg.run.scheduler = SchedulerKind::Event;
        if shifted {
            cfg.cluster.scenario.link_shifts.push(LinkShift {
                node: 0,
                at_s: 0.0,
                bandwidth_factor: 1e-4,
            });
        }
        cfg
    };
    let (fast, _, lf) = run(mk(false));
    let (slow, _, ls) = run(mk(true));
    assert_eq!(lf.count(), ls.count(), "same sync schedule");
    assert!(
        slow.virtual_time_s > fast.virtual_time_s,
        "a collapsed link must stretch the run: {} vs {}",
        slow.virtual_time_s,
        fast.virtual_time_s
    );
}
