//! Documentation-link check: every `DESIGN.md §<n>` / `EXPERIMENTS.md
//! §<name>` citation in the Rust sources must resolve to a real heading
//! in the corresponding document. Citations are the source tree's
//! architecture cross-references; a dangling one means the docs and the
//! code drifted apart. Runs as part of the normal test suite (and the
//! CI doc-link step invokes exactly this test).
//!
//! Scope: `rust/src/**`, `rust/benches/**`, `rust/tests/**` and
//! `examples/**`. The check is line-scoped: a citation must name its
//! document on the same line (the prevailing style in this tree).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Section tokens (the text after `§`) declared by markdown headings.
fn headings(doc: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in doc.lines() {
        if !line.starts_with('#') {
            continue;
        }
        if let Some(pos) = line.find('§') {
            let rest = &line[pos + '§'.len_utf8()..];
            let token: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '.')
                .collect();
            let token = token.trim_end_matches('.').to_string();
            if !token.is_empty() {
                out.insert(token);
            }
        }
    }
    out
}

/// All `§<token>` references on a line with their byte offsets
/// (trailing sentence periods stripped: `§3.` cites §3).
fn section_refs(line: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut base = 0usize;
    let mut rest = line;
    while let Some(pos) = rest.find('§') {
        let at = base + pos;
        rest = &rest[pos + '§'.len_utf8()..];
        base = at + '§'.len_utf8();
        let token: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '.')
            .collect();
        let token = token.trim_end_matches('.').to_string();
        if !token.is_empty() {
            out.push((at, token));
        }
    }
    out
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

#[test]
fn every_design_and_experiments_citation_resolves() {
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let design = std::fs::read_to_string(repo.join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repo root");
    let experiments = std::fs::read_to_string(repo.join("EXPERIMENTS.md"))
        .expect("EXPERIMENTS.md must exist at the repo root");
    let design_secs = headings(&design);
    let experiments_secs = headings(&experiments);
    assert!(
        design_secs.contains("6"),
        "DESIGN.md must declare §6 (parallel execution / determinism contract)"
    );
    assert!(experiments_secs.contains("Perf"), "EXPERIMENTS.md must declare §Perf");

    let mut files = Vec::new();
    for dir in ["rust/src", "rust/benches", "rust/tests", "examples"] {
        rust_files(&repo.join(dir), &mut files);
    }
    assert!(files.len() > 20, "source scan looks wrong: {} files", files.len());

    let mut bad: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for file in &files {
        let text = std::fs::read_to_string(file).unwrap();
        for (ln, line) in text.lines().enumerate() {
            // every document mention on the line, in byte order; a §
            // token resolves against the nearest preceding mention (or
            // the first one when the token precedes them all), so mixed
            // lines like "EXPERIMENTS.md §Perf and DESIGN.md §6" check
            // each citation against its own document
            let mut mentions: Vec<(usize, &str)> = ["DESIGN.md", "EXPERIMENTS.md"]
                .iter()
                .flat_map(|&doc| line.match_indices(doc).map(move |(p, _)| (p, doc)))
                .collect();
            if mentions.is_empty() {
                continue;
            }
            mentions.sort_by_key(|&(p, _)| p);
            for (pos, token) in section_refs(line) {
                let doc = mentions
                    .iter()
                    .rev()
                    .find(|&&(p, _)| p < pos)
                    .map(|&(_, d)| d)
                    .unwrap_or(mentions[0].1);
                let secs =
                    if doc == "DESIGN.md" { &design_secs } else { &experiments_secs };
                checked += 1;
                if !secs.contains(&token) {
                    bad.push(format!(
                        "{}:{}: {doc} §{token} does not resolve",
                        file.display(),
                        ln + 1
                    ));
                }
            }
        }
    }
    assert!(checked > 30, "expected a citation-rich tree, found {checked}");
    assert!(bad.is_empty(), "dangling doc citations:\n{}", bad.join("\n"));
}

#[test]
fn heading_and_ref_parsers_behave() {
    let doc = "## §3 The cluster\n### §3.1 Lockstep\n## §Perf — notes\nplain\n";
    let h = headings(doc);
    assert!(h.contains("3") && h.contains("3.1") && h.contains("Perf"));
    assert_eq!(h.len(), 3);
    // (doc names spelled out would make this very test a citation line,
    // so the probe string cites sections only)
    let refs = section_refs("see §3.1–§3.2 and §Perf.");
    let tokens: Vec<&str> = refs.iter().map(|(_, t)| t.as_str()).collect();
    assert_eq!(tokens, vec!["3.1", "3.2", "Perf"]);
    // byte offsets are ascending (attribution relies on this)
    assert!(refs.windows(2).all(|w| w[0].0 < w[1].0));
    assert!(section_refs("no refs here").is_empty());
}
