//! Exact-resume suite (checkpoint v2 contract): a run resumed from a
//! checkpoint taken at outer step k must produce, from step k+1 on, the
//! **bit-identical** record streams, ledger continuation, utilization
//! accounting and final `RunResult` payload of the uninterrupted run —
//! on both schedulers, at 1 and 4 threads, under the dynamic-workload
//! scenario, and with delayed-overlap collectives in flight across the
//! resume point (DESIGN.md §8).
//!
//! `best_ppl` is deliberately not compared: it minimizes over *all*
//! evaluations including the pre-checkpoint prefix the resumed run
//! never re-executes.

use adloco::config::{presets, Config, OverlapMode, SchedulerKind};
use adloco::coordinator::{Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;

/// One outer step, dispatched exactly like `Coordinator::run` does.
fn drive_step(c: &mut Coordinator, t: u64) {
    let serial_lockstep =
        c.config().run.scheduler == SchedulerKind::Lockstep && c.threads() <= 1;
    if serial_lockstep {
        c.step_outer(t).unwrap();
    } else {
        c.step_outer_event(t).unwrap();
    }
}

fn new_coord(cfg: &Config) -> Coordinator {
    let engine = build_engine(cfg).unwrap();
    Coordinator::new(cfg.clone(), engine).unwrap()
}

/// Save at outer step `k`, resume, and assert the remaining record
/// stream plus the final `RunResult` payload are bit-identical to the
/// uninterrupted run.
fn assert_exact_resume(cfg: Config, k: u64, tag: &str) {
    // reference: the uninterrupted run
    let mut full = new_coord(&cfg);
    let rfull = full.run().unwrap();

    // truncated run: drive to k exactly as run() would, snapshot, stop
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.ckpt")).to_str().unwrap().to_string();
    let mut part = new_coord(&cfg);
    for t in 1..=k {
        drive_step(&mut part, t);
    }
    part.snapshot(k).save(&path).unwrap();

    // resumed run: same config + resume_from
    let mut cfg2 = cfg.clone();
    cfg2.run.resume_from = Some(path);
    let mut resumed = new_coord(&cfg2);
    let rres = resumed.run().unwrap();

    assert_payloads_match(&rfull, &rres, tag);
    assert_suffix_matches(&full.recorder, &resumed.recorder, k, tag);
}

/// The `RunResult` determinism payload, bit for bit (minus `best_ppl`,
/// see module docs, and the wall-clock/threads perf fields).
fn assert_payloads_match(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.final_ppl.to_bits(), b.final_ppl.to_bits(), "{tag}: final ppl");
    assert_eq!(a.total_inner_steps, b.total_inner_steps, "{tag}: inner steps");
    assert_eq!(a.total_samples, b.total_samples, "{tag}: samples");
    assert_eq!(a.comm_count, b.comm_count, "{tag}: comm count");
    assert_eq!(a.comm_bytes, b.comm_bytes, "{tag}: comm bytes");
    assert_eq!(a.wan_comm_bytes, b.wan_comm_bytes, "{tag}: WAN bytes");
    assert_eq!(
        a.virtual_time_s.to_bits(),
        b.virtual_time_s.to_bits(),
        "{tag}: virtual time ({} vs {})",
        a.virtual_time_s,
        b.virtual_time_s
    );
    assert_eq!(a.trainers_left, b.trainers_left, "{tag}: trainers left");
    assert_eq!(
        a.total_idle_s.to_bits(),
        b.total_idle_s.to_bits(),
        "{tag}: idle time"
    );
    assert_eq!(
        a.mean_utilization.to_bits(),
        b.mean_utilization.to_bits(),
        "{tag}: utilization"
    );
    assert_eq!(
        a.overlap_hidden_s.to_bits(),
        b.overlap_hidden_s.to_bits(),
        "{tag}: overlap hidden"
    );
    assert_eq!(a.time_to_target, b.time_to_target, "{tag}: time to target");
    assert_eq!(a.spawn_count, b.spawn_count, "{tag}: spawn count");
    assert_eq!(
        a.mean_live_instances.to_bits(),
        b.mean_live_instances.to_bits(),
        "{tag}: mean live instances"
    );
    assert_eq!(
        a.total_vacant_s.to_bits(),
        b.total_vacant_s.to_bits(),
        "{tag}: vacant time"
    );
}

/// The resumed run's record streams must equal the uninterrupted run's
/// post-k suffix, field for field and bit for bit; utilization rows
/// (whole-run accumulators, restored from the checkpoint) must match in
/// full.
fn assert_suffix_matches(full: &Recorder, res: &Recorder, k: u64, tag: &str) {
    let full_steps: Vec<_> = full.steps.iter().filter(|s| s.outer_step > k).collect();
    assert_eq!(full_steps.len(), res.steps.len(), "{tag}: step suffix length");
    for (a, b) in full_steps.iter().zip(res.steps.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer, a.worker),
            (b.global_step, b.outer_step, b.trainer, b.worker),
            "{tag}: step identity"
        );
        assert_eq!(a.batch, b.batch, "{tag}: step batch");
        assert_eq!(a.requested_batch, b.requested_batch, "{tag}: requested");
        assert_eq!(a.accum_steps, b.accum_steps, "{tag}: accum");
        assert_eq!(a.clamped, b.clamped, "{tag}: clamp flag");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{tag}: step loss");
        assert_eq!(
            a.grad_sq_norm.to_bits(),
            b.grad_sq_norm.to_bits(),
            "{tag}: grad norm"
        );
        assert_eq!(a.sigma2.to_bits(), b.sigma2.to_bits(), "{tag}: sigma2");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{tag}: step time"
        );
    }
    let full_evals: Vec<_> = full.evals.iter().filter(|e| e.outer_step > k).collect();
    assert_eq!(full_evals.len(), res.evals.len(), "{tag}: eval suffix length");
    for (a, b) in full_evals.iter().zip(res.evals.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer),
            (b.global_step, b.outer_step, b.trainer),
            "{tag}: eval identity"
        );
        assert_eq!(a.comm_count, b.comm_count, "{tag}: eval comm count");
        assert_eq!(a.comm_bytes, b.comm_bytes, "{tag}: eval comm bytes");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{tag}: eval loss");
        assert_eq!(
            a.perplexity.to_bits(),
            b.perplexity.to_bits(),
            "{tag}: eval ppl"
        );
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{tag}: eval time"
        );
    }
    let full_merges: Vec<_> = full.merges.iter().filter(|m| m.outer_step > k).collect();
    assert_eq!(full_merges.len(), res.merges.len(), "{tag}: merge suffix length");
    for (a, b) in full_merges.iter().zip(res.merges.iter()) {
        assert_eq!(a.merged, b.merged, "{tag}: merged set");
        assert_eq!(a.representative, b.representative, "{tag}: representative");
        assert_eq!(a.trainers_left, b.trainers_left, "{tag}: trainers left");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{tag}: merge time"
        );
    }
    assert_eq!(
        full.utilization.len(),
        res.utilization.len(),
        "{tag}: utilization rows"
    );
    for (a, b) in full.utilization.iter().zip(res.utilization.iter()) {
        assert_eq!(
            (a.trainer, a.worker, a.node),
            (b.trainer, b.worker, b.node),
            "{tag}: utilization identity"
        );
        assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits(), "{tag}: busy_s");
        assert_eq!(a.wait_s.to_bits(), b.wait_s.to_bits(), "{tag}: wait_s");
        assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits(), "{tag}: comm_s");
        assert_eq!(a.hidden_s.to_bits(), b.hidden_s.to_bits(), "{tag}: hidden_s");
        assert_eq!(
            a.preempted_s.to_bits(),
            b.preempted_s.to_bits(),
            "{tag}: preempted_s"
        );
    }
}

/// The shared base schedule: small but feature-dense (multi-worker
/// trainers, adaptive batching, merging, mid-loop evals).
fn base_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "resume_base".into();
    cfg.algo.num_trainers = 3;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.outer_steps = 6;
    cfg.algo.inner_steps = 10;
    cfg.algo.merge.frequency = 2;
    cfg.run.eval_every = 4;
    cfg
}

#[test]
fn resume_is_bit_exact_lockstep_serial() {
    let cfg = base_cfg();
    assert_exact_resume(cfg, 3, "lockstep_t1");
}

#[test]
fn resume_is_bit_exact_event_serial() {
    let mut cfg = base_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    assert_exact_resume(cfg, 3, "event_t1");
}

#[test]
fn resume_is_bit_exact_event_parallel() {
    let mut cfg = base_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "event_t4");
}

#[test]
fn resume_is_bit_exact_lockstep_parallel() {
    // lockstep + threads > 1 routes through the event-equivalent path
    // (legal on static clusters); resume must hold there too
    let mut cfg = base_cfg();
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "lockstep_t4");
}

#[test]
fn resume_is_bit_exact_delayed_overlap_serial() {
    // the checkpoint at k carries trainer deltas still in flight
    // (posted at round k, applying at k+1) — the resumed run must land
    // the exact ledger rows and apply the exact stale updates
    let mut cfg = base_cfg();
    cfg.name = "resume_overlap".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg.run.scheduler = SchedulerKind::Event;
    assert_exact_resume(cfg, 3, "overlap_t1");
}

#[test]
fn resume_is_bit_exact_delayed_overlap_parallel() {
    let mut cfg = base_cfg();
    cfg.name = "resume_overlap_par".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "overlap_t4");
}

#[test]
fn resume_is_bit_exact_delayed_overlap_lockstep() {
    let mut cfg = base_cfg();
    cfg.name = "resume_overlap_lock".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    assert_exact_resume(cfg, 3, "overlap_lockstep");
}

#[test]
fn resume_is_bit_exact_hetero_dynamic() {
    // stragglers + churn + link shifts: the resume point sits inside the
    // dynamic scenario, so worker activity flags, per-step straggler
    // draws and the churn re-shard stream all cross the checkpoint
    let mut cfg = presets::hetero_dynamic();
    cfg.name = "resume_hetero".into();
    cfg.algo.outer_steps = 6;
    assert_exact_resume(cfg, 3, "hetero_t1");
}

#[test]
fn resume_is_bit_exact_hetero_dynamic_delayed() {
    let mut cfg = presets::adloco_overlap();
    cfg.name = "resume_hetero_overlap".into();
    cfg.algo.outer_steps = 6;
    assert_exact_resume(cfg, 3, "hetero_overlap_t1");
}

/// An elastic schedule whose first spawns are guaranteed at outer step
/// 1: two single-worker seed trainers over 4 nodes leave two nodes
/// fully unassigned (idle fraction 1.0 — DESIGN.md §9).
fn elastic_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "resume_elastic".into();
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.outer_steps = 6;
    cfg.algo.inner_steps = 10;
    cfg.algo.merge.frequency = 2;
    cfg.algo.elastic.mode = adloco::config::ElasticMode::UtilThreshold;
    cfg.algo.elastic.idle_threshold = 0.5;
    cfg.algo.elastic.max_instances = 4;
    cfg.run.eval_every = 4;
    cfg
}

#[test]
fn resume_is_bit_exact_across_spawn_boundary() {
    // the checkpoint at k=3 carries mid-run spawned instances (born at
    // outer 1) plus whatever merges already retired — the resumed pool
    // must rebuild ids, slots, registry and every stream exactly
    let cfg = elastic_cfg();
    assert_exact_resume(cfg, 3, "elastic_t1");
}

#[test]
fn resume_is_bit_exact_at_the_spawn_round_itself() {
    // k=1 is the round the first spawns happen: the snapshot is taken
    // with instances whose whole history is "just spawned"
    let cfg = elastic_cfg();
    assert_exact_resume(cfg, 1, "elastic_mid_spawn");
}

#[test]
fn resume_is_bit_exact_elastic_parallel_event() {
    let mut cfg = elastic_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "elastic_t4");
}

#[test]
fn resume_is_bit_exact_elastic_dynamic() {
    // the elastic_mit preset: spawns under churn + stragglers, resumed
    // mid-scenario
    let mut cfg = presets::elastic_mit();
    cfg.name = "resume_elastic_mit".into();
    cfg.algo.outer_steps = 6;
    assert_exact_resume(cfg, 3, "elastic_mit_t1");
}

#[test]
fn spawned_instances_survive_the_checkpoint_file() {
    // white-box: after the spawn round the snapshot's registry must
    // carry the spawned instances' structure, and the file must
    // roundtrip it exactly
    let cfg = elastic_cfg();
    let mut c = new_coord(&cfg);
    for t in 1..=2 {
        drive_step(&mut c, t);
    }
    let snap = c.snapshot(2);
    assert!(snap.spawn_count >= 1, "the elastic config must have spawned by k=2");
    assert_eq!(snap.registry.len(), snap.spawn_count as usize + 2);
    let spawned: Vec<_> =
        snap.registry.iter().filter(|r| r.origin == "util").collect();
    assert_eq!(spawned.len(), snap.spawn_count as usize);
    for row in &spawned {
        assert!(row.born_outer >= 1);
        assert!(!row.workers.is_empty(), "structure travels with the row");
    }
    assert_eq!(snap.rounds_count, 2, "round census accumulators travel too");
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("spawned.ckpt").to_str().unwrap().to_string();
    snap.save(&path).unwrap();
    let loaded = adloco::checkpoint::Checkpoint::load(&path).unwrap();
    assert_eq!(snap, loaded, "checkpoint file roundtrips the registry");
}

#[test]
fn pending_sync_survives_the_checkpoint_file() {
    // white-box: after k rounds of a delayed run every live trainer has
    // a collective in flight; the snapshot must carry it and the loaded
    // file must reproduce it exactly
    let mut cfg = base_cfg();
    cfg.name = "resume_pending".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.algo.merge.enabled = false; // keep every trainer alive + pending
    let mut c = new_coord(&cfg);
    for t in 1..=3 {
        drive_step(&mut c, t);
    }
    let snap = c.snapshot(3);
    assert_eq!(snap.trainers.len(), 3);
    for t in &snap.trainers {
        let p = t.pending.as_ref().expect("every trainer has a sync in flight");
        assert!(p.completes_at > p.posted_at);
        assert!(!p.delta.is_empty());
        assert!(!p.phases.is_empty());
    }
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pending.ckpt").to_str().unwrap().to_string();
    snap.save(&path).unwrap();
    let loaded = adloco::checkpoint::Checkpoint::load(&path).unwrap();
    assert_eq!(snap, loaded, "checkpoint file roundtrips the in-flight state");
}
