//! Exact-resume suite (checkpoint v4 interchange contract): a run
//! resumed from a checkpoint taken at outer step k must produce, from
//! step k+1 on, the **bit-identical** record streams, ledger
//! continuation, utilization accounting and final `RunResult` payload
//! of the uninterrupted run — on both schedulers, at 1 and 4 threads,
//! under the dynamic-workload scenario, and with delayed-overlap
//! collectives in flight across the resume point (DESIGN.md §8, §10).
//!
//! `best_ppl` is deliberately not compared: it minimizes over *all*
//! evaluations including the pre-checkpoint prefix the resumed run
//! never re-executes.
//!
//! Damage injection (truncation / bit flips / trailing garbage at
//! every offset class) lives in `tests/crash_fault.rs`; this suite owns
//! the happy paths plus the resume-time policy gates: config-digest
//! refusal, minimal warm-start, and checkpoint retention.

mod common;

use adloco::config::{presets, Config, OverlapMode, SchedulerKind};
use common::{assert_payloads_match, assert_suffix_matches, drive_step, new_coord};

/// Save at outer step `k`, resume, and assert the remaining record
/// stream plus the final `RunResult` payload are bit-identical to the
/// uninterrupted run.
fn assert_exact_resume(cfg: Config, k: u64, tag: &str) {
    // reference: the uninterrupted run
    let mut full = new_coord(&cfg);
    let rfull = full.run().unwrap();

    // truncated run: drive to k exactly as run() would, snapshot, stop
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.ckpt")).to_str().unwrap().to_string();
    let mut part = new_coord(&cfg);
    for t in 1..=k {
        drive_step(&mut part, t);
    }
    part.snapshot(k).save(&path).unwrap();

    // resumed run: same config + resume_from
    let mut cfg2 = cfg.clone();
    cfg2.run.resume_from = Some(path);
    let mut resumed = new_coord(&cfg2);
    let rres = resumed.run().unwrap();

    assert_payloads_match(&rfull, &rres, tag);
    assert_suffix_matches(&full.recorder, &resumed.recorder, k, tag);
}

/// The shared base schedule: small but feature-dense (multi-worker
/// trainers, adaptive batching, merging, mid-loop evals).
fn base_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "resume_base".into();
    cfg.algo.num_trainers = 3;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.outer_steps = 6;
    cfg.algo.inner_steps = 10;
    cfg.algo.merge.frequency = 2;
    cfg.run.eval_every = 4;
    cfg
}

#[test]
fn resume_is_bit_exact_lockstep_serial() {
    let cfg = base_cfg();
    assert_exact_resume(cfg, 3, "lockstep_t1");
}

#[test]
fn resume_is_bit_exact_event_serial() {
    let mut cfg = base_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    assert_exact_resume(cfg, 3, "event_t1");
}

#[test]
fn resume_is_bit_exact_event_parallel() {
    let mut cfg = base_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "event_t4");
}

#[test]
fn resume_is_bit_exact_lockstep_parallel() {
    // lockstep + threads > 1 routes through the event-equivalent path
    // (legal on static clusters); resume must hold there too
    let mut cfg = base_cfg();
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "lockstep_t4");
}

#[test]
fn resume_is_bit_exact_delayed_overlap_serial() {
    // the checkpoint at k carries trainer deltas still in flight
    // (posted at round k, applying at k+1) — the resumed run must land
    // the exact ledger rows and apply the exact stale updates
    let mut cfg = base_cfg();
    cfg.name = "resume_overlap".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg.run.scheduler = SchedulerKind::Event;
    assert_exact_resume(cfg, 3, "overlap_t1");
}

#[test]
fn resume_is_bit_exact_delayed_overlap_parallel() {
    let mut cfg = base_cfg();
    cfg.name = "resume_overlap_par".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "overlap_t4");
}

#[test]
fn resume_is_bit_exact_delayed_overlap_lockstep() {
    let mut cfg = base_cfg();
    cfg.name = "resume_overlap_lock".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    assert_exact_resume(cfg, 3, "overlap_lockstep");
}

#[test]
fn resume_is_bit_exact_hetero_dynamic() {
    // stragglers + churn + link shifts: the resume point sits inside the
    // dynamic scenario, so worker activity flags, per-step straggler
    // draws and the churn re-shard stream all cross the checkpoint
    let mut cfg = presets::hetero_dynamic();
    cfg.name = "resume_hetero".into();
    cfg.algo.outer_steps = 6;
    assert_exact_resume(cfg, 3, "hetero_t1");
}

#[test]
fn resume_is_bit_exact_hetero_dynamic_delayed() {
    let mut cfg = presets::adloco_overlap();
    cfg.name = "resume_hetero_overlap".into();
    cfg.algo.outer_steps = 6;
    assert_exact_resume(cfg, 3, "hetero_overlap_t1");
}

/// An elastic schedule whose first spawns are guaranteed at outer step
/// 1: two single-worker seed trainers over 4 nodes leave two nodes
/// fully unassigned (idle fraction 1.0 — DESIGN.md §9).
fn elastic_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "resume_elastic".into();
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.outer_steps = 6;
    cfg.algo.inner_steps = 10;
    cfg.algo.merge.frequency = 2;
    cfg.algo.elastic.mode = adloco::config::ElasticMode::UtilThreshold;
    cfg.algo.elastic.idle_threshold = 0.5;
    cfg.algo.elastic.max_instances = 4;
    cfg.run.eval_every = 4;
    cfg
}

#[test]
fn resume_is_bit_exact_across_spawn_boundary() {
    // the checkpoint at k=3 carries mid-run spawned instances (born at
    // outer 1) plus whatever merges already retired — the resumed pool
    // must rebuild ids, slots, registry and every stream exactly
    let cfg = elastic_cfg();
    assert_exact_resume(cfg, 3, "elastic_t1");
}

#[test]
fn resume_is_bit_exact_at_the_spawn_round_itself() {
    // k=1 is the round the first spawns happen: the snapshot is taken
    // with instances whose whole history is "just spawned"
    let cfg = elastic_cfg();
    assert_exact_resume(cfg, 1, "elastic_mid_spawn");
}

#[test]
fn resume_is_bit_exact_elastic_parallel_event() {
    let mut cfg = elastic_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    assert_exact_resume(cfg, 3, "elastic_t4");
}

#[test]
fn resume_is_bit_exact_elastic_dynamic() {
    // the elastic_mit preset: spawns under churn + stragglers, resumed
    // mid-scenario
    let mut cfg = presets::elastic_mit();
    cfg.name = "resume_elastic_mit".into();
    cfg.algo.outer_steps = 6;
    assert_exact_resume(cfg, 3, "elastic_mit_t1");
}

#[test]
fn spawned_instances_survive_the_checkpoint_file() {
    // white-box: after the spawn round the snapshot's registry must
    // carry the spawned instances' structure, and the file must
    // roundtrip it exactly
    let cfg = elastic_cfg();
    let mut c = new_coord(&cfg);
    for t in 1..=2 {
        drive_step(&mut c, t);
    }
    let snap = c.snapshot(2);
    assert!(snap.spawn_count >= 1, "the elastic config must have spawned by k=2");
    assert_eq!(snap.registry.len(), snap.spawn_count as usize + 2);
    let spawned: Vec<_> =
        snap.registry.iter().filter(|r| r.origin == "util").collect();
    assert_eq!(spawned.len(), snap.spawn_count as usize);
    for row in &spawned {
        assert!(row.born_outer >= 1);
        assert!(!row.workers.is_empty(), "structure travels with the row");
    }
    assert_eq!(snap.rounds_count, 2, "round census accumulators travel too");
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("spawned.ckpt").to_str().unwrap().to_string();
    snap.save(&path).unwrap();
    let loaded = adloco::checkpoint::Checkpoint::load(&path).unwrap();
    assert_eq!(snap, loaded, "checkpoint file roundtrips the registry");
}

#[test]
fn pending_sync_survives_the_checkpoint_file() {
    // white-box: after k rounds of a delayed run every live trainer has
    // a collective in flight; the snapshot must carry it and the loaded
    // file must reproduce it exactly
    let mut cfg = base_cfg();
    cfg.name = "resume_pending".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.algo.merge.enabled = false; // keep every trainer alive + pending
    let mut c = new_coord(&cfg);
    for t in 1..=3 {
        drive_step(&mut c, t);
    }
    let snap = c.snapshot(3);
    assert_eq!(snap.trainers.len(), 3);
    for t in &snap.trainers {
        let p = t.pending.as_ref().expect("every trainer has a sync in flight");
        assert!(p.completes_at > p.posted_at);
        assert!(!p.delta.is_empty());
        assert!(!p.phases.is_empty());
    }
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pending.ckpt").to_str().unwrap().to_string();
    snap.save(&path).unwrap();
    let loaded = adloco::checkpoint::Checkpoint::load(&path).unwrap();
    assert_eq!(snap, loaded, "checkpoint file roundtrips the in-flight state");
}

#[test]
fn warm_start_transfers_params_and_streams_only() {
    // white-box: warm-starting from a minimal interchange copies the
    // snapshot's outer parameters into the trainer and all its workers
    // and restores the RNG streams, but leaves the schedule fresh
    let cfg = base_cfg();
    let mut c = new_coord(&cfg);
    for t in 1..=3 {
        drive_step(&mut c, t);
    }
    let minimal = c.snapshot(3).to_minimal();
    assert!(!minimal.trainers.is_empty());

    let mut w = new_coord(&cfg);
    w.warm_start(&minimal).unwrap();
    let s0 = w.snapshot(0);
    for snap in &minimal.trainers {
        let t = s0
            .trainers
            .iter()
            .find(|t| t.id == snap.id)
            .expect("warm-started trainer exists");
        assert_eq!(t.params, snap.params, "trainer params transferred");
        for (wk, ws) in t.workers.iter().zip(snap.workers.iter()) {
            assert_eq!(wk.params, snap.params, "worker params transferred");
            assert_eq!(wk.noise_rng, ws.noise_rng, "noise stream transferred");
            assert_eq!(wk.time_rng, ws.time_rng, "time stream transferred");
        }
    }
    assert_eq!(s0.rng, minimal.rng, "coordinator stream transferred");
}

#[test]
fn resume_from_a_minimal_file_restarts_the_schedule() {
    // end-to-end: `run.resume_from` pointing at a minimal (warm-start)
    // file must run the whole schedule again, from outer step 1
    let cfg = base_cfg();
    let mut c = new_coord(&cfg);
    for t in 1..=3 {
        drive_step(&mut c, t);
    }
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("warm.ckpt").to_str().unwrap().to_string();
    c.snapshot(3).to_minimal().save(&path).unwrap();

    let mut cfg2 = cfg.clone();
    cfg2.run.resume_from = Some(path);
    let mut warm = new_coord(&cfg2);
    warm.run().unwrap();
    assert!(
        warm.recorder.steps.iter().any(|s| s.outer_step == 1),
        "the schedule restarts at outer step 1 after a warm start"
    );
    assert!(warm.recorder.steps.iter().any(|s| s.outer_step == 6));
}

#[test]
fn mismatched_config_digest_refuses_exact_resume() {
    // the checkpoint remembers the structural config it came from; an
    // exact resume under a structurally different config must be a
    // typed refusal, not a silent divergence
    let cfg = base_cfg();
    let mut c = new_coord(&cfg);
    drive_step(&mut c, 1);
    let dir = std::env::temp_dir().join("adloco_resume_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("digest.ckpt").to_str().unwrap().to_string();
    c.snapshot(1).save(&path).unwrap();

    let mut cfg2 = cfg.clone();
    cfg2.seed = cfg.seed + 1; // structural change
    cfg2.run.resume_from = Some(path);
    let err = new_coord(&cfg2).run().unwrap_err();
    assert!(
        format!("{err:#}").contains("different config"),
        "unexpected refusal message: {err:#}"
    );
}

#[test]
fn retention_keeps_last_n_plus_merge_pins_on_disk() {
    // end-to-end GC: with `keep_checkpoints = 2` and a checkpoint every
    // outer step, the run leaves exactly the last two step files plus
    // the merge-boundary pins — and a retained file resumes bit-exactly
    use adloco::checkpoint::retention;

    let dir = std::env::temp_dir().join("adloco_retention_run");
    let _ = std::fs::remove_dir_all(&dir); // stale files would pollute list_steps
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("run.ckpt").to_str().unwrap().to_string();

    let mut cfg = base_cfg();
    cfg.run.checkpoint_path = Some(base.clone());
    cfg.run.checkpoint_every = 1;
    cfg.run.keep_checkpoints = 2;
    let mut c = new_coord(&cfg);
    let rfull = c.run().unwrap();

    let pins: std::collections::BTreeSet<u64> =
        c.recorder.merges.iter().map(|m| m.outer_step).collect();
    assert!(!pins.is_empty(), "the base schedule merges at least once");
    let written: Vec<(u64, bool)> =
        (1..=6).map(|t| (t, pins.contains(&t))).collect();
    let want = retention::plan_retention(&written, 2);
    assert_eq!(retention::list_steps(&base), want, "on-disk set == retention plan");
    assert!(want.contains(&6), "the final checkpoint always survives");
    assert!(
        want.len() < 6,
        "retention actually pruned something (kept {want:?})"
    );

    // any retained step file is a first-class exact-resume source
    let k = *want.iter().filter(|s| **s < 6).max().unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.run.checkpoint_path = None;
    cfg2.run.keep_checkpoints = 0;
    cfg2.run.resume_from = Some(retention::step_file(&base, k));
    let mut resumed = new_coord(&cfg2);
    let rres = resumed.run().unwrap();
    assert_payloads_match(&rfull, &rres, "retention resume");
    assert_suffix_matches(&c.recorder, &resumed.recorder, k, "retention resume");
}
