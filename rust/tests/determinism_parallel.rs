//! Parallel-runtime determinism suite (DESIGN.md §6): running the same
//! config with `run.threads = 4` must produce **bit-identical** output
//! to the serial run — same `CommLedger` (kinds, bytes, participants,
//! `at_inner_step`s, timestamps down to `f64::to_bits`), same step /
//! eval / merge / utilization record streams, same `RunResult` payload —
//! for the quickstart and adloco_vs_diloco configurations and for the
//! `hetero_dynamic` dynamic-workload scenario. Threads buy wall-clock
//! only; any numerical divergence fails here first.
//!
//! The CI matrix additionally runs the whole test suite under
//! `RUN_THREADS=4` (presets default `run.threads = 0` = auto), so every
//! other test doubles as a determinism check.

use adloco::config::{presets, Config, Method, SchedulerKind};
use adloco::coordinator::{resolve_policy, Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;
use adloco::simulator::CommLedger;

fn run(cfg: Config) -> (RunResult, Recorder, CommLedger) {
    let engine = build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let r = c.run().unwrap();
    (r, c.recorder.clone(), c.ledger().clone())
}

/// Run `cfg` serially and at 4 threads; assert full bitwise agreement of
/// the determinism contract's payload (everything except wall-clock).
fn assert_threads_agree(mut cfg: Config) {
    cfg.run.threads = 1;
    let (ra, reca, leda) = run(cfg.clone());
    cfg.run.threads = 4;
    let (rb, recb, ledb) = run(cfg.clone());
    let name = &cfg.name;

    // ---- communication ledger ------------------------------------------
    assert_eq!(leda.count(), ledb.count(), "{name}: ledger count");
    assert_eq!(leda.total_bytes(), ledb.total_bytes(), "{name}: ledger bytes");
    for (i, (a, b)) in leda.events.iter().zip(ledb.events.iter()).enumerate() {
        assert_eq!(a.kind, b.kind, "{name}: event {i} kind");
        assert_eq!(a.scope, b.scope, "{name}: event {i} scope");
        assert_eq!(a.bytes, b.bytes, "{name}: event {i} bytes");
        assert_eq!(a.participants, b.participants, "{name}: event {i} participants");
        assert_eq!(a.at_inner_step, b.at_inner_step, "{name}: event {i} at_inner_step");
        assert_eq!(
            a.at_virtual_s.to_bits(),
            b.at_virtual_s.to_bits(),
            "{name}: event {i} timestamp ({} vs {})",
            a.at_virtual_s,
            b.at_virtual_s
        );
    }

    // ---- run summary (the RunResult f64s, bit for bit) -----------------
    assert_eq!(ra.total_samples, rb.total_samples, "{name}: samples");
    assert_eq!(ra.total_inner_steps, rb.total_inner_steps, "{name}: steps");
    assert_eq!(ra.trainers_left, rb.trainers_left, "{name}: trainers");
    assert_eq!(ra.comm_count, rb.comm_count, "{name}: comms");
    assert_eq!(ra.comm_bytes, rb.comm_bytes, "{name}: comm bytes");
    assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits(), "{name}: best ppl");
    assert_eq!(ra.final_ppl.to_bits(), rb.final_ppl.to_bits(), "{name}: final ppl");
    assert_eq!(
        ra.virtual_time_s.to_bits(),
        rb.virtual_time_s.to_bits(),
        "{name}: virtual time"
    );
    assert_eq!(
        ra.total_idle_s.to_bits(),
        rb.total_idle_s.to_bits(),
        "{name}: idle time"
    );
    assert_eq!(
        ra.mean_utilization.to_bits(),
        rb.mean_utilization.to_bits(),
        "{name}: utilization"
    );
    assert_eq!(ra.time_to_target, rb.time_to_target, "{name}: time to target");
    assert_eq!(
        ra.overlap_hidden_s.to_bits(),
        rb.overlap_hidden_s.to_bits(),
        "{name}: overlap hidden"
    );
    assert_eq!(ra.spawn_count, rb.spawn_count, "{name}: spawn count");
    assert_eq!(
        ra.mean_live_instances.to_bits(),
        rb.mean_live_instances.to_bits(),
        "{name}: mean live instances"
    );
    assert_eq!(
        ra.total_vacant_s.to_bits(),
        rb.total_vacant_s.to_bits(),
        "{name}: vacant time"
    );
    assert_eq!(rb.threads, 4, "{name}: resolved thread count");

    // ---- full record streams -------------------------------------------
    assert_eq!(reca.steps.len(), recb.steps.len(), "{name}: step records");
    for (a, b) in reca.steps.iter().zip(recb.steps.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer, a.worker, a.batch, a.accum_steps),
            (b.global_step, b.outer_step, b.trainer, b.worker, b.batch, b.accum_steps),
            "{name}: step identity"
        );
        assert_eq!(a.requested_batch, b.requested_batch, "{name}: requested batch");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{name}: step loss");
        assert_eq!(
            a.grad_sq_norm.to_bits(),
            b.grad_sq_norm.to_bits(),
            "{name}: step grad norm"
        );
        assert_eq!(a.sigma2.to_bits(), b.sigma2.to_bits(), "{name}: step sigma2");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{name}: step time"
        );
    }
    assert_eq!(reca.evals.len(), recb.evals.len(), "{name}: eval records");
    for (a, b) in reca.evals.iter().zip(recb.evals.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer, a.comm_count, a.comm_bytes),
            (b.global_step, b.outer_step, b.trainer, b.comm_count, b.comm_bytes),
            "{name}: eval identity"
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{name}: eval loss");
        assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits(), "{name}: eval ppl");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{name}: eval time"
        );
    }
    assert_eq!(reca.merges.len(), recb.merges.len(), "{name}: merges");
    for (a, b) in reca.merges.iter().zip(recb.merges.iter()) {
        assert_eq!(a.merged, b.merged, "{name}: merged set");
        assert_eq!(a.representative, b.representative, "{name}: representative");
        assert_eq!(a.trainers_left, b.trainers_left, "{name}: trainers left");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{name}: merge time"
        );
    }
    assert_eq!(
        reca.utilization.len(),
        recb.utilization.len(),
        "{name}: utilization rows"
    );
    for (a, b) in reca.utilization.iter().zip(recb.utilization.iter()) {
        assert_eq!(
            (a.trainer, a.worker, a.node),
            (b.trainer, b.worker, b.node),
            "{name}: utilization identity"
        );
        assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits(), "{name}: busy_s");
        assert_eq!(a.wait_s.to_bits(), b.wait_s.to_bits(), "{name}: wait_s");
        assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits(), "{name}: comm_s");
        assert_eq!(a.hidden_s.to_bits(), b.hidden_s.to_bits(), "{name}: hidden_s");
        assert_eq!(
            a.preempted_s.to_bits(),
            b.preempted_s.to_bits(),
            "{name}: preempted_s"
        );
        assert_eq!(a.vacant_s.to_bits(), b.vacant_s.to_bits(), "{name}: vacant_s");
    }
    assert_eq!(reca.rounds, recb.rounds, "{name}: round census");
    assert_eq!(
        reca.lifecycle.len(),
        recb.lifecycle.len(),
        "{name}: lifecycle records"
    );
    for (a, b) in reca.lifecycle.iter().zip(recb.lifecycle.iter()) {
        assert_eq!(
            (a.outer_step, a.instance, a.event, a.live_after),
            (b.outer_step, b.instance, b.event, b.live_after),
            "{name}: lifecycle identity"
        );
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{name}: lifecycle time"
        );
    }
}

/// The quickstart example's configuration (mock substrate, multi-worker
/// trainers, merging on), shrunk only where it does not change coverage.
fn quickstart_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "quickstart".into();
    cfg.algo.outer_steps = 6;
    cfg.algo.inner_steps = 15;
    cfg.algo.workers_per_trainer = 2;
    cfg.run.eval_every = 5;
    cfg
}

#[test]
fn quickstart_parallel_is_bit_identical_event() {
    let mut cfg = quickstart_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    assert_threads_agree(cfg);
}

#[test]
fn quickstart_parallel_is_bit_identical_lockstep() {
    // threads > 1 routes lockstep through the event-equivalent parallel
    // path; on the static cluster lockstep requires, that must still be
    // bit-identical to the serial lockstep reference walk
    let mut cfg = quickstart_cfg();
    cfg.run.scheduler = SchedulerKind::Lockstep;
    assert_threads_agree(cfg);
}

#[test]
fn adloco_vs_diloco_parallel_is_bit_identical() {
    // both arms of the adloco_vs_diloco comparison (mock substrate)
    for method in [Method::AdLoCo, Method::DiLoCo] {
        let mut cfg = presets::mock_default();
        cfg.name = format!("avd_{}", method.as_str());
        cfg.algo.method = method;
        cfg.algo.outer_steps = 5;
        cfg.algo.inner_steps = 12;
        cfg.algo.num_trainers = 3;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.merge.frequency = 2;
        cfg.run.eval_every = 4;
        cfg.run.scheduler = SchedulerKind::Event;
        let cfg = resolve_policy(&cfg);
        assert_threads_agree(cfg);
    }
}

#[test]
fn hetero_dynamic_parallel_is_bit_identical() {
    // the full dynamic-workload scenario: stragglers, a churn window,
    // link shifts, heterogeneous nodes — the hardest case for the
    // parallel runtime because time and noise streams interleave
    let mut cfg = presets::hetero_dynamic();
    cfg.algo.outer_steps = 6;
    assert_threads_agree(cfg);
}

#[test]
fn hierarchical_mit_parallel_is_bit_identical() {
    // the hierarchical two-level topology (DESIGN.md §7): intra-group
    // reduces, WAN leader rounds and topology-aware merge selection
    // must all be thread-transparent like everything else
    let mut cfg = presets::hierarchical_mit();
    cfg.algo.outer_steps = 6;
    assert_threads_agree(cfg);
}

#[test]
fn adloco_overlap_parallel_is_bit_identical() {
    // the delayed-overlap preset (DESIGN.md §8): non-blocking outer
    // collectives + stale outer updates on the full dynamic-workload
    // scenario must be thread-transparent like every other mode
    let mut cfg = presets::adloco_overlap();
    cfg.algo.outer_steps = 6;
    assert_threads_agree(cfg);
}

#[test]
fn elastic_mit_parallel_is_bit_identical() {
    // the elastic lifecycle (DESIGN.md §9) on the full dynamic-workload
    // scenario: the spawn controller, registry transitions and spawned
    // instances' private streams must all be thread-transparent
    let mut cfg = presets::elastic_mit();
    cfg.algo.outer_steps = 6;
    assert_threads_agree(cfg);
}

/// A static cluster where util_threshold spawns are *guaranteed*: two
/// single-worker seed trainers on a 4-node cluster leave nodes 2 and 3
/// entirely unassigned (idle fraction 1.0), so the controller fills
/// them at the very first boundary.
fn elastic_static_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "elastic_static".into();
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.outer_steps = 5;
    cfg.algo.inner_steps = 10;
    cfg.algo.merge.frequency = 2;
    cfg.algo.elastic.mode = adloco::config::ElasticMode::UtilThreshold;
    cfg.algo.elastic.idle_threshold = 0.5;
    cfg.algo.elastic.max_instances = 4;
    cfg.run.eval_every = 4;
    cfg
}

#[test]
fn elastic_spawns_parallel_is_bit_identical_event() {
    let mut cfg = elastic_static_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    assert_threads_agree(cfg);
}

#[test]
fn elastic_spawns_parallel_is_bit_identical_lockstep() {
    // threads > 1 routes lockstep through the event-equivalent path, so
    // this doubles as a lockstep-vs-event check with spawns in play
    let mut cfg = elastic_static_cfg();
    cfg.run.scheduler = SchedulerKind::Lockstep;
    assert_threads_agree(cfg);
}

#[test]
fn switch_mode_parallel_is_bit_identical() {
    // deep SwitchMode accumulation exercises the chain's grad/accum
    // scratch path (chain-local buffers vs the serial shared scratch)
    let mut cfg = quickstart_cfg();
    cfg.run.scheduler = SchedulerKind::Event;
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 2;
    }
    cfg.algo.batching.initial_batch = 10;
    cfg.algo.batching.max_request = 16;
    assert_threads_agree(cfg);
}

#[test]
fn thread_count_beyond_worker_count_is_fine() {
    // more threads than chains: the pool clamps, output unchanged
    let mut a = quickstart_cfg();
    a.run.scheduler = SchedulerKind::Event;
    a.run.threads = 1;
    let (ra, reca, _) = run(a);
    let mut b = quickstart_cfg();
    b.run.scheduler = SchedulerKind::Event;
    b.run.threads = 64;
    let (rb, recb, _) = run(b);
    assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits());
    assert_eq!(ra.virtual_time_s.to_bits(), rb.virtual_time_s.to_bits());
    assert_eq!(reca.steps.len(), recb.steps.len());
}
