//! Integration tests: cross-module flows over the public API, including
//! the PJRT-backed engine when artifacts are present.

use adloco::config::{presets, Config, Method};
use adloco::coordinator::{resolve_policy, run_experiment, Coordinator};
use adloco::engine::build_engine;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/tiny/meta.json").exists()
}

#[test]
fn run_experiment_writes_outputs() {
    let dir = std::env::temp_dir().join("adloco_it_out");
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = presets::quick();
    cfg.name = "it_quick".into();
    cfg.out_dir = Some(dir.to_str().unwrap().to_string());
    let r = run_experiment(cfg).unwrap();
    assert!(r.best_ppl.is_finite());
    let jsonl = dir.join("it_quick.jsonl");
    let csv = dir.join("it_quick.csv");
    assert!(jsonl.exists(), "missing {jsonl:?}");
    assert!(csv.exists(), "missing {csv:?}");
    // every jsonl line parses
    for line in std::fs::read_to_string(&jsonl).unwrap().lines() {
        adloco::util::JsonValue::parse(line).unwrap();
    }
}

#[test]
fn config_file_to_run_flow() {
    let dir = std::env::temp_dir().join("adloco_it_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(
        &path,
        r#"{
          "preset": "quick",
          "name": "from_file",
          "seed": 9,
          "algo": {"method": "diloco", "outer_steps": 2, "inner_steps": 5},
          "engine": {"kind": "mock", "dim": 100}
        }"#,
    )
    .unwrap();
    let cfg = Config::load(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.name, "from_file");
    assert_eq!(cfg.algo.method, Method::DiLoCo);
    let r = run_experiment(cfg).unwrap();
    assert!(r.best_ppl.is_finite());
    // `quick` preset starts 2 trainers; DiLoCo must not merge any away
    assert_eq!(r.trainers_left, 2, "diloco must not merge");
}

#[test]
fn cli_args_compose_with_config() {
    let args = adloco::cli::parse(
        ["train", "--preset", "quick", "--set", "algo.inner_steps=3", "--set", "seed=5"]
            .iter()
            .map(|s| s.to_string()),
    )
    .unwrap();
    let mut cfg = presets::by_name(args.opt("preset").unwrap()).unwrap();
    for s in args.opt_all("set") {
        cfg.apply_override(s).unwrap();
    }
    assert_eq!(cfg.algo.inner_steps, 3);
    assert_eq!(cfg.seed, 5);
    cfg.validate().unwrap();
}

#[test]
fn methods_rank_sanely_on_mock() {
    // On the shared setup, AdLoCo should spend less simulated wall-clock
    // and fewer communications than DiLoCo while staying competitive in
    // perplexity (the paper's Fig. 1 shape).
    let mut base = presets::mock_default();
    base.algo.outer_steps = 8;
    base.algo.inner_steps = 15;
    base.algo.workers_per_trainer = 2;
    base.algo.lr_inner = 0.15;

    let mut results = std::collections::BTreeMap::new();
    for m in [Method::AdLoCo, Method::DiLoCo] {
        let mut cfg = base.clone();
        cfg.algo.method = m;
        cfg.name = format!("rank_{}", m.as_str());
        let cfg = resolve_policy(&cfg);
        let engine = build_engine(&cfg).unwrap();
        let mut coord = Coordinator::new(cfg, engine).unwrap();
        let r = coord.run().unwrap();
        results.insert(m.as_str(), (r.best_ppl, r.virtual_time_s, r.comm_count));
    }
    let (ad_ppl, ad_time, ad_comms) = results["adloco"];
    let (di_ppl, di_time, di_comms) = results["diloco"];
    assert!(
        ad_time < di_time,
        "adloco should finish sooner in virtual time: {ad_time} vs {di_time}"
    );
    assert!(
        ad_comms <= di_comms,
        "adloco should not communicate more: {ad_comms} vs {di_comms}"
    );
    assert!(
        ad_ppl <= di_ppl * 2.0,
        "adloco perplexity should stay competitive: {ad_ppl} vs {di_ppl}"
    );
}

#[test]
fn hetero_dynamic_preset_runs_end_to_end() {
    // the dynamic-workload scenario the heterogeneous_cluster example
    // ships: stragglers + churn + a link shift on the event scheduler
    let mut cfg = presets::hetero_dynamic();
    cfg.name = "it_hetero".into();
    cfg.algo.outer_steps = 5; // keep the test fast
    cfg.algo.inner_steps = 10;
    let r = run_experiment(cfg).unwrap();
    assert!(r.best_ppl.is_finite());
    assert!(r.virtual_time_s > 0.0);
    assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
}

#[test]
fn xla_coordinator_short_run() {
    if !artifacts_present() {
        eprintln!("skipping xla integration (run `make artifacts`)");
        return;
    }
    let mut cfg = presets::xla_tiny();
    cfg.name = "it_xla".into();
    cfg.algo.outer_steps = 2;
    cfg.algo.inner_steps = 4;
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.merge.frequency = 2;
    cfg.run.eval_every = 2;
    cfg.run.eval_batches = 1;
    cfg.data.corpus_sequences = 256;
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    let r = coord.run().unwrap();
    assert!(r.best_ppl.is_finite());
    assert!(r.best_ppl < 500.0, "ppl {:.1} should be near/below vocab=256", r.best_ppl);
    assert!(!coord.recorder.steps.is_empty());
    // losses start near ln(256) ~ 5.55
    let l0 = coord.recorder.steps.first().unwrap().loss;
    assert!((l0 - 5.55).abs() < 1.0, "initial loss {l0}");
}

#[test]
fn xla_switch_mode_accumulates() {
    if !artifacts_present() {
        return;
    }
    let mut cfg = presets::xla_tiny();
    cfg.name = "it_xla_switch".into();
    cfg.algo.outer_steps = 1;
    cfg.algo.inner_steps = 2;
    cfg.algo.num_trainers = 1;
    cfg.algo.workers_per_trainer = 1;
    // force switch: request already above 2 * max_batch
    cfg.algo.batching.initial_batch = 40;
    cfg.algo.batching.max_request = 40;
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 8;
    }
    cfg.run.eval_every = 0;
    cfg.data.corpus_sequences = 128;
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    coord.run().unwrap();
    let s = coord.recorder.steps.first().unwrap();
    assert_eq!(s.batch, 8, "micro batch must be the node budget rung");
    assert_eq!(s.accum_steps, 5, "ceil(40/8) = 5 accumulation steps");
}

#[test]
fn xla_and_mock_agree_on_protocol() {
    // The coordinator must produce the same *shape* of record stream for
    // both engines (same schema, same per-step bookkeeping).
    let run = |cfg: Config| {
        let engine = build_engine(&cfg).unwrap();
        let mut coord = Coordinator::new(cfg, engine).unwrap();
        coord.run().unwrap();
        coord
            .recorder
            .steps
            .iter()
            .map(|s| (s.trainer, s.worker, s.accum_steps))
            .collect::<Vec<_>>()
    };
    let mut mock_cfg = presets::quick();
    mock_cfg.algo.num_trainers = 2;
    mock_cfg.algo.outer_steps = 2;
    mock_cfg.algo.inner_steps = 3;
    mock_cfg.algo.batching.adaptive = false;
    mock_cfg.algo.merge.enabled = false;
    let mock_stream = run(mock_cfg);

    if !artifacts_present() {
        return;
    }
    let mut xla_cfg = presets::xla_tiny();
    xla_cfg.algo.num_trainers = 2;
    xla_cfg.algo.outer_steps = 2;
    xla_cfg.algo.inner_steps = 3;
    xla_cfg.algo.batching.adaptive = false;
    xla_cfg.algo.merge.enabled = false;
    xla_cfg.run.eval_every = 0;
    xla_cfg.data.corpus_sequences = 128;
    let xla_stream = run(xla_cfg);
    assert_eq!(mock_stream, xla_stream, "record protocol must be engine-agnostic");
}

#[test]
fn checkpoint_resume_continues_run() {
    let dir = std::env::temp_dir().join("adloco_it_ckpt");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("run.ckpt").to_str().unwrap().to_string();

    // run 1: 4 outer steps, checkpoint every 2
    let mut cfg = presets::quick();
    cfg.name = "it_ckpt".into();
    cfg.algo.outer_steps = 4;
    cfg.run.checkpoint_path = Some(ckpt.clone());
    cfg.run.checkpoint_every = 2;
    let engine = build_engine(&cfg).unwrap();
    let mut c1 = Coordinator::new(cfg.clone(), engine).unwrap();
    let r1 = c1.run().unwrap();
    assert!(std::path::Path::new(&ckpt).exists());

    // the checkpoint reflects the final state
    let cp = adloco::checkpoint::Checkpoint::load(&ckpt).unwrap();
    assert_eq!(cp.outer_step, 4);
    assert_eq!(cp.total_samples, r1.total_samples);

    // run 2: same config extended to 6 outer steps, resuming from the
    // checkpoint: must skip straight past step 4 and keep the counters.
    let mut cfg2 = cfg.clone();
    cfg2.algo.outer_steps = 6;
    cfg2.run.resume_from = Some(ckpt.clone());
    cfg2.run.checkpoint_path = None;
    let engine2 = build_engine(&cfg2).unwrap();
    let mut c2 = Coordinator::new(cfg2, engine2).unwrap();
    let r2 = c2.run().unwrap();
    assert!(r2.total_samples > r1.total_samples, "resumed run must add samples");
    assert!(r2.best_ppl.is_finite());
    // resumed steps continue the per-trainer counters
    assert!(r2.total_inner_steps > r1.total_inner_steps);
}

#[test]
fn snapshot_restore_is_identity() {
    let cfg = presets::quick();
    let engine = build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg.clone(), engine).unwrap();
    c.step_outer(1).unwrap();
    let snap = c.snapshot(1);
    // fresh coordinator, restore, snapshot again: must match exactly
    let engine2 = build_engine(&cfg).unwrap();
    let mut c2 = Coordinator::new(cfg, engine2).unwrap();
    c2.restore(&snap).unwrap();
    let snap2 = c2.snapshot(1);
    assert_eq!(snap, snap2);
}
