//! Acceptance check for the zero-allocation steady state (DESIGN.md
//! §14): under `--features perf-count-alloc`, a steady-state outer
//! round (no merge / checkpoint boundary) at paper-scale params
//! performs **zero** param-sized heap allocations.
//!
//! Compiled out without the feature — CI runs this binary explicitly
//! via `cargo test --features perf-count-alloc --test alloc_steady`.
#![cfg(feature = "perf-count-alloc")]

use std::sync::Mutex;

use adloco::util::alloc_count;

/// The counting allocator and its large-allocation threshold are
/// process-global; tests in this binary serialize so one test's
/// metered window never observes another test's allocations.
static METER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    METER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Steady-round config mirroring the `round.steady(...)` micro bench:
/// merge and mid-run eval boundaries off, fixed batch, manual rounds.
fn steady_cfg(dim: usize, threads: usize) -> adloco::config::Config {
    let mut cfg = adloco::config::presets::mock_default();
    cfg.name = format!("alloc_steady_t{threads}");
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.inner_steps = 4;
    cfg.algo.outer_steps = 1_000_000; // rounds driven manually
    cfg.engine = adloco::config::EngineConfig::Mock { dim, noise: 1.0, condition: 10.0 };
    cfg.algo.batching.adaptive = false;
    cfg.algo.fixed_batch = 4;
    cfg.algo.merge.enabled = false;
    cfg.run.eval_every = 0;
    cfg.run.eval_batches = 1;
    cfg.data.val_sequences = 64;
    cfg.run.threads = threads;
    cfg
}

/// Drives `warm` unmetered rounds, then meters `rounds` more with the
/// param-sized threshold armed and returns the large-alloc delta.
fn metered_large_allocs(dim: usize, threads: usize, warm: u64, rounds: u64) -> u64 {
    let cfg = steady_cfg(dim, threads);
    let engine = adloco::engine::build_engine(&cfg).unwrap();
    let mut c = adloco::coordinator::Coordinator::new(cfg, engine).unwrap();
    let mut t = 0u64;
    for _ in 0..warm {
        t += 1;
        c.step_outer_event(t).unwrap();
    }
    // "param-sized" = at least one f32 parameter vector
    alloc_count::set_large_threshold(4 * dim);
    let before = alloc_count::snapshot();
    for _ in 0..rounds {
        t += 1;
        c.step_outer_event(t).unwrap();
    }
    let d = alloc_count::snapshot().since(before);
    alloc_count::set_large_threshold(usize::MAX);
    d.large_allocs
}

#[test]
fn steady_round_serial_makes_zero_param_sized_allocs() {
    let _g = lock();
    let large = metered_large_allocs(1_000_000, 1, 2, 3);
    assert_eq!(
        large, 0,
        "serial steady rounds at 1e6 params must not heap-allocate \
         param-sized buffers (counted {large} large allocations)"
    );
}

#[test]
fn steady_round_pooled_makes_zero_param_sized_allocs() {
    let _g = lock();
    let large = metered_large_allocs(1_000_000, 4, 2, 2);
    assert_eq!(
        large, 0,
        "pooled (threads=4) steady rounds at 1e6 params must not \
         heap-allocate param-sized buffers (counted {large} large allocations)"
    );
}
