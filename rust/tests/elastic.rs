//! Elastic trainer-lifecycle suite (DESIGN.md §9): the `elastic = off`
//! inertness anchor (the whole block must be bit-invisible when off),
//! guaranteed-spawn scenarios on both schedulers, lifecycle/registry
//! coherence, vacant-capacity accounting, and the elastic-vs-static
//! utilization comparison on the churn scenario.

mod common;

use adloco::config::{presets, Config, ElasticMode, SchedulerKind};
use adloco::coordinator::Coordinator;
use adloco::engine::build_engine;
use adloco::instances::LifecycleState;
use adloco::metrics::LifecycleEvent;
use common::{digest, run};

/// Run a config and also hand back the coordinator for registry
/// inspection.
fn run_keep(cfg: Config) -> Coordinator {
    let engine = build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    c.run().unwrap();
    c
}

/// ACC: `elastic = off` is bit-for-bit the pre-elastic behaviour — the
/// `elastic_mit` preset with the mode forced off must digest identically
/// to its `hetero_dynamic` twin, which never heard of the elastic block
/// at all (the FROZEN digest covers ledger, every record stream and the
/// RunResult payload).
#[test]
fn elastic_off_is_bit_identical_to_the_frozen_pool() {
    let mut off = presets::elastic_mit();
    off.algo.elastic.mode = ElasticMode::Off;
    let twin = presets::hetero_dynamic();
    let (r_off, rec_off, led_off) = run(off);
    let (r_twin, rec_twin, led_twin) = run(twin);
    assert_eq!(
        digest(&r_off, &rec_off, &led_off),
        digest(&r_twin, &rec_twin, &led_twin),
        "an inert elastic block must leave the record streams untouched"
    );
    assert_eq!(r_off.spawn_count, 0, "off ⇒ zero spawns");
    assert_eq!(rec_off.spawn_count(), 0);
    // the census still runs (it is a new stream, outside the frozen
    // digest) and reports the shrinking frozen pool
    assert_eq!(rec_off.rounds.len() as u64, 10);
    assert!(r_off.mean_live_instances <= 4.0);
}

/// A static cluster where util_threshold spawns are guaranteed at the
/// first boundary: 2 single-worker seed trainers over 4 nodes leave
/// nodes 2 and 3 unassigned (idle fraction 1.0).
fn guaranteed_spawn_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "elastic_guaranteed".into();
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.outer_steps = 5;
    cfg.algo.inner_steps = 10;
    cfg.algo.merge.frequency = 2;
    cfg.algo.elastic.mode = ElasticMode::UtilThreshold;
    cfg.algo.elastic.idle_threshold = 0.5;
    cfg.algo.elastic.max_instances = 4;
    cfg.run.eval_every = 4;
    cfg
}

#[test]
fn util_spawns_fill_unassigned_nodes_round_one() {
    let c = run_keep(guaranteed_spawn_cfg());
    let r = c.result();
    assert!(r.spawn_count >= 2, "both empty nodes must be filled, got {}", r.spawn_count);
    let spawns: Vec<_> = c
        .recorder
        .lifecycle
        .iter()
        .filter(|l| matches!(l.event, LifecycleEvent::Spawned { .. }))
        .collect();
    assert_eq!(spawns.len() as u64, r.spawn_count);
    // the first two spawns land at outer 1 on the unassigned nodes 2, 3
    assert_eq!(spawns[0].outer_step, 1);
    assert_eq!(spawns[1].outer_step, 1);
    let first_nodes: Vec<usize> = spawns[..2]
        .iter()
        .map(|l| match l.event {
            LifecycleEvent::Spawned { node } => node,
            _ => unreachable!(),
        })
        .collect();
    assert_eq!(first_nodes, vec![2, 3]);
    // spawned instances actually train: their step records exist
    for s in &spawns[..2] {
        assert!(
            c.recorder.steps.iter().any(|st| st.trainer == s.instance),
            "instance {} never stepped",
            s.instance
        );
    }
    // the census saw the pool grow from 2
    assert_eq!(c.recorder.rounds[0].live_instances, 4, "census runs after spawns");
    assert!(r.mean_live_instances > 2.0);
}

/// SAT3: lockstep and the event scheduler must agree bit-for-bit with
/// spawns in play (the spawn decision is a pure function of contract
/// state, and spawned streams are instance-private).
#[test]
fn elastic_lockstep_and_event_digest_identically() {
    let mk = |scheduler: SchedulerKind| {
        let mut cfg = guaranteed_spawn_cfg();
        cfg.run.scheduler = scheduler;
        cfg.run.threads = 1;
        cfg
    };
    let (rl, recl, ledl) = run(mk(SchedulerKind::Lockstep));
    let (re, rece, lede) = run(mk(SchedulerKind::Event));
    assert!(rl.spawn_count >= 2, "the comparison must actually cover spawns");
    assert_eq!(
        digest(&rl, &recl, &ledl),
        digest(&re, &rece, &lede),
        "lockstep vs event with spawns enabled"
    );
    assert_eq!(rl.spawn_count, re.spawn_count);
    assert_eq!(recl.rounds, rece.rounds);
}

#[test]
fn respawn_after_merge_refills_the_pool() {
    let mut cfg = presets::mock_default();
    cfg.name = "elastic_respawn".into();
    cfg.algo.outer_steps = 8;
    cfg.algo.inner_steps = 10;
    cfg.algo.merge.frequency = 2;
    cfg.algo.elastic.mode = ElasticMode::RespawnAfterMerge;
    cfg.algo.elastic.max_instances = 8;
    cfg.algo.elastic.node_capacity = 2;
    let c = run_keep(cfg);
    let r = c.result();
    let retired = c
        .recorder
        .lifecycle
        .iter()
        .filter(|l| l.event == LifecycleEvent::Retired)
        .count();
    assert!(retired >= 1, "mock_default merges must retire instances");
    assert!(r.spawn_count >= 1, "every merge round must respawn");
    // each respawn lands in the same round as a merge
    let merge_rounds: Vec<u64> = c.recorder.merges.iter().map(|m| m.outer_step).collect();
    for l in &c.recorder.lifecycle {
        if matches!(l.event, LifecycleEvent::Spawned { .. }) {
            assert!(
                merge_rounds.contains(&l.outer_step),
                "respawn at outer {} without a merge",
                l.outer_step
            );
        }
    }
    // registry coherence: live rows == live trainers, retired rows
    // carry their retirement round
    let reg = c.registry();
    assert_eq!(reg.live_count(), r.trainers_left);
    for m in reg.metas() {
        match m.state {
            LifecycleState::Retired => assert!(m.retired_outer.is_some()),
            _ => assert!(m.retired_outer.is_none()),
        }
    }
    assert_eq!(reg.spawn_count, r.spawn_count);
}

#[test]
fn vacant_capacity_accrues_only_for_retired_instances() {
    // a frozen pool with merges: the retired trainers' slots sit vacant
    // from their merge to the end of the run
    let mut cfg = presets::mock_default();
    cfg.name = "vacant_frozen".into();
    cfg.algo.outer_steps = 6;
    cfg.algo.inner_steps = 10;
    let c = run_keep(cfg);
    let r = c.result();
    assert!(r.trainers_left < 4, "mock_default merges must shrink the pool");
    assert!(r.total_vacant_s > 0.0, "retired slots must accrue vacancy");
    let dead: Vec<usize> = c
        .registry()
        .metas()
        .iter()
        .filter(|m| m.state == LifecycleState::Retired)
        .map(|m| m.id.0)
        .collect();
    for u in &c.recorder.utilization {
        if dead.contains(&u.trainer) {
            assert!(u.vacant_s > 0.0, "trainer {} retired but not vacant", u.trainer);
        } else {
            assert_eq!(u.vacant_s, 0.0, "live trainer {} accrued vacancy", u.trainer);
        }
    }
    // vacancy is not idleness: the contract fields are untouched
    let total: f64 = c.recorder.utilization.iter().map(|u| u.vacant_s).sum();
    assert!((total - r.total_vacant_s).abs() < 1e-9);
}

/// SAT2: a spawn that re-occupies merge-freed capacity closes that
/// node's vacancy window — the retired slot accrues vacancy only from
/// the merge barrier to the reclaiming spawn, not to the end of run.
#[test]
fn spawns_reclaim_vacancy_windows_fifo() {
    let c = run_keep(guaranteed_spawn_cfg());
    let r = c.result();
    let merge = c.recorder.merges.first().expect("the schedule must merge");
    let retired = merge.merged[0];
    let retired_node = c
        .recorder
        .utilization
        .iter()
        .find(|u| u.trainer == retired)
        .expect("retired trainer has a utilization row")
        .node;
    // the first spawn on the retired instance's node at or after the
    // merge barrier is the FIFO reclaim; the round-1 spawns predate the
    // merge and cannot close the window
    let reclaim = c
        .recorder
        .lifecycle
        .iter()
        .filter_map(|l| match l.event {
            LifecycleEvent::Spawned { node }
                if node == retired_node && l.virtual_time_s >= merge.virtual_time_s =>
            {
                Some(l.virtual_time_s)
            }
            _ => None,
        })
        .fold(f64::INFINITY, f64::min);
    assert!(
        reclaim.is_finite(),
        "the freed node is the only one with capacity, so the next spawn lands there"
    );
    let row = c
        .recorder
        .utilization
        .iter()
        .find(|u| u.trainer == retired)
        .unwrap();
    assert!(
        (row.vacant_s - (reclaim - merge.virtual_time_s)).abs() < 1e-9,
        "vacancy must end at the reclaiming spawn: {} vs {} - {}",
        row.vacant_s,
        reclaim,
        merge.virtual_time_s
    );
    assert!(
        row.vacant_s < r.virtual_time_s - merge.virtual_time_s,
        "the window must not run to the end of the run"
    );
}

/// ACC: on the churn scenario the elastic run spawns and utilizes the
/// cluster at least as well as the frozen twin, with ≥ 1 spawn event in
/// the lifecycle ledger.
#[test]
fn elastic_mit_spawns_and_does_not_waste_the_cluster() {
    let elastic = presets::elastic_mit();
    let frozen = presets::hetero_dynamic();
    let (re, rece, _lede) = run(elastic);
    let (rf, _recf, _ledf) = run(frozen);
    assert!(re.spawn_count >= 1, "elastic_mit must spawn on the churn scenario");
    assert!(rece.spawn_count() >= 1, "ledger must carry the spawn events");
    // trajectory property, not structural (merge selection diverges
    // once spawned instances join the pool), so this tier-1 test only
    // guards against a gross utilization regression; the exact ≥
    // comparison is the fig5 bench's job
    assert!(
        re.mean_utilization + 0.02 >= rf.mean_utilization,
        "elastic ({:.4}) utilizes grossly worse than static ({:.4})",
        re.mean_utilization,
        rf.mean_utilization
    );
    // live(t) ordering is provable: both runs merge at the same cadence
    // (removing w−1 = 1 per merge round while >1 instance lives), so
    // the elastic census dominates the frozen one and is strictly
    // larger from the first spawn on
    assert!(
        re.mean_live_instances > rf.mean_live_instances,
        "spawns must lift the live-instance census ({} vs {})",
        re.mean_live_instances,
        rf.mean_live_instances
    );
    assert!(re.total_samples > 0);
}

// ---------------------------------------------------------------------------
// registry restore edge cases (DESIGN.md §9, §10): the checkpoint path
// must rebuild any registry the run can produce — and refuse, cleanly,
// any shape a damaged file can produce
// ---------------------------------------------------------------------------

use adloco::instances::{InstanceId, InstanceMeta, InstanceRegistry, Origin};

#[test]
fn registry_restore_accepts_an_all_retired_pool() {
    // after enough merges every instance can be retired; a checkpoint
    // taken then holds only retired rows and must restore verbatim
    let mut reg = InstanceRegistry::seed(2, vec![1, 1, 1, 1]);
    let rows = [
        (0, Origin::Seed, 0, 0.0, Some(3)),
        (1, Origin::Seed, 0, 0.0, Some(5)),
        (2, Origin::Util, 1, 2.5, Some(3)),
        (3, Origin::Util, 1, 2.5, Some(5)),
    ];
    for (id, origin, born_outer, born_at_s, retired_outer) in rows {
        reg.restore_row(InstanceMeta {
            id: InstanceId(id),
            state: LifecycleState::Retired,
            born_outer,
            born_at_s,
            retired_outer,
            origin,
        })
        .unwrap();
    }
    assert_eq!(reg.len(), 4);
    assert_eq!(reg.live_count(), 0, "every row is retired");
    assert_eq!(reg.meta(2).retired_outer, Some(3));
    assert!(reg.metas().iter().all(|m| m.state == LifecycleState::Retired));
}

#[test]
fn registry_restore_rejects_an_id_gap_cleanly() {
    // a spawn recorded in the bookkeeping whose row never made it into
    // the file leaves a gap in the id sequence — a damaged checkpoint,
    // reported as an error rather than a panic
    let mut reg = InstanceRegistry::seed(2, vec![1, 1]);
    let err = reg
        .restore_row(InstanceMeta {
            id: InstanceId(4), // ids 2 and 3 are missing
            state: LifecycleState::Active,
            born_outer: 1,
            born_at_s: 1.0,
            retired_outer: None,
            origin: Origin::Util,
        })
        .unwrap_err();
    assert!(format!("{err:#}").contains("id order"), "{err:#}");
    assert_eq!(reg.len(), 2, "the failed row must not be applied");
}

#[test]
fn coordinator_restore_rejects_a_spawned_row_without_slots() {
    // the other half of "spawn recorded, slot never pushed": a spawned
    // registry row whose worker placement list is empty cannot be
    // rebuilt — the coordinator must refuse with a clean error
    let cfg = guaranteed_spawn_cfg();
    let mut c = run_keep_steps(&cfg, 2);
    let mut snap = c.snapshot(2);
    let initial = cfg.algo.num_trainers;
    let spawned = snap
        .registry
        .iter_mut()
        .find(|r| r.id >= initial)
        .expect("the guaranteed-spawn config spawned by outer 2");
    spawned.workers.clear();
    let engine2 = build_engine(&cfg).unwrap();
    let mut fresh = Coordinator::new(cfg.clone(), engine2).unwrap();
    let err = fresh.restore(&snap).unwrap_err();
    assert!(format!("{err:#}").contains("no workers"), "{err:#}");
}

#[test]
fn pool_full_at_checkpoint_time_stays_capped_after_resume() {
    // max_instances reached exactly at the checkpoint: outer 1 is the
    // spawn round (2 seeds + 2 spawns = max 4) and precedes the first
    // merge, so every row is live when the snapshot is taken; the
    // resumed run must carry the full pool and keep the live census
    // within the budget forever after
    let cfg = guaranteed_spawn_cfg(); // max_instances = 4
    let mut c = run_keep_steps(&cfg, 1);
    let snap = c.snapshot(1);
    assert_eq!(
        snap.registry.len(),
        cfg.algo.elastic.max_instances,
        "the pool must be full at the checkpoint"
    );
    assert!(
        snap.registry.iter().all(|r| r.state != "retired"),
        "nothing retired before the first merge"
    );

    let dir = std::env::temp_dir().join("adloco_elastic_suite");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("full_pool.ckpt").to_str().unwrap().to_string();
    snap.save(&path).unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.run.resume_from = Some(path);
    let engine2 = build_engine(&cfg2).unwrap();
    let mut resumed = Coordinator::new(cfg2, engine2).unwrap();
    resumed.run().unwrap();
    let fin = resumed.snapshot(cfg.algo.outer_steps as u64);
    let live = fin.registry.iter().filter(|r| r.state != "retired").count();
    assert!(
        live <= cfg.algo.elastic.max_instances,
        "resume must never grow the live pool past max_instances (got {live})"
    );
    assert!(fin.spawn_count >= snap.spawn_count, "spawn bookkeeping survives the resume");
}

/// Drive `k` outer steps exactly like `Coordinator::run` would (serial
/// lockstep on these configs) and hand the coordinator back.
fn run_keep_steps(cfg: &Config, k: u64) -> Coordinator {
    let engine = build_engine(cfg).unwrap();
    let mut c = Coordinator::new(cfg.clone(), engine).unwrap();
    for t in 1..=k {
        c.step_outer(t).unwrap();
    }
    c
}
