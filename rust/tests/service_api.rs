//! Black-box suite for `adloco serve` (DESIGN.md §13): the endpoint
//! matrix over a real loopback listener, the negative-path matrix with
//! exact `(status, code)` pairs, boundary-steered lifecycle
//! (pause → checkpoint → resume → cancel), deterministic queueing under
//! a bounded executor pool, and the headline contract — a run submitted
//! over HTTP is bit-identical (FNV digest) to the same config executed
//! one-shot through `run_experiment`.

mod common;

use adloco::config::{presets, ServiceConfig};
use adloco::coordinator::run_experiment;
use adloco::service::api::run_result_json;
use adloco::service::{Client, RunState, Server, SubmitRequest};
use adloco::util::JsonValue;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

fn service_cfg(max_runs: usize) -> ServiceConfig {
    ServiceConfig { max_concurrent_runs: max_runs, ..ServiceConfig::default() }
}

fn temp_root(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("adloco_service_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_str().unwrap().to_string()
}

fn start(tag: &str, cfg: ServiceConfig) -> (Server, Client) {
    let server = Server::start(cfg, &temp_root(tag)).unwrap();
    let client = Client::new(server.addr());
    (server, client)
}

/// Send raw bytes over a fresh connection and return `(status, body)`.
fn raw_roundtrip(server: &Server, bytes: &[u8]) -> (u16, JsonValue) {
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(bytes).unwrap();
    stream.flush().unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let head_end = raw.windows(4).position(|w| w == b"\r\n\r\n").unwrap();
    let head = std::str::from_utf8(&raw[..head_end]).unwrap();
    let status: u16 = head.split(' ').nth(1).unwrap().parse().unwrap();
    let body = std::str::from_utf8(&raw[head_end + 4..]).unwrap();
    (status, JsonValue::parse(body).unwrap())
}

fn error_code(v: &JsonValue) -> &str {
    v.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str()).unwrap_or("<none>")
}

/// Drop `keys` from a JSON object (determinism comparisons exclude
/// `wall_clock_s`; `threads` is equal by construction).
fn without_keys(v: &JsonValue, keys: &[&str]) -> JsonValue {
    match v {
        JsonValue::Object(fields) => JsonValue::Object(
            fields.iter().filter(|(k, _)| !keys.contains(&k.as_str())).cloned().collect(),
        ),
        other => other.clone(),
    }
}

/// Reassemble a terminal run's canonical JSONL bytes from the records
/// endpoint, exercising the cursor along the way.
fn fetch_records(client: &Client, id: u64) -> Vec<u8> {
    let page = client.records(id, 0).unwrap();
    assert_eq!(page.source, "final", "caller must wait for a terminal run");
    assert!(page.complete);
    assert_eq!(page.next, page.lines.len());
    // cursor semantics: fetching from the end yields an empty page, and
    // a mid-stream cursor serves the exact suffix
    let tail = client.records(id, page.next).unwrap();
    assert!(tail.lines.is_empty() && tail.complete && tail.next == page.next);
    let mid = page.lines.len() / 2;
    let suffix = client.records(id, mid).unwrap();
    assert_eq!(suffix.lines, page.lines[mid..].to_vec());
    let mut bytes = Vec::new();
    for l in &page.lines {
        bytes.extend_from_slice(l.as_bytes());
        bytes.push(b'\n');
    }
    bytes
}

// ---------------------------------------------------------------------------
// endpoint matrix: happy paths
// ---------------------------------------------------------------------------

#[test]
fn health_version_submit_and_result_round_trip() {
    let (server, client) = start("happy", service_cfg(1));
    assert!(client.health().unwrap());
    let v = client.version().unwrap();
    assert!(v.get("version").and_then(|x| x.as_str()).is_some());
    assert_eq!(
        v.get("checkpoint_format").and_then(|x| x.as_f64()),
        Some(adloco::checkpoint::VERSION as f64)
    );

    let req = SubmitRequest::preset("quick");
    let submitted = client.submit(&req).unwrap();
    assert_eq!(submitted.id, 0);
    assert_eq!(submitted.name, "quick");
    assert_eq!(submitted.outer_steps_total, presets::quick().algo.outer_steps as u64);

    let done = client.wait_terminal(0, Duration::from_secs(120)).unwrap();
    assert_eq!(done.state, RunState::Done);
    assert_eq!(done.started_order, Some(0));
    assert_eq!(done.outer_steps_done, done.outer_steps_total);
    assert_eq!(
        done.config_digest,
        format!("{:016x}", presets::quick().structural_digest())
    );

    let result = client.result(0).unwrap();
    assert_eq!(result.get("state").and_then(|s| s.as_str()), Some("done"));
    let payload = result.get("result").expect("done run carries a result");
    assert!(payload.get("total_inner_steps").and_then(|x| x.as_f64()).unwrap() > 0.0);

    let page = client.records(0, 0).unwrap();
    assert_eq!(page.source, "final");
    assert!(page.complete);
    assert!(!page.lines.is_empty(), "a finished run serves its canonical records");
    for line in &page.lines {
        JsonValue::parse(line).expect("every served records line is standalone JSON");
    }

    let (runs, totals) = client.runs().unwrap();
    assert_eq!(runs.len(), 1);
    assert_eq!(totals.get("total").and_then(|x| x.as_f64()), Some(1.0));
    assert_eq!(totals.get("done").and_then(|x| x.as_f64()), Some(1.0));
    server.shutdown();
}

// ---------------------------------------------------------------------------
// negative paths: exact (status, code) pairs, no panics, no silent 200s
// ---------------------------------------------------------------------------

#[test]
fn negative_paths_return_exact_typed_errors() {
    let (server, client) = start("negative", service_cfg(1));

    // malformed JSON body
    let (status, v) = raw_roundtrip(
        &server,
        b"POST /runs HTTP/1.1\r\ncontent-length: 5\r\n\r\n{oops",
    );
    assert_eq!((status, error_code(&v)), (400, "invalid_json"));

    // trailing garbage after a valid JSON document
    let (status, v) = raw_roundtrip(
        &server,
        b"POST /runs HTTP/1.1\r\ncontent-length: 20\r\n\r\n{\"preset\":\"quick\"} x",
    );
    assert_eq!((status, error_code(&v)), (400, "invalid_json"));

    // unknown field, strict deny-unknown-fields discipline
    let body = JsonValue::obj(vec![
        ("preset", JsonValue::str("quick")),
        ("bogus", JsonValue::num(1.0)),
    ]);
    let (status, v) = client.request("POST", "/runs", Some(&body)).unwrap();
    assert_eq!((status, error_code(&v)), (400, "unknown_field"));
    let msg = v.get("error").and_then(|e| e.get("message")).and_then(|m| m.as_str()).unwrap();
    assert!(msg.contains("submit.bogus"), "got: {msg}");

    // neither preset nor config
    let (status, v) = client.request("POST", "/runs", Some(&JsonValue::Object(vec![]))).unwrap();
    assert_eq!((status, error_code(&v)), (400, "missing_field"));

    // unknown preset
    let body = JsonValue::obj(vec![("preset", JsonValue::str("nope"))]);
    let (status, v) = client.request("POST", "/runs", Some(&body)).unwrap();
    assert_eq!((status, error_code(&v)), (400, "unknown_preset"));

    // config rejected by validate(), surfaced as invalid_config
    let body = JsonValue::obj(vec![
        ("preset", JsonValue::str("quick")),
        (
            "overrides",
            JsonValue::obj(vec![("algo.outer_steps", JsonValue::num(0.0))]),
        ),
    ]);
    let (status, v) = client.request("POST", "/runs", Some(&body)).unwrap();
    assert_eq!((status, error_code(&v)), (400, "invalid_config"));

    // wrong method on known endpoints
    let (status, v) = client.request("DELETE", "/runs", None).unwrap();
    assert_eq!((status, error_code(&v)), (405, "method_not_allowed"));
    let (status, v) = client.request("POST", "/health", None).unwrap();
    assert_eq!((status, error_code(&v)), (405, "method_not_allowed"));

    // unknown run ids and unknown endpoints
    let (status, v) = client.request("GET", "/runs/99", None).unwrap();
    assert_eq!((status, error_code(&v)), (404, "not_found"));
    let (status, v) = client.request("GET", "/runs/abc", None).unwrap();
    assert_eq!((status, error_code(&v)), (404, "not_found"));
    let (status, v) = client.request("GET", "/nope", None).unwrap();
    assert_eq!((status, error_code(&v)), (404, "not_found"));

    // bad query string
    let (status, v) = client.request("GET", "/runs/0/records?bogus=1", None).unwrap();
    assert_eq!((status, error_code(&v)), (400, "bad_query"));

    // mutation endpoints take no body
    let body = JsonValue::Object(vec![]);
    let (status, v) = client.request("POST", "/runs/0/cancel", Some(&body)).unwrap();
    assert_eq!((status, error_code(&v)), (400, "invalid_json"));

    // bad protocol version and transfer-encoding over the raw socket
    let (status, v) = raw_roundtrip(&server, b"GET /health HTTP/2\r\n\r\n");
    assert_eq!((status, error_code(&v)), (400, "bad_request"));
    let (status, v) = raw_roundtrip(
        &server,
        b"POST /runs HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
    );
    assert_eq!((status, error_code(&v)), (501, "unsupported"));

    // a run that exists but is not terminal: result is a 409
    let req = SubmitRequest::preset("quick")
        .with_override("algo.outer_steps", JsonValue::num(4000.0))
        .with_override("run.eval_every", JsonValue::num(1_000_000.0));
    let long = client.submit(&req).unwrap();
    let (status, v) = client.request("GET", &format!("/runs/{}/result", long.id), None).unwrap();
    assert_eq!((status, error_code(&v)), (409, "invalid_state"));
    // a terminal run rejects further mutations
    client.cancel_when_running(long.id);
    let fin = client.wait_terminal(long.id, Duration::from_secs(120)).unwrap();
    assert!(fin.state.is_terminal());
    let (status, v) =
        client.request("POST", &format!("/runs/{}/cancel", long.id), None).unwrap();
    assert_eq!((status, error_code(&v)), (409, "invalid_state"));

    server.shutdown();
}

/// Steering helper: keep trying until the registry has the run in a
/// mutable state (submission → claim is asynchronous).
trait SteerWhenRunning {
    fn cancel_when_running(&self, id: u64);
    fn pause_when_running(&self, id: u64) -> adloco::service::RunSummary;
}

impl SteerWhenRunning for Client {
    fn cancel_when_running(&self, id: u64) {
        loop {
            match self.cancel(id) {
                Ok(_) => return,
                Err(_) => {
                    if self.run(id).unwrap().state.is_terminal() {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    fn pause_when_running(&self, id: u64) -> adloco::service::RunSummary {
        loop {
            match self.pause(id) {
                Ok(s) => return s,
                Err(_) => {
                    assert!(
                        !self.run(id).unwrap().state.is_terminal(),
                        "run {id} finished before pause landed — schedule too short"
                    );
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }
}

#[test]
fn oversized_bodies_and_heads_get_413_and_431() {
    let tight = ServiceConfig {
        max_header_bytes: 256,
        max_body_bytes: 1024,
        ..service_cfg(1)
    };
    let (server, _client) = start("tight", tight);
    let (status, v) = raw_roundtrip(
        &server,
        b"POST /runs HTTP/1.1\r\ncontent-length: 5000\r\n\r\n",
    );
    assert_eq!((status, error_code(&v)), (413, "payload_too_large"));
    let mut junk = b"GET /".to_vec();
    junk.extend(vec![b'a'; 600]);
    let (status, v) = raw_roundtrip(&server, &junk);
    assert_eq!((status, error_code(&v)), (431, "header_too_large"));
    server.shutdown();
}

// ---------------------------------------------------------------------------
// lifecycle steering: every mutation lands at an outer-round boundary
// ---------------------------------------------------------------------------

#[test]
fn pause_checkpoint_resume_cancel_land_at_boundaries() {
    let (server, client) = start("steer", service_cfg(1));
    // a schedule far too long to finish on its own: the test ends it
    // with cancel, so only the boundaries it steers through actually run
    let req = SubmitRequest::preset("quick")
        .with_override("algo.outer_steps", JsonValue::num(50_000.0))
        .with_override("run.eval_every", JsonValue::num(1_000_000.0));
    let id = client.submit(&req).unwrap().id;

    let paused = client.pause_when_running(id);
    assert_eq!(paused.state, RunState::Paused);

    // while parked, records are served live from the part file
    let page = client.records(id, 0).unwrap();
    assert_eq!(page.source, "live");
    assert!(!page.complete);

    let ckpt_path = client.checkpoint(id).unwrap();
    let resumed = client.resume(id).unwrap();
    assert_eq!(resumed.state, RunState::Running);
    let after_cancel = client.cancel(id).unwrap();
    assert!(after_cancel.cancel_requested);

    let fin = client.wait_terminal(id, Duration::from_secs(120)).unwrap();
    assert_eq!(fin.state, RunState::Cancelled);
    assert!(
        fin.outer_steps_done < fin.outer_steps_total,
        "cancel must stop the schedule early ({}/{})",
        fin.outer_steps_done,
        fin.outer_steps_total
    );

    // the checkpoint requested while paused was written at the wake
    // boundary — before the cancel could land (hook order guarantee)
    assert_eq!(fin.checkpoints.len(), 1);
    let (ckpt_step, listed_path) = &fin.checkpoints[0];
    assert_eq!(listed_path, &ckpt_path);
    assert!(*ckpt_step <= fin.outer_steps_done);
    let ckpt = adloco::checkpoint::Checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(ckpt.outer_step, *ckpt_step);
    assert_eq!(format!("{:016x}", ckpt.config_digest), fin.config_digest);

    // a cancelled run still carries the truncated result
    let result = client.result(id).unwrap();
    assert_eq!(result.get("state").and_then(|s| s.as_str()), Some("cancelled"));
    assert!(result.get("result").is_some());
    server.shutdown();
}

// ---------------------------------------------------------------------------
// the headline: HTTP-served runs are bit-identical to one-shot execution
// ---------------------------------------------------------------------------

fn assert_served_matches_one_shot(preset: &str, threads: usize) {
    let tag = format!("ident_{preset}_t{threads}");
    let name = format!("svc_{preset}_t{threads}");
    let (server, client) = start(&tag, service_cfg(1));

    let req = SubmitRequest {
        name: Some(name.clone()),
        ..SubmitRequest::preset(preset)
    }
    .with_override("run.threads", JsonValue::num(threads as f64));
    let id = client.submit(&req).unwrap().id;
    let fin = client.wait_terminal(id, Duration::from_secs(300)).unwrap();
    assert_eq!(fin.state, RunState::Done, "{tag}: {:?}", fin.error);
    let served_jsonl = fetch_records(&client, id);
    let served_result = client.result(id).unwrap().get("result").unwrap().clone();
    let snap = server.registry().snapshot(id).unwrap();
    let served_csv = std::fs::read(snap.records_path.replace(".jsonl", ".csv")).unwrap();

    // one-shot arm: same config through run_experiment, buffered writer
    let dir = std::env::temp_dir().join(format!("adloco_service_oneshot_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = presets::by_name(preset).unwrap();
    cfg.name = name.clone();
    cfg.run.threads = threads;
    cfg.run.stream_records = false;
    cfg.out_dir = Some(dir.to_str().unwrap().to_string());
    let result = run_experiment(cfg).unwrap();
    let one_shot_jsonl = std::fs::read(dir.join(format!("{name}.jsonl"))).unwrap();
    let one_shot_csv = std::fs::read(dir.join(format!("{name}.csv"))).unwrap();

    assert_eq!(
        common::fnv1a(&served_jsonl),
        common::fnv1a(&one_shot_jsonl),
        "{tag}: HTTP-served records must be bit-identical to one-shot (len {} vs {})",
        served_jsonl.len(),
        one_shot_jsonl.len()
    );
    assert_eq!(
        common::fnv1a(&served_csv),
        common::fnv1a(&one_shot_csv),
        "{tag}: eval CSV must match"
    );
    assert_eq!(
        without_keys(&served_result, &["wall_clock_s"]),
        without_keys(&run_result_json(&result), &["wall_clock_s"]),
        "{tag}: RunResult payload must match minus wall-clock"
    );
    server.shutdown();
}

#[test]
fn served_run_is_bit_identical_lockstep_threads_1() {
    assert_served_matches_one_shot("quick", 1);
}

#[test]
fn served_run_is_bit_identical_lockstep_threads_4() {
    assert_served_matches_one_shot("quick", 4);
}

#[test]
fn served_run_is_bit_identical_hetero_dynamic_threads_1() {
    assert_served_matches_one_shot("hetero_dynamic", 1);
}

#[test]
fn served_run_is_bit_identical_hetero_dynamic_threads_4() {
    assert_served_matches_one_shot("hetero_dynamic", 4);
}

#[test]
fn served_run_is_bit_identical_elastic_mit_threads_1() {
    assert_served_matches_one_shot("elastic_mit", 1);
}

#[test]
fn served_run_is_bit_identical_elastic_mit_threads_4() {
    assert_served_matches_one_shot("elastic_mit", 4);
}

// ---------------------------------------------------------------------------
// bounded concurrency: deterministic queueing, serial-identical digests
// ---------------------------------------------------------------------------

#[test]
fn queued_runs_execute_fifo_with_serial_identical_digests() {
    const N: u64 = 5;
    let (server, client) = start("conc", service_cfg(2));
    for i in 0..N {
        let req = SubmitRequest {
            name: Some(format!("conc_{i}")),
            ..SubmitRequest::preset("quick")
        }
        .with_override("seed", JsonValue::num(100.0 + i as f64));
        assert_eq!(client.submit(&req).unwrap().id, i);
    }

    // totals are conserved at every instant: per-state counts sum to N
    let (_, totals) = client.runs().unwrap();
    let total = totals.get("total").and_then(|x| x.as_f64()).unwrap();
    let by_state: f64 = ["submitted", "running", "paused", "done", "failed", "cancelled"]
        .iter()
        .map(|k| totals.get(k).and_then(|x| x.as_f64()).unwrap())
        .sum();
    assert_eq!(total, N as f64);
    assert_eq!(by_state, total);

    for i in 0..N {
        let fin = client.wait_terminal(i, Duration::from_secs(120)).unwrap();
        assert_eq!(fin.state, RunState::Done, "run {i}: {:?}", fin.error);
        // the pool claims strictly FIFO, so the nth submission is the
        // nth start even with two executors racing
        assert_eq!(fin.started_order, Some(i), "run {i} started out of order");
    }
    let (_, totals) = client.runs().unwrap();
    assert_eq!(totals.get("done").and_then(|x| x.as_f64()), Some(N as f64));

    // each run's records and result are identical to a serial one-shot
    for i in 0..N {
        let served = fetch_records(&client, i);
        let served_result = client.result(i).unwrap().get("result").unwrap().clone();
        let dir = std::env::temp_dir().join(format!("adloco_service_conc_oneshot_{i}"));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = presets::quick();
        cfg.name = format!("conc_{i}");
        cfg.seed = 100 + i;
        cfg.out_dir = Some(dir.to_str().unwrap().to_string());
        let result = run_experiment(cfg).unwrap();
        let one_shot = std::fs::read(dir.join(format!("conc_{i}.jsonl"))).unwrap();
        assert_eq!(
            common::fnv1a(&served),
            common::fnv1a(&one_shot),
            "run {i}: concurrent execution changed the records"
        );
        assert_eq!(
            without_keys(&served_result, &["wall_clock_s"]),
            without_keys(&run_result_json(&result), &["wall_clock_s"]),
            "run {i}: concurrent execution changed the result"
        );
    }
    server.shutdown();
}
