//! Delayed-overlap suite (DESIGN.md §8): determinism of the ACCO-style
//! non-blocking outer sync across schedulers and thread counts, byte
//! conservation versus blocking, the in-flight gauge, and the theory
//! closed form `min(comm, next-round compute)` asserted against the
//! measured run on a static fixed-batch schedule.
//!
//! Blocking-mode bit-compatibility is guarded elsewhere: the flat and
//! hierarchical golden digests in `tests/topology.rs` run with the
//! default `comm.overlap = blocking` and must not move.

mod common;

use adloco::comm::NetworkModel;
use adloco::config::{presets, Config, OverlapMode, SchedulerKind};
use adloco::coordinator::Coordinator;
use adloco::engine::build_engine;
use adloco::theory::estimate_overlap;
use common::{digest_with_overlap, run};

fn delayed(mut cfg: Config) -> Config {
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg
}

/// A static schedule whose compute trajectory is mode-independent:
/// fixed batch (no adaptive feedback through the stale parameters), no
/// merging, no jitter/scenario — so blocking and delayed runs execute
/// the identical per-round compute and the overlap theory is exact.
fn static_fixed_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "overlap_theory".into();
    cfg.algo.num_trainers = 1;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.outer_steps = 6;
    cfg.algo.inner_steps = 12;
    cfg.algo.batching.adaptive = false;
    cfg.algo.merge.enabled = false;
    cfg.run.eval_every = 5;
    cfg
}

// ---------------------------------------------------------------------------
// determinism: delayed mode across schedulers and thread counts
// ---------------------------------------------------------------------------

/// SAT4: the delayed-overlap record stream gets its own golden digest
/// (extended serialization: clamp flags, per-worker hidden seconds,
/// `overlap_hidden_s`) pinned across the lockstep walk, the serial
/// event scheduler and the 4-thread runtime, with an optional
/// absolute-bits fixture like the topology goldens.
#[test]
fn delayed_golden_digest_across_schedulers_and_threads() {
    let mk = |sched: SchedulerKind, threads: usize| {
        let mut cfg = presets::mock_default();
        cfg.name = "overlap_golden".into();
        cfg.algo.outer_steps = 6;
        cfg.algo.inner_steps = 15;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.merge.frequency = 2;
        cfg.run.eval_every = 5;
        cfg.run.scheduler = sched;
        cfg.run.threads = threads;
        delayed(cfg)
    };
    let digest_of = |cfg: Config| {
        let (r, rec, ledger) = run(cfg);
        digest_with_overlap(&r, &rec, &ledger)
    };
    let lockstep = digest_of(mk(SchedulerKind::Lockstep, 1));
    let event = digest_of(mk(SchedulerKind::Event, 1));
    let parallel = digest_of(mk(SchedulerKind::Event, 4));
    assert_eq!(lockstep, event, "delayed: lockstep vs event digest");
    assert_eq!(event, parallel, "delayed: serial vs 4-thread digest");

    let fixture = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/overlap_golden.txt");
    if std::env::var("GOLDEN_WRITE").as_deref() == Ok("1") {
        std::fs::create_dir_all(fixture.parent().unwrap()).unwrap();
        std::fs::write(&fixture, &lockstep).unwrap();
    } else if fixture.exists() {
        let pinned = std::fs::read_to_string(&fixture).unwrap();
        assert_eq!(
            pinned.trim(),
            lockstep,
            "delayed-overlap record stream drifted from the pinned golden"
        );
    }
}

#[test]
fn delayed_hetero_dynamic_is_thread_deterministic() {
    // the adloco_overlap preset (stragglers + churn + link shifts) must
    // be bit-deterministic across thread counts like every other mode
    let mut base = presets::adloco_overlap();
    base.algo.outer_steps = 6;
    let digest_of = |threads: usize| {
        let mut cfg = base.clone();
        cfg.run.threads = threads;
        let (r, rec, ledger) = run(cfg);
        digest_with_overlap(&r, &rec, &ledger)
    };
    assert_eq!(digest_of(1), digest_of(4), "adloco_overlap serial vs 4 threads");
}

// ---------------------------------------------------------------------------
// semantics: conservation, staleness, the in-flight gauge
// ---------------------------------------------------------------------------

#[test]
fn delayed_conserves_ledger_bytes_and_events() {
    // same schedule, same collectives — the overlap changes *when* the
    // bytes are charged (completion timestamps) and when updates apply,
    // never how many bytes move
    let blocking = static_fixed_cfg();
    let (rb, recb, ledb) = run(blocking);
    let (rd, recd, ledd) = run(delayed(static_fixed_cfg()));
    assert_eq!(rd.comm_count, rb.comm_count, "event count conserved");
    assert_eq!(rd.comm_bytes, rb.comm_bytes, "total bytes conserved");
    assert_eq!(rd.wan_comm_bytes, rb.wan_comm_bytes, "WAN bytes conserved");
    assert_eq!(rd.total_samples, rb.total_samples, "sample schedule unchanged");
    assert_eq!(recd.steps.len(), recb.steps.len(), "step records unchanged");
    // the drain appends one final post-apply evaluation per live trainer
    assert_eq!(recd.evals.len(), recb.evals.len() + 1);
    // every delayed ledger event is stamped at its *completion* time and
    // the stream stays deterministic
    assert_eq!(ledd.count(), ledb.count());
    for e in &ledd.events {
        assert!(e.at_virtual_s > 0.0);
    }
    // round 1 runs from identical parameters in both modes, so the
    // first round's step records agree bit-for-bit; later rounds run on
    // stale parameters and legitimately diverge
    for (a, b) in recd
        .steps
        .iter()
        .zip(recb.steps.iter())
        .filter(|(a, _)| a.outer_step == 1)
    {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "round 1 must match");
    }
    let diverged = recd
        .steps
        .iter()
        .zip(recb.steps.iter())
        .filter(|(a, _)| a.outer_step > 2)
        .any(|(a, b)| a.loss.to_bits() != b.loss.to_bits());
    assert!(diverged, "staleness must actually change the trajectory");
}

#[test]
fn in_flight_gauge_balances_to_zero() {
    let cfg = delayed(static_fixed_cfg());
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    let r = coord.run().unwrap();
    assert!(r.overlap_hidden_s > 0.0, "something must have been hidden");
    assert_eq!(
        coord.in_flight_bytes(),
        0,
        "every posted collective must have been retired by run end"
    );
    assert!(coord.ledger().count() > 0);
}

#[test]
fn single_round_drains_fully_exposed() {
    // with one outer round there is no next round to hide under: the
    // sole collective drains fully exposed, so delayed == blocking in
    // wall-clock and nothing is hidden
    let mut cfg = static_fixed_cfg();
    cfg.algo.outer_steps = 1;
    let (rb, _, _) = run(cfg.clone());
    let (rd, _, _) = run(delayed(cfg));
    assert_eq!(rd.comm_count, rb.comm_count);
    // nothing to hide in a 1-round run (float dust only: the drain's
    // exposed residue is (t+d)-t, which can differ from d by an ulp)
    assert!(rd.overlap_hidden_s.abs() < 1e-12, "hidden {}", rd.overlap_hidden_s);
    assert!(
        (rd.virtual_time_s - rb.virtual_time_s).abs() < 1e-9,
        "fully-exposed drain must cost what blocking costs: {} vs {}",
        rd.virtual_time_s,
        rb.virtual_time_s
    );
}

#[test]
fn delayed_works_with_merging_and_hierarchical_topology() {
    // merges are full rendezvous: in-flight updates drain before the
    // consolidation, and the run completes with consolidated trainers
    let mut cfg = presets::hierarchical_mit();
    cfg.name = "overlap_hier".into();
    cfg.algo.outer_steps = 6;
    let (rb, _, _) = run(cfg.clone());
    let (rd, recd, _) = run(delayed(cfg));
    assert!(rd.best_ppl.is_finite());
    assert!(!recd.merges.is_empty(), "the preset must still merge");
    assert!(rd.overlap_hidden_s > 0.0);
    assert!(
        rd.virtual_time_s < rb.virtual_time_s,
        "hierarchical static run must finish sooner delayed: {} vs {}",
        rd.virtual_time_s,
        rb.virtual_time_s
    );
}

// ---------------------------------------------------------------------------
// theory: the closed form matches the measured run exactly (static)
// ---------------------------------------------------------------------------

#[test]
fn overlap_theory_matches_measured_wall_clock_on_static_run() {
    let cfg = static_fixed_cfg();
    let outer_steps = cfg.algo.outer_steps;
    // the collective duration every round: flat ring all-reduce over the
    // trainer's 2 workers — the exact closed form the comm layer prices
    let param_bytes = (build_engine(&cfg).unwrap().param_count() * 4) as u64;
    let net = NetworkModel {
        latency_s: cfg.cluster.net_latency_s,
        bandwidth_bps: cfg.cluster.net_bandwidth_bps,
    };
    let d = net.allreduce_time(param_bytes, 2);

    let (rb, _, ledb) = run(cfg.clone());
    let (rd, recd, _) = run(delayed(cfg));

    // per-round compute spans from the blocking ledger: each sync event
    // is stamped at barrier-end (= cohort front + d), so successive
    // stamps bracket exactly one round of compute
    assert_eq!(ledb.count(), outer_steps, "one sync per round expected");
    let mut compute = Vec::with_capacity(outer_steps);
    let mut prev_after = 0.0f64;
    for e in &ledb.events {
        compute.push((e.at_virtual_s - d) - prev_after);
        prev_after = e.at_virtual_s;
    }
    let comm = vec![d; outer_steps];
    let est = estimate_overlap(&compute, &comm);

    let tol = 1e-9 * rb.virtual_time_s.max(1.0);
    assert!(
        (est.blocking_time_s - rb.virtual_time_s).abs() < tol,
        "theory blocking {} vs measured {}",
        est.blocking_time_s,
        rb.virtual_time_s
    );
    assert!(
        (est.virtual_time_s - rd.virtual_time_s).abs() < tol,
        "theory delayed {} vs measured {}",
        est.virtual_time_s,
        rd.virtual_time_s
    );
    assert!(
        (est.hidden_s - rd.overlap_hidden_s).abs() < tol,
        "theory hidden {} vs measured {}",
        est.hidden_s,
        rd.overlap_hidden_s
    );
    // the headline inequality: delayed strictly beats blocking, by
    // exactly the hidden total (compute trajectories are identical on
    // this fixed-batch static schedule)
    assert!(rd.virtual_time_s < rb.virtual_time_s);
    assert!(
        ((rb.virtual_time_s - rd.virtual_time_s) - rd.overlap_hidden_s).abs() < tol,
        "saving {} must equal hidden {}",
        rb.virtual_time_s - rd.virtual_time_s,
        rd.overlap_hidden_s
    );
    // and the per-worker accounting agrees: both workers of the single
    // trainer saw every hidden second
    for u in &recd.utilization {
        assert!(
            (u.hidden_s - rd.overlap_hidden_s).abs() < tol,
            "worker hidden {} vs run hidden {}",
            u.hidden_s,
            rd.overlap_hidden_s
        );
    }
}
