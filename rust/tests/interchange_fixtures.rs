//! Golden-fixture cross-version matrix (DESIGN.md §10): one committed
//! container file per historical version (`tests/fixtures/v{1,2,3}.ckpt`),
//! each imported through the current interchange path and asserted
//! equivalent to a fresh v4 export of the same snapshot.
//!
//! The fixture bytes were written once by `tests/fixtures/make_fixtures.py`
//! (a toolchain-free mirror of the historical writers) and are pinned
//! by byte equality against `checkpoint::legacy::export_v{1,2,3}` —
//! regenerate with:
//!
//! ```text
//! GOLDEN_WRITE=1 cargo test --test interchange_fixtures
//! ```
//!
//! `fixture_complete()` here and the constants in `make_fixtures.py`
//! must stay in lockstep; every value is exactly representable so both
//! sides serialize identical bits.

use adloco::checkpoint::legacy::{export_v1, export_v2, export_v3};
use adloco::checkpoint::{
    import_bytes, Checkpoint, Interchange, MinimalCheckpoint, PendingSnapshot, PhaseSnapshot,
    RegistryRowSnapshot, RngSnapshot, SamplerSnapshot, TrainerSnapshot, WorkerSnapshot,
};

fn rng(s: [u64; 4], spare: Option<f64>) -> RngSnapshot {
    RngSnapshot { s, gauss_spare: spare }
}

fn rng_main() -> RngSnapshot {
    rng(
        [0x0123_4567_89ab_cdef, 0xfedc_ba98_7654_3210, 0x0f1e_2d3c_4b5a_6978, 0x1122_3344_5566_7788],
        Some(0.5),
    )
}

fn noise_a() -> RngSnapshot {
    rng(
        [0x1111_1111_1111_1111, 0x2222_2222_2222_2222, 0x3333_3333_3333_3333, 0x4444_4444_4444_4444],
        None,
    )
}

fn time_a() -> RngSnapshot {
    rng(
        [0x5555_5555_5555_5555, 0x6666_6666_6666_6666, 0x7777_7777_7777_7777, 0x8888_8888_8888_8888],
        Some(-0.75),
    )
}

fn noise_b() -> RngSnapshot {
    rng(
        [0xaaaa_aaaa_aaaa_aaaa, 0xbbbb_bbbb_bbbb_bbbb, 0xcccc_cccc_cccc_cccc, 0xdddd_dddd_dddd_dddd],
        None,
    )
}

fn time_b() -> RngSnapshot {
    rng(
        [0xeeee_eeee_eeee_eeee, 0xffff_ffff_ffff_ffff, 0x0123_0123_0123_0123, 0x4567_4567_4567_4567],
        None,
    )
}

/// The fixture snapshot: one trainer, two workers, a sync in flight,
/// a two-row registry — every field class of the complete variant.
fn fixture_complete() -> Checkpoint {
    Checkpoint {
        config_name: "fixture".into(),
        config_digest: 0, // legacy containers predate the digest
        outer_step: 3,
        total_samples: (1u64 << 53) + 1, // exercises the hex-over-JSON-number rule
        comm_count: 12,
        comm_bytes: 4096,
        comm_wan_bytes: 1024,
        overlap_hidden_s: 0.5,
        clock_times: vec![1.5, 2.25],
        busy_s: vec![1.0, 2.0],
        wait_s: vec![0.25, 0.0],
        comm_s: vec![0.125, 0.0625],
        comm_hidden_s: vec![0.0, 0.0],
        preempted_s: vec![0.0, 0.5],
        vacant_s: vec![0.0, 0.75],
        spawn_count: 1,
        last_spawn_outer: 2,
        last_merge_rep: Some(0),
        live_rounds_sum: 5,
        rounds_count: 3,
        registry: vec![
            RegistryRowSnapshot {
                id: 0,
                state: "active".into(),
                origin: "seed".into(),
                born_outer: 0,
                born_at_s: 0.0,
                retired_outer: None,
                workers: vec![(0, 0)],
            },
            RegistryRowSnapshot {
                id: 1,
                state: "spawned".into(),
                origin: "util".into(),
                born_outer: 2,
                born_at_s: 3.5,
                retired_outer: None,
                workers: vec![(1, 1)],
            },
        ],
        rng: rng_main(),
        trainers: vec![TrainerSnapshot {
            id: 0,
            params: vec![0.5, -1.25, 3.0, 0.0625],
            outer_velocity: vec![0.125, -0.5, 0.0, 2.0],
            requested_batch: 8,
            inner_steps_done: 18,
            observations: 36,
            sigma2_ema: (0.5, 36),
            ip_var_ema: (0.25, 36),
            s1_ema: (0.125, 36),
            shard: vec![0, 2, 4],
            pending: Some(PendingSnapshot {
                posted_at: 3.5,
                completes_at: 3.75,
                time_s: 0.25,
                sent_samples: 4096,
                phases: vec![
                    PhaseSnapshot { wan: false, bytes: 512, participants: 2 },
                    PhaseSnapshot { wan: true, bytes: 256, participants: 2 },
                ],
                delta: vec![0.25, -0.25, 0.5, -0.5],
            }),
            workers: vec![
                WorkerSnapshot {
                    params: vec![1.0, 2.0, -3.0, 0.25],
                    m: vec![0.0625, 0.0, -0.0625, 0.125],
                    v: vec![0.5, 0.25, 0.125, 0.0625],
                    step: 18,
                    active: true,
                    noise_rng: noise_a(),
                    time_rng: time_a(),
                    sampler: SamplerSnapshot {
                        shard: vec![0, 2, 4],
                        order: vec![2, 0, 1],
                        cursor: 1,
                        drawn: 6,
                        rng: rng([9, 10, 11, 12], None),
                    },
                },
                WorkerSnapshot {
                    params: vec![-1.0, 0.5, 0.75, -0.125],
                    m: vec![0.25, -0.25, 0.0, 0.5],
                    v: vec![0.0625, 0.125, 0.25, 0.5],
                    step: 18,
                    active: false,
                    noise_rng: noise_b(),
                    time_rng: time_b(),
                    sampler: SamplerSnapshot {
                        shard: vec![1, 3, 5],
                        order: vec![0, 1, 2],
                        cursor: 0,
                        drawn: 0,
                        rng: rng([13, 14, 15, 16], Some(1.5)),
                    },
                },
            ],
        }],
    }
}

/// Read a committed fixture; with `GOLDEN_WRITE=1`, (re)write it from
/// the current historical writer first.
fn fixture_bytes(name: &str, regen: impl Fn() -> Vec<u8>) -> Vec<u8> {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    if std::env::var("GOLDEN_WRITE").is_ok() {
        std::fs::write(&path, regen()).unwrap();
    }
    std::fs::read(&path).unwrap_or_else(|e| {
        panic!("missing fixture {path}: {e}; regenerate with GOLDEN_WRITE=1")
    })
}

fn import_complete(raw: &[u8], what: &str) -> Checkpoint {
    match import_bytes(raw).unwrap_or_else(|e| panic!("{what}: {e}")) {
        Interchange::Complete(cp) => cp,
        Interchange::Minimal(_) => panic!("{what}: expected the complete variant"),
    }
}

#[test]
fn fixtures_match_the_current_writers_byte_for_byte() {
    // the committed bytes (from make_fixtures.py) and the Rust
    // historical writers must agree exactly — any drift in either
    // encoder shows up here before it can corrupt the migration story
    let cp = fixture_complete();
    assert_eq!(fixture_bytes("v3.ckpt", || export_v3(&cp)), export_v3(&cp), "v3");
    assert_eq!(fixture_bytes("v2.ckpt", || export_v2(&cp)), export_v2(&cp), "v2");
    let min = cp.to_minimal();
    assert_eq!(fixture_bytes("v1.ckpt", || export_v1(&min)), export_v1(&min), "v1");
}

#[test]
fn v3_fixture_imports_losslessly() {
    let cp = import_complete(&fixture_bytes("v3.ckpt", || export_v3(&fixture_complete())), "v3");
    assert_eq!(cp, fixture_complete());
}

#[test]
fn v2_fixture_imports_with_elastic_defaults() {
    let cp = import_complete(&fixture_bytes("v2.ckpt", || export_v2(&fixture_complete())), "v2");
    let want = fixture_complete();
    assert_eq!(cp.trainers, want.trainers);
    assert_eq!(cp.outer_step, want.outer_step);
    assert_eq!(cp.total_samples, want.total_samples);
    assert_eq!(cp.clock_times, want.clock_times);
    assert_eq!(cp.busy_s, want.busy_s);
    assert_eq!(cp.rng, want.rng);
    // v2 could not express the elastic lifecycle: zero vacancy/spawn
    // bookkeeping and a synthesized one-row seed registry
    assert_eq!(cp.vacant_s, vec![0.0; want.clock_times.len()]);
    assert_eq!(cp.spawn_count, 0);
    assert_eq!(cp.last_merge_rep, None);
    assert_eq!(cp.registry.len(), 1);
    assert_eq!(cp.registry[0].id, 0);
    assert_eq!(cp.registry[0].state, "active");
    assert_eq!(cp.registry[0].origin, "seed");
}

#[test]
fn v1_fixture_imports_as_minimal() {
    let raw = fixture_bytes("v1.ckpt", || export_v1(&fixture_complete().to_minimal()));
    let min = match import_bytes(&raw).unwrap() {
        Interchange::Minimal(m) => m,
        Interchange::Complete(_) => panic!("v1 must import as the minimal variant"),
    };
    assert_eq!(min, fixture_complete().to_minimal());
}

#[test]
fn every_fixture_reexports_to_an_equivalent_v4() {
    // the acceptance bar: import vN, write v4, read it back — nothing
    // may be lost or altered, and the v4 encode must be deterministic
    for (name, raw) in [
        ("v2", fixture_bytes("v2.ckpt", || export_v2(&fixture_complete()))),
        ("v3", fixture_bytes("v3.ckpt", || export_v3(&fixture_complete()))),
    ] {
        let cp = import_complete(&raw, name);
        let v4 = cp.to_bytes();
        assert_eq!(v4, cp.to_bytes(), "{name}: v4 encode is deterministic");
        assert_eq!(
            import_complete(&v4, name),
            cp,
            "{name}: v4 re-export round-trips the import"
        );
    }
    let raw = fixture_bytes("v1.ckpt", || export_v1(&fixture_complete().to_minimal()));
    let min = match import_bytes(&raw).unwrap() {
        Interchange::Minimal(m) => m,
        other => panic!("v1: {other:?}"),
    };
    let v4 = min.to_bytes();
    match import_bytes(&v4).unwrap() {
        Interchange::Minimal(back) => assert_eq!(back, min, "v1 → v4 minimal round-trip"),
        other => panic!("v4 minimal decoded as {other:?}"),
    }
}

#[test]
fn damaged_fixtures_fail_with_typed_errors() {
    // the legacy import path shares the no-silent-resume contract:
    // cuts and flips on the committed bytes are typed errors
    let cp = fixture_complete();
    for (name, regen) in [
        ("v1.ckpt", export_v1(&cp.to_minimal())),
        ("v2.ckpt", export_v2(&cp)),
        ("v3.ckpt", export_v3(&cp)),
    ] {
        let raw = fixture_bytes(name, || regen.clone());
        for cut in [0, 7, 11, raw.len() / 2, raw.len() - 1] {
            assert!(import_bytes(&raw[..cut]).is_err(), "{name}: cut {cut} accepted");
        }
        for pos in [9, 12, raw.len() / 2, raw.len() - 2] {
            let mut flipped = raw.clone();
            flipped[pos] ^= 0x40;
            assert!(import_bytes(&flipped).is_err(), "{name}: flip {pos} accepted");
        }
    }
}

#[test]
fn minimal_checkpoint_matches_its_v1_ancestor_semantics() {
    // `to_minimal` of the fixture and the v1 container describe the
    // same snapshot: same ids, params and stream states
    let min = fixture_complete().to_minimal();
    assert_eq!(min.config_name, "fixture");
    assert_eq!(min.outer_step, 3);
    assert_eq!(min.trainers.len(), 1);
    assert_eq!(min.trainers[0].params, vec![0.5, -1.25, 3.0, 0.0625]);
    assert_eq!(min.trainers[0].workers.len(), 2);
    assert_eq!(min.trainers[0].workers[0].noise_rng, noise_a());
    assert_eq!(min.trainers[0].workers[1].time_rng, time_b());
    let _: &MinimalCheckpoint = &min; // the variant exact resume refuses
    let err = Checkpoint::from_bytes(&min.to_bytes()).unwrap_err();
    assert!(format!("{err:#}").contains("minimal"), "{err:#}");
}
