//! Trace-replay equivalence suite (DESIGN.md §11): a stochastic
//! scenario exported as a workload trace and replayed through the
//! `ScenarioSource` seam must reproduce the original run **bit for
//! bit** — record streams, comm ledger, and the `RunResult` payload.
//! Also pins the traced golden seam (lockstep == event == threads{1,4}
//! on a lockstep-legal diurnal trace, DESIGN.md §6), the fleet-scale
//! preset's cross-thread identity, the theory comm estimate on a
//! traced run (traces move *when* syncs happen, never how many or how
//! big — EXPERIMENTS.md §Figures, Fig. 6), and the runtime guard that
//! keeps dynamic traces off the lockstep walk.

mod common;

use adloco::cluster::{assign_workers, Topology};
use adloco::config::{
    presets, Config, EngineConfig, ScenarioConfig, SchedulerKind, TopologyKind,
    TraceGenConfig, TraceGenKind, TraceSourceConfig,
};
use adloco::engine::build_engine;
use adloco::simulator::Trace;
use adloco::theory::{estimate_ledger, TopoShape};
use common::{assert_payloads_match, digest, run};

/// Unique-per-process temp path for an exported trace file.
fn tmp_trace(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("adloco_trace_replay_{}_{tag}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Run a stochastic preset, export its scenario as a trace file, replay
/// the trace through `cluster.trace = Path(..)`, and assert the two
/// runs are bit-identical end to end.
fn assert_replay_matches(cfg: Config, tag: &str) {
    let nodes = cfg.cluster.nodes.len();
    let trace = Trace::from_scenario(&cfg.cluster.scenario, nodes);
    assert!(
        !trace.records.is_empty(),
        "{tag}: the stochastic preset must export a non-trivial trace"
    );
    let path = tmp_trace(tag);
    trace.save(&path).unwrap();
    // the file must round-trip before we trust the replay comparison
    assert_eq!(Trace::load(&path).unwrap(), trace, "{tag}: save/load round-trip");

    // the trace fully replaces the stochastic scenario block (straggler
    // parameters ride in the trace header); leaving any of it set would
    // be an ambiguous double source and is rejected by validate()
    let mut replay = cfg.clone();
    replay.name = format!("{}_replay", cfg.name);
    replay.cluster.scenario = ScenarioConfig::default();
    replay.cluster.trace = TraceSourceConfig::Path(path.clone());

    let (r_a, rec_a, led_a) = run(cfg);
    let (r_b, rec_b, led_b) = run(replay);
    assert_eq!(
        digest(&r_a, &rec_a, &led_a),
        digest(&r_b, &rec_b, &led_b),
        "{tag}: stochastic vs trace-replay record streams must be bit-identical"
    );
    assert_payloads_match(&r_a, &r_b, tag);
    std::fs::remove_file(&path).ok();
}

#[test]
fn hetero_dynamic_replays_bit_identically() {
    assert_replay_matches(presets::hetero_dynamic(), "hetero_dynamic");
}

#[test]
fn elastic_mit_replays_bit_identically() {
    assert_replay_matches(presets::elastic_mit(), "elastic_mit");
}

// ---------------------------------------------------------------------------
// golden seam on a traced (lockstep-legal) preset
// ---------------------------------------------------------------------------

/// A small diurnal-load config: speed timelines are deterministic, so
/// the trace is expressible on every scheduler (DESIGN.md §11).
fn diurnal_cfg(scheduler: SchedulerKind, threads: usize) -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = "trace_diurnal_seam".into();
    cfg.engine = EngineConfig::Mock { dim: 64, noise: 1.0, condition: 10.0 };
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.inner_steps = 6;
    cfg.algo.outer_steps = 3;
    cfg.data.corpus_sequences = 600;
    cfg.data.val_sequences = 32;
    cfg.cluster.trace = TraceSourceConfig::Generator(TraceGenConfig {
        kind: TraceGenKind::Diurnal,
        horizon_s: 10.0,
        period_s: 2.0,
        amplitude: 0.5,
        samples_per_period: 8,
        ..TraceGenConfig::default()
    });
    cfg.run.scheduler = scheduler;
    cfg.run.threads = threads;
    cfg
}

/// Lockstep == event == threads{1,4} on the diurnal trace, with a
/// golden fixture (`GOLDEN_WRITE=1` creates it on a reference machine)
/// additionally pinning the absolute record stream.
#[test]
fn diurnal_trace_seam_is_scheduler_and_thread_invariant() {
    let (r_l, rec_l, led_l) = run(diurnal_cfg(SchedulerKind::Lockstep, 1));
    let (r_e, rec_e, led_e) = run(diurnal_cfg(SchedulerKind::Event, 1));
    let (r_p, rec_p, led_p) = run(diurnal_cfg(SchedulerKind::Event, 4));
    let lockstep = digest(&r_l, &rec_l, &led_l);
    let event = digest(&r_e, &rec_e, &led_e);
    let parallel = digest(&r_p, &rec_p, &led_p);
    assert_eq!(lockstep, event, "diurnal trace: lockstep vs event digest");
    assert_eq!(event, parallel, "diurnal trace: serial vs 4-thread digest");
    assert_payloads_match(&r_l, &r_e, "diurnal lockstep vs event");
    assert_payloads_match(&r_e, &r_p, "diurnal serial vs parallel");
    // the speed timelines must actually engage: a diurnal factor > 1
    // stretches virtual time relative to the untraced twin
    let mut flat = diurnal_cfg(SchedulerKind::Lockstep, 1);
    flat.cluster.trace = TraceSourceConfig::Stochastic;
    let (r_flat, _, _) = run(flat);
    assert!(
        r_l.virtual_time_s > r_flat.virtual_time_s,
        "diurnal slowdown must stretch virtual time: {} vs {}",
        r_l.virtual_time_s,
        r_flat.virtual_time_s
    );

    let fixture = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/trace_diurnal.txt");
    if std::env::var("GOLDEN_WRITE").as_deref() == Ok("1") {
        std::fs::create_dir_all(fixture.parent().unwrap()).unwrap();
        std::fs::write(&fixture, &lockstep).unwrap();
    } else if fixture.exists() {
        let pinned = std::fs::read_to_string(&fixture).unwrap();
        assert_eq!(
            lockstep,
            pinned.trim(),
            "trace_diurnal: record stream drifted from the pinned golden"
        );
    }
}

// ---------------------------------------------------------------------------
// fleet preset: cross-thread identity + theory estimate on a traced run
// ---------------------------------------------------------------------------

#[test]
fn fleet_trace_threads_agree_and_match_theory() {
    let mk = |threads: usize| {
        let mut cfg = presets::fleet_trace();
        cfg.run.threads = threads;
        cfg
    };
    let cfg = mk(1);
    let param_bytes = (build_engine(&cfg).unwrap().param_count() * 4) as u64;
    let outer_steps = cfg.algo.outer_steps as u64;
    let k = cfg.algo.num_trainers;
    let m = cfg.algo.workers_per_trainer;
    let placement = assign_workers(k * m, cfg.cluster.nodes.len());
    let topo = Topology::compile(&cfg.cluster);
    assert_eq!(cfg.cluster.topology, TopologyKind::Flat);
    let shapes: Vec<TopoShape> = (0..k).map(|_| TopoShape::Flat { m }).collect();
    let homes: Vec<usize> = (0..k).map(|i| topo.group_of(placement[i * m])).collect();

    let (r1, rec1, led1) = run(cfg);
    let (r4, rec4, led4) = run(mk(4));
    assert_eq!(
        digest(&r1, &rec1, &led1),
        digest(&r4, &rec4, &led4),
        "fleet_trace: threads=1 vs threads=4 digest"
    );
    assert_payloads_match(&r1, &r4, "fleet_trace threads");

    // spot-market preemptions shift *when* outer syncs fire, never how
    // many collectives run or how many bytes they move — the closed
    // forms stay exact on traced timelines (merging/elastic are off in
    // this preset, so the plan streams are empty)
    assert!(rec1.merges.is_empty());
    let est = estimate_ledger(outer_steps, &shapes, &homes, false, &[], param_bytes);
    assert_eq!(est.events, led1.count(), "fleet_trace: predicted event count");
    assert_eq!(est.total_bytes, led1.total_bytes(), "fleet_trace: predicted total bytes");
    assert_eq!(est.wan_bytes, led1.wan_bytes(), "fleet_trace: predicted WAN bytes");
    assert_eq!(r1.comm_bytes, led1.total_bytes());
}

// ---------------------------------------------------------------------------
// runtime guard: dynamic traces cannot run on the lockstep walk
// ---------------------------------------------------------------------------

#[test]
fn lockstep_rejects_a_dynamic_trace_file() {
    // export hetero_dynamic's churn+shift scenario (validate() cannot
    // inspect a trace file, so this guard must live in Coordinator::new)
    let src = presets::hetero_dynamic();
    let trace = Trace::from_scenario(&src.cluster.scenario, src.cluster.nodes.len());
    let path = tmp_trace("lockstep_guard");
    trace.save(&path).unwrap();

    let mut cfg = diurnal_cfg(SchedulerKind::Lockstep, 1);
    cfg.name = "lockstep_dynamic_trace".into();
    cfg.cluster.trace = TraceSourceConfig::Path(path.clone());
    cfg.validate().unwrap(); // statically fine: the file is opaque here
    let engine = build_engine(&cfg).unwrap();
    let err = match adloco::coordinator::Coordinator::new(cfg, engine) {
        Ok(_) => panic!("a dynamic trace on the lockstep walk must be rejected"),
        Err(e) => e,
    };
    assert!(
        format!("{err:#}").contains("run.scheduler=event"),
        "unexpected error: {err:#}"
    );
    std::fs::remove_file(&path).ok();
}
