//! Satellite coverage for the persistent execution runtime
//! (DESIGN.md §14): reuse across many fan-out generations, the panic
//! story, equivalence with the one-shot `run_cells` wrapper, and the
//! O(threads)-not-O(rounds × threads) spawn contract — including
//! through a real multi-round coordinator run.

use std::sync::Mutex;

use adloco::util::parallel::{run_cells, threads_spawned, WorkerPool};

/// `threads_spawned()` is a process-global counter and the tests in
/// this binary run concurrently: every test that constructs a pool
/// serializes here so spawn-count deltas stay attributable.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The reuse-across-rounds property: one pool, 100 sequential
/// fan-outs, results in cell order every time.
#[test]
fn pool_reused_across_hundred_fanouts_stays_ordered() {
    let _g = lock();
    let pool = WorkerPool::new(4);
    for round in 0..100u64 {
        let cells: Vec<_> = (0..9u64).map(|i| move || i * 1_000 + round).collect();
        let out = pool.run(cells);
        assert_eq!(
            out,
            (0..9u64).map(|i| i * 1_000 + round).collect::<Vec<_>>(),
            "round {round}: ordered collection must hold on a reused pool"
        );
    }
}

/// The pool and the one-shot wrapper agree bit for bit on pure cells.
#[test]
fn pool_matches_run_cells_results() {
    let _g = lock();
    let mk = || {
        (0..23u64)
            .map(|i| move || i.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17))
            .collect::<Vec<_>>()
    };
    let via_wrapper = run_cells(4, mk());
    let pool = WorkerPool::new(4);
    assert_eq!(pool.run(mk()), via_wrapper);
    assert_eq!(run_cells(1, mk()), via_wrapper, "serial walk agrees too");
}

/// The panic story (DESIGN.md §14): a panicking cell's payload
/// re-raises on the caller after the generation drains — never a hang —
/// and the pool itself survives and stays usable.
#[test]
fn panicking_cell_propagates_and_pool_survives() {
    let _g = lock();
    let pool = WorkerPool::new(4);
    let cells: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8)
        .map(|i| {
            Box::new(move || {
                if i == 3 {
                    panic!("cell 3 exploded");
                }
                i
            }) as Box<dyn FnOnce() -> usize + Send>
        })
        .collect();
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(cells)))
        .expect_err("a cell panic must propagate to the caller");
    let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
    assert!(msg.contains("cell 3 exploded"), "panic payload preserved, got {msg:?}");
    // the same pool keeps working after a panicking generation
    let out = pool.run((0..5usize).map(|i| move || i * 2).collect::<Vec<_>>());
    assert_eq!(out, vec![0, 2, 4, 6, 8]);
}

/// O(threads) OS threads per pool, no matter how many generations run.
#[test]
fn pool_spawns_o_threads_not_o_rounds() {
    let _g = lock();
    let before = threads_spawned();
    let pool = WorkerPool::new(4);
    for _ in 0..50 {
        let out = pool.run((0..8usize).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }
    assert_eq!(
        threads_spawned() - before,
        4,
        "50 fan-outs over one pool must spawn exactly its 4 threads"
    );
}

/// The coordinator-level spawn contract: a full multi-round event run
/// at `threads = 4` spawns O(threads) OS threads total (the persistent
/// pool), not O(rounds × threads) as the old scoped fan-out did.
#[test]
fn coordinator_run_spawns_one_pool() {
    let _g = lock();
    let mut cfg = adloco::config::presets::mock_default();
    cfg.name = "worker_pool_spawn_census".into();
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.inner_steps = 3;
    cfg.algo.outer_steps = 20;
    cfg.run.scheduler = adloco::config::SchedulerKind::Event;
    cfg.run.threads = 4;
    let engine = adloco::engine::build_engine(&cfg).unwrap();
    let before = threads_spawned();
    let mut coord = adloco::coordinator::Coordinator::new(cfg, engine).unwrap();
    coord.run().unwrap();
    let spawned = threads_spawned() - before;
    assert!(
        spawned <= 4,
        "20 outer rounds at threads=4 must reuse one pool (spawned {spawned} threads)"
    );
}
