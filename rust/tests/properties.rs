//! Property-based tests (hand-rolled proptest-style: seeded random case
//! generation over many iterations) on the coordinator-layer invariants:
//! merge selection/conservation, switch-mode planning, ladder rounding,
//! controller monotonicity, clock barriers, and JSON round-tripping.

use adloco::batching::{plan_step, round_to_ladder, BatchController};
use adloco::config::{presets, ElasticMode};
use adloco::engine::StepStats;
use adloco::instances::{plan_spawns, NodeLoad, SpawnBudget};
use adloco::merge::{check_merge_with_policy, do_merge, MergePolicy};
use adloco::service::server::parse_request;
use adloco::service::{transition_allowed, HttpLimits, RunState};
use adloco::simulator::VirtualClock;
use adloco::util::{JsonValue, Rng};

const CASES: usize = 300;

// ---------------------------------------------------------------------------
// merge properties
// ---------------------------------------------------------------------------

#[test]
fn prop_check_merge_selects_minima() {
    let mut rng = Rng::new(100);
    for case in 0..CASES {
        let k = 2 + rng.below(10) as usize;
        let w = rng.below(k as u64 + 3) as usize;
        let min_keep = 1 + rng.below(3) as usize;
        let reqs: Vec<(usize, usize)> =
            (0..k).map(|id| (id, 1 + rng.below(100) as usize)).collect();
        let sel = check_merge_with_policy(
            &reqs,
            w,
            min_keep,
            MergePolicy::WorstByBatch,
            &mut Rng::new(0),
        );

        if !sel.is_empty() {
            assert!(sel.len() >= 2, "case {case}: merge of {} members", sel.len());
            // survivors floor
            assert!(
                k - (sel.len() - 1) >= min_keep,
                "case {case}: floor violated (k={k}, sel={}, keep={min_keep})",
                sel.len()
            );
            // selected are exactly a set of minimal b_req (allowing ties)
            let max_sel_b = sel
                .iter()
                .map(|&id| reqs.iter().find(|(i, _)| *i == id).unwrap().1)
                .max()
                .unwrap();
            let better_outside = reqs
                .iter()
                .filter(|(id, b)| !sel.contains(id) && *b < max_sel_b)
                .count();
            assert_eq!(better_outside, 0, "case {case}: non-minimal selection");
        }
    }
}

#[test]
fn prop_do_merge_is_convex_combination() {
    let mut rng = Rng::new(200);
    for case in 0..CASES {
        let n = 1 + rng.below(64) as usize;
        let k = 2 + rng.below(4) as usize;
        let mut bufs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| rng.normal_ms(0.0, 3.0) as f32).collect())
            .collect();
        let weights: Vec<usize> = (0..k).map(|_| 1 + rng.below(50) as usize).collect();

        // coordinate-wise min/max BEFORE the merge
        let lo: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).fold(f32::INFINITY, f32::min))
            .collect();
        let hi: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).fold(f32::NEG_INFINITY, f32::max))
            .collect();

        let outcome = {
            let mut members: Vec<(usize, usize, &mut [f32])> = bufs
                .iter_mut()
                .enumerate()
                .map(|(i, b)| (i, weights[i], b.as_mut_slice()))
                .collect();
            do_merge(&mut members)
        };
        let rep = outcome.representative;
        // representative has max weight (ties -> lowest id)
        let wmax = *weights.iter().max().unwrap();
        assert_eq!(weights[rep], wmax, "case {case}");
        // merged vector is inside the convex hull coordinate-wise
        for i in 0..n {
            let v = bufs[rep][i];
            assert!(
                v >= lo[i] - 1e-4 && v <= hi[i] + 1e-4,
                "case {case}: coord {i} {v} outside [{}, {}]",
                lo[i],
                hi[i]
            );
        }
        assert_eq!(outcome.removed.len(), k - 1);
        assert!(!outcome.removed.contains(&rep));
    }
}

// ---------------------------------------------------------------------------
// batching properties
// ---------------------------------------------------------------------------

#[test]
fn prop_plan_step_invariants() {
    let mut rng = Rng::new(300);
    let ladder_pool: Vec<Vec<usize>> =
        vec![vec![1, 2, 4, 8, 16], vec![1, 2, 4, 8, 16, 32, 64], vec![1, 4, 16, 64, 256]];
    for case in 0..CASES {
        let ladder = &ladder_pool[rng.below(3) as usize];
        let b_req = 1 + rng.below(4000) as usize;
        let max_batch = 1 + rng.below(80) as usize;
        let multiplier = 1.0 + rng.f64() * 3.0;
        let enabled = rng.f64() < 0.7;
        let p = plan_step(b_req, max_batch, multiplier, enabled, ladder);

        assert!(p.micro_batch >= 1, "case {case}");
        assert!(p.micro_batch <= max_batch, "case {case}: micro > max_batch");
        assert!(p.accum_steps >= 1);
        let threshold = (multiplier * max_batch as f64).floor() as usize;
        if p.switched {
            assert!(enabled && b_req > threshold, "case {case}: switched too early");
            // accumulation covers the request
            assert!(
                p.accum_steps == b_req.div_ceil(max_batch),
                "case {case}: accum {} for b_req {b_req} max {max_batch}",
                p.accum_steps
            );
        } else {
            assert_eq!(p.accum_steps, 1, "case {case}");
        }
        if !enabled {
            assert!(!p.switched);
        }
        // SAT2: switched iff accumulation actually engaged (multiplier
        // >= 1 guarantees a switching request exceeds max_batch)
        assert_eq!(
            p.switched,
            p.accum_steps > 1,
            "case {case}: switched must mean accumulation"
        );
        // SAT2: the plan never under-runs what the request, the ladder
        // and the hardware jointly allow
        let top = *ladder.last().unwrap();
        assert!(
            p.effective_batch() >= b_req.min(top).min(max_batch),
            "case {case}: effective {} under-runs min(b_req {b_req}, top {top}, max {max_batch})",
            p.effective_batch()
        );
        // when switched with the ladder covering the budget, the
        // accumulated plan covers the full request
        if p.switched && top >= max_batch {
            assert!(
                p.effective_batch() >= b_req.min(top),
                "case {case}: switched plan must cover min(b_req, top)"
            );
        }
        // SAT1/SAT2: the clamp flag is exactly "the ladder saturated
        // below the intended micro batch" — never the SwitchMode
        // dead-zone clamp, never plain rounding up
        assert_eq!(
            p.clamped,
            p.micro_batch < b_req.min(max_batch),
            "case {case}: clamp flag semantics"
        );
        if top >= max_batch {
            assert!(!p.clamped, "case {case}: covered ladder never clamps");
        }
    }
}

#[test]
fn prop_controller_monotone_under_monotone_noise() {
    // SAT2: with the EMA off and shrinking disabled, the norm test's
    // request is monotone in the noise statistic — non-decreasing sigma²
    // at fixed gradient norm must yield a non-decreasing request, even
    // for a controller allowed to shrink (monotone = false)
    let mut rng = Rng::new(510);
    for case in 0..CASES {
        let mut bc = presets::paper_table1().algo.batching;
        bc.monotone = false;
        bc.ema_beta = 0.0;
        bc.max_request = 0; // uncapped: the raw test drives the request
        let s1 = 0.5 + rng.f64() * 2.0;
        let mut c = BatchController::new(bc);
        let mut sigma2 = rng.f64();
        let mut prev_req = 0usize;
        for step in 0..30 {
            sigma2 += rng.f64() * 2.0; // monotone noise growth
            c.observe(
                &StepStats { loss: 1.0, grad_sq_norm: s1, sigma2, ip_var: 0.0 },
                8,
            );
            let req = c.requested();
            assert!(
                req >= prev_req,
                "case {case} step {step}: request shrank {prev_req} -> {req} \
                 under monotone noise"
            );
            prev_req = req;
        }
    }
}

#[test]
fn prop_controller_replay_is_deterministic() {
    // SAT2: the controller (EMAs included) is a pure fold over its
    // observation stream — replaying the same stream into a fresh
    // controller reproduces every request, and an export/restore mid-
    // stream continues the exact sequence (the checkpoint contract)
    let mut rng = Rng::new(520);
    for case in 0..60 {
        let mut bc = presets::paper_table1().algo.batching;
        bc.ema_beta = if case % 2 == 0 { 0.5 } else { 0.0 };
        bc.monotone = case % 3 == 0;
        let obs: Vec<(StepStats, usize)> = (0..40)
            .map(|_| {
                (
                    StepStats {
                        loss: rng.f64() * 10.0,
                        grad_sq_norm: rng.f64() * 2.0,
                        sigma2: rng.f64() * 5.0,
                        ip_var: rng.f64() * 5.0,
                    },
                    1 + rng.below(64) as usize,
                )
            })
            .collect();
        let mut a = BatchController::new(bc.clone());
        let mut b = BatchController::new(bc.clone());
        let mut resumed = BatchController::new(bc.clone());
        for (i, (stats, batch)) in obs.iter().enumerate() {
            a.observe(stats, *batch);
            b.observe(stats, *batch);
            assert_eq!(a.requested(), b.requested(), "case {case} step {i}: replay");
            if i == 19 {
                resumed.restore_state(&a.export_state());
            }
            if i >= 20 {
                resumed.observe(stats, *batch);
                assert_eq!(
                    a.requested(),
                    resumed.requested(),
                    "case {case} step {i}: restored controller diverged"
                );
            }
        }
        assert_eq!(a.export_state(), b.export_state(), "case {case}: final state");
        assert_eq!(a.export_state(), resumed.export_state(), "case {case}: resumed state");
    }
}

#[test]
fn prop_round_to_ladder() {
    let mut rng = Rng::new(400);
    for _ in 0..CASES {
        let mut ladder: Vec<usize> =
            (0..(1 + rng.below(8) as usize)).map(|_| 1 + rng.below(512) as usize).collect();
        ladder.sort_unstable();
        ladder.dedup();
        let b = 1 + rng.below(1024) as usize;
        let r = round_to_ladder(b, &ladder);
        assert!(ladder.contains(&r));
        if b <= *ladder.last().unwrap() {
            assert!(r >= b, "rounding must not shrink below request");
            // r is the *smallest* rung >= b
            for &rung in &ladder {
                if rung >= b {
                    assert_eq!(r, rung);
                    break;
                }
            }
        } else {
            assert_eq!(r, *ladder.last().unwrap());
        }
    }
}

#[test]
fn prop_controller_monotone_and_capped() {
    let mut rng = Rng::new(500);
    for case in 0..CASES {
        let mut bc = presets::paper_table1().algo.batching;
        bc.max_request = 1 + rng.below(500) as usize;
        bc.monotone = true;
        bc.ema_beta = if rng.f64() < 0.5 { 0.0 } else { 0.9 };
        let mut c = BatchController::new(bc.clone());
        let mut prev = c.requested();
        for _ in 0..50 {
            let stats = StepStats {
                loss: rng.f64() * 10.0,
                grad_sq_norm: rng.f64() * 2.0,
                sigma2: rng.f64() * 5.0,
                ip_var: rng.f64() * 5.0,
            };
            c.observe(&stats, 1 + rng.below(64) as usize);
            let req = c.requested();
            assert!(req >= prev, "case {case}: monotone violated {prev} -> {req}");
            assert!(req <= bc.max_request.max(prev), "case {case}: cap violated");
            assert!(req >= 1);
            prev = req;
        }
    }
}

// ---------------------------------------------------------------------------
// simulator properties
// ---------------------------------------------------------------------------

#[test]
fn prop_clock_barrier_is_max_plus_extra() {
    let mut rng = Rng::new(600);
    for _ in 0..CASES {
        let n = 2 + rng.below(16) as usize;
        let mut clock = VirtualClock::new(n);
        for w in 0..n {
            clock.advance(w, rng.f64() * 100.0);
        }
        let mut members: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut members);
        members.truncate(1 + rng.below(n as u64) as usize);
        let before_max =
            members.iter().map(|&w| clock.time(w)).fold(0.0_f64, f64::max);
        let extra = rng.f64();
        let after = clock.barrier(&members, extra);
        assert!((after - (before_max + extra)).abs() < 1e-9);
        for &w in &members {
            assert!((clock.time(w) - after).abs() < 1e-12);
        }
    }
}

#[test]
fn prop_event_queue_orders_by_time_then_fifo() {
    use adloco::simulator::{EventQueue, SimEvent};
    let mut rng = Rng::new(900);
    for case in 0..CASES {
        let n = 1 + rng.below(64) as usize;
        let mut q = EventQueue::new();
        let mut expect: Vec<(u64, usize)> = Vec::new();
        for i in 0..n {
            // coarse buckets force plenty of timestamp ties
            let bucket = rng.below(8);
            q.push(bucket as f64, SimEvent::StepDone { trainer: i, worker: 0, step: 1 });
            expect.push((bucket, i));
        }
        // stable sort == (time, push order), the queue's contract
        expect.sort_by_key(|&(b, _)| b);
        let got: Vec<(u64, usize)> = std::iter::from_fn(|| q.pop())
            .map(|(t, ev)| {
                let trainer = match ev {
                    SimEvent::StepDone { trainer, .. } => trainer,
                    _ => unreachable!(),
                };
                (t as u64, trainer)
            })
            .collect();
        assert_eq!(got, expect, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// json round-trip on random documents
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> JsonValue {
    let kind = if depth == 0 { rng.below(4) } else { rng.below(6) };
    match kind {
        0 => JsonValue::Null,
        1 => JsonValue::Bool(rng.f64() < 0.5),
        2 => {
            // keep numbers exactly representable through the writer
            let v = (rng.range(-1_000_000, 1_000_000) as f64) / 64.0;
            JsonValue::Number(v)
        }
        3 => {
            let len = rng.below(12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            JsonValue::String(s)
        }
        4 => JsonValue::Array(
            (0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => JsonValue::Object(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::new(700);
    for case in 0..CASES {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = JsonValue::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, back, "case {case}");
        let pretty = v.to_string_pretty();
        assert_eq!(v, JsonValue::parse(&pretty).unwrap(), "case {case} (pretty)");
    }
}

// ---------------------------------------------------------------------------
// end-to-end property: random small configs never panic and stay sane
// ---------------------------------------------------------------------------

#[test]
fn prop_random_configs_run_clean() {
    let mut rng = Rng::new(800);
    for case in 0..12 {
        let mut cfg = presets::quick();
        cfg.name = format!("prop_run_{case}");
        cfg.seed = rng.next_u64();
        cfg.algo.num_trainers = 1 + rng.below(4) as usize;
        cfg.algo.workers_per_trainer = 1 + rng.below(3) as usize;
        cfg.algo.inner_steps = 2 + rng.below(8) as usize;
        cfg.algo.outer_steps = 1 + rng.below(4) as usize;
        cfg.algo.merge.enabled = rng.f64() < 0.7;
        cfg.algo.merge.w = 1 + rng.below(4) as usize;
        cfg.algo.merge.frequency = 1 + rng.below(3) as usize;
        cfg.algo.switch.enabled = rng.f64() < 0.7;
        cfg.algo.batching.adaptive = rng.f64() < 0.8;
        cfg.algo.batching.max_request = 64;
        cfg.algo.batching.monotone = rng.f64() < 0.8;
        cfg.run.eval_every = 2;
        cfg.validate().unwrap();

        let r = adloco::coordinator::run_experiment(cfg).unwrap_or_else(|e| {
            panic!("case {case} failed: {e:#}")
        });
        assert!(r.best_ppl.is_finite(), "case {case}");
        assert!(r.trainers_left >= 1, "case {case}");
        assert!(r.total_inner_steps >= 1, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// elastic spawn-controller properties (DESIGN.md §9)
// ---------------------------------------------------------------------------

/// Random node-load table: capacities 1..=4, assigned 0..=capacity,
/// idle fractions in [0,1], ~1 in 8 nodes down.
fn random_loads(rng: &mut Rng) -> Vec<NodeLoad> {
    let nodes = 1 + rng.below(8) as usize;
    (0..nodes)
        .map(|node| {
            let capacity = 1 + rng.below(4) as usize;
            NodeLoad {
                node,
                capacity,
                assigned: rng.below(capacity as u64 + 1) as usize,
                idle_frac: rng.f64(),
                available: rng.below(8) != 0,
            }
        })
        .collect()
}

#[test]
fn prop_spawn_plan_respects_capacity_budget_and_availability() {
    let mut rng = Rng::new(700);
    for case in 0..CASES {
        let loads = random_loads(&mut rng);
        let budget = SpawnBudget {
            live_instances: rng.below(10) as usize,
            max_instances: rng.below(16) as usize,
            cooldown_ok: rng.below(2) == 0,
            merge_freed: rng.below(6) as usize,
            spawn_width: 1 + rng.below(3) as usize,
        };
        let threshold = rng.f64();
        for mode in [ElasticMode::UtilThreshold, ElasticMode::RespawnAfterMerge] {
            let plan = plan_spawns(mode, threshold, &loads, &budget);
            let live = budget.live_instances;
            assert!(
                live + plan.len() <= budget.max_instances.max(live),
                "case {case} {mode:?}: budget exceeded ({live} + {} > {})",
                plan.len(),
                budget.max_instances
            );
            for l in &loads {
                let placed = plan.iter().filter(|&&n| n == l.node).count();
                // slot capacity counts the full spawn width per placement
                assert!(
                    l.assigned + placed * budget.spawn_width <= l.capacity,
                    "case {case} {mode:?}: node {} over slot capacity",
                    l.node
                );
                assert!(
                    placed == 0 || l.available,
                    "case {case} {mode:?}: spawned onto a down node {}",
                    l.node
                );
            }
        }
    }
}

#[test]
fn prop_spawn_plan_is_monotone_in_idle_ratio() {
    // raising idle fractions (everything else fixed, budget unbinding)
    // can only grow the util_threshold plan — never drop a node
    let mut rng = Rng::new(701);
    for case in 0..CASES {
        let loads = random_loads(&mut rng);
        let threshold = rng.f64();
        let budget = SpawnBudget {
            live_instances: 0,
            max_instances: loads.len() + 8, // budget never binds
            cooldown_ok: true,
            merge_freed: 0,
            spawn_width: 1,
        };
        let base = plan_spawns(ElasticMode::UtilThreshold, threshold, &loads, &budget);
        let mut raised = loads.clone();
        for l in &mut raised {
            l.idle_frac = (l.idle_frac + rng.f64() * (1.0 - l.idle_frac)).min(1.0);
        }
        let more = plan_spawns(ElasticMode::UtilThreshold, threshold, &raised, &budget);
        for n in &base {
            assert!(
                more.contains(n),
                "case {case}: node {n} dropped out when idle ratios rose \
                 (base {base:?} vs {more:?})"
            );
        }
    }
}

#[test]
fn prop_elastic_off_never_spawns() {
    let mut rng = Rng::new(702);
    for _ in 0..CASES {
        let loads = random_loads(&mut rng);
        let plan = plan_spawns(
            ElasticMode::Off,
            0.0, // most permissive threshold
            &loads,
            &SpawnBudget {
                live_instances: 0,
                max_instances: usize::MAX,
                cooldown_ok: true,
                merge_freed: rng.below(10) as usize,
                spawn_width: 1,
            },
        );
        assert!(plan.is_empty(), "elastic=off must never spawn");
    }
}

// ---------------------------------------------------------------------------
// checkpoint interchange round-trips on random snapshots (DESIGN.md §10)
// ---------------------------------------------------------------------------

use adloco::checkpoint::{
    import_bytes, legacy, Checkpoint, Interchange, PendingSnapshot, PhaseSnapshot,
    RegistryRowSnapshot, RngSnapshot, SamplerSnapshot, TrainerSnapshot, WorkerSnapshot,
};

fn random_rng_snapshot(rng: &mut Rng) -> RngSnapshot {
    RngSnapshot {
        s: [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()],
        gauss_spare: if rng.f64() < 0.5 { Some(rng.f64() * 4.0 - 2.0) } else { None },
    }
}

fn random_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_ms(0.0, 2.0) as f32).collect()
}

fn random_f64s(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.f64() * 1e4).collect()
}

/// A structurally valid random snapshot: every vector length declared
/// in the header matches its blob payload, worker moment vectors share
/// the worker's parameter length, and the registry rows use the real
/// lifecycle vocabulary — values themselves (counters above 2^53,
/// negative floats, empty shards) are adversarial.
fn random_checkpoint(rng: &mut Rng) -> Checkpoint {
    let slots = 1 + rng.below(4) as usize;
    let n_trainers = 1 + rng.below(3) as usize;
    let trainers: Vec<TrainerSnapshot> = (0..n_trainers)
        .map(|id| {
            let p_len = 1 + rng.below(8) as usize;
            let workers = (0..1 + rng.below(3) as usize)
                .map(|_| {
                    let w_len = 1 + rng.below(8) as usize;
                    WorkerSnapshot {
                        params: random_f32s(rng, w_len),
                        m: random_f32s(rng, w_len),
                        v: random_f32s(rng, w_len),
                        step: rng.next_u64(),
                        active: rng.below(2) == 0,
                        noise_rng: random_rng_snapshot(rng),
                        time_rng: random_rng_snapshot(rng),
                        sampler: SamplerSnapshot {
                            shard: (0..rng.below(6) as usize).collect(),
                            order: (0..rng.below(6) as usize).collect(),
                            cursor: rng.below(6) as usize,
                            drawn: rng.next_u64(),
                            rng: random_rng_snapshot(rng),
                        },
                    }
                })
                .collect();
            TrainerSnapshot {
                id,
                params: random_f32s(rng, p_len),
                outer_velocity: random_f32s(rng, rng.below(8) as usize),
                requested_batch: 1 + rng.below(512) as usize,
                inner_steps_done: rng.next_u64(),
                observations: rng.next_u64(),
                sigma2_ema: (rng.f64() * 10.0, rng.next_u64()),
                ip_var_ema: (rng.f64() * 10.0, rng.next_u64()),
                s1_ema: (rng.f64() * 10.0, rng.next_u64()),
                shard: (0..rng.below(6) as usize).collect(),
                pending: if rng.below(2) == 0 {
                    Some(PendingSnapshot {
                        posted_at: rng.f64() * 100.0,
                        completes_at: rng.f64() * 200.0,
                        time_s: rng.f64(),
                        sent_samples: rng.next_u64(),
                        phases: (0..1 + rng.below(3) as usize)
                            .map(|_| PhaseSnapshot {
                                wan: rng.below(2) == 0,
                                bytes: rng.next_u64(),
                                participants: 1 + rng.below(8) as usize,
                            })
                            .collect(),
                        delta: random_f32s(rng, rng.below(8) as usize),
                    })
                } else {
                    None
                },
                workers,
            }
        })
        .collect();
    let registry = (0..n_trainers + rng.below(3) as usize)
        .map(|id| RegistryRowSnapshot {
            id,
            state: ["spawned", "active", "merging", "retired"][rng.below(4) as usize].into(),
            origin: ["seed", "util", "respawn"][rng.below(3) as usize].into(),
            born_outer: rng.below(100),
            born_at_s: rng.f64() * 1e3,
            retired_outer: if rng.below(2) == 0 { Some(rng.below(100)) } else { None },
            workers: (0..rng.below(3) as usize).map(|w| (rng.below(4) as usize, w)).collect(),
        })
        .collect();
    Checkpoint {
        config_name: format!("prop_ckpt_{}", rng.below(1000)),
        config_digest: rng.next_u64(),
        outer_step: rng.below(1_000_000),
        total_samples: rng.next_u64(), // above 2^53 half the time
        comm_count: rng.next_u64(),
        comm_bytes: rng.next_u64(),
        comm_wan_bytes: rng.next_u64(),
        overlap_hidden_s: rng.f64() * 1e4,
        clock_times: random_f64s(rng, slots),
        busy_s: random_f64s(rng, slots),
        wait_s: random_f64s(rng, slots),
        comm_s: random_f64s(rng, slots),
        comm_hidden_s: random_f64s(rng, slots),
        preempted_s: random_f64s(rng, slots),
        vacant_s: random_f64s(rng, slots),
        spawn_count: rng.below(100),
        last_spawn_outer: rng.below(100),
        last_merge_rep: if rng.below(2) == 0 { Some(rng.below(8) as usize) } else { None },
        live_rounds_sum: rng.next_u64(),
        rounds_count: rng.below(1000),
        registry,
        rng: random_rng_snapshot(rng),
        trainers,
    }
}

#[test]
fn prop_checkpoint_export_import_export_is_byte_identical() {
    // the v4 encoder is a pure function of the snapshot and the decoder
    // inverts it exactly: export → import → export reproduces the very
    // same bytes, for arbitrary valid snapshots
    let mut rng = Rng::new(1000);
    for case in 0..60 {
        let cp = random_checkpoint(&mut rng);
        let bytes = cp.to_bytes();
        let back = match import_bytes(&bytes).unwrap_or_else(|e| panic!("case {case}: {e}")) {
            Interchange::Complete(c) => c,
            other => panic!("case {case}: {other:?}"),
        };
        assert_eq!(back, cp, "case {case}: struct round-trip");
        assert_eq!(back.to_bytes(), bytes, "case {case}: byte round-trip");
    }
}

#[test]
fn prop_minimal_checkpoint_roundtrip_is_byte_identical() {
    let mut rng = Rng::new(1001);
    for case in 0..60 {
        let min = random_checkpoint(&mut rng).to_minimal();
        let bytes = min.to_bytes();
        let back = match import_bytes(&bytes).unwrap_or_else(|e| panic!("case {case}: {e}")) {
            Interchange::Minimal(m) => m,
            other => panic!("case {case}: {other:?}"),
        };
        assert_eq!(back, min, "case {case}: struct round-trip");
        assert_eq!(back.to_bytes(), bytes, "case {case}: byte round-trip");
    }
}

#[test]
fn prop_legacy_v3_import_inverts_the_historical_writer() {
    // migration is lossless on arbitrary snapshots, not just the golden
    // fixture: export_v3 → import recovers everything but the digest
    let mut rng = Rng::new(1002);
    for case in 0..40 {
        let cp = random_checkpoint(&mut rng);
        let back = match import_bytes(&legacy::export_v3(&cp)).unwrap() {
            Interchange::Complete(c) => c,
            other => panic!("case {case}: {other:?}"),
        };
        let mut want = cp;
        want.config_digest = 0; // v3 predates the digest
        assert_eq!(back, want, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// workload-trace properties (DESIGN.md §11): the JSONL interchange and
// the deterministic fleet generators
// ---------------------------------------------------------------------------

use adloco::simulator::generators::{
    diurnal, rack_failures, spot_market, DiurnalSpec, RackFailureSpec, SpotMarketSpec,
};
use adloco::simulator::{Trace, TraceError, TraceEvent, TraceRecord};

/// Adversarial but valid trace: timestamps spanning 24 decades (still
/// non-decreasing, ties included), factors from 1e-6 to 1e6, mixed
/// event kinds, optional straggler header.
fn random_trace(rng: &mut Rng) -> Trace {
    let nodes = 1 + rng.below(12) as usize;
    let n_records = rng.below(40) as usize;
    let mut t = 0.0f64;
    let mut records = Vec::with_capacity(n_records);
    for _ in 0..n_records {
        // huge/tiny, and sometimes exactly zero (a tie with the
        // previous record), to stress the hex round-trip
        if rng.below(4) != 0 {
            t += rng.f64() * 10f64.powi(rng.range(-12, 12) as i32);
        }
        let node = rng.below(nodes as u64) as usize;
        let factor = (rng.f64() + 1e-12) * 10f64.powi(rng.range(-6, 6) as i32);
        let ev = match rng.below(3) {
            0 => {
                // huge t + tiny duration can round back to t; the format
                // requires a strictly non-empty window
                let mut until = t + rng.f64() * 10f64.powi(rng.range(-9, 9) as i32) + 1e-12;
                if until <= t {
                    until = t * 2.0 + 1.0;
                }
                TraceEvent::Down { until }
            }
            1 => TraceEvent::Bandwidth { factor },
            _ => TraceEvent::Speed { factor },
        };
        records.push(TraceRecord { t, node, ev });
    }
    let (prob, min, max) = if rng.below(2) == 0 {
        (0.0, 1.0, 1.0)
    } else {
        let min = 1.0 + rng.f64() * 3.0;
        (rng.f64(), min, min + rng.f64() * 5.0)
    };
    Trace {
        nodes,
        straggler_prob: prob,
        straggler_min: min,
        straggler_max: max,
        records,
    }
}

#[test]
fn prop_trace_serialize_parse_is_byte_identical() {
    let mut rng = Rng::new(2024);
    for case in 0..CASES {
        let trace = random_trace(&mut rng);
        let text = trace.to_jsonl();
        let back = Trace::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(back, trace, "case {case}: struct round-trip");
        // canonical form: a second serialization is byte-identical
        assert_eq!(back.to_jsonl(), text, "case {case}: byte round-trip");
    }
}

#[test]
fn prop_trace_truncations_never_parse_silently() {
    // cutting the canonical text anywhere (beyond dropping the final
    // newline alone) yields a typed error, never a silently shorter
    // trace: line-boundary cuts are Truncated, mid-line cuts Corrupt
    let mut rng = Rng::new(2025);
    for case in 0..CASES {
        let mut trace = random_trace(&mut rng);
        if trace.records.is_empty() {
            trace.records.push(TraceRecord {
                t: 0.0,
                node: 0,
                ev: TraceEvent::Speed { factor: 1.5 },
            });
        }
        let text = trace.to_jsonl();
        let cut = 1 + rng.below(text.len() as u64 - 2) as usize;
        let clipped = &text[..floor_char_boundary(&text, cut)];
        match Trace::parse(clipped) {
            Err(
                TraceError::Truncated { .. }
                | TraceError::Corrupt { .. }
                | TraceError::MissingField { .. }
                | TraceError::BadFormat { .. },
            ) => {}
            Err(other) => panic!("case {case}: unexpected error class {other}"),
            Ok(_) => panic!("case {case}: cut at byte {cut} of {} parsed", text.len()),
        }
    }
}

fn floor_char_boundary(s: &str, mut i: usize) -> usize {
    while i > 0 && !s.is_char_boundary(i) {
        i -= 1;
    }
    i
}

#[test]
fn prop_trace_mutations_yield_typed_errors() {
    let mut rng = Rng::new(2026);
    for case in 0..CASES {
        let mut trace = random_trace(&mut rng);
        while trace.records.len() < 2 {
            let t = trace.records.last().map(|r| r.t).unwrap_or(0.0) + 1.0;
            trace.records.push(TraceRecord {
                t,
                node: 0,
                ev: TraceEvent::Bandwidth { factor: 1.0 },
            });
        }
        let n = trace.records.len();
        match rng.below(4) {
            0 => {
                // strictly decreasing timestamp (kept >= 0 so the
                // ordering check, not the value check, is what fires)
                let i = 1 + rng.below(n as u64 - 1) as usize;
                trace.records[i - 1].t += 1.0;
                if let TraceEvent::Down { until } = &mut trace.records[i - 1].ev {
                    *until = trace.records[i - 1].t * 2.0 + 1.0;
                }
                trace.records[i].t = trace.records[i - 1].t / 2.0;
                let err = Trace::parse(&trace.to_jsonl()).unwrap_err();
                assert!(
                    matches!(err, TraceError::OutOfOrder { .. }),
                    "case {case}: {err}"
                );
            }
            1 => {
                // non-positive bandwidth factor
                let i = rng.below(n as u64) as usize;
                trace.records[i].ev =
                    TraceEvent::Bandwidth { factor: -(1.0 + rng.f64()) };
                let err = Trace::parse(&trace.to_jsonl()).unwrap_err();
                assert!(
                    matches!(err, TraceError::NegativeBandwidth { .. }),
                    "case {case}: {err}"
                );
            }
            2 => {
                // node index beyond the declared cluster size
                let i = rng.below(n as u64) as usize;
                trace.records[i].node = trace.nodes + rng.below(5) as usize;
                let err = Trace::parse(&trace.to_jsonl()).unwrap_err();
                assert!(
                    matches!(err, TraceError::NodeOutOfRange { .. }),
                    "case {case}: {err}"
                );
            }
            _ => {
                // unknown field injected into a random line
                let text = trace.to_jsonl();
                let line = rng.below(1 + n as u64) as usize; // header or record
                let mutated: String = text
                    .lines()
                    .enumerate()
                    .map(|(i, l)| {
                        if i == line {
                            format!("{{\"bogus\":1,{}\n", &l[1..])
                        } else {
                            format!("{l}\n")
                        }
                    })
                    .collect();
                let err = Trace::parse(&mutated).unwrap_err();
                assert!(
                    matches!(err, TraceError::UnknownField { .. }),
                    "case {case}: {err}"
                );
            }
        }
    }
}

#[test]
fn prop_generators_are_seed_deterministic_and_invariant() {
    let mut rng = Rng::new(2027);
    for case in 0..60 {
        let seed = rng.next_u64();
        let nodes = 1 + rng.below(8) as usize;
        let horizon = 1.0 + rng.f64() * 30.0;

        let spot = SpotMarketSpec {
            nodes,
            horizon_s: horizon,
            mean_up_s: 0.1 + rng.f64() * 5.0,
            mean_down_s: 0.1 + rng.f64() * 2.0,
            seed,
        };
        let a = spot_market(&spot);
        assert_eq!(a.to_jsonl(), spot_market(&spot).to_jsonl(), "case {case}: spot seed");
        // outage windows per node: sorted, disjoint — a preempted node
        // never revives mid-outage
        for node in 0..nodes {
            let mut prev_until = f64::NEG_INFINITY;
            for r in a.records.iter().filter(|r| r.node == node) {
                let TraceEvent::Down { until } = r.ev else {
                    panic!("case {case}: spot emits only Down records");
                };
                assert!(r.t >= prev_until, "case {case}: node {node} revived mid-outage");
                assert!(until > r.t, "case {case}: empty outage window");
                prev_until = until;
            }
        }

        let amplitude = rng.f64() * 2.0;
        let di = DiurnalSpec {
            nodes,
            horizon_s: horizon,
            period_s: 0.5 + rng.f64() * 10.0,
            amplitude,
            samples_per_period: 1 + rng.below(16) as usize,
            seed,
        };
        let d = diurnal(&di);
        assert_eq!(d.to_jsonl(), diurnal(&di).to_jsonl(), "case {case}: diurnal seed");
        for r in &d.records {
            let TraceEvent::Speed { factor } = r.ev else {
                panic!("case {case}: diurnal emits only Speed records");
            };
            assert!(
                factor >= 1.0 - 1e-12 && factor <= 1.0 + amplitude + 1e-12,
                "case {case}: diurnal factor {factor} outside [1, 1+{amplitude}]"
            );
        }

        let groups: Vec<Vec<usize>> = (0..nodes).map(|i| vec![i]).collect();
        let rack = RackFailureSpec {
            nodes,
            groups: groups.clone(),
            horizon_s: horizon,
            outages_per_rack: 1 + rng.below(3) as usize,
            mean_down_s: 0.1 + rng.f64() * 2.0,
            seed,
        };
        let r1 = rack_failures(&rack);
        assert_eq!(r1.to_jsonl(), rack_failures(&rack).to_jsonl(), "case {case}: rack seed");
        // a different seed moves at least one generator's output
        let other = SpotMarketSpec { seed: seed ^ 0x9e37, ..spot };
        if !a.records.is_empty() {
            assert_ne!(a.to_jsonl(), spot_market(&other).to_jsonl(), "case {case}: seed blind");
        }
    }
}

// ---------------------------------------------------------------------------
// vectorized kernel properties (DESIGN.md §12)
//
// Every vecmath kernel is pinned bit-for-bit against a straight-line
// scalar reference implementing the SAME frozen chunked order: lane
// l ∈ 0..8 accumulates indices i ≡ l (mod 8) over the full-chunk
// prefix, lanes combine by the fixed pairwise tree
// ((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7)), tail added serially last.
// Lengths 0..=65 exhaustively, plus adversarial values: NaN, ±inf,
// denormals, and sign-magnitude zeros.
// ---------------------------------------------------------------------------

use adloco::util::vecmath;

/// The frozen chunked-order reduction, written as the plainest possible
/// scalar loop (the reference the vectorized kernels must match bit for
/// bit).
fn ref_chunked_sum(terms: &[f64]) -> f64 {
    const L: usize = vecmath::LANES;
    let full = (terms.len() / L) * L;
    let mut lanes = [0.0f64; L];
    for (i, t) in terms[..full].iter().enumerate() {
        lanes[i % L] += *t;
    }
    let a = [lanes[0] + lanes[4], lanes[1] + lanes[5], lanes[2] + lanes[6], lanes[3] + lanes[7]];
    let mut s = (a[0] + a[2]) + (a[1] + a[3]);
    for t in &terms[full..] {
        s += *t;
    }
    s
}

/// Adversarial f32 generator: normals, huge/tiny magnitudes, NaN, ±inf,
/// denormals and both zeros.
fn adversarial_f32(rng: &mut Rng) -> f32 {
    match rng.below(12) {
        0 => f32::NAN,
        1 => f32::INFINITY,
        2 => f32::NEG_INFINITY,
        3 => 0.0f32,
        4 => -0.0f32,
        5 => f32::MIN_POSITIVE / 8.0,  // denormal
        6 => -f32::MIN_POSITIVE / 4.0, // denormal
        7 => f32::MAX,
        8 => f32::MIN,
        _ => rng.normal_ms(0.0, 10.0) as f32,
    }
}

fn adversarial_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| adversarial_f32(rng)).collect()
}

/// Bitwise equality that treats every NaN payload as equal (the scalar
/// reference and the kernel compute NaNs through identical operations,
/// but asserting via to_bits keeps the check honest for non-NaN values
/// while not failing on platform NaN-payload quirks).
fn assert_bits_eq(a: f64, b: f64, what: &str) {
    if a.is_nan() && b.is_nan() {
        return;
    }
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a:?} vs {b:?}");
}

fn assert_bits_eq_f32(a: f32, b: f32, what: &str) {
    if a.is_nan() && b.is_nan() {
        return;
    }
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a:?} vs {b:?}");
}

#[test]
fn prop_dot_and_norm_match_chunked_reference() {
    let mut rng = Rng::new(9100);
    for case in 0..CASES {
        // exhaustive lengths 0..=65 on the first cases, sampled (and
        // occasionally much larger) after
        let lengths: Vec<usize> = if case < 4 {
            (0..=65).collect()
        } else {
            vec![rng.below(66) as usize, 66 + rng.below(500) as usize]
        };
        for n in lengths {
            let a = adversarial_vec(&mut rng, n);
            let b = adversarial_vec(&mut rng, n);
            let dot_terms: Vec<f64> = (0..n).map(|i| a[i] as f64 * b[i] as f64).collect();
            assert_bits_eq(
                vecmath::dot_f32(&a, &b),
                ref_chunked_sum(&dot_terms),
                &format!("case {case}: dot n={n}"),
            );
            let norm_terms: Vec<f64> = (0..n).map(|i| a[i] as f64 * a[i] as f64).collect();
            assert_bits_eq(
                vecmath::norm_sq_f32(&a),
                ref_chunked_sum(&norm_terms),
                &format!("case {case}: norm_sq n={n}"),
            );
        }
    }
}

#[test]
fn prop_sq_diff_dot_matches_chunked_reference() {
    let mut rng = Rng::new(9200);
    for case in 0..CASES {
        let n = (rng.below(66)) as usize;
        let x = adversarial_vec(&mut rng, n);
        let g = adversarial_vec(&mut rng, n);
        let (sq, ip) = vecmath::sq_diff_dot_f32(&x, &g);
        let sq_terms: Vec<f64> = (0..n)
            .map(|i| {
                let d = x[i] as f64 - g[i] as f64;
                d * d
            })
            .collect();
        let ip_terms: Vec<f64> = (0..n).map(|i| x[i] as f64 * g[i] as f64).collect();
        assert_bits_eq(sq, ref_chunked_sum(&sq_terms), &format!("case {case}: sq n={n}"));
        assert_bits_eq(ip, ref_chunked_sum(&ip_terms), &format!("case {case}: ip n={n}"));
    }
}

#[test]
fn prop_quad_kernels_match_chunked_reference() {
    let mut rng = Rng::new(9300);
    for case in 0..CASES {
        let n = (rng.below(66)) as usize;
        let x = adversarial_vec(&mut rng, n);
        let xs = adversarial_vec(&mut rng, n);
        let eig = adversarial_vec(&mut rng, n);

        let loss_terms: Vec<f64> = (0..n)
            .map(|i| {
                let d = (x[i] - xs[i]) as f64;
                0.5 * eig[i] as f64 * d * d
            })
            .collect();
        assert_bits_eq(
            vecmath::quad_loss_f32(&x, &xs, &eig),
            ref_chunked_sum(&loss_terms),
            &format!("case {case}: quad_loss n={n}"),
        );

        let mut out = vec![0.0f32; n];
        let nsq = vecmath::quad_grad_f32(&x, &xs, &eig, &mut out);
        let mut ref_out = vec![0.0f32; n];
        for i in 0..n {
            ref_out[i] = eig[i] * (x[i] - xs[i]);
        }
        for i in 0..n {
            assert_bits_eq_f32(out[i], ref_out[i], &format!("case {case}: quad_grad[{i}]"));
        }
        let nsq_terms: Vec<f64> = ref_out.iter().map(|g| *g as f64 * *g as f64).collect();
        assert_bits_eq(nsq, ref_chunked_sum(&nsq_terms), &format!("case {case}: nsq n={n}"));
    }
}

#[test]
fn prop_elementwise_kernels_match_serial_loops() {
    let mut rng = Rng::new(9400);
    for case in 0..CASES {
        let n = (rng.below(66)) as usize;
        let x = adversarial_vec(&mut rng, n);
        let alpha = adversarial_f32(&mut rng);

        // axpy
        let mut y1 = adversarial_vec(&mut rng, n);
        let mut y2 = y1.clone();
        vecmath::axpy_f32(alpha, &x, &mut y1);
        for i in 0..n {
            y2[i] += alpha * x[i];
        }
        for i in 0..n {
            assert_bits_eq_f32(y1[i], y2[i], &format!("case {case}: axpy[{i}]"));
        }

        // merge weighted accumulate + write-back
        let w = rng.f64() * 2.0 - 0.5;
        let mut acc1 = vec![0.25f64; n];
        let mut acc2 = acc1.clone();
        vecmath::weighted_add_f32(w, &x, &mut acc1);
        for i in 0..n {
            acc2[i] += w * x[i] as f64;
        }
        for i in 0..n {
            assert_bits_eq(acc1[i], acc2[i], &format!("case {case}: weighted_add[{i}]"));
        }
        let mut o1 = vec![0.0f32; n];
        vecmath::write_back_f64(&acc1, &mut o1);
        for i in 0..n {
            assert_bits_eq_f32(o1[i], acc1[i] as f32, &format!("case {case}: write_back[{i}]"));
        }

        // sub_assign (outer Average)
        let mut a1 = adversarial_vec(&mut rng, n);
        let mut a2 = a1.clone();
        vecmath::sub_assign_f32(&mut a1, &x);
        for i in 0..n {
            a2[i] -= x[i];
        }
        for i in 0..n {
            assert_bits_eq_f32(a1[i], a2[i], &format!("case {case}: sub_assign[{i}]"));
        }
    }
}

#[test]
fn prop_optimizer_kernels_match_serial_loops() {
    let mut rng = Rng::new(9500);
    for case in 0..CASES {
        let n = (rng.below(66)) as usize;
        let grad = adversarial_vec(&mut rng, n);
        let lr = rng.f64() * 0.1;

        // inner SGD: x -= (lr * g) as f32
        let mut p1 = adversarial_vec(&mut rng, n);
        let mut p2 = p1.clone();
        vecmath::sgd_step_f32(&mut p1, &grad, lr);
        for i in 0..n {
            p2[i] -= (lr * grad[i] as f64) as f32;
        }
        for i in 0..n {
            assert_bits_eq_f32(p1[i], p2[i], &format!("case {case}: sgd[{i}]"));
        }

        // outer SGD: x = (x - lr*g) as f32
        let mut q1 = adversarial_vec(&mut rng, n);
        let mut q2 = q1.clone();
        vecmath::scale_sub_f32(&mut q1, &grad, lr, false);
        for i in 0..n {
            q2[i] = (q2[i] as f64 - lr * grad[i] as f64) as f32;
        }
        for i in 0..n {
            assert_bits_eq_f32(q1[i], q2[i], &format!("case {case}: outer_sgd[{i}]"));
        }

        // nesterov
        let momentum = rng.f64();
        let mut x1 = adversarial_vec(&mut rng, n);
        let mut v1 = adversarial_vec(&mut rng, n);
        let mut x2 = x1.clone();
        let mut v2 = v1.clone();
        vecmath::nesterov_step_f32(&mut x1, &mut v1, &grad, lr, momentum);
        for i in 0..n {
            let v = momentum * v2[i] as f64 + grad[i] as f64;
            v2[i] = v as f32;
            x2[i] = (x2[i] as f64 - lr * (momentum * v + grad[i] as f64)) as f32;
        }
        for i in 0..n {
            assert_bits_eq_f32(x1[i], x2[i], &format!("case {case}: nesterov x[{i}]"));
            assert_bits_eq_f32(v1[i], v2[i], &format!("case {case}: nesterov v[{i}]"));
        }

        // adamw
        let k = vecmath::AdamCoeffs {
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
            bc1: 1.0 - 0.9f64.powf((1 + case) as f64),
            bc2: 1.0 - 0.95f64.powf((1 + case) as f64),
            lr,
        };
        let mut ap1 = adversarial_vec(&mut rng, n);
        let mut m1 = adversarial_vec(&mut rng, n);
        let mut av1 = adversarial_vec(&mut rng, n);
        let (mut ap2, mut m2, mut av2) = (ap1.clone(), m1.clone(), av1.clone());
        vecmath::adamw_step_f32(&mut ap1, &mut m1, &mut av1, &grad, &k);
        for i in 0..n {
            let g = grad[i] as f64;
            let m = k.beta1 * m2[i] as f64 + (1.0 - k.beta1) * g;
            let v = k.beta2 * av2[i] as f64 + (1.0 - k.beta2) * g * g;
            m2[i] = m as f32;
            av2[i] = v as f32;
            let m_hat = m / k.bc1;
            let v_hat = v / k.bc2;
            let xx = ap2[i] as f64;
            ap2[i] = (xx - k.lr * (m_hat / (v_hat.sqrt() + k.eps) + k.weight_decay * xx)) as f32;
        }
        for i in 0..n {
            assert_bits_eq_f32(ap1[i], ap2[i], &format!("case {case}: adamw p[{i}]"));
            assert_bits_eq_f32(m1[i], m2[i], &format!("case {case}: adamw m[{i}]"));
            assert_bits_eq_f32(av1[i], av2[i], &format!("case {case}: adamw v[{i}]"));
        }
    }
}

#[test]
fn prop_delta_and_chunk_mean_match_serial_loops() {
    let mut rng = Rng::new(9600);
    for case in 0..CASES {
        let n = (rng.below(66)) as usize;
        let workers_n = 1 + rng.below(5) as usize;

        // compute_delta: per-index worker order preserved -> bit-identical
        let x_prev = adversarial_vec(&mut rng, n);
        let bufs: Vec<Vec<f32>> = (0..workers_n).map(|_| adversarial_vec(&mut rng, n)).collect();
        let workers: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let mut got = vec![0.0f32; n];
        vecmath::delta_from_workers(&x_prev, &workers, &mut got);
        let inv = 1.0 / workers_n as f64;
        for i in 0..n {
            let mut avg = 0.0f64;
            for w in &workers {
                avg += w[i] as f64;
            }
            avg *= inv;
            let want = (x_prev[i] as f64 - avg) as f32;
            assert_bits_eq_f32(got[i], want, &format!("case {case}: delta[{i}]"));
        }

        // chunk_mean_norm_sq: grad_out bit-identical to the serial mean,
        // s1 in the chunked order over the f64 means
        if n == 0 {
            continue; // chunk kernel requires d >= 0 with chunks >= 1; n=0 trivially skipped
        }
        let chunks = 1 + rng.below(8) as usize;
        let buf = adversarial_vec(&mut rng, chunks * n);
        let mut grad_out = vec![0.0f32; n];
        let s1 = vecmath::chunk_mean_norm_sq(&buf, chunks, &mut grad_out);
        let mut means = vec![0.0f64; n];
        for i in 0..n {
            let mut acc = 0.0f64;
            for c in 0..chunks {
                acc += buf[c * n + i] as f64;
            }
            means[i] = acc / chunks as f64;
            assert_bits_eq_f32(grad_out[i], means[i] as f32, &format!("case {case}: gbar[{i}]"));
        }
        let s1_terms: Vec<f64> = means.iter().map(|g| g * g).collect();
        assert_bits_eq(s1, ref_chunked_sum(&s1_terms), &format!("case {case}: s1 n={n}"));
    }
}

// ---------------------------------------------------------------------------
// service properties: HTTP parser totality and the run-state machine
// ---------------------------------------------------------------------------

/// A random well-formed HTTP/1.1 request (method, path, optional query,
/// a few headers, a content-length body) plus its serialized bytes.
fn random_request(rng: &mut Rng) -> Vec<u8> {
    let method = ["GET", "POST", "PUT", "DELETE"][rng.below(4) as usize];
    let depth = 1 + rng.below(3) as usize;
    let mut path = String::new();
    for _ in 0..depth {
        path.push('/');
        for _ in 0..(1 + rng.below(8)) {
            path.push((b'a' + rng.below(26) as u8) as char);
        }
    }
    if rng.below(3) == 0 {
        path.push_str(&format!("?from={}", rng.below(1000)));
    }
    let body_len = rng.below(40) as usize;
    let body: Vec<u8> = (0..body_len).map(|_| b'0' + rng.below(10) as u8).collect();
    let mut raw = format!("{method} {path} HTTP/1.1\r\n").into_bytes();
    for h in 0..rng.below(4) {
        raw.extend_from_slice(format!("x-extra-{h}: v{h}\r\n").as_bytes());
    }
    raw.extend_from_slice(format!("content-length: {body_len}\r\n\r\n").as_bytes());
    raw.extend_from_slice(&body);
    raw
}

const PROP_LIMITS: HttpLimits = HttpLimits { max_header_bytes: 16 * 1024, max_body_bytes: 1 << 20 };

#[test]
fn prop_http_parser_never_completes_or_panics_on_a_strict_prefix() {
    let mut rng = Rng::new(13_000);
    for case in 0..CASES {
        let raw = random_request(&mut rng);
        // every strict prefix is incomplete — never Ok(Some), never Err,
        // never a panic (truncation at EVERY byte boundary)
        for cut in 0..raw.len() {
            let got = parse_request(&raw[..cut], &PROP_LIMITS);
            assert!(
                matches!(got, Ok(None)),
                "case {case}: prefix len {cut}/{} parsed to {got:?}",
                raw.len()
            );
        }
        // the full buffer parses and consumes exactly itself, with or
        // without trailing bytes already sitting in the receive buffer
        let (req, consumed) = parse_request(&raw, &PROP_LIMITS).unwrap().unwrap();
        assert_eq!(consumed, raw.len(), "case {case}: consumed length");
        assert!(req.path.starts_with('/'), "case {case}: path {:?}", req.path);
        let mut with_tail = raw.clone();
        with_tail.extend_from_slice(b"GARBAGE");
        let (_, consumed2) = parse_request(&with_tail, &PROP_LIMITS).unwrap().unwrap();
        assert_eq!(consumed2, raw.len(), "case {case}: trailing bytes must not be consumed");
    }
}

#[test]
fn prop_http_parser_rejects_every_mutation_class_with_its_typed_code() {
    let mut rng = Rng::new(13_100);
    for case in 0..CASES {
        let raw = random_request(&mut rng);
        let text = String::from_utf8(raw.clone()).unwrap();
        let class = rng.below(6);
        let (mutated, want_status, want_code): (Vec<u8>, u16, &str) = match class {
            // protocol version the server does not speak
            0 => (text.replacen("HTTP/1.1", "HTTP/9.9", 1).into_bytes(), 400, "bad_request"),
            // header line with its colon knocked out
            1 => (text.replacen("content-length:", "content-length", 1).into_bytes(),
                400, "bad_request"),
            // unparsable content-length value
            2 => {
                let at = text.find("content-length:").unwrap();
                let eol = at + text[at..].find("\r\n").unwrap();
                let mut s = text.clone();
                s.replace_range(at..eol, "content-length: zzz");
                (s.into_bytes(), 400, "bad_request")
            }
            // chunked transfer is typed-rejected, not half-implemented
            3 => (
                text.replacen("content-length:", "transfer-encoding: chunked\r\ncontent-length:", 1)
                    .into_bytes(),
                501,
                "unsupported",
            ),
            // declared body beyond the byte budget
            4 => {
                let at = text.find("content-length:").unwrap();
                let eol = at + text[at..].find("\r\n").unwrap();
                let mut s = text.clone();
                s.replace_range(at..eol, "content-length: 9999999");
                (s.into_bytes(), 413, "payload_too_large")
            }
            // head larger than the configured cap (tiny-limit parse below)
            _ => (text.into_bytes(), 431, "header_too_large"),
        };
        let limits = if class == 5 {
            HttpLimits { max_header_bytes: 4, max_body_bytes: 1 << 20 }
        } else {
            PROP_LIMITS
        };
        let err = match parse_request(&mutated, &limits) {
            Err(e) => e,
            other => panic!("case {case} class {class}: expected typed reject, got {other:?}"),
        };
        assert_eq!(
            (err.status, err.code.as_str()),
            (want_status, want_code),
            "case {case} class {class}: {}",
            err.message
        );
    }
}

#[test]
fn prop_run_state_machine_has_no_exits_from_terminal_states() {
    // exhaustive transition matrix
    for &from in RunState::ALL.iter() {
        for &to in RunState::ALL.iter() {
            let allowed = transition_allowed(from, to);
            assert!(!allowed || from != to, "self-transition {from:?} must not be allowed");
            if from.is_terminal() {
                assert!(!allowed, "terminal {from:?} must not reach {to:?}");
            }
            if from == RunState::Submitted {
                assert_eq!(allowed, to == RunState::Running, "Submitted may only start");
            }
            if allowed && to == RunState::Submitted {
                panic!("{from:?} must not re-enter the queue");
            }
        }
        // mutations are accepted exactly where a future boundary exists
        assert_eq!(
            from.accepts_mutation(),
            matches!(from, RunState::Running | RunState::Paused),
            "{from:?}: accepts_mutation"
        );
        // wire names round-trip
        assert_eq!(RunState::parse(from.as_str()), Some(from));
    }
    assert_eq!(RunState::parse("bogus"), None);

    // random walks respect the matrix and always end in a terminal state
    let mut rng = Rng::new(13_200);
    for case in 0..CASES {
        let mut state = RunState::Submitted;
        let mut steps = 0;
        while !state.is_terminal() {
            let nexts: Vec<RunState> = RunState::ALL
                .iter()
                .copied()
                .filter(|&to| transition_allowed(state, to))
                .collect();
            assert!(!nexts.is_empty(), "case {case}: non-terminal {state:?} is stuck");
            // bias toward termination so the walk provably halts
            let pick = if steps > 20 {
                *nexts.iter().find(|s| s.is_terminal()).unwrap()
            } else {
                nexts[rng.below(nexts.len() as u64) as usize]
            };
            state = pick;
            steps += 1;
        }
        // once terminal the walk is over: no transition leaves
        for &to in RunState::ALL.iter() {
            assert!(!transition_allowed(state, to), "case {case}: {state:?} -> {to:?}");
        }
    }
}
