//! Kill-anywhere crash-fault harness (DESIGN.md §10, EXPERIMENTS.md
//! §Robustness): a training process may die at any instant, leaving
//! behind either a complete checkpoint or a damaged one. The recovery
//! contract has exactly two legal outcomes and this suite sweeps both:
//!
//! 1. **Valid file** — resuming from a checkpoint taken at *any* event
//!    boundary (every outer step of the schedule) reproduces the
//!    uninterrupted run bit for bit, via the shared comparators in
//!    `tests/common`.
//! 2. **Damaged file** — truncating the file at every section boundary
//!    and at strided byte offsets, flipping bits at strided offsets,
//!    and appending trailing bytes must each yield a clean typed
//!    [`InterchangeError`] from the import path and a clean `Err` from
//!    the full resume path. Zero panics, zero silent divergence.
//!
//! The matrix covers both schedulers (lockstep/event), 1 and 4 worker
//! threads, blocking and delayed-overlap collectives, and elastic
//! spawning on/off. Each config is one `#[test]` so the sweeps run in
//! parallel under the default test harness.

mod common;

use adloco::checkpoint::{import_bytes, section_boundaries, Checkpoint, Interchange};
use adloco::config::{presets, Config, OverlapMode, SchedulerKind};
use common::{assert_payloads_match, assert_suffix_matches, drive_step, new_coord};

/// A small but feature-dense schedule: multi-worker trainers, adaptive
/// batching, merging and a mid-schedule eval in four outer steps.
fn base_cfg(name: &str) -> Config {
    let mut cfg = presets::mock_default();
    cfg.name = name.into();
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.outer_steps = 4;
    cfg.algo.inner_steps = 6;
    cfg.algo.merge.frequency = 2;
    cfg.run.eval_every = 3;
    cfg
}

/// The elastic variant: two single-worker seed trainers over four
/// nodes guarantee spawns at outer step 1 (idle fraction 1.0 on the
/// unassigned nodes — DESIGN.md §9).
fn elastic_cfg(name: &str) -> Config {
    let mut cfg = base_cfg(name);
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.elastic.mode = adloco::config::ElasticMode::UtilThreshold;
    cfg.algo.elastic.idle_threshold = 0.5;
    cfg.algo.elastic.max_instances = 4;
    cfg
}

/// A damaged byte stream must fail the import with a typed error (the
/// return type statically guarantees it is an [`InterchangeError`]);
/// reaching this function at all — instead of a panic/abort — is the
/// property under test.
fn expect_typed_failure(raw: &[u8], what: &str) {
    match import_bytes(raw) {
        Ok(_) => panic!("{what}: damaged checkpoint imported successfully"),
        Err(e) => {
            assert!(!e.to_string().is_empty(), "{what}: error message is empty");
        }
    }
}

/// Damage sweep over one serialized checkpoint: truncation at every
/// section boundary and at ~97 strided offsets, single-bit flips at
/// ~131 strided offsets, and trailing garbage.
fn damage_sweep(bytes: &[u8], tag: &str) {
    let boundaries = section_boundaries(bytes);
    assert!(
        boundaries.len() >= 8,
        "{tag}: a v4 container has at least four sections worth of boundaries"
    );
    for &cut in &boundaries {
        if cut == bytes.len() {
            continue; // the full file is the valid case, handled elsewhere
        }
        expect_typed_failure(&bytes[..cut], &format!("{tag}: boundary cut at {cut}"));
    }
    let stride = (bytes.len() / 97).max(1);
    for cut in (0..bytes.len()).step_by(stride) {
        expect_typed_failure(&bytes[..cut], &format!("{tag}: byte cut at {cut}"));
    }
    let stride = (bytes.len() / 131).max(1);
    for pos in (0..bytes.len()).step_by(stride) {
        let mut flipped = bytes.to_vec();
        flipped[pos] ^= 1 << (pos % 8);
        expect_typed_failure(&flipped, &format!("{tag}: bit flip at {pos}"));
    }
    let mut trailing = bytes.to_vec();
    trailing.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
    expect_typed_failure(&trailing, &format!("{tag}: trailing garbage"));
}

/// The full harness for one config:
///
/// - reference run, uninterrupted;
/// - a second run checkpointed at **every** outer step;
/// - for each mid-schedule checkpoint: resume and compare bit for bit;
/// - for the midpoint checkpoint: the damage sweep, plus damaged files
///   driven through the *full* resume path (`Coordinator::run`) at each
///   section boundary, asserting a clean `Err` end to end.
fn kill_anywhere(cfg: Config, tag: &str) {
    let mut full = new_coord(&cfg);
    let rfull = full.run().unwrap();

    let outer = cfg.algo.outer_steps as u64;
    let mut part = new_coord(&cfg);
    let mut snaps: Vec<(u64, Checkpoint)> = Vec::new();
    for t in 1..=outer {
        drive_step(&mut part, t);
        snaps.push((t, part.snapshot(t)));
    }

    let dir = std::env::temp_dir().join("adloco_crash_fault");
    std::fs::create_dir_all(&dir).unwrap();

    for (k, snap) in snaps.iter().filter(|(k, _)| *k < outer) {
        let path = dir.join(format!("{tag}_{k}.ckpt")).to_str().unwrap().to_string();
        snap.save(&path).unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.run.resume_from = Some(path);
        let mut resumed = new_coord(&cfg2);
        let rres = resumed.run().unwrap();
        let t = format!("{tag} k={k}");
        assert_payloads_match(&rfull, &rres, &t);
        assert_suffix_matches(&full.recorder, &resumed.recorder, *k, &t);
    }

    // damage the midpoint checkpoint — it carries the densest state
    // (merges done, spawns live, syncs possibly in flight)
    let (mid_k, mid) = &snaps[snaps.len() / 2];
    let bytes = mid.to_bytes();
    damage_sweep(&bytes, tag);

    // end-to-end: a damaged file on disk must surface as a clean error
    // from the resume path itself, never a panic or a silent fresh run
    for &cut in &section_boundaries(&bytes) {
        if cut == bytes.len() {
            continue;
        }
        let path = dir
            .join(format!("{tag}_damaged_{cut}.ckpt"))
            .to_str()
            .unwrap()
            .to_string();
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.run.resume_from = Some(path);
        let err = new_coord(&cfg2).run().unwrap_err();
        assert!(
            !format!("{err:#}").is_empty(),
            "{tag}: resume from cut {cut} of the k={mid_k} file must explain itself"
        );
    }
}

#[test]
fn kill_anywhere_lockstep_serial_blocking() {
    kill_anywhere(base_cfg("cf_lock_t1"), "lock_t1");
}

#[test]
fn kill_anywhere_lockstep_parallel_blocking() {
    let mut cfg = base_cfg("cf_lock_t4");
    cfg.run.threads = 4;
    kill_anywhere(cfg, "lock_t4");
}

#[test]
fn kill_anywhere_event_serial_blocking() {
    let mut cfg = base_cfg("cf_event_t1");
    cfg.run.scheduler = SchedulerKind::Event;
    kill_anywhere(cfg, "event_t1");
}

#[test]
fn kill_anywhere_event_serial_delayed() {
    let mut cfg = base_cfg("cf_event_t1_delayed");
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.comm.overlap = OverlapMode::Delayed;
    kill_anywhere(cfg, "event_t1_delayed");
}

#[test]
fn kill_anywhere_event_parallel_delayed() {
    let mut cfg = base_cfg("cf_event_t4_delayed");
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    cfg.comm.overlap = OverlapMode::Delayed;
    kill_anywhere(cfg, "event_t4_delayed");
}

#[test]
fn kill_anywhere_elastic_lockstep_serial() {
    kill_anywhere(elastic_cfg("cf_elastic_t1"), "elastic_t1");
}

#[test]
fn kill_anywhere_elastic_event_parallel_delayed() {
    let mut cfg = elastic_cfg("cf_elastic_t4_delayed");
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.run.threads = 4;
    cfg.comm.overlap = OverlapMode::Delayed;
    kill_anywhere(cfg, "elastic_t4_delayed");
}

#[test]
fn minimal_checkpoints_survive_the_damage_sweep_too() {
    // the warm-start variant shares the container, so it shares the
    // integrity contract: every cut and flip is a typed error
    let cfg = base_cfg("cf_minimal");
    let mut c = new_coord(&cfg);
    for t in 1..=2 {
        drive_step(&mut c, t);
    }
    let bytes = c.snapshot(2).to_minimal().to_bytes();
    match import_bytes(&bytes).unwrap() {
        Interchange::Minimal(_) => {}
        Interchange::Complete(_) => panic!("minimal container decoded as complete"),
    }
    damage_sweep(&bytes, "minimal");
}
