#!/usr/bin/env python3
"""Build the committed v1/v2/v3 golden checkpoint fixtures.

The byte layouts mirror adloco's historical writers (``export_v1`` /
``export_v2`` / ``export_v3`` in ``src/checkpoint/legacy.rs``) applied
to the fixture snapshot defined in ``tests/interchange_fixtures.rs`` —
the two definitions must stay in lockstep, and the test suite asserts
byte equality between these files and the Rust writers.

The authoritative regeneration path is::

    GOLDEN_WRITE=1 cargo test --test interchange_fixtures

This script exists so the fixtures can be rebuilt without a Rust
toolchain and as an independent, executable description of the legacy
container:

    "ADLC"  u32-LE version  u32-LE header_len  header-JSON  raw-f32-blobs
    u32-LE CRC32(everything above)

u64 values are 16-digit hex strings (JSON numbers are f64 and round
above 2^53); f64 values are the hex of their raw bits (bit-exact,
survives non-finite values); small structural integers stay plain.
"""

import json
import os
import struct
import zlib


def hx(v):
    return format(v, "016x")


def fbits(x):
    return hx(struct.unpack("<Q", struct.pack("<d", x))[0])


def rng(s, spare=None):
    return {"s": [hx(w) for w in s], "spare": None if spare is None else fbits(spare)}


def ema(value, steps):
    return {"value": fbits(value), "steps": hx(steps)}


def f32s(xs):
    return b"".join(struct.pack("<f", x) for x in xs)


# --------------------------------------------------------------------------
# the fixture snapshot (keep identical to fixture_complete() in
# tests/interchange_fixtures.rs)
# --------------------------------------------------------------------------

RNG_MAIN = rng(
    [0x0123456789ABCDEF, 0xFEDCBA9876543210, 0x0F1E2D3C4B5A6978, 0x1122334455667788],
    spare=0.5,
)
NOISE_A = rng([0x1111111111111111, 0x2222222222222222, 0x3333333333333333, 0x4444444444444444])
TIME_A = rng(
    [0x5555555555555555, 0x6666666666666666, 0x7777777777777777, 0x8888888888888888],
    spare=-0.75,
)
SAMPLER_RNG_A = rng([9, 10, 11, 12])
NOISE_B = rng([0xAAAAAAAAAAAAAAAA, 0xBBBBBBBBBBBBBBBB, 0xCCCCCCCCCCCCCCCC, 0xDDDDDDDDDDDDDDDD])
TIME_B = rng([0xEEEEEEEEEEEEEEEE, 0xFFFFFFFFFFFFFFFF, 0x0123012301230123, 0x4567456745674567])
SAMPLER_RNG_B = rng([13, 14, 15, 16], spare=1.5)

PARAMS = [0.5, -1.25, 3.0, 0.0625]
VELOCITY = [0.125, -0.5, 0.0, 2.0]
DELTA = [0.25, -0.25, 0.5, -0.5]
W_A = {"params": [1.0, 2.0, -3.0, 0.25], "m": [0.0625, 0.0, -0.0625, 0.125], "v": [0.5, 0.25, 0.125, 0.0625]}
W_B = {"params": [-1.0, 0.5, 0.75, -0.125], "m": [0.25, -0.25, 0.0, 0.5], "v": [0.0625, 0.125, 0.25, 0.5]}

TRAINER = {
    "id": 0,
    "param_len": 4,
    "velocity_len": 4,
    "requested_batch": 8,
    "inner_steps_done": hx(18),
    "observations": hx(36),
    "sigma2_ema": ema(0.5, 36),
    "ip_var_ema": ema(0.25, 36),
    "s1_ema": ema(0.125, 36),
    "shard": [0, 2, 4],
    "pending": {
        "posted_at": fbits(3.5),
        "completes_at": fbits(3.75),
        "time_s": fbits(0.25),
        "sent_samples": hx(4096),
        "delta_len": 4,
        "phases": [
            {"wan": False, "bytes": hx(512), "participants": 2},
            {"wan": True, "bytes": hx(256), "participants": 2},
        ],
    },
    "workers": [
        {
            "param_len": 4,
            "step": hx(18),
            "active": True,
            "noise_rng": NOISE_A,
            "time_rng": TIME_A,
            "sampler": {
                "shard": [0, 2, 4],
                "order": [2, 0, 1],
                "cursor": 1,
                "drawn": hx(6),
                "rng": SAMPLER_RNG_A,
            },
        },
        {
            "param_len": 4,
            "step": hx(18),
            "active": False,
            "noise_rng": NOISE_B,
            "time_rng": TIME_B,
            "sampler": {
                "shard": [1, 3, 5],
                "order": [0, 1, 2],
                "cursor": 0,
                "drawn": hx(0),
                "rng": SAMPLER_RNG_B,
            },
        },
    ],
}

REGISTRY = [
    {
        "id": 0,
        "state": "active",
        "origin": "seed",
        "born_outer": hx(0),
        "born_at_s": fbits(0.0),
        "retired_outer": None,
        "workers": [[0, 0]],
    },
    {
        "id": 1,
        "state": "spawned",
        "origin": "util",
        "born_outer": hx(2),
        "born_at_s": fbits(3.5),
        "retired_outer": None,
        "workers": [[1, 1]],
    },
]

STATE = {
    "outer_step": hx(3),
    "total_samples": hx(2**53 + 1),  # exercises the hex-over-JSON-number rule
    "comm_count": hx(12),
    "comm_bytes": hx(4096),
    "comm_wan_bytes": hx(1024),
    "overlap_hidden_s": fbits(0.5),
    "clock_times": [fbits(1.5), fbits(2.25)],
    "busy_s": [fbits(1.0), fbits(2.0)],
    "wait_s": [fbits(0.25), fbits(0.0)],
    "comm_s": [fbits(0.125), fbits(0.0625)],
    "comm_hidden_s": [fbits(0.0), fbits(0.0)],
    "preempted_s": [fbits(0.0), fbits(0.5)],
    "vacant_s": [fbits(0.0), fbits(0.75)],
    "spawn_count": hx(1),
    "last_spawn_outer": hx(2),
    "last_merge_rep": 0,
    "live_rounds_sum": hx(5),
    "rounds_count": hx(3),
}

# blob order: per trainer params, velocity, pending delta, then per
# worker params/m/v (src/checkpoint/mod.rs::blob_bytes)
BLOB_COMPLETE = (
    f32s(PARAMS)
    + f32s(VELOCITY)
    + f32s(DELTA)
    + f32s(W_A["params"]) + f32s(W_A["m"]) + f32s(W_A["v"])
    + f32s(W_B["params"]) + f32s(W_B["m"]) + f32s(W_B["v"])
)


def container(version, header_obj, blobs):
    header = json.dumps(header_obj, separators=(",", ":")).encode()
    out = b"ADLC" + struct.pack("<I", version) + struct.pack("<I", len(header)) + header + blobs
    return out + struct.pack("<I", zlib.crc32(out) & 0xFFFFFFFF)


def v3():
    header = {"config_name": "fixture"}
    header.update(STATE)
    header["registry"] = REGISTRY
    header["rng"] = RNG_MAIN
    header["trainers"] = [TRAINER]
    return container(3, header, BLOB_COMPLETE)


def v2():
    # the v3 layout minus the elastic fields (vacancy, spawn
    # bookkeeping, round census, registry)
    header = {"config_name": "fixture"}
    for k, v in STATE.items():
        if k in ("vacant_s", "spawn_count", "last_spawn_outer", "last_merge_rep",
                 "live_rounds_sum", "rounds_count"):
            continue
        header[k] = v
    header["rng"] = RNG_MAIN
    header["trainers"] = [TRAINER]
    return container(2, header, BLOB_COMPLETE)


def v1():
    header = {
        "config_name": "fixture",
        "outer_step": hx(3),
        "rng": RNG_MAIN,
        "trainers": [
            {
                "id": 0,
                "param_len": 4,
                "workers": [
                    {"noise_rng": NOISE_A, "time_rng": TIME_A},
                    {"noise_rng": NOISE_B, "time_rng": TIME_B},
                ],
            }
        ],
    }
    return container(1, header, f32s(PARAMS))


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    for name, build in (("v1.ckpt", v1), ("v2.ckpt", v2), ("v3.ckpt", v3)):
        data = build()
        # self-check: CRC trailer and header JSON must verify
        assert data[:4] == b"ADLC"
        assert struct.unpack("<I", data[-4:])[0] == zlib.crc32(data[:-4]) & 0xFFFFFFFF
        hlen = struct.unpack("<I", data[8:12])[0]
        json.loads(data[12 : 12 + hlen].decode())
        path = os.path.join(here, name)
        with open(path, "wb") as f:
            f.write(data)
        print(f"wrote {path} ({len(data)} bytes)")


if __name__ == "__main__":
    main()
