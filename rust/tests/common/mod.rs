//! Shared helpers for the integration suites: the canonical run driver,
//! the FNV golden-digest serialization used by the seam anchors
//! (`tests/topology.rs`, `tests/overlap.rs`, `tests/checkpoint_resume.rs`)
//! and the bit-exact resume comparators shared by the resume suite and
//! the kill-anywhere harness (`tests/crash_fault.rs`).
//!
//! The `digest` serialization is FROZEN: it writes exactly the fields it
//! wrote when the flat golden was first pinned, so refactors that add
//! record fields cannot silently shift historical digests. New fields
//! get their own extended digest (`digest_with_overlap`).
#![allow(dead_code)]

use adloco::comm::{CommLedger, CommScope};
use adloco::config::{Config, SchedulerKind};
use adloco::coordinator::{Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;

/// Build + run a config, returning the full determinism-contract payload.
pub fn run(cfg: Config) -> (RunResult, Recorder, CommLedger) {
    let engine = build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let r = c.run().unwrap();
    (r, c.recorder.clone(), c.ledger().clone())
}

/// Build a coordinator for a config (without running it).
pub fn new_coord(cfg: &Config) -> Coordinator {
    let engine = build_engine(cfg).unwrap();
    Coordinator::new(cfg.clone(), engine).unwrap()
}

/// One outer step, dispatched exactly like `Coordinator::run` does.
pub fn drive_step(c: &mut Coordinator, t: u64) {
    let serial_lockstep =
        c.config().run.scheduler == SchedulerKind::Lockstep && c.threads() <= 1;
    if serial_lockstep {
        c.step_outer(t).unwrap();
    } else {
        c.step_outer_event(t).unwrap();
    }
}

/// The `RunResult` determinism payload, bit for bit (minus `best_ppl` —
/// it minimizes over the pre-checkpoint prefix a resumed run never
/// re-executes — and the wall-clock/threads perf fields).
pub fn assert_payloads_match(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.final_ppl.to_bits(), b.final_ppl.to_bits(), "{tag}: final ppl");
    assert_eq!(a.total_inner_steps, b.total_inner_steps, "{tag}: inner steps");
    assert_eq!(a.total_samples, b.total_samples, "{tag}: samples");
    assert_eq!(a.comm_count, b.comm_count, "{tag}: comm count");
    assert_eq!(a.comm_bytes, b.comm_bytes, "{tag}: comm bytes");
    assert_eq!(a.wan_comm_bytes, b.wan_comm_bytes, "{tag}: WAN bytes");
    assert_eq!(
        a.virtual_time_s.to_bits(),
        b.virtual_time_s.to_bits(),
        "{tag}: virtual time ({} vs {})",
        a.virtual_time_s,
        b.virtual_time_s
    );
    assert_eq!(a.trainers_left, b.trainers_left, "{tag}: trainers left");
    assert_eq!(
        a.total_idle_s.to_bits(),
        b.total_idle_s.to_bits(),
        "{tag}: idle time"
    );
    assert_eq!(
        a.mean_utilization.to_bits(),
        b.mean_utilization.to_bits(),
        "{tag}: utilization"
    );
    assert_eq!(
        a.overlap_hidden_s.to_bits(),
        b.overlap_hidden_s.to_bits(),
        "{tag}: overlap hidden"
    );
    assert_eq!(a.time_to_target, b.time_to_target, "{tag}: time to target");
    assert_eq!(a.spawn_count, b.spawn_count, "{tag}: spawn count");
    assert_eq!(
        a.mean_live_instances.to_bits(),
        b.mean_live_instances.to_bits(),
        "{tag}: mean live instances"
    );
    assert_eq!(
        a.total_vacant_s.to_bits(),
        b.total_vacant_s.to_bits(),
        "{tag}: vacant time"
    );
}

/// The resumed run's record streams must equal the uninterrupted run's
/// post-k suffix, field for field and bit for bit; utilization rows
/// (whole-run accumulators, restored from the checkpoint) must match in
/// full.
pub fn assert_suffix_matches(full: &Recorder, res: &Recorder, k: u64, tag: &str) {
    let full_steps: Vec<_> = full.steps.iter().filter(|s| s.outer_step > k).collect();
    assert_eq!(full_steps.len(), res.steps.len(), "{tag}: step suffix length");
    for (a, b) in full_steps.iter().zip(res.steps.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer, a.worker),
            (b.global_step, b.outer_step, b.trainer, b.worker),
            "{tag}: step identity"
        );
        assert_eq!(a.batch, b.batch, "{tag}: step batch");
        assert_eq!(a.requested_batch, b.requested_batch, "{tag}: requested");
        assert_eq!(a.accum_steps, b.accum_steps, "{tag}: accum");
        assert_eq!(a.clamped, b.clamped, "{tag}: clamp flag");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{tag}: step loss");
        assert_eq!(
            a.grad_sq_norm.to_bits(),
            b.grad_sq_norm.to_bits(),
            "{tag}: grad norm"
        );
        assert_eq!(a.sigma2.to_bits(), b.sigma2.to_bits(), "{tag}: sigma2");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{tag}: step time"
        );
    }
    let full_evals: Vec<_> = full.evals.iter().filter(|e| e.outer_step > k).collect();
    assert_eq!(full_evals.len(), res.evals.len(), "{tag}: eval suffix length");
    for (a, b) in full_evals.iter().zip(res.evals.iter()) {
        assert_eq!(
            (a.global_step, a.outer_step, a.trainer),
            (b.global_step, b.outer_step, b.trainer),
            "{tag}: eval identity"
        );
        assert_eq!(a.comm_count, b.comm_count, "{tag}: eval comm count");
        assert_eq!(a.comm_bytes, b.comm_bytes, "{tag}: eval comm bytes");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{tag}: eval loss");
        assert_eq!(
            a.perplexity.to_bits(),
            b.perplexity.to_bits(),
            "{tag}: eval ppl"
        );
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{tag}: eval time"
        );
    }
    let full_merges: Vec<_> = full.merges.iter().filter(|m| m.outer_step > k).collect();
    assert_eq!(full_merges.len(), res.merges.len(), "{tag}: merge suffix length");
    for (a, b) in full_merges.iter().zip(res.merges.iter()) {
        assert_eq!(a.merged, b.merged, "{tag}: merged set");
        assert_eq!(a.representative, b.representative, "{tag}: representative");
        assert_eq!(a.trainers_left, b.trainers_left, "{tag}: trainers left");
        assert_eq!(
            a.virtual_time_s.to_bits(),
            b.virtual_time_s.to_bits(),
            "{tag}: merge time"
        );
    }
    assert_eq!(
        full.utilization.len(),
        res.utilization.len(),
        "{tag}: utilization rows"
    );
    for (a, b) in full.utilization.iter().zip(res.utilization.iter()) {
        assert_eq!(
            (a.trainer, a.worker, a.node),
            (b.trainer, b.worker, b.node),
            "{tag}: utilization identity"
        );
        assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits(), "{tag}: busy_s");
        assert_eq!(a.wait_s.to_bits(), b.wait_s.to_bits(), "{tag}: wait_s");
        assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits(), "{tag}: comm_s");
        assert_eq!(a.hidden_s.to_bits(), b.hidden_s.to_bits(), "{tag}: hidden_s");
        assert_eq!(
            a.preempted_s.to_bits(),
            b.preempted_s.to_bits(),
            "{tag}: preempted_s"
        );
    }
}

/// FNV-1a over a byte string (the digest hash).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Canonical serialization of everything the determinism contract
/// covers: record streams, ledger, and the RunResult payload, with
/// every f64 rendered as raw bits. FROZEN — see module docs.
pub fn digest(r: &RunResult, rec: &Recorder, ledger: &CommLedger) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for e in &ledger.events {
        let kind = match e.kind {
            adloco::comm::CommKind::OuterSync => "sync",
            adloco::comm::CommKind::Merge => "merge",
        };
        let scope = match e.scope {
            CommScope::Intra => "intra",
            CommScope::Wan => "wan",
        };
        let _ = writeln!(
            s,
            "L:{kind}:{scope}:{}:{}:{}:{:016x}",
            e.bytes,
            e.participants,
            e.at_inner_step,
            e.at_virtual_s.to_bits()
        );
    }
    for st in &rec.steps {
        let _ = writeln!(
            s,
            "S:{}:{}:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}:{:016x}",
            st.global_step,
            st.outer_step,
            st.trainer,
            st.worker,
            st.batch,
            st.requested_batch,
            st.accum_steps,
            st.loss.to_bits(),
            st.grad_sq_norm.to_bits(),
            st.sigma2.to_bits(),
            st.virtual_time_s.to_bits()
        );
    }
    for e in &rec.evals {
        let _ = writeln!(
            s,
            "E:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}",
            e.global_step,
            e.outer_step,
            e.trainer,
            e.comm_count,
            e.comm_bytes,
            e.loss.to_bits(),
            e.perplexity.to_bits(),
            e.virtual_time_s.to_bits()
        );
    }
    for m in &rec.merges {
        let _ = writeln!(
            s,
            "M:{}:{:?}:{}:{}:{:016x}",
            m.outer_step,
            m.merged,
            m.representative,
            m.trainers_left,
            m.virtual_time_s.to_bits()
        );
    }
    for u in &rec.utilization {
        let _ = writeln!(
            s,
            "U:{}:{}:{}:{:016x}:{:016x}:{:016x}:{:016x}",
            u.trainer,
            u.worker,
            u.node,
            u.busy_s.to_bits(),
            u.wait_s.to_bits(),
            u.comm_s.to_bits(),
            u.preempted_s.to_bits()
        );
    }
    let _ = writeln!(
        s,
        "R:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}",
        r.total_inner_steps,
        r.total_samples,
        r.comm_count,
        r.comm_bytes,
        r.trainers_left,
        r.best_ppl.to_bits(),
        r.final_ppl.to_bits(),
        r.virtual_time_s.to_bits()
    );
    format!("{:016x}", fnv1a(s.as_bytes()))
}

/// Extended digest for the delayed-overlap seam (DESIGN.md §8): the
/// frozen serialization plus the overlap-specific payload — per-step
/// clamp flags, per-worker hidden-comm seconds and the run-level
/// `overlap_hidden_s` — so future comm refactors can't silently shift
/// the new observables either.
pub fn digest_with_overlap(r: &RunResult, rec: &Recorder, ledger: &CommLedger) -> String {
    use std::fmt::Write as _;
    let mut s = digest(r, rec, ledger);
    for st in &rec.steps {
        let _ = writeln!(s, "C:{}:{}:{}", st.trainer, st.global_step, st.clamped as u8);
    }
    for u in &rec.utilization {
        let _ = writeln!(s, "H:{}:{}:{:016x}", u.trainer, u.worker, u.hidden_s.to_bits());
    }
    let _ = writeln!(s, "O:{:016x}", r.overlap_hidden_s.to_bits());
    format!("{:016x}", fnv1a(s.as_bytes()))
}
