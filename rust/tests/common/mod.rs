//! Shared helpers for the integration suites: the canonical run driver
//! and the FNV golden-digest serialization used by the seam anchors
//! (`tests/topology.rs`, `tests/overlap.rs`, `tests/checkpoint_resume.rs`).
//!
//! The `digest` serialization is FROZEN: it writes exactly the fields it
//! wrote when the flat golden was first pinned, so refactors that add
//! record fields cannot silently shift historical digests. New fields
//! get their own extended digest (`digest_with_overlap`).
#![allow(dead_code)]

use adloco::comm::{CommLedger, CommScope};
use adloco::config::Config;
use adloco::coordinator::{Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;

/// Build + run a config, returning the full determinism-contract payload.
pub fn run(cfg: Config) -> (RunResult, Recorder, CommLedger) {
    let engine = build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let r = c.run().unwrap();
    (r, c.recorder.clone(), c.ledger().clone())
}

/// FNV-1a over a byte string (the digest hash).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Canonical serialization of everything the determinism contract
/// covers: record streams, ledger, and the RunResult payload, with
/// every f64 rendered as raw bits. FROZEN — see module docs.
pub fn digest(r: &RunResult, rec: &Recorder, ledger: &CommLedger) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for e in &ledger.events {
        let kind = match e.kind {
            adloco::comm::CommKind::OuterSync => "sync",
            adloco::comm::CommKind::Merge => "merge",
        };
        let scope = match e.scope {
            CommScope::Intra => "intra",
            CommScope::Wan => "wan",
        };
        let _ = writeln!(
            s,
            "L:{kind}:{scope}:{}:{}:{}:{:016x}",
            e.bytes,
            e.participants,
            e.at_inner_step,
            e.at_virtual_s.to_bits()
        );
    }
    for st in &rec.steps {
        let _ = writeln!(
            s,
            "S:{}:{}:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}:{:016x}",
            st.global_step,
            st.outer_step,
            st.trainer,
            st.worker,
            st.batch,
            st.requested_batch,
            st.accum_steps,
            st.loss.to_bits(),
            st.grad_sq_norm.to_bits(),
            st.sigma2.to_bits(),
            st.virtual_time_s.to_bits()
        );
    }
    for e in &rec.evals {
        let _ = writeln!(
            s,
            "E:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}",
            e.global_step,
            e.outer_step,
            e.trainer,
            e.comm_count,
            e.comm_bytes,
            e.loss.to_bits(),
            e.perplexity.to_bits(),
            e.virtual_time_s.to_bits()
        );
    }
    for m in &rec.merges {
        let _ = writeln!(
            s,
            "M:{}:{:?}:{}:{}:{:016x}",
            m.outer_step,
            m.merged,
            m.representative,
            m.trainers_left,
            m.virtual_time_s.to_bits()
        );
    }
    for u in &rec.utilization {
        let _ = writeln!(
            s,
            "U:{}:{}:{}:{:016x}:{:016x}:{:016x}:{:016x}",
            u.trainer,
            u.worker,
            u.node,
            u.busy_s.to_bits(),
            u.wait_s.to_bits(),
            u.comm_s.to_bits(),
            u.preempted_s.to_bits()
        );
    }
    let _ = writeln!(
        s,
        "R:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}",
        r.total_inner_steps,
        r.total_samples,
        r.comm_count,
        r.comm_bytes,
        r.trainers_left,
        r.best_ppl.to_bits(),
        r.final_ppl.to_bits(),
        r.virtual_time_s.to_bits()
    );
    format!("{:016x}", fnv1a(s.as_bytes()))
}

/// Extended digest for the delayed-overlap seam (DESIGN.md §8): the
/// frozen serialization plus the overlap-specific payload — per-step
/// clamp flags, per-worker hidden-comm seconds and the run-level
/// `overlap_hidden_s` — so future comm refactors can't silently shift
/// the new observables either.
pub fn digest_with_overlap(r: &RunResult, rec: &Recorder, ledger: &CommLedger) -> String {
    use std::fmt::Write as _;
    let mut s = digest(r, rec, ledger);
    for st in &rec.steps {
        let _ = writeln!(s, "C:{}:{}:{}", st.trainer, st.global_step, st.clamped as u8);
    }
    for u in &rec.utilization {
        let _ = writeln!(s, "H:{}:{}:{:016x}", u.trainer, u.worker, u.hidden_s.to_bits());
    }
    let _ = writeln!(s, "O:{:016x}", r.overlap_hidden_s.to_bits());
    format!("{:016x}", fnv1a(s.as_bytes()))
}
