//! FIG6 — event-path scalability under fleet-scale traced workloads
//! (DESIGN.md §11): the `fleet_trace` preset's spot-market replay scaled
//! to 100 / 1,000 / 10,000 workers, recording wall-clock versus node
//! count and pinning each point's record stream with the FROZEN FNV
//! digest recipe (the `tests/topology.rs` golden-seam convention:
//! in-process cross-thread equality always asserted; the absolute bits
//! live in `tests/fixtures/fig6_scale.txt`, written on the reference
//! machine with `GOLDEN_WRITE=1` and compared whenever present).
//!
//! Asserted invariants:
//!
//! * every scale point completes on the event scheduler — including the
//!   10k-worker point in `--smoke` mode (the CI leg);
//! * the smallest point is **bit-identical** across `threads=1` and
//!   `threads=4` (the determinism contract, DESIGN.md §6, at the
//!   fleet-trace seam);
//! * the generated trace is identical across the points' construction
//!   (same seed ⇒ same per-node streams), so digests are functions of
//!   scale alone.
//!
//! Output: summary table + bench_results/fig6_scale.csv + the repo's
//! first perf artifact, bench_results/BENCH_fig6.json (wall-clock vs
//! node count rows; uploaded by CI).
//!
//! Run: `cargo bench --bench fig6_scale` (`--smoke` — or `--quick` /
//! `ADLOCO_BENCH_QUICK=1` — for the CI-sized schedule; `--threads N`
//! fans worker chains out, bit-identically).

use adloco::benchkit::{
    bench_args, quick_mode, threads_arg, wall_time, write_json_artifact, Table,
};
use adloco::comm::{CommLedger, CommScope};
use adloco::config::{presets, Config, NodeConfig};
use adloco::coordinator::{run_experiment, Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;
use adloco::util::JsonValue;

fn smoke_mode() -> bool {
    quick_mode() || bench_args().iter().any(|a| a == "--smoke")
}

/// The `fleet_trace` preset rescaled to `workers` workers: 4 workers
/// per trainer, 2 workers per node, uniform hosts. The trace source
/// (spot-market generator) rides along and regenerates for the larger
/// node count from the same seed-derived streams.
fn scale_config(workers: usize, smoke: bool, threads: usize) -> Config {
    assert!(workers % 4 == 0 && workers % 2 == 0);
    let mut cfg = presets::fleet_trace();
    cfg.name = format!("fig6_w{workers}");
    cfg.algo.num_trainers = workers / 4;
    cfg.algo.workers_per_trainer = 4;
    cfg.cluster.nodes =
        (0..workers / 2).map(|_| NodeConfig { max_batch: 32, speed: 1.0 }).collect();
    if smoke {
        cfg.algo.outer_steps = 3;
        cfg.algo.inner_steps = 4;
        cfg.engine = adloco::config::EngineConfig::Mock { dim: 64, noise: 1.0, condition: 10.0 };
        // fixed micro-batches keep the smoke flop budget linear in the
        // worker count (adaptive growth is fig1-fig3 territory)
        cfg.algo.batching.adaptive = false;
        cfg.algo.fixed_batch = 4;
        cfg.run.eval_batches = 1;
        cfg.data.val_sequences = 64;
    }
    cfg.run.threads = threads;
    cfg
}

fn run_arm(cfg: Config) -> (RunResult, Recorder, CommLedger, f64, JsonValue) {
    let rounds = cfg.algo.outer_steps as f64;
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    // per-round average allocation count across the whole run (includes
    // the first round's arena growth — the amortized figure); null
    // without `--features perf-count-alloc`
    let before = adloco::util::alloc_count::snapshot();
    let (r, wall_s) = wall_time(|| coord.run().unwrap());
    let d = adloco::util::alloc_count::snapshot().since(before);
    let allocs_per_round = if adloco::util::alloc_count::counting_enabled() && rounds > 0.0 {
        JsonValue::num(d.allocs as f64 / rounds)
    } else {
        JsonValue::Null
    };
    let rec = coord.recorder.clone();
    let ledger = coord.ledger().clone();
    (r, rec, ledger, wall_s, allocs_per_round)
}

/// FNV-1a over a byte string (the digest hash).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The FROZEN golden-digest serialization from `tests/common/mod.rs`,
/// inlined because benches cannot link the test support crate. Any
/// drift from that recipe is a bug: the fixture written here must stay
/// comparable with the digests the integration suites pin.
fn digest(r: &RunResult, rec: &Recorder, ledger: &CommLedger) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for e in &ledger.events {
        let kind = match e.kind {
            adloco::comm::CommKind::OuterSync => "sync",
            adloco::comm::CommKind::Merge => "merge",
        };
        let scope = match e.scope {
            CommScope::Intra => "intra",
            CommScope::Wan => "wan",
        };
        let _ = writeln!(
            s,
            "L:{kind}:{scope}:{}:{}:{}:{:016x}",
            e.bytes,
            e.participants,
            e.at_inner_step,
            e.at_virtual_s.to_bits()
        );
    }
    for st in &rec.steps {
        let _ = writeln!(
            s,
            "S:{}:{}:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}:{:016x}",
            st.global_step,
            st.outer_step,
            st.trainer,
            st.worker,
            st.batch,
            st.requested_batch,
            st.accum_steps,
            st.loss.to_bits(),
            st.grad_sq_norm.to_bits(),
            st.sigma2.to_bits(),
            st.virtual_time_s.to_bits()
        );
    }
    for e in &rec.evals {
        let _ = writeln!(
            s,
            "E:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}",
            e.global_step,
            e.outer_step,
            e.trainer,
            e.comm_count,
            e.comm_bytes,
            e.loss.to_bits(),
            e.perplexity.to_bits(),
            e.virtual_time_s.to_bits()
        );
    }
    for m in &rec.merges {
        let _ = writeln!(
            s,
            "M:{}:{:?}:{}:{}:{:016x}",
            m.outer_step,
            m.merged,
            m.representative,
            m.trainers_left,
            m.virtual_time_s.to_bits()
        );
    }
    for u in &rec.utilization {
        let _ = writeln!(
            s,
            "U:{}:{}:{}:{:016x}:{:016x}:{:016x}:{:016x}",
            u.trainer,
            u.worker,
            u.node,
            u.busy_s.to_bits(),
            u.wait_s.to_bits(),
            u.comm_s.to_bits(),
            u.preempted_s.to_bits()
        );
    }
    let _ = writeln!(
        s,
        "R:{}:{}:{}:{}:{}:{:016x}:{:016x}:{:016x}",
        r.total_inner_steps,
        r.total_samples,
        r.comm_count,
        r.comm_bytes,
        r.trainers_left,
        r.best_ppl.to_bits(),
        r.final_ppl.to_bits(),
        r.virtual_time_s.to_bits()
    );
    format!("{:016x}", fnv1a(s.as_bytes()))
}

/// Golden fixture for the smoke grid (the CI configuration): one
/// `workers=<N> digest=<hex>` line per scale point. `GOLDEN_WRITE=1`
/// (re)writes it on the reference machine; when the file exists, every
/// run — on both RUN_THREADS CI legs — must reproduce it bit for bit.
fn check_fixture(points: &[(usize, String)]) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/fig6_scale.txt");
    let rendered: String =
        points.iter().map(|(w, d)| format!("workers={w} digest={d}\n")).collect();
    if std::env::var("GOLDEN_WRITE").as_deref() == Ok("1") {
        std::fs::write(path, &rendered).unwrap();
        eprintln!("fig6_scale: wrote golden fixture {path}");
        return;
    }
    match std::fs::read_to_string(path) {
        Ok(want) => {
            assert_eq!(
                rendered, want,
                "fig6_scale: record-stream digests drifted from the pinned golden {path}"
            );
            eprintln!("fig6_scale: golden fixture matched ({} points)", points.len());
        }
        Err(_) => eprintln!(
            "fig6_scale: no golden fixture at {path} (set GOLDEN_WRITE=1 to pin); \
             cross-thread bit-identity still asserted in-process"
        ),
    }
}

fn main() {
    let smoke = smoke_mode();
    let threads = threads_arg();
    if smoke {
        eprintln!("fig6_scale: smoke mode (reduced schedule)");
    }

    // ---- cross-thread bit-identity at the smallest point ----------------
    let (r1, rec1, led1, _, _) = run_arm(scale_config(100, smoke, 1));
    let (r4, rec4, led4, _, _) = run_arm(scale_config(100, smoke, 4));
    let d1 = digest(&r1, &rec1, &led1);
    let d4 = digest(&r4, &rec4, &led4);
    assert_eq!(d1, d4, "threads=1 vs threads=4 digests must match (DESIGN.md §6)");

    // ---- streamed-vs-buffered byte identity at the smallest point --------
    // fleet_trace defaults to run.stream_records = on (the fleet preset
    // is where the buffered recorder's open tail hurts); assert here in
    // the smoke leg that the streamed JSONL is byte-identical to the
    // buffered writer at the smallest grid point.
    if smoke {
        let base = std::env::temp_dir().join("adloco_fig6_stream");
        let arm = |stream: bool, sub: &str| -> (Vec<u8>, Vec<u8>) {
            let dir = base.join(sub);
            std::fs::remove_dir_all(&dir).ok();
            let mut cfg = scale_config(100, true, threads);
            cfg.run.stream_records = stream;
            cfg.out_dir = Some(dir.to_str().unwrap().to_string());
            let name = cfg.name.clone();
            run_experiment(cfg).unwrap();
            (
                std::fs::read(dir.join(format!("{name}.jsonl"))).unwrap(),
                std::fs::read(dir.join(format!("{name}.csv"))).unwrap(),
            )
        };
        let buffered = arm(false, "buffered");
        let streamed = arm(true, "streamed");
        assert_eq!(
            fnv1a(&buffered.0),
            fnv1a(&streamed.0),
            "fig6 smoke: streamed JSONL digest must equal buffered"
        );
        assert_eq!(buffered.0, streamed.0, "fig6 smoke: streamed JSONL bytes must equal buffered");
        assert_eq!(buffered.1, streamed.1, "fig6 smoke: eval CSV must match");
        eprintln!("fig6_scale: streamed-vs-buffered byte identity held at 100 workers");
    }

    // ---- the scale grid --------------------------------------------------
    let grid: &[usize] = &[100, 1_000, 10_000];
    let mut table =
        Table::new(&["workers", "nodes", "trainers", "steps", "vtime_s", "wall_s", "digest"]);
    let mut points: Vec<(usize, String)> = Vec::new();
    let mut rows: Vec<JsonValue> = Vec::new();
    for &w in grid {
        let cfg = scale_config(w, smoke, threads);
        let nodes = cfg.cluster.nodes.len();
        let trainers = cfg.algo.num_trainers;
        let (r, rec, led, wall_s, allocs_per_round) = run_arm(cfg);
        let d = digest(&r, &rec, &led);
        assert!(r.total_inner_steps > 0, "the {w}-worker point must actually step");
        table.row(&[
            w.to_string(),
            nodes.to_string(),
            trainers.to_string(),
            r.total_inner_steps.to_string(),
            format!("{:.3}", r.virtual_time_s),
            format!("{wall_s:.3}"),
            d.clone(),
        ]);
        rows.push(JsonValue::obj(vec![
            ("workers", JsonValue::num(w as f64)),
            ("nodes", JsonValue::num(nodes as f64)),
            ("trainers", JsonValue::num(trainers as f64)),
            ("inner_steps", JsonValue::num(r.total_inner_steps as f64)),
            ("virtual_time_s", JsonValue::num(r.virtual_time_s)),
            ("wall_s", JsonValue::num(wall_s)),
            ("digest", JsonValue::str(d.clone())),
            ("allocs_per_round", allocs_per_round),
            // process high-water mark, monotone across grid points —
            // the trajectory artifact CI tracks, not a per-point figure
            (
                "peak_rss_bytes",
                match adloco::util::alloc_count::peak_rss_bytes() {
                    Some(b) => JsonValue::num(b as f64),
                    None => JsonValue::Null,
                },
            ),
        ]));
        points.push((w, d));
    }

    // the fixture pins the CI (smoke) configuration only; the full
    // schedule produces its own digests and is not golden-pinned
    if smoke {
        check_fixture(&points);
    }

    table.print();
    table.write_csv("fig6_scale").ok();
    let artifact = JsonValue::obj(vec![
        ("bench", JsonValue::str("fig6_scale")),
        ("smoke", JsonValue::Bool(smoke)),
        ("threads", JsonValue::num(threads as f64)),
        ("rows", JsonValue::Array(rows)),
    ]);
    write_json_artifact("fig6", &artifact).ok();

    println!(
        "\nfig6_scale: {} points up to {} workers completed on the event path \
         (threads={threads}, smoke={smoke})",
        grid.len(),
        grid.last().unwrap()
    );
}
