//! FIG2 — regenerates the paper's Figure 2 ablation study: full AdLoCo vs
//! (−adaptive batching), (−trainer merger), (−switch mode).
//!
//! Paper findings to reproduce in shape (§6.3):
//!   * without adaptivity: GPU under-utilization, slower descent;
//!   * without the merger: wasted computation on weak trainers;
//!   * without switching: instability/inefficiency at large batch regimes.
//!
//! Run: `cargo bench --bench fig2_ablation` (`--quick` to smoke;
//! `--threads N` runs the ablation arms across N OS threads —
//! bit-identical to the serial grid, see DESIGN.md §6).

use adloco::benchkit::{quick_mode, run_cells, threads_arg, Table};
use adloco::config::{presets, Config, SchedulerKind};
use adloco::coordinator::Coordinator;
use adloco::engine::build_engine;

struct Arm {
    name: &'static str,
    mutate: fn(&mut Config),
}

fn base_config(quick: bool) -> Config {
    let mut cfg = presets::paper_table1();
    // small mock dimension so every arm converges to the loss floor
    // within the paper's 20-outer-step horizon (ppl floor = e^1 ~ 2.72)
    cfg.engine = adloco::config::EngineConfig::Mock { dim: 40, noise: 1.0, condition: 10.0 };
    cfg.algo.batching.max_request = 128;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.outer_steps = if quick { 4 } else { 20 };
    cfg.algo.inner_steps = if quick { 10 } else { 50 };
    cfg.algo.lr_inner = 0.02;
    cfg.run.eval_every = 10;
    cfg.algo.fixed_batch = 8;
    // stress the switch-mode arm: modest per-node budget so adaptive
    // requests cross the 2x threshold within the horizon
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 16;
    }
    cfg.algo.batching.max_request = 256;
    // event scheduler (bit-identical to lockstep on this static cluster)
    cfg.run.scheduler = SchedulerKind::Event;
    cfg
}

fn main() {
    let quick = quick_mode();
    let arms: Vec<Arm> = vec![
        Arm { name: "full", mutate: |_| {} },
        Arm {
            name: "no_adaptive",
            mutate: |c| c.algo.batching.adaptive = false,
        },
        Arm { name: "no_merge", mutate: |c| c.algo.merge.enabled = false },
        Arm { name: "no_switch", mutate: |c| c.algo.switch.enabled = false },
    ];
    let target_ppl = 3.2; // between the e^1 floor and the start

    let mut table = Table::new(&[
        "arm",
        "best_ppl",
        "final_ppl",
        "step@target",
        "vtime@target_s",
        "total_comms",
        "trainers_left",
        "mean_batch",
        "accum_steps_seen",
        "idle_s",
    ]);

    // one cell per ablation arm; `--threads` fans them out with ordered
    // result collection (rows stay in arm order)
    let threads = threads_arg();
    let t0 = std::time::Instant::now();
    let rows = run_cells(
        threads,
        arms.iter()
            .map(|arm| {
                let name = arm.name;
                let mutate = arm.mutate;
                move || {
                    let mut cfg = base_config(quick);
                    mutate(&mut cfg);
                    cfg.name = format!("fig2_{name}");
                    // cells run their workers serially (see fig1): the
                    // grid owns the thread budget, not the runs
                    cfg.run.threads = 1;
                    let engine = build_engine(&cfg).unwrap();
                    let mut coord = Coordinator::new(cfg, engine).unwrap();
                    let r = coord.run().unwrap();
                    let rec = &coord.recorder;
                    rec.write_eval_csv(&format!("bench_results/fig2_{name}.csv")).unwrap();
                    let tt = rec.time_to_target(target_ppl);
                    let max_accum =
                        rec.steps.iter().map(|s| s.accum_steps).max().unwrap_or(1);
                    vec![
                        name.to_string(),
                        format!("{:.3}", r.best_ppl),
                        format!("{:.3}", r.final_ppl),
                        tt.map(|t| t.0.to_string()).unwrap_or_else(|| "-".into()),
                        tt.map(|t| format!("{:.2}", t.1)).unwrap_or_else(|| "-".into()),
                        r.comm_count.to_string(),
                        r.trainers_left.to_string(),
                        format!("{:.1}", rec.mean_batch()),
                        max_accum.to_string(),
                        format!("{:.2}", r.total_idle_s),
                    ]
                }
            })
            .collect(),
    );
    for row in &rows {
        table.row(row);
    }
    let grid_wall = t0.elapsed().as_secs_f64();

    println!("\nFIG2 — AdLoCo ablation study (target ppl = {target_ppl})");
    println!("grid: {} arms in {grid_wall:.2}s on {threads} thread(s)", rows.len());
    println!("(paper Fig. 2: each component removed degrades convergence)\n");
    table.print();
    table.write_csv("fig2_summary").unwrap();
}
