//! FIG1 — regenerates the paper's Figure 1: AdLoCo vs DiLoCo (plus the
//! LocalSGD baseline of §3.1) on the same workload.
//!
//! The paper plots validation perplexity against training step and reports
//! faster time-to-target and better communication efficiency for AdLoCo.
//! This bench reproduces the *shape*: who wins, and by what factor, on the
//! three axes (steps, virtual wall-clock, communications) — absolute
//! values differ because the substrate is the simulated cluster
//! (DESIGN.md §4).
//!
//! Output: summary table + per-method eval-curve CSVs under
//! bench_results/fig1_<method>.csv.
//!
//! Run: `cargo bench --bench fig1_adloco_vs_diloco` (`--quick` to smoke;
//! `--threads N` runs the method arms across N OS threads — results are
//! bit-identical to the serial grid, see DESIGN.md §6).

use adloco::benchkit::{quick_mode, run_cells, threads_arg, Table};
use adloco::config::{presets, Config, Method, SchedulerKind};
use adloco::coordinator::{resolve_policy, Coordinator};
use adloco::engine::build_engine;

fn base_config(quick: bool) -> Config {
    let mut cfg = presets::paper_table1();
    // event scheduler (bit-identical to lockstep on this static cluster;
    // also exercises the tentpole path and yields utilization columns)
    cfg.run.scheduler = SchedulerKind::Event;
    // small mock dimension so every arm converges to the loss floor
    // within the paper's 20-outer-step horizon (ppl floor = e^1 ~ 2.72)
    cfg.engine = adloco::config::EngineConfig::Mock { dim: 40, noise: 1.0, condition: 10.0 };
    cfg.algo.batching.max_request = 128;
    cfg.algo.workers_per_trainer = 2;
    if quick {
        cfg.algo.outer_steps = 4;
        cfg.algo.inner_steps = 10;
    } else {
        // paper: 20 outer x 200 inner; scaled to keep the bench minutes-long
        cfg.algo.outer_steps = 20;
        cfg.algo.inner_steps = 50;
    }
    cfg.algo.lr_inner = 0.02; // AdamW on the mock quadratic
    cfg.run.eval_every = 10;
    // fixed-batch arm (DiLoCo) uses the paper's effective batch scale
    cfg.algo.fixed_batch = 8;
    cfg
}

fn main() {
    let quick = quick_mode();
    let methods = [Method::AdLoCo, Method::DiLoCo, Method::LocalSgd];
    // target chosen to sit on the descent path of all arms (mock loss
    // floor is 1.0 => ppl floor e^1 = 2.72)
    let target_ppl = 3.2; // between the e^1 floor and the start

    let mut table = Table::new(&[
        "method",
        "best_ppl",
        "final_ppl",
        "step@target",
        "vtime@target_s",
        "comms@target",
        "total_comms",
        "mean_batch",
        "idle_s",
        "util",
    ]);

    // one cell per method arm; `--threads` fans the grid out with
    // ordered result collection (rows stay in method order)
    let threads = threads_arg();
    let t0 = std::time::Instant::now();
    let rows = run_cells(
        threads,
        methods
            .iter()
            .map(|&m| {
                move || {
                    let mut cfg = base_config(quick);
                    cfg.algo.method = m;
                    cfg.name = format!("fig1_{}", m.as_str());
                    cfg.run.target_ppl = 0.0; // full horizon; target post-hoc
                    // grid-level parallelism composes poorly with the
                    // in-run pool (RUN_THREADS would oversubscribe);
                    // cells run their workers serially, like the sweep
                    cfg.run.threads = 1;
                    let cfg = resolve_policy(&cfg);
                    let engine = build_engine(&cfg).unwrap();
                    let mut coord = Coordinator::new(cfg, engine).unwrap();
                    let r = coord.run().unwrap();
                    let rec = &coord.recorder;
                    rec.write_eval_csv(&format!("bench_results/fig1_{}.csv", m.as_str()))
                        .unwrap();
                    let tt = rec.time_to_target(target_ppl);
                    vec![
                        m.as_str().to_string(),
                        format!("{:.3}", r.best_ppl),
                        format!("{:.3}", r.final_ppl),
                        tt.map(|t| t.0.to_string()).unwrap_or_else(|| "-".into()),
                        tt.map(|t| format!("{:.2}", t.1)).unwrap_or_else(|| "-".into()),
                        tt.map(|t| t.2.to_string()).unwrap_or_else(|| "-".into()),
                        r.comm_count.to_string(),
                        format!("{:.1}", rec.mean_batch()),
                        format!("{:.2}", r.total_idle_s),
                        format!("{:.2}", r.mean_utilization),
                    ]
                }
            })
            .collect(),
    );
    for row in &rows {
        table.row(row);
    }
    let grid_wall = t0.elapsed().as_secs_f64();

    println!("\nFIG1 — AdLoCo vs DiLoCo vs LocalSGD (target ppl = {target_ppl})");
    println!("grid: {} arms in {grid_wall:.2}s on {threads} thread(s)", rows.len());
    println!("(paper Fig. 1: AdLoCo reaches target perplexity in fewer steps,");
    println!(" less simulated time and fewer communications than DiLoCo)\n");
    table.print();
    table.write_csv("fig1_summary").unwrap();
}
