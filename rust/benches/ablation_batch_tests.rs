//! IPT — §3.3 comparison of the three batch-size tests: norm test
//! (Eq. 10), inner-product test (Eq. 12) and augmented test (Eq. 13).
//!
//! The paper reports (§3.3.2) that the augmented inner-product test is
//! impractical because the orthogonality statistic dwarfs the
//! inner-product one — they observed a ~1e7-order difference between the
//! statistics. This bench measures the same two quantities on the mock
//! objective and on the recorded transformer statistics, and compares the
//! batch trajectories each test produces.
//!
//! Run: `cargo bench --bench ablation_batch_tests` (`--quick` to smoke).

use adloco::benchkit::{quick_mode, Table};
use adloco::config::{presets, BatchTest};
use adloco::coordinator::Coordinator;
use adloco::engine::{build_engine, MockEngine, MockSpec, TrainEngine};

fn main() {
    let quick = quick_mode();
    let inner = if quick { 10 } else { 40 };

    let mut table = Table::new(&[
        "test",
        "mean_b_req",
        "final_b_req",
        "best_ppl",
        "comms",
        "mean_sigma2",
        "mean_ip_var",
    ]);

    for test in [BatchTest::Norm, BatchTest::InnerProduct, BatchTest::Augmented] {
        let mut cfg = presets::paper_table1();
        cfg.name = format!("ipt_{}", test.as_str());
        cfg.algo.batching.test = test;
        cfg.algo.batching.max_request = 4096;
        cfg.algo.outer_steps = 8;
        cfg.algo.inner_steps = inner;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.lr_inner = 0.02;
        cfg.run.eval_every = 10;
        let engine = build_engine(&cfg).unwrap();
        let mut coord = Coordinator::new(cfg, engine).unwrap();
        let r = coord.run().unwrap();
        let rec = &coord.recorder;
        let reqs: Vec<f64> = rec.steps.iter().map(|s| s.requested_batch as f64).collect();
        let mean_req = reqs.iter().sum::<f64>() / reqs.len() as f64;
        let mean_sigma2 =
            rec.steps.iter().map(|s| s.sigma2).sum::<f64>() / rec.steps.len() as f64;
        // ip_var is not in StepRecord; recompute a probe below instead
        table.row(&[
            test.as_str().to_string(),
            format!("{mean_req:.1}"),
            format!("{:.0}", reqs.last().unwrap()),
            format!("{:.3}", r.best_ppl),
            r.comm_count.to_string(),
            format!("{mean_sigma2:.3}"),
            "-".to_string(),
        ]);
    }

    // direct statistic-magnitude probe (the paper's 1e7 observation):
    // sample grad stats at a fixed parameter point and compare the
    // norm-test statistic sigma² against the inner-product statistic
    // Var(<g_i, gbar>) — the latter scales with ||gbar||² ~ s1, so the
    // *requests* they imply differ by orders of magnitude.
    // probe NEAR THE OPTIMUM (init_scale ~ 0): this is the regime the
    // paper's observation concerns — as ||gbar||^2 collapses, the
    // inner-product/orthogonality statistics (which divide by ||gbar||^4
    // resp. ||gbar||^2) dwarf the norm-test statistic by orders of
    // magnitude, making the augmented test impractical.
    let engine = MockEngine::new(MockSpec {
        dim: 2000,
        noise: 1.0,
        condition: 25.0,
        seed: 3,
        use_sgd: true,
        ..MockSpec::default()
    });
    // x = x* + tiny offset: the near-convergence regime
    let mut probe_rng = adloco::util::Rng::new(99);
    let params: Vec<f32> = engine
        .optimum()
        .iter()
        .map(|&x| x + probe_rng.normal_ms(0.0, 0.003) as f32)
        .collect();
    let mut grad = vec![0.0f32; engine.param_count()];
    let mut noise = adloco::util::Rng::new(123);
    let batch = adloco::data::TokenBatch::new(64, 8);
    let (mut s_sig, mut s_ip, mut s_s1) = (0.0, 0.0, 0.0);
    let probes = 100;
    for _ in 0..probes {
        let s = engine.grad_step(&params, &batch, &mut grad, &mut noise).unwrap();
        s_sig += s.sigma2 / probes as f64;
        s_ip += s.ip_var / probes as f64;
        s_s1 += s.grad_sq_norm / probes as f64;
    }
    // implied batch requests at the paper's constants
    let eta = 0.8;
    let theta = 0.01;
    let b_norm = s_sig / (eta * eta * s_s1);
    let b_ip = s_ip / (theta * theta * s_s1 * s_s1);

    println!("\nIPT — batch-test comparison (paper §3.3)");
    table.print();
    table.write_csv("ipt_summary").unwrap();
    println!("\nstatistic magnitudes at a fixed point (mock, 100 probes):");
    println!("  sigma²              : {s_sig:.4e}");
    println!("  Var(<g_i, gbar>)    : {s_ip:.4e}");
    println!("  ||gbar||²           : {s_s1:.4e}");
    println!("  ratio ip/sigma      : {:.3e}", s_ip / s_sig);
    println!("  implied b (norm)    : {b_norm:.1}");
    println!("  implied b (ip)      : {b_ip:.1}");
    let orth_var = (s_sig - s_ip / s_s1).max(0.0);
    let nu = 0.3;
    let b_aug = orth_var / (nu * nu * s_s1);
    println!("  implied b (aug-orth): {b_aug:.3e}");
    println!(
        "  (paper §3.3.2 observed a ~1e7-order gap between the raw statistics;\n   here sigma²/Var(<g_i,gbar>) = {:.1e} — {} orders of magnitude at this\n   problem scale — and the implied requests are {:.1}x / {:.1}x the\n   norm-test request, reproducing why the augmented test is impractical)",
        s_sig / s_ip.max(1e-300),
        (s_sig / s_ip.max(1e-300)).log10().round(),
        b_ip / b_norm.max(1e-12),
        b_aug / b_norm.max(1e-12)
    );
}
