//! THM2 — empirical check of Theorem 2 (communication complexity):
//!
//!   E[C(N)] = O( b_max η² L (1+η²) (F(x₀)−F(x*)) / σ² · ln N )
//!
//! The paper's Lemma 3 defines the communication functional as
//! C(N) = Σ_{k=0}^{N} b_max / b_k over optimizer iterations k. We run
//! AdLoCo on the MockEngine (SGD, norm test — the theorem's setting),
//! evaluate C(N) from the *measured* requested-batch series, and check
//! (a) C grows logarithmically (r² of C vs ln N) and (b) the Theorem-2
//! curve with a fitted constant tracks it.
//!
//! For contrast, the same functional under DiLoCo's fixed batch grows
//! linearly in N — the gap is the paper's communication-efficiency claim.
//!
//! Run: `cargo bench --bench theory_comm_complexity` (`--quick` to smoke).

use adloco::benchkit::{quick_mode, Table};
use adloco::config::presets;
use adloco::coordinator::Coordinator;
use adloco::engine::{MockEngine, MockSpec};
use adloco::theory::{fit_scale, BoundParams};

/// C(N) series from a b_k series: prefix sums of b_max/b_k.
fn comm_series(bks: &[usize], b_max: usize) -> Vec<f64> {
    let mut acc = 0.0;
    bks.iter()
        .map(|&b| {
            acc += b_max as f64 / b.max(1) as f64;
            acc
        })
        .collect()
}

fn main() {
    let quick = quick_mode();
    let inner_total = if quick { 300 } else { 3000 };

    let mut cfg = presets::paper_table1();
    cfg.name = "thm2".into();
    cfg.algo.num_trainers = 1;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.outer_steps = 10;
    cfg.algo.inner_steps = inner_total / 10;
    cfg.algo.merge.enabled = false;
    cfg.algo.switch.enabled = false;
    cfg.algo.batching.max_request = 0;
    cfg.algo.batching.ema_beta = 0.9;
    cfg.algo.lr_inner = 0.02;
    cfg.run.eval_every = 0;

    let spec = MockSpec {
        dim: 20,
        noise: 3.0,
        condition: 10.0,
        seed: 7,
        use_sgd: true,
        init_scale: 0.0,
        ..MockSpec::default()
    };

    // ---- AdLoCo arm -------------------------------------------------------
    let engine = MockEngine::new(spec.clone());
    let mut coord = Coordinator::new(cfg.clone(), Box::new(engine)).unwrap();
    coord.run().unwrap();
    let bks: Vec<usize> =
        coord.recorder.steps.iter().map(|s| s.requested_batch).collect();
    let b_max = cfg.cluster.nodes[0].max_batch;
    let c_adaptive = comm_series(&bks, b_max);

    // ---- fixed-batch (DiLoCo) controls -------------------------------------
    // two fixed arms: the paper's initial batch (1) and a generous fixed
    // batch (16). Both are linear in N; adaptive is logarithmic, so it
    // eventually beats ANY fixed batch — the crossover vs 16 is reported.
    let c_fixed1 = comm_series(&vec![1usize; bks.len()], b_max);
    let fixed_b = cfg.algo.fixed_batch;
    let c_fixed = comm_series(&vec![fixed_b; bks.len()], b_max);

    // ---- shape fits --------------------------------------------------------
    let ns: Vec<f64> = (1..=bks.len()).map(|n| n as f64).collect();
    let lns: Vec<f64> = ns.iter().map(|n| n.ln().max(1e-9)).collect();
    // skip the warm-up region where b_k is still ~1 (C grows linearly there)
    // skip until the request has actually left the warm-up regime
    let skip = bks
        .iter()
        .position(|&b| b >= 8)
        .unwrap_or(bks.len() / 10)
        .max(10)
        .min(bks.len() - 2);
    // affine log fit C ~ a + s*ln N (the theorem's O(ln N) allows an
    // additive constant from the warm-up segment)
    let (ln_a, ln_scale, ln_r2) =
        adloco::util::stats::linear_fit(&lns[skip..], &c_adaptive[skip..]);
    let (_, lin_r2_fixed) = fit_scale(&ns[skip..], &c_fixed[skip..]);

    let f_gap = coord.recorder.steps.first().map(|s| s.loss - 1.0).unwrap_or(1.0);
    let bound = BoundParams {
        sigma2: spec.noise * spec.noise,
        eta: cfg.algo.batching.eta,
        l_smooth: 1.0,
        h: cfg.algo.inner_steps,
        m: 1,
        f_gap,
        b_max,
    };
    let theory: Vec<f64> =
        (1..=bks.len()).map(|n| bound.comm_upper_bound(n as u64, 1.0)).collect();
    let (th_scale, th_r2) = fit_scale(&theory[skip..], &c_adaptive[skip..]);

    // marginal communication rate: mean of b_max/b_k over a window — the
    // paper's efficiency claim is exactly that this rate *decays* under
    // adaptive batching and is constant under any fixed batch.
    let n = bks.len();
    let quarter = n / 4;
    let rate = |lo: usize, hi: usize| {
        (c_adaptive[hi - 1] - if lo == 0 { 0.0 } else { c_adaptive[lo - 1] })
            / (hi - lo) as f64
    };
    let early_rate = rate(0, quarter.max(1));
    let late_rate = rate(n - quarter.max(1), n);
    // crossover vs the fixed-16 arm: first N where adaptive's cumulative C
    // dips below fixed's (may exceed the horizon at small N)
    let crossover = c_adaptive
        .iter()
        .zip(c_fixed.iter())
        .position(|(a, f)| a < f)
        .map(|i| (i + 1).to_string())
        .unwrap_or_else(|| format!("> {n} (extrapolated: adaptive rate already {:.2}x fixed)",
            late_rate / (b_max as f64 / fixed_b as f64)));

    println!("\nTHM2 — communication complexity C(N) = Σ b_max/b_k");
    println!("  iterations N        : {n}");
    println!("  C(N) adaptive       : {:.1}", c_adaptive.last().unwrap());
    println!("  C(N) fixed b=1      : {:.1}  ({:.0}x more)", c_fixed1.last().unwrap(),
        c_fixed1.last().unwrap() / c_adaptive.last().unwrap());
    println!("  C(N) fixed b={fixed_b:<2}     : {:.1}  (crossover at N = {crossover})",
        c_fixed.last().unwrap());
    println!("  marginal comm rate  : {early_rate:.2} (first quarter) -> {late_rate:.2} (last quarter)");
    println!("  ln-fit (adaptive)   : C ≈ {ln_a:.1} + {ln_scale:.2}·ln N   r² = {ln_r2:.4}");
    println!("  theorem-2 fit       : scale {th_scale:.3}, r² = {th_r2:.4}");
    println!("  linear fit (fixed)  : r² = {lin_r2_fixed:.4} (fixed batch is linear by construction)");

    let mut table = Table::new(&["N", "C_adaptive", "C_fixed1", "C_fixed16", "theory(lnN)"]);
    let stride = (n / 20).max(1);
    for i in (skip..n).step_by(stride) {
        table.row(&[
            (i + 1).to_string(),
            format!("{:.1}", c_adaptive[i]),
            format!("{:.1}", c_fixed1[i]),
            format!("{:.1}", c_fixed[i]),
            format!("{:.1}", th_scale * theory[i]),
        ]);
    }
    table.print();
    table.write_csv("thm2_comm_complexity").unwrap();

    assert!(
        c_adaptive.last().unwrap() < &(c_fixed1.last().unwrap() / 4.0),
        "adaptive must beat the paper's initial fixed batch by >= 4x"
    );
    assert!(
        late_rate < early_rate / 3.0,
        "marginal comm rate must decay (Theorem 2): {early_rate:.2} -> {late_rate:.2}"
    );
    assert!(ln_r2 > 0.8, "C(N) not credibly logarithmic (r² = {ln_r2})");
}
