//! FIG4 — overlapped outer sync: the same heterogeneous schedule run
//! with blocking vs ACCO-style delayed collectives (DESIGN.md §8),
//! reporting wall-clock, hidden collective seconds and byte
//! conservation (EXPERIMENTS.md §Figures, Fig. 4 table).
//!
//! Two comparisons:
//!
//! * **matched** — the `hetero_dynamic` nodes on a *static* schedule
//!   with a fixed batch, so both modes execute the identical compute
//!   trajectory and delayed must beat blocking by exactly the hidden
//!   total. Asserted strictly.
//! * **dynamic** — the full `hetero_dynamic` scenario vs the
//!   `adloco_overlap` preset (stragglers + churn + link shifts,
//!   adaptive batching). The stale-update trajectory may legally
//!   diverge from blocking's; strict wall-clock dominance is asserted
//!   when the two arms executed the same step plans (they do in
//!   practice — the monotone controller saturates its request cap in
//!   round 1), and the hidden total must be positive always.
//!
//! Output: summary table + bench_results/fig4_overlap.csv.
//!
//! Run: `cargo bench --bench fig4_overlap` (`--smoke` — or the usual
//! `--quick` / `ADLOCO_BENCH_QUICK=1` — for the CI-sized run;
//! `--threads N` fans worker chains out, bit-identically).

use adloco::benchkit::{bench_args, quick_mode, threads_arg, wall_time, Table};
use adloco::config::{presets, Config, OverlapMode};
use adloco::coordinator::{Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;

fn smoke_mode() -> bool {
    quick_mode() || bench_args().iter().any(|a| a == "--smoke")
}

fn shrink(cfg: &mut Config, smoke: bool) {
    if smoke {
        cfg.algo.outer_steps = 5;
        cfg.algo.inner_steps = 10;
    }
    cfg.run.threads = threads_arg();
}

/// The matched arm: hetero nodes, static cluster, fixed batch — the
/// compute trajectory is provably mode-independent.
fn matched_config(overlap: OverlapMode, smoke: bool) -> Config {
    let mut cfg = presets::hetero_dynamic();
    cfg.name = format!("fig4_matched_{}", overlap.as_str());
    cfg.cluster.scenario = Default::default();
    cfg.run.scheduler = adloco::config::SchedulerKind::Event;
    cfg.algo.batching.adaptive = false;
    cfg.comm.overlap = overlap;
    shrink(&mut cfg, smoke);
    cfg
}

/// The dynamic arm: the hetero_dynamic preset as shipped (blocking) vs
/// the adloco_overlap preset (same schedule, delayed).
fn dynamic_config(overlap: OverlapMode, smoke: bool) -> Config {
    let mut cfg = match overlap {
        OverlapMode::Blocking => presets::hetero_dynamic(),
        OverlapMode::Delayed => presets::adloco_overlap(),
    };
    cfg.name = format!("fig4_dynamic_{}", overlap.as_str());
    shrink(&mut cfg, smoke);
    cfg
}

fn run_arm(cfg: Config) -> (RunResult, Recorder, f64) {
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    let (r, wall_s) = wall_time(|| coord.run().unwrap());
    (r, coord.recorder.clone(), wall_s)
}

/// (trainer, worker, global_step, micro_batch, accum_steps) of one step.
type PlanId = (usize, usize, u64, usize, usize);

/// The per-step plan identity stream — when two arms agree here they
/// executed the same compute schedule and wall-clocks compare apples
/// to apples.
fn plan_stream(rec: &Recorder) -> Vec<PlanId> {
    rec.steps
        .iter()
        .map(|s| (s.trainer, s.worker, s.global_step, s.batch, s.accum_steps))
        .collect()
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        eprintln!("fig4_overlap: smoke mode (reduced schedule)");
    }
    let mut table = Table::new(&[
        "arm",
        "overlap",
        "comms",
        "total_bytes",
        "vtime_s",
        "hidden_s",
        "best_ppl",
        "wall_s",
    ]);

    let mut report = |arm: &str, overlap: OverlapMode, r: &RunResult, wall_s: f64| {
        table.row(&[
            arm.to_string(),
            overlap.as_str().to_string(),
            r.comm_count.to_string(),
            r.comm_bytes.to_string(),
            format!("{:.3}", r.virtual_time_s),
            format!("{:.4}", r.overlap_hidden_s),
            format!("{:.3}", r.best_ppl),
            format!("{:.3}", wall_s),
        ]);
    };

    // ---- matched arms: strict dominance guaranteed -----------------------
    let (mb, _, mb_wall) = run_arm(matched_config(OverlapMode::Blocking, smoke));
    let (md, _, md_wall) = run_arm(matched_config(OverlapMode::Delayed, smoke));
    report("matched", OverlapMode::Blocking, &mb, mb_wall);
    report("matched", OverlapMode::Delayed, &md, md_wall);
    assert!(
        md.virtual_time_s < mb.virtual_time_s,
        "matched: delayed must be strictly faster ({} vs {})",
        md.virtual_time_s,
        mb.virtual_time_s
    );
    assert!(md.overlap_hidden_s > 0.0, "matched: nothing was hidden");
    assert_eq!(md.comm_bytes, mb.comm_bytes, "matched: bytes must be conserved");
    // the global saving is the gating trainer's hidden time — bounded by
    // (and typically well below) the run-wide hidden total
    let saving = mb.virtual_time_s - md.virtual_time_s;
    assert!(
        saving <= md.overlap_hidden_s + 1e-9,
        "matched: saving {saving} cannot exceed the hidden total {}",
        md.overlap_hidden_s
    );

    // ---- dynamic arms: the paper-motivating scenario ---------------------
    let (db, db_rec, db_wall) = run_arm(dynamic_config(OverlapMode::Blocking, smoke));
    let (dd, dd_rec, dd_wall) = run_arm(dynamic_config(OverlapMode::Delayed, smoke));
    report("hetero_dynamic", OverlapMode::Blocking, &db, db_wall);
    report("hetero_dynamic", OverlapMode::Delayed, &dd, dd_wall);
    assert!(dd.overlap_hidden_s > 0.0, "dynamic: nothing was hidden");
    let plans_match = plan_stream(&db_rec) == plan_stream(&dd_rec);
    if plans_match {
        assert!(
            dd.virtual_time_s < db.virtual_time_s,
            "dynamic (matched plans): delayed must be strictly faster ({} vs {})",
            dd.virtual_time_s,
            db.virtual_time_s
        );
    } else {
        eprintln!(
            "fig4_overlap: dynamic arms diverged in step plans (stale-update \
             trajectory changed the adaptive schedule); reporting without the \
             strict wall-clock assertion"
        );
    }

    table.print();
    table.write_csv("fig4_overlap").ok();

    println!(
        "\nmatched: blocking {:.3}s vs delayed {:.3}s ({:.4}s hidden = {:.2}% of \
         the blocking wall-clock)",
        mb.virtual_time_s,
        md.virtual_time_s,
        md.overlap_hidden_s,
        100.0 * (mb.virtual_time_s - md.virtual_time_s) / mb.virtual_time_s
    );
    println!(
        "hetero_dynamic: blocking {:.3}s vs delayed {:.3}s ({:.4}s hidden, plans {})",
        db.virtual_time_s,
        dd.virtual_time_s,
        dd.overlap_hidden_s,
        if plans_match { "matched" } else { "diverged" }
    );
}
