//! FIG3 — topology comparison: the same heterogeneous-node MIT
//! schedule run flat vs hierarchical (DESIGN.md §7), reporting WAN
//! bytes, total comm volume and wall/virtual time. The hierarchical
//! arm must move strictly fewer bytes across the WAN while conserving
//! the total — the two-level cost asymmetry the paper's MIT stage
//! rests on (EXPERIMENTS.md §Figures, Fig. 3 table).
//!
//! Output: summary table + bench_results/fig3_topology.csv.
//!
//! Run: `cargo bench --bench fig3_topology` (`--smoke` — or the usual
//! `--quick` / `ADLOCO_BENCH_QUICK=1` — for the CI-sized run;
//! `--threads N` fans worker chains out, bit-identically).

use adloco::benchkit::{bench_args, quick_mode, threads_arg, wall_time, Table};
use adloco::config::{presets, Config, TopologyKind};
use adloco::coordinator::{Coordinator, RunResult};
use adloco::engine::build_engine;

fn smoke_mode() -> bool {
    quick_mode() || bench_args().iter().any(|a| a == "--smoke")
}

fn base_config(smoke: bool) -> Config {
    let mut cfg = presets::hierarchical_mit();
    if smoke {
        cfg.algo.outer_steps = 4;
        cfg.algo.inner_steps = 8;
    }
    cfg.run.threads = threads_arg();
    cfg
}

fn run_arm(topology: TopologyKind, smoke: bool) -> (RunResult, f64) {
    let mut cfg = base_config(smoke);
    cfg.cluster.topology = topology;
    cfg.name = format!("fig3_{}", topology.as_str());
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    let (r, wall_s) = wall_time(|| coord.run().unwrap());
    (r, wall_s)
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        eprintln!("fig3_topology: smoke mode (reduced schedule)");
    }
    let mut table = Table::new(&[
        "topology",
        "comms",
        "total_bytes",
        "wan_bytes",
        "trainers_left",
        "best_ppl",
        "vtime_s",
        "wall_s",
    ]);
    let mut wan = Vec::new();
    let mut totals = Vec::new();
    for topology in [TopologyKind::Flat, TopologyKind::Hierarchical] {
        let (r, wall_s) = run_arm(topology, smoke);
        table.row(&[
            topology.as_str().to_string(),
            r.comm_count.to_string(),
            r.comm_bytes.to_string(),
            r.wan_comm_bytes.to_string(),
            r.trainers_left.to_string(),
            format!("{:.3}", r.best_ppl),
            format!("{:.3}", r.virtual_time_s),
            format!("{:.3}", wall_s),
        ]);
        wan.push(r.wan_comm_bytes);
        totals.push(r.comm_bytes);
    }
    table.print();
    table.write_csv("fig3_topology").ok();

    let (flat_wan, hier_wan) = (wan[0], wan[1]);
    println!(
        "\nWAN bytes: flat {} vs hierarchical {} ({:.1}x less WAN traffic)",
        flat_wan,
        hier_wan,
        flat_wan as f64 / hier_wan.max(1) as f64
    );
    assert!(
        hier_wan < flat_wan,
        "hierarchical topology must shrink WAN bytes ({hier_wan} vs {flat_wan})"
    );
    println!(
        "total bytes: flat {} vs hierarchical {} (closed forms conserve volume)",
        totals[0], totals[1]
    );
}
