//! MICRO — hot-path microbenchmarks backing EXPERIMENTS.md §Perf:
//! the vectorized L3 kernels (DESIGN.md §12) across a paper-scale
//! parameter ladder, the batch controller, data sampling, the
//! MockEngine step, checkpoint encode/decode (raw64le vs legacy hex
//! accounting), and — when artifacts are present — the PJRT
//! train/grad/eval calls across the batch ladder.
//!
//! Run: `cargo bench --bench micro_hotpath` (`--quick` to smoke).
//! Emits `bench_results/BENCH_micro.json` (one row per op: params,
//! median_ms, p90_ms, bytes_per_s) — the artifact CI uploads and
//! `scripts/perf_gate.py` compares against the committed baseline.

use adloco::batching::BatchController;
use adloco::benchkit::{quick_mode, threads_arg, time_auto, write_json_artifact, Table, Timing};
use adloco::checkpoint::{import_bytes, interchange::encode_complete_with, AccountingEncoding};
use adloco::config::presets;
use adloco::data::{make_shards, BatchSampler, Corpus, CorpusSpec, TokenBatch};
use adloco::engine::{MockEngine, MockSpec, StepStats, TrainEngine};
use adloco::merge::do_merge;
use adloco::outer::OuterOpt;
use adloco::util::{vecmath, JsonValue, Rng};

/// Table + JSON rows kept in sync: every op lands in both the printed
/// table and the machine-readable artifact.
struct Rows {
    table: Table,
    json: Vec<JsonValue>,
}

impl Rows {
    fn new() -> Rows {
        Rows {
            table: Table::new(&["op", "params", "median_ms", "p90_ms", "GB_per_s"]),
            json: Vec::new(),
        }
    }

    /// `bytes_per_rep` is the approximate DRAM traffic of one rep (0
    /// for ops where a bandwidth figure is meaningless).
    fn push(&mut self, op: &str, params: usize, bytes_per_rep: usize, t: Timing) {
        let bps = if t.median_s > 0.0 { bytes_per_rep as f64 / t.median_s } else { 0.0 };
        self.table.row(&[
            op.to_string(),
            if params > 0 { format!("{params}") } else { "-".into() },
            format!("{:.4}", t.median_s * 1e3),
            format!("{:.4}", t.p90_s * 1e3),
            if bps > 0.0 { format!("{:.2}", bps / 1e9) } else { "-".into() },
        ]);
        self.json.push(JsonValue::obj(vec![
            ("op", JsonValue::str(op)),
            ("params", JsonValue::num(params as f64)),
            ("median_ms", JsonValue::num(t.median_s * 1e3)),
            ("p90_ms", JsonValue::num(t.p90_s * 1e3)),
            ("bytes_per_s", JsonValue::num(bps)),
        ]));
    }
}

/// Cheap deterministic fill (hash ramp) — generating 1e8 values through
/// the Box–Muller RNG would dominate setup time.
fn fill(n: usize, salt: u32) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u32).wrapping_add(salt).wrapping_mul(2_654_435_761);
            ((h >> 16) & 0xffff) as f32 / 65_536.0 - 0.5
        })
        .collect()
}

fn main() {
    let quick = quick_mode();
    let budget = if quick { 0.05 } else { 0.5 };
    let mut rows = Rows::new();

    // ---- vectorized kernel ladder (DESIGN.md §12) ------------------------
    // Single-vector ops climb to 1e8 on full runs; multi-buffer ops
    // (merge, outer) stop at 1e7 to bound resident memory (4 extra
    // buffers each).
    let singles: Vec<usize> = if quick {
        vec![100_000, 10_000_000]
    } else {
        vec![1_000_000, 10_000_000, 100_000_000]
    };
    let multis: Vec<usize> =
        if quick { vec![100_000, 10_000_000] } else { vec![1_000_000, 10_000_000] };

    for &n in &singles {
        let a = fill(n, 1);
        let b = fill(n, 2);
        let t = time_auto(budget, 3, || {
            std::hint::black_box(vecmath::dot_f32(&a, &b));
        });
        rows.push(&format!("vec.dot(n={n})"), n, 8 * n, t);

        let t = time_auto(budget, 3, || {
            std::hint::black_box(vecmath::norm_sq_f32(&a));
        });
        rows.push(&format!("vec.norm_sq(n={n})"), n, 4 * n, t);

        let mut y = b.clone();
        let t = time_auto(budget, 3, || {
            vecmath::axpy_f32(0.5, &a, &mut y);
            std::hint::black_box(&y);
        });
        rows.push(&format!("vec.axpy(n={n})"), n, 12 * n, t);

        let mut p = b.clone();
        let t = time_auto(budget, 3, || {
            vecmath::sgd_step_f32(&mut p, &a, 1e-4);
            std::hint::black_box(&p);
        });
        rows.push(&format!("vec.sgd_step(n={n})"), n, 12 * n, t);
    }

    for &n in &multis {
        // merge: weighted average over 4 trainers (f64 accumulator
        // allocated per call, exactly like the coordinator's path)
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|i| fill(n, 10 + i as u32)).collect();
        let t = time_auto(budget, 3, || {
            let mut it = bufs.iter_mut();
            let (a, b, c, d) =
                (it.next().unwrap(), it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
            let mut members = vec![
                (0usize, 3usize, a.as_mut_slice()),
                (1, 7, b.as_mut_slice()),
                (2, 2, c.as_mut_slice()),
                (3, 9, d.as_mut_slice()),
            ];
            std::hint::black_box(do_merge(&mut members));
        });
        rows.push(&format!("merge.do_merge(4,n={n})"), n, 36 * n, t);

        // outer delta + Nesterov over 4 workers
        let x_prev = fill(n, 20);
        let workers: Vec<Vec<f32>> = (0..4).map(|i| fill(n, 30 + i as u32)).collect();
        let mut x = x_prev.clone();
        let mut delta = vec![0.0f32; n];
        let mut opt = OuterOpt::new(
            adloco::config::OuterOptKind::Nesterov { momentum: 0.9 },
            0.5,
            n,
        );
        let t = time_auto(budget, 3, || {
            let wr: Vec<&[f32]> = workers.iter().map(|w| w.as_slice()).collect();
            OuterOpt::compute_delta(&x_prev, &wr, &mut delta);
            opt.step(&mut x, &delta);
            std::hint::black_box(&x);
        });
        rows.push(&format!("outer.delta+nesterov(4,n={n})"), n, 48 * n, t);

        // adamw: params/m/v read-write + grad read
        let grad = fill(n, 40);
        let mut p = fill(n, 41);
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let k = vecmath::AdamCoeffs {
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
            bc1: 1.0 - 0.9f64.powf(10.0),
            bc2: 1.0 - 0.95f64.powf(10.0),
            lr: 1e-3,
        };
        let t = time_auto(budget, 3, || {
            vecmath::adamw_step_f32(&mut p, &mut m, &mut v, &grad, &k);
            std::hint::black_box(&p);
        });
        rows.push(&format!("vec.adamw_step(n={n})"), n, 28 * n, t);
    }

    // ---- batch controller ------------------------------------------------
    let mut rng = Rng::new(1);
    let mut ctl = BatchController::new(presets::paper_table1().algo.batching);
    let stats = StepStats { loss: 2.0, grad_sq_norm: 0.5, sigma2: 1.3, ip_var: 0.2 };
    let t = time_auto(budget.min(0.1), 100, || {
        for _ in 0..1000 {
            ctl.observe(std::hint::black_box(&stats), 8);
        }
    });
    rows.push("controller.observe x1000", 0, 0, t);

    // ---- data sampling ---------------------------------------------------
    let corpus = Corpus::generate(CorpusSpec::new(4000, 64, 256, 1.1, 5));
    let shard = make_shards(4000, 1, 1.0, &mut rng).pop().unwrap();
    let mut sampler = BatchSampler::new(shard, rng.fork(9));
    let mut buf = TokenBatch::new(16, corpus.width());
    let t = time_auto(budget.min(0.2), 20, || {
        sampler.next_batch(&corpus, &mut buf);
        std::hint::black_box(&buf);
    });
    rows.push("sampler.next_batch(b=16,s=64)", 0, 0, t);

    // ---- mock engine step (vectorized grad statistics) -------------------
    let dim = if quick { 2000 } else { 20_000 };
    let mock = MockEngine::new(MockSpec { dim, ..MockSpec::default() });
    let mut st = mock.init_state(0);
    let mut noise = Rng::new(17);
    let mb = TokenBatch::new(16, 8);
    let t = time_auto(budget, 5, || {
        mock.train_step(&mut st, 0.01, &mb, &mut noise).unwrap();
    });
    rows.push(&format!("mock.train_step(dim={dim},b=16)"), dim, 0, t);

    // ---- checkpoint interchange: raw64le vs legacy hex accounting --------
    {
        let c = {
            let mut cfg = presets::mock_default();
            cfg.name = "bench_ckpt".into();
            cfg.algo.num_trainers = 4;
            cfg.algo.workers_per_trainer = 2;
            cfg.algo.inner_steps = 2;
            cfg.algo.outer_steps = 1;
            let engine = adloco::engine::build_engine(&cfg).unwrap();
            let mut c = adloco::coordinator::Coordinator::new(cfg, engine).unwrap();
            c.step_outer(1).unwrap();
            c
        };
        let snap = c.snapshot(1);
        let encodings = [(AccountingEncoding::Raw, "raw64le"), (AccountingEncoding::Hex, "hex")];
        for (enc, tag) in encodings {
            let bytes = encode_complete_with(&snap, enc);
            let kib = bytes.len() / 1024;
            let t = time_auto(budget, 5, || {
                std::hint::black_box(encode_complete_with(&snap, enc));
            });
            rows.push(&format!("ckpt.encode[{tag}]({kib} KiB)"), 0, bytes.len(), t);
            let t = time_auto(budget, 5, || {
                std::hint::black_box(import_bytes(&bytes).unwrap());
            });
            rows.push(&format!("ckpt.import[{tag}]({kib} KiB)"), 0, bytes.len(), t);
        }
    }

    // ---- PJRT ladder (artifacts-gated) -----------------------------------
    if std::path::Path::new("artifacts/tiny/meta.json").exists() {
        let eng = adloco::runtime::XlaEngine::load("artifacts", "tiny").unwrap();
        let width = eng.meta().seq_len + 1;
        let vocab = eng.meta().vocab as i64;
        let ladder: Vec<usize> = eng.supported_batches().to_vec();
        for b in ladder {
            let mut state = eng.init_state(0);
            let mut tb = TokenBatch::new(b, width);
            let mut r2 = Rng::new(3);
            for t in tb.tokens.iter_mut() {
                *t = r2.range(0, vocab) as i32;
            }
            eng.train_step(&mut state, 1e-4, &tb, &mut noise).unwrap(); // compile
            let t = time_auto(budget, 3, || {
                eng.train_step(&mut state, 1e-4, &tb, &mut noise).unwrap();
            });
            rows.push(&format!("xla.train_step(tiny,b={b})"), 0, 0, t);
        }
        // grad + apply at max batch
        let bmax = eng.meta().grad_step_batch;
        let mut tb = TokenBatch::new(bmax, width);
        let mut r2 = Rng::new(4);
        for t in tb.tokens.iter_mut() {
            *t = r2.range(0, vocab) as i32;
        }
        let st0 = eng.init_state(0);
        let mut grad = vec![0.0f32; eng.param_count()];
        eng.grad_step(&st0.params, &tb, &mut grad, &mut noise).unwrap();
        let t = time_auto(budget, 3, || {
            eng.grad_step(&st0.params, &tb, &mut grad, &mut noise).unwrap();
        });
        rows.push(&format!("xla.grad_step(tiny,b={bmax})"), 0, 0, t);

        let eb = eng.eval_batch();
        let mut tb = TokenBatch::new(eb, width);
        for t in tb.tokens.iter_mut() {
            *t = r2.range(0, vocab) as i32;
        }
        eng.eval_loss(&st0.params, &tb, &mut noise).unwrap();
        let t = time_auto(budget, 3, || {
            eng.eval_loss(&st0.params, &tb, &mut noise).unwrap();
        });
        rows.push(&format!("xla.eval(tiny,b={eb})"), 0, 0, t);
    } else {
        eprintln!("artifacts/tiny missing — run `make artifacts` for PJRT rows");
    }

    // ---- steady-state round loop: persistent pool + arenas ---------------
    // (DESIGN.md §14) One full `step_outer_event` round at paper-scale
    // params with merge / mid-loop-eval boundaries disabled — the
    // zero-param-sized-allocation steady state the runtime contract
    // promises. Rows carry measured allocs_per_round /
    // param_allocs_per_round under `--features perf-count-alloc` (null
    // otherwise) plus the process peak-RSS probe.
    {
        use adloco::util::alloc_count;
        let dim = if quick { 100_000 } else { 1_000_000 };
        for th in [1usize, 4] {
            let mut cfg = presets::mock_default();
            cfg.name = format!("micro_steady_t{th}");
            cfg.algo.num_trainers = 2;
            cfg.algo.workers_per_trainer = 2;
            cfg.algo.inner_steps = 4;
            cfg.algo.outer_steps = 1_000_000; // rounds are driven manually below
            cfg.engine = adloco::config::EngineConfig::Mock { dim, noise: 1.0, condition: 10.0 };
            cfg.algo.batching.adaptive = false;
            cfg.algo.fixed_batch = 4;
            cfg.algo.merge.enabled = false;
            cfg.run.eval_every = 0;
            cfg.run.eval_batches = 1;
            cfg.data.val_sequences = 64;
            cfg.run.threads = th;
            let engine = adloco::engine::build_engine(&cfg).unwrap();
            let mut c = adloco::coordinator::Coordinator::new(cfg, engine).unwrap();
            let mut t = 0u64;
            // warm: arenas grow to their working size, pool threads park
            for _ in 0..2 {
                t += 1;
                c.step_outer_event(t).unwrap();
            }
            let timing = time_auto(budget, 3, || {
                t += 1;
                c.step_outer_event(t).unwrap();
            });
            // allocation accounting over a fixed round count, after the
            // timing loop (every buffer is at steady state by now);
            // "param-sized" = at least one f32 parameter vector
            alloc_count::set_large_threshold(4 * dim);
            let rounds = 5u64;
            let before = alloc_count::snapshot();
            for _ in 0..rounds {
                t += 1;
                c.step_outer_event(t).unwrap();
            }
            let d = alloc_count::snapshot().since(before);
            alloc_count::set_large_threshold(usize::MAX);
            let (apr, papr) = if alloc_count::counting_enabled() {
                (
                    JsonValue::num(d.allocs as f64 / rounds as f64),
                    JsonValue::num(d.large_allocs as f64 / rounds as f64),
                )
            } else {
                (JsonValue::Null, JsonValue::Null)
            };
            let rss = match alloc_count::peak_rss_bytes() {
                Some(b) => JsonValue::num(b as f64),
                None => JsonValue::Null,
            };
            let op = format!("round.steady(p={dim},threads={th})");
            rows.table.row(&[
                op.clone(),
                format!("{dim}"),
                format!("{:.4}", timing.median_s * 1e3),
                format!("{:.4}", timing.p90_s * 1e3),
                "-".into(),
            ]);
            rows.json.push(JsonValue::obj(vec![
                ("op", JsonValue::str(op)),
                ("params", JsonValue::num(dim as f64)),
                ("median_ms", JsonValue::num(timing.median_s * 1e3)),
                ("p90_ms", JsonValue::num(timing.p90_s * 1e3)),
                ("bytes_per_s", JsonValue::num(0.0)),
                ("allocs_per_round", apr),
                ("param_allocs_per_round", papr),
                ("peak_rss_bytes", rss),
            ]));
        }
    }

    println!("\nMICRO — hot-path benchmarks");
    rows.table.print();
    rows.table.write_csv("micro_hotpath").unwrap();
    let doc = JsonValue::obj(vec![
        ("bench", JsonValue::str("micro")),
        ("quick", JsonValue::Bool(quick)),
        ("threads", JsonValue::num(threads_arg() as f64)),
        ("rows", JsonValue::Array(rows.json)),
    ]);
    write_json_artifact("micro", &doc).unwrap();
}
