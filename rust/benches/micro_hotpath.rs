//! MICRO — hot-path microbenchmarks backing EXPERIMENTS.md §Perf:
//! the L3 dense-vector operations (merge, outer delta+step, controller),
//! data sampling, the MockEngine step, and — when artifacts are present —
//! the PJRT train/grad/eval calls across the batch ladder.
//!
//! Run: `cargo bench --bench micro_hotpath` (`--quick` to smoke).

use adloco::batching::BatchController;
use adloco::benchkit::{quick_mode, time_auto, Table};
use adloco::config::presets;
use adloco::data::{make_shards, BatchSampler, Corpus, CorpusSpec, TokenBatch};
use adloco::engine::{MockEngine, MockSpec, StepStats, TrainEngine};
use adloco::merge::do_merge;
use adloco::outer::OuterOpt;
use adloco::util::Rng;

fn main() {
    let quick = quick_mode();
    let budget = if quick { 0.05 } else { 0.5 };
    let p = 117_056; // tiny-profile parameter count
    let mut rng = Rng::new(1);
    let mut table = Table::new(&["op", "median_ms", "p90_ms", "ops_per_s"]);
    fn push(table: &mut Table, name: &str, t: adloco::benchkit::Timing) {
        table.row(&[
            name.to_string(),
            format!("{:.4}", t.median_s * 1e3),
            format!("{:.4}", t.p90_s * 1e3),
            format!("{:.1}", t.per_sec()),
        ]);
    }

    // ---- merge (DoMerge weighted average over 4 trainers) ----------------
    let mut bufs: Vec<Vec<f32>> =
        (0..4).map(|_| (0..p).map(|_| rng.normal() as f32).collect()).collect();
    let t = time_auto(budget, 5, || {
        let mut it = bufs.iter_mut();
        let (a, b, c, d) =
            (it.next().unwrap(), it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut members = vec![
            (0usize, 3usize, a.as_mut_slice()),
            (1, 7, b.as_mut_slice()),
            (2, 2, c.as_mut_slice()),
            (3, 9, d.as_mut_slice()),
        ];
        std::hint::black_box(do_merge(&mut members));
    });
    push(&mut table, "do_merge(4 x 117k)", t);

    // ---- outer delta + Nesterov step --------------------------------------
    let x_prev: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let workers: Vec<Vec<f32>> =
        (0..4).map(|_| (0..p).map(|_| rng.normal() as f32).collect()).collect();
    let mut x = x_prev.clone();
    let mut delta = vec![0.0f32; p];
    let mut opt = OuterOpt::new(
        adloco::config::OuterOptKind::Nesterov { momentum: 0.9 },
        0.5,
        p,
    );
    let t = time_auto(budget, 5, || {
        let wr: Vec<&[f32]> = workers.iter().map(|w| w.as_slice()).collect();
        OuterOpt::compute_delta(&x_prev, &wr, &mut delta);
        opt.step(&mut x, &delta);
        std::hint::black_box(&x);
    });
    push(&mut table, "outer_delta+nesterov(4 x 117k)", t);

    // ---- batch controller --------------------------------------------------
    let mut ctl = BatchController::new(presets::paper_table1().algo.batching);
    let stats = StepStats { loss: 2.0, grad_sq_norm: 0.5, sigma2: 1.3, ip_var: 0.2 };
    let t = time_auto(budget.min(0.1), 100, || {
        for _ in 0..1000 {
            ctl.observe(std::hint::black_box(&stats), 8);
        }
    });
    table.row(&[
        "controller.observe x1000".into(),
        format!("{:.4}", t.median_s * 1e3),
        format!("{:.4}", t.p90_s * 1e3),
        format!("{:.1}", t.per_sec()),
    ]);

    // ---- data sampling ------------------------------------------------------
    let corpus = Corpus::generate(CorpusSpec::new(4000, 64, 256, 1.1, 5));
    let shard = make_shards(4000, 1, 1.0, &mut rng).pop().unwrap();
    let mut sampler = BatchSampler::new(shard, rng.fork(9));
    let mut buf = TokenBatch::new(16, corpus.width());
    let t = time_auto(budget.min(0.2), 20, || {
        sampler.next_batch(&corpus, &mut buf);
        std::hint::black_box(&buf);
    });
    push(&mut table, "sampler.next_batch(b=16,s=64)", t);

    // ---- mock engine step ---------------------------------------------------
    let mock = MockEngine::new(MockSpec { dim: 2000, ..MockSpec::default() });
    let mut st = mock.init_state(0);
    let mut noise = Rng::new(17);
    let mb = TokenBatch::new(16, 8);
    let t = time_auto(budget, 5, || {
        mock.train_step(&mut st, 0.01, &mb, &mut noise).unwrap();
    });
    push(&mut table, "mock.train_step(dim=2000,b=16)", t);

    // ---- checkpoint interchange (v4 encode/decode, DESIGN.md §10) ----------
    {
        let c = {
            let mut cfg = presets::mock_default();
            cfg.name = "bench_ckpt".into();
            cfg.algo.num_trainers = 4;
            cfg.algo.workers_per_trainer = 2;
            cfg.algo.inner_steps = 2;
            cfg.algo.outer_steps = 1;
            let engine = adloco::engine::build_engine(&cfg).unwrap();
            let mut c = adloco::coordinator::Coordinator::new(cfg, engine).unwrap();
            c.step_outer(1).unwrap();
            c
        };
        let snap = c.snapshot(1);
        let bytes = snap.to_bytes();
        let t = time_auto(budget, 5, || {
            std::hint::black_box(snap.to_bytes());
        });
        push(&mut table, &format!("ckpt.to_bytes({} KiB)", bytes.len() / 1024), t);
        let t = time_auto(budget, 5, || {
            std::hint::black_box(adloco::checkpoint::import_bytes(&bytes).unwrap());
        });
        push(&mut table, &format!("ckpt.import_bytes({} KiB)", bytes.len() / 1024), t);
    }

    // ---- PJRT ladder (artifacts-gated) --------------------------------------
    if std::path::Path::new("artifacts/tiny/meta.json").exists() {
        let eng = adloco::runtime::XlaEngine::load("artifacts", "tiny").unwrap();
        let width = eng.meta().seq_len + 1;
        let vocab = eng.meta().vocab as i64;
        let ladder: Vec<usize> = eng.supported_batches().to_vec();
        for b in ladder {
            let mut state = eng.init_state(0);
            let mut tb = TokenBatch::new(b, width);
            let mut r2 = Rng::new(3);
            for t in tb.tokens.iter_mut() {
                *t = r2.range(0, vocab) as i32;
            }
            eng.train_step(&mut state, 1e-4, &tb, &mut noise).unwrap(); // compile
            let t = time_auto(budget, 3, || {
                eng.train_step(&mut state, 1e-4, &tb, &mut noise).unwrap();
            });
            push(&mut table, &format!("xla.train_step(tiny,b={b})"), t);
        }
        // grad + apply at max batch
        let bmax = eng.meta().grad_step_batch;
        let mut tb = TokenBatch::new(bmax, width);
        let mut r2 = Rng::new(4);
        for t in tb.tokens.iter_mut() {
            *t = r2.range(0, vocab) as i32;
        }
        let st0 = eng.init_state(0);
        let mut grad = vec![0.0f32; eng.param_count()];
        eng.grad_step(&st0.params, &tb, &mut grad, &mut noise).unwrap();
        let t = time_auto(budget, 3, || {
            eng.grad_step(&st0.params, &tb, &mut grad, &mut noise).unwrap();
        });
        push(&mut table, &format!("xla.grad_step(tiny,b={bmax})"), t);

        let eb = eng.eval_batch();
        let mut tb = TokenBatch::new(eb, width);
        for t in tb.tokens.iter_mut() {
            *t = r2.range(0, vocab) as i32;
        }
        eng.eval_loss(&st0.params, &tb, &mut noise).unwrap();
        let t = time_auto(budget, 3, || {
            eng.eval_loss(&st0.params, &tb, &mut noise).unwrap();
        });
        push(&mut table, &format!("xla.eval(tiny,b={eb})"), t);
    } else {
        eprintln!("artifacts/tiny missing — run `make artifacts` for PJRT rows");
    }

    println!("\nMICRO — hot-path benchmarks");
    table.print();
    table.write_csv("micro_hotpath").unwrap();
}
