//! FIG5 — elastic vs static instance pools (DESIGN.md §9): the same
//! churn + straggler schedule run with a frozen pool, the
//! utilization-driven spawn controller (`elastic_mit`), and the
//! respawn-after-merge policy, reporting spawns, mean live instances
//! m(t), utilization, vacant capacity and throughput
//! (EXPERIMENTS.md §Figures, Fig. 5 table).
//!
//! Asserted invariants:
//!
//! * the respawn arm spawns (its merges are deterministic) and no
//!   elastic arm utilizes the cluster *worse* than the frozen pool;
//! * `elastic = off` is **bit-identical** to the frozen pool — the
//!   `elastic_mit` preset with the mode forced off must reproduce the
//!   `hetero_dynamic` twin's ledger, record streams and RunResult
//!   payload exactly (the CI golden-digest leg for the elastic seam);
//! * every spawning arm strictly lifts the time-averaged live-instance
//!   census m(t) above the frozen pool's (both run the same merge
//!   cadence, so the census ordering is structural). Samples and
//!   vacant capacity are *reported* for the Fig. 5 table, not asserted
//!   — adaptive-batch trajectories legally diverge once merge
//!   selection differs.
//!
//! Output: summary table + bench_results/fig5_elastic.csv.
//!
//! Run: `cargo bench --bench fig5_elastic` (`--smoke` — or the usual
//! `--quick` / `ADLOCO_BENCH_QUICK=1` — for the CI-sized run;
//! `--threads N` fans worker chains out, bit-identically).

use adloco::benchkit::{bench_args, quick_mode, threads_arg, wall_time, Table};
use adloco::config::{presets, Config, ElasticMode};
use adloco::coordinator::{Coordinator, RunResult};
use adloco::engine::build_engine;
use adloco::metrics::Recorder;

fn smoke_mode() -> bool {
    quick_mode() || bench_args().iter().any(|a| a == "--smoke")
}

fn shrink(cfg: &mut Config, smoke: bool) {
    if smoke {
        cfg.algo.outer_steps = 6;
        cfg.algo.inner_steps = 10;
    }
    cfg.run.threads = threads_arg();
}

/// The frozen-pool baseline: the churn scenario as shipped.
fn static_config(smoke: bool) -> Config {
    let mut cfg = presets::hetero_dynamic();
    cfg.name = "fig5_static".into();
    shrink(&mut cfg, smoke);
    cfg
}

/// The `elastic_mit` preset with the mode forced off — must be
/// bit-identical to the static baseline.
fn off_config(smoke: bool) -> Config {
    let mut cfg = presets::elastic_mit();
    cfg.name = "fig5_elastic_off".into();
    cfg.algo.elastic.mode = ElasticMode::Off;
    shrink(&mut cfg, smoke);
    cfg
}

/// The utilization-driven spawn controller (the preset as shipped).
fn util_config(smoke: bool) -> Config {
    let mut cfg = presets::elastic_mit();
    cfg.name = "fig5_elastic_util".into();
    shrink(&mut cfg, smoke);
    cfg
}

/// Respawn-after-merge on the same schedule: merges at the preset's
/// frequency are deterministic, so this arm's spawns are guaranteed.
fn respawn_config(smoke: bool) -> Config {
    let mut cfg = presets::elastic_mit();
    cfg.name = "fig5_elastic_respawn".into();
    cfg.algo.elastic.mode = ElasticMode::RespawnAfterMerge;
    shrink(&mut cfg, smoke);
    cfg
}

fn run_arm(cfg: Config) -> (RunResult, Recorder, f64) {
    let engine = build_engine(&cfg).unwrap();
    let mut coord = Coordinator::new(cfg, engine).unwrap();
    let (r, wall_s) = wall_time(|| coord.run().unwrap());
    (r, coord.recorder.clone(), wall_s)
}

/// Bitwise equality of the determinism payload + record streams of two
/// runs (the `elastic = off` golden check, inlined — the run *name* is
/// the only field allowed to differ).
fn assert_bit_identical(a: &(RunResult, Recorder, f64), b: &(RunResult, Recorder, f64)) {
    let (ra, reca, _) = a;
    let (rb, recb, _) = b;
    assert_eq!(ra.total_samples, rb.total_samples, "off-twin: samples");
    assert_eq!(ra.comm_count, rb.comm_count, "off-twin: comms");
    assert_eq!(ra.comm_bytes, rb.comm_bytes, "off-twin: bytes");
    assert_eq!(ra.wan_comm_bytes, rb.wan_comm_bytes, "off-twin: WAN bytes");
    assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits(), "off-twin: best ppl");
    assert_eq!(ra.final_ppl.to_bits(), rb.final_ppl.to_bits(), "off-twin: final ppl");
    assert_eq!(
        ra.virtual_time_s.to_bits(),
        rb.virtual_time_s.to_bits(),
        "off-twin: virtual time"
    );
    assert_eq!(
        ra.mean_utilization.to_bits(),
        rb.mean_utilization.to_bits(),
        "off-twin: utilization"
    );
    assert_eq!(ra.spawn_count, 0, "off-twin: spawns must be zero");
    assert_eq!(reca.steps.len(), recb.steps.len(), "off-twin: step records");
    for (sa, sb) in reca.steps.iter().zip(recb.steps.iter()) {
        assert_eq!(sa.loss.to_bits(), sb.loss.to_bits(), "off-twin: step loss");
        assert_eq!(
            sa.virtual_time_s.to_bits(),
            sb.virtual_time_s.to_bits(),
            "off-twin: step time"
        );
    }
    assert_eq!(reca.evals.len(), recb.evals.len(), "off-twin: eval records");
    for (ea, eb) in reca.evals.iter().zip(recb.evals.iter()) {
        assert_eq!(ea.perplexity.to_bits(), eb.perplexity.to_bits(), "off-twin: eval");
    }
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        eprintln!("fig5_elastic: smoke mode (reduced schedule)");
    }
    let mut table = Table::new(&[
        "arm",
        "spawns",
        "mean_live",
        "mean_util",
        "vacant_s",
        "samples",
        "vtime_s",
        "best_ppl",
        "wall_s",
    ]);
    let mut report = |arm: &str, r: &RunResult, wall_s: f64| {
        table.row(&[
            arm.to_string(),
            r.spawn_count.to_string(),
            format!("{:.2}", r.mean_live_instances),
            format!("{:.4}", r.mean_utilization),
            format!("{:.3}", r.total_vacant_s),
            r.total_samples.to_string(),
            format!("{:.3}", r.virtual_time_s),
            format!("{:.3}", r.best_ppl),
            format!("{:.3}", wall_s),
        ]);
    };

    // ---- golden leg: elastic=off is the frozen pool, bit for bit --------
    let st = run_arm(static_config(smoke));
    let off = run_arm(off_config(smoke));
    assert_bit_identical(&st, &off);
    report("static", &st.0, st.2);
    report("elastic_off", &off.0, off.2);

    // ---- elastic arms ----------------------------------------------------
    let util = run_arm(util_config(smoke));
    let resp = run_arm(respawn_config(smoke));
    report("elastic_util", &util.0, util.2);
    report("elastic_respawn", &resp.0, resp.2);

    assert!(
        resp.0.spawn_count >= 1,
        "respawn arm must spawn (merges are deterministic on this schedule)"
    );
    assert!(
        util.0.spawn_count.max(resp.0.spawn_count) >= 1,
        "at least one elastic arm must spawn"
    );
    for (arm, r) in [("util", &util.0), ("respawn", &resp.0)] {
        if r.spawn_count > 0 {
            assert!(
                r.mean_utilization + 1e-9 >= st.0.mean_utilization,
                "elastic_{arm} ({:.4}) must not utilize worse than static ({:.4})",
                r.mean_utilization,
                st.0.mean_utilization
            );
            // both runs merge at the same cadence, so spawns strictly
            // lift the time-averaged live-instance census m(t)
            assert!(
                r.mean_live_instances > st.0.mean_live_instances,
                "elastic_{arm} must lift the live census ({:.3} vs {:.3})",
                r.mean_live_instances,
                st.0.mean_live_instances
            );
        }
    }

    table.print();
    table.write_csv("fig5_elastic").ok();

    println!(
        "\nstatic: util {:.4}, {} samples | elastic_util: {} spawns, util {:.4}, {} \
         samples | elastic_respawn: {} spawns, util {:.4}, {} samples",
        st.0.mean_utilization,
        st.0.total_samples,
        util.0.spawn_count,
        util.0.mean_utilization,
        util.0.total_samples,
        resp.0.spawn_count,
        resp.0.mean_utilization,
        resp.0.total_samples,
    );
}
