//! THM1 — empirical check of Theorem 1 (batch-size growth):
//!
//!   E[b_k] = Ω( k σ² / (η² L (HM + η²) (F(x₀) − F(x*))) )
//!
//! Setup mirrors the theorem's assumptions: MockEngine quadratic
//! (L-smooth, bounded gradient-noise variance), *SGD* inner optimizer,
//! norm-test adaptive batching. We record the requested batch b_k over a
//! long horizon, fit the analytic Ω(k)-shape with a free constant
//! (theory::fit_scale) and report r² — the measured curve should be an
//! approximately linear ramp until the max_request guard or the noise
//! floor of the clamped execution batch kicks in.
//!
//! Run: `cargo bench --bench theory_batch_growth` (`--quick` to smoke).

use adloco::benchkit::{quick_mode, Table};
use adloco::config::presets;
use adloco::coordinator::Coordinator;
use adloco::engine::{MockEngine, MockSpec};
use adloco::theory::{fit_scale, BoundParams};

fn main() {
    let quick = quick_mode();
    let inner = if quick { 200 } else { 2000 };

    let mut cfg = presets::paper_table1();
    cfg.name = "thm1".into();
    cfg.algo.num_trainers = 1;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.outer_steps = 10;
    cfg.algo.inner_steps = inner / 10;
    cfg.algo.merge.enabled = false;
    cfg.algo.switch.enabled = false; // requests recorded, execution clamped
    cfg.algo.batching.max_request = 0; // uncapped: observe the raw growth
    cfg.algo.batching.ema_beta = 0.9; // smooth the single-trainer noise
    cfg.algo.lr_inner = 0.02;
    cfg.run.eval_every = 0;
    cfg.run.eval_batches = 1;

    // noise-dominated from step 1: tiny init distance, strong per-sample
    // noise (sigma=3), so the norm test's request is > 1 immediately and
    // the growth regime spans the whole horizon
    let spec = MockSpec {
        dim: 20,
        noise: 3.0,
        condition: 10.0,
        seed: 42,
        use_sgd: true, // the theorems assume SGD
        init_scale: 0.0,
        ..MockSpec::default()
    };
    let engine = MockEngine::new(spec.clone());
    let mut coord = Coordinator::new(cfg.clone(), Box::new(engine)).unwrap();
    let r = coord.run().unwrap();
    let series = coord.recorder.batch_growth_series();

    // fit the Theorem-1 shape on the pre-saturation segment (before the
    // executed batch clamps at max_batch and the SNR feedback flattens)
    let max_batch = cfg.cluster.nodes[0].max_batch as usize;
    let sat = series
        .iter()
        .position(|&(_, b)| b >= 4 * max_batch)
        .unwrap_or(series.len())
        .max(10)
        .min(series.len());
    let ks: Vec<f64> = series[..sat].iter().map(|&(k, _)| k as f64).collect();
    let bs: Vec<f64> = series[..sat].iter().map(|&(_, b)| b as f64).collect();

    let bound = BoundParams {
        sigma2: spec.noise * spec.noise,
        eta: cfg.algo.batching.eta,
        l_smooth: 1.0, // mock eigenvalues are in [1/cond, 1]
        h: cfg.algo.inner_steps,
        m: cfg.algo.workers_per_trainer,
        f_gap: 0.0, // filled below from the actual run
        b_max: max_batch,
    };
    // F(x0) - F* from the recorded first loss minus the mock loss floor
    let f_gap = coord.recorder.steps.first().map(|s| s.loss - 1.0).unwrap_or(1.0);
    let shape: Vec<f64> = ks
        .iter()
        .map(|&k| BoundParams { f_gap, ..bound }.batch_lower_bound(k as u64, 1.0))
        .collect();
    let (scale, r2) = fit_scale(&shape, &bs);
    let (a, slope, lin_r2) = adloco::util::stats::linear_fit(&ks, &bs);

    println!("\nTHM1 — batch growth E[b_k] = Ω(k·σ²/…)");
    println!("  steps measured      : {}", series.len());
    println!("  fit segment         : first {sat} steps (pre-saturation)");
    println!("  linear fit          : b_k ≈ {a:.2} + {slope:.4}·k   (r² = {lin_r2:.4})");
    println!("  theorem-shape fit   : scale = {scale:.3}, r² = {r2:.4}");
    println!("  final requested b   : {}", series.last().unwrap().1);
    println!("  run summary         : best_ppl {:.3}, samples {}", r.best_ppl, r.total_samples);

    let mut table = Table::new(&["k", "b_req", "theory_shape"]);
    let stride = (sat / 20).max(1);
    for i in (0..sat).step_by(stride) {
        table.row(&[
            format!("{}", ks[i] as u64),
            format!("{}", bs[i] as u64),
            format!("{:.2}", scale * shape[i]),
        ]);
    }
    table.print();
    table.write_csv("thm1_batch_growth").unwrap();

    assert!(slope > 0.0, "batch must grow");
    assert!(lin_r2 > 0.5, "growth not credibly linear (r²={lin_r2})");
}
