//! Minimal CLI argument parser (no `clap` in the offline crate set).
//!
//! Grammar: `adloco <subcommand> [--flag] [--key value | --key=value]...`
//! with repeatable keys (e.g. `--set a=1 --set b=2`).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First bare token (e.g. `train`).
    pub subcommand: Option<String>,
    /// Bare tokens after the subcommand.
    pub positional: Vec<String>,
    /// key -> values, in order of appearance (repeatable options).
    pub options: BTreeMap<String, Vec<String>>,
    /// bare `--flag`s (no value).
    pub flags: Vec<String>,
}

/// Options that take a value; anything else after `--` is a bare flag.
/// Keeping an explicit list avoids the classic `--flag value` ambiguity.
const VALUE_OPTS: &[&str] = &[
    "config", "preset", "set", "out", "profile", "artifacts", "methods",
    "steps", "seed", "log-level", "target-ppl", "format", "param", "values",
    "threads", "jobs", "topology", "overlap", "elastic", "checkpoint",
    "resume", "keep-checkpoints", "addr", "port", "max-runs",
];

/// Parse an argv-style token stream (exclusive of the binary name).
pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
    let mut args = Args::default();
    let mut it = argv.into_iter().peekable();
    while let Some(tok) = it.next() {
        if let Some(rest) = tok.strip_prefix("--") {
            let (key, inline_val) = match rest.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (rest.to_string(), None),
            };
            if let Some(v) = inline_val {
                args.options.entry(key).or_default().push(v);
            } else if VALUE_OPTS.contains(&key.as_str()) {
                match it.next() {
                    Some(v) => args.options.entry(key).or_default().push(v),
                    None => bail!("option --{key} requires a value"),
                }
            } else {
                args.flags.push(key);
            }
        } else if args.subcommand.is_none() {
            args.subcommand = Some(tok);
        } else {
            args.positional.push(tok);
        }
    }
    Ok(args)
}

impl Args {
    /// Last value of `--key` (CLI convention: last one wins).
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    /// Every value of a repeatable `--key`.
    pub fn opt_all(&self, key: &str) -> &[String] {
        self.options.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    /// True when bare `--key` was passed.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse `--key`'s value into `T` (None when absent).
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => match s.parse::<T>() {
                Ok(v) => Ok(Some(v)),
                Err(e) => bail!("--{key} {s:?}: {e}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = p("train --preset quick --set a=1 --set b.c=2 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("preset"), Some("quick"));
        assert_eq!(a.opt_all("set"), &["a=1".to_string(), "b.c=2".to_string()]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = p("bench --profile=tiny --steps=100");
        assert_eq!(a.opt("profile"), Some("tiny"));
        assert_eq!(a.opt_parse::<usize>("steps").unwrap(), Some(100));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(["train".into(), "--preset".into()]).is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = p("x --steps nope");
        assert!(a.opt_parse::<usize>("steps").is_err());
    }

    #[test]
    fn positional_collection() {
        let a = p("report runs/a.jsonl runs/b.jsonl");
        assert_eq!(a.positional, vec!["runs/a.jsonl", "runs/b.jsonl"]);
    }

    #[test]
    fn last_value_wins_for_opt() {
        let a = p("t --preset a --preset b");
        assert_eq!(a.opt("preset"), Some("b"));
    }
}
