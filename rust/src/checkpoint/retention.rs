//! Checkpoint retention (`run.keep_checkpoints`, DESIGN.md §10).
//!
//! Long elastic runs checkpoint every few rounds; with a single target
//! path each write overwrites the last good file, and with per-step
//! paths the directory grows without bound. Retention gives the middle
//! ground: when `run.keep_checkpoints = N > 0`, the coordinator writes
//! each snapshot to `<path>.<step:06>` and then prunes, keeping
//!
//! * the **last N** checkpoints by step, plus
//! * every **pinned** step — the merge-boundary checkpoints, since a
//!   merge is the one event after which the pool's composition changed
//!   and an earlier file can no longer be reproduced by re-running a
//!   kept one (DESIGN.md §9).
//!
//! `keep_checkpoints = 0` (the default) keeps today's behaviour: one
//! file at `run.checkpoint_path`, overwritten in place. The planner
//! ([`plan_retention`]) is pure — the fs sweep ([`enforce`]) only
//! deletes files the planner names, and never the one just written.

use anyhow::{Context, Result};
use std::collections::BTreeSet;

/// The per-step file a retention-managed run writes for `step`.
/// Zero-padded so lexicographic directory order is step order.
pub fn step_file(base: &str, step: u64) -> String {
    format!("{base}.{step:06}")
}

/// Parse the step back out of a [`step_file`] name for `base`.
/// `None` for the bare base path or unrelated files.
pub fn parse_step_file(base: &str, name: &str) -> Option<u64> {
    let suffix = name.strip_prefix(base)?.strip_prefix('.')?;
    if suffix.is_empty() || !suffix.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    suffix.parse().ok()
}

/// Decide which steps to delete: everything except the last `keep`
/// steps and the pinned ones. `keep == 0` disables retention (nothing
/// is ever deleted).
pub fn plan_retention(steps: &[(u64, bool)], keep: usize) -> Vec<u64> {
    if keep == 0 {
        return Vec::new();
    }
    let mut sorted: Vec<u64> = steps.iter().map(|&(s, _)| s).collect();
    sorted.sort_unstable();
    sorted.dedup();
    let cutoff = sorted.len().saturating_sub(keep);
    let recent: BTreeSet<u64> = sorted[cutoff..].iter().copied().collect();
    let pinned: BTreeSet<u64> =
        steps.iter().filter(|&&(_, pin)| pin).map(|&(s, _)| s).collect();
    sorted
        .into_iter()
        .filter(|s| !recent.contains(s) && !pinned.contains(s))
        .collect()
}

/// List the steps that currently have a [`step_file`] on disk for
/// `base`, ascending.
pub fn list_steps(base: &str) -> Vec<u64> {
    let path = std::path::Path::new(base);
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let file = match path.file_name().and_then(|f| f.to_str()) {
        Some(f) => f,
        None => return Vec::new(),
    };
    let entries = match std::fs::read_dir(dir.unwrap_or_else(|| std::path::Path::new("."))) {
        Ok(e) => e,
        Err(_) => return Vec::new(),
    };
    let mut steps: Vec<u64> = entries
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().to_str().and_then(|n| parse_step_file(file, n)))
        .collect();
    steps.sort_unstable();
    steps.dedup();
    steps
}

/// Prune `base`'s step files down to the last `keep` plus `pinned`
/// steps. Returns the steps actually deleted. No-op when `keep == 0`.
pub fn enforce(base: &str, keep: usize, pinned: &BTreeSet<u64>) -> Result<Vec<u64>> {
    if keep == 0 {
        return Ok(Vec::new());
    }
    let on_disk: Vec<(u64, bool)> =
        list_steps(base).into_iter().map(|s| (s, pinned.contains(&s))).collect();
    let mut deleted = Vec::new();
    for step in plan_retention(&on_disk, keep) {
        let path = step_file(base, step);
        std::fs::remove_file(&path).with_context(|| format!("pruning checkpoint {path}"))?;
        deleted.push(step);
    }
    Ok(deleted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_file_names_sort_in_step_order() {
        assert_eq!(step_file("out/run.ckpt", 7), "out/run.ckpt.000007");
        assert!(step_file("c", 99) < step_file("c", 100));
        assert_eq!(parse_step_file("run.ckpt", "run.ckpt.000042"), Some(42));
        assert_eq!(parse_step_file("run.ckpt", "run.ckpt"), None);
        assert_eq!(parse_step_file("run.ckpt", "run.ckpt.tmp"), None);
        assert_eq!(parse_step_file("run.ckpt", "other.ckpt.000001"), None);
    }

    #[test]
    fn planner_keeps_last_n_and_pins() {
        let steps: Vec<(u64, bool)> =
            vec![(2, false), (4, true), (6, false), (8, true), (10, false), (12, false)];
        // keep the last 2 (10, 12) plus the pinned merge boundaries (4, 8)
        assert_eq!(plan_retention(&steps, 2), vec![2, 6]);
        // a large enough keep deletes nothing
        assert_eq!(plan_retention(&steps, 6), Vec::<u64>::new());
        assert_eq!(plan_retention(&steps, 100), Vec::<u64>::new());
        // keep == 0 disables retention entirely
        assert_eq!(plan_retention(&steps, 0), Vec::<u64>::new());
        // pins alone never count against the keep window
        assert_eq!(plan_retention(&steps, 1), vec![2, 6, 10]);
    }

    #[test]
    fn enforce_prunes_only_step_files() {
        let dir = std::env::temp_dir().join("adloco_retention_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("run.ckpt");
        let base = base.to_str().unwrap();
        for step in [2u64, 4, 6, 8, 10] {
            std::fs::write(step_file(base, step), b"x").unwrap();
        }
        // an unrelated file and the bare base must survive any sweep
        std::fs::write(base, b"bare").unwrap();
        std::fs::write(dir.join("notes.txt"), b"n").unwrap();

        let pinned: BTreeSet<u64> = [4u64].into_iter().collect();
        let deleted = enforce(base, 2, &pinned).unwrap();
        assert_eq!(deleted, vec![2, 6]);
        assert_eq!(list_steps(base), vec![4, 8, 10]);
        assert!(std::path::Path::new(base).exists());
        assert!(dir.join("notes.txt").exists());

        // idempotent: a second sweep has nothing left to do
        assert_eq!(enforce(base, 2, &pinned).unwrap(), Vec::<u64>::new());
    }
}
