//! The v4 checkpoint interchange container (DESIGN.md §10).
//!
//! Byte layout:
//!
//! ```text
//! "ADLC"  u32-LE version=4
//! for each section META, HEAD, BLOB, "END.":
//!     tag[4]  u32-LE payload_len  payload  u64-LE fnv1a(tag‖len‖payload)
//! u64-LE fnv1a(everything above)            -- the file seal
//! ```
//!
//! * **META** — format metadata JSON: the interchange variant
//!   (`complete` for exact resume, `minimal` for params+RNG
//!   warm-start), `interchange_format_version`, the producing crate
//!   version, the config name, the config structural digest, and the
//!   accounting-array encoding flag (`accounting_encoding`, see
//!   [`AccountingEncoding`]; absent in pre-PR-8 files = `hex`).
//! * **HEAD** — the state header JSON (everything except raw payloads;
//!   wide integers and all f64s as bit-exact hex strings — except the
//!   per-slot f64 accounting arrays under `raw64le`, where HEAD keeps
//!   only their element counts).
//! * **BLOB** — raw little-endian payload, in header order. Under
//!   `raw64le` the seven accounting f64 arrays come first (HEAD field
//!   order), then the f32 state vectors; under `hex` it is the f32
//!   vectors alone.
//! * **END.** — empty; a positional sentinel so a file cut between
//!   BLOB's seal and the file seal is still structurally detected.
//!
//! Every section carries its own FNV-1a seal, and the whole file a
//! final one, so truncation at *any* offset and any single-byte
//! corruption are detected deterministically (see `util::hash` for the
//! single-byte guarantee) and surface as a typed [`InterchangeError`]
//! — never a panic, never a silent partial resume. Bytes after the
//! file seal are rejected as [`InterchangeError::TrailingGarbage`].
//!
//! Parsing is strict (`deny_unknown_fields`-style): every JSON object
//! in META/HEAD must be fully consumed; an unrecognized or duplicated
//! field is [`InterchangeError::UnknownField`], so files written by a
//! newer schema revision fail loudly instead of silently dropping
//! state. `tests/crash_fault.rs` drives all of this kill-anywhere:
//! truncating at every section boundary and flipping sampled bytes of
//! real mid-run checkpoints.

use super::{
    blob_bytes, bytes_to_f32s, bytes_to_f64s, f64s_to_bytes, state_fields_with, Checkpoint,
    Interchange, MinimalCheckpoint, MinimalTrainer, MinimalWorker, PendingSnapshot,
    PhaseSnapshot, RegistryRowSnapshot, RngSnapshot, SamplerSnapshot, TrainerSnapshot,
    WorkerSnapshot, MAGIC, VERSION,
};
use crate::util::{fnv1a, JsonValue};
use std::fmt;

/// Typed interchange failure. Every way a checkpoint file can be
/// unreadable maps to exactly one of these — callers (and the
/// crash-fault harness) match on the variant, not on message text.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InterchangeError {
    /// Strict parsing found a field the schema does not define (or a
    /// duplicate of one it does).
    UnknownField {
        /// Path of the enclosing object, e.g. `HEAD.trainers[0]`.
        context: String,
        /// The offending field name.
        field: String,
    },
    /// The container (or META) declares a version this build does not
    /// read.
    VersionMismatch {
        /// The declared version.
        found: u32,
    },
    /// The file ends before a section's declared extent.
    Truncated {
        /// Section being read when the bytes ran out.
        section: String,
        /// Bytes the section needed the file to reach.
        needed: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// A seal mismatch or malformed content inside a section.
    Corrupt {
        /// Section (or legacy region) that failed.
        section: String,
        /// What was wrong.
        detail: String,
    },
    /// Bytes present after the file seal.
    TrailingGarbage {
        /// How many extra bytes follow.
        bytes: usize,
    },
}

impl fmt::Display for InterchangeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InterchangeError::UnknownField { context, field } => write!(
                f,
                "unknown field {field:?} in {context} (strict interchange parsing \
                 rejects unrecognized fields)"
            ),
            InterchangeError::VersionMismatch { found } => write!(
                f,
                "unsupported checkpoint interchange version {found} (this build reads \
                 versions 1 through {VERSION})"
            ),
            InterchangeError::Truncated { section, needed, have } => write!(
                f,
                "checkpoint truncated in {section}: need {needed} bytes, have {have}"
            ),
            InterchangeError::Corrupt { section, detail } => {
                write!(f, "checkpoint corrupt in {section}: {detail}")
            }
            InterchangeError::TrailingGarbage { bytes } => write!(
                f,
                "checkpoint has {bytes} trailing byte(s) after the file seal"
            ),
        }
    }
}

impl std::error::Error for InterchangeError {}

type IResult<T> = std::result::Result<T, InterchangeError>;

/// The two interchange variants (META `interchange_format`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InterchangeFormat {
    /// Params + RNG states: enough to warm-start, not to resume.
    Minimal,
    /// Everything exact resume reads.
    Complete,
}

impl InterchangeFormat {
    /// The META field value.
    pub fn as_str(self) -> &'static str {
        match self {
            InterchangeFormat::Minimal => "minimal",
            InterchangeFormat::Complete => "complete",
        }
    }
}

/// How the seven per-slot f64 accounting arrays of a *complete*
/// snapshot (clock_times, busy_s, wait_s, comm_s, comm_hidden_s,
/// preempted_s, vacant_s) are encoded (META `accounting_encoding`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccountingEncoding {
    /// Per-f64 hex strings inline in HEAD — what pre-PR-8 v4 files (no
    /// META flag) and the legacy v3 exporter carry. ~18 JSON bytes per
    /// element; allocation-heavy at 10k slots.
    Hex,
    /// Raw little-endian f64 bytes at the front of the BLOB section
    /// (HEAD field order); HEAD keeps only the element counts. Exact
    /// (bit-for-bit, like hex) at 8 bytes per element.
    Raw,
}

impl AccountingEncoding {
    /// The META field value.
    pub fn as_str(self) -> &'static str {
        match self {
            AccountingEncoding::Hex => "hex",
            AccountingEncoding::Raw => "raw64le",
        }
    }
}

/// Parsed META section: what the file *is*, before any state is read.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InterchangeMeta {
    /// Which variant the file carries.
    pub format: InterchangeFormat,
    /// Declared interchange version (must match the container's).
    pub format_version: u32,
    /// `CARGO_PKG_VERSION` of the writer — informational only; any
    /// value loads.
    pub crate_version: String,
    /// Name of the config that produced the snapshot.
    pub config_name: String,
    /// `Config::structural_digest` of the producing config (0 when
    /// unknown).
    pub config_digest: u64,
    /// Accounting-array encoding; files without the META flag (written
    /// before it existed) decode as [`AccountingEncoding::Hex`].
    pub accounting: AccountingEncoding,
}

const SEC_META: &[u8; 4] = b"META";
const SEC_HEAD: &[u8; 4] = b"HEAD";
const SEC_BLOB: &[u8; 4] = b"BLOB";
const SEC_END: &[u8; 4] = b"END.";
const SECTION_TAGS: [&[u8; 4]; 4] = [SEC_META, SEC_HEAD, SEC_BLOB, SEC_END];

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

fn push_section(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    let start = out.len();
    out.extend_from_slice(tag);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let seal = fnv1a(&out[start..]);
    out.extend_from_slice(&seal.to_le_bytes());
}

fn container(meta: &[u8], head: &[u8], blob: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(meta.len() + head.len() + blob.len() + 64);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    push_section(&mut out, SEC_META, meta);
    push_section(&mut out, SEC_HEAD, head);
    push_section(&mut out, SEC_BLOB, blob);
    push_section(&mut out, SEC_END, &[]);
    let seal = fnv1a(&out);
    out.extend_from_slice(&seal.to_le_bytes());
    out
}

fn meta_json(
    format: InterchangeFormat,
    config_name: &str,
    config_digest: u64,
    accounting: AccountingEncoding,
) -> String {
    JsonValue::obj(vec![
        ("interchange_format", JsonValue::str(format.as_str())),
        ("interchange_format_version", JsonValue::num(VERSION as f64)),
        ("crate_version", JsonValue::str(env!("CARGO_PKG_VERSION"))),
        ("config_name", JsonValue::str(config_name)),
        ("config_digest", super::u64_json(config_digest)),
        ("accounting_encoding", JsonValue::str(accounting.as_str())),
    ])
    .to_string()
}

/// The seven accounting arrays in HEAD field order — the raw64le BLOB
/// prefix order the writer and reader must agree on.
fn accounting_arrays(cp: &Checkpoint) -> [&[f64]; 7] {
    [
        &cp.clock_times,
        &cp.busy_s,
        &cp.wait_s,
        &cp.comm_s,
        &cp.comm_hidden_s,
        &cp.preempted_s,
        &cp.vacant_s,
    ]
}

/// Serialize a full snapshot as the v4 *complete* container (raw64le
/// accounting — the default writer since PR 8).
pub fn encode_complete(cp: &Checkpoint) -> Vec<u8> {
    encode_complete_with(cp, AccountingEncoding::Raw)
}

/// `encode_complete` with an explicit accounting encoding. `Hex`
/// reproduces the pre-PR-8 writer byte layout (kept callable so tests
/// and the micro bench can pin legacy importability and measure the
/// encoding gap).
pub fn encode_complete_with(cp: &Checkpoint, accounting: AccountingEncoding) -> Vec<u8> {
    let meta =
        meta_json(InterchangeFormat::Complete, &cp.config_name, cp.config_digest, accounting);
    let raw = accounting == AccountingEncoding::Raw;
    let head = JsonValue::obj(state_fields_with(cp, raw)).to_string();
    let blob = if raw {
        let mut out = Vec::new();
        for arr in accounting_arrays(cp) {
            f64s_to_bytes(arr, &mut out);
        }
        out.extend_from_slice(&blob_bytes(cp));
        out
    } else {
        blob_bytes(cp)
    };
    container(meta.as_bytes(), head.as_bytes(), &blob)
}

/// Serialize a warm-start snapshot as the v4 *minimal* container.
/// (Minimal files carry no accounting arrays; the META flag is emitted
/// as `hex` purely for uniformity.)
pub fn encode_minimal(m: &MinimalCheckpoint) -> Vec<u8> {
    let meta = meta_json(
        InterchangeFormat::Minimal,
        &m.config_name,
        m.config_digest,
        AccountingEncoding::Hex,
    );
    let head = JsonValue::obj(vec![
        ("outer_step", super::u64_json(m.outer_step)),
        ("rng", super::rng_json(&m.rng)),
        (
            "trainers",
            JsonValue::Array(
                m.trainers
                    .iter()
                    .map(|t| {
                        JsonValue::obj(vec![
                            ("id", JsonValue::num(t.id as f64)),
                            ("param_len", JsonValue::num(t.params.len() as f64)),
                            (
                                "workers",
                                JsonValue::Array(
                                    t.workers
                                        .iter()
                                        .map(|w| {
                                            JsonValue::obj(vec![
                                                ("noise_rng", super::rng_json(&w.noise_rng)),
                                                ("time_rng", super::rng_json(&w.time_rng)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
    .to_string();
    let mut blob = Vec::new();
    for t in &m.trainers {
        super::f32s_to_bytes(&t.params, &mut blob);
    }
    container(meta.as_bytes(), head.as_bytes(), &blob)
}

// ---------------------------------------------------------------------------
// structural walk
// ---------------------------------------------------------------------------

fn tag_name(tag: &[u8; 4]) -> &'static str {
    match tag {
        b"META" => "META",
        b"HEAD" => "HEAD",
        b"BLOB" => "BLOB",
        _ => "END.",
    }
}

/// Split a v4 container into its four section payloads, verifying the
/// section seals, the file seal, and the absence of trailing bytes.
fn split_sections(raw: &[u8]) -> IResult<[&[u8]; 4]> {
    let mut cur = 8usize; // past magic + version
    let mut payloads: [&[u8]; 4] = [&[]; 4];
    for (i, tag) in SECTION_TAGS.iter().enumerate() {
        let name = tag_name(tag);
        if raw.len() < cur + 8 {
            return Err(InterchangeError::Truncated {
                section: name.into(),
                needed: cur + 8,
                have: raw.len(),
            });
        }
        if &raw[cur..cur + 4] != *tag {
            return Err(InterchangeError::Corrupt {
                section: name.into(),
                detail: format!(
                    "expected section tag {:?}, found {:?}",
                    String::from_utf8_lossy(*tag),
                    String::from_utf8_lossy(&raw[cur..cur + 4])
                ),
            });
        }
        let len = u32::from_le_bytes(raw[cur + 4..cur + 8].try_into().unwrap()) as usize;
        let end = cur + 8 + len;
        if raw.len() < end + 8 {
            return Err(InterchangeError::Truncated {
                section: name.into(),
                needed: end + 8,
                have: raw.len(),
            });
        }
        let seal = u64::from_le_bytes(raw[end..end + 8].try_into().unwrap());
        if fnv1a(&raw[cur..end]) != seal {
            return Err(InterchangeError::Corrupt {
                section: name.into(),
                detail: "section seal mismatch".into(),
            });
        }
        payloads[i] = &raw[cur + 8..end];
        cur = end + 8;
    }
    if !payloads[3].is_empty() {
        return Err(InterchangeError::Corrupt {
            section: "END.".into(),
            detail: format!("sentinel section carries {} payload bytes", payloads[3].len()),
        });
    }
    if raw.len() < cur + 8 {
        return Err(InterchangeError::Truncated {
            section: "file seal".into(),
            needed: cur + 8,
            have: raw.len(),
        });
    }
    let seal = u64::from_le_bytes(raw[cur..cur + 8].try_into().unwrap());
    if fnv1a(&raw[..cur]) != seal {
        return Err(InterchangeError::Corrupt {
            section: "file seal".into(),
            detail: "file seal mismatch".into(),
        });
    }
    cur += 8;
    if raw.len() > cur {
        return Err(InterchangeError::TrailingGarbage { bytes: raw.len() - cur });
    }
    Ok(payloads)
}

/// Structural offsets of a (valid) v4 container: the prologue edges,
/// every section's tag/length/payload/seal edges, and the file end.
/// The crash-fault harness truncates at each of these — every cut
/// before the end must fail typed.
pub fn section_boundaries(raw: &[u8]) -> Vec<usize> {
    let mut out = vec![0usize, 4, 8];
    let mut cur = 8usize;
    for _ in SECTION_TAGS.iter() {
        if raw.len() < cur + 8 {
            break;
        }
        let len = u32::from_le_bytes(raw[cur + 4..cur + 8].try_into().unwrap()) as usize;
        let end = cur + 8 + len;
        if end + 8 > raw.len() {
            break;
        }
        out.extend_from_slice(&[cur + 4, cur + 8, end, end + 8]);
        cur = end + 8;
    }
    if *out.last().unwrap() < raw.len() {
        out.push(raw.len());
    }
    out.dedup();
    out
}

// ---------------------------------------------------------------------------
// strict reader
// ---------------------------------------------------------------------------

/// `deny_unknown_fields` over a parsed JSON object: every field must be
/// consumed exactly once; `finish` rejects whatever is left (which also
/// catches duplicated keys — the second copy is never consumable).
struct StrictObj<'a> {
    fields: &'a [(String, JsonValue)],
    taken: Vec<bool>,
    section: &'static str,
    path: String,
}

impl<'a> StrictObj<'a> {
    fn new(v: &'a JsonValue, section: &'static str, path: String) -> IResult<StrictObj<'a>> {
        let fields = v.as_object().ok_or_else(|| InterchangeError::Corrupt {
            section: section.into(),
            detail: format!("{path} is not an object"),
        })?;
        let taken = vec![false; fields.len()];
        Ok(StrictObj { fields, taken, section, path })
    }

    fn take(&mut self, key: &str) -> IResult<&'a JsonValue> {
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if !self.taken[i] && k == key {
                self.taken[i] = true;
                return Ok(v);
            }
        }
        Err(InterchangeError::Corrupt {
            section: self.section.into(),
            detail: format!("{}: missing field {key:?}", self.path),
        })
    }

    /// `take` for fields added after the format shipped: None when the
    /// field is absent (older writer), so the caller picks the legacy
    /// default instead of erroring.
    fn take_opt(&mut self, key: &str) -> Option<&'a JsonValue> {
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if !self.taken[i] && k == key {
                self.taken[i] = true;
                return Some(v);
            }
        }
        None
    }

    fn finish(self) -> IResult<()> {
        for (i, (k, _)) in self.fields.iter().enumerate() {
            if !self.taken[i] {
                return Err(InterchangeError::UnknownField {
                    context: self.path,
                    field: k.clone(),
                });
            }
        }
        Ok(())
    }
}

fn corrupt(section: &'static str, detail: String) -> InterchangeError {
    InterchangeError::Corrupt { section: section.into(), detail }
}

fn s_str<'a>(v: &'a JsonValue, sec: &'static str, path: &str) -> IResult<&'a str> {
    v.as_str().ok_or_else(|| corrupt(sec, format!("{path} is not a string")))
}

fn s_bool(v: &JsonValue, sec: &'static str, path: &str) -> IResult<bool> {
    v.as_bool().ok_or_else(|| corrupt(sec, format!("{path} is not a bool")))
}

fn s_hex(v: &JsonValue, sec: &'static str, path: &str) -> IResult<u64> {
    let s = s_str(v, sec, path)?;
    u64::from_str_radix(s, 16).map_err(|_| corrupt(sec, format!("{path}: bad hex word {s:?}")))
}

/// Exact u64: the hex-string form the writer emits, with plain integral
/// numbers tolerated for hand-written headers.
fn s_u64(v: &JsonValue, sec: &'static str, path: &str) -> IResult<u64> {
    if v.as_str().is_some() {
        return s_hex(v, sec, path);
    }
    match v.as_f64() {
        Some(n) if n >= 0.0 && n.fract() == 0.0 => Ok(n as u64),
        _ => Err(corrupt(sec, format!("{path} is not an integer"))),
    }
}

/// Bit-exact f64 (hex of the raw bits), plain numbers tolerated.
fn s_f64(v: &JsonValue, sec: &'static str, path: &str) -> IResult<f64> {
    if v.as_str().is_some() {
        return Ok(f64::from_bits(s_hex(v, sec, path)?));
    }
    v.as_f64().ok_or_else(|| corrupt(sec, format!("{path} is not a number")))
}

fn s_usize(v: &JsonValue, sec: &'static str, path: &str) -> IResult<usize> {
    v.as_usize().ok_or_else(|| corrupt(sec, format!("{path} is not a small integer")))
}

fn s_array<'a>(v: &'a JsonValue, sec: &'static str, path: &str) -> IResult<&'a [JsonValue]> {
    v.as_array().ok_or_else(|| corrupt(sec, format!("{path} is not an array")))
}

fn s_usizes(v: &JsonValue, sec: &'static str, path: &str) -> IResult<Vec<usize>> {
    s_array(v, sec, path)?
        .iter()
        .enumerate()
        .map(|(i, x)| s_usize(x, sec, &format!("{path}[{i}]")))
        .collect()
}

fn s_f64s(v: &JsonValue, sec: &'static str, path: &str) -> IResult<Vec<f64>> {
    s_array(v, sec, path)?
        .iter()
        .enumerate()
        .map(|(i, x)| s_f64(x, sec, &format!("{path}[{i}]")))
        .collect()
}

fn s_rng(v: &JsonValue, sec: &'static str, path: &str) -> IResult<RngSnapshot> {
    let mut o = StrictObj::new(v, sec, path.to_string())?;
    let words = s_array(o.take("s")?, sec, &format!("{path}.s"))?;
    if words.len() != 4 {
        return Err(corrupt(sec, format!("{path}.s: expected 4 rng words, got {}", words.len())));
    }
    let mut s = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        s[i] = s_hex(w, sec, &format!("{path}.s[{i}]"))?;
    }
    let gauss_spare = match o.take("spare")? {
        JsonValue::Null => None,
        x => Some(f64::from_bits(s_hex(x, sec, &format!("{path}.spare"))?)),
    };
    o.finish()?;
    Ok(RngSnapshot { s, gauss_spare })
}

fn s_ema(v: &JsonValue, sec: &'static str, path: &str) -> IResult<(f64, u64)> {
    let mut o = StrictObj::new(v, sec, path.to_string())?;
    let value = s_f64(o.take("value")?, sec, &format!("{path}.value"))?;
    let steps = s_u64(o.take("steps")?, sec, &format!("{path}.steps"))?;
    o.finish()?;
    Ok((value, steps))
}

fn parse_json(payload: &[u8], sec: &'static str) -> IResult<JsonValue> {
    let text = std::str::from_utf8(payload)
        .map_err(|e| corrupt(sec, format!("payload is not UTF-8: {e}")))?;
    JsonValue::parse(text).map_err(|e| corrupt(sec, format!("payload is not valid JSON: {e}")))
}

fn take_f32s(blob: &[u8], cursor: &mut usize, n: usize, what: &str) -> IResult<Vec<f32>> {
    let bytes = n * 4;
    if *cursor + bytes > blob.len() {
        return Err(corrupt(
            "BLOB",
            format!(
                "payload exhausted reading {what}: need {} bytes at offset {}, have {}",
                bytes,
                *cursor,
                blob.len()
            ),
        ));
    }
    let out = bytes_to_f32s(&blob[*cursor..*cursor + bytes]);
    *cursor += bytes;
    Ok(out)
}

fn take_f64s(blob: &[u8], cursor: &mut usize, n: usize, what: &str) -> IResult<Vec<f64>> {
    let bytes = n * 8;
    if *cursor + bytes > blob.len() {
        return Err(corrupt(
            "BLOB",
            format!(
                "payload exhausted reading {what}: need {} bytes at offset {}, have {}",
                bytes,
                *cursor,
                blob.len()
            ),
        ));
    }
    let out = bytes_to_f64s(&blob[*cursor..*cursor + bytes]);
    *cursor += bytes;
    Ok(out)
}

/// One accounting array: inline hex f64s (`hex`), or an element count
/// resolved against the BLOB prefix (`raw64le`).
fn accounting_array(
    v: &JsonValue,
    accounting: AccountingEncoding,
    blob: &[u8],
    cursor: &mut usize,
    path: &str,
) -> IResult<Vec<f64>> {
    match accounting {
        AccountingEncoding::Hex => s_f64s(v, "HEAD", path),
        AccountingEncoding::Raw => {
            let n = s_usize(v, "HEAD", path)?;
            take_f64s(blob, cursor, n, path)
        }
    }
}

// ---------------------------------------------------------------------------
// decoders
// ---------------------------------------------------------------------------

fn parse_meta(payload: &[u8]) -> IResult<InterchangeMeta> {
    let v = parse_json(payload, "META")?;
    let mut o = StrictObj::new(&v, "META", "META".into())?;
    let format = match s_str(o.take("interchange_format")?, "META", "META.interchange_format")? {
        "minimal" => InterchangeFormat::Minimal,
        "complete" => InterchangeFormat::Complete,
        other => {
            return Err(corrupt("META", format!("unknown interchange_format {other:?}")));
        }
    };
    let format_version =
        s_u64(o.take("interchange_format_version")?, "META", "META.interchange_format_version")?;
    if format_version != VERSION as u64 {
        return Err(InterchangeError::VersionMismatch { found: format_version as u32 });
    }
    let crate_version =
        s_str(o.take("crate_version")?, "META", "META.crate_version")?.to_string();
    let config_name = s_str(o.take("config_name")?, "META", "META.config_name")?.to_string();
    let config_digest = s_u64(o.take("config_digest")?, "META", "META.config_digest")?;
    let accounting = match o.take_opt("accounting_encoding") {
        // pre-flag writers: inline hex accounting arrays
        None => AccountingEncoding::Hex,
        Some(v) => match s_str(v, "META", "META.accounting_encoding")? {
            "hex" => AccountingEncoding::Hex,
            "raw64le" => AccountingEncoding::Raw,
            other => {
                return Err(corrupt("META", format!("unknown accounting_encoding {other:?}")));
            }
        },
    };
    o.finish()?;
    Ok(InterchangeMeta {
        format,
        format_version: format_version as u32,
        crate_version,
        config_name,
        config_digest,
        accounting,
    })
}

fn parse_registry_row(v: &JsonValue, path: &str) -> IResult<RegistryRowSnapshot> {
    const S: &str = "HEAD";
    let mut o = StrictObj::new(v, S, path.to_string())?;
    let id = s_usize(o.take("id")?, S, &format!("{path}.id"))?;
    let state = s_str(o.take("state")?, S, &format!("{path}.state"))?.to_string();
    let origin = s_str(o.take("origin")?, S, &format!("{path}.origin"))?.to_string();
    let born_outer = s_u64(o.take("born_outer")?, S, &format!("{path}.born_outer"))?;
    let born_at_s = s_f64(o.take("born_at_s")?, S, &format!("{path}.born_at_s"))?;
    let retired_outer = match o.take("retired_outer")? {
        JsonValue::Null => None,
        x => Some(s_u64(x, S, &format!("{path}.retired_outer"))?),
    };
    let workers = s_array(o.take("workers")?, S, &format!("{path}.workers"))?
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let wp = format!("{path}.workers[{i}]");
            let pair = s_array(w, S, &wp)?;
            if pair.len() != 2 {
                return Err(corrupt(S, format!("{wp}: expected [node, slot]")));
            }
            Ok((s_usize(&pair[0], S, &wp)?, s_usize(&pair[1], S, &wp)?))
        })
        .collect::<IResult<Vec<(usize, usize)>>>()?;
    o.finish()?;
    Ok(RegistryRowSnapshot { id, state, origin, born_outer, born_at_s, retired_outer, workers })
}

fn parse_trainer(v: &JsonValue, path: &str, blob: &[u8], cursor: &mut usize) -> IResult<TrainerSnapshot> {
    const S: &str = "HEAD";
    let mut o = StrictObj::new(v, S, path.to_string())?;
    let id = s_usize(o.take("id")?, S, &format!("{path}.id"))?;
    let param_len = s_usize(o.take("param_len")?, S, &format!("{path}.param_len"))?;
    let velocity_len = s_usize(o.take("velocity_len")?, S, &format!("{path}.velocity_len"))?;
    let requested_batch = s_usize(o.take("requested_batch")?, S, &format!("{path}.requested_batch"))?;
    let inner_steps_done = s_u64(o.take("inner_steps_done")?, S, &format!("{path}.inner_steps_done"))?;
    let observations = s_u64(o.take("observations")?, S, &format!("{path}.observations"))?;
    let sigma2_ema = s_ema(o.take("sigma2_ema")?, S, &format!("{path}.sigma2_ema"))?;
    let ip_var_ema = s_ema(o.take("ip_var_ema")?, S, &format!("{path}.ip_var_ema"))?;
    let s1_ema = s_ema(o.take("s1_ema")?, S, &format!("{path}.s1_ema"))?;
    let shard = s_usizes(o.take("shard")?, S, &format!("{path}.shard"))?;

    // pending header first (its delta sits between the trainer vectors
    // and the worker vectors in the blob)
    let pending_v = o.take("pending")?;
    let pending_head = match pending_v {
        JsonValue::Null => None,
        x => {
            let pp = format!("{path}.pending");
            let mut po = StrictObj::new(x, S, pp.clone())?;
            let posted_at = s_f64(po.take("posted_at")?, S, &format!("{pp}.posted_at"))?;
            let completes_at = s_f64(po.take("completes_at")?, S, &format!("{pp}.completes_at"))?;
            let time_s = s_f64(po.take("time_s")?, S, &format!("{pp}.time_s"))?;
            let sent_samples = s_u64(po.take("sent_samples")?, S, &format!("{pp}.sent_samples"))?;
            let delta_len = s_usize(po.take("delta_len")?, S, &format!("{pp}.delta_len"))?;
            let phases = s_array(po.take("phases")?, S, &format!("{pp}.phases"))?
                .iter()
                .enumerate()
                .map(|(i, ph)| {
                    let php = format!("{pp}.phases[{i}]");
                    let mut pho = StrictObj::new(ph, S, php.clone())?;
                    let wan = s_bool(pho.take("wan")?, S, &format!("{php}.wan"))?;
                    let bytes = s_u64(pho.take("bytes")?, S, &format!("{php}.bytes"))?;
                    let participants =
                        s_usize(pho.take("participants")?, S, &format!("{php}.participants"))?;
                    pho.finish()?;
                    Ok(PhaseSnapshot { wan, bytes, participants })
                })
                .collect::<IResult<Vec<PhaseSnapshot>>>()?;
            po.finish()?;
            Some((posted_at, completes_at, time_s, sent_samples, delta_len, phases))
        }
    };

    let workers_v = s_array(o.take("workers")?, S, &format!("{path}.workers"))?.to_vec();
    o.finish()?;

    // blob fills, in writer order: params, velocity, pending delta,
    // then per-worker params/m/v
    let params = take_f32s(blob, cursor, param_len, &format!("{path}.params"))?;
    let outer_velocity = take_f32s(blob, cursor, velocity_len, &format!("{path}.velocity"))?;
    let pending = match pending_head {
        None => None,
        Some((posted_at, completes_at, time_s, sent_samples, delta_len, phases)) => {
            let delta = take_f32s(blob, cursor, delta_len, &format!("{path}.pending.delta"))?;
            Some(PendingSnapshot { posted_at, completes_at, time_s, sent_samples, phases, delta })
        }
    };
    let mut workers = Vec::with_capacity(workers_v.len());
    for (wi, wv) in workers_v.iter().enumerate() {
        let wp = format!("{path}.workers[{wi}]");
        let mut wo = StrictObj::new(wv, S, wp.clone())?;
        let w_param_len = s_usize(wo.take("param_len")?, S, &format!("{wp}.param_len"))?;
        let step = s_u64(wo.take("step")?, S, &format!("{wp}.step"))?;
        let active = s_bool(wo.take("active")?, S, &format!("{wp}.active"))?;
        let noise_rng = s_rng(wo.take("noise_rng")?, S, &format!("{wp}.noise_rng"))?;
        let time_rng = s_rng(wo.take("time_rng")?, S, &format!("{wp}.time_rng"))?;
        let sv = wo.take("sampler")?;
        let sp = format!("{wp}.sampler");
        let mut so = StrictObj::new(sv, S, sp.clone())?;
        let sampler = SamplerSnapshot {
            shard: s_usizes(so.take("shard")?, S, &format!("{sp}.shard"))?,
            order: s_usizes(so.take("order")?, S, &format!("{sp}.order"))?,
            cursor: s_usize(so.take("cursor")?, S, &format!("{sp}.cursor"))?,
            drawn: s_u64(so.take("drawn")?, S, &format!("{sp}.drawn"))?,
            rng: s_rng(so.take("rng")?, S, &format!("{sp}.rng"))?,
        };
        so.finish()?;
        wo.finish()?;
        let w_params = take_f32s(blob, cursor, w_param_len, &format!("{wp}.params"))?;
        let m = take_f32s(blob, cursor, w_param_len, &format!("{wp}.m"))?;
        let vv = take_f32s(blob, cursor, w_param_len, &format!("{wp}.v"))?;
        workers.push(WorkerSnapshot {
            params: w_params,
            m,
            v: vv,
            step,
            active,
            noise_rng,
            time_rng,
            sampler,
        });
    }

    Ok(TrainerSnapshot {
        id,
        params,
        outer_velocity,
        requested_batch,
        inner_steps_done,
        observations,
        sigma2_ema,
        ip_var_ema,
        s1_ema,
        shard,
        pending,
        workers,
    })
}

fn decode_complete(meta: &InterchangeMeta, head: &[u8], blob: &[u8]) -> IResult<Checkpoint> {
    const S: &str = "HEAD";
    let v = parse_json(head, S)?;
    let mut o = StrictObj::new(&v, S, S.into())?;
    let outer_step = s_u64(o.take("outer_step")?, S, "HEAD.outer_step")?;
    let total_samples = s_u64(o.take("total_samples")?, S, "HEAD.total_samples")?;
    let comm_count = s_u64(o.take("comm_count")?, S, "HEAD.comm_count")?;
    let comm_bytes = s_u64(o.take("comm_bytes")?, S, "HEAD.comm_bytes")?;
    let comm_wan_bytes = s_u64(o.take("comm_wan_bytes")?, S, "HEAD.comm_wan_bytes")?;
    let overlap_hidden_s = s_f64(o.take("overlap_hidden_s")?, S, "HEAD.overlap_hidden_s")?;
    // under raw64le the accounting arrays occupy the BLOB prefix, so the
    // cursor the trainer vectors continue from starts after them
    let mut cursor = 0usize;
    let acct = meta.accounting;
    let clock_times =
        accounting_array(o.take("clock_times")?, acct, blob, &mut cursor, "HEAD.clock_times")?;
    let busy_s = accounting_array(o.take("busy_s")?, acct, blob, &mut cursor, "HEAD.busy_s")?;
    let wait_s = accounting_array(o.take("wait_s")?, acct, blob, &mut cursor, "HEAD.wait_s")?;
    let comm_s = accounting_array(o.take("comm_s")?, acct, blob, &mut cursor, "HEAD.comm_s")?;
    let comm_hidden_s =
        accounting_array(o.take("comm_hidden_s")?, acct, blob, &mut cursor, "HEAD.comm_hidden_s")?;
    let preempted_s =
        accounting_array(o.take("preempted_s")?, acct, blob, &mut cursor, "HEAD.preempted_s")?;
    let vacant_s =
        accounting_array(o.take("vacant_s")?, acct, blob, &mut cursor, "HEAD.vacant_s")?;
    let spawn_count = s_u64(o.take("spawn_count")?, S, "HEAD.spawn_count")?;
    let last_spawn_outer = s_u64(o.take("last_spawn_outer")?, S, "HEAD.last_spawn_outer")?;
    let last_merge_rep = match o.take("last_merge_rep")? {
        JsonValue::Null => None,
        x => Some(s_usize(x, S, "HEAD.last_merge_rep")?),
    };
    let live_rounds_sum = s_u64(o.take("live_rounds_sum")?, S, "HEAD.live_rounds_sum")?;
    let rounds_count = s_u64(o.take("rounds_count")?, S, "HEAD.rounds_count")?;
    let registry = s_array(o.take("registry")?, S, "HEAD.registry")?
        .iter()
        .enumerate()
        .map(|(i, r)| parse_registry_row(r, &format!("HEAD.registry[{i}]")))
        .collect::<IResult<Vec<RegistryRowSnapshot>>>()?;
    let rng = s_rng(o.take("rng")?, S, "HEAD.rng")?;
    let trainers_v = s_array(o.take("trainers")?, S, "HEAD.trainers")?.to_vec();
    o.finish()?;

    let trainers = trainers_v
        .iter()
        .enumerate()
        .map(|(i, t)| parse_trainer(t, &format!("HEAD.trainers[{i}]"), blob, &mut cursor))
        .collect::<IResult<Vec<TrainerSnapshot>>>()?;
    if cursor != blob.len() {
        return Err(corrupt(
            "BLOB",
            format!("{} payload bytes beyond the last declared vector", blob.len() - cursor),
        ));
    }

    Ok(Checkpoint {
        config_name: meta.config_name.clone(),
        config_digest: meta.config_digest,
        outer_step,
        total_samples,
        comm_count,
        comm_bytes,
        comm_wan_bytes,
        overlap_hidden_s,
        clock_times,
        busy_s,
        wait_s,
        comm_s,
        comm_hidden_s,
        preempted_s,
        vacant_s,
        spawn_count,
        last_spawn_outer,
        last_merge_rep,
        live_rounds_sum,
        rounds_count,
        registry,
        rng,
        trainers,
    })
}

fn decode_minimal(meta: &InterchangeMeta, head: &[u8], blob: &[u8]) -> IResult<MinimalCheckpoint> {
    const S: &str = "HEAD";
    let v = parse_json(head, S)?;
    let mut o = StrictObj::new(&v, S, S.into())?;
    let outer_step = s_u64(o.take("outer_step")?, S, "HEAD.outer_step")?;
    let rng = s_rng(o.take("rng")?, S, "HEAD.rng")?;
    let trainers_v = s_array(o.take("trainers")?, S, "HEAD.trainers")?.to_vec();
    o.finish()?;

    let mut cursor = 0usize;
    let mut trainers = Vec::with_capacity(trainers_v.len());
    for (i, tv) in trainers_v.iter().enumerate() {
        let tp = format!("HEAD.trainers[{i}]");
        let mut to = StrictObj::new(tv, S, tp.clone())?;
        let id = s_usize(to.take("id")?, S, &format!("{tp}.id"))?;
        let param_len = s_usize(to.take("param_len")?, S, &format!("{tp}.param_len"))?;
        let workers = s_array(to.take("workers")?, S, &format!("{tp}.workers"))?
            .iter()
            .enumerate()
            .map(|(wi, wv)| {
                let wp = format!("{tp}.workers[{wi}]");
                let mut wo = StrictObj::new(wv, S, wp.clone())?;
                let noise_rng = s_rng(wo.take("noise_rng")?, S, &format!("{wp}.noise_rng"))?;
                let time_rng = s_rng(wo.take("time_rng")?, S, &format!("{wp}.time_rng"))?;
                wo.finish()?;
                Ok(MinimalWorker { noise_rng, time_rng })
            })
            .collect::<IResult<Vec<MinimalWorker>>>()?;
        to.finish()?;
        let params = take_f32s(blob, &mut cursor, param_len, &format!("{tp}.params"))?;
        trainers.push(MinimalTrainer { id, params, workers });
    }
    if cursor != blob.len() {
        return Err(corrupt(
            "BLOB",
            format!("{} payload bytes beyond the last declared vector", blob.len() - cursor),
        ));
    }

    Ok(MinimalCheckpoint {
        config_name: meta.config_name.clone(),
        config_digest: meta.config_digest,
        outer_step,
        rng,
        trainers,
    })
}

/// Decode a v4 container (magic and version already checked by
/// `import_bytes`) into its interchange variant.
pub(crate) fn decode_v4(raw: &[u8]) -> IResult<Interchange> {
    let [meta_b, head_b, blob_b, _end] = split_sections(raw)?;
    let meta = parse_meta(meta_b)?;
    match meta.format {
        InterchangeFormat::Complete => {
            decode_complete(&meta, head_b, blob_b).map(Interchange::Complete)
        }
        InterchangeFormat::Minimal => {
            decode_minimal(&meta, head_b, blob_b).map(Interchange::Minimal)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::sample_checkpoint;
    use super::super::{import_bytes, state_fields, Interchange};
    use super::*;

    #[test]
    fn every_single_bit_flip_is_detected() {
        // the seal's deterministic single-byte guarantee, end to end:
        // flip one bit at EVERY byte offset of a real container and the
        // import must fail typed — no flip may parse, and none may panic
        let bytes = sample_checkpoint().to_bytes();
        for pos in 0..bytes.len() {
            let mut m = bytes.clone();
            m[pos] ^= 1 << (pos % 8);
            assert!(
                import_bytes(&m).is_err(),
                "bit flip at offset {pos}/{} went undetected",
                bytes.len()
            );
        }
    }

    #[test]
    fn truncation_at_every_length_is_detected() {
        // every proper prefix must fail typed (zero panics, zero
        // partial parses) — the in-process version of the kill-anywhere
        // sweep in tests/crash_fault.rs
        let bytes = sample_checkpoint().to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                import_bytes(&bytes[..cut]).is_err(),
                "truncation to {cut}/{} bytes went undetected",
                bytes.len()
            );
        }
    }

    #[test]
    fn section_boundaries_walk_the_layout() {
        let bytes = sample_checkpoint().to_bytes();
        let bounds = section_boundaries(&bytes);
        assert_eq!(bounds[0], 0);
        assert_eq!(*bounds.last().unwrap(), bytes.len());
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "boundaries must be increasing");
        // 3 prologue edges + 4 edges per section + file end, deduped
        assert!(bounds.len() >= 3 + 4 * 4, "got only {} boundaries", bounds.len());
        for &cut in &bounds {
            if cut < bytes.len() {
                assert!(import_bytes(&bytes[..cut]).is_err(), "cut at boundary {cut} parsed");
            }
        }
    }

    #[test]
    fn unknown_field_in_meta_rejected() {
        let cp = sample_checkpoint();
        let meta = JsonValue::obj(vec![
            ("interchange_format", JsonValue::str("complete")),
            ("interchange_format_version", JsonValue::num(VERSION as f64)),
            ("crate_version", JsonValue::str("0.0.0")),
            ("config_name", JsonValue::str("unit")),
            ("config_digest", super::super::u64_json(0)),
            ("surprise", JsonValue::Bool(true)),
        ])
        .to_string();
        let head = JsonValue::obj(state_fields(&cp)).to_string();
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        let err = import_bytes(&bytes).unwrap_err();
        assert_eq!(
            err,
            InterchangeError::UnknownField { context: "META".into(), field: "surprise".into() },
            "{err}"
        );
    }

    #[test]
    fn unknown_field_in_head_rejected() {
        let cp = sample_checkpoint();
        let meta = meta_json(
            InterchangeFormat::Complete,
            &cp.config_name,
            cp.config_digest,
            AccountingEncoding::Hex,
        );
        let mut fields = state_fields(&cp);
        fields.push(("extra_state", JsonValue::num(1.0)));
        let head = JsonValue::obj(fields).to_string();
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        let err = import_bytes(&bytes).unwrap_err();
        assert!(
            matches!(
                &err,
                InterchangeError::UnknownField { context, field }
                    if context == "HEAD" && field == "extra_state"
            ),
            "{err}"
        );
    }

    #[test]
    fn duplicate_field_rejected() {
        // a duplicated key is only consumable once; strict parsing
        // reports the second copy as unknown
        let cp = sample_checkpoint();
        let meta = meta_json(
            InterchangeFormat::Complete,
            &cp.config_name,
            cp.config_digest,
            AccountingEncoding::Hex,
        );
        let mut fields = state_fields(&cp);
        fields.push(("outer_step", super::super::u64_json(99)));
        let head = JsonValue::obj(fields).to_string();
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        let err = import_bytes(&bytes).unwrap_err();
        assert!(
            matches!(&err, InterchangeError::UnknownField { field, .. } if field == "outer_step"),
            "{err}"
        );
    }

    #[test]
    fn meta_version_mismatch_rejected() {
        let cp = sample_checkpoint();
        let meta = JsonValue::obj(vec![
            ("interchange_format", JsonValue::str("complete")),
            ("interchange_format_version", JsonValue::num(7.0)),
            ("crate_version", JsonValue::str("0.0.0")),
            ("config_name", JsonValue::str("unit")),
            ("config_digest", super::super::u64_json(0)),
        ])
        .to_string();
        let head = JsonValue::obj(state_fields(&cp)).to_string();
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        let err = import_bytes(&bytes).unwrap_err();
        assert_eq!(err, InterchangeError::VersionMismatch { found: 7 }, "{err}");
    }

    #[test]
    fn foreign_crate_version_still_loads() {
        // crate_version is informational: files written by other builds
        // of the same interchange version must load
        let cp = sample_checkpoint();
        let meta = JsonValue::obj(vec![
            ("interchange_format", JsonValue::str("complete")),
            ("interchange_format_version", JsonValue::num(VERSION as f64)),
            ("crate_version", JsonValue::str("99.1.0-beta")),
            ("config_name", JsonValue::str(cp.config_name.as_str())),
            ("config_digest", super::super::u64_json(cp.config_digest)),
        ])
        .to_string();
        let head = JsonValue::obj(state_fields(&cp)).to_string();
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        match import_bytes(&bytes).unwrap() {
            Interchange::Complete(back) => assert_eq!(back, cp),
            other => panic!("expected complete variant, got {other:?}"),
        }
    }

    #[test]
    fn raw_and_hex_accounting_decode_identically() {
        // the raw64le writer and the legacy hex writer must produce
        // bit-identical checkpoints on import — encoding is a container
        // concern, never a state one
        let cp = sample_checkpoint();
        let raw_bytes = encode_complete_with(&cp, AccountingEncoding::Raw);
        let hex_bytes = encode_complete_with(&cp, AccountingEncoding::Hex);
        assert!(
            raw_bytes.len() < hex_bytes.len(),
            "raw64le should be smaller ({} vs {} bytes)",
            raw_bytes.len(),
            hex_bytes.len()
        );
        let from_raw = match import_bytes(&raw_bytes).unwrap() {
            Interchange::Complete(c) => c,
            other => panic!("expected complete, got {other:?}"),
        };
        let from_hex = match import_bytes(&hex_bytes).unwrap() {
            Interchange::Complete(c) => c,
            other => panic!("expected complete, got {other:?}"),
        };
        assert_eq!(from_raw, cp);
        assert_eq!(from_hex, cp);
    }

    #[test]
    fn meta_without_accounting_flag_defaults_to_hex() {
        // pre-PR-8 v4 files carry no accounting_encoding field and hex
        // arrays in HEAD: they must keep importing unchanged
        let cp = sample_checkpoint();
        let meta = JsonValue::obj(vec![
            ("interchange_format", JsonValue::str("complete")),
            ("interchange_format_version", JsonValue::num(VERSION as f64)),
            ("crate_version", JsonValue::str("0.0.0")),
            ("config_name", JsonValue::str(cp.config_name.as_str())),
            ("config_digest", super::super::u64_json(cp.config_digest)),
        ])
        .to_string();
        let head = JsonValue::obj(state_fields(&cp)).to_string();
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        match import_bytes(&bytes).unwrap() {
            Interchange::Complete(back) => assert_eq!(back, cp),
            other => panic!("expected complete variant, got {other:?}"),
        }
    }

    #[test]
    fn unknown_accounting_encoding_rejected() {
        let cp = sample_checkpoint();
        let meta = JsonValue::obj(vec![
            ("interchange_format", JsonValue::str("complete")),
            ("interchange_format_version", JsonValue::num(VERSION as f64)),
            ("crate_version", JsonValue::str("0.0.0")),
            ("config_name", JsonValue::str("unit")),
            ("config_digest", super::super::u64_json(0)),
            ("accounting_encoding", JsonValue::str("base85")),
        ])
        .to_string();
        let head = JsonValue::obj(state_fields(&cp)).to_string();
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        let err = import_bytes(&bytes).unwrap_err();
        assert!(
            matches!(&err, InterchangeError::Corrupt { section, detail }
                if section == "META" && detail.contains("accounting_encoding")),
            "{err}"
        );
    }

    #[test]
    fn raw_accounting_short_blob_rejected() {
        // a raw64le HEAD declaring more accounting elements than the
        // BLOB prefix carries must fail typed in BLOB, not panic
        let cp = sample_checkpoint();
        let meta = meta_json(
            InterchangeFormat::Complete,
            &cp.config_name,
            cp.config_digest,
            AccountingEncoding::Raw,
        );
        let head = JsonValue::obj(state_fields_with(&cp, true)).to_string();
        // blob deliberately missing the accounting prefix entirely,
        // while HEAD declares non-empty arrays
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob_bytes(&cp));
        match import_bytes(&bytes) {
            Err(InterchangeError::Corrupt { section, .. }) => assert_eq!(section, "BLOB"),
            // with small checkpoints the misaligned read can also
            // surface as the end-of-blob length check
            Err(other) => {
                panic!("expected a typed Corrupt error, got {other}")
            }
            Ok(_) => panic!("short raw accounting blob must not import"),
        }
    }

    #[test]
    fn every_raw_bit_flip_is_detected() {
        // the seal guarantee holds for the raw64le layout too
        let bytes = encode_complete_with(&sample_checkpoint(), AccountingEncoding::Raw);
        for pos in (0..bytes.len()).step_by(7) {
            let mut m = bytes.clone();
            m[pos] ^= 1 << (pos % 8);
            assert!(import_bytes(&m).is_err(), "bit flip at offset {pos} went undetected");
        }
    }

    #[test]
    fn blob_length_mismatch_rejected() {
        // a header that declares less payload than BLOB carries must
        // not silently ignore the excess
        let cp = sample_checkpoint();
        let meta = meta_json(
            InterchangeFormat::Complete,
            &cp.config_name,
            cp.config_digest,
            AccountingEncoding::Hex,
        );
        let head = JsonValue::obj(state_fields(&cp)).to_string();
        let mut blob = blob_bytes(&cp);
        blob.extend_from_slice(&[0u8; 4]);
        let bytes = container(meta.as_bytes(), head.as_bytes(), &blob);
        let err = import_bytes(&bytes).unwrap_err();
        assert!(
            matches!(&err, InterchangeError::Corrupt { section, .. } if section == "BLOB"),
            "{err}"
        );
    }
}
