//! Checkpointing: serialize / restore the trainer pool mid-run.
//!
//! A production distributed trainer must survive restarts; this module
//! gives the coordinator durable snapshots of **everything the run
//! needs to continue bit-for-bit**: per-trainer outer parameters and
//! outer-momentum, per-worker model + AdamW state, every stochastic
//! stream (the coordinator RNG and each worker's noise/time/sampler
//! streams, mid-sequence), each sampler's epoch position, the adaptive
//! controller's full statistics, the cluster's per-slot time
//! accounting, the communication counters, and any delayed-overlap
//! collective still in flight (DESIGN.md §8).
//!
//! The on-disk story is the **versioned interchange** (DESIGN.md §10):
//! the current container is v4 — a sectioned, FNV-sealed layout with a
//! format-metadata header and strict (`deny_unknown_fields`-style)
//! parsing, in two variants: *complete* (exact resume — everything
//! above) and *minimal* (parameters + RNG states, enough to warm-start
//! a fresh schedule). See [`interchange`] for the byte layout and
//! [`legacy`] for the v1/v2/v3 importers; every historical version
//! still loads through [`import_bytes`]. Damage never resumes
//! silently: truncation and bit flips surface as typed
//! [`InterchangeError`]s (`tests/crash_fault.rs` proves this at every
//! section boundary and under sampled byte corruption).
//!
//! Every 64-bit quantity that must restore bit-exactly — RNG words,
//! wide counters (samples/bytes/draws), and all f64 state — is a hex
//! string in the JSON headers: JSON numbers are f64, which would round
//! counters above 2^53 and turn a non-finite f64 into an unloadable
//! `null`. Small structural integers (ids, lengths, cursors) stay
//! plain numbers for readability.
//!
//! Resume contract (enforced by `tests/checkpoint_resume.rs`): a run
//! resumed from a complete checkpoint taken at outer step k produces,
//! from step k+1 on, the **bit-identical** record streams, ledger
//! continuation and final `RunResult` payload of the uninterrupted run
//! — on both schedulers, at any thread count, and under the
//! delayed-overlap mode.

use crate::util::JsonValue;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

pub mod interchange;
pub mod legacy;
pub mod retention;

pub use interchange::{
    section_boundaries, AccountingEncoding, InterchangeError, InterchangeFormat, InterchangeMeta,
};

/// File magic of the checkpoint container format (all versions).
pub const MAGIC: &[u8; 4] = b"ADLC";
/// Container format version (1 = the minimal params+RNG warm-start
/// layout; 2 = exact-resume: stream states, sampler positions,
/// controller statistics, time accounting, in-flight syncs; 3 = the
/// elastic lifecycle, DESIGN.md §9: the instance registry, spawn
/// bookkeeping, vacancy and round-census accounting; 4 = the sectioned
/// interchange, DESIGN.md §10: format-metadata header, per-section
/// FNV seals, strict parsing, minimal/complete variants).
pub const VERSION: u32 = 4;

/// A captured RNG stream (`Rng::state`): the four xoshiro words plus
/// the cached Box-Muller spare.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RngSnapshot {
    /// xoshiro256** state words.
    pub s: [u64; 4],
    /// Cached second Box-Muller output, if one is pending.
    pub gauss_spare: Option<f64>,
}

impl RngSnapshot {
    /// Capture a live stream.
    pub fn of(rng: &crate::util::Rng) -> RngSnapshot {
        let (s, gauss_spare) = rng.state();
        RngSnapshot { s, gauss_spare }
    }

    /// Rebuild the live stream.
    pub fn to_rng(&self) -> crate::util::Rng {
        crate::util::Rng::from_state(self.s, self.gauss_spare)
    }
}

/// A captured sampler position (`BatchSampler::export_state`).
#[derive(Clone, Debug, PartialEq)]
pub struct SamplerSnapshot {
    /// Shard sequence indices.
    pub shard: Vec<usize>,
    /// Current epoch's shuffled order.
    pub order: Vec<usize>,
    /// Cursor into `order`.
    pub cursor: usize,
    /// Total draws since construction.
    pub drawn: u64,
    /// Shuffle stream.
    pub rng: RngSnapshot,
}

/// One ledger phase of an in-flight collective (scope + closed-form
/// bytes + participant count) — enough to land the exact `CommEvent`s
/// when the resumed run retires the handle.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSnapshot {
    /// True for the WAN tier, false for intra-group.
    pub wan: bool,
    /// Ledger bytes of the phase.
    pub bytes: u64,
    /// Phase participant count.
    pub participants: usize,
}

/// A delayed-overlap outer update still in flight at snapshot time
/// (DESIGN.md §8): the priced collective plus the frozen delta it will
/// apply at the trainer's next outer boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct PendingSnapshot {
    /// Virtual time the last contribution was posted.
    pub posted_at: f64,
    /// Virtual time the transfer completes.
    pub completes_at: f64,
    /// Modeled transfer seconds (the hidden/exposed split's total).
    pub time_s: f64,
    /// `total_samples` at post time (the ledger's C(N) stamp).
    pub sent_samples: u64,
    /// Ledger phases to land at completion.
    pub phases: Vec<PhaseSnapshot>,
    /// The frozen outer delta.
    pub delta: Vec<f32>,
}

/// Snapshot of one worker's optimizer state and streams.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSnapshot {
    /// Worker parameter vector.
    pub params: Vec<f32>,
    /// AdamW first moments.
    pub m: Vec<f32>,
    /// AdamW second moments.
    pub v: Vec<f32>,
    /// Optimizer step counter.
    pub step: u64,
    /// Churn activity flag at snapshot time.
    pub active: bool,
    /// Engine gradient/loss noise stream, mid-sequence.
    pub noise_rng: RngSnapshot,
    /// Compute-time perturbation stream, mid-sequence.
    pub time_rng: RngSnapshot,
    /// Data sampler position, mid-epoch.
    pub sampler: SamplerSnapshot,
}

/// Snapshot of one live trainer.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainerSnapshot {
    /// Trainer id (position in the coordinator's pool).
    pub id: usize,
    /// Outer parameter vector.
    pub params: Vec<f32>,
    /// Outer-optimizer momentum buffer (empty for Average/Sgd).
    pub outer_velocity: Vec<f32>,
    /// Adaptive controller's requested batch.
    pub requested_batch: usize,
    /// Inner steps completed by this trainer.
    pub inner_steps_done: u64,
    /// Controller observation count.
    pub observations: u64,
    /// `(value, steps)` of the controller's sigma² EMA.
    pub sigma2_ema: (f64, u64),
    /// `(value, steps)` of the controller's inner-product EMA.
    pub ip_var_ema: (f64, u64),
    /// `(value, steps)` of the controller's gradient-norm EMA.
    pub s1_ema: (f64, u64),
    /// The trainer-level shard (workers partition it; churn re-splits).
    pub shard: Vec<usize>,
    /// Delayed-overlap update in flight, if any.
    pub pending: Option<PendingSnapshot>,
    /// Per-worker optimizer state.
    pub workers: Vec<WorkerSnapshot>,
}

/// One instance-registry row (DESIGN.md §9): lifecycle metadata plus
/// the structural facts — worker node/clock-slot assignments — needed
/// to rebuild instances that did not exist at config time. Rows cover
/// *every* instance that ever existed (retired ones included), so a
/// resumed pool reproduces the uninterrupted run's indices and
/// utilization rows exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct RegistryRowSnapshot {
    /// Stable instance id (position in the trainer pool).
    pub id: usize,
    /// Lifecycle state name (`instances::LifecycleState::as_str`).
    pub state: String,
    /// Origin name (`instances::Origin::as_str`).
    pub origin: String,
    /// Outer step the instance joined the pool (0 for seed instances).
    pub born_outer: u64,
    /// Virtual time the instance joined (0.0 for seed instances) — the
    /// vacancy-reclamation anchor (DESIGN.md §9).
    pub born_at_s: f64,
    /// Outer step a merge retired it, if any.
    pub retired_outer: Option<u64>,
    /// (node, clock_slot) of each worker, in worker order.
    pub workers: Vec<(usize, usize)>,
}

/// A full coordinator snapshot (the *complete* interchange variant —
/// everything exact resume reads).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Checkpoint {
    /// Name of the config that produced the snapshot.
    pub config_name: String,
    /// `Config::structural_digest` of the producing config (0 when
    /// unknown — hand-built snapshots and pre-v4 imports). Resume
    /// refuses a nonzero digest that does not match the running config.
    pub config_digest: u64,
    /// Outer step the snapshot was taken after.
    pub outer_step: u64,
    /// Samples consumed so far.
    pub total_samples: u64,
    /// Ledger communication count at snapshot time.
    pub comm_count: u64,
    /// Ledger communication bytes at snapshot time.
    pub comm_bytes: u64,
    /// Ledger WAN-tier bytes at snapshot time.
    pub comm_wan_bytes: u64,
    /// Overlap-hidden collective seconds accumulated so far.
    pub overlap_hidden_s: f64,
    /// Per-slot virtual clock times.
    pub clock_times: Vec<f64>,
    /// Per-slot compute seconds.
    pub busy_s: Vec<f64>,
    /// Per-slot barrier-wait seconds.
    pub wait_s: Vec<f64>,
    /// Per-slot exposed communication seconds.
    pub comm_s: Vec<f64>,
    /// Per-slot overlap-hidden communication seconds.
    pub comm_hidden_s: Vec<f64>,
    /// Per-slot churn-preemption seconds.
    pub preempted_s: Vec<f64>,
    /// Per-slot vacant capacity seconds (DESIGN.md §9).
    pub vacant_s: Vec<f64>,
    /// Instances spawned so far (the registry's spawn ledger).
    pub spawn_count: u64,
    /// Outer step of the most recent spawn round (0 = never) — the
    /// spawn controller's cooldown anchor.
    pub last_spawn_outer: u64,
    /// Representative of the most recent merge, if any (future spawns
    /// seed their parameters from it).
    pub last_merge_rep: Option<usize>,
    /// Σ live instances over the rounds driven so far (the
    /// `mean_live_instances` numerator; resumed runs must report the
    /// uninterrupted value).
    pub live_rounds_sum: u64,
    /// Rounds driven so far (the denominator).
    pub rounds_count: u64,
    /// The full instance registry, one row per instance that ever
    /// existed (empty only in hand-written headers; `snapshot` always
    /// fills it).
    pub registry: Vec<RegistryRowSnapshot>,
    /// The coordinator's own stream (merge selection forks, churn
    /// re-shard forks), mid-sequence.
    pub rng: RngSnapshot,
    /// Live trainers (dead ones are omitted).
    pub trainers: Vec<TrainerSnapshot>,
}

/// One trainer of the *minimal* interchange variant: the outer
/// parameters plus the per-worker stochastic streams — enough to
/// warm-start a fresh schedule from a trained model, not enough for
/// exact resume.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct MinimalTrainer {
    /// Trainer id (position in the coordinator's pool).
    pub id: usize,
    /// Outer parameter vector (workers warm-start from it too).
    pub params: Vec<f32>,
    /// Per-worker RNG states, in worker order.
    pub workers: Vec<MinimalWorker>,
}

/// Per-worker RNG states of the minimal variant.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct MinimalWorker {
    /// Engine gradient/loss noise stream.
    pub noise_rng: RngSnapshot,
    /// Compute-time perturbation stream.
    pub time_rng: RngSnapshot,
}

/// The *minimal* interchange variant (params + RNG states): what the
/// v1 container carried, and what `Checkpoint::to_minimal` strips a
/// full snapshot down to. Loading one warm-starts a fresh run
/// (`Coordinator::warm_start`) instead of exact-resuming it.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct MinimalCheckpoint {
    /// Name of the config that produced the snapshot.
    pub config_name: String,
    /// `Config::structural_digest` of the producing config (0 when
    /// unknown). Warm-start across configs is legal, so a mismatch
    /// only logs — it does not refuse the load.
    pub config_digest: u64,
    /// Outer step the snapshot was taken after.
    pub outer_step: u64,
    /// The coordinator's own stream.
    pub rng: RngSnapshot,
    /// Per-trainer parameters and streams.
    pub trainers: Vec<MinimalTrainer>,
}

/// A parsed interchange file: either variant, any container version.
#[derive(Clone, Debug, PartialEq)]
pub enum Interchange {
    /// Exact-resume payload (container v2/v3/v4-complete).
    Complete(Checkpoint),
    /// Warm-start payload (container v1 / v4-minimal).
    Minimal(MinimalCheckpoint),
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE) — the pre-v4 trailer integrity check; kept for the
// legacy importers. v4 seals with FNV-1a instead (util::fnv1a).
// ---------------------------------------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    for (i, e) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *e = c;
    }
    table
}

/// CRC32 (IEEE) of `data` — the v1/v2/v3 trailer integrity check.
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// encoding helpers (shared by the v4 writer and the legacy exporters)
// ---------------------------------------------------------------------------

pub(crate) fn f32s_to_bytes(v: &[f32], out: &mut Vec<u8>) {
    out.reserve(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn bytes_to_f32s(raw: &[u8]) -> Vec<f32> {
    raw.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

pub(crate) fn f64s_to_bytes(v: &[f64], out: &mut Vec<u8>) {
    out.reserve(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn bytes_to_f64s(raw: &[u8]) -> Vec<f64> {
    raw.chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

pub(crate) fn usizes_json(v: &[usize]) -> JsonValue {
    JsonValue::Array(v.iter().map(|&x| JsonValue::num(x as f64)).collect())
}

/// Bit-exact f64: raw bits as a hex string (survives non-finite values
/// and never depends on decimal round-tripping).
pub(crate) fn f64_json(x: f64) -> JsonValue {
    JsonValue::str(format!("{:016x}", x.to_bits()))
}

/// Exact u64: hex string (JSON numbers are f64 and round above 2^53).
pub(crate) fn u64_json(x: u64) -> JsonValue {
    JsonValue::str(format!("{x:016x}"))
}

pub(crate) fn f64s_json(v: &[f64]) -> JsonValue {
    JsonValue::Array(v.iter().map(|&x| f64_json(x)).collect())
}

pub(crate) fn rng_json(r: &RngSnapshot) -> JsonValue {
    JsonValue::obj(vec![
        (
            "s",
            JsonValue::Array(
                r.s.iter().map(|&w| JsonValue::str(format!("{w:016x}"))).collect(),
            ),
        ),
        (
            "spare",
            match r.gauss_spare {
                // bit-exact: store the f64's raw bits in hex
                Some(x) => JsonValue::str(format!("{:016x}", x.to_bits())),
                None => JsonValue::Null,
            },
        ),
    ])
}

pub(crate) fn ema_json(e: (f64, u64)) -> JsonValue {
    JsonValue::obj(vec![("value", f64_json(e.0)), ("steps", u64_json(e.1))])
}

/// The state fields shared by the v3 header and the v4 HEAD section
/// (v3 additionally leads with `config_name`; v4 moves identity into
/// the META section). Hex accounting arrays — what the legacy exporter
/// and pre-PR-8 v4 files carry.
pub(crate) fn state_fields(cp: &Checkpoint) -> Vec<(&'static str, JsonValue)> {
    state_fields_with(cp, false)
}

/// `state_fields` with a choice of accounting-array encoding: inline
/// per-f64 hex strings (`raw_accounting = false`), or just the element
/// counts, with the raw little-endian f64 bytes prepended to the BLOB
/// section by the writer (`raw_accounting = true` — the v4 `raw64le`
/// META flag; exact and ~4.5x smaller per element than hex-in-JSON).
pub(crate) fn state_fields_with(
    cp: &Checkpoint,
    raw_accounting: bool,
) -> Vec<(&'static str, JsonValue)> {
    let acct = |v: &[f64]| {
        if raw_accounting {
            JsonValue::num(v.len() as f64)
        } else {
            f64s_json(v)
        }
    };
    vec![
        ("outer_step", u64_json(cp.outer_step)),
        ("total_samples", u64_json(cp.total_samples)),
        ("comm_count", u64_json(cp.comm_count)),
        ("comm_bytes", u64_json(cp.comm_bytes)),
        ("comm_wan_bytes", u64_json(cp.comm_wan_bytes)),
        ("overlap_hidden_s", f64_json(cp.overlap_hidden_s)),
        ("clock_times", acct(&cp.clock_times)),
        ("busy_s", acct(&cp.busy_s)),
        ("wait_s", acct(&cp.wait_s)),
        ("comm_s", acct(&cp.comm_s)),
        ("comm_hidden_s", acct(&cp.comm_hidden_s)),
        ("preempted_s", acct(&cp.preempted_s)),
        ("vacant_s", acct(&cp.vacant_s)),
        ("spawn_count", u64_json(cp.spawn_count)),
        ("last_spawn_outer", u64_json(cp.last_spawn_outer)),
        (
            "last_merge_rep",
            match cp.last_merge_rep {
                Some(r) => JsonValue::num(r as f64),
                None => JsonValue::Null,
            },
        ),
        ("live_rounds_sum", u64_json(cp.live_rounds_sum)),
        ("rounds_count", u64_json(cp.rounds_count)),
        (
            "registry",
            JsonValue::Array(cp.registry.iter().map(registry_row_json).collect()),
        ),
        ("rng", rng_json(&cp.rng)),
        (
            "trainers",
            JsonValue::Array(cp.trainers.iter().map(trainer_json).collect()),
        ),
    ]
}

fn registry_row_json(r: &RegistryRowSnapshot) -> JsonValue {
    JsonValue::obj(vec![
        ("id", JsonValue::num(r.id as f64)),
        ("state", JsonValue::str(r.state.clone())),
        ("origin", JsonValue::str(r.origin.clone())),
        ("born_outer", u64_json(r.born_outer)),
        ("born_at_s", f64_json(r.born_at_s)),
        (
            "retired_outer",
            match r.retired_outer {
                Some(t) => u64_json(t),
                None => JsonValue::Null,
            },
        ),
        (
            "workers",
            JsonValue::Array(
                r.workers
                    .iter()
                    .map(|&(n, s)| {
                        JsonValue::Array(vec![
                            JsonValue::num(n as f64),
                            JsonValue::num(s as f64),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

pub(crate) fn trainer_json(t: &TrainerSnapshot) -> JsonValue {
    let pending = match &t.pending {
        None => JsonValue::Null,
        Some(p) => JsonValue::obj(vec![
            ("posted_at", f64_json(p.posted_at)),
            ("completes_at", f64_json(p.completes_at)),
            ("time_s", f64_json(p.time_s)),
            ("sent_samples", u64_json(p.sent_samples)),
            ("delta_len", JsonValue::num(p.delta.len() as f64)),
            (
                "phases",
                JsonValue::Array(
                    p.phases
                        .iter()
                        .map(|ph| {
                            JsonValue::obj(vec![
                                ("wan", JsonValue::Bool(ph.wan)),
                                ("bytes", u64_json(ph.bytes)),
                                ("participants", JsonValue::num(ph.participants as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    };
    JsonValue::obj(vec![
        ("id", JsonValue::num(t.id as f64)),
        ("param_len", JsonValue::num(t.params.len() as f64)),
        ("velocity_len", JsonValue::num(t.outer_velocity.len() as f64)),
        ("requested_batch", JsonValue::num(t.requested_batch as f64)),
        ("inner_steps_done", u64_json(t.inner_steps_done)),
        ("observations", u64_json(t.observations)),
        ("sigma2_ema", ema_json(t.sigma2_ema)),
        ("ip_var_ema", ema_json(t.ip_var_ema)),
        ("s1_ema", ema_json(t.s1_ema)),
        ("shard", usizes_json(&t.shard)),
        ("pending", pending),
        (
            "workers",
            JsonValue::Array(
                t.workers
                    .iter()
                    .map(|w| {
                        JsonValue::obj(vec![
                            ("param_len", JsonValue::num(w.params.len() as f64)),
                            ("step", u64_json(w.step)),
                            ("active", JsonValue::Bool(w.active)),
                            ("noise_rng", rng_json(&w.noise_rng)),
                            ("time_rng", rng_json(&w.time_rng)),
                            (
                                "sampler",
                                JsonValue::obj(vec![
                                    ("shard", usizes_json(&w.sampler.shard)),
                                    ("order", usizes_json(&w.sampler.order)),
                                    ("cursor", JsonValue::num(w.sampler.cursor as f64)),
                                    ("drawn", u64_json(w.sampler.drawn)),
                                    ("rng", rng_json(&w.sampler.rng)),
                                ]),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The raw f32 payload, in header order: per trainer — params,
/// outer_velocity, the pending delta if one is in flight, then per
/// worker params/m/v. Identical across v2, v3 and the v4 BLOB section.
pub(crate) fn blob_bytes(cp: &Checkpoint) -> Vec<u8> {
    let mut out = Vec::new();
    for t in &cp.trainers {
        f32s_to_bytes(&t.params, &mut out);
        f32s_to_bytes(&t.outer_velocity, &mut out);
        if let Some(p) = &t.pending {
            f32s_to_bytes(&p.delta, &mut out);
        }
        for w in &t.workers {
            f32s_to_bytes(&w.params, &mut out);
            f32s_to_bytes(&w.m, &mut out);
            f32s_to_bytes(&w.v, &mut out);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// tolerant decoding helpers (the legacy importers; the v4 path uses the
// strict reader in `interchange`)
// ---------------------------------------------------------------------------

/// A u64 field: exact hex string, or a plain number for the small
/// structural integers (ids, lengths, cursors).
pub(crate) fn get_u64(v: &JsonValue, k: &str) -> Result<u64> {
    let x = v.get(k).ok_or_else(|| anyhow!("checkpoint header missing {k}"))?;
    if let Some(s) = x.as_str() {
        return parse_hex_u64(s);
    }
    x.as_f64()
        .map(|n| n as u64)
        .ok_or_else(|| anyhow!("checkpoint header field {k} is not an integer"))
}

/// An f64 field: bit-exact hex string (the v2+ form), or a plain number
/// (tolerated for hand-written headers).
pub(crate) fn get_f64(v: &JsonValue, k: &str) -> Result<f64> {
    let x = v.get(k).ok_or_else(|| anyhow!("checkpoint header missing {k}"))?;
    if let Some(s) = x.as_str() {
        return Ok(f64::from_bits(parse_hex_u64(s)?));
    }
    x.as_f64().ok_or_else(|| anyhow!("checkpoint header field {k} is not a number"))
}

pub(crate) fn parse_hex_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex word {s:?}"))
}

pub(crate) fn parse_usizes(v: &JsonValue, k: &str) -> Result<Vec<usize>> {
    v.get(k)
        .and_then(|x| x.as_array())
        .ok_or_else(|| anyhow!("checkpoint header missing {k}"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("non-integer entry in {k}")))
        .collect()
}

pub(crate) fn parse_f64s(v: &JsonValue, k: &str) -> Result<Vec<f64>> {
    v.get(k)
        .and_then(|x| x.as_array())
        .ok_or_else(|| anyhow!("checkpoint header missing {k}"))?
        .iter()
        .map(|x| {
            if let Some(s) = x.as_str() {
                return Ok(f64::from_bits(parse_hex_u64(s)?));
            }
            x.as_f64().ok_or_else(|| anyhow!("non-number entry in {k}"))
        })
        .collect()
}

pub(crate) fn parse_rng(v: &JsonValue, k: &str) -> Result<RngSnapshot> {
    let r = v.get(k).ok_or_else(|| anyhow!("checkpoint header missing {k}"))?;
    let words = r
        .get("s")
        .and_then(|x| x.as_array())
        .ok_or_else(|| anyhow!("{k}: missing rng words"))?;
    if words.len() != 4 {
        bail!("{k}: expected 4 rng words, got {}", words.len());
    }
    let mut s = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        s[i] = parse_hex_u64(w.as_str().ok_or_else(|| anyhow!("{k}: rng word not a string"))?)?;
    }
    let gauss_spare = match r.get("spare") {
        Some(JsonValue::Null) | None => None,
        Some(x) => Some(f64::from_bits(parse_hex_u64(
            x.as_str().ok_or_else(|| anyhow!("{k}: spare not a string"))?,
        )?)),
    };
    Ok(RngSnapshot { s, gauss_spare })
}

pub(crate) fn parse_ema(v: &JsonValue, k: &str) -> Result<(f64, u64)> {
    let e = v.get(k).ok_or_else(|| anyhow!("checkpoint header missing {k}"))?;
    Ok((get_f64(e, "value")?, get_u64(e, "steps")?))
}

// ---------------------------------------------------------------------------
// the public container API
// ---------------------------------------------------------------------------

/// Parse any supported container version into its interchange variant:
/// v4 dispatches on the META `interchange_format`; v2/v3 import as
/// complete, v1 as minimal. Every failure is a typed
/// [`InterchangeError`] — damaged bytes never parse partially.
pub fn import_bytes(raw: &[u8]) -> std::result::Result<Interchange, InterchangeError> {
    if raw.len() < 8 {
        return Err(InterchangeError::Truncated {
            section: "prologue".into(),
            needed: 8,
            have: raw.len(),
        });
    }
    if &raw[0..4] != MAGIC {
        return Err(InterchangeError::Corrupt {
            section: "magic".into(),
            detail: format!("bad checkpoint magic {:?}", &raw[0..4]),
        });
    }
    let version = u32::from_le_bytes(raw[4..8].try_into().unwrap());
    match version {
        4 => interchange::decode_v4(raw),
        3 => legacy::import_v3(raw).map(Interchange::Complete),
        2 => legacy::import_v2(raw).map(Interchange::Complete),
        1 => legacy::import_v1(raw).map(Interchange::Minimal),
        v => Err(InterchangeError::VersionMismatch { found: v }),
    }
}

/// Read and verify an interchange file of any supported version.
pub fn load_interchange(path: &str) -> Result<Interchange> {
    let mut raw = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {path}"))?
        .read_to_end(&mut raw)?;
    import_bytes(&raw).with_context(|| format!("loading checkpoint {path}"))
}

fn save_bytes(path: &str, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    // write-then-rename for crash safety
    let tmp = format!("{path}.tmp");
    let mut f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp}"))?;
    f.write_all(bytes)?;
    f.sync_all().ok();
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp} -> {path}"))?;
    Ok(())
}

impl Checkpoint {
    /// Serialize to the v4 *complete* container (see [`interchange`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        interchange::encode_complete(self)
    }

    /// Parse and verify a serialized checkpoint of any supported
    /// version, requiring the exact-resume (complete) variant.
    pub fn from_bytes(raw: &[u8]) -> Result<Checkpoint> {
        match import_bytes(raw) {
            Ok(Interchange::Complete(cp)) => Ok(cp),
            Ok(Interchange::Minimal(_)) => bail!(
                "checkpoint is a minimal (warm-start) interchange; exact resume \
                 requires a complete checkpoint"
            ),
            Err(e) => Err(anyhow::Error::new(e)),
        }
    }

    /// Strip down to the minimal (warm-start) variant: outer params +
    /// RNG states. Everything else — optimizer moments, samplers,
    /// controller statistics, time accounting — is dropped.
    pub fn to_minimal(&self) -> MinimalCheckpoint {
        MinimalCheckpoint {
            config_name: self.config_name.clone(),
            config_digest: self.config_digest,
            outer_step: self.outer_step,
            rng: self.rng.clone(),
            trainers: self
                .trainers
                .iter()
                .map(|t| MinimalTrainer {
                    id: t.id,
                    params: t.params.clone(),
                    workers: t
                        .workers
                        .iter()
                        .map(|w| MinimalWorker {
                            noise_rng: w.noise_rng.clone(),
                            time_rng: w.time_rng.clone(),
                        })
                        .collect(),
                })
                .collect(),
        }
    }

    /// Write the checkpoint to `path` (write-then-rename, crash-safe).
    pub fn save(&self, path: &str) -> Result<()> {
        save_bytes(path, &self.to_bytes())
    }

    /// Read and verify a complete checkpoint from `path`.
    pub fn load(path: &str) -> Result<Checkpoint> {
        let mut raw = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path}"))?
            .read_to_end(&mut raw)?;
        Self::from_bytes(&raw).with_context(|| format!("loading checkpoint {path}"))
    }
}

impl MinimalCheckpoint {
    /// Serialize to the v4 *minimal* container.
    pub fn to_bytes(&self) -> Vec<u8> {
        interchange::encode_minimal(self)
    }

    /// Write the minimal checkpoint to `path` (write-then-rename).
    pub fn save(&self, path: &str) -> Result<()> {
        save_bytes(path, &self.to_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rng_snap(seed: u64, with_spare: bool) -> RngSnapshot {
        let mut r = Rng::new(seed);
        if with_spare {
            let _ = r.normal(); // populate the Box-Muller spare
        }
        RngSnapshot::of(&r)
    }

    pub(super) fn sample_checkpoint() -> Checkpoint {
        let mut rng = Rng::new(3);
        let mk = |n: usize, rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        let sampler = |seed: u64| SamplerSnapshot {
            shard: vec![3, 1, 4, 1, 5, 9],
            order: vec![2, 0, 5, 1, 4, 3],
            cursor: 3,
            drawn: 21,
            rng: rng_snap(seed, false),
        };
        let worker = |rng: &mut Rng, seed: u64| WorkerSnapshot {
            params: mk(64, rng),
            m: mk(64, rng),
            v: mk(64, rng),
            step: 140,
            active: seed % 2 == 0,
            noise_rng: rng_snap(seed, true),
            time_rng: rng_snap(seed ^ 7, false),
            sampler: sampler(seed ^ 99),
        };
        Checkpoint {
            config_name: "unit".into(),
            config_digest: 0x1234_5678_9abc_def0,
            outer_step: 7,
            total_samples: 12345,
            comm_count: 42,
            comm_bytes: 9876,
            comm_wan_bytes: 5432,
            overlap_hidden_s: 0.125625,
            clock_times: vec![1.5, 2.25, 0.0],
            busy_s: vec![1.0, 2.0, 0.5],
            wait_s: vec![0.25, 0.0, 0.75],
            comm_s: vec![0.01, 0.02, 0.03],
            comm_hidden_s: vec![0.001, 0.0, 0.002],
            preempted_s: vec![0.0, 0.5, 0.0],
            vacant_s: vec![0.0, 0.0, 1.25],
            spawn_count: 1,
            last_spawn_outer: 5,
            last_merge_rep: Some(2),
            live_rounds_sum: 17,
            rounds_count: 7,
            registry: vec![
                RegistryRowSnapshot {
                    id: 0,
                    state: "active".into(),
                    origin: "seed".into(),
                    born_outer: 0,
                    born_at_s: 0.0,
                    retired_outer: None,
                    workers: vec![(0, 0), (1, 1)],
                },
                RegistryRowSnapshot {
                    id: 1,
                    state: "retired".into(),
                    origin: "seed".into(),
                    born_outer: 0,
                    born_at_s: 0.0,
                    retired_outer: Some(4),
                    workers: vec![(1, 2)],
                },
                RegistryRowSnapshot {
                    id: 2,
                    state: "spawned".into(),
                    origin: "util".into(),
                    born_outer: 5,
                    born_at_s: 7.25,
                    retired_outer: None,
                    workers: vec![(3, 3)],
                },
            ],
            rng: rng_snap(11, true),
            trainers: vec![
                TrainerSnapshot {
                    id: 0,
                    params: mk(64, &mut rng),
                    outer_velocity: mk(64, &mut rng),
                    requested_batch: 17,
                    inner_steps_done: 140,
                    observations: 280,
                    sigma2_ema: (1.2345678901234567, 280),
                    ip_var_ema: (0.0, 0),
                    s1_ema: (9.87e-3, 280),
                    shard: vec![0, 2, 4, 6, 8, 10],
                    pending: Some(PendingSnapshot {
                        posted_at: 3.5,
                        completes_at: 3.502,
                        time_s: 0.002,
                        sent_samples: 12000,
                        phases: vec![
                            PhaseSnapshot { wan: false, bytes: 4000, participants: 2 },
                            PhaseSnapshot { wan: true, bytes: 2000, participants: 2 },
                        ],
                        delta: mk(64, &mut rng),
                    }),
                    workers: vec![worker(&mut rng, 2), worker(&mut rng, 5)],
                },
                TrainerSnapshot {
                    id: 2,
                    params: mk(64, &mut rng),
                    outer_velocity: vec![],
                    requested_batch: 3,
                    inner_steps_done: 140,
                    observations: 140,
                    sigma2_ema: (0.5, 140),
                    ip_var_ema: (0.25, 140),
                    s1_ema: (0.125, 140),
                    shard: vec![1, 3, 5],
                    pending: None,
                    workers: vec![worker(&mut rng, 8)],
                },
            ],
        }
    }

    #[test]
    fn roundtrip_exact() {
        let cp = sample_checkpoint();
        let bytes = cp.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(cp, back);
    }

    #[test]
    fn rng_snapshot_roundtrips_bit_exact() {
        // hex words + bit-hex spare must survive the JSON header exactly
        let cp = sample_checkpoint();
        let back = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        assert_eq!(back.rng.s, cp.rng.s);
        assert_eq!(
            back.rng.gauss_spare.unwrap().to_bits(),
            cp.rng.gauss_spare.unwrap().to_bits()
        );
        // a resumed stream continues draw-for-draw
        let mut a = cp.rng.to_rng();
        let mut b = back.rng.to_rng();
        for _ in 0..8 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn wide_counters_and_nonfinite_f64s_roundtrip() {
        // counters above 2^53 and non-finite f64 state must survive the
        // header (hex encoding) — a JSON-number encoding would round the
        // former and turn the latter into an unloadable null
        let mut cp = sample_checkpoint();
        cp.total_samples = (1u64 << 53) + 1;
        cp.comm_bytes = u64::MAX - 7;
        cp.overlap_hidden_s = f64::NAN;
        cp.clock_times[1] = f64::INFINITY;
        cp.trainers[0].sigma2_ema = (f64::NEG_INFINITY, u64::MAX);
        let back = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        assert_eq!(back.total_samples, (1u64 << 53) + 1);
        assert_eq!(back.comm_bytes, u64::MAX - 7);
        assert!(back.overlap_hidden_s.is_nan());
        assert_eq!(
            back.overlap_hidden_s.to_bits(),
            cp.overlap_hidden_s.to_bits(),
            "even NaN payload bits survive"
        );
        assert_eq!(back.clock_times[1], f64::INFINITY);
        assert_eq!(back.trainers[0].sigma2_ema.0, f64::NEG_INFINITY);
        assert_eq!(back.trainers[0].sigma2_ema.1, u64::MAX);
    }

    #[test]
    fn registry_and_spawn_bookkeeping_roundtrip() {
        let cp = sample_checkpoint();
        let back = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        assert_eq!(back.registry, cp.registry);
        assert_eq!(back.registry[2].origin, "util");
        assert_eq!(back.registry[1].retired_outer, Some(4));
        assert_eq!(back.registry[0].workers, vec![(0, 0), (1, 1)]);
        assert_eq!(back.spawn_count, 1);
        assert_eq!(back.last_spawn_outer, 5);
        assert_eq!(back.last_merge_rep, Some(2));
        assert_eq!(back.live_rounds_sum, 17);
        assert_eq!(back.rounds_count, 7);
        assert_eq!(back.vacant_s[2].to_bits(), 1.25f64.to_bits());
        // None variants survive too
        let mut cp2 = cp.clone();
        cp2.last_merge_rep = None;
        let back2 = Checkpoint::from_bytes(&cp2.to_bytes()).unwrap();
        assert_eq!(back2.last_merge_rep, None);
    }

    #[test]
    fn pending_sync_roundtrips() {
        let cp = sample_checkpoint();
        let back = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        let p = back.trainers[0].pending.as_ref().unwrap();
        let q = cp.trainers[0].pending.as_ref().unwrap();
        assert_eq!(p.completes_at.to_bits(), q.completes_at.to_bits());
        assert_eq!(p.phases, q.phases);
        assert_eq!(p.delta, q.delta);
        assert!(back.trainers[1].pending.is_none());
    }

    #[test]
    fn file_roundtrip() {
        let cp = sample_checkpoint();
        let dir = std::env::temp_dir().join("adloco_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        cp.save(path.to_str().unwrap()).unwrap();
        let back = Checkpoint::load(path.to_str().unwrap()).unwrap();
        assert_eq!(cp, back);
    }

    #[test]
    fn config_digest_roundtrips() {
        let cp = sample_checkpoint();
        let back = Checkpoint::from_bytes(&cp.to_bytes()).unwrap();
        assert_eq!(back.config_digest, 0x1234_5678_9abc_def0);
    }

    #[test]
    fn corruption_detected_with_typed_error() {
        let cp = sample_checkpoint();
        let mut bytes = cp.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let err = import_bytes(&bytes).unwrap_err();
        assert!(
            matches!(err, InterchangeError::Corrupt { .. }),
            "expected Corrupt, got {err}"
        );
        // the anyhow seam preserves the typed error for downcasting
        let any = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(any.downcast_ref::<InterchangeError>().is_some(), "{any}");
    }

    #[test]
    fn truncation_detected_with_typed_error() {
        let cp = sample_checkpoint();
        let bytes = cp.to_bytes();
        let err = import_bytes(&bytes[..bytes.len() - 9]).unwrap_err();
        assert!(
            matches!(
                err,
                InterchangeError::Truncated { .. } | InterchangeError::Corrupt { .. }
            ),
            "expected a typed damage error, got {err}"
        );
    }

    #[test]
    fn trailing_garbage_rejected_with_typed_error() {
        // regression (satellite of the v4 interchange PR): bytes after
        // the last section must never be silently accepted
        let cp = sample_checkpoint();
        let mut bytes = cp.to_bytes();
        bytes.extend_from_slice(b"junk");
        let err = import_bytes(&bytes).unwrap_err();
        assert_eq!(err, InterchangeError::TrailingGarbage { bytes: 4 }, "{err}");
    }

    #[test]
    fn bad_magic_detected() {
        let cp = sample_checkpoint();
        let mut bytes = cp.to_bytes();
        bytes[0] = b'X';
        let err = import_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn future_version_rejected_with_version_mismatch() {
        let cp = sample_checkpoint();
        let mut bytes = cp.to_bytes();
        bytes[4..8].copy_from_slice(&9u32.to_le_bytes());
        let err = import_bytes(&bytes).unwrap_err();
        assert_eq!(err, InterchangeError::VersionMismatch { found: 9 }, "{err}");
        assert!(err.to_string().contains("version 9"), "{err}");
    }

    #[test]
    fn minimal_variant_roundtrips_and_is_refused_for_exact_resume() {
        let cp = sample_checkpoint();
        let min = cp.to_minimal();
        assert_eq!(min.trainers.len(), cp.trainers.len());
        assert_eq!(min.trainers[0].params, cp.trainers[0].params);
        assert_eq!(min.trainers[0].workers.len(), 2);
        assert_eq!(min.trainers[0].workers[1].time_rng, cp.trainers[0].workers[1].time_rng);
        let bytes = min.to_bytes();
        match import_bytes(&bytes).unwrap() {
            Interchange::Minimal(back) => assert_eq!(back, min),
            other => panic!("expected minimal variant, got {other:?}"),
        }
        // the exact-resume loader must refuse a warm-start file
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("minimal"), "{err}");
    }

    #[test]
    fn minimal_file_roundtrip() {
        let min = sample_checkpoint().to_minimal();
        let dir = std::env::temp_dir().join("adloco_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        min.save(path.to_str().unwrap()).unwrap();
        match load_interchange(path.to_str().unwrap()).unwrap() {
            Interchange::Minimal(back) => assert_eq!(back, min),
            other => panic!("expected minimal variant, got {other:?}"),
        }
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
