//! Checkpointing: serialize / restore the trainer pool mid-run.
//!
//! A production distributed trainer must survive restarts; this module
//! gives the coordinator durable snapshots of everything the *optimizer*
//! needs to continue: per-trainer outer parameters and outer-momentum,
//! per-worker model + AdamW state, the adaptive-batching controller's
//! requested batch, virtual-clock times and the communication counters.
//!
//! Format (little-endian): `b"ADLC"` magic, u32 version, u32 JSON header
//! length, JSON header (structure + counters), then the raw f32 blobs in
//! header order, and a trailing CRC32 of everything before it.
//!
//! Data-pipeline position (sampler permutation, engine-internal RNG) is
//! deliberately NOT captured: on resume the samplers reshuffle from the
//! config seed. Parameter/optimizer state — the expensive part — resumes
//! exactly; the data order after resume is a fresh deterministic stream
//! (the same trade most real frameworks make).

use crate::util::JsonValue;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

/// File magic of the checkpoint container format.
pub const MAGIC: &[u8; 4] = b"ADLC";
/// Container format version.
pub const VERSION: u32 = 1;

/// Snapshot of one worker's optimizer state.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSnapshot {
    /// Worker parameter vector.
    pub params: Vec<f32>,
    /// AdamW first moments.
    pub m: Vec<f32>,
    /// AdamW second moments.
    pub v: Vec<f32>,
    /// Optimizer step counter.
    pub step: u64,
}

/// Snapshot of one live trainer.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainerSnapshot {
    /// Trainer id (position in the coordinator's pool).
    pub id: usize,
    /// Outer parameter vector.
    pub params: Vec<f32>,
    /// Outer-optimizer momentum buffer (empty for Average/Sgd).
    pub outer_velocity: Vec<f32>,
    /// Adaptive controller's requested batch.
    pub requested_batch: usize,
    /// Inner steps completed by this trainer.
    pub inner_steps_done: u64,
    /// Per-worker optimizer state.
    pub workers: Vec<WorkerSnapshot>,
}

/// A full coordinator snapshot.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Checkpoint {
    /// Name of the config that produced the snapshot.
    pub config_name: String,
    /// Outer step the snapshot was taken after.
    pub outer_step: u64,
    /// Samples consumed so far.
    pub total_samples: u64,
    /// Ledger communication count at snapshot time.
    pub comm_count: u64,
    /// Ledger communication bytes at snapshot time.
    pub comm_bytes: u64,
    /// Per-slot virtual clock times.
    pub clock_times: Vec<f64>,
    /// Live trainers (dead ones are omitted).
    pub trainers: Vec<TrainerSnapshot>,
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE) — small table-driven implementation; no external crates.
// ---------------------------------------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    for (i, e) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *e = c;
    }
    table
}

/// CRC32 (IEEE) of `data` — the checkpoint trailer integrity check.
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------------

fn f32s_to_bytes(v: &[f32], out: &mut Vec<u8>) {
    out.reserve(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn bytes_to_f32s(raw: &[u8]) -> Vec<f32> {
    raw.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

impl Checkpoint {
    fn header_json(&self) -> JsonValue {
        JsonValue::obj(vec![
            ("config_name", JsonValue::str(self.config_name.clone())),
            ("outer_step", JsonValue::num(self.outer_step as f64)),
            ("total_samples", JsonValue::num(self.total_samples as f64)),
            ("comm_count", JsonValue::num(self.comm_count as f64)),
            ("comm_bytes", JsonValue::num(self.comm_bytes as f64)),
            (
                "clock_times",
                JsonValue::Array(self.clock_times.iter().map(|&t| JsonValue::num(t)).collect()),
            ),
            (
                "trainers",
                JsonValue::Array(
                    self.trainers
                        .iter()
                        .map(|t| {
                            JsonValue::obj(vec![
                                ("id", JsonValue::num(t.id as f64)),
                                ("param_len", JsonValue::num(t.params.len() as f64)),
                                (
                                    "velocity_len",
                                    JsonValue::num(t.outer_velocity.len() as f64),
                                ),
                                (
                                    "requested_batch",
                                    JsonValue::num(t.requested_batch as f64),
                                ),
                                (
                                    "inner_steps_done",
                                    JsonValue::num(t.inner_steps_done as f64),
                                ),
                                (
                                    "workers",
                                    JsonValue::Array(
                                        t.workers
                                            .iter()
                                            .map(|w| {
                                                JsonValue::obj(vec![
                                                    (
                                                        "param_len",
                                                        JsonValue::num(w.params.len() as f64),
                                                    ),
                                                    ("step", JsonValue::num(w.step as f64)),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Serialize to bytes (see module docs for the layout).
    pub fn to_bytes(&self) -> Vec<u8> {
        let header = self.header_json().to_string();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for t in &self.trainers {
            f32s_to_bytes(&t.params, &mut out);
            f32s_to_bytes(&t.outer_velocity, &mut out);
            for w in &t.workers {
                f32s_to_bytes(&w.params, &mut out);
                f32s_to_bytes(&w.m, &mut out);
                f32s_to_bytes(&w.v, &mut out);
            }
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and CRC-verify a serialized checkpoint.
    pub fn from_bytes(raw: &[u8]) -> Result<Checkpoint> {
        if raw.len() < 16 {
            bail!("checkpoint too short");
        }
        let (body, crc_bytes) = raw.split_at(raw.len() - 4);
        let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let got = crc32(body);
        if want != got {
            bail!("checkpoint CRC mismatch: file {want:#x} vs computed {got:#x}");
        }
        if &body[0..4] != MAGIC {
            bail!("bad checkpoint magic");
        }
        let version = u32::from_le_bytes(body[4..8].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let hlen = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
        if body.len() < 12 + hlen {
            bail!("truncated checkpoint header");
        }
        let header_text = std::str::from_utf8(&body[12..12 + hlen])
            .context("checkpoint header not utf-8")?;
        let h = JsonValue::parse(header_text).map_err(|e| anyhow!("header: {e}"))?;

        let gu = |v: &JsonValue, k: &str| -> Result<u64> {
            v.get(k)
                .and_then(|x| x.as_f64())
                .map(|n| n as u64)
                .ok_or_else(|| anyhow!("header missing {k}"))
        };

        let mut cp = Checkpoint {
            config_name: h
                .get("config_name")
                .and_then(|x| x.as_str())
                .unwrap_or_default()
                .to_string(),
            outer_step: gu(&h, "outer_step")?,
            total_samples: gu(&h, "total_samples")?,
            comm_count: gu(&h, "comm_count")?,
            comm_bytes: gu(&h, "comm_bytes")?,
            clock_times: h
                .get("clock_times")
                .and_then(|x| x.as_array())
                .ok_or_else(|| anyhow!("header missing clock_times"))?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0))
                .collect(),
            trainers: Vec::new(),
        };

        let mut cursor = 12 + hlen;
        let mut take_f32s = |n: usize, cursor: &mut usize| -> Result<Vec<f32>> {
            let bytes = n * 4;
            if body.len() < *cursor + bytes {
                bail!("truncated checkpoint blob");
            }
            let v = bytes_to_f32s(&body[*cursor..*cursor + bytes]);
            *cursor += bytes;
            Ok(v)
        };

        for tj in h
            .get("trainers")
            .and_then(|x| x.as_array())
            .ok_or_else(|| anyhow!("header missing trainers"))?
        {
            let plen = gu(tj, "param_len")? as usize;
            let vlen = gu(tj, "velocity_len")? as usize;
            let params = take_f32s(plen, &mut cursor)?;
            let outer_velocity = take_f32s(vlen, &mut cursor)?;
            let mut workers = Vec::new();
            for wj in tj
                .get("workers")
                .and_then(|x| x.as_array())
                .ok_or_else(|| anyhow!("trainer missing workers"))?
            {
                let wlen = gu(wj, "param_len")? as usize;
                workers.push(WorkerSnapshot {
                    params: take_f32s(wlen, &mut cursor)?,
                    m: take_f32s(wlen, &mut cursor)?,
                    v: take_f32s(wlen, &mut cursor)?,
                    step: gu(wj, "step")?,
                });
            }
            cp.trainers.push(TrainerSnapshot {
                id: gu(tj, "id")? as usize,
                params,
                outer_velocity,
                requested_batch: gu(tj, "requested_batch")? as usize,
                inner_steps_done: gu(tj, "inner_steps_done")?,
                workers,
            });
        }
        if cursor != body.len() {
            bail!("checkpoint has {} trailing bytes", body.len() - cursor);
        }
        Ok(cp)
    }

    /// Write the checkpoint to `path` (write-then-rename, crash-safe).
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        // write-then-rename for crash safety
        let tmp = format!("{path}.tmp");
        let mut f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp}"))?;
        f.write_all(&self.to_bytes())?;
        f.sync_all().ok();
        std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp} -> {path}"))?;
        Ok(())
    }

    /// Read and verify a checkpoint from `path`.
    pub fn load(path: &str) -> Result<Checkpoint> {
        let mut raw = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path}"))?
            .read_to_end(&mut raw)?;
        Self::from_bytes(&raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_checkpoint() -> Checkpoint {
        let mut rng = Rng::new(3);
        let mk = |n: usize, rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32).collect()
        };
        Checkpoint {
            config_name: "unit".into(),
            outer_step: 7,
            total_samples: 12345,
            comm_count: 42,
            comm_bytes: 9876,
            clock_times: vec![1.5, 2.25, 0.0],
            trainers: vec![
                TrainerSnapshot {
                    id: 0,
                    params: mk(64, &mut rng),
                    outer_velocity: mk(64, &mut rng),
                    requested_batch: 17,
                    inner_steps_done: 140,
                    workers: vec![
                        WorkerSnapshot {
                            params: mk(64, &mut rng),
                            m: mk(64, &mut rng),
                            v: mk(64, &mut rng),
                            step: 140,
                        },
                        WorkerSnapshot {
                            params: mk(64, &mut rng),
                            m: mk(64, &mut rng),
                            v: mk(64, &mut rng),
                            step: 140,
                        },
                    ],
                },
                TrainerSnapshot {
                    id: 2,
                    params: mk(64, &mut rng),
                    outer_velocity: vec![],
                    requested_batch: 3,
                    inner_steps_done: 140,
                    workers: vec![WorkerSnapshot {
                        params: mk(64, &mut rng),
                        m: mk(64, &mut rng),
                        v: mk(64, &mut rng),
                        step: 140,
                    }],
                },
            ],
        }
    }

    #[test]
    fn roundtrip_exact() {
        let cp = sample_checkpoint();
        let bytes = cp.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(cp, back);
    }

    #[test]
    fn file_roundtrip() {
        let cp = sample_checkpoint();
        let dir = std::env::temp_dir().join("adloco_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        cp.save(path.to_str().unwrap()).unwrap();
        let back = Checkpoint::load(path.to_str().unwrap()).unwrap();
        assert_eq!(cp, back);
    }

    #[test]
    fn corruption_detected() {
        let cp = sample_checkpoint();
        let mut bytes = cp.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let cp = sample_checkpoint();
        let bytes = cp.to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
    }

    #[test]
    fn bad_magic_detected() {
        let cp = sample_checkpoint();
        let mut bytes = cp.to_bytes();
        bytes[0] = b'X';
        // CRC covers the magic, so recompute it to isolate the magic check
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
