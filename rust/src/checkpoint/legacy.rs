//! Importers (and historical writers) for the pre-v4 checkpoint
//! containers — the interchange's migration story (DESIGN.md §10).
//!
//! All three legacy versions share one container shape:
//!
//! ```text
//! "ADLC"  u32-LE version  u32-LE header_len  header-JSON  raw-f32-blobs
//! u32-LE CRC32(everything above)
//! ```
//!
//! * **v1** — the minimal layout: outer params + RNG streams per
//!   trainer. Imports as [`MinimalCheckpoint`] (warm-start only).
//! * **v2** — exact resume before the elastic lifecycle: adds optimizer
//!   moments, sampler cursors, controller statistics, time accounting
//!   and in-flight syncs. Imports as a complete [`Checkpoint`] with the
//!   elastic fields defaulted (zero vacancy/spawn bookkeeping and a
//!   best-effort registry: one active seed row per live trainer, worker
//!   assignments unknown — the coordinator keeps its config-seeded
//!   assignments for such rows).
//! * **v3** — v2 plus the registry, spawn bookkeeping, vacancy and
//!   round-census accounting. Imports losslessly (`config_digest`
//!   becomes 0: the field did not exist yet, so resume skips the
//!   digest check for imported files).
//!
//! The writers ([`export_v1`]/[`export_v2`]/[`export_v3`]) reproduce
//! the historical bytes; they exist for the cross-version
//! compatibility matrix (`tests/interchange_fixtures.rs`) and for
//! regenerating the golden fixture files — current code always writes
//! v4.

use super::{
    crc32, f32s_to_bytes, f64_json, f64s_json, get_f64, get_u64, parse_ema, parse_f64s,
    parse_hex_u64, parse_rng, parse_usizes, rng_json, trainer_json, u64_json, usizes_json,
    Checkpoint, InterchangeError, MinimalCheckpoint, MinimalTrainer, MinimalWorker,
    PendingSnapshot, PhaseSnapshot, RegistryRowSnapshot, RngSnapshot, SamplerSnapshot,
    TrainerSnapshot, WorkerSnapshot, MAGIC,
};
use crate::util::JsonValue;
use anyhow::{anyhow, bail, Result};

type IResult<T> = std::result::Result<T, InterchangeError>;

// ---------------------------------------------------------------------------
// container walk (shared by all three versions)
// ---------------------------------------------------------------------------

/// Verify a legacy container's structure and CRC trailer; return the
/// parsed header and the raw blob body.
fn split_legacy<'a>(raw: &'a [u8], what: &'static str) -> IResult<(JsonValue, &'a [u8])> {
    // magic and version were already checked by `import_bytes`
    if raw.len() < 16 {
        return Err(InterchangeError::Truncated {
            section: format!("{what} prologue"),
            needed: 16,
            have: raw.len(),
        });
    }
    let header_len = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
    let need = 12 + header_len + 4;
    if raw.len() < need {
        return Err(InterchangeError::Truncated {
            section: format!("{what} header"),
            needed: need,
            have: raw.len(),
        });
    }
    let stored = u32::from_le_bytes(raw[raw.len() - 4..].try_into().unwrap());
    if crc32(&raw[..raw.len() - 4]) != stored {
        return Err(InterchangeError::Corrupt {
            section: format!("{what} CRC trailer"),
            detail: "whole-file CRC mismatch".into(),
        });
    }
    let text = std::str::from_utf8(&raw[12..12 + header_len]).map_err(|e| {
        InterchangeError::Corrupt {
            section: format!("{what} header"),
            detail: format!("header is not UTF-8: {e}"),
        }
    })?;
    let header = JsonValue::parse(text).map_err(|e| InterchangeError::Corrupt {
        section: format!("{what} header"),
        detail: format!("header is not valid JSON: {e}"),
    })?;
    Ok((header, &raw[12 + header_len..raw.len() - 4]))
}

fn as_corrupt(what: &'static str, e: anyhow::Error) -> InterchangeError {
    InterchangeError::Corrupt { section: format!("{what} payload"), detail: format!("{e:#}") }
}

fn take_f32s(body: &[u8], cursor: &mut usize, n: usize) -> Result<Vec<f32>> {
    let bytes = n * 4;
    if *cursor + bytes > body.len() {
        bail!(
            "payload exhausted: need {bytes} bytes at offset {cursor}, have {}",
            body.len()
        );
    }
    let out = super::bytes_to_f32s(&body[*cursor..*cursor + bytes]);
    *cursor += bytes;
    Ok(out)
}

// ---------------------------------------------------------------------------
// tolerant header parsing (legacy files predate strict mode)
// ---------------------------------------------------------------------------

fn parse_registry(header: &JsonValue) -> Result<Vec<RegistryRowSnapshot>> {
    let rows = header
        .get("registry")
        .and_then(|x| x.as_array())
        .ok_or_else(|| anyhow!("missing registry"))?;
    rows.iter()
        .map(|r| {
            let workers = r
                .get("workers")
                .and_then(|x| x.as_array())
                .ok_or_else(|| anyhow!("registry row missing workers"))?
                .iter()
                .map(|w| {
                    let pair = w.as_array().ok_or_else(|| anyhow!("bad worker pair"))?;
                    if pair.len() != 2 {
                        bail!("worker pair must be [node, slot]");
                    }
                    Ok((
                        pair[0].as_usize().ok_or_else(|| anyhow!("bad worker node"))?,
                        pair[1].as_usize().ok_or_else(|| anyhow!("bad worker slot"))?,
                    ))
                })
                .collect::<Result<Vec<(usize, usize)>>>()?;
            Ok(RegistryRowSnapshot {
                id: r.get("id").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("row id"))?,
                state: r
                    .get("state")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("row state"))?
                    .to_string(),
                origin: r
                    .get("origin")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("row origin"))?
                    .to_string(),
                born_outer: get_u64(r, "born_outer")?,
                born_at_s: get_f64(r, "born_at_s")?,
                retired_outer: match r.get("retired_outer") {
                    Some(JsonValue::Null) | None => None,
                    Some(x) => Some(if let Some(s) = x.as_str() {
                        parse_hex_u64(s)?
                    } else {
                        x.as_f64().ok_or_else(|| anyhow!("bad retired_outer"))? as u64
                    }),
                },
                workers,
            })
        })
        .collect()
}

fn parse_trainers(header: &JsonValue, body: &[u8]) -> Result<Vec<TrainerSnapshot>> {
    let ts = header
        .get("trainers")
        .and_then(|x| x.as_array())
        .ok_or_else(|| anyhow!("missing trainers"))?;
    let mut cursor = 0usize;
    let mut out = Vec::with_capacity(ts.len());
    for t in ts {
        let id = t.get("id").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("trainer id"))?;
        let param_len =
            t.get("param_len").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("param_len"))?;
        let velocity_len = t
            .get("velocity_len")
            .and_then(|x| x.as_usize())
            .ok_or_else(|| anyhow!("velocity_len"))?;
        let pending_head = match t.get("pending") {
            Some(JsonValue::Null) | None => None,
            Some(p) => {
                let delta_len = p
                    .get("delta_len")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("pending delta_len"))?;
                let phases = p
                    .get("phases")
                    .and_then(|x| x.as_array())
                    .ok_or_else(|| anyhow!("pending phases"))?
                    .iter()
                    .map(|ph| {
                        Ok(PhaseSnapshot {
                            wan: ph
                                .get("wan")
                                .and_then(|x| x.as_bool())
                                .ok_or_else(|| anyhow!("phase wan"))?,
                            bytes: get_u64(ph, "bytes")?,
                            participants: ph
                                .get("participants")
                                .and_then(|x| x.as_usize())
                                .ok_or_else(|| anyhow!("phase participants"))?,
                        })
                    })
                    .collect::<Result<Vec<PhaseSnapshot>>>()?;
                Some((
                    PendingSnapshot {
                        posted_at: get_f64(p, "posted_at")?,
                        completes_at: get_f64(p, "completes_at")?,
                        time_s: get_f64(p, "time_s")?,
                        sent_samples: get_u64(p, "sent_samples")?,
                        phases,
                        delta: Vec::new(), // filled from the blob below
                    },
                    delta_len,
                ))
            }
        };
        let params = take_f32s(body, &mut cursor, param_len)?;
        let outer_velocity = take_f32s(body, &mut cursor, velocity_len)?;
        let pending = match pending_head {
            None => None,
            Some((mut p, delta_len)) => {
                p.delta = take_f32s(body, &mut cursor, delta_len)?;
                Some(p)
            }
        };
        let workers_json = t
            .get("workers")
            .and_then(|x| x.as_array())
            .ok_or_else(|| anyhow!("trainer workers"))?;
        let mut workers = Vec::with_capacity(workers_json.len());
        for w in workers_json {
            let w_len = w
                .get("param_len")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("worker param_len"))?;
            let sampler_v = w.get("sampler").ok_or_else(|| anyhow!("worker sampler"))?;
            let sampler = SamplerSnapshot {
                shard: parse_usizes(sampler_v, "shard")?,
                order: parse_usizes(sampler_v, "order")?,
                cursor: sampler_v
                    .get("cursor")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("sampler cursor"))?,
                drawn: get_u64(sampler_v, "drawn")?,
                rng: parse_rng(sampler_v, "rng")?,
            };
            workers.push(WorkerSnapshot {
                params: take_f32s(body, &mut cursor, w_len)?,
                m: take_f32s(body, &mut cursor, w_len)?,
                v: take_f32s(body, &mut cursor, w_len)?,
                step: get_u64(w, "step")?,
                active: w
                    .get("active")
                    .and_then(|x| x.as_bool())
                    .ok_or_else(|| anyhow!("worker active"))?,
                noise_rng: parse_rng(w, "noise_rng")?,
                time_rng: parse_rng(w, "time_rng")?,
                sampler,
            });
        }
        out.push(TrainerSnapshot {
            id,
            params,
            outer_velocity,
            requested_batch: t
                .get("requested_batch")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("requested_batch"))?,
            inner_steps_done: get_u64(t, "inner_steps_done")?,
            observations: get_u64(t, "observations")?,
            sigma2_ema: parse_ema(t, "sigma2_ema")?,
            ip_var_ema: parse_ema(t, "ip_var_ema")?,
            s1_ema: parse_ema(t, "s1_ema")?,
            shard: parse_usizes(t, "shard")?,
            pending,
            workers,
        });
    }
    if cursor != body.len() {
        bail!("{} trailing payload bytes beyond the last declared vector", body.len() - cursor);
    }
    Ok(out)
}

fn parse_complete(header: &JsonValue, body: &[u8], has_elastic: bool) -> Result<Checkpoint> {
    let clock_times = parse_f64s(header, "clock_times")?;
    let trainers = parse_trainers(header, body)?;
    let (vacant_s, spawn_count, last_spawn_outer, last_merge_rep, live_rounds_sum, rounds_count, registry);
    if has_elastic {
        vacant_s = parse_f64s(header, "vacant_s")?;
        spawn_count = get_u64(header, "spawn_count")?;
        last_spawn_outer = get_u64(header, "last_spawn_outer")?;
        last_merge_rep = match header.get("last_merge_rep") {
            Some(JsonValue::Null) | None => None,
            Some(x) => Some(x.as_usize().ok_or_else(|| anyhow!("bad last_merge_rep"))?),
        };
        live_rounds_sum = get_u64(header, "live_rounds_sum")?;
        rounds_count = get_u64(header, "rounds_count")?;
        registry = parse_registry(header)?;
    } else {
        // pre-elastic file: no vacancy, no spawns, and a best-effort
        // registry — one active seed row per live trainer; worker
        // assignments are unknown (empty), which the coordinator
        // resolves by keeping its config-seeded assignment
        vacant_s = vec![0.0; clock_times.len()];
        spawn_count = 0;
        last_spawn_outer = 0;
        last_merge_rep = None;
        live_rounds_sum = 0;
        rounds_count = 0;
        registry = trainers
            .iter()
            .map(|t| RegistryRowSnapshot {
                id: t.id,
                state: "active".into(),
                origin: "seed".into(),
                born_outer: 0,
                born_at_s: 0.0,
                retired_outer: None,
                workers: Vec::new(),
            })
            .collect();
    }
    Ok(Checkpoint {
        config_name: header
            .get("config_name")
            .and_then(|x| x.as_str())
            .unwrap_or_default()
            .to_string(),
        config_digest: 0, // predates the digest; resume skips the check
        outer_step: get_u64(header, "outer_step")?,
        total_samples: get_u64(header, "total_samples")?,
        comm_count: get_u64(header, "comm_count")?,
        comm_bytes: get_u64(header, "comm_bytes")?,
        comm_wan_bytes: get_u64(header, "comm_wan_bytes")?,
        overlap_hidden_s: get_f64(header, "overlap_hidden_s")?,
        clock_times,
        busy_s: parse_f64s(header, "busy_s")?,
        wait_s: parse_f64s(header, "wait_s")?,
        comm_s: parse_f64s(header, "comm_s")?,
        comm_hidden_s: parse_f64s(header, "comm_hidden_s")?,
        preempted_s: parse_f64s(header, "preempted_s")?,
        vacant_s,
        spawn_count,
        last_spawn_outer,
        last_merge_rep,
        live_rounds_sum,
        rounds_count,
        registry,
        rng: parse_rng(header, "rng")?,
        trainers,
    })
}

// ---------------------------------------------------------------------------
// importers
// ---------------------------------------------------------------------------

/// Import a v3 container (elastic-era exact resume). Lossless.
pub(crate) fn import_v3(raw: &[u8]) -> IResult<Checkpoint> {
    let (header, body) = split_legacy(raw, "v3")?;
    parse_complete(&header, body, true).map_err(|e| as_corrupt("v3", e))
}

/// Import a v2 container (pre-elastic exact resume); elastic fields
/// default as documented on the module.
pub(crate) fn import_v2(raw: &[u8]) -> IResult<Checkpoint> {
    let (header, body) = split_legacy(raw, "v2")?;
    parse_complete(&header, body, false).map_err(|e| as_corrupt("v2", e))
}

/// Import a v1 container (params + RNG streams) as the minimal
/// warm-start variant.
pub(crate) fn import_v1(raw: &[u8]) -> IResult<MinimalCheckpoint> {
    let (header, body) = split_legacy(raw, "v1")?;
    parse_minimal(&header, body).map_err(|e| as_corrupt("v1", e))
}

fn parse_minimal(header: &JsonValue, body: &[u8]) -> Result<MinimalCheckpoint> {
    let ts = header
        .get("trainers")
        .and_then(|x| x.as_array())
        .ok_or_else(|| anyhow!("missing trainers"))?;
    let mut cursor = 0usize;
    let mut trainers = Vec::with_capacity(ts.len());
    for t in ts {
        let param_len =
            t.get("param_len").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("param_len"))?;
        let workers = t
            .get("workers")
            .and_then(|x| x.as_array())
            .ok_or_else(|| anyhow!("trainer workers"))?
            .iter()
            .map(|w| {
                Ok(MinimalWorker {
                    noise_rng: parse_rng(w, "noise_rng")?,
                    time_rng: parse_rng(w, "time_rng")?,
                })
            })
            .collect::<Result<Vec<MinimalWorker>>>()?;
        trainers.push(MinimalTrainer {
            id: t.get("id").and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("trainer id"))?,
            params: take_f32s(body, &mut cursor, param_len)?,
            workers,
        });
    }
    if cursor != body.len() {
        bail!("{} trailing payload bytes beyond the last declared vector", body.len() - cursor);
    }
    Ok(MinimalCheckpoint {
        config_name: header
            .get("config_name")
            .and_then(|x| x.as_str())
            .unwrap_or_default()
            .to_string(),
        config_digest: 0,
        outer_step: get_u64(header, "outer_step")?,
        rng: parse_rng(header, "rng")?,
        trainers,
    })
}

// ---------------------------------------------------------------------------
// historical writers
// ---------------------------------------------------------------------------

fn legacy_container(version: u32, header: &str, blobs: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + header.len() + blobs.len() + 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(blobs);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Write the historical v3 bytes of a snapshot (elastic-era layout).
pub fn export_v3(cp: &Checkpoint) -> Vec<u8> {
    let mut fields = vec![("config_name", JsonValue::str(cp.config_name.as_str()))];
    fields.extend(super::state_fields(cp));
    legacy_container(3, &JsonValue::obj(fields).to_string(), &super::blob_bytes(cp))
}

/// Write the historical v2 bytes of a snapshot: the v3 layout minus
/// the elastic fields (vacancy, spawn bookkeeping, round census,
/// registry). Elastic state present on `cp` is dropped — v2 could not
/// express it.
pub fn export_v2(cp: &Checkpoint) -> Vec<u8> {
    let fields = vec![
        ("config_name", JsonValue::str(cp.config_name.as_str())),
        ("outer_step", u64_json(cp.outer_step)),
        ("total_samples", u64_json(cp.total_samples)),
        ("comm_count", u64_json(cp.comm_count)),
        ("comm_bytes", u64_json(cp.comm_bytes)),
        ("comm_wan_bytes", u64_json(cp.comm_wan_bytes)),
        ("overlap_hidden_s", f64_json(cp.overlap_hidden_s)),
        ("clock_times", f64s_json(&cp.clock_times)),
        ("busy_s", f64s_json(&cp.busy_s)),
        ("wait_s", f64s_json(&cp.wait_s)),
        ("comm_s", f64s_json(&cp.comm_s)),
        ("comm_hidden_s", f64s_json(&cp.comm_hidden_s)),
        ("preempted_s", f64s_json(&cp.preempted_s)),
        ("rng", rng_json(&cp.rng)),
        (
            "trainers",
            JsonValue::Array(cp.trainers.iter().map(trainer_json).collect()),
        ),
    ];
    legacy_container(2, &JsonValue::obj(fields).to_string(), &super::blob_bytes(cp))
}

/// Write the historical v1 bytes of a minimal snapshot (params + RNG
/// streams; blob carries only the outer parameter vectors).
pub fn export_v1(m: &MinimalCheckpoint) -> Vec<u8> {
    let fields = vec![
        ("config_name", JsonValue::str(m.config_name.as_str())),
        ("outer_step", u64_json(m.outer_step)),
        ("rng", rng_json(&m.rng)),
        (
            "trainers",
            JsonValue::Array(
                m.trainers
                    .iter()
                    .map(|t| {
                        JsonValue::obj(vec![
                            ("id", JsonValue::num(t.id as f64)),
                            ("param_len", JsonValue::num(t.params.len() as f64)),
                            (
                                "workers",
                                JsonValue::Array(
                                    t.workers
                                        .iter()
                                        .map(|w| {
                                            JsonValue::obj(vec![
                                                ("noise_rng", rng_json(&w.noise_rng)),
                                                ("time_rng", rng_json(&w.time_rng)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    let mut blobs = Vec::new();
    for t in &m.trainers {
        f32s_to_bytes(&t.params, &mut blobs);
    }
    legacy_container(1, &JsonValue::obj(fields).to_string(), &blobs)
}

#[cfg(test)]
mod tests {
    use super::super::tests::sample_checkpoint;
    use super::super::{import_bytes, Interchange};
    use super::*;

    #[test]
    fn v3_roundtrips_through_the_import_path() {
        let cp = sample_checkpoint();
        let bytes = export_v3(&cp);
        let back = match import_bytes(&bytes).unwrap() {
            Interchange::Complete(c) => c,
            other => panic!("expected complete, got {other:?}"),
        };
        // v3 predates the config digest; everything else is lossless
        let mut want = cp;
        want.config_digest = 0;
        assert_eq!(back, want);
    }

    #[test]
    fn v2_import_fills_elastic_defaults() {
        let cp = sample_checkpoint();
        let back = match import_bytes(&export_v2(&cp)).unwrap() {
            Interchange::Complete(c) => c,
            other => panic!("expected complete, got {other:?}"),
        };
        assert_eq!(back.outer_step, cp.outer_step);
        assert_eq!(back.trainers, cp.trainers);
        assert_eq!(back.clock_times, cp.clock_times);
        assert_eq!(back.vacant_s, vec![0.0; cp.clock_times.len()]);
        assert_eq!(back.spawn_count, 0);
        assert_eq!(back.last_merge_rep, None);
        assert_eq!(back.rounds_count, 0);
        // best-effort registry: one active seed row per live trainer
        assert_eq!(back.registry.len(), cp.trainers.len());
        assert_eq!(back.registry[0].id, cp.trainers[0].id);
        assert_eq!(back.registry[1].id, cp.trainers[1].id);
        assert!(back.registry.iter().all(|r| r.state == "active" && r.origin == "seed"));
        assert!(back.registry.iter().all(|r| r.workers.is_empty()));
    }

    #[test]
    fn v1_imports_as_minimal() {
        let min = sample_checkpoint().to_minimal();
        let back = match import_bytes(&export_v1(&min)).unwrap() {
            Interchange::Minimal(m) => m,
            other => panic!("expected minimal, got {other:?}"),
        };
        let mut want = min;
        want.config_digest = 0;
        assert_eq!(back, want);
    }

    #[test]
    fn legacy_crc_damage_is_typed() {
        let mut bytes = export_v3(&sample_checkpoint());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        let err = import_bytes(&bytes).unwrap_err();
        assert!(
            matches!(&err, InterchangeError::Corrupt { section, .. } if section.contains("CRC")),
            "{err}"
        );
    }

    #[test]
    fn legacy_truncation_is_typed() {
        let bytes = export_v2(&sample_checkpoint());
        for cut in [9, 14, bytes.len() / 2, bytes.len() - 1] {
            let err = import_bytes(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(
                    &err,
                    InterchangeError::Truncated { .. } | InterchangeError::Corrupt { .. }
                ),
                "cut {cut}: {err}"
            );
        }
    }

    #[test]
    fn version_dispatch_matrix() {
        // every container version routes to its importer and comes back
        // as the right variant
        let cp = sample_checkpoint();
        let min = cp.to_minimal();
        for (version, bytes) in [
            (1u32, export_v1(&min)),
            (2, export_v2(&cp)),
            (3, export_v3(&cp)),
            (4, cp.to_bytes()),
        ] {
            assert_eq!(
                u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
                version,
                "writer stamped the wrong container version"
            );
            let got = import_bytes(&bytes).unwrap();
            match (version, got) {
                (1, Interchange::Minimal(_)) => {}
                (2..=4, Interchange::Complete(_)) => {}
                (v, other) => panic!("version {v} imported as {other:?}"),
            }
        }
    }
}
