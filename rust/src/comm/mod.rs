//! The communication layer: network tiers, pluggable collectives and
//! the run-wide communication ledger (DESIGN.md §7).
//!
//! Carved out of the coordinator god-module so that **every
//! [`CommEvent`] is produced by exactly one code path**: the
//! coordinator describes a synchronization (kind, payload size,
//! participant nodes) and the [`CommLayer`] prices it through a
//! [`collective::Collective`] trait object, yielding the modeled
//! transfer seconds *and* the ledger bytes from the same closed form.
//! Before this layer existed, the byte formulas were hand-inlined at
//! five `ledger.record` call sites.
//!
//! Two network tiers express the paper's MIT cost asymmetry — many
//! lightweight merges on cheap local links, few expensive DiLoCo syncs
//! across the cluster boundary: the *intra-group* network
//! (`cluster.net_*`) and the *WAN* (`cluster.wan_*`), composed per the
//! [`crate::cluster::Topology`]. Under the flat topology only the base
//! network exists and every event is scoped [`CommScope::Wan`] — the
//! single shared interconnect *is* the wide-area link of the
//! flat-vs-hierarchical comparison (`theory::estimate_ledger`,
//! `benches/fig3_topology.rs`).

pub mod collective;

use crate::cluster::Topology;
use crate::config::ClusterConfig;
use collective::{collective_for, Collective, GATHER};
use std::collections::BTreeMap;

/// Latency + bandwidth network model shared by all links of one tier.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Per-transfer latency, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// One point-to-point transfer of `bytes`.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// The same link with its bandwidth scaled by `factor` — how the
    /// scenario layer's time-varying links enter a sync's cost. A factor
    /// of exactly 1.0 reproduces `self` bit-for-bit.
    pub fn scaled(&self, factor: f64) -> NetworkModel {
        NetworkModel {
            latency_s: self.latency_s,
            bandwidth_bps: self.bandwidth_bps * factor,
        }
    }

    /// Parameter-averaging round among `m` participants of `bytes` each.
    /// Modeled as a ring all-reduce: 2(m-1)/m * bytes on the wire per
    /// node, plus one latency per ring hop (the time half of
    /// [`collective::RingAllReduce`]'s closed form).
    pub fn allreduce_time(&self, bytes: u64, m: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let hops = 2 * (m - 1);
        hops as f64 * self.latency_s
            + (2.0 * (m as f64 - 1.0) / m as f64) * bytes as f64 / self.bandwidth_bps
    }
}

/// What a communication event was for (ledger taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommKind {
    /// Inner-trainer worker averaging at an outer step (DiLoCo sync).
    /// Closed form: the configured sync collective's all-reduce row
    /// (ring default: `2(m−1)·P` ledger bytes — see [`collective`]).
    OuterSync,
    /// Trainer merge (MIT DoMerge parameter movement). Closed form:
    /// the gather row, `(m−1)·P` ledger bytes.
    Merge,
}

/// Which network tier carried a communication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommScope {
    /// Fast intra-group link (hierarchical topology only).
    Intra,
    /// Wide-area tier: the inter-group link of the hierarchical
    /// topology — or the single shared network of a flat cluster,
    /// which plays the WAN role in the flat-vs-hierarchical
    /// comparison.
    Wan,
}

/// One recorded communication event.
#[derive(Clone, Debug)]
pub struct CommEvent {
    /// What the communication was for.
    pub kind: CommKind,
    /// Network tier that carried it.
    pub scope: CommScope,
    /// Virtual time the communication completed.
    pub at_virtual_s: f64,
    /// Bytes moved.
    pub bytes: u64,
    /// Number of participating workers/trainers (group leaders for a
    /// hierarchical WAN phase).
    pub participants: usize,
    /// Inner-step index (global, per run) at which it happened.
    pub at_inner_step: u64,
}

/// Ledger of all communications — the observable behind Theorem 2's
/// C(N) and the "communication efficiency" axis of Fig. 1.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    /// Every recorded communication, in completion order.
    pub events: Vec<CommEvent>,
    /// Events recorded before a checkpoint this run resumed from (the
    /// events themselves live in the pre-resume run; only the counters
    /// carry over so `count`/`total_bytes` continue the original
    /// sequence bit-for-bit — DESIGN.md §8 resume semantics).
    base_count: usize,
    /// Bytes recorded before the resume point.
    base_bytes: u64,
    /// WAN-tier bytes recorded before the resume point.
    base_wan_bytes: u64,
}

impl CommLedger {
    /// Append one communication.
    pub fn record(&mut self, ev: CommEvent) {
        self.events.push(ev);
    }

    /// Seed the counters with a resumed run's pre-checkpoint totals, so
    /// every later `count()`/`total_bytes()`/`wan_bytes()` read matches
    /// the uninterrupted run exactly (checkpoint/resume contract).
    pub fn resume_from(&mut self, count: usize, bytes: u64, wan_bytes: u64) {
        self.base_count = count;
        self.base_bytes = bytes;
        self.base_wan_bytes = wan_bytes;
    }

    /// Total recorded communications (including any resumed-from base).
    pub fn count(&self) -> usize {
        self.base_count + self.events.len()
    }

    /// Recorded communications of one kind (post-resume events only; the
    /// resumed base is not broken down by kind).
    pub fn count_kind(&self, kind: CommKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Total bytes across all recorded communications (including any
    /// resumed-from base).
    pub fn total_bytes(&self) -> u64 {
        self.base_bytes + self.events.iter().map(|e| e.bytes).sum::<u64>()
    }

    /// Bytes that crossed the WAN tier (== [`Self::total_bytes`] on a
    /// flat cluster) — the axis the hierarchical topology shrinks.
    pub fn wan_bytes(&self) -> u64 {
        self.base_wan_bytes
            + self
                .events
                .iter()
                .filter(|e| e.scope == CommScope::Wan)
                .map(|e| e.bytes)
                .sum::<u64>()
    }

    /// Total bytes of one event kind.
    pub fn bytes_kind(&self, kind: CommKind) -> u64 {
        self.events.iter().filter(|e| e.kind == kind).map(|e| e.bytes).sum()
    }

    /// Cumulative (inner_step, count) series for C(N) plots.
    pub fn cumulative_by_step(&self) -> Vec<(u64, usize)> {
        let mut evs: Vec<&CommEvent> = self.events.iter().collect();
        evs.sort_by_key(|e| e.at_inner_step);
        evs.iter()
            .enumerate()
            .map(|(i, e)| (e.at_inner_step, i + 1))
            .collect()
    }
}

/// One network phase of a priced communication — the ledger row it
/// will produce once the rendezvous completes.
#[derive(Clone, Debug)]
pub struct CommPhase {
    /// Tier the phase ran on.
    pub scope: CommScope,
    /// Ledger bytes of the phase (the collective's closed form).
    pub bytes: u64,
    /// Members of the phase (workers/trainers intra, group leaders on
    /// the WAN).
    pub participants: usize,
}

/// A priced communication: total modeled transfer seconds plus the
/// ledger phases. Intra-group phases run concurrently across groups
/// (time = max over groups); the WAN phase runs after them (adds).
#[derive(Clone, Debug)]
pub struct CommCost {
    /// Modeled seconds the participants spend in the transfer (what
    /// the barrier charges as comm time).
    pub time_s: f64,
    /// Ledger rows (empty when nothing moved, e.g. one participant).
    pub phases: Vec<CommPhase>,
}

impl CommCost {
    /// Total ledger bytes across phases.
    pub fn total_bytes(&self) -> u64 {
        self.phases.iter().map(|p| p.bytes).sum()
    }
}

/// A non-blocking collective in flight (DESIGN.md §8): the priced cost,
/// when the last contribution was posted, and when the transfer
/// completes. Produced by [`CommLayer::begin_sync`]; the ledger rows
/// land only when [`CommLayer::complete_sync`] retires the handle, so
/// the in-flight byte gauge always balances back to zero at run end.
#[derive(Clone, Debug)]
pub struct SyncHandle {
    /// What the in-flight collective is for.
    pub kind: CommKind,
    /// The priced cost (duration + ledger phases) captured at post time,
    /// including any scenario bandwidth factor then in effect.
    pub cost: CommCost,
    /// Virtual time the last participant posted its contribution.
    pub posted_at: f64,
    /// Virtual time the collective completes (`posted_at + cost.time_s`).
    pub completes_at: f64,
}

/// The comm layer a run owns: the two network tiers, the collectives
/// pricing syncs and merges, and the ledger every phase lands in.
pub struct CommLayer {
    /// Base network: the whole cluster (flat) or the intra-group links
    /// (hierarchical).
    net: NetworkModel,
    /// Inter-group (WAN) network of the hierarchical topology.
    wan: NetworkModel,
    /// Collective pricing outer syncs (`cluster.sync_collective`).
    sync: &'static dyn Collective,
    /// Collective pricing MIT merges (gather at the representative).
    merge: &'static dyn Collective,
    /// Bytes currently travelling in non-blocking collectives (delayed
    /// overlap mode): incremented at `begin_sync`, released at
    /// `complete_sync`. Always zero in blocking mode and at run end.
    in_flight_bytes: u64,
    /// The run-wide communication ledger.
    pub ledger: CommLedger,
}

impl CommLayer {
    /// Build the layer from the cluster config block.
    pub fn new(cfg: &ClusterConfig) -> CommLayer {
        CommLayer {
            net: NetworkModel {
                latency_s: cfg.net_latency_s,
                bandwidth_bps: cfg.net_bandwidth_bps,
            },
            wan: NetworkModel {
                latency_s: cfg.wan_latency_s,
                bandwidth_bps: cfg.wan_bandwidth_bps,
            },
            sync: collective_for(cfg.sync_collective),
            merge: &GATHER,
            in_flight_bytes: 0,
            ledger: CommLedger::default(),
        }
    }

    /// Bytes currently in flight in non-blocking collectives.
    pub fn in_flight_bytes(&self) -> u64 {
        self.in_flight_bytes
    }

    /// Post a non-blocking collective (DESIGN.md §8): the priced cost
    /// starts travelling at `posted_at` and completes `cost.time_s`
    /// later. Nothing lands in the ledger yet — the returned handle is
    /// retired through [`Self::complete_sync`] when the delayed outer
    /// update applies.
    pub fn begin_sync(&mut self, kind: CommKind, cost: CommCost, posted_at: f64) -> SyncHandle {
        self.in_flight_bytes += cost.total_bytes();
        let completes_at = posted_at + cost.time_s;
        SyncHandle { kind, cost, posted_at, completes_at }
    }

    /// Retire an in-flight collective: release its bytes from the
    /// in-flight gauge and land its ledger rows, stamped with the
    /// *completion* time captured at post (the transfer ran concurrently
    /// with compute, so completion — not application — is the honest
    /// timestamp).
    pub fn complete_sync(&mut self, handle: &SyncHandle, at_inner_step: u64) {
        debug_assert!(self.in_flight_bytes >= handle.cost.total_bytes());
        self.in_flight_bytes -= handle.cost.total_bytes();
        self.record(handle.kind, &handle.cost, handle.completes_at, at_inner_step);
    }

    /// Re-adopt an in-flight collective restored from a checkpoint
    /// (resume rebuilds the pending handle; the gauge must account its
    /// bytes again so the eventual `complete_sync` balances).
    pub fn adopt_in_flight(&mut self, handle: &SyncHandle) {
        self.in_flight_bytes += handle.cost.total_bytes();
    }

    /// Flat pricing: one round of `coll` among all `m` members over the
    /// base network; the single phase is WAN-scoped (the shared network
    /// is the flat cluster's wide-area link).
    fn flat(coll: &dyn Collective, bytes: u64, m: usize, net: &NetworkModel) -> CommCost {
        let (time_s, moved) = coll.cost(bytes, m, net);
        let phases = if m > 1 {
            vec![CommPhase { scope: CommScope::Wan, bytes: moved, participants: m }]
        } else {
            Vec::new()
        };
        CommCost { time_s, phases }
    }

    /// Two-level pricing: one round of `coll` inside each node group
    /// (concurrent; the slowest group gates), then one round among the
    /// group leaders over the WAN. Groups enumerate in ascending group
    /// id, so the phase order — and the ledger — is deterministic.
    fn two_level(
        coll: &dyn Collective,
        bytes: u64,
        member_nodes: &[usize],
        topo: &Topology,
        net: &NetworkModel,
        wan: &NetworkModel,
    ) -> CommCost {
        let mut groups: BTreeMap<usize, usize> = BTreeMap::new();
        for &n in member_nodes {
            *groups.entry(topo.group_of(n)).or_insert(0) += 1;
        }
        let mut phases = Vec::new();
        let mut intra_s = 0.0_f64;
        for &g_m in groups.values() {
            let (t, moved) = coll.cost(bytes, g_m, net);
            intra_s = intra_s.max(t);
            if g_m > 1 {
                phases.push(CommPhase {
                    scope: CommScope::Intra,
                    bytes: moved,
                    participants: g_m,
                });
            }
        }
        let leaders = groups.len();
        let (wan_s, wan_moved) = coll.cost(bytes, leaders, wan);
        if leaders > 1 {
            phases.push(CommPhase {
                scope: CommScope::Wan,
                bytes: wan_moved,
                participants: leaders,
            });
        }
        CommCost { time_s: intra_s + wan_s, phases }
    }

    /// Price one outer sync (DiLoCo worker averaging) among the workers
    /// sitting on `member_nodes`. `bw_factor` is the scenario's slowest
    /// participating-link factor at barrier time (1.0 reproduces the
    /// unscaled network bit-for-bit).
    pub fn sync_cost(
        &self,
        param_bytes: u64,
        member_nodes: &[usize],
        topo: &Topology,
        bw_factor: f64,
    ) -> CommCost {
        if topo.is_hierarchical() {
            Self::two_level(
                self.sync,
                param_bytes,
                member_nodes,
                topo,
                &self.net.scaled(bw_factor),
                &self.wan.scaled(bw_factor),
            )
        } else {
            Self::flat(
                self.sync,
                param_bytes,
                member_nodes.len(),
                &self.net.scaled(bw_factor),
            )
        }
    }

    /// Price one MIT merge (gather at the representative) among the
    /// trainers homed on `home_nodes`. Hierarchically, each group
    /// gathers at its leader on intra links, then `G−1` leaders cross
    /// the WAN — the cheap-local / expensive-global asymmetry the MIT
    /// stage rests on.
    pub fn merge_cost(
        &self,
        param_bytes: u64,
        home_nodes: &[usize],
        topo: &Topology,
        bw_factor: f64,
    ) -> CommCost {
        if topo.is_hierarchical() {
            Self::two_level(
                self.merge,
                param_bytes,
                home_nodes,
                topo,
                &self.net.scaled(bw_factor),
                &self.wan.scaled(bw_factor),
            )
        } else {
            Self::flat(
                self.merge,
                param_bytes,
                home_nodes.len(),
                &self.net.scaled(bw_factor),
            )
        }
    }

    /// Land a priced communication in the ledger: one event per phase,
    /// all stamped with the rendezvous completion time. This is the
    /// single point every `CommEvent` of a run flows through.
    pub fn record(
        &mut self,
        kind: CommKind,
        cost: &CommCost,
        at_virtual_s: f64,
        at_inner_step: u64,
    ) {
        for ph in &cost.phases {
            self.ledger.record(CommEvent {
                kind,
                scope: ph.scope,
                at_virtual_s,
                bytes: ph.bytes,
                participants: ph.participants,
                at_inner_step,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, TopologyKind};

    #[test]
    fn allreduce_time_properties() {
        let net = NetworkModel { latency_s: 1e-3, bandwidth_bps: 1e9 };
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        let t2 = net.allreduce_time(1_000_000, 2);
        let t4 = net.allreduce_time(1_000_000, 4);
        assert!(t2 > 0.0);
        assert!(t4 > t2, "more participants -> more ring hops");
        // bandwidth term approaches 2*bytes/bw from below
        let t_big = net.allreduce_time(1_000_000_000, 4);
        assert!(t_big < 2.0 * 1e9 / 1e9 + 1.0);
    }

    #[test]
    fn scaled_by_one_is_bit_identical() {
        let net = NetworkModel { latency_s: 1e-3, bandwidth_bps: 1.25e9 };
        let s = net.scaled(1.0);
        assert_eq!(s.latency_s.to_bits(), net.latency_s.to_bits());
        assert_eq!(s.bandwidth_bps.to_bits(), net.bandwidth_bps.to_bits());
        assert_eq!(
            s.allreduce_time(4_000_000, 3).to_bits(),
            net.allreduce_time(4_000_000, 3).to_bits()
        );
    }

    #[test]
    fn ledger_accounting() {
        let mut l = CommLedger::default();
        l.record(CommEvent {
            kind: CommKind::OuterSync,
            scope: CommScope::Wan,
            at_virtual_s: 1.0,
            bytes: 100,
            participants: 2,
            at_inner_step: 10,
        });
        l.record(CommEvent {
            kind: CommKind::Merge,
            scope: CommScope::Intra,
            at_virtual_s: 2.0,
            bytes: 50,
            participants: 3,
            at_inner_step: 20,
        });
        assert_eq!(l.count(), 2);
        assert_eq!(l.count_kind(CommKind::OuterSync), 1);
        assert_eq!(l.total_bytes(), 150);
        assert_eq!(l.wan_bytes(), 100, "intra bytes stay off the WAN tally");
        assert_eq!(l.bytes_kind(CommKind::Merge), 50);
        assert_eq!(l.cumulative_by_step(), vec![(10, 1), (20, 2)]);
    }

    #[test]
    fn begin_complete_sync_balances_in_flight_and_records_at_completion() {
        let c = presets::mock_default().cluster;
        let mut layer = CommLayer::new(&c);
        let topo = Topology::compile(&c);
        let cost = layer.sync_cost(1_000, &[0, 1, 2], &topo, 1.0);
        let total = cost.total_bytes();
        let d = cost.time_s;
        assert_eq!(layer.in_flight_bytes(), 0);
        let h = layer.begin_sync(CommKind::OuterSync, cost, 5.0);
        assert_eq!(layer.in_flight_bytes(), total, "posted bytes are in flight");
        assert_eq!(h.posted_at, 5.0);
        assert_eq!(h.completes_at.to_bits(), (5.0 + d).to_bits());
        assert!(layer.ledger.events.is_empty(), "nothing lands before completion");
        layer.complete_sync(&h, 77);
        assert_eq!(layer.in_flight_bytes(), 0, "gauge balances back to zero");
        assert_eq!(layer.ledger.count(), 1);
        let ev = &layer.ledger.events[0];
        assert_eq!(ev.bytes, total);
        assert_eq!(ev.at_inner_step, 77);
        assert_eq!(ev.at_virtual_s.to_bits(), h.completes_at.to_bits());
        // resume adoption re-arms the gauge without touching the ledger
        layer.adopt_in_flight(&h);
        assert_eq!(layer.in_flight_bytes(), total);
        assert_eq!(layer.ledger.count(), 1);
    }

    #[test]
    fn ledger_resume_offsets_continue_the_counters() {
        let mut l = CommLedger::default();
        l.resume_from(3, 600, 400);
        assert_eq!(l.count(), 3);
        assert_eq!(l.total_bytes(), 600);
        assert_eq!(l.wan_bytes(), 400);
        l.record(CommEvent {
            kind: CommKind::OuterSync,
            scope: CommScope::Intra,
            at_virtual_s: 1.0,
            bytes: 50,
            participants: 2,
            at_inner_step: 5,
        });
        assert_eq!(l.count(), 4);
        assert_eq!(l.total_bytes(), 650);
        assert_eq!(l.wan_bytes(), 400, "intra event adds nothing to the WAN tally");
    }

    /// A hierarchical cluster config: 4 nodes grouped [[0,1],[2,3]]
    /// with a WAN 10x slower than the intra links.
    fn hier_cluster() -> crate::config::ClusterConfig {
        let mut c = presets::mock_default().cluster;
        c.topology = TopologyKind::Hierarchical;
        c.groups = vec![vec![0, 1], vec![2, 3]];
        c.wan_latency_s = 10.0 * c.net_latency_s;
        c.wan_bandwidth_bps = c.net_bandwidth_bps / 10.0;
        c
    }

    #[test]
    fn flat_sync_cost_matches_legacy_formulas() {
        let mut c = presets::mock_default().cluster;
        c.topology = TopologyKind::Flat;
        let layer = CommLayer::new(&c);
        let net = NetworkModel { latency_s: c.net_latency_s, bandwidth_bps: c.net_bandwidth_bps };
        let topo = Topology::compile(&c);
        let p = 4_000u64;
        let cost = layer.sync_cost(p, &[0, 1, 2], &topo, 1.0);
        assert_eq!(cost.time_s.to_bits(), net.allreduce_time(p, 3).to_bits());
        assert_eq!(cost.phases.len(), 1);
        assert_eq!(cost.phases[0].bytes, 2 * 2 * p);
        assert_eq!(cost.phases[0].scope, CommScope::Wan);
        // single member: a free barrier, no ledger rows
        let solo = layer.sync_cost(p, &[2], &topo, 1.0);
        assert_eq!(solo.time_s, 0.0);
        assert!(solo.phases.is_empty());
        // merge gather: (k-1)P one way
        let mcost = layer.merge_cost(p, &[0, 1], &topo, 1.0);
        assert_eq!(mcost.time_s.to_bits(), net.transfer_time(p).to_bits());
        assert_eq!(mcost.total_bytes(), p);
    }

    #[test]
    fn hierarchical_sync_conserves_bytes_and_shrinks_wan() {
        let c = hier_cluster();
        let layer = CommLayer::new(&c);
        let topo = Topology::compile(&c);
        let p = 1_000u64;
        // 4 workers spanning both groups, 2 per group
        let cost = layer.sync_cost(p, &[0, 1, 2, 3], &topo, 1.0);
        // phases: intra g0, intra g1, WAN leaders
        assert_eq!(cost.phases.len(), 3);
        let wan: u64 = cost
            .phases
            .iter()
            .filter(|ph| ph.scope == CommScope::Wan)
            .map(|ph| ph.bytes)
            .sum();
        // total conserved vs flat: 2(m-1)P; WAN shrinks to 2(G-1)P
        assert_eq!(cost.total_bytes(), 2 * 3 * p);
        assert_eq!(wan, 2 * p);
        // all members in one group: nothing crosses the WAN
        let local = layer.sync_cost(p, &[0, 1], &topo, 1.0);
        assert_eq!(local.phases.len(), 1);
        assert_eq!(local.phases[0].scope, CommScope::Intra);
        assert_eq!(local.total_bytes(), 2 * p);
    }

    #[test]
    fn hierarchical_merge_splits_gather_by_group() {
        let c = hier_cluster();
        let layer = CommLayer::new(&c);
        let topo = Topology::compile(&c);
        let p = 1_000u64;
        // 3 trainers homed on nodes 0, 1 (group 0) and 2 (group 1):
        // intra gather (2-1)P in group 0, WAN (2-1)P between leaders
        let cost = layer.merge_cost(p, &[0, 1, 2], &topo, 1.0);
        assert_eq!(cost.total_bytes(), 2 * p, "(k-1)P conserved");
        let wan: u64 = cost
            .phases
            .iter()
            .filter(|ph| ph.scope == CommScope::Wan)
            .map(|ph| ph.bytes)
            .sum();
        assert_eq!(wan, p);
        // cross-group WAN leg is priced on the slow tier: strictly
        // slower than the same gather over intra links
        let all_local = layer.merge_cost(p, &[0, 1], &topo, 1.0);
        assert!(cost.time_s > all_local.time_s);
    }
}
