//! Pluggable collective-communication cost models (DESIGN.md §7).
//!
//! A [`Collective`] prices one synchronization round among `m`
//! participants, each contributing a `bytes`-sized payload, over a
//! [`NetworkModel`]: it returns the modeled wall-clock seconds of the
//! round *and* the bytes the [`crate::comm::CommLedger`] charges for it
//! — one closed form per collective, in one place, instead of formulas
//! hand-inlined at every `ledger.record` call site.
//!
//! Closed forms (`α` = link latency, `β` = bandwidth, `B` = bytes,
//! `m` = participants; every collective costs nothing at `m <= 1`):
//!
//! | collective        | time model                       | ledger bytes |
//! |-------------------|----------------------------------|--------------|
//! | ring all-reduce   | `2(m−1)·α + 2(m−1)/m · B/β`      | `2(m−1)·B`   |
//! | tree all-reduce   | `2⌈log₂m⌉ · (α + B/β)`           | `2(m−1)·B`   |
//! | parameter server  | `2α + 2(m−1) · B/β`              | `2(m−1)·B`   |
//! | gather (merge)    | `α + (m−1) · B/β`                | `(m−1)·B`    |
//!
//! Every reduce-style collective moves the same `2(m−1)·B` in total —
//! they differ in *when* and *how parallel* the wire is used. The merge
//! gather moves half: MIT DoMerge parameters flow one way, to the
//! representative ([`crate::comm::CommKind::Merge`]'s form; the
//! all-reduce row is [`crate::comm::CommKind::OuterSync`]'s).

use super::NetworkModel;
use crate::config::CollectiveKind;

/// Cost model of one collective round, used as a trait object by the
/// [`crate::comm::CommLayer`] so the collective *shape* (who talks to
/// whom, when) is a pluggable config axis.
pub trait Collective: Sync {
    /// Canonical lowercase name (bench / debug output).
    fn name(&self) -> &'static str;

    /// `(seconds, ledger_bytes)` for `m` members exchanging `bytes`
    /// each over `net`. `m <= 1` costs `(0.0, 0)`.
    fn cost(&self, bytes: u64, m: usize, net: &NetworkModel) -> (f64, u64);
}

/// Ring all-reduce — the DiLoCo outer-sync default. The time side is
/// [`NetworkModel::allreduce_time`] (the formula the simulator has
/// always used); the ledger side is the `2(m−1)·B` reduce-scatter +
/// all-gather wire total.
pub struct RingAllReduce;

impl Collective for RingAllReduce {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn cost(&self, bytes: u64, m: usize, net: &NetworkModel) -> (f64, u64) {
        if m <= 1 {
            return (0.0, 0);
        }
        (net.allreduce_time(bytes, m), 2 * (m as u64 - 1) * bytes)
    }
}

/// Binary-tree all-reduce: reduce up `⌈log₂m⌉` levels then broadcast
/// back down, each level one full-payload hop. Fewer latency terms
/// than the ring at large `m`, more bandwidth-serial at small `m`.
pub struct TreeAllReduce;

impl Collective for TreeAllReduce {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn cost(&self, bytes: u64, m: usize, net: &NetworkModel) -> (f64, u64) {
        if m <= 1 {
            return (0.0, 0);
        }
        // ceil(log2 m) = bit length of m-1 for m >= 2
        let levels = (usize::BITS - (m - 1).leading_zeros()) as f64;
        let per_level = net.latency_s + bytes as f64 / net.bandwidth_bps;
        (2.0 * levels * per_level, 2 * (m as u64 - 1) * bytes)
    }
}

/// Central parameter server: `m−1` members upload, the server reduces
/// and broadcasts back. The server link serializes both directions, so
/// time is linear in `m` — the worst scaling of the three, kept as the
/// classic baseline shape.
pub struct ParameterServer;

impl Collective for ParameterServer {
    fn name(&self) -> &'static str {
        "param_server"
    }

    fn cost(&self, bytes: u64, m: usize, net: &NetworkModel) -> (f64, u64) {
        if m <= 1 {
            return (0.0, 0);
        }
        let moved = 2 * (m as u64 - 1) * bytes;
        (2.0 * net.latency_s + moved as f64 / net.bandwidth_bps, moved)
    }
}

/// Gather at the representative — the MIT DoMerge movement: `m−1`
/// members each ship their parameters one way over a shared link
/// (time is [`NetworkModel::transfer_time`] of the whole payload).
pub struct GatherMerge;

impl Collective for GatherMerge {
    fn name(&self) -> &'static str {
        "gather"
    }

    fn cost(&self, bytes: u64, m: usize, net: &NetworkModel) -> (f64, u64) {
        if m <= 1 {
            return (0.0, 0);
        }
        let moved = (m as u64 - 1) * bytes;
        (net.transfer_time(moved), moved)
    }
}

/// The ring instance behind [`CollectiveKind::Ring`].
pub static RING: RingAllReduce = RingAllReduce;
/// The tree instance behind [`CollectiveKind::Tree`].
pub static TREE: TreeAllReduce = TreeAllReduce;
/// The parameter-server instance behind [`CollectiveKind::ParamServer`].
pub static PARAM_SERVER: ParameterServer = ParameterServer;
/// The gather instance pricing every MIT merge.
pub static GATHER: GatherMerge = GatherMerge;

/// Resolve a configured sync collective to its trait object.
pub fn collective_for(kind: CollectiveKind) -> &'static dyn Collective {
    match kind {
        CollectiveKind::Ring => &RING,
        CollectiveKind::Tree => &TREE,
        CollectiveKind::ParamServer => &PARAM_SERVER,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> NetworkModel {
        NetworkModel { latency_s: 1e-3, bandwidth_bps: 1e9 }
    }

    #[test]
    fn singletons_cost_nothing() {
        for c in [&RING as &dyn Collective, &TREE, &PARAM_SERVER, &GATHER] {
            assert_eq!(c.cost(1_000_000, 1, &net()), (0.0, 0), "{}", c.name());
            assert_eq!(c.cost(1_000_000, 0, &net()), (0.0, 0), "{}", c.name());
        }
    }

    #[test]
    fn ring_matches_network_model_and_ledger_form() {
        let n = net();
        for m in [2usize, 3, 8] {
            let (t, b) = RING.cost(4_000_000, m, &n);
            assert_eq!(t.to_bits(), n.allreduce_time(4_000_000, m).to_bits());
            assert_eq!(b, 2 * (m as u64 - 1) * 4_000_000);
        }
    }

    #[test]
    fn gather_matches_transfer_time_and_half_bytes() {
        let n = net();
        for m in [2usize, 4] {
            let (t, b) = GATHER.cost(1_000_000, m, &n);
            assert_eq!(b, (m as u64 - 1) * 1_000_000);
            assert_eq!(t.to_bits(), n.transfer_time(b).to_bits());
        }
    }

    #[test]
    fn reduce_collectives_move_identical_totals() {
        let n = net();
        for m in [2usize, 5, 16] {
            let (_, ring_b) = RING.cost(123_456, m, &n);
            let (_, tree_b) = TREE.cost(123_456, m, &n);
            let (_, ps_b) = PARAM_SERVER.cost(123_456, m, &n);
            assert_eq!(ring_b, tree_b);
            assert_eq!(ring_b, ps_b);
        }
    }

    #[test]
    fn tree_levels_are_ceil_log2() {
        let n = NetworkModel { latency_s: 1.0, bandwidth_bps: f64::INFINITY };
        // with infinite bandwidth the time is 2*levels*latency
        let levels = |m: usize| TREE.cost(1, m, &n).0 / 2.0;
        assert_eq!(levels(2), 1.0);
        assert_eq!(levels(3), 2.0);
        assert_eq!(levels(4), 2.0);
        assert_eq!(levels(5), 3.0);
        assert_eq!(levels(8), 3.0);
    }

    #[test]
    fn param_server_scales_linearly() {
        let n = net();
        let (t2, _) = PARAM_SERVER.cost(1_000_000_000, 2, &n);
        let (t4, _) = PARAM_SERVER.cost(1_000_000_000, 4, &n);
        assert!(t4 > 2.0 * t2, "server link serializes uploads: {t2} vs {t4}");
    }

    #[test]
    fn kind_resolution() {
        assert_eq!(collective_for(CollectiveKind::Ring).name(), "ring");
        assert_eq!(collective_for(CollectiveKind::Tree).name(), "tree");
        assert_eq!(collective_for(CollectiveKind::ParamServer).name(), "param_server");
    }
}
