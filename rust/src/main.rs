//! `adloco` — CLI entry point for the AdLoCo reproduction.
//!
//! Subcommands:
//!   train      run one experiment from a preset/config (+ --set overrides)
//!   compare    run several methods on the same setup and tabulate them
//!   calibrate  measure real PJRT step times and fit the simulator model
//!   inspect    print an artifact profile's metadata
//!   presets    list named presets
//!   serve      long-lived daemon: submit/steer runs over HTTP
//!
//! Examples:
//!   adloco train --preset quick
//!   adloco train --preset hetero_dynamic --threads 4
//!   adloco train --preset hierarchical_mit --topology flat   # WAN-bytes baseline
//!   adloco train --preset adloco_overlap                     # delayed outer syncs
//!   adloco train --preset hetero_dynamic --overlap delayed   # same knob, any preset
//!   adloco train --preset elastic_mit                        # elastic lifecycle on
//!   adloco train --preset hetero_dynamic --elastic respawn_after_merge
//!   adloco train --preset xla_tiny --set algo.outer_steps=4 --out runs
//!   adloco train --preset quick --checkpoint runs/q.ckpt --keep-checkpoints 3
//!   adloco train --preset quick --resume runs/q.ckpt.000004    # exact resume
//!   adloco compare --preset mock_default --methods adloco,diloco,localsgd
//!   adloco sweep --preset quick --param algo.batching.eta \
//!       --values 0.4,0.8,1.6 --jobs 4
//!   adloco calibrate --profile tiny
//!   adloco serve --port 7700 --max-runs 2 --out runs/service
//!
//! `--threads N` drives the in-run parallel execution runtime; `--jobs N`
//! parallelizes sweep grids across cells. Both are bit-identical to their
//! serial counterparts (DESIGN.md §6).

use adloco::cli;
use adloco::config::{presets, Config, Method};
use adloco::coordinator::{resolve_policy, run_experiment, RunResult};
use adloco::engine::TrainEngine;
use adloco::util::logger;
use anyhow::{bail, Context, Result};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = cli::parse(argv)?;
    if let Some(lvl) = args.opt("log-level") {
        logger::set_max_level(match lvl {
            "error" => logger::Level::Error,
            "warn" => logger::Level::Warn,
            "info" => logger::Level::Info,
            "debug" => logger::Level::Debug,
            "trace" => logger::Level::Trace,
            other => bail!("unknown log level {other:?}"),
        });
    }
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("compare") => cmd_compare(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("report") => cmd_report(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("serve") => cmd_serve(&args),
        Some("presets") => {
            for name in presets::preset_names() {
                println!("{name}");
            }
            Ok(())
        }
        Some(other) => {
            bail!("unknown subcommand {other:?} (try: train, compare, calibrate, inspect, report, sweep, serve, presets)")
        }
        None => {
            println!("adloco — AdLoCo distributed-training reproduction");
            println!("usage: adloco <train|compare|calibrate|inspect|report|sweep|serve|presets> [options]");
            Ok(())
        }
    }
}

fn load_config(args: &cli::Args) -> Result<Config> {
    let mut cfg = match (args.opt("config"), args.opt("preset")) {
        (Some(path), _) => Config::load(path)?,
        (None, Some(name)) => {
            presets::by_name(name).with_context(|| format!("unknown preset {name:?}"))?
        }
        (None, None) => presets::mock_default(),
    };
    for spec in args.opt_all("set") {
        cfg.apply_override(spec)?;
    }
    if let Some(out) = args.opt("out") {
        cfg.out_dir = Some(out.to_string());
    }
    if let Some(seed) = args.opt_parse::<u64>("seed")? {
        cfg.seed = seed;
    }
    if let Some(t) = args.opt_parse::<f64>("target-ppl")? {
        cfg.run.target_ppl = t;
    }
    if let Some(n) = args.opt_parse::<usize>("threads")? {
        cfg.run.threads = n;
    }
    if let Some(t) = args.opt("topology") {
        cfg.cluster.topology = adloco::config::TopologyKind::parse(t)?;
    }
    if let Some(o) = args.opt("overlap") {
        cfg.comm.overlap = adloco::config::OverlapMode::parse(o)?;
    }
    if let Some(e) = args.opt("elastic") {
        cfg.algo.elastic.mode = adloco::config::ElasticMode::parse(e)?;
    }
    if let Some(p) = args.opt("checkpoint") {
        cfg.run.checkpoint_path = Some(p.to_string());
    }
    if let Some(p) = args.opt("resume") {
        cfg.run.resume_from = Some(p.to_string());
    }
    if let Some(n) = args.opt_parse::<usize>("keep-checkpoints")? {
        cfg.run.keep_checkpoints = n;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn print_result(r: &RunResult) {
    println!("== {} ({}) ==", r.name, r.method.as_str());
    println!("  best ppl        : {:.4}", r.best_ppl);
    println!("  final ppl       : {:.4}", r.final_ppl);
    println!("  inner steps     : {}", r.total_inner_steps);
    println!("  samples         : {}", r.total_samples);
    println!(
        "  communications  : {} ({} bytes, {} on the WAN)",
        r.comm_count, r.comm_bytes, r.wan_comm_bytes
    );
    println!("  virtual time    : {:.3}s", r.virtual_time_s);
    if r.overlap_hidden_s > 0.0 {
        println!(
            "  overlap hidden  : {:.3}s of collective time under compute",
            r.overlap_hidden_s
        );
    }
    println!("  trainers left   : {}", r.trainers_left);
    if r.spawn_count > 0 {
        println!(
            "  elastic         : {} spawned, {:.2} mean live instances",
            r.spawn_count, r.mean_live_instances
        );
    }
    println!(
        "  utilization     : {:.1}% mean ({:.3}s idle across workers, {:.3}s vacant)",
        r.mean_utilization * 100.0,
        r.total_idle_s,
        r.total_vacant_s
    );
    if let Some((step, t, comms)) = r.time_to_target {
        println!("  time-to-target  : step {step}, {t:.3}s, {comms} comms");
    }
    println!(
        "  wall clock      : {:.3}s on {} thread{}",
        r.wall_clock_s,
        r.threads,
        if r.threads == 1 { "" } else { "s" }
    );
}

fn cmd_train(args: &cli::Args) -> Result<()> {
    let cfg = load_config(args)?;
    adloco::info!("running {} [{}]", cfg.name, cfg.algo.method.as_str());
    let r = run_experiment(cfg)?;
    print_result(&r);
    Ok(())
}

fn cmd_compare(args: &cli::Args) -> Result<()> {
    let methods: Vec<Method> = args
        .opt("methods")
        .unwrap_or("adloco,diloco,localsgd")
        .split(',')
        .map(Method::parse)
        .collect::<Result<_>>()?;
    let base = load_config(args)?;
    let mut rows = Vec::new();
    for m in methods {
        let mut cfg = base.clone();
        cfg.algo.method = m;
        cfg.name = format!("{}_{}", base.name, m.as_str());
        let cfg = resolve_policy(&cfg);
        adloco::info!("running {}", cfg.name);
        rows.push(run_experiment(cfg)?);
    }
    println!(
        "{:<26} {:>10} {:>10} {:>8} {:>12} {:>10}",
        "run", "best_ppl", "final_ppl", "comms", "samples", "vtime_s"
    );
    for r in &rows {
        println!(
            "{:<26} {:>10.4} {:>10.4} {:>8} {:>12} {:>10.3}",
            r.name, r.best_ppl, r.final_ppl, r.comm_count, r.total_samples, r.virtual_time_s
        );
    }
    Ok(())
}

/// Measure real PJRT step times across the ladder and fit the simulator's
/// step-time model t = a + b * batch * seq (printed as config overrides).
fn cmd_calibrate(args: &cli::Args) -> Result<()> {
    let profile = args.opt("profile").unwrap_or("tiny");
    let dir = args.opt("artifacts").unwrap_or("artifacts");
    let engine = adloco::runtime::XlaEngine::load(dir, profile)?;
    let seq = engine.meta().seq_len;
    let vocab = engine.meta().vocab as i64;
    let width = seq + 1;
    let reps = args.opt_parse::<usize>("steps")?.unwrap_or(5);

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    println!("{:>8} {:>12}", "batch", "sec/step");
    let ladder: Vec<usize> = engine.supported_batches().to_vec();
    let mut noise = adloco::util::Rng::new(7); // ignored by the PJRT engine
    for b in ladder {
        let mut state = engine.init_state(0);
        let mut batch = adloco::data::TokenBatch::new(b, width);
        let mut rng = adloco::util::Rng::new(1);
        for t in batch.tokens.iter_mut() {
            *t = rng.range(0, vocab) as i32;
        }
        // one warmup (compile) + timed reps
        engine.train_step(&mut state, 1e-4, &batch, &mut noise)?;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            engine.train_step(&mut state, 1e-4, &batch, &mut noise)?;
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("{b:>8} {per:>12.6}");
        xs.push((b * seq) as f64);
        ys.push(per);
    }
    let (a, b, r2) = adloco::util::stats::linear_fit(&xs, &ys);
    println!("\nfitted: t_step = {a:.6} + {b:.3e} * batch * seq   (r2 = {r2:.4})");
    println!("config overrides:");
    println!("  --set cluster.step_fixed_s={a:.6} --set cluster.step_per_token_s={b:.3e}");
    Ok(())
}

fn cmd_inspect(args: &cli::Args) -> Result<()> {
    let profile = args.opt("profile").unwrap_or("tiny");
    let dir = args.opt("artifacts").unwrap_or("artifacts");
    let meta = adloco::runtime::ArtifactMeta::load(
        std::path::Path::new(dir).join(profile).join("meta.json").as_path(),
    )?;
    println!("profile      : {}", meta.profile);
    println!("params       : {}", meta.param_count);
    println!(
        "model        : vocab={} d_model={} layers={} heads={} seq={}",
        meta.vocab, meta.d_model, meta.n_layers, meta.n_heads, meta.seq_len
    );
    println!(
        "ladder       : {:?}",
        meta.ladder.iter().map(|r| r.batch).collect::<Vec<_>>()
    );
    println!("grad_step    : batch {}", meta.grad_step_batch);
    println!("eval         : batch {}", meta.eval_batch);
    println!("layout ({} tensors):", meta.layout.len());
    for e in &meta.layout {
        println!("  {:<20} {:>10?} @ {}", e.name, e.shape, e.offset);
    }
    Ok(())
}

/// Summarize one or more run JSONL files written by `--out` / examples.
fn cmd_report(args: &cli::Args) -> Result<()> {
    use adloco::util::JsonValue;
    if args.positional.is_empty() {
        bail!("usage: adloco report <run.jsonl> [more.jsonl ...]");
    }
    println!(
        "{:<28} {:>7} {:>10} {:>10} {:>8} {:>8} {:>10}",
        "run", "evals", "first_ppl", "best_ppl", "steps", "merges", "mean_batch"
    );
    for path in &args.positional {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let mut evals = 0usize;
        let mut first_ppl = f64::NAN;
        let mut best_ppl = f64::INFINITY;
        let mut steps = 0u64;
        let mut merges = 0usize;
        let mut batch_sum = 0.0;
        let mut batch_n = 0usize;
        for line in text.lines() {
            let v = JsonValue::parse(line).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            match v.get("type").and_then(|t| t.as_str()) {
                Some("eval") => {
                    let ppl = v.get("perplexity").and_then(|x| x.as_f64()).unwrap_or(f64::NAN);
                    if evals == 0 {
                        first_ppl = ppl;
                    }
                    if ppl < best_ppl {
                        best_ppl = ppl;
                    }
                    evals += 1;
                }
                Some("step") => {
                    steps += 1;
                    if let Some(b) = v.get("batch").and_then(|x| x.as_f64()) {
                        let accum = v.get("accum_steps").and_then(|x| x.as_f64()).unwrap_or(1.0);
                        batch_sum += b * accum;
                        batch_n += 1;
                    }
                }
                Some("merge") => merges += 1,
                _ => {}
            }
        }
        let name = std::path::Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| path.clone());
        println!(
            "{:<28} {:>7} {:>10.3} {:>10.3} {:>8} {:>8} {:>10.1}",
            name,
            evals,
            first_ppl,
            best_ppl,
            steps,
            merges,
            if batch_n > 0 { batch_sum / batch_n as f64 } else { 0.0 }
        );
    }
    Ok(())
}

/// Long-lived daemon: bind `service.addr:service.port` and execute
/// submitted runs on a bounded executor pool. `--addr/--port/--max-runs`
/// shadow the `service.*` config knobs; `--out` picks the run-artifact
/// root (default `runs/service`). Blocks until killed.
fn cmd_serve(args: &cli::Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(a) = args.opt("addr") {
        cfg.service.addr = a.to_string();
    }
    if let Some(p) = args.opt_parse::<u16>("port")? {
        cfg.service.port = p;
    }
    if let Some(n) = args.opt_parse::<usize>("max-runs")? {
        cfg.service.max_concurrent_runs = n;
    }
    cfg.validate()?;
    let root = args.opt("out").unwrap_or("runs/service").to_string();
    std::fs::create_dir_all(&root).with_context(|| format!("creating run root {root}"))?;
    let server = adloco::service::Server::start(cfg.service.clone(), &root)?;
    println!("adloco serve listening on http://{}", server.addr());
    println!("run artifacts under {root}/<id>/");
    println!("try: curl http://{}/health", server.addr());
    loop {
        std::thread::park();
    }
}

/// Grid-sweep one config knob: `adloco sweep --preset X --param
/// algo.batching.eta --values 0.4,0.8,1.6 [--methods adloco,diloco]
/// [--jobs 4]`. `--jobs` fans the grid's cells out across OS threads;
/// cell results are bit-identical to the serial walk (DESIGN.md §6).
fn cmd_sweep(args: &cli::Args) -> Result<()> {
    let base = load_config(args)?;
    let param = args
        .opt("param")
        .ok_or_else(|| anyhow::anyhow!("--param dotted.path required"))?;
    let values: Vec<String> = args
        .opt("values")
        .ok_or_else(|| anyhow::anyhow!("--values v1,v2,... required"))?
        .split(',')
        .map(str::to_string)
        .collect();
    let methods: Vec<Method> = args
        .opt("methods")
        .unwrap_or("adloco")
        .split(',')
        .map(Method::parse)
        .collect::<Result<_>>()?;
    let jobs = args.opt_parse::<usize>("jobs")?.unwrap_or(1);
    let t0 = std::time::Instant::now();
    let rows = adloco::sweep::run_sweep_jobs(&base, param, &values, &methods, jobs)?;
    print!("{}", adloco::sweep::format_table(param, &rows));
    println!(
        "grid wall clock: {:.3}s across {} cells ({} job{})",
        t0.elapsed().as_secs_f64(),
        rows.len(),
        jobs.max(1),
        if jobs.max(1) == 1 { "" } else { "s" }
    );
    Ok(())
}
