//! Theorem 1/2 bound evaluators (paper §5 + Appendix A.1), used by the
//! theory benches to overlay the analytic curves on measured series.
//!
//! Theorem 1 (batch growth):
//!   E[b_k] = Ω( k σ² / (η² L (HM + η²) (F(x₀) − F(x*))) )
//! Theorem 2 (communication complexity, after N accumulation iterations):
//!   E[C(N)] = O( b_max η² L (1 + η²) (F(x₀) − F(x*)) / σ² · ln N )

/// Problem constants entering the bounds.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// Gradient-noise variance σ².
    pub sigma2: f64,
    /// Norm-test constant η.
    pub eta: f64,
    /// Smoothness constant L.
    pub l_smooth: f64,
    /// Inner steps per outer step H.
    pub h: usize,
    /// Workers per trainer M.
    pub m: usize,
    /// Initial optimality gap F(x₀) − F(x*).
    pub f_gap: f64,
    /// Hardware max batch b_max.
    pub b_max: usize,
}

impl BoundParams {
    /// Theorem 1 lower-bound on E[b_k] up to the hidden constant
    /// (`scale` absorbs the Ω(·) constant when fitting measured data).
    pub fn batch_lower_bound(&self, k: u64, scale: f64) -> f64 {
        let denom = self.eta * self.eta
            * self.l_smooth
            * (self.h as f64 * self.m as f64 + self.eta * self.eta)
            * self.f_gap;
        scale * k as f64 * self.sigma2 / denom
    }

    /// Theorem 2 upper-bound on E[C(N)] up to the hidden constant.
    pub fn comm_upper_bound(&self, n: u64, scale: f64) -> f64 {
        if n < 2 {
            return 0.0;
        }
        let num = self.b_max as f64
            * self.eta
            * self.eta
            * self.l_smooth
            * (1.0 + self.eta * self.eta)
            * self.f_gap;
        scale * num / self.sigma2 * (n as f64).ln()
    }
}

/// Fit the hidden constant of a bound to a measured series by least
/// squares on `measured ≈ scale * shape(x)`. Returns (scale, r²) where r²
/// is the goodness of the *shape* match (1.0 = the measured curve is an
/// exact multiple of the analytic one).
pub fn fit_scale(shape: &[f64], measured: &[f64]) -> (f64, f64) {
    assert_eq!(shape.len(), measured.len());
    assert!(!shape.is_empty());
    let num: f64 = shape.iter().zip(measured).map(|(s, m)| s * m).sum();
    let den: f64 = shape.iter().map(|s| s * s).sum();
    let scale = if den > 0.0 { num / den } else { 0.0 };
    // r² of the scaled fit
    let mean = measured.iter().sum::<f64>() / measured.len() as f64;
    let ss_tot: f64 = measured.iter().map(|m| (m - mean) * (m - mean)).sum();
    let ss_res: f64 = shape
        .iter()
        .zip(measured)
        .map(|(s, m)| {
            let e = m - scale * s;
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (scale, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> BoundParams {
        BoundParams {
            sigma2: 1.0,
            eta: 0.8,
            l_smooth: 1.0,
            h: 10,
            m: 2,
            f_gap: 5.0,
            b_max: 64,
        }
    }

    #[test]
    fn batch_bound_linear_in_k() {
        let p = params();
        let b1 = p.batch_lower_bound(100, 1.0);
        let b2 = p.batch_lower_bound(200, 1.0);
        assert!((b2 / b1 - 2.0).abs() < 1e-12, "must be linear in k");
        assert!(b1 > 0.0);
    }

    #[test]
    fn comm_bound_logarithmic_in_n() {
        let p = params();
        let c1 = p.comm_upper_bound(1_000, 1.0);
        let c2 = p.comm_upper_bound(1_000_000, 1.0);
        assert!((c2 / c1 - 2.0).abs() < 1e-9, "ln(N²)/ln(N) = 2");
        assert_eq!(p.comm_upper_bound(1, 1.0), 0.0);
    }

    #[test]
    fn bounds_move_with_constants() {
        let p = params();
        let mut p2 = p;
        p2.sigma2 = 2.0;
        // more noise -> larger batches needed, fewer comms
        assert!(p2.batch_lower_bound(100, 1.0) > p.batch_lower_bound(100, 1.0));
        assert!(p2.comm_upper_bound(1000, 1.0) < p.comm_upper_bound(1000, 1.0));
        let mut p3 = p;
        p3.h = 100;
        assert!(p3.batch_lower_bound(100, 1.0) < p.batch_lower_bound(100, 1.0));
    }

    #[test]
    fn fit_scale_exact_multiple() {
        let shape: Vec<f64> = (1..=50).map(|k| k as f64).collect();
        let measured: Vec<f64> = shape.iter().map(|s| 3.5 * s).collect();
        let (scale, r2) = fit_scale(&shape, &measured);
        assert!((scale - 3.5).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fit_scale_detects_shape_mismatch() {
        let shape: Vec<f64> = (1..=50).map(|k| k as f64).collect();
        // measured is quadratic, shape linear -> r² noticeably below 1
        let measured: Vec<f64> = (1..=50).map(|k| (k * k) as f64).collect();
        let (_, r2) = fit_scale(&shape, &measured);
        assert!(r2 < 0.99, "r2 {r2}");
    }
}
