//! Theorem 1/2 bound evaluators (paper §5 + Appendix A.1), used by the
//! theory benches to overlay the analytic curves on measured series,
//! plus the closed-form communication-volume estimates for the
//! flat/hierarchical topologies (DESIGN.md §7), compared against the
//! measured `CommLedger` in `tests/topology.rs`.
//!
//! The comm estimates hold unchanged on trace-driven runs (DESIGN.md
//! §11): a replayed workload trace moves *when* collectives fire on the
//! virtual clock, never how many run or how many bytes they move, so
//! the closed forms stay exact on traced timelines too — pinned by
//! `tests/trace_replay.rs` against the fleet preset's ledger.
//!
//! Theorem 1 (batch growth):
//!   E[b_k] = Ω( k σ² / (η² L (HM + η²) (F(x₀) − F(x*))) )
//! Theorem 2 (communication complexity, after N accumulation iterations):
//!   E[C(N)] = O( b_max η² L (1 + η²) (F(x₀) − F(x*)) / σ² · ln N )

/// Problem constants entering the bounds.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// Gradient-noise variance σ².
    pub sigma2: f64,
    /// Norm-test constant η.
    pub eta: f64,
    /// Smoothness constant L.
    pub l_smooth: f64,
    /// Inner steps per outer step H.
    pub h: usize,
    /// Workers per trainer M.
    pub m: usize,
    /// Initial optimality gap F(x₀) − F(x*).
    pub f_gap: f64,
    /// Hardware max batch b_max.
    pub b_max: usize,
}

impl BoundParams {
    /// Theorem 1 lower-bound on E[b_k] up to the hidden constant
    /// (`scale` absorbs the Ω(·) constant when fitting measured data).
    pub fn batch_lower_bound(&self, k: u64, scale: f64) -> f64 {
        let denom = self.eta * self.eta
            * self.l_smooth
            * (self.h as f64 * self.m as f64 + self.eta * self.eta)
            * self.f_gap;
        scale * k as f64 * self.sigma2 / denom
    }

    /// Theorem 2 upper-bound on E[C(N)] up to the hidden constant.
    pub fn comm_upper_bound(&self, n: u64, scale: f64) -> f64 {
        if n < 2 {
            return 0.0;
        }
        scale * self.comm_rate() * (n as f64).ln()
    }

    /// The per-instance constant in front of `ln N` in Theorem 2's
    /// bound (everything except the hidden `scale`).
    fn comm_rate(&self) -> f64 {
        self.b_max as f64
            * self.eta
            * self.eta
            * self.l_smooth
            * (1.0 + self.eta * self.eta)
            * self.f_gap
            / self.sigma2
    }

    /// Theorem 2 extended to a **time-varying instance count m(t)**
    /// (the elastic lifecycle, DESIGN.md §9): each live instance
    /// contributes the per-instance `K·ln N` communication rate, so
    /// over a sample axis partitioned into spans `(n_start, n_end, m)`
    /// with `m` instances live,
    ///
    /// `E[C] = scale · K · Σ_spans m · (ln n_end − ln n_start)`.
    ///
    /// A single span `(1, N, 1)` reduces exactly to
    /// [`Self::comm_upper_bound`]; a frozen pool of `m` instances is
    /// the single span `(1, N, m)`. Span starts are clamped to ≥ 1 (so
    /// `ln` is well-defined) and degenerate spans contribute 0.
    pub fn comm_upper_bound_timevarying(&self, spans: &[(u64, u64, usize)], scale: f64) -> f64 {
        let k = scale * self.comm_rate();
        spans
            .iter()
            .map(|&(n0, n1, m)| {
                let n0 = n0.max(1) as f64;
                let n1 = (n1.max(1) as f64).max(n0);
                m as f64 * (n1.ln() - n0.ln())
            })
            .sum::<f64>()
            * k
    }
}

/// Fit the hidden constant of a bound to a measured series by least
/// squares on `measured ≈ scale * shape(x)`. Returns (scale, r²) where r²
/// is the goodness of the *shape* match (1.0 = the measured curve is an
/// exact multiple of the analytic one).
pub fn fit_scale(shape: &[f64], measured: &[f64]) -> (f64, f64) {
    assert_eq!(shape.len(), measured.len());
    assert!(!shape.is_empty());
    let num: f64 = shape.iter().zip(measured).map(|(s, m)| s * m).sum();
    let den: f64 = shape.iter().map(|s| s * s).sum();
    let scale = if den > 0.0 { num / den } else { 0.0 };
    // r² of the scaled fit
    let mean = measured.iter().sum::<f64>() / measured.len() as f64;
    let ss_tot: f64 = measured.iter().map(|m| (m - mean) * (m - mean)).sum();
    let ss_res: f64 = shape
        .iter()
        .zip(measured)
        .map(|(s, m)| {
            let e = m - scale * s;
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (scale, r2)
}

// ---------------------------------------------------------------------------
// Communication-volume estimates (DESIGN.md §7)
//
// Deterministic replays of the comm layer's closed forms: given the
// topology shape of every synchronization and the measured merge
// timeline, predict exactly what the ledger records — event counts,
// total bytes, and the WAN/intra split. On a static cluster the
// prediction is exact (asserted in tests/topology.rs).
// ---------------------------------------------------------------------------

/// Byte split of a predicted communication between network tiers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommBytes {
    /// Bytes on fast intra-group links (0 for flat).
    pub intra: u64,
    /// Bytes on the WAN tier (all bytes, for flat).
    pub wan: u64,
}

impl CommBytes {
    /// Total bytes across both tiers.
    pub fn total(&self) -> u64 {
        self.intra + self.wan
    }
}

/// Topology shape of one synchronization's participant set.
#[derive(Clone, Debug)]
pub enum TopoShape {
    /// Flat cluster: all `m` participants on the one shared network
    /// (WAN-scoped in the ledger).
    Flat {
        /// Participant count.
        m: usize,
    },
    /// Hierarchical cluster: per-group participant counts (one entry
    /// per group that has members; the entry count is G, the number
    /// of group leaders crossing the WAN).
    Hier {
        /// Participants per involved group.
        parts: Vec<usize>,
    },
}

/// The shared event walk of both closed forms: one event per group
/// with ≥ 2 members charging `(gᵢ−1)·wire_bytes` intra, plus one
/// leader event charging `(G−1)·wire_bytes` WAN when G ≥ 2 (flat =
/// one WAN clique). `wire_bytes` is the ledger charge per non-leader
/// edge: `2·P` for all-reduce syncs, `P` for one-way merge gathers.
fn shape_comm(shape: &TopoShape, wire_bytes: u64) -> (usize, CommBytes) {
    match shape {
        TopoShape::Flat { m } => {
            if *m <= 1 {
                return (0, CommBytes::default());
            }
            (1, CommBytes { intra: 0, wan: (*m as u64 - 1) * wire_bytes })
        }
        TopoShape::Hier { parts } => {
            let mut events = 0usize;
            let mut intra = 0u64;
            for &g in parts {
                if g > 1 {
                    events += 1;
                    intra += (g as u64 - 1) * wire_bytes;
                }
            }
            let leaders = parts.len();
            let mut wan = 0u64;
            if leaders > 1 {
                events += 1;
                wan = (leaders as u64 - 1) * wire_bytes;
            }
            (events, CommBytes { intra, wan })
        }
    }
}

/// Predicted ledger rows + bytes of one outer sync (all-reduce ring
/// form): flat `2(m−1)·B` on the WAN in one event; hierarchical
/// `Σᵢ 2(gᵢ−1)·B` intra plus `2(G−1)·B` WAN — the same total, moved
/// off the WAN.
pub fn sync_comm(shape: &TopoShape, param_bytes: u64) -> (usize, CommBytes) {
    shape_comm(shape, 2 * param_bytes)
}

/// Predicted ledger rows + bytes of one MIT merge (gather form): flat
/// `(k−1)·B` WAN; hierarchical `Σᵢ (gᵢ−1)·B = (k−G)·B` intra plus
/// `(G−1)·B` WAN — again byte-conserving, WAN-shrinking.
pub fn merge_comm(shape: &TopoShape, param_bytes: u64) -> (usize, CommBytes) {
    shape_comm(shape, param_bytes)
}

// ---------------------------------------------------------------------------
// Delayed-overlap wall-clock estimate (DESIGN.md §8)
//
// The ACCO-style delayed outer sync hides each round's collective under
// the next round's compute: per applied sync the saving is exactly
// min(comm, time-until-next-boundary). On a static fixed-batch run the
// replay below is not an approximation — the coordinator performs the
// same recurrence, so the prediction matches the measured run to float
// tolerance (asserted in tests/overlap.rs).
// ---------------------------------------------------------------------------

/// Predicted wall-clock outcome of one trainer's delayed-overlap
/// schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OverlapEstimate {
    /// Predicted end-to-end virtual time of the delayed run (through
    /// the final drain).
    pub virtual_time_s: f64,
    /// Predicted end-to-end virtual time of the equivalent blocking run
    /// (`Σ compute + Σ comm`).
    pub blocking_time_s: f64,
    /// Collective seconds hidden under compute:
    /// `Σ_r min(comm_r, compute-until-apply)` — equals
    /// `blocking_time_s − virtual_time_s`.
    pub hidden_s: f64,
    /// Collective seconds the next round's compute could NOT hide (the
    /// residue the workers still stall on): `Σ comm − hidden_s`.
    pub exposed_s: f64,
}

/// Replay the delayed-overlap recurrence for one trainer cohort
/// (DESIGN.md §8): round `r` computes for `compute_s[r]`, posts its
/// collective of duration `comm_s[r]` non-blocking, and applies round
/// `r−1`'s update stalling only for the unhidden residue; the final
/// round's collective drains fully exposed. The two slices must have
/// equal length (one entry per outer round).
///
/// Closed form: the saving versus blocking is
/// `Σ_r min(comm_r, next-round compute + residue)` — every round but
/// the last hides up to its full collective; the last hides nothing.
pub fn estimate_overlap(compute_s: &[f64], comm_s: &[f64]) -> OverlapEstimate {
    assert_eq!(compute_s.len(), comm_s.len(), "one entry per outer round");
    let mut clock = 0.0_f64;
    let mut pending: Option<(f64, f64)> = None; // (completes_at, duration)
    let mut hidden = 0.0_f64;
    let mut exposed_total = 0.0_f64;
    for (&c, &d) in compute_s.iter().zip(comm_s.iter()) {
        clock += c; // the round's compute reaches the boundary
        let completes = clock + d; // post this round's collective
        if let Some((prev_done, prev_d)) = pending.take() {
            // apply the previous round's update: stall only for the
            // residue the compute did not cover
            let exposed = (prev_done - clock).max(0.0);
            clock += exposed;
            hidden += (prev_d - exposed).max(0.0);
            exposed_total += exposed;
        }
        pending = Some((completes, d));
    }
    if let Some((prev_done, prev_d)) = pending.take() {
        // end-of-run drain: nothing left to hide under
        let exposed = (prev_done - clock).max(0.0);
        clock += exposed;
        hidden += (prev_d - exposed).max(0.0);
        exposed_total += exposed;
    }
    let blocking: f64 =
        compute_s.iter().sum::<f64>() + comm_s.iter().sum::<f64>();
    OverlapEstimate {
        virtual_time_s: clock,
        blocking_time_s: blocking,
        hidden_s: hidden,
        exposed_s: exposed_total,
    }
}

/// Predicted whole-run ledger aggregate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LedgerEstimate {
    /// Recorded `CommEvent` count.
    pub events: usize,
    /// Bytes across both tiers.
    pub total_bytes: u64,
    /// Bytes on the WAN tier only.
    pub wan_bytes: u64,
}

/// One planned/measured merge of a run's schedule (chronological).
#[derive(Clone, Debug)]
pub struct MergePlanStep {
    /// Outer step the merge ran at.
    pub outer_step: u64,
    /// Trainers consumed (removed) by the merge.
    pub removed: Vec<usize>,
    /// Representative that carries on.
    pub representative: usize,
}

/// One planned/measured elastic spawn (chronological, DESIGN.md §9):
/// from `outer_step` on, a new instance with cohort `shape` homed in
/// `home_group` syncs every round. Instance ids are assigned in spawn
/// order after the seed pool, matching the coordinator's registry.
#[derive(Clone, Debug)]
pub struct SpawnPlanStep {
    /// Outer step the spawn happened at (the instance syncs from this
    /// step on — spawns land before the round's syncs, after merges).
    pub outer_step: u64,
    /// The spawned instance's worker-cohort shape.
    pub shape: TopoShape,
    /// Home group of the instance (ignored on flat clusters).
    pub home_group: usize,
}

fn fold(est: &mut LedgerEstimate, (events, bytes): (usize, CommBytes)) {
    est.events += events;
    est.total_bytes += bytes.total();
    est.wan_bytes += bytes.wan;
}

/// Replay a run's schedule against the closed forms. `sync_shapes[i]`
/// is trainer `i`'s worker-cohort shape, `home_groups[i]` its home
/// group (ignored when `hierarchical` is false), `merges` the merge
/// timeline (e.g. a run's `MergeRecord`s). The walk matches the
/// coordinator's order on a *static* cluster: merges fire at the top
/// of their outer step, then every live trainer syncs once, for
/// `outer_steps` steps.
pub fn estimate_ledger(
    outer_steps: u64,
    sync_shapes: &[TopoShape],
    home_groups: &[usize],
    hierarchical: bool,
    merges: &[MergePlanStep],
    param_bytes: u64,
) -> LedgerEstimate {
    estimate_ledger_elastic(
        outer_steps,
        sync_shapes,
        home_groups,
        hierarchical,
        merges,
        &[],
        param_bytes,
    )
}

/// [`estimate_ledger`] extended to an **elastic pool** (DESIGN.md §9):
/// the live instance count becomes a function of the round, m(t) —
/// merges shrink it, `spawns` grow it. The walk matches the
/// coordinator's boundary order exactly: at the top of each outer step
/// the merges due fire, then the spawns due join (appending their
/// shapes after the existing pool, like the registry appends ids),
/// then every live instance syncs once. With no spawns this is
/// bit-identical to the historical closed form — the `estimate_ledger`
/// wrapper delegates here with an empty spawn plan.
pub fn estimate_ledger_elastic(
    outer_steps: u64,
    sync_shapes: &[TopoShape],
    home_groups: &[usize],
    hierarchical: bool,
    merges: &[MergePlanStep],
    spawns: &[SpawnPlanStep],
    param_bytes: u64,
) -> LedgerEstimate {
    assert_eq!(sync_shapes.len(), home_groups.len());
    let mut shapes: Vec<TopoShape> = sync_shapes.to_vec();
    let mut homes: Vec<usize> = home_groups.to_vec();
    let mut alive = vec![true; shapes.len()];
    let mut est = LedgerEstimate::default();
    let mut mi = 0usize;
    let mut si = 0usize;
    for t in 1..=outer_steps {
        while mi < merges.len() && merges[mi].outer_step == t {
            let m = &merges[mi];
            let mut parts: Vec<usize> = m.removed.clone();
            parts.push(m.representative);
            let shape = if hierarchical {
                let mut counts: std::collections::BTreeMap<usize, usize> =
                    std::collections::BTreeMap::new();
                for &id in &parts {
                    *counts.entry(homes[id]).or_insert(0) += 1;
                }
                TopoShape::Hier { parts: counts.values().copied().collect() }
            } else {
                TopoShape::Flat { m: parts.len() }
            };
            fold(&mut est, merge_comm(&shape, param_bytes));
            for &dead in &m.removed {
                alive[dead] = false;
            }
            mi += 1;
        }
        while si < spawns.len() && spawns[si].outer_step == t {
            shapes.push(spawns[si].shape.clone());
            homes.push(spawns[si].home_group);
            alive.push(true);
            si += 1;
        }
        for (id, shape) in shapes.iter().enumerate() {
            if alive[id] {
                fold(&mut est, sync_comm(shape, param_bytes));
            }
        }
    }
    est
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> BoundParams {
        BoundParams {
            sigma2: 1.0,
            eta: 0.8,
            l_smooth: 1.0,
            h: 10,
            m: 2,
            f_gap: 5.0,
            b_max: 64,
        }
    }

    #[test]
    fn batch_bound_linear_in_k() {
        let p = params();
        let b1 = p.batch_lower_bound(100, 1.0);
        let b2 = p.batch_lower_bound(200, 1.0);
        assert!((b2 / b1 - 2.0).abs() < 1e-12, "must be linear in k");
        assert!(b1 > 0.0);
    }

    #[test]
    fn comm_bound_logarithmic_in_n() {
        let p = params();
        let c1 = p.comm_upper_bound(1_000, 1.0);
        let c2 = p.comm_upper_bound(1_000_000, 1.0);
        assert!((c2 / c1 - 2.0).abs() < 1e-9, "ln(N²)/ln(N) = 2");
        assert_eq!(p.comm_upper_bound(1, 1.0), 0.0);
    }

    #[test]
    fn bounds_move_with_constants() {
        let p = params();
        let mut p2 = p;
        p2.sigma2 = 2.0;
        // more noise -> larger batches needed, fewer comms
        assert!(p2.batch_lower_bound(100, 1.0) > p.batch_lower_bound(100, 1.0));
        assert!(p2.comm_upper_bound(1000, 1.0) < p.comm_upper_bound(1000, 1.0));
        let mut p3 = p;
        p3.h = 100;
        assert!(p3.batch_lower_bound(100, 1.0) < p.batch_lower_bound(100, 1.0));
    }

    #[test]
    fn fit_scale_exact_multiple() {
        let shape: Vec<f64> = (1..=50).map(|k| k as f64).collect();
        let measured: Vec<f64> = shape.iter().map(|s| 3.5 * s).collect();
        let (scale, r2) = fit_scale(&shape, &measured);
        assert!((scale - 3.5).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fit_scale_detects_shape_mismatch() {
        let shape: Vec<f64> = (1..=50).map(|k| k as f64).collect();
        // measured is quadratic, shape linear -> r² noticeably below 1
        let measured: Vec<f64> = (1..=50).map(|k| (k * k) as f64).collect();
        let (_, r2) = fit_scale(&shape, &measured);
        assert!(r2 < 0.99, "r2 {r2}");
    }

    #[test]
    fn sync_and_merge_forms_conserve_bytes() {
        let p = 1000u64;
        // hierarchical total equals the flat total for the same m
        let flat = sync_comm(&TopoShape::Flat { m: 4 }, p).1;
        let hier = sync_comm(&TopoShape::Hier { parts: vec![2, 2] }, p).1;
        assert_eq!(flat.total(), hier.total());
        assert_eq!(flat.wan, 2 * 3 * p);
        assert_eq!(hier.wan, 2 * p, "only the leader round crosses the WAN");
        let flat_m = merge_comm(&TopoShape::Flat { m: 5 }, p).1;
        let hier_m = merge_comm(&TopoShape::Hier { parts: vec![3, 2] }, p).1;
        assert_eq!(flat_m.total(), hier_m.total());
        assert_eq!(hier_m.intra, 3 * p, "(k-G)P stays intra");
        assert_eq!(hier_m.wan, p, "(G-1)P crosses the WAN");
    }

    #[test]
    fn degenerate_shapes_cost_nothing() {
        let p = 7u64;
        assert_eq!(sync_comm(&TopoShape::Flat { m: 1 }, p), (0, CommBytes::default()));
        assert_eq!(merge_comm(&TopoShape::Flat { m: 1 }, p), (0, CommBytes::default()));
        // one group, one member: no events at all
        let (e, b) = sync_comm(&TopoShape::Hier { parts: vec![1] }, p);
        assert_eq!((e, b.total()), (0, 0));
        // one group, many members: intra only
        let (e, b) = sync_comm(&TopoShape::Hier { parts: vec![3] }, p);
        assert_eq!(e, 1);
        assert_eq!(b.wan, 0);
        assert_eq!(b.intra, 2 * 2 * p);
    }

    #[test]
    fn overlap_estimate_hides_all_but_the_last_collective() {
        // compute far longer than comm: every sync but the last hides
        // fully; the last drains fully exposed
        let compute = vec![1.0; 5];
        let comm = vec![0.01; 5];
        let est = estimate_overlap(&compute, &comm);
        assert!((est.hidden_s - 4.0 * 0.01).abs() < 1e-12, "hidden {}", est.hidden_s);
        assert!((est.exposed_s - 0.01).abs() < 1e-12);
        assert!((est.blocking_time_s - 5.05).abs() < 1e-12);
        assert!(
            (est.blocking_time_s - est.virtual_time_s - est.hidden_s).abs() < 1e-12,
            "saving must equal the hidden total"
        );
    }

    #[test]
    fn overlap_estimate_exposes_comm_longer_than_compute() {
        // comm longer than a round's compute: only the compute-sized
        // part hides; the rest stalls the boundary
        let compute = vec![1.0; 3];
        let comm = vec![2.5; 3];
        let est = estimate_overlap(&compute, &comm);
        // replay by hand (contributions post at the boundary, BEFORE the
        // apply stall — a sync's transfer runs while its cohort waits):
        //   r0: clock 1.0, post c0 (done 3.5)
        //   r1: clock 2.0, post c1 (done 4.5); apply c0: exposed 1.5
        //       -> clock 3.5, hidden 1.0
        //   r2: clock 4.5, post c2 (done 7.0); apply c1: exposed 0.0
        //       -> hidden 2.5
        //   drain c2: exposed 2.5 -> clock 7.0, hidden 0
        assert!((est.virtual_time_s - 7.0).abs() < 1e-12, "{}", est.virtual_time_s);
        assert!((est.hidden_s - 3.5).abs() < 1e-12);
        assert!((est.exposed_s - 4.0).abs() < 1e-12);
        assert!((est.blocking_time_s - 10.5).abs() < 1e-12);
        assert!(
            (est.blocking_time_s - est.virtual_time_s - est.hidden_s).abs() < 1e-12
        );
    }

    #[test]
    fn overlap_estimate_degenerate_cases() {
        assert_eq!(estimate_overlap(&[], &[]), OverlapEstimate::default());
        // zero comm: nothing to hide, delayed == blocking
        let est = estimate_overlap(&[1.0, 2.0], &[0.0, 0.0]);
        assert_eq!(est.hidden_s, 0.0);
        assert!((est.virtual_time_s - 3.0).abs() < 1e-12);
        assert!((est.virtual_time_s - est.blocking_time_s).abs() < 1e-12);
    }

    #[test]
    fn timevarying_comm_bound_reduces_to_theorem_2() {
        let p = params();
        for n in [10u64, 1_000, 1_000_000] {
            let single = p.comm_upper_bound_timevarying(&[(1, n, 1)], 1.0);
            assert!(
                (single - p.comm_upper_bound(n, 1.0)).abs() < 1e-9,
                "single unit span must reduce to the Theorem 2 bound at N={n}"
            );
        }
        // a frozen pool of m instances is m times the per-instance bound
        let frozen = p.comm_upper_bound_timevarying(&[(1, 1000, 4)], 1.0);
        assert!((frozen - 4.0 * p.comm_upper_bound(1000, 1.0)).abs() < 1e-9);
        // splitting a span is additive; growing m(t) mid-run lands
        // strictly between the frozen m_lo and m_hi bounds
        let split = p.comm_upper_bound_timevarying(&[(1, 100, 2), (100, 1000, 2)], 1.0);
        assert!((split - p.comm_upper_bound_timevarying(&[(1, 1000, 2)], 1.0)).abs() < 1e-9);
        let grown = p.comm_upper_bound_timevarying(&[(1, 100, 2), (100, 1000, 3)], 1.0);
        let lo = p.comm_upper_bound_timevarying(&[(1, 1000, 2)], 1.0);
        let hi = p.comm_upper_bound_timevarying(&[(1, 1000, 3)], 1.0);
        assert!(grown > lo && grown < hi, "{lo} < {grown} < {hi}");
        // degenerate spans contribute nothing
        assert_eq!(p.comm_upper_bound_timevarying(&[(5, 5, 9), (7, 3, 9)], 1.0), 0.0);
    }

    #[test]
    fn estimate_ledger_elastic_replays_spawn_timeline() {
        // 1 seed trainer with 2 workers; at t=2 a single-worker spawn
        // joins; flat cluster, 3 outer steps
        let shapes = vec![TopoShape::Flat { m: 2 }];
        let homes = vec![0];
        let spawns = vec![SpawnPlanStep {
            outer_step: 2,
            shape: TopoShape::Flat { m: 1 },
            home_group: 0,
        }];
        let p = 10u64;
        let est = estimate_ledger_elastic(3, &shapes, &homes, false, &[], &spawns, p);
        // the m=1 spawned cohort syncs for free (no peers), so events
        // and bytes match the seed trainer alone...
        assert_eq!(est.events, 3);
        assert_eq!(est.total_bytes, 3 * 2 * p);
        // ...while a 2-worker spawn adds one sync event per remaining
        // round at 2(2-1)P each
        let spawns2 = vec![SpawnPlanStep {
            outer_step: 2,
            shape: TopoShape::Flat { m: 2 },
            home_group: 0,
        }];
        let est2 = estimate_ledger_elastic(3, &shapes, &homes, false, &[], &spawns2, p);
        assert_eq!(est2.events, 3 + 2);
        assert_eq!(est2.total_bytes, 3 * 2 * p + 2 * 2 * p);
        // empty spawn plan delegates to the frozen closed form exactly
        let frozen = estimate_ledger(3, &shapes, &homes, false, &[], p);
        let empty = estimate_ledger_elastic(3, &shapes, &homes, false, &[], &[], p);
        assert_eq!(frozen, empty);
    }

    #[test]
    fn estimate_ledger_elastic_interleaves_merges_and_spawns() {
        // 2 seed trainers (2 workers each); the t=2 merge removes one,
        // and a respawn joins the same round — the round's syncs cover
        // the survivor + the spawn
        let shapes = vec![TopoShape::Flat { m: 2 }, TopoShape::Flat { m: 2 }];
        let homes = vec![0, 0];
        let merges =
            vec![MergePlanStep { outer_step: 2, removed: vec![1], representative: 0 }];
        let spawns = vec![SpawnPlanStep {
            outer_step: 2,
            shape: TopoShape::Flat { m: 2 },
            home_group: 0,
        }];
        let p = 10u64;
        let est = estimate_ledger_elastic(3, &shapes, &homes, false, &merges, &spawns, p);
        // t1: 2 syncs; t2: merge + 2 syncs (survivor + spawn); t3: 2 syncs
        assert_eq!(est.events, 2 + 1 + 2 + 2);
        assert_eq!(est.total_bytes, 6 * 2 * p + p);
    }

    #[test]
    fn estimate_ledger_replays_merge_timeline() {
        // 2 trainers, 2 workers each, flat: one sync apiece per outer
        // step until a merge at t=2 removes trainer 1
        let shapes = vec![TopoShape::Flat { m: 2 }, TopoShape::Flat { m: 2 }];
        let homes = vec![0, 0];
        let merges = vec![MergePlanStep { outer_step: 2, removed: vec![1], representative: 0 }];
        let p = 10u64;
        let est = estimate_ledger(3, &shapes, &homes, false, &merges, p);
        // syncs: t1 both (2 events), t2..t3 only trainer 0 (2 events),
        // plus one merge event
        assert_eq!(est.events, 5);
        // bytes: 4 syncs x 2(2-1)p + merge (2-1)p
        assert_eq!(est.total_bytes, 4 * 2 * p + p);
        assert_eq!(est.wan_bytes, est.total_bytes, "flat: everything is WAN");
    }
}
