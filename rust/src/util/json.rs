//! Minimal JSON parser + serializer (no `serde` in the offline crate set).
//!
//! Used for three interchange points: `artifacts/<profile>/meta.json`
//! written by the python AOT path, experiment config files, and metric /
//! result dumps consumed by the plotting-free report generators.
//!
//! Supports the full JSON grammar except for exotic number forms beyond
//! f64 (all numbers are f64, which matches what python's `json` emits for
//! this repo's data). Object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON document.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64, matching python's `json`).
    Number(f64),
    /// A string.
    String(String),
    /// An array.
    Array(Vec<JsonValue>),
    /// Insertion-ordered object (Vec keeps meta.json diffs stable).
    Object(Vec<(String, JsonValue)>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl JsonValue {
    // ---------------- accessors ----------------

    /// Object field by key (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// `get` that errors with a path-aware message — meta.json parsing
    /// should fail loudly, not with unwraps.
    pub fn req(&self, key: &str) -> Result<&JsonValue, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            offset: 0,
        })
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer value, if representable.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64 * 4096.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Object fields in insertion order, if this is an object.
    pub fn as_object(&self) -> Option<&[(String, JsonValue)]> {
        match self {
            JsonValue::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: object -> BTreeMap view (copies keys).
    pub fn to_map(&self) -> BTreeMap<String, JsonValue> {
        match self {
            JsonValue::Object(o) => o.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---------------- construction helpers ----------------

    /// Object from (key, value) pairs.
    pub fn obj(fields: Vec<(&str, JsonValue)>) -> JsonValue {
        JsonValue::Object(
            fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    /// Number value.
    pub fn num(n: f64) -> JsonValue {
        JsonValue::Number(n)
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> JsonValue {
        JsonValue::String(s.into())
    }

    // ---------------- serialization ----------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Indented multi-line serialization.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => write_number(out, *n),
            JsonValue::String(s) => write_escaped(out, s),
            JsonValue::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            JsonValue::Object(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---------------- parsing ----------------

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<JsonValue, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no Inf/NaN; emit null like python's json.dumps(allow_nan=False) would reject —
        // we choose null so metric dumps stay loadable.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<JsonValue, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<JsonValue, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(JsonValue::Number)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse("-3.5e2").unwrap(), JsonValue::Number(-350.0));
        assert_eq!(
            JsonValue::parse("\"a\\nb\"").unwrap(),
            JsonValue::String("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = JsonValue::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&JsonValue::Null));
    }

    #[test]
    fn roundtrip_preserves_structure() {
        let src = r#"{"m":{"x":1,"y":[true,false,null]},"s":"q\"uote","n":-0.125}"#;
        let v = JsonValue::parse(src).unwrap();
        let v2 = JsonValue::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = JsonValue::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escapes() {
        let v = JsonValue::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("01x").is_err());
        assert!(JsonValue::parse("\"unterminated").is_err());
        assert!(JsonValue::parse("{} trailing").is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = JsonValue::Number(117056.0);
        assert_eq!(v.to_string(), "117056");
    }

    #[test]
    fn object_key_order_preserved() {
        let v = JsonValue::parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<&str> =
            v.as_object().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn parses_real_meta_json_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/meta.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = JsonValue::parse(&text).unwrap();
            assert_eq!(v.get("profile").unwrap().as_str(), Some("tiny"));
            assert!(v.get("param_count").unwrap().as_usize().unwrap() > 0);
        }
    }
}
