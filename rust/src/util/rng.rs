//! Deterministic PRNG: xoshiro256** seeded through splitmix64.
//!
//! Every stochastic component in the coordinator (data sampling, trainer
//! initialization jitter, the MockEngine's gradient noise, the network
//! jitter model) draws from an explicitly-seeded `Rng`, so whole
//! experiment runs are bit-reproducible from the config seed.

/// xoshiro256** 1.0 (Blackman & Vigna), public domain reference algorithm.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Raw generator state: the four xoshiro words plus the cached
    /// Box-Muller spare. Together with [`Rng::from_state`] this makes a
    /// stream checkpointable mid-sequence — the resumed stream continues
    /// draw-for-draw where the saved one stopped (checkpoint contract,
    /// DESIGN.md §8).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from a captured [`Rng::state`].
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Rng {
        Rng { s, gauss_spare }
    }

    /// Derive an independent stream (e.g. per trainer / per worker).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "range({lo}, {hi})");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with explicit mean / standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices({n}, {k})");
        // Partial Fisher-Yates over an index vector; O(n) setup is fine
        // for the dataset sizes involved.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from a Zipf(s) distribution over {0, .., n-1} via inverse CDF
    /// on a precomputed table (see `ZipfTable`).
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        table.sample(self)
    }
}

/// Deterministically derive an independent seed from a base seed and a
/// textual tag: FNV-1a over the tag folded into the base, finalized
/// through splitmix64. A pure function of its inputs — the parallel
/// sweep derives each cell's seed this way (DESIGN.md §6), so cell
/// results are independent of which thread runs which cell and of the
/// grid's enumeration order.
pub fn derive_seed(base: u64, tag: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325; // FNV-1a offset basis
    for &b in tag.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3); // FNV prime
    }
    let mut sm = base ^ h;
    splitmix64(&mut sm)
}

/// Precomputed inverse-CDF table for Zipf-distributed token sampling.
/// Heavy-tailed unigram statistics are the property of natural-language
/// corpora that adaptive batching reacts to (gradient noise dominated by
/// rare tokens), so the synthetic corpus generator uses this.
#[derive(Clone, Debug)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    /// Table over `{0, .., n-1}` with exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        ZipfTable { cdf }
    }

    /// Support size.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True for an empty support (never constructed; `new` asserts).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// One inverse-CDF draw using `rng`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Rng::new(99);
        // advance past a normal() so the Box-Muller spare is populated
        let _ = a.normal();
        let (s, spare) = a.state();
        assert!(spare.is_some(), "box-muller caches its second output");
        let mut b = Rng::from_state(s, spare);
        for _ in 0..16 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_gives_distinct_streams() {
        let mut root = Rng::new(1);
        let mut x = root.fork(0);
        let mut y = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| x.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| y.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
    }

    #[test]
    fn zipf_is_heavy_tailed() {
        let table = ZipfTable::new(1000, 1.1);
        let mut r = Rng::new(19);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if table.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // top-10 of 1000 tokens should carry a large share of the mass
        assert!(head as f64 / n as f64 > 0.3, "head share {head}/{n}");
    }

    #[test]
    fn derive_seed_is_stable_and_tag_sensitive() {
        let a = derive_seed(7, "algo.batching.eta=0.4:adloco");
        let b = derive_seed(7, "algo.batching.eta=0.4:adloco");
        assert_eq!(a, b, "pure function of (base, tag)");
        assert_ne!(a, derive_seed(7, "algo.batching.eta=0.8:adloco"));
        assert_ne!(a, derive_seed(8, "algo.batching.eta=0.4:adloco"));
        // derived seeds feed Rng::new; make sure streams differ
        let mut ra = Rng::new(a);
        let mut rb = Rng::new(derive_seed(7, "x"));
        assert_ne!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            let v = r.range(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }
}
