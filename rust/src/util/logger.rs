//! Leveled stderr logger controlled by the `ADLOCO_LOG` env var.
//!
//! Levels: `error < warn < info < debug < trace`; default `info`.
//! Kept free of globals-with-locks on the hot path: the level is read once
//! and cached in an atomic, and the macros skip formatting entirely when
//! the level is disabled.
//!
//! Thread safety (DESIGN.md §6): the parallel runtime logs from worker
//! and sweep-cell threads concurrently. Each record is formatted into a
//! single buffer first and emitted as one `write_all` under stderr's
//! lock, so lines never tear or interleave mid-record. Threads running
//! on behalf of a worker chain or a sweep cell tag their lines via
//! [`set_thread_context`] (e.g. `t2.w1`, `cell3`), so interleaved
//! output stays attributable.

use std::cell::RefCell;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from most to least important.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or user-visible failures.
    Error = 0,
    /// Suspicious-but-continuing conditions.
    Warn = 1,
    /// Run-level progress (default).
    Info = 2,
    /// Per-outer-step diagnostics.
    Debug = 3,
    /// Per-inner-step firehose.
    Trace = 4,
}

impl Level {
    /// Uppercase label used in log lines.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env() -> Level {
        match std::env::var("ADLOCO_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        }
    }
}

const UNINIT: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);

/// Current max enabled level (cached after first call).
pub fn max_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != UNINIT {
        // SAFETY: only valid discriminants are ever stored.
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let lvl = Level::from_env();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (tests, CLI `--log-level`).
pub fn set_max_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// True when records at `lvl` are currently emitted.
#[inline]
pub fn log_enabled(lvl: Level) -> bool {
    lvl <= max_level()
}

/// Seconds (with millis) since process start — cheap monotonic timestamps.
pub fn uptime_secs() -> f64 {
    use std::time::Instant;
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

thread_local! {
    /// Worker/cell tag of the current thread (None on the main thread).
    static THREAD_CONTEXT: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Tag every subsequent log line from this thread with `tag` (the
/// parallel runtime uses `t<trainer>.w<worker>`; sweep cells use
/// `cell<i>`). Overwrites any previous tag.
pub fn set_thread_context(tag: impl Into<String>) {
    let tag = tag.into();
    THREAD_CONTEXT.with(|c| *c.borrow_mut() = Some(tag));
}

/// Re-tag this thread **in place**: formats `args` into the existing
/// tag `String`, reusing its capacity, so steady-state re-tagging (a
/// pool thread switching from `p<t>` to `cell<k>` or `t<i>.w<j>` every
/// round — DESIGN.md §14) performs no heap allocation once the buffer
/// has grown to its working size.
pub fn set_thread_context_args(args: std::fmt::Arguments<'_>) {
    use std::fmt::Write as _;
    THREAD_CONTEXT.with(|c| {
        let mut slot = c.borrow_mut();
        match slot.as_mut() {
            Some(s) => {
                s.clear();
                let _ = s.write_fmt(args);
            }
            None => {
                let mut s = String::new();
                let _ = s.write_fmt(args);
                *slot = Some(s);
            }
        }
    });
}

/// Remove this thread's log tag.
pub fn clear_thread_context() {
    THREAD_CONTEXT.with(|c| *c.borrow_mut() = None);
}

/// This thread's current log tag, if any (lets nested fan-outs save
/// and restore the caller's tag — see `util::parallel::run_cells`).
pub fn thread_context() -> Option<String> {
    THREAD_CONTEXT.with(|c| c.borrow().clone())
}

#[doc(hidden)]
pub fn log_impl(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    // format the whole record (timestamp, level, module, thread tag,
    // message) into one buffer, then emit it as a single write under
    // stderr's own lock — records from concurrent worker/cell threads
    // interleave only at line granularity, never mid-record
    let line = THREAD_CONTEXT.with(|c| match c.borrow().as_deref() {
        Some(tag) => format!(
            "[{:>9.3}s {} {} {}] {}\n",
            uptime_secs(),
            lvl.as_str(),
            module,
            tag,
            args
        ),
        None => format!("[{:>9.3}s {} {}] {}\n", uptime_secs(), lvl.as_str(), module, args),
    });
    let _ = std::io::stderr().lock().write_all(line.as_bytes());
}

/// Log at an explicit [`Level`]; prefer the per-level macros.
#[macro_export]
macro_rules! log_at {
    ($lvl:expr, $($arg:tt)*) => {
        if $crate::util::logger::log_enabled($lvl) {
            $crate::util::logger::log_impl($lvl, module_path!(), format_args!($($arg)*));
        }
    };
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Error, $($arg)*) };
}
/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Warn, $($arg)*) };
}
/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Info, $($arg)*) };
}
/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Debug, $($arg)*) };
}
/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Trace, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        set_max_level(Level::Debug);
        assert!(log_enabled(Level::Debug));
        assert!(!log_enabled(Level::Trace));
        set_max_level(Level::Info);
    }

    #[test]
    fn thread_context_is_per_thread() {
        set_thread_context("t0.w1");
        THREAD_CONTEXT.with(|c| assert_eq!(c.borrow().as_deref(), Some("t0.w1")));
        // a fresh thread starts untagged and its tag stays its own
        std::thread::spawn(|| {
            THREAD_CONTEXT.with(|c| assert!(c.borrow().is_none()));
            set_thread_context("cell7");
            THREAD_CONTEXT.with(|c| assert_eq!(c.borrow().as_deref(), Some("cell7")));
        })
        .join()
        .unwrap();
        THREAD_CONTEXT.with(|c| assert_eq!(c.borrow().as_deref(), Some("t0.w1")));
        clear_thread_context();
        THREAD_CONTEXT.with(|c| assert!(c.borrow().is_none()));
    }

    #[test]
    fn context_args_rewrites_in_place() {
        clear_thread_context();
        set_thread_context_args(format_args!("t{}.w{}", 3, 41));
        assert_eq!(thread_context().as_deref(), Some("t3.w41"));
        let cap_before = THREAD_CONTEXT.with(|c| c.borrow().as_ref().unwrap().capacity());
        // a shorter rewrite must reuse the same buffer (no realloc)
        set_thread_context_args(format_args!("p{}", 1));
        assert_eq!(thread_context().as_deref(), Some("p1"));
        let cap_after = THREAD_CONTEXT.with(|c| c.borrow().as_ref().unwrap().capacity());
        assert_eq!(cap_before, cap_after, "in-place rewrite must not reallocate");
        clear_thread_context();
    }

    #[test]
    fn concurrent_logging_does_not_panic() {
        // tears can't be asserted from inside the process, but the
        // emission path (including context formatting) must be race-free
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    set_thread_context(format!("t{i}.w0"));
                    for j in 0..50 {
                        log_impl(
                            Level::Error,
                            "logger::test",
                            format_args!("thread {i} line {j}"),
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
