//! Leveled stderr logger controlled by the `ADLOCO_LOG` env var.
//!
//! Levels: `error < warn < info < debug < trace`; default `info`.
//! Kept free of globals-with-locks on the hot path: the level is read once
//! and cached in an atomic, and the macros skip formatting entirely when
//! the level is disabled.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env() -> Level {
        match std::env::var("ADLOCO_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        }
    }
}

const UNINIT: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);

/// Current max enabled level (cached after first call).
pub fn max_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != UNINIT {
        // SAFETY: only valid discriminants are ever stored.
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let lvl = Level::from_env();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (tests, CLI `--log-level`).
pub fn set_max_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

#[inline]
pub fn log_enabled(lvl: Level) -> bool {
    lvl <= max_level()
}

/// Seconds (with millis) since process start — cheap monotonic timestamps.
pub fn uptime_secs() -> f64 {
    use std::time::Instant;
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

#[doc(hidden)]
pub fn log_impl(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{:>9.3}s {} {}] {}", uptime_secs(), lvl.as_str(), module, args);
}

#[macro_export]
macro_rules! log_at {
    ($lvl:expr, $($arg:tt)*) => {
        if $crate::util::logger::log_enabled($lvl) {
            $crate::util::logger::log_impl($lvl, module_path!(), format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Error, $($arg)*) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Warn, $($arg)*) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Info, $($arg)*) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Debug, $($arg)*) };
}
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::Level::Trace, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        set_max_level(Level::Debug);
        assert!(log_enabled(Level::Debug));
        assert!(!log_enabled(Level::Trace));
        set_max_level(Level::Info);
    }
}
