//! FNV-1a (64-bit) — the checkpoint interchange's section/file seal and
//! the config structural digest (DESIGN.md §10).
//!
//! Not cryptographic: the seal detects *accidental* damage (truncation,
//! bit flips, torn writes), not forgery. One guarantee matters for the
//! kill-anywhere harness (`tests/crash_fault.rs`) and is worth stating
//! precisely: every byte step `h = (h ^ b) * P` is a bijection of the
//! 64-bit state for a fixed input byte (XOR is an involution, and
//! multiplication by the odd prime `P` is invertible mod 2^64), so two
//! equal-length inputs that differ in **exactly one byte** always hash
//! differently — the diverged states walk through the same remaining
//! bijections and can never re-collide. Single-bit corruption is
//! therefore detected deterministically, not probabilistically;
//! multi-byte damage is detected with overwhelming probability; length
//! changes are caught structurally by the container walk.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte string.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // reference values from the FNV specification's test suite
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn single_byte_difference_always_detected() {
        // the deterministic-detection property the interchange seal
        // relies on: flip any single byte (every bit pattern) at every
        // position and the hash must change
        let base = b"ADLC interchange seal property".to_vec();
        let h0 = fnv1a(&base);
        for pos in 0..base.len() {
            for flip in 1..=255u8 {
                let mut m = base.clone();
                m[pos] ^= flip;
                assert_ne!(fnv1a(&m), h0, "collision at pos {pos} flip {flip:#x}");
            }
        }
    }
}
