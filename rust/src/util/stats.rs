//! Numeric helpers shared by the controller, metrics and benches.

/// Online mean/variance accumulator (Welford). Numerically stable for the
/// long metric streams the theory benches produce.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Samples folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 before any sample).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (ddof = 1); 0 for fewer than two samples.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Exponential moving average with bias correction (Adam-style), used by
/// the adaptive-batching controller to smooth noisy variance estimates.
#[derive(Clone, Debug)]
pub struct Ema {
    beta: f64,
    value: f64,
    steps: u64,
}

impl Ema {
    /// EMA with smoothing `beta` in [0, 1).
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta must be in [0,1)");
        Ema { beta, value: 0.0, steps: 0 }
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
        self.steps += 1;
    }

    /// Raw accumulator state `(value, steps)` — checkpointing (beta is
    /// config-derived and not part of the state).
    pub fn state(&self) -> (f64, u64) {
        (self.value, self.steps)
    }

    /// Restore a captured [`Ema::state`] (checkpoint resume).
    pub fn set_state(&mut self, value: f64, steps: u64) {
        self.value = value;
        self.steps = steps;
    }

    /// Bias-corrected estimate; None before any sample.
    pub fn get(&self) -> Option<f64> {
        if self.steps == 0 {
            None
        } else {
            // saturate instead of `as i32` (which wraps above i32::MAX and
            // could flip the exponent sign); the correction term is
            // indistinguishable from 1.0 long before the cap anyway
            let exp = i32::try_from(self.steps).unwrap_or(i32::MAX);
            Some(self.value / (1.0 - self.beta.powi(exp)))
        }
    }

    /// Samples folded in so far.
    pub fn count(&self) -> u64 {
        self.steps
    }
}

/// Percentile of a sample (linear interpolation, like numpy's default).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Dot product over f32 slices (hot path of merge / outer step checks).
/// Delegates to the vectorized kernel; summation follows the fixed
/// chunked order of DESIGN.md §12.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    super::vecmath::dot_f32(a, b)
}

/// Squared L2 norm of an f32 slice, accumulated in f64 (chunked order,
/// DESIGN.md §12).
#[inline]
pub fn norm_sq_f32(a: &[f32]) -> f64 {
    super::vecmath::norm_sq_f32(a)
}

/// `y += alpha * x` (axpy) over f32 slices. Elementwise — bit-identical
/// to the serial loop regardless of chunking.
#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    super::vecmath::axpy_f32(alpha, x, y)
}

/// Simple ordinary-least-squares fit y ~ a + b*x. Returns (a, b, r2).
/// Used to (1) fit the simulator's step-time model from measured PJRT
/// timings and (2) check Theorem 1/2 curve shapes in the theory benches.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys.iter()) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let a = my - b * mx;
    let r2 = if sxx > 0.0 && syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of that classic set is 32/7
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn ema_bias_correction() {
        let mut e = Ema::new(0.9);
        assert!(e.get().is_none());
        e.push(10.0);
        // after one sample the bias-corrected value equals the sample
        assert!((e.get().unwrap() - 10.0).abs() < 1e-12);
        for _ in 0..200 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ema_bias_correction_saturates_huge_step_counts() {
        // regression: `steps as i32` used to wrap above i32::MAX, flipping
        // the exponent sign and corrupting the correction factor
        let mut e = Ema::new(0.9);
        e.set_state(5.0, u64::MAX);
        let got = e.get().unwrap();
        assert!(got.is_finite());
        assert!((got - 5.0).abs() < 1e-12, "correction must be ~1.0 at huge steps, got {got}");
        // just past i32::MAX specifically
        e.set_state(5.0, i32::MAX as u64 + 1);
        assert!((e.get().unwrap() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn vector_ops() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert!((dot_f32(&a, &b) - 32.0).abs() < 1e-9);
        assert!((norm_sq_f32(&a) - 14.0).abs() < 1e-9);
        let mut y = b;
        axpy_f32(2.0, &a, &mut y);
        assert_eq!(y, [6.0f32, 9.0, 12.0]);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }
}
