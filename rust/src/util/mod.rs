//! Small self-contained utilities the rest of the crate builds on.
//!
//! The build runs fully offline against the vendored crate set (see
//! `.cargo/config.toml`), which ships neither `rand`, `serde`, nor a
//! logging facade — so this module provides from-scratch equivalents:
//! a counter-seeded xoshiro256** PRNG, a JSON parser/serializer (used for
//! `artifacts/*/meta.json`, experiment configs and metric dumps), a
//! leveled logger and a handful of numeric helpers.

pub mod alloc_count;
pub mod hash;
pub mod json;
pub mod logger;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod vecmath;

pub use hash::fnv1a;
pub use json::JsonValue;
pub use logger::{clear_thread_context, log_enabled, set_thread_context, Level};
pub use parallel::{run_cells, WorkerPool};
pub use rng::{derive_seed, Rng, ZipfTable};
