//! Persistent work-stealing execution runtime with ordered collection —
//! the one thread-pool primitive every parallel layer shares
//! (DESIGN.md §6, §14): the coordinator's worker chains, the
//! `sweep::run_sweep_jobs` cells, and the fig1/fig2 bench grids
//! (re-exported as `benchkit::run_cells`).
//!
//! The [`WorkerPool`] spawns its OS threads **once** and parks them on a
//! condvar between fan-outs, so a training run costs O(threads) thread
//! spawns instead of O(rounds × threads). [`run_cells`] remains the thin
//! one-shot wrapper for callers that fan out a single time (sweeps,
//! bench grids) and don't want to hold a pool.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Cumulative count of pool OS threads ever spawned by this process.
static THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total pool OS threads spawned by this process so far (cumulative,
/// never reset). A persistent-pool run must grow this by O(threads),
/// not O(rounds × threads) — asserted in `tests/worker_pool.rs`.
pub fn threads_spawned() -> u64 {
    THREADS_SPAWNED.load(Ordering::Relaxed)
}

/// One fan-out generation, type-erased so parked workers can execute
/// arbitrary-lifetime closures. The `'static` here is a lie told under
/// a strict protocol: [`WorkerPool::run`] publishes the reference and
/// does not return until every worker has finished the generation, so
/// the pointee (a stack-local closure inside `run`) strictly outlives
/// every dereference.
type ErasedJob = &'static (dyn Fn() + Sync);

struct PoolState {
    /// Generation counter; bumped once per published job. Workers
    /// remember the last generation they ran so spurious condvar
    /// wakeups and re-locks never re-run a job.
    seq: u64,
    job: Option<ErasedJob>,
    /// Workers still inside the current generation.
    remaining: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Signalled when a new generation is published (or on shutdown).
    work_cv: Condvar,
    /// Signalled by the last worker leaving a generation.
    done_cv: Condvar,
}

/// Persistent work-stealing thread pool with ordered collection
/// (DESIGN.md §14). Threads are spawned once in [`WorkerPool::new`] and
/// parked between [`WorkerPool::run`] calls; the `Coordinator` owns one
/// for the lifetime of a run and `parallel_inner_phase` reuses it every
/// round.
///
/// Determinism contract (DESIGN.md §6): results are collected **in cell
/// order**, so pool scheduling leaves no trace in the output. A cell
/// must be a pure function of its captured inputs — derive any seed it
/// needs from its identity (see [`crate::util::derive_seed`]), never
/// from shared mutable state. Thread identity (`p<t>` log tags) is
/// cosmetic; cell identity is what the contract is written against.
///
/// Panic story: if a cell panics, the panic is caught on the pool
/// thread, the first panic payload is recorded, and that worker stops
/// claiming further cells (the others drain the remaining cells, same
/// as `std::thread::scope` semantics). [`WorkerPool::run`] then
/// re-raises the recorded panic on the caller thread after the
/// generation fully completes — never a hang, and the pool itself
/// survives and stays usable for subsequent `run` calls.
///
/// `run` is not reentrant: one generation at a time, from one caller
/// thread (the coordinator is the single owner; a cell must never call
/// back into its own pool).
pub struct WorkerPool {
    threads: usize,
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `threads` parked OS threads. `threads <= 1`
    /// spawns nothing: every [`WorkerPool::run`] then degenerates to
    /// the in-order serial walk on the caller thread.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { seq: 0, job: None, remaining: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::new();
        if threads > 1 {
            for t in 0..threads {
                let shared = Arc::clone(&shared);
                handles.push(std::thread::spawn(move || {
                    THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
                    // thread-identity tag, allocated once per pool
                    // thread for its whole lifetime (cells may re-tag
                    // in place via `set_thread_context_args`, which
                    // reuses this same String buffer)
                    crate::util::set_thread_context(format!("p{t}"));
                    worker_loop(&shared);
                }));
            }
        }
        WorkerPool { threads, shared, handles }
    }

    /// Number of OS threads this pool fans out across (1 = serial).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run independent cells across the pool and return their results
    /// **in cell order**. Cells are claimed work-stealing style off a
    /// shared counter, so a slow cell never strands the remaining
    /// threads. Blocks until every cell has completed; re-raises the
    /// first cell panic, if any, after the generation is fully drained.
    pub fn run<T, F>(&self, cells: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = cells.len();
        if n == 0 {
            return Vec::new();
        }
        if self.threads <= 1 || n == 1 {
            return run_serial(cells);
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<F>>> = cells.into_iter().map(|f| Mutex::new(Some(f))).collect();
        let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let first_panic: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);
        let body = || {
            loop {
                // Relaxed is sufficient: this counter only partitions
                // cell indices between workers (each fetch_add hands
                // out a distinct i by RMW atomicity alone); all
                // happens-before edges for the cell closures and their
                // results flow through the slot/out mutexes and the
                // pool's state mutex, never through this counter.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // cell-identity tag, written into the pool thread's
                // existing tag buffer — no per-cell String allocation
                crate::util::logger::set_thread_context_args(format_args!("cell{i}"));
                let f = slots[i].lock().unwrap().take().expect("cell claimed twice");
                match catch_unwind(AssertUnwindSafe(f)) {
                    Ok(r) => *out[i].lock().unwrap() = Some(r),
                    Err(p) => {
                        let mut fp = first_panic.lock().unwrap();
                        if fp.is_none() {
                            *fp = Some(p);
                        }
                        // stop claiming; peers drain the rest
                        break;
                    }
                }
            }
        };
        let body_ref: &(dyn Fn() + Sync) = &body;
        // SAFETY: `body` lives on this stack frame and `run` does not
        // return (or unwind past this point) until the wait loop below
        // has observed `remaining == 0`, i.e. every worker has exited
        // the generation. No worker dereferences the job after
        // decrementing `remaining`, so the erased reference never
        // outlives the pointee. The captures (`next`, `slots`, `out`,
        // `first_panic`) are all Sync, and `T`/`F` are Send, so calling
        // `body` from pool threads is sound.
        let erased: ErasedJob = unsafe {
            std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(body_ref)
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(erased);
            st.seq += 1;
            st.remaining = self.handles.len();
            self.shared.work_cv.notify_all();
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining != 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
        }
        if let Some(p) = first_panic.into_inner().unwrap() {
            resume_unwind(p);
        }
        out.into_iter()
            .map(|m| m.into_inner().unwrap().expect("cell produced no result"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            // workers only unwind if a panic escapes `catch_unwind`
            // (i.e. never in practice); don't double-panic in Drop
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    let mut last_seq = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.seq != last_seq {
                    last_seq = st.seq;
                    break st.job.expect("generation published without a job");
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        job();
        let mut st = shared.state.lock().unwrap();
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// In-order serial walk on the caller thread, tagging each cell's log
/// lines and restoring whatever tag the caller already carried.
fn run_serial<T, F>(cells: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T,
{
    let caller_tag = crate::util::logger::thread_context();
    let out = cells
        .into_iter()
        .enumerate()
        .map(|(i, f)| {
            crate::util::logger::set_thread_context_args(format_args!("cell{i}"));
            f()
        })
        .collect();
    match caller_tag {
        Some(tag) => crate::util::set_thread_context(tag),
        None => crate::util::clear_thread_context(),
    }
    out
}

/// Run independent cells across `threads` OS threads and return their
/// results **in cell order** (ordered collection — the scheduling of
/// the pool leaves no trace in the output). One-shot wrapper over
/// [`WorkerPool`] for callers that fan out a single time (sweeps, bench
/// grids); round-loop callers should hold a pool instead.
/// `threads <= 1` degenerates to a plain in-order loop.
///
/// Determinism contract (DESIGN.md §6): a cell must be a pure function
/// of its captured inputs — derive any seed it needs from its identity
/// (see [`crate::util::derive_seed`]), never from shared mutable state.
pub fn run_cells<T, F>(threads: usize, cells: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = cells.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        return run_serial(cells);
    }
    WorkerPool::new(threads).run(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_cells_is_ordered_and_complete() {
        // 17 cells over 4 threads: results must land at their own index
        let cells: Vec<_> = (0..17).map(|i| move || i * 10).collect();
        let out = run_cells(4, cells);
        assert_eq!(out, (0..17).map(|i| i * 10).collect::<Vec<_>>());
        // degenerate cases
        let out = run_cells(1, (1..=2).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![1, 2]);
        let out: Vec<i32> = run_cells(8, Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn serial_path_restores_caller_tag() {
        crate::util::set_thread_context("outer");
        let out = run_cells(1, (0..3).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![0, 1, 2]);
        assert_eq!(
            crate::util::logger::thread_context().as_deref(),
            Some("outer"),
            "run_cells must not wipe the caller's log tag"
        );
        crate::util::clear_thread_context();
    }

    #[test]
    fn uneven_cells_all_complete() {
        // a deliberately slow first cell must not strand the rest: the
        // claim counter hands every remaining cell to the idle threads
        let cells: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..9)
            .map(|i| {
                Box::new(move || {
                    if i == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        assert_eq!(run_cells(3, cells), (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn pool_reuse_borrows_caller_state() {
        // a persistent pool must execute closures borrowing the
        // caller's stack across many generations (non-'static cells)
        let pool = WorkerPool::new(4);
        let base = vec![100usize, 200, 300, 400, 500];
        for round in 0..10 {
            let cells: Vec<_> = base.iter().map(|&b| move || b + round).collect();
            let out = pool.run(cells);
            assert_eq!(out, base.iter().map(|&b| b + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn pool_single_cell_runs_serial() {
        let pool = WorkerPool::new(4);
        let out = pool.run(vec![|| 7usize]);
        assert_eq!(out, vec![7]);
        let out: Vec<i32> = pool.run(Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }
}
