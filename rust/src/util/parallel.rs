//! Work-stealing fan-out with ordered collection — the one thread-pool
//! primitive every parallel layer shares (DESIGN.md §6): the
//! coordinator's worker chains, `sweep::run_sweep_jobs` cells, and the
//! fig1/fig2 bench grids (re-exported as `benchkit::run_cells`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run independent cells across `threads` OS threads and return their
/// results **in cell order** (ordered collection — the scheduling of
/// the pool leaves no trace in the output). Cells are claimed
/// work-stealing style off a shared counter, so a slow cell never
/// strands the remaining threads. `threads <= 1` degenerates to a
/// plain in-order loop.
///
/// Determinism contract (DESIGN.md §6): a cell must be a pure function
/// of its captured inputs — derive any seed it needs from its identity
/// (see [`crate::util::derive_seed`]), never from shared mutable state.
pub fn run_cells<T, F>(threads: usize, cells: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = cells.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        // serial walk: still tag each cell's log lines, restoring
        // whatever tag the calling thread already carried afterwards
        let caller_tag = crate::util::logger::thread_context();
        let out = cells
            .into_iter()
            .enumerate()
            .map(|(i, f)| {
                crate::util::set_thread_context(format!("cell{i}"));
                f()
            })
            .collect();
        match caller_tag {
            Some(tag) => crate::util::set_thread_context(tag),
            None => crate::util::clear_thread_context(),
        }
        return out;
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<F>>> = cells.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            // pool threads are scope-local: their tags die with them,
            // and the calling thread's tag is never touched
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                crate::util::set_thread_context(format!("cell{i}"));
                let f = slots[i].lock().unwrap().take().expect("cell claimed twice");
                let r = f();
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell produced no result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_cells_is_ordered_and_complete() {
        // 17 cells over 4 threads: results must land at their own index
        let cells: Vec<_> = (0..17).map(|i| move || i * 10).collect();
        let out = run_cells(4, cells);
        assert_eq!(out, (0..17).map(|i| i * 10).collect::<Vec<_>>());
        // degenerate cases
        let out = run_cells(1, (1..=2).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![1, 2]);
        let out: Vec<i32> = run_cells(8, Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn serial_path_restores_caller_tag() {
        crate::util::set_thread_context("outer");
        let out = run_cells(1, (0..3).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![0, 1, 2]);
        assert_eq!(
            crate::util::logger::thread_context().as_deref(),
            Some("outer"),
            "run_cells must not wipe the caller's log tag"
        );
        crate::util::clear_thread_context();
    }

    #[test]
    fn uneven_cells_all_complete() {
        // a deliberately slow first cell must not strand the rest: the
        // claim counter hands every remaining cell to the idle threads
        let cells: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..9)
            .map(|i| {
                Box::new(move || {
                    if i == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        assert_eq!(run_cells(3, cells), (0..9).collect::<Vec<_>>());
    }
}
