//! Allocation accounting for the perf trajectory (DESIGN.md §14).
//!
//! Two probes feed the `allocs_per_round` / `peak_rss_bytes` rows in
//! `BENCH_micro.json` / `BENCH_fig6.json`:
//!
//! * a **counting global allocator**, compiled only under the
//!   `perf-count-alloc` cargo feature (installed by `lib.rs` via
//!   `#[global_allocator]`): every `alloc`/`alloc_zeroed`/`realloc`
//!   bumps process-wide relaxed atomic counters, including a separate
//!   counter for "large" allocations at or above a settable threshold —
//!   the instrument behind the zero-param-sized-allocations acceptance
//!   check (`tests/alloc_steady.rs`). With the feature off, the probe
//!   API stays callable and reports zeros / `counting_enabled() ==
//!   false`, so benches emit `null` rows instead of diverging.
//! * a **peak-RSS probe** reading `VmHWM` from `/proc/self/status`
//!   (always compiled; `None` off Linux) — the process high-water mark,
//!   monotone over the process lifetime.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

// The counters live unconditionally (they are four statics); only the
// allocator that feeds them is feature-gated. This keeps every probe
// call site free of cfg noise.
static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);
static LARGE_ALLOCS: AtomicU64 = AtomicU64::new(0);
static LARGE_THRESHOLD: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Counting wrapper over [`std::alloc::System`]; installed as the
/// global allocator by `lib.rs` when the `perf-count-alloc` feature is
/// on. Deallocations are intentionally not counted: the perf contract
/// is about allocation *traffic*, and frees pair 1:1 with the counted
/// allocs.
#[cfg(feature = "perf-count-alloc")]
pub struct CountingAlloc;

#[cfg(feature = "perf-count-alloc")]
// SAFETY: defers every allocation verbatim to `System`; the counter
// updates are relaxed atomics with no allocation of their own, so the
// GlobalAlloc contract is exactly `System`'s.
unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        record(layout.size());
        std::alloc::System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: std::alloc::Layout) -> *mut u8 {
        record(layout.size());
        std::alloc::System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        record(new_size);
        std::alloc::System.realloc(ptr, layout, new_size)
    }
}

#[cfg(feature = "perf-count-alloc")]
fn record(size: usize) {
    ALLOCS.fetch_add(1, Ordering::Relaxed);
    BYTES.fetch_add(size as u64, Ordering::Relaxed);
    if size >= LARGE_THRESHOLD.load(Ordering::Relaxed) {
        LARGE_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }
}

/// True when the counting allocator is installed (the
/// `perf-count-alloc` feature): [`snapshot`] deltas are meaningful.
pub fn counting_enabled() -> bool {
    cfg!(feature = "perf-count-alloc")
}

/// Point-in-time reading of the process-wide allocation counters
/// (all zeros when counting is disabled). Subtract two snapshots via
/// [`AllocSnapshot::since`] to attribute traffic to a code region —
/// process-wide, so keep other threads quiet while measuring.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Heap allocations performed (`alloc` + `alloc_zeroed` + `realloc`).
    pub allocs: u64,
    /// Bytes requested across those allocations.
    pub bytes: u64,
    /// Allocations at or above the [`set_large_threshold`] cutoff.
    pub large_allocs: u64,
}

impl AllocSnapshot {
    /// Counter deltas from `earlier` to `self`.
    pub fn since(self, earlier: AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocs: self.allocs.wrapping_sub(earlier.allocs),
            bytes: self.bytes.wrapping_sub(earlier.bytes),
            large_allocs: self.large_allocs.wrapping_sub(earlier.large_allocs),
        }
    }
}

/// Read the current counters.
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Ordering::Relaxed),
        bytes: BYTES.load(Ordering::Relaxed),
        large_allocs: LARGE_ALLOCS.load(Ordering::Relaxed),
    }
}

/// Count allocations of at least `bytes` separately (the
/// "param-sized" cutoff: set it just below `4 * param_count` to catch
/// any param-sized f32 buffer). Applies from the next allocation on.
pub fn set_large_threshold(bytes: usize) {
    LARGE_THRESHOLD.store(bytes, Ordering::Relaxed);
}

/// Process peak resident set size in bytes (`VmHWM` from
/// `/proc/self/status`); `None` when the probe is unavailable (non-Linux
/// or unreadable procfs). Monotone over the process lifetime — a
/// high-water mark, not a point-in-time reading.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_since_is_elementwise() {
        let a = AllocSnapshot { allocs: 10, bytes: 1000, large_allocs: 1 };
        let b = AllocSnapshot { allocs: 17, bytes: 1500, large_allocs: 1 };
        assert_eq!(b.since(a), AllocSnapshot { allocs: 7, bytes: 500, large_allocs: 0 });
    }

    #[test]
    fn peak_rss_probe_reads_on_linux() {
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            let v = rss.expect("VmHWM present in /proc/self/status on Linux");
            assert!(v > 0, "peak RSS must be positive, got {v}");
        }
    }

    #[cfg(feature = "perf-count-alloc")]
    #[test]
    fn counters_observe_a_large_allocation() {
        set_large_threshold(1 << 20);
        let before = snapshot();
        let buf = vec![0u8; 2 << 20];
        std::hint::black_box(&buf);
        let d = snapshot().since(before);
        assert!(d.allocs >= 1, "allocation not counted");
        assert!(d.bytes >= (2 << 20) as u64, "bytes not counted: {}", d.bytes);
        assert!(d.large_allocs >= 1, "large allocation not counted");
        set_large_threshold(usize::MAX);
    }
}
