//! Vectorized dense-vector kernels for the L3 hot paths (DESIGN.md §12).
//!
//! Every dense loop the coordinator drives per outer round — merge
//! weighted averages, outer delta/Nesterov updates, the MockEngine's
//! gradient statistics, and the inner SGD/AdamW updates — funnels
//! through this module. The kernels are written in the
//! *independent-accumulator* shape stable-Rust LLVM auto-vectorizes:
//! fixed lane width [`LANES`] = 8, a flat array of per-lane
//! accumulators carried across full chunks, and a serial scalar tail.
//! No `unsafe`, no nightly SIMD intrinsics — the shape alone is enough
//! for the autovectorizer to emit packed adds/multiplies on any target
//! with 128-bit-or-wider vector units.
//!
//! ## Determinism contract (DESIGN.md §12)
//!
//! Two kernel classes, with different bit-level guarantees:
//!
//! * **Elementwise kernels** (`axpy_f32`, `weighted_add_f32`,
//!   `write_back_f64`, `delta_from_workers`, `sub_assign_f32`,
//!   `scale_sub_f32`, `nesterov_step_f32`, `sgd_step_f32`,
//!   `adamw_step_f32`): each output element is produced by *exactly*
//!   the arithmetic expression of the pre-vectorization serial loop, in
//!   the same per-index operation order. Chunking only regroups
//!   independent iterations, so these are bit-identical to their serial
//!   ancestors on every input, NaNs and all.
//!
//! * **Reduction kernels** (`dot_f32`, `norm_sq_f32`, `quad_loss_f32`,
//!   `quad_grad_f32`, `chunk_mean_norm_sq`, `sq_diff_dot_f32`): the
//!   summation order is the *fixed chunked order* — lane `l` (0..8)
//!   accumulates exactly the indices `i ≡ l (mod 8)` with
//!   `i < 8·⌊n/8⌋`, in ascending order; the eight lane accumulators are
//!   then combined by the fixed pairwise tree
//!   `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`; tail indices
//!   `8·⌊n/8⌋ ≤ i < n` are added serially, last. This order is frozen:
//!   it does not depend on the target CPU, thread count, scheduler or
//!   optimization level, so lockstep/event × threads{1,4} stay
//!   bit-identical to *each other* (the §6 contract). It differs from
//!   the old strictly-serial order, which is why the FROZEN goldens
//!   were re-pinned once when this module landed (CHANGES.md, PR 8).
//!
//! `tests/properties.rs` pins every kernel here bit-for-bit against a
//! straight-line scalar reference implementing the same chunked order,
//! over exhaustive lengths 0..=65 and adversarial values (NaN, ±inf,
//! denormals, signed zeros).

/// Fixed lane width of every chunked kernel. Eight f64 accumulators
/// fill one AVX-512 register, two AVX2 registers or four NEON
/// registers — wide enough that the reduction chain never serializes,
/// narrow enough that the scalar tail stays cheap.
pub const LANES: usize = 8;

/// Combine the eight lane accumulators with the fixed pairwise tree
/// (part of the frozen summation order — see the module docs).
#[inline(always)]
fn reduce_lanes(acc: &[f64; LANES]) -> f64 {
    let a = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
    (a[0] + a[2]) + (a[1] + a[3])
}

// ---------------------------------------------------------------------------
// reductions (fixed chunked summation order)
// ---------------------------------------------------------------------------

/// Dot product over f32 slices, accumulated in f64 lanes.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let mut acc = [0.0f64; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            acc[l] += a[base + l] as f64 * b[base + l] as f64;
        }
    }
    let mut s = reduce_lanes(&acc);
    for i in chunks * LANES..n {
        s += a[i] as f64 * b[i] as f64;
    }
    s
}

/// Squared L2 norm of an f32 slice, accumulated in f64 lanes.
#[inline]
pub fn norm_sq_f32(a: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / LANES;
    let mut acc = [0.0f64; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            acc[l] += a[base + l] as f64 * a[base + l] as f64;
        }
    }
    let mut s = reduce_lanes(&acc);
    for i in chunks * LANES..n {
        s += a[i] as f64 * a[i] as f64;
    }
    s
}

/// Diagonal-quadratic loss Σ_i ½·eig_i·(x_i − xstar_i)² — the
/// MockEngine objective (per-element arithmetic unchanged: the f32
/// subtraction widens to f64 *after* it happens, exactly like the old
/// serial loop).
#[inline]
pub fn quad_loss_f32(x: &[f32], xstar: &[f32], eig: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), xstar.len());
    debug_assert_eq!(x.len(), eig.len());
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [0.0f64; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let d = (x[base + l] - xstar[base + l]) as f64;
            acc[l] += 0.5 * eig[base + l] as f64 * d * d;
        }
    }
    let mut s = reduce_lanes(&acc);
    for i in chunks * LANES..n {
        let d = (x[i] - xstar[i]) as f64;
        s += 0.5 * eig[i] as f64 * d * d;
    }
    s
}

/// Diagonal-quadratic gradient g_i = eig_i·(x_i − xstar_i) into `out`
/// (f32 arithmetic, elementwise — bit-identical), returning Σ g_i²
/// (f64 lane reduction — chunked order).
#[inline]
pub fn quad_grad_f32(x: &[f32], xstar: &[f32], eig: &[f32], out: &mut [f32]) -> f64 {
    debug_assert_eq!(x.len(), xstar.len());
    debug_assert_eq!(x.len(), eig.len());
    debug_assert_eq!(x.len(), out.len());
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [0.0f64; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let g = eig[base + l] * (x[base + l] - xstar[base + l]);
            out[base + l] = g;
            acc[l] += g as f64 * g as f64;
        }
    }
    let mut s = reduce_lanes(&acc);
    for i in chunks * LANES..n {
        let g = eig[i] * (x[i] - xstar[i]);
        out[i] = g;
        s += g as f64 * g as f64;
    }
    s
}

/// Mean over `chunks` stacked gradient rows (`chunk_buf` is flat
/// `[chunks * d]`, row-major) into `grad_out` (`d` elements), returning
/// ||mean||². The per-element mean keeps the old serial order (rows
/// ascending, divided once at the end), so `grad_out` is bit-identical
/// to the pre-vectorization loop; only the ||·||² reduction moved to
/// the chunked order. Blocked over 8 output lanes, so the row reads are
/// contiguous 8-wide runs instead of the old `[c*d + i]` stride-d walk.
#[inline]
pub fn chunk_mean_norm_sq(chunk_buf: &[f32], chunks: usize, grad_out: &mut [f32]) -> f64 {
    let d = grad_out.len();
    debug_assert!(chunks >= 1);
    debug_assert_eq!(chunk_buf.len(), chunks * d);
    let blocks = d / LANES;
    let mut s1 = [0.0f64; LANES];
    for bl in 0..blocks {
        let base = bl * LANES;
        let mut acc = [0.0f64; LANES];
        for c in 0..chunks {
            let row = &chunk_buf[c * d + base..c * d + base + LANES];
            for l in 0..LANES {
                acc[l] += row[l] as f64;
            }
        }
        for l in 0..LANES {
            let g = acc[l] / chunks as f64;
            grad_out[base + l] = g as f32;
            s1[l] += g * g;
        }
    }
    let mut s = reduce_lanes(&s1);
    for i in blocks * LANES..d {
        let mut acc = 0.0f64;
        for c in 0..chunks {
            acc += chunk_buf[c * d + i] as f64;
        }
        let g = acc / chunks as f64;
        grad_out[i] = g as f32;
        s += g * g;
    }
    s
}

/// Fused pair of reductions over one gradient row `x` against the mean
/// gradient `g`: `(Σ (x_i − g_i)², Σ x_i·g_i)` — the per-chunk (s2, ip)
/// statistics of the variance estimator. Both sums use the chunked
/// order.
#[inline]
pub fn sq_diff_dot_f32(x: &[f32], g: &[f32]) -> (f64, f64) {
    debug_assert_eq!(x.len(), g.len());
    let n = x.len();
    let chunks = n / LANES;
    let mut acc_sq = [0.0f64; LANES];
    let mut acc_ip = [0.0f64; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let xv = x[base + l] as f64;
            let gv = g[base + l] as f64;
            let diff = xv - gv;
            acc_sq[l] += diff * diff;
            acc_ip[l] += xv * gv;
        }
    }
    let mut sq = reduce_lanes(&acc_sq);
    let mut ip = reduce_lanes(&acc_ip);
    for i in chunks * LANES..n {
        let xv = x[i] as f64;
        let gv = g[i] as f64;
        let diff = xv - gv;
        sq += diff * diff;
        ip += xv * gv;
    }
    (sq, ip)
}

// ---------------------------------------------------------------------------
// elementwise kernels (bit-identical to the serial loops)
// ---------------------------------------------------------------------------

/// `y += alpha * x` over f32 slices (f32 arithmetic, like the original).
#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            y[base + l] += alpha * x[base + l];
        }
    }
    for i in chunks * LANES..n {
        y[i] += alpha * x[i];
    }
}

/// `acc[i] += w * p[i]` widening f32 → f64 — the per-member pass of the
/// merge weighted average.
#[inline]
pub fn weighted_add_f32(w: f64, p: &[f32], acc: &mut [f64]) {
    debug_assert_eq!(p.len(), acc.len());
    let n = p.len();
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            acc[base + l] += w * p[base + l] as f64;
        }
    }
    for i in chunks * LANES..n {
        acc[i] += w * p[i] as f64;
    }
}

/// Narrow an f64 accumulator back into an f32 buffer (merge write-back).
#[inline]
pub fn write_back_f64(acc: &[f64], out: &mut [f32]) {
    debug_assert_eq!(acc.len(), out.len());
    let n = acc.len();
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            out[base + l] = acc[base + l] as f32;
        }
    }
    for i in chunks * LANES..n {
        out[i] = acc[i] as f32;
    }
}

/// Δ_i = x_prev_i − (Σ_w w_i) / |workers| over every worker's
/// post-inner-loop parameters. Register-blocked over 8 output lanes;
/// the per-element worker-sum order (workers ascending, one multiply
/// by 1/|workers| at the end) matches the old serial loop exactly, so
/// the result is bit-identical.
#[inline]
pub fn delta_from_workers(x_prev: &[f32], workers: &[&[f32]], delta: &mut [f32]) {
    debug_assert!(!workers.is_empty());
    let n = x_prev.len();
    debug_assert_eq!(delta.len(), n);
    let inv = 1.0 / workers.len() as f64;
    let blocks = n / LANES;
    for bl in 0..blocks {
        let base = bl * LANES;
        let mut acc = [0.0f64; LANES];
        for w in workers {
            let row = &w[base..base + LANES];
            for l in 0..LANES {
                acc[l] += row[l] as f64;
            }
        }
        for l in 0..LANES {
            delta[base + l] = (x_prev[base + l] as f64 - acc[l] * inv) as f32;
        }
    }
    for i in blocks * LANES..n {
        let mut avg = 0.0f64;
        for w in workers {
            avg += w[i] as f64;
        }
        delta[i] = (x_prev[i] as f64 - avg * inv) as f32;
    }
}

/// `x[i] -= d[i]` (f32 — the Average outer step).
#[inline]
pub fn sub_assign_f32(x: &mut [f32], d: &[f32]) {
    debug_assert_eq!(x.len(), d.len());
    let n = x.len();
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            x[base + l] -= d[base + l];
        }
    }
    for i in chunks * LANES..n {
        x[i] -= d[i];
    }
}

/// `x[i] = (x[i] − lr·d[i])` with f64 intermediates (SGD steps: the
/// outer-SGD update, and — via `sgd_step` — the inner one, whose
/// original loop computed `x[i] -= (lr * d[i] as f64) as f32`; pass
/// `narrow_rhs = true` for that variant, which narrows the product
/// before subtracting in f32).
#[inline]
pub fn scale_sub_f32(x: &mut [f32], d: &[f32], lr: f64, narrow_rhs: bool) {
    debug_assert_eq!(x.len(), d.len());
    let n = x.len();
    let chunks = n / LANES;
    if narrow_rhs {
        for c in 0..chunks {
            let base = c * LANES;
            for l in 0..LANES {
                x[base + l] -= (lr * d[base + l] as f64) as f32;
            }
        }
        for i in chunks * LANES..n {
            x[i] -= (lr * d[i] as f64) as f32;
        }
    } else {
        for c in 0..chunks {
            let base = c * LANES;
            for l in 0..LANES {
                x[base + l] = (x[base + l] as f64 - lr * d[base + l] as f64) as f32;
            }
        }
        for i in chunks * LANES..n {
            x[i] = (x[i] as f64 - lr * d[i] as f64) as f32;
        }
    }
}

/// DiLoCo's Nesterov outer update: v ← μ·v + Δ;
/// x ← x − lr·(μ·v + Δ) — per-element arithmetic identical to the old
/// serial loop.
#[inline]
pub fn nesterov_step_f32(
    x: &mut [f32],
    velocity: &mut [f32],
    delta: &[f32],
    lr: f64,
    momentum: f64,
) {
    debug_assert_eq!(x.len(), delta.len());
    debug_assert_eq!(velocity.len(), x.len());
    let n = x.len();
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let i = base + l;
            let v = momentum * velocity[i] as f64 + delta[i] as f64;
            velocity[i] = v as f32;
            x[i] = (x[i] as f64 - lr * (momentum * v + delta[i] as f64)) as f32;
        }
    }
    for i in chunks * LANES..n {
        let v = momentum * velocity[i] as f64 + delta[i] as f64;
        velocity[i] = v as f32;
        x[i] = (x[i] as f64 - lr * (momentum * v + delta[i] as f64)) as f32;
    }
}

/// Inner SGD: `params[i] -= (lr * grad[i] as f64) as f32`.
#[inline]
pub fn sgd_step_f32(params: &mut [f32], grad: &[f32], lr: f64) {
    scale_sub_f32(params, grad, lr, true);
}

/// Precomputed per-step AdamW coefficients (the bias corrections depend
/// on the step count, everything else on config).
#[derive(Clone, Copy, Debug)]
pub struct AdamCoeffs {
    /// First-moment decay rate.
    pub beta1: f64,
    /// Second-moment decay rate.
    pub beta2: f64,
    /// Denominator fuzz term.
    pub eps: f64,
    /// Decoupled weight-decay coefficient.
    pub weight_decay: f64,
    /// 1 − β1^t.
    pub bc1: f64,
    /// 1 − β2^t.
    pub bc2: f64,
    /// Learning rate.
    pub lr: f64,
}

/// One AdamW update over flat state vectors — per-element arithmetic
/// identical to the pre-vectorization `engine::adamw_step` loop.
#[inline]
pub fn adamw_step_f32(
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    grad: &[f32],
    k: &AdamCoeffs,
) {
    debug_assert_eq!(params.len(), grad.len());
    debug_assert_eq!(m.len(), grad.len());
    debug_assert_eq!(v.len(), grad.len());
    let n = grad.len();
    let chunks = n / LANES;
    #[inline(always)]
    fn one(
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        grad: &[f32],
        k: &AdamCoeffs,
        i: usize,
    ) {
        let g = grad[i] as f64;
        let mi = k.beta1 * m[i] as f64 + (1.0 - k.beta1) * g;
        let vi = k.beta2 * v[i] as f64 + (1.0 - k.beta2) * g * g;
        m[i] = mi as f32;
        v[i] = vi as f32;
        let m_hat = mi / k.bc1;
        let v_hat = vi / k.bc2;
        let x = params[i] as f64;
        params[i] = (x - k.lr * (m_hat / (v_hat.sqrt() + k.eps) + k.weight_decay * x)) as f32;
    }
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            one(params, m, v, grad, k, base + l);
        }
    }
    for i in chunks * LANES..n {
        one(params, m, v, grad, k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar reference for the frozen chunked reduction order: lane
    /// `i % 8` accumulates index `i` over the full-chunk prefix, the
    /// pairwise tree combines lanes, tail added serially last.
    fn chunked_sum(terms: impl ExactSizeIterator<Item = f64> + Clone) -> f64 {
        let n = terms.len();
        let full = (n / LANES) * LANES;
        let mut acc = [0.0f64; LANES];
        for (i, t) in terms.clone().take(full).enumerate() {
            acc[i % LANES] += t;
        }
        let mut s = reduce_lanes(&acc);
        for t in terms.skip(full) {
            s += t;
        }
        s
    }

    fn ramp(n: usize, seed: f32) -> Vec<f32> {
        (0..n).map(|i| seed + i as f32 * 0.25 - (i % 3) as f32).collect()
    }

    #[test]
    fn dot_and_norm_follow_chunked_order() {
        for n in [0usize, 1, 7, 8, 9, 16, 33, 65, 1000] {
            let a = ramp(n, 0.5);
            let b = ramp(n, -1.25);
            let want = chunked_sum(a.iter().zip(b.iter()).map(|(x, y)| *x as f64 * *y as f64));
            assert_eq!(dot_f32(&a, &b).to_bits(), want.to_bits(), "dot n={n}");
            let want = chunked_sum(a.iter().map(|x| *x as f64 * *x as f64));
            assert_eq!(norm_sq_f32(&a).to_bits(), want.to_bits(), "norm n={n}");
        }
    }

    #[test]
    fn elementwise_kernels_match_serial_loops() {
        for n in [0usize, 1, 7, 8, 9, 31, 64, 65] {
            let x = ramp(n, 2.0);
            let mut y1 = ramp(n, -0.5);
            let mut y2 = y1.clone();
            axpy_f32(1.5, &x, &mut y1);
            for i in 0..n {
                y2[i] += 1.5 * x[i];
            }
            assert_eq!(y1, y2, "axpy n={n}");

            let mut a1 = vec![0.125f64; n];
            let mut a2 = a1.clone();
            weighted_add_f32(0.75, &x, &mut a1);
            for i in 0..n {
                a2[i] += 0.75 * x[i] as f64;
            }
            assert_eq!(a1, a2, "weighted_add n={n}");

            let mut o1 = vec![0.0f32; n];
            write_back_f64(&a1, &mut o1);
            for i in 0..n {
                assert_eq!(o1[i].to_bits(), (a1[i] as f32).to_bits(), "write_back n={n}");
            }
        }
    }

    #[test]
    fn delta_matches_serial_worker_mean() {
        for n in [0usize, 1, 8, 9, 65] {
            let x_prev = ramp(n, 1.0);
            let w1 = ramp(n, -2.0);
            let w2 = ramp(n, 3.5);
            let w3 = ramp(n, 0.25);
            let workers: Vec<&[f32]> = vec![&w1, &w2, &w3];
            let mut got = vec![0.0f32; n];
            delta_from_workers(&x_prev, &workers, &mut got);
            let inv = 1.0 / 3.0f64;
            for i in 0..n {
                let mut avg = 0.0f64;
                for w in &workers {
                    avg += w[i] as f64;
                }
                avg *= inv;
                let want = (x_prev[i] as f64 - avg) as f32;
                assert_eq!(got[i].to_bits(), want.to_bits(), "delta n={n} i={i}");
            }
        }
    }

    #[test]
    fn chunk_mean_preserves_per_element_order() {
        let d = 21;
        let chunks = 5;
        let buf: Vec<f32> = (0..chunks * d).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut got = vec![0.0f32; d];
        let s1 = chunk_mean_norm_sq(&buf, chunks, &mut got);
        let mut want_g = vec![0.0f32; d];
        for i in 0..d {
            let mut acc = 0.0f64;
            for c in 0..chunks {
                acc += buf[c * d + i] as f64;
            }
            want_g[i] = (acc / chunks as f64) as f32;
        }
        assert_eq!(got, want_g, "mean gradient must be bit-identical to the serial loop");
        let want_s1 =
            chunked_sum(want_g.iter().map(|g| {
                // recompute the pre-narrowing f64 mean the kernel squares
                *g as f64 * *g as f64
            }));
        // the kernel squares the f64 mean before narrowing; recompute it
        let mut means = Vec::with_capacity(d);
        for i in 0..d {
            let mut acc = 0.0f64;
            for c in 0..chunks {
                acc += buf[c * d + i] as f64;
            }
            means.push(acc / chunks as f64);
        }
        let want_s1_exact = chunked_sum(means.iter().map(|g| g * g));
        assert_eq!(s1.to_bits(), want_s1_exact.to_bits());
        let _ = want_s1;
    }

    #[test]
    fn sq_diff_dot_follows_chunked_order() {
        let n = 65;
        let x = ramp(n, 0.1);
        let g = ramp(n, -0.9);
        let (sq, ip) = sq_diff_dot_f32(&x, &g);
        let want_sq = chunked_sum(x.iter().zip(g.iter()).map(|(a, b)| {
            let d = *a as f64 - *b as f64;
            d * d
        }));
        let want_ip = chunked_sum(x.iter().zip(g.iter()).map(|(a, b)| *a as f64 * *b as f64));
        assert_eq!(sq.to_bits(), want_sq.to_bits());
        assert_eq!(ip.to_bits(), want_ip.to_bits());
    }

    #[test]
    fn optimizer_steps_match_serial_loops() {
        let n = 65;
        let grad = ramp(n, 0.7);
        // sgd (inner form: narrow the product)
        let mut p1 = ramp(n, 1.0);
        let mut p2 = p1.clone();
        sgd_step_f32(&mut p1, &grad, 0.05);
        for i in 0..n {
            p2[i] -= (0.05 * grad[i] as f64) as f32;
        }
        assert_eq!(p1, p2);
        // outer sgd (f64 subtract, then narrow)
        let mut p1 = ramp(n, 1.0);
        let mut p2 = p1.clone();
        scale_sub_f32(&mut p1, &grad, 0.7, false);
        for i in 0..n {
            p2[i] = (p2[i] as f64 - 0.7 * grad[i] as f64) as f32;
        }
        assert_eq!(p1, p2);
        // nesterov
        let mut x1 = ramp(n, -1.0);
        let mut v1 = vec![0.25f32; n];
        let mut x2 = x1.clone();
        let mut v2 = v1.clone();
        nesterov_step_f32(&mut x1, &mut v1, &grad, 0.5, 0.9);
        for i in 0..n {
            let v = 0.9 * v2[i] as f64 + grad[i] as f64;
            v2[i] = v as f32;
            x2[i] = (x2[i] as f64 - 0.5 * (0.9 * v + grad[i] as f64)) as f32;
        }
        assert_eq!(x1, x2);
        assert_eq!(v1, v2);
        // adamw
        let k = AdamCoeffs {
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
            bc1: 1.0 - 0.9f64.powf(3.0),
            bc2: 1.0 - 0.95f64.powf(3.0),
            lr: 1e-3,
        };
        let mut p1 = ramp(n, 0.3);
        let mut m1 = vec![0.01f32; n];
        let mut vv1 = vec![0.02f32; n];
        let (mut p2, mut m2, mut vv2) = (p1.clone(), m1.clone(), vv1.clone());
        adamw_step_f32(&mut p1, &mut m1, &mut vv1, &grad, &k);
        for i in 0..n {
            let g = grad[i] as f64;
            let mi = k.beta1 * m2[i] as f64 + (1.0 - k.beta1) * g;
            let vi = k.beta2 * vv2[i] as f64 + (1.0 - k.beta2) * g * g;
            m2[i] = mi as f32;
            vv2[i] = vi as f32;
            let x = p2[i] as f64;
            p2[i] = (x - k.lr * (mi / k.bc1 / ((vi / k.bc2).sqrt() + k.eps) + k.weight_decay * x))
                as f32;
        }
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
        assert_eq!(vv1, vv2);
    }

    #[test]
    fn quad_kernels_match_reference_order() {
        let n = 33;
        let x = ramp(n, 0.4);
        let xs = ramp(n, -0.8);
        let eig: Vec<f32> = (0..n).map(|i| 0.1 + i as f32 * 0.01).collect();
        let want = chunked_sum((0..n).map(|i| {
            let d = (x[i] - xs[i]) as f64;
            0.5 * eig[i] as f64 * d * d
        }));
        assert_eq!(quad_loss_f32(&x, &xs, &eig).to_bits(), want.to_bits());

        let mut out = vec![0.0f32; n];
        let nsq = quad_grad_f32(&x, &xs, &eig, &mut out);
        let mut want_out = vec![0.0f32; n];
        for i in 0..n {
            want_out[i] = eig[i] * (x[i] - xs[i]);
        }
        assert_eq!(out, want_out);
        let want_nsq = chunked_sum(want_out.iter().map(|g| *g as f64 * *g as f64));
        assert_eq!(nsq.to_bits(), want_nsq.to_bits());
    }
}
