//! # AdLoCo — adaptive batching for communication-efficient distributed training
//!
//! Reproduction of *"AdLoCo: adaptive batching significantly improves
//! communications efficiency and convergence for Large Language Models"*
//! (Kutuzov et al., 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   AdLoCo orchestrator ([`coordinator`]) with adaptive batching
//!   ([`batching`]), multi-instance trainer merging ([`merge`]), SwitchMode
//!   gradient accumulation, DiLoCo-style outer optimization ([`outer`]),
//!   a simulated multi-GPU cluster ([`simulator`]), plus the DiLoCo and
//!   LocalSGD baselines.
//! * **L2/L1 (build-time Python)** — a MicroLlama-style transformer with a
//!   Pallas flash-attention kernel and a fused gradient-moment kernel,
//!   AOT-lowered to HLO text and executed through the PJRT runtime
//!   ([`runtime`]).
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod batching;
pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod merge;
pub mod metrics;
pub mod outer;
pub mod runtime;
pub mod schedule;
pub mod simulator;
pub mod sweep;
pub mod theory;
pub mod trainer;
pub mod util;
