//! # AdLoCo — adaptive batching for communication-efficient distributed training
//!
//! Reproduction of *"AdLoCo: adaptive batching significantly improves
//! communications efficiency and convergence for Large Language Models"*
//! (Kutuzov et al., 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   AdLoCo orchestrator ([`coordinator`]) with adaptive batching
//!   ([`batching`]), multi-instance trainer merging ([`merge`]), SwitchMode
//!   gradient accumulation, DiLoCo-style outer optimization ([`outer`]),
//!   a simulated multi-GPU cluster ([`simulator`]), plus the DiLoCo and
//!   LocalSGD baselines.
//! * **L2/L1 (build-time Python)** — a MicroLlama-style transformer with a
//!   Pallas flash-attention kernel and a fused gradient-moment kernel,
//!   AOT-lowered to HLO text and executed through the PJRT runtime
//!   ([`runtime`], behind the `xla` cargo feature).
//!
//! ```text
//!  examples / benches / CLI (main.rs)     HTTP clients
//!        │                                     │
//!        │              ┌──────────────────────▼──────────────────┐
//!        │              │ service — `adloco serve` daemon         │
//!        │              │   server (HTTP/1.1)  api  state  client │
//!        │              └──────────────────────┬──────────────────┘
//!  ┌─────▼──────────────────────────────────────▼───────────────────┐
//!  │ coordinator  — Algorithm 3 run loop (lockstep | event-driven)  │
//!  │   batching   merge   outer   schedule   trainer                │
//!  │   instances  — elastic lifecycle registry + spawn controller   │
//!  └──┬─────────────┬────────────────────┬──────────────────────────┘
//!     │             │                    │
//!  ┌──▼──────────┐ ┌▼─────────────────┐ ┌▼────────────────────────┐
//!  │ cluster     │ │ comm             │ │ engine: TrainEngine     │
//!  │  clocks     │ │  NetworkModel x2 │ │  MockEngine (pure Rust) │
//!  │  NodeModel  │ │  collectives     │ │  XlaEngine (PJRT,       │
//!  │  topology   │ │  CommLedger      │ │   `xla` feature)        │
//!  │  churn      │ └──────────────────┘ └──┬──────────────────────┘
//!  └──┬──────────┘   simulator: EventQueue │ runtime/artifacts
//!     └─ Scenario ──── (discrete events)   │   (AOT HLO)
//!        data (synthetic Zipf corpus) ─────┘
//! ```
//!
//! The `cluster`/`comm` split (DESIGN.md §7) also carries the
//! hierarchical two-level topology: node groups with fast intra links,
//! a slow WAN between group leaders, pluggable collective cost models,
//! and WAN-vs-intra byte accounting in the ledger. On top of it sits
//! the delayed-overlap mode (DESIGN.md §8, `comm.overlap = delayed`):
//! outer collectives post non-blocking through `SyncHandle`s and their
//! updates apply one round late, hiding transfer time under the next
//! round's compute while conserving every ledger byte. The elastic
//! lifecycle (DESIGN.md §9, `algo.elastic`) makes the instance pool a
//! *runtime* quantity: an [`instances`] registry tracks every instance
//! through Spawn → Active → Merging → Retired, and a utilization-driven
//! spawn controller refills capacity freed by churn and MIT merges with
//! fresh lightweight streams — `num_trainers` becomes a policy output,
//! not an input.
//!
//! # Quickstart
//!
//! The smallest end-to-end run (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use adloco::config::presets;
//! use adloco::coordinator::Coordinator;
//! use adloco::engine::build_engine;
//!
//! let mut cfg = presets::mock_default();
//! cfg.algo.outer_steps = 8;
//! let engine = build_engine(&cfg)?;
//! let mut coord = Coordinator::new(cfg, engine)?;
//! let result = coord.run()?;
//! println!("best ppl {:.3} over {} comms", result.best_ppl, result.comm_count);
//! # anyhow::Ok(())
//! ```
//!
//! For the paper's dynamic-workload story, run the churn + straggler
//! scenario on the event-driven scheduler and read the per-worker
//! utilization table it produces:
//!
//! ```no_run
//! use adloco::config::presets;
//! use adloco::coordinator::Coordinator;
//! use adloco::engine::build_engine;
//!
//! let cfg = presets::hetero_dynamic(); // stragglers + churn + link shift
//! let engine = build_engine(&cfg)?;
//! let mut coord = Coordinator::new(cfg, engine)?;
//! let result = coord.run()?;
//! for u in &coord.recorder.utilization {
//!     println!("trainer {} worker {} on node {}: {:.0}% busy, {:.2}s idle",
//!         u.trainer, u.worker, u.node, u.utilization() * 100.0, u.idle_s());
//! }
//! println!("cluster idle total: {:.2}s", result.total_idle_s);
//! # anyhow::Ok(())
//! ```
//!
//! Or from the shell: `cargo run --release --example heterogeneous_cluster`.
//!
//! # Parallel execution
//!
//! Set `run.threads` (CLI `--threads`, env `RUN_THREADS`) to fan each
//! outer round's worker chains out across OS threads, and `adloco sweep
//! --jobs N` to parallelize sweep grids across cells. Parallelism is
//! **bit-transparent**: ledgers, records and results are bit-identical
//! to the serial run at any thread count — only wall-clock changes. The
//! contract and its proof obligations live in DESIGN.md §6 and are
//! enforced by `tests/determinism_parallel.rs`.
//!
//! See DESIGN.md for the architecture (§3 covers the discrete-event
//! clock, schedulers and scenarios; §4 the synthetic corpus; §6 the
//! parallel runtime and determinism contract) and EXPERIMENTS.md for the
//! paper-vs-measured record and §Perf notes (serial-vs-parallel speedup
//! table included).

#![warn(missing_docs)]

/// Counting global allocator for the perf trajectory (DESIGN.md §14):
/// only installed under the `perf-count-alloc` feature, so default
/// builds keep the system allocator untouched.
#[cfg(feature = "perf-count-alloc")]
#[global_allocator]
static COUNTING_ALLOC: util::alloc_count::CountingAlloc = util::alloc_count::CountingAlloc;

pub mod batching;
pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod instances;
pub mod merge;
pub mod metrics;
pub mod outer;
pub mod runtime;
pub mod schedule;
pub mod service;
pub mod simulator;
pub mod sweep;
pub mod theory;
pub mod trainer;
pub mod util;
