//! Versioned JSONL workload traces (DESIGN.md §11).
//!
//! A [`Trace`] is a replayable description of cluster dynamics: per-node
//! availability windows, bandwidth shifts, and compute-speed factors on
//! a shared virtual-time axis. It is the file-format side of the
//! [`ScenarioSource`] seam — `cluster.scenario` can come from the
//! stochastic config model *or* from a trace replayed record-for-record,
//! and an exported stochastic scenario replays bit-identically (see
//! `tests/trace_replay.rs`).
//!
//! ## Format (`adloco-trace` v1)
//!
//! One JSON object per line. Line 1 is the header:
//!
//! ```text
//! {"format":"adloco-trace","version":1,"nodes":4,"records":2,
//!  "straggler_prob":"...","straggler_min":"...","straggler_max":"..."}
//! ```
//!
//! then exactly `records` record lines, globally non-decreasing in `t`:
//!
//! ```text
//! {"t":"<hex f64>","node":3,"kind":"down","until":"<hex f64>"}
//! {"t":"<hex f64>","node":1,"kind":"bw","factor":"<hex f64>"}
//! {"t":"<hex f64>","node":0,"kind":"speed","factor":"<hex f64>"}
//! ```
//!
//! `down` preempts the node over `[t, until)`; `bw` sets the node's
//! link-bandwidth multiplier from `t` on (piecewise constant); `speed`
//! sets a compute-time multiplier (>= values slow the node down) from
//! `t` on. All f64s are written as bit-exact hex strings (the
//! `checkpoint/interchange.rs` convention) with plain JSON numbers
//! tolerated on input, so serialize → parse → serialize is
//! byte-identical.
//!
//! Parsing follows the interchange strict-parse discipline: unknown or
//! duplicate fields, out-of-order timestamps, non-positive factors,
//! truncation and trailing garbage are all **typed** [`TraceError`]s —
//! never silent defaults.

use crate::config::{ClusterConfig, ScenarioConfig, TraceGenKind, TraceSourceConfig};
use crate::simulator::Scenario;
use crate::util::JsonValue;
use std::fmt;

/// Format tag in the header line.
pub const TRACE_FORMAT: &str = "adloco-trace";
/// Current (and only) trace format version.
pub const TRACE_VERSION: u64 = 1;

/// Typed trace parse/validation errors (strict: every malformed input
/// maps to one of these, never a silent default).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceError {
    /// A line is not valid JSON / not an object / a field has the wrong
    /// JSON type or appears twice.
    Corrupt { line: usize, detail: String },
    /// Header `format` is not `adloco-trace`.
    BadFormat { found: String },
    /// Header `version` is not a supported version.
    VersionMismatch { found: u64 },
    /// A required field is absent.
    MissingField { line: usize, field: &'static str },
    /// A field the format does not define (deny-unknown-fields).
    UnknownField { line: usize, field: String },
    /// A field is present but its value is out of domain.
    BadValue { line: usize, field: &'static str, detail: String },
    /// A `bw` record with factor <= 0 (a dead link is a `down` window,
    /// not a zero-bandwidth shift).
    NegativeBandwidth { line: usize, value: f64 },
    /// Record timestamps must be globally non-decreasing.
    OutOfOrder { line: usize, t: f64, prev: f64 },
    /// Record `node` is >= the header's `nodes`.
    NodeOutOfRange { line: usize, node: usize, nodes: usize },
    /// Fewer record lines than the header's `records` count.
    Truncated { expected: usize, have: usize },
    /// Non-empty content after the declared record count.
    TrailingGarbage { line: usize },
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::Corrupt { line, detail } => {
                write!(f, "trace line {line}: corrupt ({detail})")
            }
            TraceError::BadFormat { found } => {
                write!(f, "trace header: format {found:?} is not {TRACE_FORMAT:?}")
            }
            TraceError::VersionMismatch { found } => {
                write!(f, "trace header: version {found} unsupported (expected {TRACE_VERSION})")
            }
            TraceError::MissingField { line, field } => {
                write!(f, "trace line {line}: missing field {field:?}")
            }
            TraceError::UnknownField { line, field } => {
                write!(f, "trace line {line}: unknown field {field:?}")
            }
            TraceError::BadValue { line, field, detail } => {
                write!(f, "trace line {line}: bad {field:?}: {detail}")
            }
            TraceError::NegativeBandwidth { line, value } => {
                write!(f, "trace line {line}: bandwidth factor {value} must be > 0")
            }
            TraceError::OutOfOrder { line, t, prev } => {
                write!(f, "trace line {line}: t={t} precedes previous record t={prev}")
            }
            TraceError::NodeOutOfRange { line, node, nodes } => {
                write!(f, "trace line {line}: node {node} out of range ({nodes} nodes)")
            }
            TraceError::Truncated { expected, have } => {
                write!(f, "trace truncated: header declares {expected} records, found {have}")
            }
            TraceError::TrailingGarbage { line } => {
                write!(f, "trace line {line}: content after the declared record count")
            }
        }
    }
}

impl std::error::Error for TraceError {}

type TResult<T> = Result<T, TraceError>;

/// One timeline event on one node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// Node preempted over `[t, until)`.
    Down { until: f64 },
    /// Link-bandwidth multiplier from `t` on (piecewise constant).
    Bandwidth { factor: f64 },
    /// Compute-time multiplier from `t` on (piecewise constant; > 1
    /// slows the node, < 1 speeds it up). Deterministic — consumes no
    /// RNG — so speed-only traces stay legal under the lockstep walk.
    Speed { factor: f64 },
}

/// A timestamped per-node record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    /// Virtual time the event takes effect (seconds, non-decreasing
    /// across the file).
    pub t: f64,
    /// Node the event applies to.
    pub node: usize,
    /// The event payload.
    pub ev: TraceEvent,
}

/// A parsed (or generated) workload trace.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Cluster size the trace was recorded against; replay requires an
    /// exact match.
    pub nodes: usize,
    /// Straggler model carried through from the stochastic scenario
    /// (draws still come from each worker's private time stream, so a
    /// replay reproduces the original run's draws exactly).
    pub straggler_prob: f64,
    /// Straggler slowdown range, lower end.
    pub straggler_min: f64,
    /// Straggler slowdown range, upper end.
    pub straggler_max: f64,
    /// Timeline records, non-decreasing in `t`.
    pub records: Vec<TraceRecord>,
}

// ---------------------------------------------------------------------------
// strict line reader (the interchange consumption-tracking discipline)
// ---------------------------------------------------------------------------

/// Deny-unknown-fields view over one parsed JSONL object: every `take`
/// marks a field consumed; `finish` rejects whatever was not consumed.
struct StrictLine<'a> {
    line: usize,
    fields: Vec<(&'a str, &'a JsonValue, std::cell::Cell<bool>)>,
}

impl<'a> StrictLine<'a> {
    fn new(line: usize, v: &'a JsonValue) -> TResult<StrictLine<'a>> {
        let pairs = v
            .as_object()
            .ok_or_else(|| TraceError::Corrupt { line, detail: "not a JSON object".into() })?;
        let mut fields: Vec<(&str, &JsonValue, std::cell::Cell<bool>)> = Vec::new();
        for (k, val) in pairs {
            if fields.iter().any(|(name, _, _)| *name == k.as_str()) {
                return Err(TraceError::Corrupt { line, detail: format!("duplicate field {k:?}") });
            }
            fields.push((k.as_str(), val, std::cell::Cell::new(false)));
        }
        Ok(StrictLine { line, fields })
    }

    fn take(&self, field: &'static str) -> TResult<&'a JsonValue> {
        for (name, val, used) in &self.fields {
            if *name == field {
                used.set(true);
                return Ok(val);
            }
        }
        Err(TraceError::MissingField { line: self.line, field })
    }

    fn take_f64(&self, field: &'static str) -> TResult<f64> {
        parse_f64(self.take(field)?, self.line, field)
    }

    fn take_usize(&self, field: &'static str) -> TResult<usize> {
        self.take(field)?.as_usize().ok_or(TraceError::BadValue {
            line: self.line,
            field,
            detail: "expected a non-negative integer".into(),
        })
    }

    fn take_str(&self, field: &'static str) -> TResult<&'a str> {
        self.take(field)?.as_str().ok_or(TraceError::BadValue {
            line: self.line,
            field,
            detail: "expected a string".into(),
        })
    }

    fn finish(&self) -> TResult<()> {
        for (name, _, used) in &self.fields {
            if !used.get() {
                return Err(TraceError::UnknownField {
                    line: self.line,
                    field: (*name).to_string(),
                });
            }
        }
        Ok(())
    }
}

/// Bit-exact f64: the hex-string form the writer emits, plain JSON
/// numbers tolerated (the interchange `s_f64` convention).
fn parse_f64(v: &JsonValue, line: usize, field: &'static str) -> TResult<f64> {
    if let Some(s) = v.as_str() {
        let bits = u64::from_str_radix(s, 16).map_err(|_| TraceError::BadValue {
            line,
            field,
            detail: format!("bad hex f64 {s:?}"),
        })?;
        return Ok(f64::from_bits(bits));
    }
    v.as_f64().ok_or(TraceError::BadValue {
        line,
        field,
        detail: "expected a number or hex string".into(),
    })
}

fn hex_f64(v: f64) -> JsonValue {
    JsonValue::str(format!("{:016x}", v.to_bits()))
}

fn check_time(t: f64, line: usize, field: &'static str) -> TResult<()> {
    if !t.is_finite() || t < 0.0 {
        return Err(TraceError::BadValue {
            line,
            field,
            detail: format!("{t} is not a finite time >= 0"),
        });
    }
    Ok(())
}

impl Trace {
    /// Canonical JSONL serialization (header + records, one object per
    /// line, f64s as bit-exact hex). `parse` of this text reproduces
    /// `self` exactly, and re-serializing reproduces these bytes.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let header = JsonValue::obj(vec![
            ("format", JsonValue::str(TRACE_FORMAT)),
            ("version", JsonValue::num(TRACE_VERSION as f64)),
            ("nodes", JsonValue::num(self.nodes as f64)),
            ("records", JsonValue::num(self.records.len() as f64)),
            ("straggler_prob", hex_f64(self.straggler_prob)),
            ("straggler_min", hex_f64(self.straggler_min)),
            ("straggler_max", hex_f64(self.straggler_max)),
        ]);
        out.push_str(&header.to_string());
        out.push('\n');
        for r in &self.records {
            let mut fields = vec![
                ("t", hex_f64(r.t)),
                ("node", JsonValue::num(r.node as f64)),
            ];
            match r.ev {
                TraceEvent::Down { until } => {
                    fields.push(("kind", JsonValue::str("down")));
                    fields.push(("until", hex_f64(until)));
                }
                TraceEvent::Bandwidth { factor } => {
                    fields.push(("kind", JsonValue::str("bw")));
                    fields.push(("factor", hex_f64(factor)));
                }
                TraceEvent::Speed { factor } => {
                    fields.push(("kind", JsonValue::str("speed")));
                    fields.push(("factor", hex_f64(factor)));
                }
            }
            out.push_str(&JsonValue::obj(fields).to_string());
            out.push('\n');
        }
        out
    }

    /// Strict parse of the JSONL form. Every malformed input yields a
    /// typed [`TraceError`]; nothing is defaulted or skipped.
    pub fn parse(text: &str) -> TResult<Trace> {
        let mut lines = text.lines().enumerate().map(|(i, l)| (i + 1, l));
        let (hline, htext) = lines
            .find(|(_, l)| !l.trim().is_empty())
            .ok_or(TraceError::Corrupt { line: 1, detail: "empty trace".into() })?;
        let hjson = JsonValue::parse(htext)
            .map_err(|e| TraceError::Corrupt { line: hline, detail: format!("{e:?}") })?;
        let h = StrictLine::new(hline, &hjson)?;
        let format = h.take_str("format")?;
        if format != TRACE_FORMAT {
            return Err(TraceError::BadFormat { found: format.to_string() });
        }
        let version = h.take_usize("version")? as u64;
        if version != TRACE_VERSION {
            return Err(TraceError::VersionMismatch { found: version });
        }
        let nodes = h.take_usize("nodes")?;
        if nodes == 0 {
            return Err(TraceError::BadValue {
                line: hline,
                field: "nodes",
                detail: "a trace needs at least one node".into(),
            });
        }
        let expected = h.take_usize("records")?;
        let straggler_prob = h.take_f64("straggler_prob")?;
        let straggler_min = h.take_f64("straggler_min")?;
        let straggler_max = h.take_f64("straggler_max")?;
        h.finish()?;
        if !(0.0..=1.0).contains(&straggler_prob) {
            return Err(TraceError::BadValue {
                line: hline,
                field: "straggler_prob",
                detail: format!("{straggler_prob} not in [0,1]"),
            });
        }
        if straggler_prob > 0.0 && (straggler_min < 1.0 || straggler_max < straggler_min) {
            return Err(TraceError::BadValue {
                line: hline,
                field: "straggler_min",
                detail: "straggler factors need 1 <= min <= max".into(),
            });
        }

        let mut records = Vec::with_capacity(expected);
        let mut prev_t = f64::NEG_INFINITY;
        for (line, text) in lines.by_ref() {
            if records.len() == expected {
                if text.trim().is_empty() {
                    continue;
                }
                return Err(TraceError::TrailingGarbage { line });
            }
            if text.trim().is_empty() {
                return Err(TraceError::Corrupt {
                    line,
                    detail: "blank line inside the record stream".into(),
                });
            }
            let rjson = JsonValue::parse(text)
                .map_err(|e| TraceError::Corrupt { line, detail: format!("{e:?}") })?;
            let r = StrictLine::new(line, &rjson)?;
            let t = r.take_f64("t")?;
            check_time(t, line, "t")?;
            if t < prev_t {
                return Err(TraceError::OutOfOrder { line, t, prev: prev_t });
            }
            let node = r.take_usize("node")?;
            if node >= nodes {
                return Err(TraceError::NodeOutOfRange { line, node, nodes });
            }
            let ev = match r.take_str("kind")? {
                "down" => {
                    let until = r.take_f64("until")?;
                    check_time(until, line, "until")?;
                    if until <= t {
                        return Err(TraceError::BadValue {
                            line,
                            field: "until",
                            detail: format!("window [{t}, {until}) is empty"),
                        });
                    }
                    TraceEvent::Down { until }
                }
                "bw" => {
                    let factor = r.take_f64("factor")?;
                    if !factor.is_finite() || factor <= 0.0 {
                        return Err(TraceError::NegativeBandwidth { line, value: factor });
                    }
                    TraceEvent::Bandwidth { factor }
                }
                "speed" => {
                    let factor = r.take_f64("factor")?;
                    if !factor.is_finite() || factor <= 0.0 {
                        return Err(TraceError::BadValue {
                            line,
                            field: "factor",
                            detail: format!("speed factor {factor} must be finite and > 0"),
                        });
                    }
                    TraceEvent::Speed { factor }
                }
                other => {
                    return Err(TraceError::BadValue {
                        line,
                        field: "kind",
                        detail: format!("unknown record kind {other:?}"),
                    });
                }
            };
            r.finish()?;
            prev_t = t;
            records.push(TraceRecord { t, node, ev });
        }
        if records.len() < expected {
            return Err(TraceError::Truncated { expected, have: records.len() });
        }
        Ok(Trace { nodes, straggler_prob, straggler_min, straggler_max, records })
    }

    /// Read and parse a trace file.
    pub fn load(path: &str) -> anyhow::Result<Trace> {
        use anyhow::Context;
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
        Trace::parse(&text).with_context(|| format!("parsing trace {path}"))
    }

    /// Serialize and write a trace file.
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        use anyhow::Context;
        std::fs::write(path, self.to_jsonl()).with_context(|| format!("writing trace {path}"))
    }

    /// Export a stochastic scenario config as a trace over `nodes`
    /// nodes. Churn windows become `down` records and link shifts `bw`
    /// records, bit-exactly; the straggler model rides in the header
    /// (its draws live in per-worker streams, so replay reproduces
    /// them). `Scenario::compile_trace` of the result equals
    /// `Scenario::compile` of the config, hence bit-identical replay.
    pub fn from_scenario(sc: &ScenarioConfig, nodes: usize) -> Trace {
        let mut records: Vec<TraceRecord> = Vec::new();
        for w in &sc.churn {
            if w.node < nodes && w.until_s > w.from_s {
                records.push(TraceRecord {
                    t: w.from_s,
                    node: w.node,
                    ev: TraceEvent::Down { until: w.until_s },
                });
            }
        }
        for s in &sc.link_shifts {
            if s.node < nodes && s.bandwidth_factor > 0.0 {
                records.push(TraceRecord {
                    t: s.at_s,
                    node: s.node,
                    ev: TraceEvent::Bandwidth { factor: s.bandwidth_factor },
                });
            }
        }
        // stable: equal-t records keep config order, matching the
        // stable per-node sort inside Scenario::compile
        records.sort_by(|a, b| a.t.total_cmp(&b.t));
        Trace {
            nodes,
            straggler_prob: sc.straggler_prob,
            straggler_min: sc.straggler_min,
            straggler_max: sc.straggler_max,
            records,
        }
    }
}

// ---------------------------------------------------------------------------
// the ScenarioSource seam
// ---------------------------------------------------------------------------

/// Where the compiled [`Scenario`] comes from: the stochastic config
/// model (the historical path) or a replayed [`Trace`] (loaded from
/// disk or produced by a deterministic generator at startup).
#[derive(Clone, Debug)]
pub enum ScenarioSource {
    /// Compile `cluster.scenario` directly (the default).
    Stochastic(ScenarioConfig),
    /// Replay a trace record-for-record.
    Replay(Trace),
}

impl ScenarioSource {
    /// Resolve the configured source: load the trace file, run the
    /// generator (streams via `util::derive_seed`, never the run RNG),
    /// or pass the stochastic model through.
    pub fn resolve(cluster: &ClusterConfig, seed: u64) -> anyhow::Result<ScenarioSource> {
        use crate::simulator::generators;
        let nodes = cluster.nodes.len();
        Ok(match &cluster.trace {
            TraceSourceConfig::Stochastic => {
                ScenarioSource::Stochastic(cluster.scenario.clone())
            }
            TraceSourceConfig::Path(path) => ScenarioSource::Replay(Trace::load(path)?),
            TraceSourceConfig::Generator(g) => {
                let trace = match g.kind {
                    TraceGenKind::SpotMarket => generators::spot_market(&generators::SpotMarketSpec {
                        nodes,
                        horizon_s: g.horizon_s,
                        mean_up_s: g.mean_up_s,
                        mean_down_s: g.mean_down_s,
                        seed,
                    }),
                    TraceGenKind::Diurnal => generators::diurnal(&generators::DiurnalSpec {
                        nodes,
                        horizon_s: g.horizon_s,
                        period_s: g.period_s,
                        amplitude: g.amplitude,
                        samples_per_period: g.samples_per_period,
                        seed,
                    }),
                    TraceGenKind::RackFailures => {
                        generators::rack_failures(&generators::RackFailureSpec {
                            nodes,
                            groups: cluster.groups.clone(),
                            horizon_s: g.horizon_s,
                            outages_per_rack: g.outages_per_rack,
                            mean_down_s: g.mean_down_s,
                            seed,
                        })
                    }
                };
                ScenarioSource::Replay(trace)
            }
        })
    }

    /// Human-readable provenance tag for run metadata.
    pub fn describe(&self) -> String {
        match self {
            ScenarioSource::Stochastic(_) => "stochastic".to_string(),
            ScenarioSource::Replay(t) => {
                format!("trace({} nodes, {} records)", t.nodes, t.records.len())
            }
        }
    }

    /// Compile for a cluster of `nodes` nodes; a replayed trace must
    /// have been recorded against exactly that cluster size.
    pub fn compile(&self, nodes: usize) -> anyhow::Result<Scenario> {
        match self {
            ScenarioSource::Stochastic(sc) => Ok(Scenario::compile(sc, nodes)),
            ScenarioSource::Replay(t) => {
                if t.nodes != nodes {
                    anyhow::bail!(
                        "trace recorded for {} nodes, cluster has {nodes}",
                        t.nodes
                    );
                }
                Ok(Scenario::compile_trace(t))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChurnWindow, LinkShift};

    fn sample_trace() -> Trace {
        Trace {
            nodes: 4,
            straggler_prob: 0.25,
            straggler_min: 1.5,
            straggler_max: 4.0,
            records: vec![
                TraceRecord { t: 0.0, node: 0, ev: TraceEvent::Speed { factor: 1.25 } },
                TraceRecord { t: 2.0, node: 1, ev: TraceEvent::Bandwidth { factor: 0.5 } },
                TraceRecord { t: 2.0, node: 3, ev: TraceEvent::Down { until: 5.5 } },
                TraceRecord { t: 9.0, node: 1, ev: TraceEvent::Bandwidth { factor: 1.0 } },
            ],
        }
    }

    #[test]
    fn roundtrip_is_byte_identical() {
        let t = sample_trace();
        let text = t.to_jsonl();
        let back = Trace::parse(&text).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.to_jsonl(), text);
    }

    #[test]
    fn plain_numbers_tolerated_on_input() {
        let text = concat!(
            "{\"format\":\"adloco-trace\",\"version\":1,\"nodes\":2,\"records\":1,",
            "\"straggler_prob\":0,\"straggler_min\":1.5,\"straggler_max\":4}\n",
            "{\"t\":1.5,\"node\":0,\"kind\":\"bw\",\"factor\":0.5}\n",
        );
        let t = Trace::parse(text).unwrap();
        assert_eq!(t.records[0].t, 1.5);
        assert_eq!(t.records[0].ev, TraceEvent::Bandwidth { factor: 0.5 });
        // canonical re-serialization switches to hex and round-trips
        let canon = t.to_jsonl();
        assert_eq!(Trace::parse(&canon).unwrap(), t);
    }

    #[test]
    fn unknown_field_is_typed() {
        let mut t = sample_trace();
        t.records.truncate(1);
        let text = t.to_jsonl().replace("{\"t\":", "{\"bogus\":1,\"t\":");
        match Trace::parse(&text) {
            Err(TraceError::UnknownField { line: 2, field }) => assert_eq!(field, "bogus"),
            other => panic!("expected UnknownField, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_field_is_typed() {
        let mut t = sample_trace();
        t.records.truncate(1);
        let text = t.to_jsonl().replace("\"node\":0,", "\"node\":0,\"node\":0,");
        match Trace::parse(&text) {
            Err(TraceError::Corrupt { line: 2, .. }) => {}
            other => panic!("expected Corrupt (duplicate), got {other:?}"),
        }
    }

    #[test]
    fn out_of_order_timestamp_is_typed() {
        let mut t = sample_trace();
        t.records.swap(0, 3); // t=9 first, then t=2
        match Trace::parse(&t.to_jsonl()) {
            Err(TraceError::OutOfOrder { line: 3, .. }) => {}
            other => panic!("expected OutOfOrder, got {other:?}"),
        }
    }

    #[test]
    fn negative_bandwidth_is_typed() {
        let t = Trace {
            records: vec![TraceRecord {
                t: 1.0,
                node: 0,
                ev: TraceEvent::Bandwidth { factor: -0.5 },
            }],
            ..sample_trace()
        };
        match Trace::parse(&t.to_jsonl()) {
            Err(TraceError::NegativeBandwidth { line: 2, value }) => assert_eq!(value, -0.5),
            other => panic!("expected NegativeBandwidth, got {other:?}"),
        }
    }

    #[test]
    fn truncation_and_trailing_garbage_are_typed() {
        let t = sample_trace();
        let text = t.to_jsonl();
        // drop the last record line
        let cut = text.rfind("{\"t\"").unwrap();
        match Trace::parse(&text[..cut]) {
            Err(TraceError::Truncated { expected: 4, have: 3 }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // an extra record past the declared count
        let extra = format!("{text}{}", text.lines().last().unwrap());
        match Trace::parse(&extra) {
            Err(TraceError::TrailingGarbage { .. }) => {}
            other => panic!("expected TrailingGarbage, got {other:?}"),
        }
    }

    #[test]
    fn version_and_format_are_checked() {
        let text = sample_trace().to_jsonl();
        let v2 = text.replacen("\"version\":1", "\"version\":2", 1);
        assert_eq!(Trace::parse(&v2), Err(TraceError::VersionMismatch { found: 2 }));
        let alien = text.replacen("adloco-trace", "mystery-trace", 1);
        match Trace::parse(&alien) {
            Err(TraceError::BadFormat { found }) => assert_eq!(found, "mystery-trace"),
            other => panic!("expected BadFormat, got {other:?}"),
        }
    }

    #[test]
    fn node_out_of_range_is_typed() {
        let t = Trace {
            records: vec![TraceRecord {
                t: 0.0,
                node: 9,
                ev: TraceEvent::Speed { factor: 2.0 },
            }],
            ..sample_trace()
        };
        match Trace::parse(&t.to_jsonl()) {
            Err(TraceError::NodeOutOfRange { line: 2, node: 9, nodes: 4 }) => {}
            other => panic!("expected NodeOutOfRange, got {other:?}"),
        }
    }

    #[test]
    fn empty_down_window_is_typed() {
        let t = Trace {
            records: vec![TraceRecord { t: 3.0, node: 0, ev: TraceEvent::Down { until: 3.0 } }],
            ..sample_trace()
        };
        match Trace::parse(&t.to_jsonl()) {
            Err(TraceError::BadValue { line: 2, field: "until", .. }) => {}
            other => panic!("expected BadValue(until), got {other:?}"),
        }
    }

    #[test]
    fn from_scenario_exports_churn_and_shifts() {
        let sc = ScenarioConfig {
            straggler_prob: 0.15,
            churn: vec![ChurnWindow { node: 3, from_s: 8.0, until_s: 16.0 }],
            link_shifts: vec![
                LinkShift { node: 1, at_s: 5.0, bandwidth_factor: 0.1 },
                LinkShift { node: 1, at_s: 20.0, bandwidth_factor: 1.0 },
            ],
            ..ScenarioConfig::default()
        };
        let t = Trace::from_scenario(&sc, 4);
        assert_eq!(t.records.len(), 3);
        assert_eq!(t.straggler_prob, 0.15);
        // sorted by t: bw@5, down@8, bw@20
        assert_eq!(t.records[0].ev, TraceEvent::Bandwidth { factor: 0.1 });
        assert_eq!(t.records[1].ev, TraceEvent::Down { until: 16.0 });
        assert_eq!(t.records[2].t, 20.0);
        // and the export parses back identically through the file form
        assert_eq!(Trace::parse(&t.to_jsonl()).unwrap(), t);
    }
}
