//! Discrete-event machinery for the simulated cluster (DESIGN.md §3.2).
//!
//! The event-driven scheduler replaces the round-lockstep worker walk with
//! a priority queue of timestamped events: every worker posts a
//! [`SimEvent::StepDone`] when its current inner step completes, and
//! rendezvous points (outer sync, trainer merge) are announced via
//! [`SimEvent::SyncArrive`] / [`SimEvent::MergeArrive`]. The coordinator
//! pops events in virtual-time order, so a fast worker's step 7 can be
//! processed before a straggler's step 2 — which is what lets dynamic
//! workload scenarios (stragglers, churn, time-varying links) be expressed
//! at all.
//!
//! Determinism: the queue orders by `(time, push sequence)`. Ties at the
//! same virtual timestamp pop in push order, so a run is a pure function
//! of the config seed regardless of platform or hash-map iteration order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happened, to whom (indices are coordinator-level: trainer id and
/// worker position within that trainer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimEvent {
    /// Worker `worker` of trainer `trainer` finished inner step `step`
    /// (1-based within the current outer step).
    StepDone { trainer: usize, worker: usize, step: u64 },
    /// Worker finished its inner loop and arrived at the outer-sync
    /// barrier of its trainer.
    SyncArrive { trainer: usize, worker: usize },
    /// Worker arrived at a cross-trainer merge rendezvous.
    MergeArrive { trainer: usize, worker: usize },
    /// A delayed-overlap (non-blocking) outer collective of `trainer`
    /// finished transferring (DESIGN.md §8). A trace marker: the stale
    /// outer update applies at the trainer's next outer boundary, not at
    /// this pop, so consuming it changes no numerics.
    SyncComplete { trainer: usize },
    /// The elastic lifecycle (DESIGN.md §9) spawned `instance` at this
    /// round's boundary. A trace marker like `SyncComplete`: the spawn
    /// itself already happened before the queue was seeded, so the pop
    /// changes no numerics — it only places the event in the trace.
    InstanceSpawned { instance: usize },
    /// A merge at this round's boundary retired `instance` (trace
    /// marker, same rules as `InstanceSpawned`).
    InstanceRetired { instance: usize },
}

/// One scheduled event: virtual timestamp plus FIFO tie-break.
#[derive(Clone, Copy, Debug)]
struct Scheduled {
    at_s: f64,
    seq: u64,
    ev: SimEvent,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.at_s.total_cmp(&other.at_s) == Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    // BinaryHeap is a max-heap; invert so the EARLIEST (time, seq) pops
    // first. NaN timestamps are rejected at push, so total_cmp is a
    // plain numeric order here.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at_s
            .total_cmp(&self.at_s)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-priority event queue over virtual time.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    next_seq: u64,
}

impl EventQueue {
    /// Empty queue.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Scheduled-but-unpopped event count.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at virtual second `at_s`.
    pub fn push(&mut self, at_s: f64, ev: SimEvent) {
        assert!(at_s.is_finite(), "event time must be finite, got {at_s}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { at_s, seq, ev });
    }

    /// Earliest event's timestamp without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.at_s)
    }

    /// Remove and return the earliest `(time, event)`.
    pub fn pop(&mut self) -> Option<(f64, SimEvent)> {
        self.heap.pop().map(|s| (s.at_s, s.ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(t: usize, w: usize, s: u64) -> SimEvent {
        SimEvent::StepDone { trainer: t, worker: w, step: s }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, step(0, 0, 3));
        q.push(1.0, step(0, 0, 1));
        q.push(2.0, step(0, 0, 2));
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_pop_in_push_order() {
        let mut q = EventQueue::new();
        for w in 0..5 {
            q.push(1.0, step(0, w, 1));
        }
        let workers: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, ev)| match ev {
                SimEvent::StepDone { worker, .. } => worker,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(workers, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn interleaves_time_and_sequence() {
        let mut q = EventQueue::new();
        q.push(2.0, step(0, 0, 1)); // seq 0
        q.push(1.0, step(1, 0, 1)); // seq 1
        q.push(2.0, step(2, 0, 1)); // seq 2
        q.push(0.5, step(3, 0, 1)); // seq 3
        let trainers: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, ev)| match ev {
                SimEvent::StepDone { trainer, .. } => trainer,
                _ => unreachable!(),
            })
            .collect();
        // 0.5 -> trainer 3, 1.0 -> trainer 1, then the 2.0 tie in push order
        assert_eq!(trainers, vec![3, 1, 0, 2]);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        assert!(q.peek_time().is_none());
        q.push(4.0, SimEvent::SyncArrive { trainer: 0, worker: 0 });
        q.push(2.0, SimEvent::MergeArrive { trainer: 1, worker: 1 });
        assert_eq!(q.peek_time(), Some(2.0));
        let (t, ev) = q.pop().unwrap();
        assert_eq!(t, 2.0);
        assert_eq!(ev, SimEvent::MergeArrive { trainer: 1, worker: 1 });
        assert_eq!(q.len(), 1);
    }

    #[test]
    #[should_panic]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, step(0, 0, 1));
    }

    #[test]
    fn lifecycle_markers_order_like_any_event() {
        let mut q = EventQueue::new();
        q.push(2.0, step(0, 0, 1));
        q.push(1.0, SimEvent::InstanceSpawned { instance: 4 });
        q.push(1.5, SimEvent::InstanceRetired { instance: 2 });
        assert_eq!(q.pop().unwrap().1, SimEvent::InstanceSpawned { instance: 4 });
        assert_eq!(q.pop().unwrap().1, SimEvent::InstanceRetired { instance: 2 });
        assert_eq!(q.pop().unwrap().0, 2.0);
    }

    #[test]
    fn sync_complete_orders_like_any_event() {
        let mut q = EventQueue::new();
        q.push(2.0, step(0, 0, 1));
        q.push(1.0, SimEvent::SyncComplete { trainer: 3 });
        let (t, ev) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        assert_eq!(ev, SimEvent::SyncComplete { trainer: 3 });
        // a completion in the past still pops (before later compute)
        q.push(0.5, SimEvent::SyncComplete { trainer: 1 });
        assert_eq!(q.pop().unwrap().1, SimEvent::SyncComplete { trainer: 1 });
        assert_eq!(q.pop().unwrap().0, 2.0);
    }
}
