//! Dynamic-workload scenarios for the simulated cluster (DESIGN.md §3.3).
//!
//! The paper motivates AdLoCo by DiLoCo-style methods "fail[ing] to fully
//! exploit computational clusters under dynamic workloads". A [`Scenario`]
//! is the simulator's model of such a workload, compiled from the
//! `cluster.scenario` config block:
//!
//! * **stragglers** — each inner step's compute time is multiplied, with
//!   probability `straggler_prob`, by a uniform draw from
//!   `[straggler_min, straggler_max]`. Draws come from the per-worker
//!   time stream forked off the run RNG, so runs stay bit-reproducible.
//! * **node churn** — nodes are preempted over `[from_s, until_s)`
//!   windows of virtual time. Workers on a down node sit out the outer
//!   steps that start inside the window (their shard is re-split among
//!   the trainer's remaining workers) and rejoin afterwards.
//! * **time-varying links** — per-node bandwidth factors change at
//!   scheduled virtual times; a sync's transfer time uses the slowest
//!   participating link at barrier time.
//!
//! A default (all-empty) scenario is *static*: every query degenerates to
//! the constant-cluster answer and the event-driven scheduler reproduces
//! the lockstep ledger bit-for-bit (see `tests/event_scheduler.rs`).

use crate::config::ScenarioConfig;
use crate::util::Rng;

/// Compiled scenario: per-node down windows (sorted, coalesced) and
/// per-node bandwidth shift timelines (sorted).
#[derive(Clone, Debug)]
pub struct Scenario {
    straggler_prob: f64,
    straggler_min: f64,
    straggler_max: f64,
    /// node -> sorted disjoint (from_s, until_s) preemption windows.
    windows: Vec<Vec<(f64, f64)>>,
    /// node -> sorted (at_s, bandwidth_factor) steps; factor 1.0 before
    /// the first entry.
    shifts: Vec<Vec<(f64, f64)>>,
}

impl Scenario {
    /// Compile a config block for a cluster of `nodes` nodes. Entries
    /// referring to out-of-range nodes are rejected by config validation
    /// before this is reached.
    pub fn compile(cfg: &ScenarioConfig, nodes: usize) -> Scenario {
        let mut windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        for w in &cfg.churn {
            if w.node < nodes && w.until_s > w.from_s {
                windows[w.node].push((w.from_s, w.until_s));
            }
        }
        for wins in &mut windows {
            wins.sort_by(|a, b| a.0.total_cmp(&b.0));
            // coalesce overlapping/adjacent windows
            let mut merged: Vec<(f64, f64)> = Vec::with_capacity(wins.len());
            for &(from, until) in wins.iter() {
                match merged.last_mut() {
                    Some(last) if from <= last.1 => last.1 = last.1.max(until),
                    _ => merged.push((from, until)),
                }
            }
            *wins = merged;
        }
        let mut shifts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        for s in &cfg.link_shifts {
            if s.node < nodes && s.bandwidth_factor > 0.0 {
                shifts[s.node].push((s.at_s, s.bandwidth_factor));
            }
        }
        for sh in &mut shifts {
            sh.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        Scenario {
            straggler_prob: cfg.straggler_prob,
            straggler_min: cfg.straggler_min,
            straggler_max: cfg.straggler_max,
            windows,
            shifts,
        }
    }

    /// True when the scenario never perturbs the cluster (the bit-identity
    /// regime of the event scheduler).
    pub fn is_static(&self) -> bool {
        self.straggler_prob <= 0.0
            && self.windows.iter().all(|w| w.is_empty())
            && self.shifts.iter().all(|s| s.is_empty())
    }

    /// Per-step compute-time multiplier drawn from `rng` (>= 1.0).
    /// Consumes one uniform always, a second on a straggler hit, keeping
    /// the stream layout simple to reason about.
    pub fn straggler_factor(&self, rng: &mut Rng) -> f64 {
        if self.straggler_prob <= 0.0 {
            return 1.0;
        }
        if rng.f64() < self.straggler_prob {
            self.straggler_min + rng.f64() * (self.straggler_max - self.straggler_min)
        } else {
            1.0
        }
    }

    /// Is `node` up at virtual time `t`?
    pub fn node_available(&self, node: usize, t: f64) -> bool {
        self.down_until(node, t).is_none()
    }

    /// If `node` is down at `t`, the end of its preemption window.
    fn down_until(&self, node: usize, t: f64) -> Option<f64> {
        self.windows[node]
            .iter()
            .find(|&&(from, until)| t >= from && t < until)
            .map(|&(_, until)| until)
    }

    /// Earliest down-window start in `(t, ..)` for `node`.
    fn next_down_start(&self, node: usize, t: f64) -> Option<f64> {
        self.windows[node].iter().map(|&(from, _)| from).find(|&from| from > t)
    }

    /// Finish time and stalled seconds for `busy` seconds of compute on
    /// `node` starting at `start`, stretched across preemption windows.
    pub fn compute_span(&self, node: usize, start: f64, busy: f64) -> (f64, f64) {
        let mut t = start;
        let mut stall = 0.0;
        let mut remaining = busy;
        loop {
            if let Some(up) = self.down_until(node, t) {
                stall += up - t;
                t = up;
                continue;
            }
            match self.next_down_start(node, t) {
                Some(ws) if ws < t + remaining => {
                    remaining -= ws - t;
                    t = ws;
                }
                _ => return (t + remaining, stall),
            }
        }
    }

    /// Bandwidth multiplier of `node`'s link at time `t` (1.0 before the
    /// first scheduled shift).
    pub fn bandwidth_factor(&self, node: usize, t: f64) -> f64 {
        self.shifts[node]
            .iter()
            .take_while(|&&(at, _)| at <= t)
            .last()
            .map(|&(_, f)| f)
            .unwrap_or(1.0)
    }

    /// Slowest participating link's factor at `t` — the ring all-reduce
    /// is throttled by its narrowest hop. Boost factors (> 1.0) pass
    /// through; an empty participant set yields the neutral 1.0.
    pub fn min_bandwidth_factor<I: IntoIterator<Item = usize>>(&self, nodes: I, t: f64) -> f64 {
        let min = nodes
            .into_iter()
            .map(|n| self.bandwidth_factor(n, t))
            .fold(f64::INFINITY, f64::min);
        if min.is_finite() {
            min
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChurnWindow, LinkShift};

    fn cfg_with(churn: Vec<ChurnWindow>, shifts: Vec<LinkShift>) -> ScenarioConfig {
        ScenarioConfig { churn, link_shifts: shifts, ..ScenarioConfig::default() }
    }

    #[test]
    fn default_is_static() {
        let s = Scenario::compile(&ScenarioConfig::default(), 4);
        assert!(s.is_static());
        assert!(s.node_available(0, 123.0));
        assert_eq!(s.bandwidth_factor(3, 1e9), 1.0);
        assert_eq!(s.compute_span(1, 5.0, 2.0), (7.0, 0.0));
        let mut rng = Rng::new(1);
        for _ in 0..32 {
            assert_eq!(s.straggler_factor(&mut rng), 1.0);
        }
    }

    #[test]
    fn straggler_draws_in_range() {
        let cfg = ScenarioConfig {
            straggler_prob: 0.5,
            straggler_min: 2.0,
            straggler_max: 3.0,
            ..ScenarioConfig::default()
        };
        let s = Scenario::compile(&cfg, 1);
        let mut rng = Rng::new(7);
        let mut hits = 0;
        for _ in 0..2000 {
            let f = s.straggler_factor(&mut rng);
            if f != 1.0 {
                hits += 1;
                assert!((2.0..=3.0).contains(&f), "factor {f}");
            }
        }
        // ~50% hit rate
        assert!((700..1300).contains(&hits), "hits {hits}");
    }

    #[test]
    fn churn_windows_coalesce_and_answer() {
        let cfg = cfg_with(
            vec![
                ChurnWindow { node: 0, from_s: 10.0, until_s: 20.0 },
                ChurnWindow { node: 0, from_s: 15.0, until_s: 25.0 }, // overlaps
                ChurnWindow { node: 0, from_s: 40.0, until_s: 50.0 },
            ],
            vec![],
        );
        let s = Scenario::compile(&cfg, 2);
        assert!(!s.is_static());
        assert!(s.node_available(0, 9.9));
        assert!(!s.node_available(0, 10.0));
        assert!(!s.node_available(0, 24.9));
        assert!(s.node_available(0, 25.0));
        assert!(s.node_available(1, 15.0), "other node unaffected");
    }

    #[test]
    fn compute_span_stretches_across_downtime() {
        let cfg = cfg_with(vec![ChurnWindow { node: 0, from_s: 10.0, until_s: 14.0 }], vec![]);
        let s = Scenario::compile(&cfg, 1);
        // 5s of compute starting at 8: 2s busy, 4s stalled, 3s busy
        let (end, stall) = s.compute_span(0, 8.0, 5.0);
        assert!((end - 17.0).abs() < 1e-12, "end {end}");
        assert!((stall - 4.0).abs() < 1e-12, "stall {stall}");
        // starting inside the window: wait for the end first
        let (end, stall) = s.compute_span(0, 11.0, 1.0);
        assert!((end - 15.0).abs() < 1e-12);
        assert!((stall - 3.0).abs() < 1e-12);
        // fully clear of windows: untouched
        assert_eq!(s.compute_span(0, 20.0, 2.5), (22.5, 0.0));
    }

    #[test]
    fn bandwidth_shifts_are_piecewise_constant() {
        let cfg = cfg_with(
            vec![],
            vec![
                LinkShift { node: 1, at_s: 10.0, bandwidth_factor: 0.25 },
                LinkShift { node: 1, at_s: 30.0, bandwidth_factor: 1.0 },
            ],
        );
        let s = Scenario::compile(&cfg, 2);
        assert_eq!(s.bandwidth_factor(1, 0.0), 1.0);
        assert_eq!(s.bandwidth_factor(1, 10.0), 0.25);
        assert_eq!(s.bandwidth_factor(1, 29.9), 0.25);
        assert_eq!(s.bandwidth_factor(1, 30.0), 1.0);
        // min across participants
        assert_eq!(s.min_bandwidth_factor([0usize, 1], 15.0), 0.25);
        assert_eq!(s.min_bandwidth_factor([0usize], 15.0), 1.0);
        // empty participant set is neutral
        assert_eq!(s.min_bandwidth_factor(std::iter::empty(), 15.0), 1.0);
    }

    #[test]
    fn bandwidth_boosts_pass_through() {
        let cfg = cfg_with(
            vec![],
            vec![
                LinkShift { node: 0, at_s: 0.0, bandwidth_factor: 2.0 },
                LinkShift { node: 1, at_s: 0.0, bandwidth_factor: 3.0 },
            ],
        );
        let s = Scenario::compile(&cfg, 2);
        // a uniformly upgraded link set must not be clamped back to 1.0
        assert_eq!(s.min_bandwidth_factor([0usize, 1], 1.0), 2.0);
    }
}
