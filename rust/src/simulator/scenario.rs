//! Dynamic-workload scenarios for the simulated cluster (DESIGN.md §3.3).
//!
//! The paper motivates AdLoCo by DiLoCo-style methods "fail[ing] to fully
//! exploit computational clusters under dynamic workloads". A [`Scenario`]
//! is the simulator's model of such a workload, compiled from the
//! `cluster.scenario` config block:
//!
//! * **stragglers** — each inner step's compute time is multiplied, with
//!   probability `straggler_prob`, by a uniform draw from
//!   `[straggler_min, straggler_max]`. Draws come from the per-worker
//!   time stream forked off the run RNG, so runs stay bit-reproducible.
//! * **node churn** — nodes are preempted over `[from_s, until_s)`
//!   windows of virtual time. Workers on a down node sit out the outer
//!   steps that start inside the window (their shard is re-split among
//!   the trainer's remaining workers) and rejoin afterwards.
//! * **time-varying links** — per-node bandwidth factors change at
//!   scheduled virtual times; a sync's transfer time uses the slowest
//!   participating link at barrier time.
//!
//! A default (all-empty) scenario is *static*: every query degenerates to
//! the constant-cluster answer and the event-driven scheduler reproduces
//! the lockstep ledger bit-for-bit (see `tests/event_scheduler.rs`).
//!
//! Besides the stochastic config block, a scenario can be compiled from
//! a replayed workload trace ([`Scenario::compile_trace`], DESIGN.md
//! §11), which additionally carries deterministic per-node *speed*
//! timelines — piecewise-constant compute-time multipliers that consume
//! no RNG, and are therefore legal under the lockstep reference walk
//! (unlike stragglers/churn/shifts, see [`Scenario::requires_event`]).
//!
//! All timeline lookups are binary searches (`partition_point`) over the
//! sorted per-node vectors: queries run on every inner step of every
//! worker, so the 10k-node fleet traces of `benches/fig6_scale.rs` would
//! turn linear scans into the event path's bottleneck.

use crate::config::ScenarioConfig;
use crate::simulator::trace::{Trace, TraceEvent};
use crate::util::Rng;

/// Compiled scenario: per-node down windows (sorted, coalesced) and
/// per-node bandwidth/speed shift timelines (sorted).
#[derive(Clone, Debug)]
pub struct Scenario {
    straggler_prob: f64,
    straggler_min: f64,
    straggler_max: f64,
    /// node -> sorted disjoint (from_s, until_s) preemption windows.
    windows: Vec<Vec<(f64, f64)>>,
    /// node -> sorted (at_s, bandwidth_factor) steps; factor 1.0 before
    /// the first entry.
    shifts: Vec<Vec<(f64, f64)>>,
    /// node -> sorted (at_s, compute-time multiplier) steps; factor 1.0
    /// before the first entry. Deterministic (no RNG), so speed-only
    /// scenarios keep lockstep == event bit-identity.
    speeds: Vec<Vec<(f64, f64)>>,
}

/// Sort windows by start and coalesce overlapping/adjacent ones into a
/// disjoint sorted set (shared by the config and trace compilers, so an
/// exported scenario recompiles to bit-identical windows).
fn sort_coalesce(wins: &mut Vec<(f64, f64)>) {
    wins.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut merged: Vec<(f64, f64)> = Vec::with_capacity(wins.len());
    for &(from, until) in wins.iter() {
        match merged.last_mut() {
            Some(last) if from <= last.1 => last.1 = last.1.max(until),
            _ => merged.push((from, until)),
        }
    }
    *wins = merged;
}

impl Scenario {
    /// Compile a config block for a cluster of `nodes` nodes. Entries
    /// referring to out-of-range nodes are rejected by config validation
    /// before this is reached.
    pub fn compile(cfg: &ScenarioConfig, nodes: usize) -> Scenario {
        let mut windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        for w in &cfg.churn {
            if w.node < nodes && w.until_s > w.from_s {
                windows[w.node].push((w.from_s, w.until_s));
            }
        }
        for wins in &mut windows {
            sort_coalesce(wins);
        }
        let mut shifts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        for s in &cfg.link_shifts {
            if s.node < nodes && s.bandwidth_factor > 0.0 {
                shifts[s.node].push((s.at_s, s.bandwidth_factor));
            }
        }
        for sh in &mut shifts {
            sh.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        Scenario {
            straggler_prob: cfg.straggler_prob,
            straggler_min: cfg.straggler_min,
            straggler_max: cfg.straggler_max,
            windows,
            shifts,
            speeds: vec![Vec::new(); nodes],
        }
    }

    /// Compile a replayed workload trace (DESIGN.md §11). Uses the same
    /// per-node sort/coalesce as [`Scenario::compile`], so a trace
    /// exported with `Trace::from_scenario` compiles to bit-identical
    /// timelines — the invariant behind `tests/trace_replay.rs`.
    pub fn compile_trace(trace: &Trace) -> Scenario {
        let nodes = trace.nodes;
        let mut windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        let mut shifts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        let mut speeds: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        for r in &trace.records {
            match r.ev {
                TraceEvent::Down { until } => {
                    if until > r.t {
                        windows[r.node].push((r.t, until));
                    }
                }
                TraceEvent::Bandwidth { factor } => {
                    if factor > 0.0 {
                        shifts[r.node].push((r.t, factor));
                    }
                }
                TraceEvent::Speed { factor } => {
                    if factor > 0.0 {
                        speeds[r.node].push((r.t, factor));
                    }
                }
            }
        }
        for wins in &mut windows {
            sort_coalesce(wins);
        }
        for sh in &mut shifts {
            sh.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        for sp in &mut speeds {
            sp.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        Scenario {
            straggler_prob: trace.straggler_prob,
            straggler_min: trace.straggler_min,
            straggler_max: trace.straggler_max,
            windows,
            shifts,
            speeds,
        }
    }

    /// True when the scenario never perturbs the cluster (the bit-identity
    /// regime of the event scheduler).
    pub fn is_static(&self) -> bool {
        self.straggler_prob <= 0.0
            && self.windows.iter().all(|w| w.is_empty())
            && self.shifts.iter().all(|s| s.is_empty())
            && self.speeds.iter().all(|s| s.is_empty())
    }

    /// True when any node has a preemption window — the only scenario
    /// feature that needs outer-boundary churn bookkeeping
    /// (`ClusterState::apply_churn`).
    pub fn has_windows(&self) -> bool {
        self.windows.iter().any(|w| !w.is_empty())
    }

    /// True when the scenario needs the event scheduler: stragglers,
    /// churn and link shifts all interleave with scheduling decisions
    /// the lockstep reference walk cannot express. Deterministic speed
    /// timelines are exempt — they multiply each step's compute time in
    /// place, identically under every scheduler.
    pub fn requires_event(&self) -> bool {
        self.straggler_prob > 0.0
            || self.windows.iter().any(|w| !w.is_empty())
            || self.shifts.iter().any(|s| !s.is_empty())
    }

    /// Per-step compute-time multiplier drawn from `rng` (>= 1.0).
    /// Consumes one uniform always, a second on a straggler hit, keeping
    /// the stream layout simple to reason about.
    pub fn straggler_factor(&self, rng: &mut Rng) -> f64 {
        if self.straggler_prob <= 0.0 {
            return 1.0;
        }
        if rng.f64() < self.straggler_prob {
            self.straggler_min + rng.f64() * (self.straggler_max - self.straggler_min)
        } else {
            1.0
        }
    }

    /// Is `node` up at virtual time `t`?
    pub fn node_available(&self, node: usize, t: f64) -> bool {
        self.down_until(node, t).is_none()
    }

    /// If `node` is down at `t`, the end of its preemption window.
    /// Binary search over the sorted disjoint windows: the last window
    /// starting at or before `t` is the only candidate covering it.
    fn down_until(&self, node: usize, t: f64) -> Option<f64> {
        let wins = &self.windows[node];
        let i = wins.partition_point(|&(from, _)| from <= t);
        match i.checked_sub(1).map(|i| wins[i]) {
            Some((_, until)) if t < until => Some(until),
            _ => None,
        }
    }

    /// Earliest down-window start in `(t, ..)` for `node` (binary
    /// search; windows are sorted by start).
    fn next_down_start(&self, node: usize, t: f64) -> Option<f64> {
        let wins = &self.windows[node];
        let i = wins.partition_point(|&(from, _)| from <= t);
        wins.get(i).map(|&(from, _)| from)
    }

    /// Finish time and stalled seconds for `busy` seconds of compute on
    /// `node` starting at `start`, stretched across preemption windows.
    pub fn compute_span(&self, node: usize, start: f64, busy: f64) -> (f64, f64) {
        let mut t = start;
        let mut stall = 0.0;
        let mut remaining = busy;
        loop {
            if let Some(up) = self.down_until(node, t) {
                stall += up - t;
                t = up;
                continue;
            }
            match self.next_down_start(node, t) {
                Some(ws) if ws < t + remaining => {
                    remaining -= ws - t;
                    t = ws;
                }
                _ => return (t + remaining, stall),
            }
        }
    }

    /// Bandwidth multiplier of `node`'s link at time `t` (1.0 before the
    /// first scheduled shift). Binary search for the last shift at or
    /// before `t`; on equal timestamps the later entry wins, exactly as
    /// the historical `take_while(..).last()` scan resolved ties.
    pub fn bandwidth_factor(&self, node: usize, t: f64) -> f64 {
        Self::timeline_at(&self.shifts[node], t)
    }

    /// Compute-time multiplier of `node` at time `t` (1.0 before the
    /// first speed record; traced timelines only — the stochastic
    /// config model has no speed knob).
    pub fn speed_factor(&self, node: usize, t: f64) -> f64 {
        Self::timeline_at(&self.speeds[node], t)
    }

    /// Last value of a sorted piecewise-constant `(at_s, value)`
    /// timeline at or before `t`; 1.0 before the first entry.
    fn timeline_at(steps: &[(f64, f64)], t: f64) -> f64 {
        let i = steps.partition_point(|&(at, _)| at <= t);
        match i.checked_sub(1) {
            Some(i) => steps[i].1,
            None => 1.0,
        }
    }

    /// Slowest participating link's factor at `t` — the ring all-reduce
    /// is throttled by its narrowest hop. Boost factors (> 1.0) pass
    /// through; an empty participant set yields the neutral 1.0.
    pub fn min_bandwidth_factor<I: IntoIterator<Item = usize>>(&self, nodes: I, t: f64) -> f64 {
        let min = nodes
            .into_iter()
            .map(|n| self.bandwidth_factor(n, t))
            .fold(f64::INFINITY, f64::min);
        if min.is_finite() {
            min
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChurnWindow, LinkShift};

    fn cfg_with(churn: Vec<ChurnWindow>, shifts: Vec<LinkShift>) -> ScenarioConfig {
        ScenarioConfig { churn, link_shifts: shifts, ..ScenarioConfig::default() }
    }

    #[test]
    fn default_is_static() {
        let s = Scenario::compile(&ScenarioConfig::default(), 4);
        assert!(s.is_static());
        assert!(s.node_available(0, 123.0));
        assert_eq!(s.bandwidth_factor(3, 1e9), 1.0);
        assert_eq!(s.compute_span(1, 5.0, 2.0), (7.0, 0.0));
        let mut rng = Rng::new(1);
        for _ in 0..32 {
            assert_eq!(s.straggler_factor(&mut rng), 1.0);
        }
    }

    #[test]
    fn straggler_draws_in_range() {
        let cfg = ScenarioConfig {
            straggler_prob: 0.5,
            straggler_min: 2.0,
            straggler_max: 3.0,
            ..ScenarioConfig::default()
        };
        let s = Scenario::compile(&cfg, 1);
        let mut rng = Rng::new(7);
        let mut hits = 0;
        for _ in 0..2000 {
            let f = s.straggler_factor(&mut rng);
            if f != 1.0 {
                hits += 1;
                assert!((2.0..=3.0).contains(&f), "factor {f}");
            }
        }
        // ~50% hit rate
        assert!((700..1300).contains(&hits), "hits {hits}");
    }

    #[test]
    fn churn_windows_coalesce_and_answer() {
        let cfg = cfg_with(
            vec![
                ChurnWindow { node: 0, from_s: 10.0, until_s: 20.0 },
                ChurnWindow { node: 0, from_s: 15.0, until_s: 25.0 }, // overlaps
                ChurnWindow { node: 0, from_s: 40.0, until_s: 50.0 },
            ],
            vec![],
        );
        let s = Scenario::compile(&cfg, 2);
        assert!(!s.is_static());
        assert!(s.node_available(0, 9.9));
        assert!(!s.node_available(0, 10.0));
        assert!(!s.node_available(0, 24.9));
        assert!(s.node_available(0, 25.0));
        assert!(s.node_available(1, 15.0), "other node unaffected");
    }

    #[test]
    fn compute_span_stretches_across_downtime() {
        let cfg = cfg_with(vec![ChurnWindow { node: 0, from_s: 10.0, until_s: 14.0 }], vec![]);
        let s = Scenario::compile(&cfg, 1);
        // 5s of compute starting at 8: 2s busy, 4s stalled, 3s busy
        let (end, stall) = s.compute_span(0, 8.0, 5.0);
        assert!((end - 17.0).abs() < 1e-12, "end {end}");
        assert!((stall - 4.0).abs() < 1e-12, "stall {stall}");
        // starting inside the window: wait for the end first
        let (end, stall) = s.compute_span(0, 11.0, 1.0);
        assert!((end - 15.0).abs() < 1e-12);
        assert!((stall - 3.0).abs() < 1e-12);
        // fully clear of windows: untouched
        assert_eq!(s.compute_span(0, 20.0, 2.5), (22.5, 0.0));
    }

    #[test]
    fn bandwidth_shifts_are_piecewise_constant() {
        let cfg = cfg_with(
            vec![],
            vec![
                LinkShift { node: 1, at_s: 10.0, bandwidth_factor: 0.25 },
                LinkShift { node: 1, at_s: 30.0, bandwidth_factor: 1.0 },
            ],
        );
        let s = Scenario::compile(&cfg, 2);
        assert_eq!(s.bandwidth_factor(1, 0.0), 1.0);
        assert_eq!(s.bandwidth_factor(1, 10.0), 0.25);
        assert_eq!(s.bandwidth_factor(1, 29.9), 0.25);
        assert_eq!(s.bandwidth_factor(1, 30.0), 1.0);
        // min across participants
        assert_eq!(s.min_bandwidth_factor([0usize, 1], 15.0), 0.25);
        assert_eq!(s.min_bandwidth_factor([0usize], 15.0), 1.0);
        // empty participant set is neutral
        assert_eq!(s.min_bandwidth_factor(std::iter::empty(), 15.0), 1.0);
    }

    #[test]
    fn bandwidth_boosts_pass_through() {
        let cfg = cfg_with(
            vec![],
            vec![
                LinkShift { node: 0, at_s: 0.0, bandwidth_factor: 2.0 },
                LinkShift { node: 1, at_s: 0.0, bandwidth_factor: 3.0 },
            ],
        );
        let s = Scenario::compile(&cfg, 2);
        // a uniformly upgraded link set must not be clamped back to 1.0
        assert_eq!(s.min_bandwidth_factor([0usize, 1], 1.0), 2.0);
    }

    #[test]
    fn compile_trace_matches_compile_on_exported_scenario() {
        let cfg = ScenarioConfig {
            straggler_prob: 0.2,
            churn: vec![
                ChurnWindow { node: 0, from_s: 10.0, until_s: 20.0 },
                ChurnWindow { node: 0, from_s: 15.0, until_s: 25.0 },
                ChurnWindow { node: 2, from_s: 1.0, until_s: 2.0 },
            ],
            link_shifts: vec![
                LinkShift { node: 1, at_s: 5.0, bandwidth_factor: 0.1 },
                LinkShift { node: 1, at_s: 5.0, bandwidth_factor: 0.3 }, // same-t tie
                LinkShift { node: 1, at_s: 20.0, bandwidth_factor: 1.0 },
            ],
            ..ScenarioConfig::default()
        };
        let direct = Scenario::compile(&cfg, 3);
        let replayed =
            Scenario::compile_trace(&crate::simulator::trace::Trace::from_scenario(&cfg, 3));
        // Debug prints every timeline f64 — bit-level structural equality
        assert_eq!(format!("{direct:?}"), format!("{replayed:?}"));
        // same-t tie resolution survives the round trip
        assert_eq!(direct.bandwidth_factor(1, 5.0), 0.3);
        assert_eq!(replayed.bandwidth_factor(1, 5.0), 0.3);
    }

    #[test]
    fn speed_timelines_are_piecewise_and_lockstep_legal() {
        use crate::simulator::trace::{Trace, TraceEvent, TraceRecord};
        let t = Trace {
            nodes: 2,
            straggler_prob: 0.0,
            straggler_min: 1.5,
            straggler_max: 4.0,
            records: vec![
                TraceRecord { t: 5.0, node: 0, ev: TraceEvent::Speed { factor: 2.0 } },
                TraceRecord { t: 10.0, node: 0, ev: TraceEvent::Speed { factor: 0.5 } },
            ],
        };
        let s = Scenario::compile_trace(&t);
        assert_eq!(s.speed_factor(0, 4.9), 1.0);
        assert_eq!(s.speed_factor(0, 5.0), 2.0);
        assert_eq!(s.speed_factor(0, 9.9), 2.0);
        assert_eq!(s.speed_factor(0, 10.0), 0.5);
        assert_eq!(s.speed_factor(1, 100.0), 1.0, "other node untouched");
        // speed-only: dynamic, but legal under lockstep and churn-free
        assert!(!s.is_static());
        assert!(!s.requires_event());
        assert!(!s.has_windows());
    }

    #[test]
    fn binary_search_window_lookups_match_linear_reference() {
        let mut rng = Rng::new(0xB15EC7);
        for _ in 0..200 {
            let n = 1 + rng.below(20) as usize;
            let churn: Vec<ChurnWindow> = (0..n)
                .map(|_| {
                    let from = rng.f64() * 100.0;
                    ChurnWindow { node: 0, from_s: from, until_s: from + 0.1 + rng.f64() * 10.0 }
                })
                .collect();
            let s = Scenario::compile(&cfg_with(churn, vec![]), 1);
            for _ in 0..50 {
                let t = rng.f64() * 120.0;
                let lin_down = s.windows[0]
                    .iter()
                    .find(|&&(from, until)| t >= from && t < until)
                    .map(|&(_, until)| until);
                assert_eq!(s.down_until(0, t), lin_down);
                let lin_next =
                    s.windows[0].iter().map(|&(from, _)| from).find(|&from| from > t);
                assert_eq!(s.next_down_start(0, t), lin_next);
            }
        }
    }
}
