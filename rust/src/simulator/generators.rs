//! Deterministic fleet-dynamics trace generators (DESIGN.md §11).
//!
//! Each generator maps a spec + seed to a [`Trace`], modelling a
//! workload regime the paper's 4-node scenarios cannot reach:
//!
//! * [`spot_market`] — per-node alternating up/down renewal process
//!   with exponential holding times (spot-instance preemption);
//! * [`diurnal`] — per-node sinusoidal compute-slowdown timelines with
//!   random phase (time-of-day load on shared hosts). Speed-only, so
//!   the result is legal under the lockstep walk;
//! * [`rack_failures`] — correlated outages that take a whole topology
//!   group down at once (switch/PDU failure).
//!
//! Every random stream is forked with [`derive_seed`] from the config
//! seed and a per-node/per-group tag — **never** the run's main RNG —
//! so identical seeds reproduce identical traces regardless of how the
//! surrounding run consumes randomness, and generating a trace never
//! perturbs the training stream layout (DESIGN.md §6).

use crate::simulator::trace::{Trace, TraceEvent, TraceRecord};
use crate::util::{derive_seed, Rng};

/// Spot-market preemption: alternating exponential up/down intervals
/// per node.
#[derive(Clone, Debug)]
pub struct SpotMarketSpec {
    /// Cluster size.
    pub nodes: usize,
    /// Only windows *starting* before this horizon are emitted (a
    /// window may extend past it).
    pub horizon_s: f64,
    /// Mean up-time between preemptions (seconds).
    pub mean_up_s: f64,
    /// Mean preemption length (seconds).
    pub mean_down_s: f64,
    /// Config seed the generator streams are derived from.
    pub seed: u64,
}

/// Diurnal load: sinusoidal per-node compute-time multiplier in
/// `[1, 1 + amplitude]`, sampled piecewise-constant.
#[derive(Clone, Debug)]
pub struct DiurnalSpec {
    /// Cluster size.
    pub nodes: usize,
    /// Samples cover `[0, horizon_s)`.
    pub horizon_s: f64,
    /// Period of the load wave (seconds).
    pub period_s: f64,
    /// Peak extra slowdown (factor tops out at `1 + amplitude`).
    pub amplitude: f64,
    /// Piecewise-constant samples per period.
    pub samples_per_period: usize,
    /// Config seed the per-node phase streams are derived from.
    pub seed: u64,
}

/// Correlated rack failures: each outage takes every node of a
/// topology group down over the same window.
#[derive(Clone, Debug)]
pub struct RackFailureSpec {
    /// Cluster size.
    pub nodes: usize,
    /// The topology group map (`cluster.groups`): `groups[g]` lists the
    /// node ids failing together.
    pub groups: Vec<Vec<usize>>,
    /// Outage starts are drawn uniformly over `[0, horizon_s)`.
    pub horizon_s: f64,
    /// Outages drawn per rack.
    pub outages_per_rack: usize,
    /// Mean outage length (seconds, exponential).
    pub mean_down_s: f64,
    /// Config seed the per-group streams are derived from.
    pub seed: u64,
}

/// Exponential draw with the given mean (inverse-CDF of one uniform;
/// `u < 1` keeps it finite).
fn exp_draw(rng: &mut Rng, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

fn sorted_trace(nodes: usize, mut records: Vec<TraceRecord>) -> Trace {
    // stable: equal-t records keep emission order (node-major)
    records.sort_by(|a, b| a.t.total_cmp(&b.t));
    Trace {
        nodes,
        straggler_prob: 0.0,
        straggler_min: 1.5,
        straggler_max: 4.0,
        records,
    }
}

/// Generate a spot-market preemption trace. Per-node windows are
/// strictly increasing and disjoint by construction (a node is never
/// revived mid-outage: the next window starts after the previous one
/// ends plus a fresh up-time).
pub fn spot_market(spec: &SpotMarketSpec) -> Trace {
    let mut records = Vec::new();
    for node in 0..spec.nodes {
        let mut rng = Rng::new(derive_seed(spec.seed, &format!("trace/spot/node={node}")));
        let mut t = exp_draw(&mut rng, spec.mean_up_s);
        while t < spec.horizon_s {
            let down = exp_draw(&mut rng, spec.mean_down_s).max(1e-9);
            records.push(TraceRecord { t, node, ev: TraceEvent::Down { until: t + down } });
            t = t + down + exp_draw(&mut rng, spec.mean_up_s);
        }
    }
    sorted_trace(spec.nodes, records)
}

/// Generate a diurnal-load speed trace: each node's compute-time
/// multiplier follows `1 + a/2 * (1 - cos(2π (t + phase) / period))`,
/// sampled every `period / samples_per_period` seconds. Factors stay
/// inside `[1, 1 + amplitude]` and consume no RNG at replay time.
pub fn diurnal(spec: &DiurnalSpec) -> Trace {
    let mut records = Vec::new();
    let samples = spec.samples_per_period.max(1);
    let dt = spec.period_s / samples as f64;
    for node in 0..spec.nodes {
        let mut rng = Rng::new(derive_seed(spec.seed, &format!("trace/diurnal/node={node}")));
        let phase = rng.f64() * spec.period_s;
        let mut i = 0u64;
        loop {
            let t = i as f64 * dt;
            if t >= spec.horizon_s {
                break;
            }
            let angle = std::f64::consts::TAU * (t + phase) / spec.period_s;
            let factor = 1.0 + spec.amplitude * 0.5 * (1.0 - angle.cos());
            records.push(TraceRecord { t, node, ev: TraceEvent::Speed { factor } });
            i += 1;
        }
    }
    sorted_trace(spec.nodes, records)
}

/// Generate correlated rack failures: for each topology group, draw
/// `outages_per_rack` outage windows and emit an identical `down`
/// record for every member node — the whole rack fails and recovers
/// atomically.
pub fn rack_failures(spec: &RackFailureSpec) -> Trace {
    let mut records = Vec::new();
    for (g, members) in spec.groups.iter().enumerate() {
        let mut rng = Rng::new(derive_seed(spec.seed, &format!("trace/rack/group={g}")));
        for _ in 0..spec.outages_per_rack {
            let start = rng.f64() * spec.horizon_s;
            let down = exp_draw(&mut rng, spec.mean_down_s).max(1e-9);
            for &node in members {
                if node < spec.nodes {
                    records.push(TraceRecord {
                        t: start,
                        node,
                        ev: TraceEvent::Down { until: start + down },
                    });
                }
            }
        }
    }
    sorted_trace(spec.nodes, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spot_windows_never_revive_mid_outage() {
        let spec = SpotMarketSpec {
            nodes: 6,
            horizon_s: 200.0,
            mean_up_s: 10.0,
            mean_down_s: 3.0,
            seed: 42,
        };
        let t = spot_market(&spec);
        assert!(!t.records.is_empty());
        for node in 0..spec.nodes {
            let mut prev_until = f64::NEG_INFINITY;
            for r in t.records.iter().filter(|r| r.node == node) {
                let TraceEvent::Down { until } = r.ev else {
                    panic!("spot trace emits only down records, got {:?}", r.ev)
                };
                assert!(r.t < spec.horizon_s, "window starts inside the horizon");
                assert!(
                    r.t > prev_until,
                    "node {node}: window at t={} overlaps previous outage ending {prev_until}",
                    r.t
                );
                assert!(until > r.t);
                prev_until = until;
            }
        }
    }

    #[test]
    fn diurnal_factors_stay_within_bounds() {
        let spec = DiurnalSpec {
            nodes: 4,
            horizon_s: 50.0,
            period_s: 10.0,
            amplitude: 0.75,
            samples_per_period: 8,
            seed: 7,
        };
        let t = diurnal(&spec);
        assert_eq!(t.records.len(), 4 * 40); // 5 periods x 8 samples x 4 nodes
        for r in &t.records {
            let TraceEvent::Speed { factor } = r.ev else {
                panic!("diurnal trace emits only speed records")
            };
            assert!(
                (1.0..=1.0 + spec.amplitude).contains(&factor),
                "factor {factor} outside [1, 1.75]"
            );
        }
        // phases differ across nodes: the t=0 samples are not all equal
        let first: Vec<f64> = t
            .records
            .iter()
            .filter(|r| r.t == 0.0)
            .map(|r| match r.ev {
                TraceEvent::Speed { factor } => factor,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(first.len(), 4);
        assert!(first.iter().any(|&f| f != first[0]), "per-node phase streams differ");
    }

    #[test]
    fn rack_failures_are_group_atomic() {
        let spec = RackFailureSpec {
            nodes: 8,
            groups: vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]],
            horizon_s: 100.0,
            outages_per_rack: 3,
            mean_down_s: 5.0,
            seed: 11,
        };
        let t = rack_failures(&spec);
        assert_eq!(t.records.len(), 2 * 3 * 4);
        // group every record by (t, until): each window must cover one
        // full rack, and only nodes from that rack
        let mut windows: Vec<(f64, f64, Vec<usize>)> = Vec::new();
        for r in &t.records {
            let TraceEvent::Down { until } = r.ev else { panic!("only down records") };
            match windows.iter_mut().find(|(t0, u0, _)| *t0 == r.t && *u0 == until) {
                Some((_, _, nodes)) => nodes.push(r.node),
                None => windows.push((r.t, until, vec![r.node])),
            }
        }
        assert_eq!(windows.len(), 6);
        for (t0, _, mut nodes) in windows {
            nodes.sort_unstable();
            let rack = spec
                .groups
                .iter()
                .find(|g| g.contains(&nodes[0]))
                .expect("node belongs to a rack");
            let mut want = rack.clone();
            want.sort_unstable();
            assert_eq!(nodes, want, "outage at t={t0} must cover exactly one rack");
        }
    }

    #[test]
    fn identical_seeds_reproduce_identical_traces() {
        let spec = SpotMarketSpec {
            nodes: 5,
            horizon_s: 80.0,
            mean_up_s: 8.0,
            mean_down_s: 2.0,
            seed: 99,
        };
        assert_eq!(spot_market(&spec).to_jsonl(), spot_market(&spec).to_jsonl());
        let mut other = spec.clone();
        other.seed = 100;
        assert_ne!(spot_market(&spec).to_jsonl(), spot_market(&other).to_jsonl());

        let d = DiurnalSpec {
            nodes: 3,
            horizon_s: 20.0,
            period_s: 10.0,
            amplitude: 0.5,
            samples_per_period: 4,
            seed: 5,
        };
        assert_eq!(diurnal(&d).to_jsonl(), diurnal(&d).to_jsonl());
        let r = RackFailureSpec {
            nodes: 4,
            groups: vec![vec![0, 1], vec![2, 3]],
            horizon_s: 60.0,
            outages_per_rack: 2,
            mean_down_s: 4.0,
            seed: 13,
        };
        assert_eq!(rack_failures(&r).to_jsonl(), rack_failures(&r).to_jsonl());
    }
}
