//! Simulated heterogeneous cluster: the discrete-event queue and the
//! dynamic-workload scenarios (plus re-exports of the cluster/comm
//! layers carved out of this module and the coordinator — DESIGN.md §7).
//!
//! The paper simulated its 4-GPU cluster by running trainer threads on one
//! A100 and measuring wall-clock. We replace thread interleaving with a
//! *discrete-event virtual clock* (DESIGN.md §3): every worker carries its
//! own virtual time; compute advances it through a fitted step-time model,
//! synchronization points advance every participant to the barrier maximum
//! plus the modeled transfer time. This is deterministic, reproducible,
//! and lets the theory benches run 10^5 steps in milliseconds.
//!
//! Scheduling comes in two flavours (DESIGN.md §3.1–§3.2): the retained
//! *lockstep* reference walk, and the *event-driven* scheduler built on
//! [`events::EventQueue`], which consumes `StepDone` / `SyncArrive` /
//! `MergeArrive` events in virtual-time order — plus `SyncComplete`
//! markers for delayed-overlap collectives (DESIGN.md §8) — and is the
//! substrate for the [`scenario`] dynamic workloads (stragglers, churn,
//! link shifts).
//!
//! Scenarios themselves come through the [`trace::ScenarioSource`]
//! seam (DESIGN.md §11): either the stochastic config model or a
//! replayed [`trace::Trace`] — a versioned JSONL timeline loaded from
//! disk or produced by the deterministic fleet-dynamics
//! [`generators`] (spot-market preemption, diurnal load, correlated
//! rack failures).
//!
//! Layering note: the clock/node/placement types now live in
//! [`crate::cluster`] and the network/ledger/collective types in
//! [`crate::comm`]; both are re-exported here so historical imports
//! (`adloco::simulator::VirtualClock`, `adloco::simulator::CommLedger`,
//! …) keep resolving.

pub mod events;
pub mod generators;
pub mod scenario;
pub mod trace;

pub use events::{EventQueue, SimEvent};
pub use scenario::Scenario;
pub use trace::{ScenarioSource, Trace, TraceError, TraceEvent, TraceRecord};

pub use crate::cluster::{assign_workers, node_models, NodeModel, VirtualClock};
pub use crate::comm::{CommEvent, CommKind, CommLedger, CommScope, NetworkModel};
