//! Simulated heterogeneous cluster: compute-time model, network model,
//! per-worker virtual clocks, the discrete-event queue, dynamic-workload
//! scenarios, and the communication ledger.
//!
//! The paper simulated its 4-GPU cluster by running trainer threads on one
//! A100 and measuring wall-clock. We replace thread interleaving with a
//! *discrete-event virtual clock* (DESIGN.md §3): every worker carries its
//! own virtual time; compute advances it through a fitted step-time model,
//! synchronization points advance every participant to the barrier maximum
//! plus the modeled transfer time. This is deterministic, reproducible,
//! and lets the theory benches run 10^5 steps in milliseconds.
//!
//! Scheduling comes in two flavours (DESIGN.md §3.1–§3.2): the retained
//! *lockstep* reference walk, and the *event-driven* scheduler built on
//! [`events::EventQueue`], which consumes `StepDone` / `SyncArrive` /
//! `MergeArrive` events in virtual-time order and is the substrate for
//! the [`scenario`] dynamic workloads (stragglers, churn, link shifts).

pub mod events;
pub mod scenario;

pub use events::{EventQueue, SimEvent};
pub use scenario::Scenario;

use crate::config::ClusterConfig;

/// Compute-rate model of one simulated node (GPU).
#[derive(Clone, Debug)]
pub struct NodeModel {
    /// Memory-limited max batch (the paper's `max_batch`).
    pub max_batch: usize,
    /// Relative speed multiplier (1.0 = reference hardware).
    pub speed: f64,
    /// t_step = (fixed + per_token * batch * seq) / speed
    pub step_fixed_s: f64,
    /// Per-token term of the step-time model.
    pub step_per_token_s: f64,
}

impl NodeModel {
    /// Virtual seconds to execute one optimizer step at `batch` x `seq`.
    pub fn step_time(&self, batch: usize, seq: usize) -> f64 {
        (self.step_fixed_s + self.step_per_token_s * (batch * seq) as f64) / self.speed
    }
}

/// Latency + bandwidth network model shared by all links.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Per-transfer latency, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// One point-to-point transfer of `bytes`.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// The same link with its bandwidth scaled by `factor` — how the
    /// scenario layer's time-varying links enter a sync's cost. A factor
    /// of exactly 1.0 reproduces `self` bit-for-bit.
    pub fn scaled(&self, factor: f64) -> NetworkModel {
        NetworkModel {
            latency_s: self.latency_s,
            bandwidth_bps: self.bandwidth_bps * factor,
        }
    }

    /// Parameter-averaging round among `m` participants of `bytes` each.
    /// Modeled as a ring all-reduce: 2(m-1)/m * bytes on the wire per
    /// node, plus one latency per ring hop.
    pub fn allreduce_time(&self, bytes: u64, m: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let hops = 2 * (m - 1);
        hops as f64 * self.latency_s
            + (2.0 * (m as f64 - 1.0) / m as f64) * bytes as f64 / self.bandwidth_bps
    }
}

/// What a communication event was for (ledger taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommKind {
    /// Inner-trainer worker averaging at an outer step (DiLoCo sync).
    OuterSync,
    /// Trainer merge (MIT DoMerge parameter movement).
    Merge,
}

/// One recorded communication event.
#[derive(Clone, Debug)]
pub struct CommEvent {
    /// What the communication was for.
    pub kind: CommKind,
    /// Virtual time the communication completed.
    pub at_virtual_s: f64,
    /// Bytes moved.
    pub bytes: u64,
    /// Number of participating workers/trainers.
    pub participants: usize,
    /// Inner-step index (global, per run) at which it happened.
    pub at_inner_step: u64,
}

/// Ledger of all communications — the observable behind Theorem 2's
/// C(N) and the "communication efficiency" axis of Fig. 1.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    /// Every recorded communication, in completion order.
    pub events: Vec<CommEvent>,
}

impl CommLedger {
    /// Append one communication.
    pub fn record(&mut self, ev: CommEvent) {
        self.events.push(ev);
    }

    /// Total recorded communications.
    pub fn count(&self) -> usize {
        self.events.len()
    }

    /// Recorded communications of one kind.
    pub fn count_kind(&self, kind: CommKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Total bytes across all recorded communications.
    pub fn total_bytes(&self) -> u64 {
        self.events.iter().map(|e| e.bytes).sum()
    }

    /// Cumulative (inner_step, count) series for C(N) plots.
    pub fn cumulative_by_step(&self) -> Vec<(u64, usize)> {
        let mut evs: Vec<&CommEvent> = self.events.iter().collect();
        evs.sort_by_key(|e| e.at_inner_step);
        evs.iter()
            .enumerate()
            .map(|(i, e)| (e.at_inner_step, i + 1))
            .collect()
    }
}

/// Per-worker virtual clocks plus barrier helpers.
#[derive(Clone, Debug)]
pub struct VirtualClock {
    times: Vec<f64>,
}

impl VirtualClock {
    /// All-zero clocks for `workers` slots.
    pub fn new(workers: usize) -> Self {
        VirtualClock { times: vec![0.0; workers] }
    }

    /// Number of clock slots.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    /// True when no slots exist.
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Slot `w`'s current virtual time.
    pub fn time(&self, w: usize) -> f64 {
        self.times[w]
    }

    /// Advance slot `w` by `dt >= 0` seconds.
    pub fn advance(&mut self, w: usize, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.times[w] += dt;
    }

    /// Jump worker `w` forward to absolute time `t` (no-op if already
    /// past). The event scheduler assigns pop timestamps directly so a
    /// worker's clock matches the lockstep `+= dt` chain bit-for-bit.
    pub fn advance_to(&mut self, w: usize, t: f64) {
        if t > self.times[w] {
            self.times[w] = t;
        }
    }

    /// Barrier across a subset: all members jump to the max member time,
    /// then advance by `extra` (e.g. the all-reduce transfer time).
    /// Returns the post-barrier time.
    pub fn barrier(&mut self, members: &[usize], extra: f64) -> f64 {
        let t = members
            .iter()
            .map(|&w| self.times[w])
            .fold(0.0_f64, f64::max)
            + extra;
        for &w in members {
            self.times[w] = t;
        }
        t
    }

    /// Global max time (run wall-clock in virtual seconds).
    pub fn max_time(&self) -> f64 {
        self.times.iter().copied().fold(0.0, f64::max)
    }

    /// Drop clocks not in `keep`, preserving order (trainer merges shrink
    /// the worker set).
    pub fn retain(&mut self, keep: &[usize]) {
        self.times = keep.iter().map(|&w| self.times[w]).collect();
    }
}

/// Build per-node models from a cluster config.
pub fn node_models(cfg: &ClusterConfig) -> Vec<NodeModel> {
    cfg.nodes
        .iter()
        .map(|n| NodeModel {
            max_batch: n.max_batch,
            speed: n.speed,
            step_fixed_s: cfg.step_fixed_s,
            step_per_token_s: cfg.step_per_token_s,
        })
        .collect()
}

/// Round-robin worker->node placement (the paper packs `nodes_per_gpu`
/// trainer processes per simulated GPU the same way).
pub fn assign_workers(total_workers: usize, nodes: usize) -> Vec<usize> {
    (0..total_workers).map(|w| w % nodes).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_scales_with_batch_and_speed() {
        let n = NodeModel { max_batch: 8, speed: 2.0, step_fixed_s: 0.01, step_per_token_s: 1e-4 };
        let t1 = n.step_time(1, 64);
        let t8 = n.step_time(8, 64);
        assert!(t8 > t1);
        assert!((t1 - (0.01 + 64.0 * 1e-4) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn allreduce_time_properties() {
        let net = NetworkModel { latency_s: 1e-3, bandwidth_bps: 1e9 };
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        let t2 = net.allreduce_time(1_000_000, 2);
        let t4 = net.allreduce_time(1_000_000, 4);
        assert!(t2 > 0.0);
        assert!(t4 > t2, "more participants -> more ring hops");
        // bandwidth term approaches 2*bytes/bw from below
        let t_big = net.allreduce_time(1_000_000_000, 4);
        assert!(t_big < 2.0 * 1e9 as f64 / 1e9 + 1.0);
    }

    #[test]
    fn barrier_aligns_members() {
        let mut c = VirtualClock::new(4);
        c.advance(0, 1.0);
        c.advance(1, 3.0);
        c.advance(2, 2.0);
        let t = c.barrier(&[0, 1, 2], 0.5);
        assert!((t - 3.5).abs() < 1e-12);
        for w in 0..3 {
            assert!((c.time(w) - 3.5).abs() < 1e-12);
        }
        assert_eq!(c.time(3), 0.0, "non-member unaffected");
    }

    #[test]
    fn retain_preserves_selected() {
        let mut c = VirtualClock::new(3);
        c.advance(0, 1.0);
        c.advance(1, 2.0);
        c.advance(2, 3.0);
        c.retain(&[0, 2]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.time(0), 1.0);
        assert_eq!(c.time(1), 3.0);
    }

    #[test]
    fn ledger_accounting() {
        let mut l = CommLedger::default();
        l.record(CommEvent {
            kind: CommKind::OuterSync,
            at_virtual_s: 1.0,
            bytes: 100,
            participants: 2,
            at_inner_step: 10,
        });
        l.record(CommEvent {
            kind: CommKind::Merge,
            at_virtual_s: 2.0,
            bytes: 50,
            participants: 3,
            at_inner_step: 20,
        });
        assert_eq!(l.count(), 2);
        assert_eq!(l.count_kind(CommKind::OuterSync), 1);
        assert_eq!(l.total_bytes(), 150);
        assert_eq!(l.cumulative_by_step(), vec![(10, 1), (20, 2)]);
    }

    #[test]
    fn assignment_round_robin() {
        assert_eq!(assign_workers(5, 2), vec![0, 1, 0, 1, 0]);
    }
}
