//! Cluster topology: flat single-tier, or hierarchical two-tier node
//! groups (DESIGN.md §7).
//!
//! A hierarchical cluster partitions its nodes into groups wired by
//! fast intra-group links; groups talk to each other only through
//! their leaders over the slow WAN. The [`Topology`] is the compiled
//! node→group map the [`crate::comm::CommLayer`] consults when pricing
//! a synchronization and the coordinator consults when selecting
//! merge candidates (prefer trainers homed in the same group — the
//! cheap side of the MIT cost asymmetry).

use crate::config::{ClusterConfig, TopologyKind};

/// Compiled node→group map. Flat clusters get a single implicit group
/// (every cost path then degenerates to the one-network formula).
#[derive(Clone, Debug)]
pub struct Topology {
    kind: TopologyKind,
    /// Node id → group id (all zeros when flat).
    group_of: Vec<usize>,
    n_groups: usize,
}

impl Topology {
    /// Compile the config's topology block. Malformed group maps
    /// (empty group, node in two groups, unassigned node) are rejected
    /// by `Config::validate` before this is reached.
    pub fn compile(cfg: &ClusterConfig) -> Topology {
        match cfg.topology {
            TopologyKind::Flat => Topology {
                kind: TopologyKind::Flat,
                group_of: vec![0; cfg.nodes.len()],
                n_groups: 1,
            },
            TopologyKind::Hierarchical => {
                let mut group_of = vec![0usize; cfg.nodes.len()];
                for (g, members) in cfg.groups.iter().enumerate() {
                    for &node in members {
                        if node < group_of.len() {
                            group_of[node] = g;
                        }
                    }
                }
                Topology {
                    kind: TopologyKind::Hierarchical,
                    group_of,
                    n_groups: cfg.groups.len(),
                }
            }
        }
    }

    /// True under the two-tier (grouped) topology.
    pub fn is_hierarchical(&self) -> bool {
        self.kind == TopologyKind::Hierarchical
    }

    /// Group of `node` (0 for every node of a flat cluster).
    pub fn group_of(&self, node: usize) -> usize {
        self.group_of[node]
    }

    /// Number of groups (1 for flat).
    pub fn n_groups(&self) -> usize {
        self.n_groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn flat_is_one_group() {
        let cfg = presets::mock_default().cluster;
        let t = Topology::compile(&cfg);
        assert!(!t.is_hierarchical());
        assert_eq!(t.n_groups(), 1);
        for n in 0..cfg.nodes.len() {
            assert_eq!(t.group_of(n), 0);
        }
    }

    #[test]
    fn hierarchical_maps_nodes_to_groups() {
        let mut cfg = presets::mock_default().cluster;
        cfg.topology = TopologyKind::Hierarchical;
        cfg.groups = vec![vec![0, 2], vec![1, 3]];
        let t = Topology::compile(&cfg);
        assert!(t.is_hierarchical());
        assert_eq!(t.n_groups(), 2);
        assert_eq!(t.group_of(0), 0);
        assert_eq!(t.group_of(2), 0);
        assert_eq!(t.group_of(1), 1);
        assert_eq!(t.group_of(3), 1);
    }
}
