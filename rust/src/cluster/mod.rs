//! The cluster layer: node compute models, worker placement, per-worker
//! virtual clocks with barrier/utilization accounting, churn lifecycle,
//! and the flat/hierarchical topology (DESIGN.md §7).
//!
//! Carved out of the coordinator god-module together with [`crate::comm`]:
//! the coordinator now asks the [`ClusterState`] *where time goes*
//! (clock ownership, barrier waits, preemption downtime) and the comm
//! layer *what a synchronization costs*; only training policy stays in
//! `coordinator/`. The split keeps the determinism contract intact —
//! every f64 accumulation sequence here is the exact arithmetic the
//! pre-split coordinator performed (DESIGN.md §6).

pub mod topology;

pub use topology::Topology;

use crate::config::ClusterConfig;
use crate::metrics::UtilRecord;
use crate::simulator::Scenario;
use crate::trainer::Trainer;
use crate::util::Rng;
use anyhow::Result;

/// Compute-rate model of one simulated node (GPU).
#[derive(Clone, Debug)]
pub struct NodeModel {
    /// Memory-limited max batch (the paper's `max_batch`).
    pub max_batch: usize,
    /// Relative speed multiplier (1.0 = reference hardware).
    pub speed: f64,
    /// t_step = (fixed + per_token * batch * seq) / speed
    pub step_fixed_s: f64,
    /// Per-token term of the step-time model.
    pub step_per_token_s: f64,
}

impl NodeModel {
    /// Virtual seconds to execute one optimizer step at `batch` x `seq`.
    pub fn step_time(&self, batch: usize, seq: usize) -> f64 {
        (self.step_fixed_s + self.step_per_token_s * (batch * seq) as f64) / self.speed
    }
}

/// Per-worker virtual clocks plus barrier helpers.
#[derive(Clone, Debug)]
pub struct VirtualClock {
    times: Vec<f64>,
}

impl VirtualClock {
    /// All-zero clocks for `workers` slots.
    pub fn new(workers: usize) -> Self {
        VirtualClock { times: vec![0.0; workers] }
    }

    /// Number of clock slots.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    /// True when no slots exist.
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Slot `w`'s current virtual time.
    pub fn time(&self, w: usize) -> f64 {
        self.times[w]
    }

    /// Advance slot `w` by `dt >= 0` seconds.
    pub fn advance(&mut self, w: usize, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.times[w] += dt;
    }

    /// Jump worker `w` forward to absolute time `t` (no-op if already
    /// past). The event scheduler assigns pop timestamps directly so a
    /// worker's clock matches the lockstep `+= dt` chain bit-for-bit.
    pub fn advance_to(&mut self, w: usize, t: f64) {
        if t > self.times[w] {
            self.times[w] = t;
        }
    }

    /// Barrier across a subset: all members jump to the max member time,
    /// then advance by `extra` (e.g. the all-reduce transfer time).
    /// Returns the post-barrier time.
    pub fn barrier(&mut self, members: &[usize], extra: f64) -> f64 {
        let t = members
            .iter()
            .map(|&w| self.times[w])
            .fold(0.0_f64, f64::max)
            + extra;
        for &w in members {
            self.times[w] = t;
        }
        t
    }

    /// Global max time (run wall-clock in virtual seconds).
    pub fn max_time(&self) -> f64 {
        self.times.iter().copied().fold(0.0, f64::max)
    }

    /// Drop clocks not in `keep`, preserving order (trainer merges shrink
    /// the worker set).
    pub fn retain(&mut self, keep: &[usize]) {
        self.times = keep.iter().map(|&w| self.times[w]).collect();
    }

    /// Append a new clock slot starting at absolute time `t` (elastic
    /// spawns join mid-run at the cluster front, not at t = 0 —
    /// DESIGN.md §9). Returns the new slot's index.
    pub fn push(&mut self, t: f64) -> usize {
        debug_assert!(t >= 0.0);
        self.times.push(t);
        self.times.len() - 1
    }
}

/// Build per-node models from a cluster config.
pub fn node_models(cfg: &ClusterConfig) -> Vec<NodeModel> {
    cfg.nodes
        .iter()
        .map(|n| NodeModel {
            max_batch: n.max_batch,
            speed: n.speed,
            step_fixed_s: cfg.step_fixed_s,
            step_per_token_s: cfg.step_per_token_s,
        })
        .collect()
}

/// Round-robin worker->node placement (the paper packs `nodes_per_gpu`
/// trainer processes per simulated GPU the same way).
pub fn assign_workers(total_workers: usize, nodes: usize) -> Vec<usize> {
    (0..total_workers).map(|w| w % nodes).collect()
}

/// Everything the simulated cluster knows about *time and place*: node
/// models, per-worker virtual clocks, the dynamic-workload scenario,
/// the topology, and the per-slot time accounting behind the
/// utilization report.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// Per-worker virtual clocks (one slot per worker).
    pub clock: VirtualClock,
    /// Per-node compute models.
    pub nodes: Vec<NodeModel>,
    /// Compiled dynamic-workload scenario.
    pub scenario: Scenario,
    /// Compiled flat/hierarchical topology.
    pub topology: Topology,
    /// Per-slot compute seconds.
    pub busy_s: Vec<f64>,
    /// Per-slot barrier-wait seconds (idling behind slower peers).
    pub wait_s: Vec<f64>,
    /// Per-slot modeled communication seconds.
    pub comm_s: Vec<f64>,
    /// Per-slot communication seconds *hidden* under the next round's
    /// compute by the delayed-overlap mode (DESIGN.md §8). Unlike
    /// `comm_s` these never advanced the worker's clock — they are the
    /// part of a collective the overlap amortized away. Always zero in
    /// blocking mode.
    pub comm_hidden_s: Vec<f64>,
    /// Per-slot churn-preemption downtime seconds.
    pub preempted_s: Vec<f64>,
    /// Per-slot capacity seconds with no live instance assigned
    /// (DESIGN.md §9): accrued for the frozen slots of merge-retired
    /// trainers. Distinct from `wait_s`/`preempted_s` — nobody was
    /// scheduled there — and excluded from the utilization denominator.
    pub vacant_s: Vec<f64>,
}

impl ClusterState {
    /// Build the cluster layer for `slots` worker clock slots, compiling
    /// the stochastic `cluster.scenario` block directly.
    pub fn new(cfg: &ClusterConfig, slots: usize) -> ClusterState {
        let scenario = Scenario::compile(&cfg.scenario, cfg.nodes.len());
        ClusterState::new_with_scenario(cfg, slots, scenario)
    }

    /// Build the cluster layer around an already-compiled scenario —
    /// the `ScenarioSource` seam (DESIGN.md §11): the coordinator
    /// resolves `cluster.trace` (stochastic model, trace file, or
    /// generator) and injects the result here.
    pub fn new_with_scenario(cfg: &ClusterConfig, slots: usize, scenario: Scenario) -> ClusterState {
        ClusterState {
            clock: VirtualClock::new(slots),
            nodes: node_models(cfg),
            scenario,
            topology: Topology::compile(cfg),
            busy_s: vec![0.0; slots],
            wait_s: vec![0.0; slots],
            comm_s: vec![0.0; slots],
            comm_hidden_s: vec![0.0; slots],
            preempted_s: vec![0.0; slots],
            vacant_s: vec![0.0; slots],
        }
    }

    /// Allocate a fresh worker clock slot starting at absolute time `t`
    /// with zeroed time accounting — how elastic spawns obtain their
    /// slots (DESIGN.md §9). Existing slots are untouched, so growing
    /// the pool never perturbs any accumulated f64 sequence.
    pub fn push_slot(&mut self, t: f64) -> usize {
        let slot = self.clock.push(t);
        self.busy_s.push(0.0);
        self.wait_s.push(0.0);
        self.comm_s.push(0.0);
        self.comm_hidden_s.push(0.0);
        self.preempted_s.push(0.0);
        self.vacant_s.push(0.0);
        slot
    }

    /// Set slot `w`'s vacant capacity to the window from its frozen
    /// clock to `until` (no live instance assigned — DESIGN.md §9).
    /// An **assignment**, not an accumulation: the window is fully
    /// recomputable from the frozen clock and the reclaim timeline, so
    /// re-running the end-of-run accounting (e.g. resuming from a
    /// snapshot taken after a completed run) is idempotent. The clock
    /// itself is not advanced: the slot has no owner to move.
    pub fn set_vacant_window(&mut self, w: usize, until: f64) {
        self.vacant_s[w] = (until - self.clock.time(w)).max(0.0);
    }

    /// Credit `hidden` seconds of overlapped (clock-free) communication
    /// to every member slot — the per-worker side of the delayed-overlap
    /// accounting (DESIGN.md §8).
    pub fn charge_hidden(&mut self, members: &[usize], hidden: f64) {
        debug_assert!(hidden >= 0.0);
        for &w in members {
            self.comm_hidden_s[w] += hidden;
        }
    }

    /// Barrier with utilization accounting: members wait for the slowest
    /// (wait time) then pay the transfer (comm time). Numerically exactly
    /// [`VirtualClock::barrier`].
    pub fn barrier_tracked(&mut self, members: &[usize], extra: f64) -> f64 {
        let t_start = members
            .iter()
            .map(|&w| self.clock.time(w))
            .fold(0.0_f64, f64::max);
        for &w in members {
            self.wait_s[w] += t_start - self.clock.time(w);
            self.comm_s[w] += extra;
        }
        self.clock.barrier(members, extra)
    }

    /// Per-worker utilization rows from the accumulated time accounting
    /// (workers enumerate in clock-slot order).
    pub fn utilization_table(&self, trainers: &[Trainer]) -> Vec<UtilRecord> {
        let mut out = Vec::with_capacity(self.busy_s.len());
        for tr in trainers {
            for (wi, w) in tr.workers.iter().enumerate() {
                let s = w.clock_slot;
                out.push(UtilRecord {
                    trainer: tr.id,
                    worker: wi,
                    node: w.node,
                    busy_s: self.busy_s[s],
                    wait_s: self.wait_s[s],
                    comm_s: self.comm_s[s],
                    hidden_s: self.comm_hidden_s[s],
                    preempted_s: self.preempted_s[s],
                    vacant_s: self.vacant_s[s],
                });
            }
        }
        out
    }

    /// Churn bookkeeping at an outer boundary: workers on preempted nodes
    /// sit the round out; returning workers catch their clocks up and the
    /// trainer's shard is re-split among the currently active workers
    /// (the `Shard::split` / `union_shards` machinery).
    #[allow(clippy::needless_range_loop)] // body interleaves &mut self calls
    pub fn apply_churn(&mut self, trainers: &mut [Trainer], rng: &mut Rng) -> Result<()> {
        // only preemption windows need boundary bookkeeping; shift- or
        // straggler-only scenarios used to pay this full-fleet sweep
        // too, which the fig6 scale pass showed up at 10k workers
        if !self.scenario.has_windows() {
            return Ok(());
        }
        for ti in 0..trainers.len() {
            if !trainers[ti].alive {
                continue;
            }
            // the trainer front: where its active cohort currently is; a
            // fully-preempted trainer's clocks are frozen, so fall back
            // to the global front or it would never see its window end
            let mut t_now = trainers[ti]
                .workers
                .iter()
                .map(|w| self.clock.time(w.clock_slot))
                .fold(0.0f64, f64::max);
            if !trainers[ti].workers.iter().any(|w| w.active) {
                t_now = t_now.max(self.clock.max_time());
            }
            let changed = trainers[ti]
                .workers
                .iter()
                .any(|w| self.scenario.node_available(w.node, t_now) != w.active);
            if !changed {
                continue;
            }
            for wi in 0..trainers[ti].workers.len() {
                let (node, slot, was_active) = {
                    let w = &trainers[ti].workers[wi];
                    (w.node, w.clock_slot, w.active)
                };
                let avail = self.scenario.node_available(node, t_now);
                if avail && !was_active {
                    // rejoin: jump to the trainer front; the gap was
                    // preemption downtime
                    let cur = self.clock.time(slot);
                    if t_now > cur {
                        self.clock.advance_to(slot, t_now);
                        self.preempted_s[slot] += t_now - cur;
                    }
                }
                trainers[ti].workers[wi].active = avail;
            }
            let active_ix: Vec<usize> = trainers[ti]
                .workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.active)
                .map(|(i, _)| i)
                .collect();
            if active_ix.is_empty() {
                crate::info!("trainer {ti}: all workers preempted; sitting this round out");
                continue;
            }
            let parts = trainers[ti].shard.split(active_ix.len());
            for (&w_ix, part) in active_ix.iter().zip(parts.into_iter()) {
                trainers[ti].workers[w_ix].sampler = crate::data::BatchSampler::new(
                    part,
                    rng.fork(0xC4A5 ^ ((ti as u64) << 8) ^ (w_ix as u64)),
                );
            }
            crate::debug!(
                "trainer {ti}: churn re-shard over {} active workers at t={t_now:.2}s",
                active_ix.len()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_scales_with_batch_and_speed() {
        let n = NodeModel { max_batch: 8, speed: 2.0, step_fixed_s: 0.01, step_per_token_s: 1e-4 };
        let t1 = n.step_time(1, 64);
        let t8 = n.step_time(8, 64);
        assert!(t8 > t1);
        assert!((t1 - (0.01 + 64.0 * 1e-4) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn barrier_aligns_members() {
        let mut c = VirtualClock::new(4);
        c.advance(0, 1.0);
        c.advance(1, 3.0);
        c.advance(2, 2.0);
        let t = c.barrier(&[0, 1, 2], 0.5);
        assert!((t - 3.5).abs() < 1e-12);
        for w in 0..3 {
            assert!((c.time(w) - 3.5).abs() < 1e-12);
        }
        assert_eq!(c.time(3), 0.0, "non-member unaffected");
    }

    #[test]
    fn retain_preserves_selected() {
        let mut c = VirtualClock::new(3);
        c.advance(0, 1.0);
        c.advance(1, 2.0);
        c.advance(2, 3.0);
        c.retain(&[0, 2]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.time(0), 1.0);
        assert_eq!(c.time(1), 3.0);
    }

    #[test]
    fn assignment_round_robin() {
        assert_eq!(assign_workers(5, 2), vec![0, 1, 0, 1, 0]);
    }

    #[test]
    fn barrier_tracked_accounts_wait_and_comm() {
        let cfg = crate::config::presets::mock_default().cluster;
        let mut cs = ClusterState::new(&cfg, 3);
        cs.clock.advance(0, 1.0);
        cs.clock.advance(1, 3.0);
        let t = cs.barrier_tracked(&[0, 1], 0.5);
        assert!((t - 3.5).abs() < 1e-12);
        assert!((cs.wait_s[0] - 2.0).abs() < 1e-12, "slot 0 waited for slot 1");
        assert_eq!(cs.wait_s[1], 0.0);
        assert!((cs.comm_s[0] - 0.5).abs() < 1e-12);
        assert!((cs.comm_s[1] - 0.5).abs() < 1e-12);
        assert_eq!(cs.wait_s[2], 0.0, "non-member unaffected");
    }

    #[test]
    fn push_slot_extends_all_accounting_in_lockstep() {
        let cfg = crate::config::presets::mock_default().cluster;
        let mut cs = ClusterState::new(&cfg, 2);
        cs.clock.advance(0, 1.0);
        cs.busy_s[0] = 1.0;
        let s = cs.push_slot(7.5);
        assert_eq!(s, 2);
        assert_eq!(cs.clock.len(), 3);
        assert_eq!(cs.clock.time(2), 7.5, "spawned slot starts at the front");
        let tables =
            [&cs.busy_s, &cs.wait_s, &cs.comm_s, &cs.comm_hidden_s, &cs.preempted_s, &cs.vacant_s];
        for v in tables {
            assert_eq!(v.len(), 3);
            assert_eq!(v[2], 0.0);
        }
        assert_eq!(cs.clock.time(0), 1.0, "existing slots untouched");
        assert_eq!(cs.busy_s[0], 1.0);
    }

    #[test]
    fn vacant_window_is_assigned_idempotently_without_moving_the_clock() {
        let cfg = crate::config::presets::mock_default().cluster;
        let mut cs = ClusterState::new(&cfg, 2);
        cs.clock.advance(0, 2.0);
        cs.set_vacant_window(0, 5.0);
        assert!((cs.vacant_s[0] - 3.0).abs() < 1e-12);
        assert_eq!(cs.clock.time(0), 2.0, "no owner, no clock movement");
        // re-running the accounting is an assignment, never a double count
        cs.set_vacant_window(0, 5.0);
        assert!((cs.vacant_s[0] - 3.0).abs() < 1e-12, "idempotent");
        // an earlier end recomputes (clamped at zero)
        cs.set_vacant_window(0, 1.0);
        assert_eq!(cs.vacant_s[0], 0.0);
        assert_eq!(cs.wait_s[0], 0.0, "vacancy never inflates wait_s");
    }

    #[test]
    fn charge_hidden_credits_members_without_moving_clocks() {
        let cfg = crate::config::presets::mock_default().cluster;
        let mut cs = ClusterState::new(&cfg, 3);
        cs.clock.advance(0, 1.0);
        cs.charge_hidden(&[0, 2], 0.25);
        assert!((cs.comm_hidden_s[0] - 0.25).abs() < 1e-12);
        assert_eq!(cs.comm_hidden_s[1], 0.0, "non-member unaffected");
        assert!((cs.comm_hidden_s[2] - 0.25).abs() < 1e-12);
        assert_eq!(cs.clock.time(0), 1.0, "hidden comm never advances a clock");
        assert_eq!(cs.comm_s[0], 0.0, "hidden time is not exposed comm time");
    }
}
