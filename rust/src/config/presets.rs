//! Named configuration presets, including the paper's Table 1.

use super::*;

/// Look up a preset by name (used by config files' `"preset"` key).
pub fn by_name(name: &str) -> Option<Config> {
    match name {
        "mock_default" => Some(mock_default()),
        "paper_table1" => Some(paper_table1()),
        "xla_tiny" => Some(xla_tiny()),
        "xla_small" => Some(xla_small()),
        "quick" => Some(quick()),
        "hetero_dynamic" => Some(hetero_dynamic()),
        "hierarchical_mit" => Some(hierarchical_mit()),
        "adloco_overlap" => Some(adloco_overlap()),
        "elastic_mit" => Some(elastic_mit()),
        "fleet_trace" => Some(fleet_trace()),
        _ => None,
    }
}

/// Every preset name `by_name` resolves.
pub fn preset_names() -> &'static [&'static str] {
    &[
        "mock_default",
        "paper_table1",
        "xla_tiny",
        "xla_small",
        "quick",
        "hetero_dynamic",
        "hierarchical_mit",
        "adloco_overlap",
        "elastic_mit",
        "fleet_trace",
    ]
}

fn base_batching() -> BatchingConfig {
    BatchingConfig {
        adaptive: true,
        test: BatchTest::Norm,
        eta: 0.8,      // paper Table 1
        theta: 0.01,   // paper Table 1 (vartheta)
        nu: 0.3,       // paper Table 1
        initial_batch: 1, // paper Table 1
        ema_beta: 0.5,
        monotone: true,
        // 8x the paper's switch threshold (2 * max_batch = 128): deep
        // enough to exercise SwitchMode, bounded enough to terminate.
        max_request: 1024,
    }
}

fn base_cluster(nodes: usize, max_batch: usize) -> ClusterConfig {
    ClusterConfig {
        nodes: (0..nodes)
            .map(|_| NodeConfig { max_batch, speed: 1.0 })
            .collect(),
        // Values in the ballpark of a 10 GbE interconnect between the
        // paper's simulated GPUs; overridable per experiment.
        net_latency_s: 1e-3,
        net_bandwidth_bps: 1.25e9,
        // Filled from measured PJRT timings by `adloco calibrate`; these
        // defaults approximate the tiny profile on this machine.
        step_fixed_s: 5e-3,
        step_per_token_s: 3e-5,
        step_jitter: 0.0,
        scenario: ScenarioConfig::default(),
        trace: TraceSourceConfig::Stochastic,
        // flat single tier by default; the WAN tier only engages under
        // topology=hierarchical (a 10x slower cross-group link in the
        // ballpark of a shared datacenter uplink)
        topology: TopologyKind::Flat,
        groups: Vec::new(),
        wan_latency_s: 1e-2,
        wan_bandwidth_bps: 1.25e8,
        sync_collective: CollectiveKind::Ring,
    }
}

/// The paper's Table 1 hyperparameters, MockEngine substrate.
///
/// | num_outer_steps 20 | num_inner_steps 200 | lr_inner 2e-5 | lr_outer 0.5 |
/// | nodes_per_gpu 4 | num_init_trainers 4 | initial_batch_size 1 |
/// | merge_frequency 3 | eta 0.8 | theta 0.01 | nu 0.3 |
pub fn paper_table1() -> Config {
    Config {
        name: "paper_table1".into(),
        seed: 0,
        engine: EngineConfig::Mock { dim: 2000, noise: 1.0, condition: 25.0 },
        algo: AlgoConfig {
            method: Method::AdLoCo,
            num_trainers: 4,      // num_init_trainers
            workers_per_trainer: 1,
            inner_steps: 200,     // num_inner_steps
            outer_steps: 20,      // num_outer_steps
            lr_inner: 2e-5,
            lr_outer: 0.5,
            lr_schedule: ScheduleConfig::default(),
            outer_opt: OuterOptKind::Nesterov { momentum: 0.9 },
            batching: base_batching(),
            merge: MergeConfig {
                enabled: true,
                w: 2,
                frequency: 3,
                min_trainers: 1,
                policy: MergeSelect::WorstByBatch,
            },
            switch: SwitchConfig { enabled: true, multiplier: 2.0 },
            elastic: ElasticConfig::default(), // frozen pool (DESIGN.md §9)
            fixed_batch: 16,
        },
        data: DataConfig {
            corpus_sequences: 20_000,
            vocab: 256,
            seq_len: 64,
            zipf_s: 1.1,
            shard_fraction: 0.5,
            val_sequences: 512,
            seed: 7,
        },
        cluster: base_cluster(4, 64), // 4 simulated GPUs (paper §6.1)
        comm: CommConfig::default(), // blocking outer syncs (DESIGN.md §8)
        run: RunConfig {
            eval_every: 10, // paper: eval every 10 steps
            eval_batches: 4,
            target_ppl: 0.0,
            max_inner_steps: 0,
            checkpoint_path: None,
            checkpoint_every: 0,
            resume_from: None,
            keep_checkpoints: 0, // overwrite-in-place; N>0 keeps last N + merge pins
            scheduler: SchedulerKind::Lockstep,
            threads: 0, // auto: RUN_THREADS env var, else serial
            stream_records: false, // buffered JSONL; fleet-scale runs opt in
        },
        service: ServiceConfig::default(),
        out_dir: None,
    }
}

/// Fast MockEngine default for tests and quick CLI runs.
pub fn mock_default() -> Config {
    let mut cfg = paper_table1();
    cfg.name = "mock_default".into();
    cfg.algo.inner_steps = 20;
    cfg.algo.outer_steps = 8;
    cfg.algo.lr_inner = 0.05;
    cfg.engine = EngineConfig::Mock { dim: 500, noise: 1.0, condition: 10.0 };
    cfg.data.corpus_sequences = 4_000;
    cfg.data.val_sequences = 128;
    cfg
}

/// XlaEngine on the `tiny` artifact profile (matches python/compile/aot.py).
pub fn xla_tiny() -> Config {
    let mut cfg = paper_table1();
    cfg.name = "xla_tiny".into();
    cfg.engine = EngineConfig::Xla {
        artifacts_dir: "artifacts".into(),
        profile: "tiny".into(),
    };
    cfg.algo.inner_steps = 10;
    cfg.algo.outer_steps = 6;
    cfg.algo.lr_inner = 4e-4; // paper §6.1 AdamW lr
    cfg.data.vocab = 256;
    cfg.data.seq_len = 64;
    cfg.data.corpus_sequences = 4_000;
    cfg.data.val_sequences = 64;
    // ladder tops out at 16 for the tiny profile
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 16;
    }
    cfg.run.eval_every = 10;
    cfg.run.eval_batches = 2;
    cfg
}

/// XlaEngine on the `small` profile — the end-to-end example model.
pub fn xla_small() -> Config {
    let mut cfg = xla_tiny();
    cfg.name = "xla_small".into();
    cfg.engine = EngineConfig::Xla {
        artifacts_dir: "artifacts".into(),
        profile: "small".into(),
    };
    cfg.data.vocab = 512;
    cfg.data.seq_len = 128;
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 32;
    }
    cfg
}

/// Heterogeneous cluster under a dynamic workload: mixed node speeds and
/// memory budgets, stochastic stragglers, one mid-run node preemption and
/// a temporary bandwidth collapse — the scenario the paper's introduction
/// motivates. Runs on the event scheduler (required for scenarios).
pub fn hetero_dynamic() -> Config {
    let mut cfg = paper_table1();
    cfg.name = "hetero_dynamic".into();
    cfg.algo.outer_steps = 10;
    cfg.algo.inner_steps = 30;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.lr_inner = 0.02;
    cfg.algo.fixed_batch = 8;
    cfg.engine = EngineConfig::Mock { dim: 500, noise: 1.0, condition: 10.0 };
    cfg.data.corpus_sequences = 4_000;
    cfg.data.val_sequences = 128;
    cfg.run.eval_every = 10;
    cfg.run.scheduler = SchedulerKind::Event;
    // one fast/big node, two mid, one slow/small straggler host
    cfg.cluster.nodes = vec![
        NodeConfig { max_batch: 128, speed: 2.0 },
        NodeConfig { max_batch: 64, speed: 1.0 },
        NodeConfig { max_batch: 64, speed: 1.0 },
        NodeConfig { max_batch: 16, speed: 0.35 },
    ];
    cfg.cluster.scenario = ScenarioConfig {
        straggler_prob: 0.15,
        straggler_min: 1.5,
        straggler_max: 4.0,
        // the slow node drops out mid-run, then returns
        churn: vec![ChurnWindow { node: 3, from_s: 8.0, until_s: 16.0 }],
        // node 1's uplink collapses to a tenth for a while
        link_shifts: vec![
            LinkShift { node: 1, at_s: 5.0, bandwidth_factor: 0.1 },
            LinkShift { node: 1, at_s: 20.0, bandwidth_factor: 1.0 },
        ],
    };
    cfg
}

/// Hierarchical two-level MIT topology on heterogeneous nodes: the
/// four hetero nodes partitioned into two groups (`[[0,1],[2,3]]`)
/// with fast intra-group links and a 10x slower WAN between group
/// leaders. Worker→trainer reduces and MIT merges run intra-group;
/// only cross-group merges touch the WAN — the two-level cost
/// asymmetry of the paper's MIT stage (DESIGN.md §7). Static scenario
/// (no stragglers/churn), so `theory::estimate_ledger` predicts the
/// comm ledger exactly (see `tests/topology.rs`).
pub fn hierarchical_mit() -> Config {
    let mut cfg = paper_table1();
    cfg.name = "hierarchical_mit".into();
    cfg.algo.outer_steps = 10;
    cfg.algo.inner_steps = 30;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.lr_inner = 0.02;
    cfg.algo.fixed_batch = 8;
    cfg.engine = EngineConfig::Mock { dim: 500, noise: 1.0, condition: 10.0 };
    cfg.data.corpus_sequences = 4_000;
    cfg.data.val_sequences = 128;
    cfg.run.eval_every = 10;
    cfg.run.scheduler = SchedulerKind::Event;
    // heterogeneous nodes as in hetero_dynamic, but a static cluster
    cfg.cluster.nodes = vec![
        NodeConfig { max_batch: 128, speed: 2.0 },
        NodeConfig { max_batch: 64, speed: 1.0 },
        NodeConfig { max_batch: 64, speed: 1.0 },
        NodeConfig { max_batch: 16, speed: 0.35 },
    ];
    cfg.cluster.topology = TopologyKind::Hierarchical;
    cfg.cluster.groups = vec![vec![0, 1], vec![2, 3]];
    cfg.cluster.wan_latency_s = 1e-2;
    cfg.cluster.wan_bandwidth_bps = 1.25e8; // a tenth of the intra links
    cfg
}

/// The `hetero_dynamic` schedule with ACCO-style delayed outer syncs
/// (DESIGN.md §8): the round-k collective is posted non-blocking and its
/// outer update applies one round late, hiding the transfer under the
/// next round's compute — the overlap lever AdLoCo's adaptive batching
/// complements (`benches/fig4_overlap.rs` measures the saving).
pub fn adloco_overlap() -> Config {
    let mut cfg = hetero_dynamic();
    cfg.name = "adloco_overlap".into();
    cfg.comm.overlap = OverlapMode::Delayed;
    cfg
}

/// The `hetero_dynamic` schedule with the elastic trainer lifecycle on
/// (DESIGN.md §9): one extra worker slot of headroom per node
/// (`node_capacity = 3` against the initial 2-per-node packing) and a
/// utilization-driven spawn controller, so capacity freed by the churn
/// window and by MIT merges is refilled with fresh lightweight streams
/// instead of idling — the paper's "multiple lightweight training
/// streams … increasing throughput and reducing idle time" made a
/// runtime policy (`benches/fig5_elastic.rs` measures the gain).
pub fn elastic_mit() -> Config {
    let mut cfg = hetero_dynamic();
    cfg.name = "elastic_mit".into();
    cfg.algo.elastic = ElasticConfig {
        mode: ElasticMode::UtilThreshold,
        // the 2:1:1:0.35 speed spread makes fast nodes wait far longer
        // than this at every sync barrier, so freed capacity refills
        idle_threshold: 0.05,
        max_instances: 8,
        cooldown_rounds: 2,
        workers_per_spawn: 1,
        node_capacity: 3,
    };
    cfg
}

/// Fleet-scale trace replay (DESIGN.md §11): 8 trainers x 4 workers
/// spread over 16 uniform nodes, driven by a generated spot-market
/// preemption trace instead of the hand-set stochastic scenario. The
/// membership is kept fixed (merging off, pool frozen) so the preset
/// scales cleanly to the 100/1k/10k-worker grid of
/// `benches/fig6_scale.rs` — node churn, not algorithm phase changes,
/// is what the big-cluster points stress.
pub fn fleet_trace() -> Config {
    let mut cfg = paper_table1();
    cfg.name = "fleet_trace".into();
    cfg.engine = EngineConfig::Mock { dim: 256, noise: 1.0, condition: 10.0 };
    cfg.algo.num_trainers = 8;
    cfg.algo.workers_per_trainer = 4;
    cfg.algo.inner_steps = 12;
    cfg.algo.outer_steps = 6;
    cfg.algo.lr_inner = 0.02;
    cfg.algo.fixed_batch = 8;
    cfg.algo.merge.enabled = false;
    cfg.data.corpus_sequences = 4_000;
    cfg.data.val_sequences = 128;
    cfg.run.eval_every = 6;
    cfg.run.scheduler = SchedulerKind::Event;
    cfg.cluster = base_cluster(16, 32);
    // spot-market churn sized to the run's few-seconds virtual-time
    // span, so preemptions actually land inside the run
    cfg.cluster.trace = TraceSourceConfig::Generator(TraceGenConfig {
        kind: TraceGenKind::SpotMarket,
        horizon_s: 8.0,
        mean_up_s: 2.5,
        mean_down_s: 0.8,
        ..TraceGenConfig::default()
    });
    // fleet scale is exactly where the buffered recorder's open tail
    // hurts (10k workers x thousands of step records held in RAM):
    // stream per-round when an out_dir is set. The final JSONL stays
    // byte-identical to buffered (tests/stream_records.rs; the fig6
    // smoke bench asserts it at scale).
    cfg.run.stream_records = true;
    cfg
}

/// Minimal smoke-run preset (seconds, MockEngine).
pub fn quick() -> Config {
    let mut cfg = mock_default();
    cfg.name = "quick".into();
    cfg.algo.inner_steps = 5;
    cfg.algo.outer_steps = 3;
    cfg.algo.num_trainers = 2;
    cfg.data.corpus_sequences = 500;
    cfg.data.val_sequences = 32;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    /// TAB1: pin the paper's Table 1 values exactly.
    #[test]
    fn table1_values() {
        let c = paper_table1();
        assert_eq!(c.algo.outer_steps, 20);
        assert_eq!(c.algo.inner_steps, 200);
        assert_eq!(c.algo.lr_inner, 2e-5);
        assert_eq!(c.algo.lr_outer, 0.5);
        assert_eq!(c.cluster.nodes.len(), 4); // nodes_per_gpu
        assert_eq!(c.algo.num_trainers, 4);   // num_init_trainers
        assert_eq!(c.algo.batching.initial_batch, 1);
        assert_eq!(c.algo.merge.frequency, 3);
        assert_eq!(c.algo.batching.eta, 0.8);
        assert_eq!(c.algo.batching.theta, 0.01);
        assert_eq!(c.algo.batching.nu, 0.3);
        assert_eq!(c.algo.switch.multiplier, 2.0);
    }

    #[test]
    fn all_presets_resolvable_and_valid() {
        for name in preset_names() {
            let cfg = by_name(name).unwrap();
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn elastic_preset_is_util_driven_hetero_dynamic() {
        let cfg = elastic_mit();
        assert_eq!(cfg.algo.elastic.mode, ElasticMode::UtilThreshold);
        assert!(cfg.algo.elastic.node_capacity > 0, "explicit spawn headroom");
        assert!(cfg.algo.elastic.max_instances >= cfg.algo.num_trainers);
        // every other preset keeps the pool frozen
        for name in preset_names() {
            let want = if *name == "elastic_mit" {
                ElasticMode::UtilThreshold
            } else {
                ElasticMode::Off
            };
            assert_eq!(by_name(name).unwrap().algo.elastic.mode, want, "{name}");
        }
        // same cluster/scenario/schedule as hetero_dynamic: only the
        // lifecycle knob differs
        let hetero = hetero_dynamic();
        assert_eq!(cfg.cluster.nodes.len(), hetero.cluster.nodes.len());
        assert_eq!(cfg.cluster.scenario.churn, hetero.cluster.scenario.churn);
        assert_eq!(cfg.run.scheduler, SchedulerKind::Event);
    }

    #[test]
    fn fleet_trace_preset_replays_a_generated_spot_trace() {
        let cfg = fleet_trace();
        cfg.validate().unwrap();
        assert_eq!(cfg.run.scheduler, SchedulerKind::Event);
        assert_eq!(cfg.algo.num_trainers * cfg.algo.workers_per_trainer, 32);
        assert!(cfg.cluster.scenario.is_static(), "trace replaces the stochastic model");
        match &cfg.cluster.trace {
            TraceSourceConfig::Generator(g) => {
                assert_eq!(g.kind, TraceGenKind::SpotMarket);
                assert!(g.horizon_s > 0.0 && g.mean_up_s > 0.0 && g.mean_down_s > 0.0);
            }
            other => panic!("fleet_trace must use a generator source, got {other:?}"),
        }
        // membership stays fixed so the preset scales to the fig6 grid
        assert!(!cfg.algo.merge.enabled);
        // fleet scale drains the recorder per round instead of holding
        // the open tail in RAM; all other presets stay buffered
        assert!(cfg.run.stream_records);
        // every other preset keeps the stochastic source (and the
        // buffered recorder)
        for name in preset_names() {
            if *name != "fleet_trace" {
                let other = by_name(name).unwrap();
                assert_eq!(other.cluster.trace, TraceSourceConfig::Stochastic, "{name}");
                assert!(!other.run.stream_records, "{name}");
            }
        }
    }

    #[test]
    fn overlap_preset_is_delayed_hetero_dynamic() {
        let overlap = adloco_overlap();
        assert_eq!(overlap.comm.overlap, OverlapMode::Delayed);
        // every preset other than the overlap one keeps blocking syncs
        for name in preset_names() {
            let cfg = by_name(name).unwrap();
            let want = if *name == "adloco_overlap" {
                OverlapMode::Delayed
            } else {
                OverlapMode::Blocking
            };
            assert_eq!(cfg.comm.overlap, want, "{name}");
        }
        // the twin relationship: same cluster/scenario/schedule as
        // hetero_dynamic, only the overlap knob differs
        let hetero = hetero_dynamic();
        assert_eq!(overlap.algo.outer_steps, hetero.algo.outer_steps);
        assert_eq!(overlap.cluster.nodes.len(), hetero.cluster.nodes.len());
        assert_eq!(overlap.cluster.scenario.churn, hetero.cluster.scenario.churn);
        assert_eq!(overlap.run.scheduler, SchedulerKind::Event);
    }
}
