//! Experiment configuration: typed structs, JSON loading, dotted-path
//! overrides, validation, and the paper's hyperparameter presets.
//!
//! A config fully determines a run (together with the artifact profile):
//! engine, algorithm (AdLoCo / DiLoCo / LocalSGD and every ablation knob),
//! data generation, simulated cluster, and run schedule.  `Config::load`
//! reads a JSON file; `Config::apply_override` implements
//! `--set algo.batching.eta=0.5`-style CLI overrides so benches and
//! examples can sweep parameters without writing files.

pub mod presets;

use crate::util::JsonValue;
use anyhow::{anyhow, bail, Context, Result};

/// Which coordination algorithm the run uses. AdLoCo with every feature
/// disabled degrades to DiLoCo; DiLoCo with a trivial outer optimizer and
/// H-step averaging is LocalSGD — the coordinator implements all three via
/// these knobs, matching the paper's ablation structure (Fig. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// The paper's full method (adaptive batching + MIT + SwitchMode).
    AdLoCo,
    /// DiLoCo baseline (fixed batch, no merging/switching).
    DiLoCo,
    /// LocalSGD baseline (DiLoCo with a plain-average outer step).
    LocalSgd,
}

impl Method {
    /// Parse a CLI/config method name.
    pub fn parse(s: &str) -> Result<Method> {
        match s.to_ascii_lowercase().as_str() {
            "adloco" => Ok(Method::AdLoCo),
            "diloco" => Ok(Method::DiLoCo),
            "localsgd" | "local_sgd" => Ok(Method::LocalSgd),
            _ => bail!("unknown method {s:?} (adloco|diloco|localsgd)"),
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            Method::AdLoCo => "adloco",
            Method::DiLoCo => "diloco",
            Method::LocalSgd => "localsgd",
        }
    }
}

/// Which statistical test drives the requested batch size (paper §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchTest {
    /// Eq. 10 — the paper's default.
    Norm,
    /// Eq. 12.
    InnerProduct,
    /// Eq. 13 (max of inner-product and orthogonality terms).
    Augmented,
}

impl BatchTest {
    /// Parse a CLI/config batch-test name.
    pub fn parse(s: &str) -> Result<BatchTest> {
        match s.to_ascii_lowercase().as_str() {
            "norm" => Ok(BatchTest::Norm),
            "inner_product" | "ip" => Ok(BatchTest::InnerProduct),
            "augmented" | "aug" => Ok(BatchTest::Augmented),
            _ => bail!("unknown batch test {s:?} (norm|inner_product|augmented)"),
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            BatchTest::Norm => "norm",
            BatchTest::InnerProduct => "inner_product",
            BatchTest::Augmented => "augmented",
        }
    }
}

/// Which compute substrate the run uses.
#[derive(Clone, Debug)]
pub enum EngineConfig {
    /// Pure-Rust synthetic objective (fast; powers theory benches & tests).
    Mock {
        /// Problem dimension.
        dim: usize,
        /// Per-sample gradient noise standard deviation.
        noise: f64,
        /// Condition number of the quadratic part.
        condition: f64,
    },
    /// PJRT-backed transformer from `artifacts/<profile>/`.
    Xla {
        /// Root artifacts directory (holds one subdir per profile).
        artifacts_dir: String,
        /// Profile name (e.g. "tiny", "small").
        profile: String,
    },
}

/// Adaptive-batching knobs (paper §3.3).
#[derive(Clone, Debug)]
pub struct BatchingConfig {
    /// false => fixed batch (DiLoCo / ablation arm).
    pub adaptive: bool,
    /// Which statistical test drives the request.
    pub test: BatchTest,
    /// Norm-test eta (paper Table 1: 0.8).
    pub eta: f64,
    /// Inner-product-test theta (paper Table 1: 0.01).
    pub theta: f64,
    /// Augmented-test nu (paper Table 1: 0.3).
    pub nu: f64,
    /// Starting batch size (paper Table 1: 1).
    pub initial_batch: usize,
    /// EMA smoothing for noisy variance estimates (beta; 0 disables).
    pub ema_beta: f64,
    /// Batch can only grow (monotone, as in AdAdaGrad's theory) if true.
    pub monotone: bool,
    /// Hard cap on the requested batch (bounds SwitchMode accumulation
    /// depth; 0 = uncapped). Real systems always carry such a guard —
    /// without it the norm test's request diverges as ||∇F|| → 0.
    pub max_request: usize,
}

/// Multi-Instance Training merge knobs (paper §4.1).
#[derive(Clone, Debug)]
pub struct MergeConfig {
    /// Master switch for MIT merging.
    pub enabled: bool,
    /// Merge the `w` worst trainers by requested batch (Algorithm 1).
    pub w: usize,
    /// Check for merges every this many outer steps (paper Table 1: 3).
    pub frequency: usize,
    /// Minimum trainer count to keep (merging stops at this many).
    pub min_trainers: usize,
    /// Selection rule: the paper's worst-by-requested-batch, or random
    /// (the control arm isolating the selection policy's contribution).
    pub policy: MergeSelect,
}

/// Merge-selection rule (paper default vs control arm).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeSelect {
    /// The paper's rule: merge the w worst trainers by requested batch.
    WorstByBatch,
    /// Random selection (control arm isolating the rule's contribution).
    Random,
}

impl MergeSelect {
    /// Parse a CLI/config merge-policy name.
    pub fn parse(s: &str) -> Result<MergeSelect> {
        match s.to_ascii_lowercase().as_str() {
            "worst" | "worst_by_batch" => Ok(MergeSelect::WorstByBatch),
            "random" => Ok(MergeSelect::Random),
            _ => bail!("unknown merge policy {s:?} (worst|random)"),
        }
    }
}

/// Learning-rate schedule parameters (see `crate::schedule`).
#[derive(Clone, Debug)]
pub struct ScheduleConfig {
    /// constant | warmup | warmup_cosine | step_decay
    pub kind: String,
    /// Linear-warmup steps (warmup kinds).
    pub warmup_steps: u64,
    /// 0 = derive from outer_steps * inner_steps.
    pub total_steps: u64,
    /// Cosine floor as a fraction of the base lr.
    pub min_frac: f64,
    /// Steps between decays (step_decay).
    pub decay_every: u64,
    /// Multiplier applied at each decay (step_decay).
    pub decay_factor: f64,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        ScheduleConfig {
            kind: "constant".into(),
            warmup_steps: 0,
            total_steps: 0,
            min_frac: 0.1,
            decay_every: 100,
            decay_factor: 0.5,
        }
    }
}

/// Elastic trainer-lifecycle policy (DESIGN.md §9): whether — and how —
/// the coordinator may grow the instance pool at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElasticMode {
    /// The pool is frozen at config time (historical behaviour, and the
    /// bit-for-bit default: no registry decision is ever evaluated).
    Off,
    /// Spawn a lightweight instance on any available node whose idle
    /// fraction reaches `elastic.idle_threshold` and that still has
    /// worker-slot capacity (churn- or merge-freed capacity counts as
    /// fully idle).
    UtilThreshold,
    /// After each MIT merge retires part of the pool, respawn as many
    /// fresh instances on the least-loaded nodes — merges consolidate
    /// knowledge without permanently draining parallelism.
    RespawnAfterMerge,
}

impl ElasticMode {
    /// Parse a CLI/config elastic-mode name.
    pub fn parse(s: &str) -> Result<ElasticMode> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Ok(ElasticMode::Off),
            "util_threshold" | "util" => Ok(ElasticMode::UtilThreshold),
            "respawn_after_merge" | "respawn" => Ok(ElasticMode::RespawnAfterMerge),
            _ => bail!("unknown elastic mode {s:?} (off|util_threshold|respawn_after_merge)"),
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            ElasticMode::Off => "off",
            ElasticMode::UtilThreshold => "util_threshold",
            ElasticMode::RespawnAfterMerge => "respawn_after_merge",
        }
    }
}

/// Elastic-lifecycle knobs (DESIGN.md §9). The whole block is inert
/// while `mode == Off`.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Lifecycle policy (see [`ElasticMode`]).
    pub mode: ElasticMode,
    /// `util_threshold`: spawn when a node's accumulated idle fraction
    /// `(wait + preempted) / accounted` reaches this.
    pub idle_threshold: f64,
    /// Hard cap on live instances (0 = `2 × algo.num_trainers`).
    pub max_instances: usize,
    /// Minimum outer rounds between consecutive `util_threshold` spawn
    /// rounds (respawn-after-merge fires immediately).
    pub cooldown_rounds: usize,
    /// Workers per spawned instance — the paper's "lightweight training
    /// stream" width (seed instances keep `workers_per_trainer`).
    pub workers_per_spawn: usize,
    /// Per-node worker-slot capacity the spawn controller respects
    /// (0 = derive from the densest initial placement).
    pub node_capacity: usize,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            mode: ElasticMode::Off,
            idle_threshold: 0.25,
            max_instances: 0,
            cooldown_rounds: 2,
            workers_per_spawn: 1,
            node_capacity: 0,
        }
    }
}

/// SwitchMode (gradient accumulation) knobs (paper §4.2).
#[derive(Clone, Debug)]
pub struct SwitchConfig {
    /// Master switch for SwitchMode.
    pub enabled: bool,
    /// Accumulation engages when b_req > multiplier * max_batch (paper: 2).
    pub multiplier: f64,
}

/// Outer-optimizer flavour (Algorithm 3 line 43).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OuterOptKind {
    /// Plain parameter averaging (LocalSGD-style).
    Average,
    /// SGD on the outer delta (what the theorems assume).
    Sgd,
    /// Nesterov momentum on the outer delta (DiLoCo's default).
    Nesterov {
        /// Momentum coefficient (DiLoCo default: 0.9).
        momentum: f64,
    },
}

/// The coordination algorithm and its hyperparameters.
#[derive(Clone, Debug)]
pub struct AlgoConfig {
    /// Which method the run realizes (see [`Method`]).
    pub method: Method,
    /// k — initial number of trainers (paper Table 1: 4).
    pub num_trainers: usize,
    /// M — workers per trainer.
    pub workers_per_trainer: usize,
    /// H — inner steps per outer step (paper Table 1: 200).
    pub inner_steps: usize,
    /// T — outer steps (paper Table 1: 20).
    pub outer_steps: usize,
    /// Inner (worker) learning rate.
    pub lr_inner: f64,
    /// Outer-optimizer learning rate.
    pub lr_outer: f64,
    /// Inner-lr schedule over the worker's inner-step axis.
    pub lr_schedule: ScheduleConfig,
    /// Outer-optimizer flavour.
    pub outer_opt: OuterOptKind,
    /// Adaptive-batching knobs.
    pub batching: BatchingConfig,
    /// MIT merging knobs.
    pub merge: MergeConfig,
    /// SwitchMode knobs.
    pub switch: SwitchConfig,
    /// Elastic trainer-lifecycle knobs (DESIGN.md §9).
    pub elastic: ElasticConfig,
    /// Batch used when batching.adaptive == false.
    pub fixed_batch: usize,
}

/// Synthetic-corpus generation knobs (DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Total corpus size in sequences.
    pub corpus_sequences: usize,
    /// Vocabulary (must match the artifact profile for XlaEngine).
    pub vocab: usize,
    /// Sequence length + 1 tokens per example (input+target overlap).
    pub seq_len: usize,
    /// Zipf exponent of the unigram distribution.
    pub zipf_s: f64,
    /// Fraction of each trainer's shard drawn from the shared pool
    /// (shards "possibly intersecting", §4.1.1).
    pub shard_fraction: f64,
    /// Held-out validation sequences.
    pub val_sequences: usize,
    /// Corpus-generation seed (independent of the run seed).
    pub seed: u64,
}

/// One simulated node (GPU) of the cluster.
#[derive(Clone, Debug)]
pub struct NodeConfig {
    /// Memory-limited max batch per node (the paper's max_batch).
    pub max_batch: usize,
    /// Relative compute speed (1.0 = reference; heterogeneity knob).
    pub speed: f64,
}

/// Which run loop drives the simulated cluster (DESIGN.md §3.1–§3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// The reference round-lockstep walk: trainers and workers are
    /// iterated in fixed program order. Bit-exact anchor for regressions;
    /// cannot express dynamic workloads.
    Lockstep,
    /// Discrete-event scheduler: worker steps, sync and merge arrivals
    /// are consumed from a priority queue in virtual-time order. On a
    /// static cluster it reproduces the lockstep ledger bit-for-bit;
    /// with a scenario configured it models stragglers, churn and
    /// time-varying links.
    Event,
}

impl SchedulerKind {
    /// Parse a CLI/config scheduler name.
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        match s.to_ascii_lowercase().as_str() {
            "lockstep" => Ok(SchedulerKind::Lockstep),
            "event" => Ok(SchedulerKind::Event),
            _ => bail!("unknown scheduler {s:?} (lockstep|event)"),
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            SchedulerKind::Lockstep => "lockstep",
            SchedulerKind::Event => "event",
        }
    }
}

/// Cluster topology flavour (DESIGN.md §7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Single-tier cluster: one shared network connects every node
    /// (that network plays the WAN role in topology comparisons).
    Flat,
    /// Two-tier cluster: nodes are partitioned into `cluster.groups`
    /// with fast intra-group links (`cluster.net_*`); groups talk only
    /// through their leaders over the slow WAN (`cluster.wan_*`). MIT
    /// merges and worker→trainer reduces stay intra-group where
    /// possible; outer DiLoCo syncs cross the WAN leader-to-leader.
    Hierarchical,
}

impl TopologyKind {
    /// Parse a CLI/config topology name.
    pub fn parse(s: &str) -> Result<TopologyKind> {
        match s.to_ascii_lowercase().as_str() {
            "flat" => Ok(TopologyKind::Flat),
            "hierarchical" | "hier" => Ok(TopologyKind::Hierarchical),
            _ => bail!("unknown topology {s:?} (flat|hierarchical)"),
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            TopologyKind::Flat => "flat",
            TopologyKind::Hierarchical => "hierarchical",
        }
    }
}

/// How outer syncs overlap with compute (DESIGN.md §8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// The historical rendezvous: workers barrier at the outer boundary
    /// and pay the full collective time before the outer update applies.
    /// Bit-identical to every pre-overlap release.
    Blocking,
    /// ACCO-style delayed application: the round-k collective is posted
    /// non-blocking at the boundary and its outer update applies one
    /// outer round later, so round k+1's compute runs on parameters
    /// stale by exactly one update while the transfer is in flight.
    /// Workers only stall for whatever part of the collective the next
    /// round's compute could not hide.
    Delayed,
}

impl OverlapMode {
    /// Parse a CLI/config overlap-mode name.
    pub fn parse(s: &str) -> Result<OverlapMode> {
        match s.to_ascii_lowercase().as_str() {
            "blocking" => Ok(OverlapMode::Blocking),
            "delayed" => Ok(OverlapMode::Delayed),
            _ => bail!("unknown overlap mode {s:?} (blocking|delayed)"),
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            OverlapMode::Blocking => "blocking",
            OverlapMode::Delayed => "delayed",
        }
    }
}

/// Communication-behaviour knobs (the comm layer's config block; the
/// network *shapes* stay under `cluster.*` where they always lived).
#[derive(Clone, Debug)]
pub struct CommConfig {
    /// Outer-sync overlap mode (DESIGN.md §8). `Blocking` reproduces
    /// the pre-overlap output bit-for-bit.
    pub overlap: OverlapMode,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig { overlap: OverlapMode::Blocking }
    }
}

/// Which collective prices the outer sync (the pluggable-collective
/// axis of the comm layer; cost table in `comm::collective`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveKind {
    /// Ring all-reduce (default; the historical simulator model).
    Ring,
    /// Binary-tree all-reduce.
    Tree,
    /// Central parameter server.
    ParamServer,
}

impl CollectiveKind {
    /// Parse a CLI/config collective name.
    pub fn parse(s: &str) -> Result<CollectiveKind> {
        match s.to_ascii_lowercase().as_str() {
            "ring" => Ok(CollectiveKind::Ring),
            "tree" => Ok(CollectiveKind::Tree),
            "param_server" | "ps" => Ok(CollectiveKind::ParamServer),
            _ => bail!("unknown collective {s:?} (ring|tree|param_server)"),
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            CollectiveKind::Ring => "ring",
            CollectiveKind::Tree => "tree",
            CollectiveKind::ParamServer => "param_server",
        }
    }
}

/// A node-preemption window: the node is down over `[from_s, until_s)`
/// of virtual time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnWindow {
    /// Node preempted by this window.
    pub node: usize,
    /// Window start (virtual seconds, inclusive).
    pub from_s: f64,
    /// Window end (virtual seconds, exclusive).
    pub until_s: f64,
}

/// A scheduled bandwidth change on one node's link: from `at_s` on, the
/// link runs at `bandwidth_factor` x the base bandwidth (piecewise
/// constant until the next shift).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkShift {
    /// Node whose link shifts.
    pub node: usize,
    /// Virtual time the shift takes effect.
    pub at_s: f64,
    /// New bandwidth multiplier (piecewise constant onward).
    pub bandwidth_factor: f64,
}

/// Dynamic-workload scenario knobs (compiled by `simulator::Scenario`).
/// The default is fully static; any non-static scenario requires the
/// event scheduler.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    /// Per-inner-step probability a worker's compute is slowed (0 = off).
    pub straggler_prob: f64,
    /// Slowdown multiplier range, drawn uniformly on a straggler hit.
    pub straggler_min: f64,
    /// Upper end of the straggler slowdown range.
    pub straggler_max: f64,
    /// Node preemption windows (virtual seconds).
    pub churn: Vec<ChurnWindow>,
    /// Scheduled per-node link-bandwidth changes.
    pub link_shifts: Vec<LinkShift>,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            straggler_prob: 0.0,
            straggler_min: 1.5,
            straggler_max: 4.0,
            churn: Vec::new(),
            link_shifts: Vec::new(),
        }
    }
}

impl ScenarioConfig {
    /// True when no knob perturbs the cluster.
    pub fn is_static(&self) -> bool {
        self.straggler_prob <= 0.0 && self.churn.is_empty() && self.link_shifts.is_empty()
    }
}

/// Where the run's workload scenario comes from (the `ScenarioSource`
/// seam, DESIGN.md §11). Non-stochastic sources replace the
/// `cluster.scenario` block, which must then stay at its static
/// default (validated — two sources would be ambiguous).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum TraceSourceConfig {
    /// Compile the stochastic `cluster.scenario` model (default).
    #[default]
    Stochastic,
    /// Replay a JSONL trace file (`simulator::Trace`); set via
    /// `cluster.trace_path`.
    Path(String),
    /// Generate a deterministic trace at startup
    /// (`simulator::generators`); set via `cluster.trace_gen`.
    Generator(TraceGenConfig),
}

/// Which fleet-dynamics generator builds the trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceGenKind {
    /// Per-node alternating exponential up/down preemption windows.
    SpotMarket,
    /// Sinusoidal per-node compute-slowdown timelines (speed-only, so
    /// legal under the lockstep scheduler).
    Diurnal,
    /// Correlated outages taking whole `cluster.groups` racks down.
    RackFailures,
}

impl TraceGenKind {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> Result<TraceGenKind> {
        match s {
            "spot_market" => Ok(TraceGenKind::SpotMarket),
            "diurnal" => Ok(TraceGenKind::Diurnal),
            "rack_failures" => Ok(TraceGenKind::RackFailures),
            _ => bail!("unknown trace generator {s:?} (spot_market | diurnal | rack_failures)"),
        }
    }

    /// Canonical config-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            TraceGenKind::SpotMarket => "spot_market",
            TraceGenKind::Diurnal => "diurnal",
            TraceGenKind::RackFailures => "rack_failures",
        }
    }
}

/// Knobs for the deterministic trace generators. Only the fields the
/// chosen `kind` reads are validated; the rest ride along so partial
/// overlays can switch kinds without resetting everything.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceGenConfig {
    /// Generator flavour.
    pub kind: TraceGenKind,
    /// Trace horizon: events are generated over `[0, horizon_s)` of
    /// virtual time.
    pub horizon_s: f64,
    /// Spot market: mean up-time between preemptions (seconds).
    pub mean_up_s: f64,
    /// Spot market / rack failures: mean outage length (seconds).
    pub mean_down_s: f64,
    /// Diurnal: load-wave period (seconds).
    pub period_s: f64,
    /// Diurnal: peak extra slowdown (factor tops out at 1 + amplitude).
    pub amplitude: f64,
    /// Diurnal: piecewise-constant samples per period.
    pub samples_per_period: usize,
    /// Rack failures: outage windows drawn per rack.
    pub outages_per_rack: usize,
}

impl Default for TraceGenConfig {
    fn default() -> Self {
        TraceGenConfig {
            kind: TraceGenKind::SpotMarket,
            horizon_s: 20.0,
            mean_up_s: 6.0,
            mean_down_s: 1.5,
            period_s: 10.0,
            amplitude: 0.5,
            samples_per_period: 8,
            outages_per_rack: 1,
        }
    }
}

/// The simulated cluster: nodes, network, and dynamic workload.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Simulated nodes (workers are placed round-robin across them).
    pub nodes: Vec<NodeConfig>,
    /// Per-sync latency, seconds (alpha in t = alpha + bytes/beta).
    pub net_latency_s: f64,
    /// Bandwidth, bytes/second.
    pub net_bandwidth_bps: f64,
    /// Step-time model: t_step = step_fixed_s + step_per_token_s * b * seq.
    pub step_fixed_s: f64,
    /// Per-token term of the step-time model.
    pub step_per_token_s: f64,
    /// Fractional lognormal-ish jitter on per-step compute time
    /// (dynamic-workload knob from the paper's motivation; 0 = none).
    /// Drawn from each worker's private time stream, so it is
    /// scheduler-order independent.
    pub step_jitter: f64,
    /// Dynamic-workload scenario (stragglers / churn / link shifts).
    pub scenario: ScenarioConfig,
    /// Scenario source seam (DESIGN.md §11): the stochastic `scenario`
    /// block, a replayed JSONL trace file, or a deterministic trace
    /// generator.
    pub trace: TraceSourceConfig,
    /// Topology flavour: flat (one shared network) or hierarchical
    /// (node groups + WAN between group leaders) — DESIGN.md §7.
    pub topology: TopologyKind,
    /// Hierarchical node groups: `groups[g]` lists the node ids of
    /// group `g`. Must partition `nodes` exactly (validated: no empty
    /// group, no node — and hence no worker — in two groups, no
    /// unassigned node). Ignored under the flat topology.
    pub groups: Vec<Vec<usize>>,
    /// WAN latency between group leaders, seconds (hierarchical only).
    pub wan_latency_s: f64,
    /// WAN bandwidth between group leaders, bytes/second.
    pub wan_bandwidth_bps: f64,
    /// Collective model pricing outer syncs (ring | tree | param_server).
    pub sync_collective: CollectiveKind,
}

/// Run-schedule knobs: evaluation cadence, stopping, checkpoints,
/// scheduler flavour and the parallel runtime's thread count.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Evaluate every this many *inner* steps (paper: every 10 steps).
    pub eval_every: usize,
    /// Number of eval batches averaged per evaluation.
    pub eval_batches: usize,
    /// Stop early when validation perplexity reaches this (0 = never).
    pub target_ppl: f64,
    /// Hard cap on total inner steps across the run (0 = no cap).
    pub max_inner_steps: usize,
    /// Write a checkpoint here every `checkpoint_every` outer steps.
    pub checkpoint_path: Option<String>,
    /// 0 disables periodic checkpointing (a final one is still written
    /// when `checkpoint_path` is set).
    pub checkpoint_every: usize,
    /// Resume trainer state from this checkpoint before the first step.
    pub resume_from: Option<String>,
    /// Checkpoint retention (DESIGN.md §10): 0 (default) overwrites the
    /// single `checkpoint_path` file in place; N > 0 writes per-step
    /// files `<checkpoint_path>.<step:06>` and prunes to the last N
    /// plus the pinned merge-boundary checkpoints.
    pub keep_checkpoints: usize,
    /// Run-loop flavour; `Event` is required for dynamic scenarios.
    pub scheduler: SchedulerKind,
    /// OS threads for the in-run parallel execution runtime (DESIGN.md
    /// §6): worker inner-step chains fan out across this many threads
    /// between sync/merge rendezvous. `1` = serial; `0` = auto (the
    /// `RUN_THREADS` env var if set, else 1). Any value produces
    /// bit-identical ledgers/records/results — threads only change
    /// wall-clock (the determinism suite in
    /// `tests/determinism_parallel.rs` enforces this).
    pub threads: usize,
    /// Stream per-inner-step records to disk once per outer round
    /// instead of holding them all in RAM (fleet-scale runs: 10k workers
    /// × thousands of rounds). Requires `out_dir`; the final JSONL is
    /// byte-identical to the buffered writer's
    /// (`tests/stream_records.rs`).
    pub stream_records: bool,
}

impl RunConfig {
    /// Resolve the `threads` knob: an explicit value wins; `0` defers to
    /// the `RUN_THREADS` environment variable (serial when unset or
    /// unparsable). Always >= 1.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        std::env::var("RUN_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    }
}

/// Service-mode settings for the `adloco serve` daemon (DESIGN.md §13).
/// Like `run`, none of these affect a run's output — they only shape how
/// the control plane accepts and schedules work — so they are excluded
/// from [`Config::structural_digest`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Bind address for the HTTP listener (loopback by default; the
    /// daemon has no auth layer, so exposing it wider is on you).
    pub addr: String,
    /// TCP port; `0` asks the OS for an ephemeral port (the daemon
    /// prints the bound address at startup — also how the tests avoid
    /// loopback port collisions across parallel CI legs).
    pub port: u16,
    /// How many submitted runs may execute concurrently; further
    /// submissions queue FIFO in `Submitted` state. Each run still uses
    /// its own `run.threads` inner fan-out.
    pub max_concurrent_runs: usize,
    /// Reject request bodies larger than this many bytes (HTTP 413).
    pub max_body_bytes: usize,
    /// Reject request heads (request line + headers) larger than this
    /// many bytes (HTTP 431).
    pub max_header_bytes: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1".to_string(),
            port: 0,
            max_concurrent_runs: 2,
            max_body_bytes: 1 << 20,
            max_header_bytes: 16 * 1024,
        }
    }
}

/// A full experiment description; determines a run together with the
/// artifact profile (and nothing else — see the determinism contract,
/// DESIGN.md §6).
#[derive(Clone, Debug)]
pub struct Config {
    /// Run name (output files, logs, result rows).
    pub name: String,
    /// Master seed every stochastic stream forks from.
    pub seed: u64,
    /// Compute substrate.
    pub engine: EngineConfig,
    /// Coordination algorithm + hyperparameters.
    pub algo: AlgoConfig,
    /// Synthetic-corpus generation.
    pub data: DataConfig,
    /// Simulated cluster + dynamic workload.
    pub cluster: ClusterConfig,
    /// Communication behaviour (outer-sync overlap mode).
    pub comm: CommConfig,
    /// Run schedule (eval cadence, checkpoints, scheduler, threads).
    pub run: RunConfig,
    /// `adloco serve` control-plane settings (DESIGN.md §13).
    pub service: ServiceConfig,
    /// Metrics output directory (JSONL/CSV); None = in-memory only.
    pub out_dir: Option<String>,
}

impl Config {
    /// Validate cross-field invariants; call after construction/overrides.
    pub fn validate(&self) -> Result<()> {
        let a = &self.algo;
        if a.num_trainers == 0 {
            bail!("algo.num_trainers must be >= 1");
        }
        if a.workers_per_trainer == 0 {
            bail!("algo.workers_per_trainer must be >= 1");
        }
        if a.inner_steps == 0 || a.outer_steps == 0 {
            bail!("algo.inner_steps / outer_steps must be >= 1");
        }
        if a.batching.initial_batch == 0 {
            bail!("batching.initial_batch must be >= 1");
        }
        if !(0.0..1.0).contains(&a.batching.ema_beta) {
            bail!("batching.ema_beta must be in [0,1)");
        }
        if a.batching.eta <= 0.0 || a.batching.theta <= 0.0 || a.batching.nu <= 0.0 {
            bail!("batching test constants must be positive");
        }
        if a.merge.enabled && a.merge.w == 0 {
            bail!("merge.w must be >= 1 when merging is enabled");
        }
        if a.merge.min_trainers == 0 {
            bail!("merge.min_trainers must be >= 1");
        }
        if a.switch.enabled && a.switch.multiplier < 1.0 {
            bail!("switch.multiplier must be >= 1");
        }
        if a.elastic.mode != ElasticMode::Off {
            if !(0.0..=1.0).contains(&a.elastic.idle_threshold) {
                bail!("elastic.idle_threshold must be in [0,1]");
            }
            if a.elastic.workers_per_spawn == 0 {
                bail!("elastic.workers_per_spawn must be >= 1");
            }
            if a.elastic.max_instances != 0 && a.elastic.max_instances < a.num_trainers {
                bail!(
                    "elastic.max_instances ({}) below the initial pool ({})",
                    a.elastic.max_instances,
                    a.num_trainers
                );
            }
            if a.elastic.mode == ElasticMode::RespawnAfterMerge && !a.merge.enabled {
                bail!("elastic=respawn_after_merge requires merge.enabled");
            }
        }
        if self.cluster.nodes.is_empty() {
            bail!("cluster.nodes must be non-empty");
        }
        for (i, n) in self.cluster.nodes.iter().enumerate() {
            if n.max_batch == 0 || n.speed <= 0.0 {
                bail!("cluster.nodes[{i}] invalid (max_batch >= 1, speed > 0)");
            }
        }
        if self.cluster.net_bandwidth_bps <= 0.0 {
            bail!("cluster.net_bandwidth_bps must be positive");
        }
        if self.cluster.wan_bandwidth_bps <= 0.0 {
            bail!("cluster.wan_bandwidth_bps must be positive");
        }
        if self.cluster.topology == TopologyKind::Hierarchical {
            let n = self.cluster.nodes.len();
            if self.cluster.groups.is_empty() {
                bail!("cluster.groups must be non-empty under topology=hierarchical");
            }
            let mut owner: Vec<Option<usize>> = vec![None; n];
            for (g, members) in self.cluster.groups.iter().enumerate() {
                if members.is_empty() {
                    bail!("cluster.groups[{g}] is empty");
                }
                for &node in members {
                    if node >= n {
                        bail!("cluster.groups[{g}] node {node} out of range ({n} nodes)");
                    }
                    if let Some(prev) = owner[node] {
                        bail!(
                            "cluster.groups: node {node} (and its workers) appears in \
                             groups {prev} and {g}"
                        );
                    }
                    owner[node] = Some(g);
                }
            }
            if let Some(node) = owner.iter().position(|o| o.is_none()) {
                bail!("cluster.groups: node {node} (and its workers) belongs to no group");
            }
        }
        if !(0.0..1.0).contains(&self.cluster.step_jitter) {
            bail!("cluster.step_jitter must be in [0,1)");
        }
        let sc = &self.cluster.scenario;
        if !(0.0..=1.0).contains(&sc.straggler_prob) {
            bail!("scenario.straggler_prob must be in [0,1]");
        }
        if sc.straggler_prob > 0.0
            && (sc.straggler_min < 1.0 || sc.straggler_max < sc.straggler_min)
        {
            bail!("scenario straggler factors need 1 <= min <= max");
        }
        for (i, w) in sc.churn.iter().enumerate() {
            if w.node >= self.cluster.nodes.len() {
                bail!("scenario.churn[{i}].node {} out of range", w.node);
            }
            if !w.from_s.is_finite()
                || w.from_s < 0.0
                || !w.until_s.is_finite()
                || w.until_s <= w.from_s
            {
                bail!("scenario.churn[{i}] needs 0 <= from_s < until_s (finite)");
            }
        }
        for (i, s) in sc.link_shifts.iter().enumerate() {
            if s.node >= self.cluster.nodes.len() {
                bail!("scenario.link_shifts[{i}].node {} out of range", s.node);
            }
            if !s.at_s.is_finite()
                || s.at_s < 0.0
                || !s.bandwidth_factor.is_finite()
                || s.bandwidth_factor <= 0.0
            {
                bail!("scenario.link_shifts[{i}] needs at_s >= 0 and bandwidth_factor > 0");
            }
        }
        if !sc.is_static() && self.run.scheduler != SchedulerKind::Event {
            bail!(
                "a dynamic scenario requires run.scheduler=event \
                 (the lockstep reference walk cannot express it)"
            );
        }
        match &self.cluster.trace {
            TraceSourceConfig::Stochastic => {}
            TraceSourceConfig::Path(p) => {
                if p.is_empty() {
                    bail!("cluster.trace_path must be non-empty");
                }
                if !sc.is_static() {
                    bail!(
                        "cluster.trace replaces the stochastic scenario; \
                         clear cluster.scenario or drop the trace (ambiguous sources)"
                    );
                }
                // whether the file's dynamics need the event scheduler
                // is only known after loading; Coordinator::new checks.
            }
            TraceSourceConfig::Generator(g) => {
                if !sc.is_static() {
                    bail!(
                        "cluster.trace replaces the stochastic scenario; \
                         clear cluster.scenario or drop the generator (ambiguous sources)"
                    );
                }
                if !g.horizon_s.is_finite() || g.horizon_s <= 0.0 {
                    bail!("trace_gen.horizon_s must be finite and > 0");
                }
                match g.kind {
                    TraceGenKind::SpotMarket => {
                        if !g.mean_up_s.is_finite()
                            || g.mean_up_s <= 0.0
                            || !g.mean_down_s.is_finite()
                            || g.mean_down_s <= 0.0
                        {
                            bail!("trace_gen spot_market needs mean_up_s, mean_down_s > 0");
                        }
                    }
                    TraceGenKind::Diurnal => {
                        if !g.period_s.is_finite() || g.period_s <= 0.0 {
                            bail!("trace_gen.period_s must be finite and > 0");
                        }
                        if !g.amplitude.is_finite() || g.amplitude < 0.0 {
                            bail!("trace_gen.amplitude must be finite and >= 0");
                        }
                        if g.samples_per_period == 0 {
                            bail!("trace_gen.samples_per_period must be >= 1");
                        }
                    }
                    TraceGenKind::RackFailures => {
                        if !g.mean_down_s.is_finite() || g.mean_down_s <= 0.0 {
                            bail!("trace_gen rack_failures needs mean_down_s > 0");
                        }
                        if g.outages_per_rack == 0 {
                            bail!("trace_gen.outages_per_rack must be >= 1");
                        }
                        if self.cluster.groups.is_empty() {
                            bail!("trace_gen rack_failures requires cluster.groups (the rack map)");
                        }
                        let n = self.cluster.nodes.len();
                        for (gi, members) in self.cluster.groups.iter().enumerate() {
                            if let Some(&node) = members.iter().find(|&&node| node >= n) {
                                bail!(
                                    "cluster.groups[{gi}] node {node} out of range ({n} nodes)"
                                );
                            }
                        }
                    }
                }
                // preemption traces interleave with scheduling in ways
                // the lockstep walk cannot express; diurnal (speed-only)
                // traces are deterministic and scheduler-agnostic
                if matches!(g.kind, TraceGenKind::SpotMarket | TraceGenKind::RackFailures)
                    && self.run.scheduler != SchedulerKind::Event
                {
                    bail!(
                        "trace generator {:?} produces preemption windows and requires \
                         run.scheduler=event",
                        g.kind.as_str()
                    );
                }
            }
        }
        if self.data.vocab < 2 || self.data.seq_len == 0 {
            bail!("data.vocab >= 2 and data.seq_len >= 1 required");
        }
        if self.data.corpus_sequences == 0 {
            bail!("data.corpus_sequences must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.data.shard_fraction) {
            bail!("data.shard_fraction must be in [0,1]");
        }
        let total_workers = a.num_trainers * a.workers_per_trainer;
        if total_workers > 16384 {
            // raised from 4096 by the fig6 scale pass (DESIGN.md §11):
            // the event path sustains the 10k-worker fleet point
            bail!("{total_workers} workers is beyond the simulator's design range (16384)");
        }
        if self.service.max_concurrent_runs == 0 {
            bail!("service.max_concurrent_runs must be >= 1");
        }
        if self.service.max_body_bytes < 1024 {
            bail!("service.max_body_bytes must be >= 1024 (a submit body must fit)");
        }
        if self.service.max_header_bytes < 256 {
            bail!("service.max_header_bytes must be >= 256 (a request head must fit)");
        }
        if self.service.addr.is_empty() {
            bail!("service.addr must be a bind address, e.g. 127.0.0.1");
        }
        Ok(())
    }

    /// Load a config JSON file on top of a preset base.
    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let v = JsonValue::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let base = match v.get("preset").and_then(|p| p.as_str()) {
            Some(name) => presets::by_name(name)
                .ok_or_else(|| anyhow!("unknown preset {name:?}"))?,
            None => presets::mock_default(),
        };
        let mut cfg = base;
        apply_json(&mut cfg, &v)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply a JSON overlay (the config-file format: only present keys
    /// change) on top of this config. This is the same machinery
    /// [`Config::load`] and `--set` overrides route through; the service
    /// control plane uses it to apply a `POST /runs` body's `config`
    /// object, so HTTP submissions get byte-identical semantics — and
    /// the same typed errors — as the CLI (DESIGN.md §13).
    pub fn apply_overlay(&mut self, v: &JsonValue) -> Result<()> {
        apply_json(self, v)
    }

    /// Apply a `--set dotted.path=value` override.
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        let (path, value) = spec
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be path=value, got {spec:?}"))?;
        set_path(self, path.trim(), value.trim())
            .with_context(|| format!("applying override {spec:?}"))
    }

    /// Digest of the *structural* config — the fields a checkpoint's
    /// state depends on (seed, engine, algo, data, cluster, comm). The
    /// run schedule, name and output routing are excluded, so resuming
    /// with a different checkpoint cadence, thread count or out_dir
    /// keeps the digest equal. Stamped into every v4 checkpoint's META
    /// (`config_digest`, DESIGN.md §10); exact resume refuses a
    /// mismatch, warm-start only logs one.
    pub fn structural_digest(&self) -> u64 {
        let repr = format!(
            "{}|{:?}|{:?}|{:?}|{:?}|{:?}",
            self.seed, self.engine, self.algo, self.data, self.cluster, self.comm
        );
        crate::util::fnv1a(repr.as_bytes())
    }
}

// ---------------------------------------------------------------------------
// JSON -> Config application (partial overlays: only present keys change)
// ---------------------------------------------------------------------------

fn apply_json(cfg: &mut Config, v: &JsonValue) -> Result<()> {
    if let Some(s) = v.get("name").and_then(|x| x.as_str()) {
        cfg.name = s.to_string();
    }
    if let Some(n) = v.get("seed").and_then(|x| x.as_f64()) {
        cfg.seed = n as u64;
    }
    if let Some(o) = v.get("out_dir").and_then(|x| x.as_str()) {
        cfg.out_dir = Some(o.to_string());
    }
    if let Some(e) = v.get("engine") {
        apply_engine(cfg, e)?;
    }
    if let Some(a) = v.get("algo") {
        apply_algo(&mut cfg.algo, a)?;
    }
    if let Some(d) = v.get("data") {
        apply_data(&mut cfg.data, d)?;
    }
    if let Some(c) = v.get("cluster") {
        apply_cluster(&mut cfg.cluster, c)?;
    }
    if let Some(c) = v.get("comm") {
        if let Some(x) = c.get("overlap").and_then(|x| x.as_str()) {
            cfg.comm.overlap = OverlapMode::parse(x)?;
        }
    }
    if let Some(r) = v.get("run") {
        apply_run(&mut cfg.run, r)?;
    }
    if let Some(s) = v.get("service") {
        apply_service(&mut cfg.service, s)?;
    }
    Ok(())
}

fn apply_engine(cfg: &mut Config, v: &JsonValue) -> Result<()> {
    match v.get("kind").and_then(|x| x.as_str()) {
        Some("mock") => {
            let mut dim = 1000;
            let mut noise = 1.0;
            let mut condition = 10.0;
            if let EngineConfig::Mock { dim: d, noise: n, condition: c } = &cfg.engine {
                dim = *d;
                noise = *n;
                condition = *c;
            }
            if let Some(x) = v.get("dim").and_then(|x| x.as_usize()) {
                dim = x;
            }
            if let Some(x) = v.get("noise").and_then(|x| x.as_f64()) {
                noise = x;
            }
            if let Some(x) = v.get("condition").and_then(|x| x.as_f64()) {
                condition = x;
            }
            cfg.engine = EngineConfig::Mock { dim, noise, condition };
        }
        Some("xla") => {
            let dir = v
                .get("artifacts_dir")
                .and_then(|x| x.as_str())
                .unwrap_or("artifacts")
                .to_string();
            let profile = v
                .get("profile")
                .and_then(|x| x.as_str())
                .unwrap_or("tiny")
                .to_string();
            cfg.engine = EngineConfig::Xla { artifacts_dir: dir, profile };
        }
        Some(k) => bail!("unknown engine kind {k:?}"),
        None => bail!("engine.kind required"),
    }
    Ok(())
}

fn apply_algo(a: &mut AlgoConfig, v: &JsonValue) -> Result<()> {
    if let Some(s) = v.get("method").and_then(|x| x.as_str()) {
        a.method = Method::parse(s)?;
    }
    macro_rules! usize_field {
        ($key:literal, $field:expr) => {
            if let Some(x) = v.get($key).and_then(|x| x.as_usize()) {
                $field = x;
            }
        };
    }
    macro_rules! f64_field {
        ($v:expr, $key:literal, $field:expr) => {
            if let Some(x) = $v.get($key).and_then(|x| x.as_f64()) {
                $field = x;
            }
        };
    }
    usize_field!("num_trainers", a.num_trainers);
    usize_field!("workers_per_trainer", a.workers_per_trainer);
    usize_field!("inner_steps", a.inner_steps);
    usize_field!("outer_steps", a.outer_steps);
    usize_field!("fixed_batch", a.fixed_batch);
    f64_field!(v, "lr_inner", a.lr_inner);
    f64_field!(v, "lr_outer", a.lr_outer);
    if let Some(sc) = v.get("lr_schedule") {
        if let Some(x) = sc.get("kind").and_then(|x| x.as_str()) {
            a.lr_schedule.kind = x.to_string();
        }
        if let Some(x) = sc.get("warmup_steps").and_then(|x| x.as_usize()) {
            a.lr_schedule.warmup_steps = x as u64;
        }
        if let Some(x) = sc.get("total_steps").and_then(|x| x.as_usize()) {
            a.lr_schedule.total_steps = x as u64;
        }
        if let Some(x) = sc.get("min_frac").and_then(|x| x.as_f64()) {
            a.lr_schedule.min_frac = x;
        }
        if let Some(x) = sc.get("decay_every").and_then(|x| x.as_usize()) {
            a.lr_schedule.decay_every = x as u64;
        }
        if let Some(x) = sc.get("decay_factor").and_then(|x| x.as_f64()) {
            a.lr_schedule.decay_factor = x;
        }
    }
    if let Some(o) = v.get("outer_opt") {
        let kind = o.get("kind").and_then(|x| x.as_str()).unwrap_or("nesterov");
        a.outer_opt = match kind {
            "average" => OuterOptKind::Average,
            "sgd" => OuterOptKind::Sgd,
            "nesterov" => OuterOptKind::Nesterov {
                momentum: o.get("momentum").and_then(|x| x.as_f64()).unwrap_or(0.9),
            },
            k => bail!("unknown outer_opt kind {k:?}"),
        };
    }
    if let Some(b) = v.get("batching") {
        if let Some(x) = b.get("adaptive").and_then(|x| x.as_bool()) {
            a.batching.adaptive = x;
        }
        if let Some(s) = b.get("test").and_then(|x| x.as_str()) {
            a.batching.test = BatchTest::parse(s)?;
        }
        f64_field!(b, "eta", a.batching.eta);
        f64_field!(b, "theta", a.batching.theta);
        f64_field!(b, "nu", a.batching.nu);
        f64_field!(b, "ema_beta", a.batching.ema_beta);
        if let Some(x) = b.get("initial_batch").and_then(|x| x.as_usize()) {
            a.batching.initial_batch = x;
        }
        if let Some(x) = b.get("monotone").and_then(|x| x.as_bool()) {
            a.batching.monotone = x;
        }
        if let Some(x) = b.get("max_request").and_then(|x| x.as_usize()) {
            a.batching.max_request = x;
        }
    }
    if let Some(m) = v.get("merge") {
        if let Some(x) = m.get("enabled").and_then(|x| x.as_bool()) {
            a.merge.enabled = x;
        }
        if let Some(x) = m.get("w").and_then(|x| x.as_usize()) {
            a.merge.w = x;
        }
        if let Some(x) = m.get("frequency").and_then(|x| x.as_usize()) {
            a.merge.frequency = x;
        }
        if let Some(x) = m.get("min_trainers").and_then(|x| x.as_usize()) {
            a.merge.min_trainers = x;
        }
        if let Some(x) = m.get("policy").and_then(|x| x.as_str()) {
            a.merge.policy = MergeSelect::parse(x)?;
        }
    }
    if let Some(s) = v.get("switch") {
        if let Some(x) = s.get("enabled").and_then(|x| x.as_bool()) {
            a.switch.enabled = x;
        }
        f64_field!(s, "multiplier", a.switch.multiplier);
    }
    if let Some(e) = v.get("elastic") {
        // a bare string sets the mode (`--set algo.elastic=util_threshold`);
        // an object addresses the individual knobs
        if let Some(s) = e.as_str() {
            a.elastic.mode = ElasticMode::parse(s)?;
        } else {
            if let Some(s) = e.get("mode").and_then(|x| x.as_str()) {
                a.elastic.mode = ElasticMode::parse(s)?;
            }
            f64_field!(e, "idle_threshold", a.elastic.idle_threshold);
            if let Some(x) = e.get("max_instances").and_then(|x| x.as_usize()) {
                a.elastic.max_instances = x;
            }
            if let Some(x) = e.get("cooldown_rounds").and_then(|x| x.as_usize()) {
                a.elastic.cooldown_rounds = x;
            }
            if let Some(x) = e.get("workers_per_spawn").and_then(|x| x.as_usize()) {
                a.elastic.workers_per_spawn = x;
            }
            if let Some(x) = e.get("node_capacity").and_then(|x| x.as_usize()) {
                a.elastic.node_capacity = x;
            }
        }
    }
    Ok(())
}

fn apply_data(d: &mut DataConfig, v: &JsonValue) -> Result<()> {
    if let Some(x) = v.get("corpus_sequences").and_then(|x| x.as_usize()) {
        d.corpus_sequences = x;
    }
    if let Some(x) = v.get("vocab").and_then(|x| x.as_usize()) {
        d.vocab = x;
    }
    if let Some(x) = v.get("seq_len").and_then(|x| x.as_usize()) {
        d.seq_len = x;
    }
    if let Some(x) = v.get("zipf_s").and_then(|x| x.as_f64()) {
        d.zipf_s = x;
    }
    if let Some(x) = v.get("shard_fraction").and_then(|x| x.as_f64()) {
        d.shard_fraction = x;
    }
    if let Some(x) = v.get("val_sequences").and_then(|x| x.as_usize()) {
        d.val_sequences = x;
    }
    if let Some(x) = v.get("seed").and_then(|x| x.as_f64()) {
        d.seed = x as u64;
    }
    Ok(())
}

fn apply_cluster(c: &mut ClusterConfig, v: &JsonValue) -> Result<()> {
    if let Some(nodes) = v.get("nodes").and_then(|x| x.as_array()) {
        c.nodes = nodes
            .iter()
            .map(|n| {
                Ok(NodeConfig {
                    max_batch: n
                        .get("max_batch")
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow!("node.max_batch required"))?,
                    speed: n.get("speed").and_then(|x| x.as_f64()).unwrap_or(1.0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(x) = v.get("net_latency_s").and_then(|x| x.as_f64()) {
        c.net_latency_s = x;
    }
    if let Some(x) = v.get("net_bandwidth_bps").and_then(|x| x.as_f64()) {
        c.net_bandwidth_bps = x;
    }
    if let Some(x) = v.get("step_fixed_s").and_then(|x| x.as_f64()) {
        c.step_fixed_s = x;
    }
    if let Some(x) = v.get("step_per_token_s").and_then(|x| x.as_f64()) {
        c.step_per_token_s = x;
    }
    if let Some(x) = v.get("step_jitter").and_then(|x| x.as_f64()) {
        c.step_jitter = x;
    }
    if let Some(s) = v.get("scenario") {
        apply_scenario(&mut c.scenario, s)?;
    }
    if let Some(x) = v.get("trace_source").and_then(|x| x.as_str()) {
        // explicit reset back to the stochastic model (the other
        // variants are selected by trace_path / trace_gen below)
        match x {
            "stochastic" => c.trace = TraceSourceConfig::Stochastic,
            other => bail!(
                "cluster.trace_source {other:?} unknown (use \"stochastic\", or set \
                 cluster.trace_path / cluster.trace_gen)"
            ),
        }
    }
    if let Some(x) = v.get("trace_path").and_then(|x| x.as_str()) {
        c.trace = TraceSourceConfig::Path(x.to_string());
    }
    if let Some(gv) = v.get("trace_gen") {
        // partial overlay over the current generator knobs (or the
        // defaults when the source was not a generator); a bare string
        // just picks the kind: `--set cluster.trace_gen=spot_market`
        let mut g = match &c.trace {
            TraceSourceConfig::Generator(g) => g.clone(),
            _ => TraceGenConfig::default(),
        };
        if let Some(s) = gv.as_str() {
            g.kind = TraceGenKind::parse(s)?;
        } else {
            if let Some(s) = gv.get("kind").and_then(|x| x.as_str()) {
                g.kind = TraceGenKind::parse(s)?;
            }
            if let Some(x) = gv.get("horizon_s").and_then(|x| x.as_f64()) {
                g.horizon_s = x;
            }
            if let Some(x) = gv.get("mean_up_s").and_then(|x| x.as_f64()) {
                g.mean_up_s = x;
            }
            if let Some(x) = gv.get("mean_down_s").and_then(|x| x.as_f64()) {
                g.mean_down_s = x;
            }
            if let Some(x) = gv.get("period_s").and_then(|x| x.as_f64()) {
                g.period_s = x;
            }
            if let Some(x) = gv.get("amplitude").and_then(|x| x.as_f64()) {
                g.amplitude = x;
            }
            if let Some(x) = gv.get("samples_per_period").and_then(|x| x.as_usize()) {
                g.samples_per_period = x;
            }
            if let Some(x) = gv.get("outages_per_rack").and_then(|x| x.as_usize()) {
                g.outages_per_rack = x;
            }
        }
        c.trace = TraceSourceConfig::Generator(g);
    }
    if let Some(x) = v.get("topology").and_then(|x| x.as_str()) {
        c.topology = TopologyKind::parse(x)?;
    }
    if let Some(arr) = v.get("groups").and_then(|x| x.as_array()) {
        c.groups = arr
            .iter()
            .map(|g| {
                let members = g
                    .as_array()
                    .ok_or_else(|| anyhow!("cluster.groups must be an array of node-id arrays"))?;
                members
                    .iter()
                    .map(|n| {
                        n.as_usize()
                            .ok_or_else(|| anyhow!("cluster.groups entries must be node ids"))
                    })
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<Vec<Vec<usize>>>>()?;
    }
    if let Some(x) = v.get("wan_latency_s").and_then(|x| x.as_f64()) {
        c.wan_latency_s = x;
    }
    if let Some(x) = v.get("wan_bandwidth_bps").and_then(|x| x.as_f64()) {
        c.wan_bandwidth_bps = x;
    }
    if let Some(x) = v.get("sync_collective").and_then(|x| x.as_str()) {
        c.sync_collective = CollectiveKind::parse(x)?;
    }
    Ok(())
}

fn apply_scenario(sc: &mut ScenarioConfig, v: &JsonValue) -> Result<()> {
    if let Some(x) = v.get("straggler_prob").and_then(|x| x.as_f64()) {
        sc.straggler_prob = x;
    }
    if let Some(x) = v.get("straggler_min").and_then(|x| x.as_f64()) {
        sc.straggler_min = x;
    }
    if let Some(x) = v.get("straggler_max").and_then(|x| x.as_f64()) {
        sc.straggler_max = x;
    }
    if let Some(arr) = v.get("churn").and_then(|x| x.as_array()) {
        sc.churn = arr
            .iter()
            .map(|w| {
                Ok(ChurnWindow {
                    node: w
                        .get("node")
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow!("churn.node required"))?,
                    from_s: w
                        .get("from_s")
                        .and_then(|x| x.as_f64())
                        .ok_or_else(|| anyhow!("churn.from_s required"))?,
                    until_s: w
                        .get("until_s")
                        .and_then(|x| x.as_f64())
                        .ok_or_else(|| anyhow!("churn.until_s required"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(arr) = v.get("link_shifts").and_then(|x| x.as_array()) {
        sc.link_shifts = arr
            .iter()
            .map(|s| {
                Ok(LinkShift {
                    node: s
                        .get("node")
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow!("link_shifts.node required"))?,
                    at_s: s
                        .get("at_s")
                        .and_then(|x| x.as_f64())
                        .ok_or_else(|| anyhow!("link_shifts.at_s required"))?,
                    bandwidth_factor: s
                        .get("bandwidth_factor")
                        .and_then(|x| x.as_f64())
                        .ok_or_else(|| anyhow!("link_shifts.bandwidth_factor required"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    Ok(())
}

fn apply_run(r: &mut RunConfig, v: &JsonValue) -> Result<()> {
    if let Some(x) = v.get("eval_every").and_then(|x| x.as_usize()) {
        r.eval_every = x;
    }
    if let Some(x) = v.get("eval_batches").and_then(|x| x.as_usize()) {
        r.eval_batches = x;
    }
    if let Some(x) = v.get("target_ppl").and_then(|x| x.as_f64()) {
        r.target_ppl = x;
    }
    if let Some(x) = v.get("max_inner_steps").and_then(|x| x.as_usize()) {
        r.max_inner_steps = x;
    }
    if let Some(x) = v.get("checkpoint_path").and_then(|x| x.as_str()) {
        r.checkpoint_path = Some(x.to_string());
    }
    if let Some(x) = v.get("checkpoint_every").and_then(|x| x.as_usize()) {
        r.checkpoint_every = x;
    }
    if let Some(x) = v.get("resume_from").and_then(|x| x.as_str()) {
        r.resume_from = Some(x.to_string());
    }
    if let Some(x) = v.get("keep_checkpoints").and_then(|x| x.as_usize()) {
        r.keep_checkpoints = x;
    }
    if let Some(x) = v.get("scheduler").and_then(|x| x.as_str()) {
        r.scheduler = SchedulerKind::parse(x)?;
    }
    if let Some(x) = v.get("threads").and_then(|x| x.as_usize()) {
        r.threads = x;
    }
    if let Some(x) = v.get("stream_records").and_then(|x| x.as_bool()) {
        r.stream_records = x;
    }
    Ok(())
}

fn apply_service(s: &mut ServiceConfig, v: &JsonValue) -> Result<()> {
    if let Some(x) = v.get("addr").and_then(|x| x.as_str()) {
        s.addr = x.to_string();
    }
    if let Some(x) = v.get("port").and_then(|x| x.as_usize()) {
        if x > u16::MAX as usize {
            bail!("service.port must be <= {}", u16::MAX);
        }
        s.port = x as u16;
    }
    if let Some(x) = v.get("max_concurrent_runs").and_then(|x| x.as_usize()) {
        s.max_concurrent_runs = x;
    }
    if let Some(x) = v.get("max_body_bytes").and_then(|x| x.as_usize()) {
        s.max_body_bytes = x;
    }
    if let Some(x) = v.get("max_header_bytes").and_then(|x| x.as_usize()) {
        s.max_header_bytes = x;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// dotted-path overrides (CLI --set)
// ---------------------------------------------------------------------------

fn set_path(cfg: &mut Config, path: &str, value: &str) -> Result<()> {
    // Route through the JSON overlay machinery: build a nested one-key
    // object and apply it, so every JSON-settable field is CLI-settable.
    let mut leaf = parse_scalar(value);
    for key in path.split('.').rev() {
        leaf = JsonValue::Object(vec![(key.to_string(), leaf)]);
    }
    apply_json(cfg, &leaf)
}

fn parse_scalar(s: &str) -> JsonValue {
    match s {
        "true" => return JsonValue::Bool(true),
        "false" => return JsonValue::Bool(false),
        "null" => return JsonValue::Null,
        _ => {}
    }
    if let Ok(n) = s.parse::<f64>() {
        return JsonValue::Number(n);
    }
    // allow inline JSON arrays/objects for e.g. cluster.nodes
    if (s.starts_with('[') || s.starts_with('{')) && JsonValue::parse(s).is_ok() {
        return JsonValue::parse(s).unwrap();
    }
    JsonValue::String(s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_is_valid() {
        presets::mock_default().validate().unwrap();
        presets::paper_table1().validate().unwrap();
        presets::xla_tiny().validate().unwrap();
        presets::xla_small().validate().unwrap();
        presets::hetero_dynamic().validate().unwrap();
        presets::hierarchical_mit().validate().unwrap();
        presets::elastic_mit().validate().unwrap();
        presets::fleet_trace().validate().unwrap();
    }

    #[test]
    fn service_overrides_and_validation() {
        let mut cfg = presets::mock_default();
        assert_eq!(cfg.service, ServiceConfig::default());
        cfg.apply_override("service.addr=0.0.0.0").unwrap();
        cfg.apply_override("service.port=8080").unwrap();
        cfg.apply_override("service.max_concurrent_runs=4").unwrap();
        cfg.apply_override("service.max_body_bytes=2048").unwrap();
        cfg.apply_override("service.max_header_bytes=512").unwrap();
        assert_eq!(cfg.service.addr, "0.0.0.0");
        assert_eq!(cfg.service.port, 8080);
        assert_eq!(cfg.service.max_concurrent_runs, 4);
        assert_eq!(cfg.service.max_body_bytes, 2048);
        assert_eq!(cfg.service.max_header_bytes, 512);
        cfg.validate().unwrap();
        assert!(cfg.apply_override("service.port=65536").is_err());
        cfg.apply_override("service.max_concurrent_runs=0").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("max_concurrent_runs"));
        cfg.apply_override("service.max_concurrent_runs=2").unwrap();
        cfg.apply_override("service.max_body_bytes=10").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("max_body_bytes"));
        cfg.apply_override("service.max_body_bytes=4096").unwrap();
        cfg.apply_override("service.max_header_bytes=10").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("max_header_bytes"));
        // service knobs never move the structural digest (DESIGN.md §10)
        let a = presets::mock_default().structural_digest();
        cfg.apply_override("service.max_header_bytes=512").unwrap();
        assert_eq!(cfg.structural_digest(), a);
    }

    #[test]
    fn overlay_is_public_and_matches_set_path() {
        let mut via_overlay = presets::mock_default();
        let v = JsonValue::parse(r#"{"algo":{"outer_steps":3},"run":{"threads":4}}"#).unwrap();
        via_overlay.apply_overlay(&v).unwrap();
        let mut via_set = presets::mock_default();
        via_set.apply_override("algo.outer_steps=3").unwrap();
        via_set.apply_override("run.threads=4").unwrap();
        assert_eq!(via_overlay.algo.outer_steps, via_set.algo.outer_steps);
        assert_eq!(via_overlay.run.threads, via_set.run.threads);
        assert_eq!(via_overlay.structural_digest(), via_set.structural_digest());
    }

    #[test]
    fn trace_source_overrides_and_validation() {
        let mut cfg = presets::mock_default();
        assert_eq!(cfg.cluster.trace, TraceSourceConfig::Stochastic);
        cfg.apply_override("cluster.trace_path=traces/run.jsonl").unwrap();
        assert_eq!(
            cfg.cluster.trace,
            TraceSourceConfig::Path("traces/run.jsonl".into())
        );
        cfg.validate().unwrap();
        // a bare string picks the generator kind; objects overlay knobs
        cfg.apply_override("cluster.trace_gen=diurnal").unwrap();
        cfg.apply_override(r#"cluster.trace_gen={"horizon_s":30.0,"amplitude":0.25}"#).unwrap();
        match &cfg.cluster.trace {
            TraceSourceConfig::Generator(g) => {
                assert_eq!(g.kind, TraceGenKind::Diurnal);
                assert_eq!(g.horizon_s, 30.0);
                assert_eq!(g.amplitude, 0.25);
            }
            other => panic!("expected generator source, got {other:?}"),
        }
        // diurnal (speed-only) traces stay legal under lockstep
        cfg.validate().unwrap();
        // preemption generators require the event scheduler...
        cfg.apply_override("cluster.trace_gen=spot_market").unwrap();
        assert!(cfg.validate().is_err());
        cfg.apply_override("run.scheduler=event").unwrap();
        cfg.validate().unwrap();
        // ...rack failures additionally need the group map
        cfg.apply_override("cluster.trace_gen=rack_failures").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("cluster.groups"));
        cfg.apply_override("cluster.groups=[[0,1],[2,3]]").unwrap();
        cfg.validate().unwrap();
        // a trace source plus a non-static stochastic scenario is ambiguous
        cfg.apply_override("cluster.scenario.straggler_prob=0.1").unwrap();
        assert!(cfg.validate().is_err());
        cfg.apply_override("cluster.scenario.straggler_prob=0.0").unwrap();
        // and an explicit reset returns to the stochastic model
        cfg.apply_override("cluster.trace_source=stochastic").unwrap();
        assert_eq!(cfg.cluster.trace, TraceSourceConfig::Stochastic);
        cfg.validate().unwrap();
        assert!(cfg.apply_override("cluster.trace_source=bogus").is_err());
    }

    #[test]
    fn override_numeric_and_bool() {
        let mut cfg = presets::mock_default();
        cfg.apply_override("algo.batching.eta=0.5").unwrap();
        assert_eq!(cfg.algo.batching.eta, 0.5);
        cfg.apply_override("algo.merge.enabled=false").unwrap();
        assert!(!cfg.algo.merge.enabled);
        cfg.apply_override("algo.method=diloco").unwrap();
        assert_eq!(cfg.algo.method, Method::DiLoCo);
        cfg.apply_override("algo.merge.policy=random").unwrap();
        assert_eq!(cfg.algo.merge.policy, MergeSelect::Random);
    }

    #[test]
    fn override_nested_nodes() {
        let mut cfg = presets::mock_default();
        cfg.apply_override(r#"cluster.nodes=[{"max_batch":4},{"max_batch":8,"speed":0.5}]"#)
            .unwrap();
        assert_eq!(cfg.cluster.nodes.len(), 2);
        assert_eq!(cfg.cluster.nodes[1].max_batch, 8);
        assert_eq!(cfg.cluster.nodes[1].speed, 0.5);
    }

    #[test]
    fn scheduler_and_scenario_overrides() {
        let mut cfg = presets::mock_default();
        assert_eq!(cfg.run.scheduler, SchedulerKind::Lockstep);
        cfg.apply_override("run.scheduler=event").unwrap();
        assert_eq!(cfg.run.scheduler, SchedulerKind::Event);
        cfg.apply_override("cluster.scenario.straggler_prob=0.2").unwrap();
        cfg.apply_override(
            r#"cluster.scenario.churn=[{"node":0,"from_s":1.0,"until_s":2.0}]"#,
        )
        .unwrap();
        cfg.apply_override(
            r#"cluster.scenario.link_shifts=[{"node":1,"at_s":3.0,"bandwidth_factor":0.5}]"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.scenario.straggler_prob, 0.2);
        assert_eq!(cfg.cluster.scenario.churn, vec![ChurnWindow {
            node: 0,
            from_s: 1.0,
            until_s: 2.0
        }]);
        assert_eq!(cfg.cluster.scenario.link_shifts[0].bandwidth_factor, 0.5);
        cfg.validate().unwrap();
    }

    #[test]
    fn dynamic_scenario_requires_event_scheduler() {
        let mut cfg = presets::mock_default();
        cfg.cluster.scenario.straggler_prob = 0.5;
        assert!(cfg.validate().is_err(), "straggler scenario on lockstep must fail");
        cfg.run.scheduler = SchedulerKind::Event;
        cfg.validate().unwrap();
        cfg.cluster
            .scenario
            .churn
            .push(ChurnWindow { node: 99, from_s: 0.0, until_s: 1.0 });
        assert!(cfg.validate().is_err(), "out-of-range churn node must fail");
        cfg.cluster.scenario.churn[0].node = 0;
        cfg.cluster.scenario.churn[0].until_s = 0.0;
        assert!(cfg.validate().is_err(), "empty churn window must fail");
    }

    #[test]
    fn threads_override_and_resolution() {
        let mut cfg = presets::mock_default();
        assert_eq!(cfg.run.threads, 0, "presets default to auto");
        cfg.apply_override("run.threads=4").unwrap();
        assert_eq!(cfg.run.threads, 4);
        assert_eq!(cfg.run.effective_threads(), 4);
        cfg.run.threads = 1;
        // explicit values win over the RUN_THREADS env var (which may be
        // set by the CI parallel leg, so threads=0 is not asserted here)
        assert_eq!(cfg.run.effective_threads(), 1);
        cfg.validate().unwrap();
    }

    #[test]
    fn topology_overrides_and_group_validation() {
        let mut cfg = presets::mock_default();
        assert_eq!(cfg.cluster.topology, TopologyKind::Flat);
        cfg.apply_override("cluster.topology=hierarchical").unwrap();
        assert_eq!(cfg.cluster.topology, TopologyKind::Hierarchical);
        // hierarchical without groups must fail
        assert!(cfg.validate().is_err(), "missing group map must fail");
        cfg.apply_override("cluster.groups=[[0,1],[2,3]]").unwrap();
        cfg.validate().unwrap();
        cfg.apply_override("cluster.sync_collective=tree").unwrap();
        assert_eq!(cfg.cluster.sync_collective, CollectiveKind::Tree);
        cfg.apply_override("cluster.wan_bandwidth_bps=1e8").unwrap();
        assert_eq!(cfg.cluster.wan_bandwidth_bps, 1e8);
        cfg.validate().unwrap();

        // malformed group maps: empty group, node in two groups,
        // unassigned node, out-of-range node
        let mut bad = cfg.clone();
        bad.cluster.groups = vec![vec![0, 1, 2, 3], vec![]];
        assert!(bad.validate().is_err(), "empty group must fail");
        let mut bad = cfg.clone();
        bad.cluster.groups = vec![vec![0, 1], vec![1, 2, 3]];
        assert!(bad.validate().is_err(), "node in two groups must fail");
        let mut bad = cfg.clone();
        bad.cluster.groups = vec![vec![0, 1], vec![2]];
        assert!(bad.validate().is_err(), "unassigned node must fail");
        let mut bad = cfg.clone();
        bad.cluster.groups = vec![vec![0, 1], vec![2, 99]];
        assert!(bad.validate().is_err(), "out-of-range node must fail");

        // flat ignores the group map entirely
        let mut flat = cfg.clone();
        flat.cluster.topology = TopologyKind::Flat;
        flat.cluster.groups = vec![vec![0], vec![]];
        flat.validate().unwrap();
    }

    #[test]
    fn overlap_override_and_parse() {
        let mut cfg = presets::mock_default();
        assert_eq!(cfg.comm.overlap, OverlapMode::Blocking, "blocking is the default");
        cfg.apply_override("comm.overlap=delayed").unwrap();
        assert_eq!(cfg.comm.overlap, OverlapMode::Delayed);
        cfg.validate().unwrap();
        assert!(cfg.apply_override("comm.overlap=sometimes").is_err());
        assert_eq!(OverlapMode::Delayed.as_str(), "delayed");
        assert_eq!(OverlapMode::parse("BLOCKING").unwrap(), OverlapMode::Blocking);
        // delayed composes with both schedulers and topologies
        cfg.run.scheduler = SchedulerKind::Event;
        cfg.validate().unwrap();
        cfg.run.scheduler = SchedulerKind::Lockstep;
        cfg.validate().unwrap();
    }

    #[test]
    fn elastic_overrides_and_validation() {
        let mut cfg = presets::mock_default();
        assert_eq!(cfg.algo.elastic.mode, ElasticMode::Off, "off is the default");
        // bare-string form sets the mode
        cfg.apply_override("algo.elastic=util_threshold").unwrap();
        assert_eq!(cfg.algo.elastic.mode, ElasticMode::UtilThreshold);
        // object form addresses the knobs
        cfg.apply_override("algo.elastic.idle_threshold=0.4").unwrap();
        cfg.apply_override("algo.elastic.max_instances=6").unwrap();
        cfg.apply_override("algo.elastic.workers_per_spawn=2").unwrap();
        cfg.apply_override("algo.elastic.node_capacity=3").unwrap();
        assert_eq!(cfg.algo.elastic.idle_threshold, 0.4);
        assert_eq!(cfg.algo.elastic.max_instances, 6);
        assert_eq!(cfg.algo.elastic.workers_per_spawn, 2);
        assert_eq!(cfg.algo.elastic.node_capacity, 3);
        cfg.validate().unwrap();
        assert!(cfg.apply_override("algo.elastic=sometimes").is_err());
        assert_eq!(ElasticMode::parse("respawn").unwrap(), ElasticMode::RespawnAfterMerge);
        assert_eq!(ElasticMode::UtilThreshold.as_str(), "util_threshold");

        // validation: cap below the initial pool, zero-width spawns,
        // respawn without merging
        let mut bad = cfg.clone();
        bad.algo.elastic.max_instances = bad.algo.num_trainers - 1;
        assert!(bad.validate().is_err(), "cap below initial pool must fail");
        let mut bad = cfg.clone();
        bad.algo.elastic.workers_per_spawn = 0;
        assert!(bad.validate().is_err(), "zero-width spawn must fail");
        let mut bad = cfg.clone();
        bad.algo.elastic.mode = ElasticMode::RespawnAfterMerge;
        bad.algo.merge.enabled = false;
        assert!(bad.validate().is_err(), "respawn without merging must fail");
        let mut bad = cfg.clone();
        bad.algo.elastic.idle_threshold = 1.5;
        assert!(bad.validate().is_err(), "threshold beyond 1 must fail");
        // everything is inert when off
        let mut off = cfg.clone();
        off.algo.elastic.mode = ElasticMode::Off;
        off.algo.elastic.idle_threshold = 99.0;
        off.validate().unwrap();
    }

    #[test]
    fn bad_override_is_error() {
        let mut cfg = presets::mock_default();
        assert!(cfg.apply_override("no_equals_sign").is_err());
        assert!(cfg.apply_override("algo.method=bogus").is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = presets::mock_default();
        cfg.algo.num_trainers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = presets::mock_default();
        cfg.algo.batching.ema_beta = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = presets::mock_default();
        cfg.cluster.nodes.clear();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn load_json_overlay() {
        let dir = std::env::temp_dir().join("adloco_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(
            &path,
            r#"{"preset":"paper_table1","name":"t","algo":{"inner_steps":7},
               "engine":{"kind":"mock","dim":55}}"#,
        )
        .unwrap();
        let cfg = Config::load(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.name, "t");
        assert_eq!(cfg.algo.inner_steps, 7);
        match cfg.engine {
            EngineConfig::Mock { dim, .. } => assert_eq!(dim, 55),
            _ => panic!("expected mock engine"),
        }
        // untouched field keeps the preset value (paper Table 1: eta=0.8)
        assert_eq!(cfg.algo.batching.eta, 0.8);
    }
}
