//! The `TrainEngine` abstraction: what the coordinator needs from the
//! compute substrate, implemented by both the PJRT-backed
//! [`crate::runtime::XlaEngine`] (the real transformer) and the pure-Rust
//! [`MockEngine`] (a synthetic stochastic objective for tests and the
//! long-horizon theory benches).
//!
//! The engine boundary is deliberately *stateless about training policy*:
//! batch sizes, accumulation, merging and outer optimization all live in
//! the coordinator. The engine only knows how to (a) take one inner
//! optimizer step at one of its supported batch sizes, (b) produce a raw
//! gradient for SwitchMode accumulation, (c) commit an accumulated
//! gradient, and (d) evaluate.
//!
//! Stochasticity contract (DESIGN.md §3.4): every stochastic engine call
//! receives an explicit `noise: &mut Rng` stream and must draw *all* of
//! its randomness from it. Deterministic engines (the PJRT transformer)
//! ignore the stream. The coordinator hands each worker its own forked
//! stream, which makes results independent of the order workers are
//! scheduled in — the property that lets the event-driven scheduler
//! reproduce the lockstep reference bit-for-bit on static clusters.

pub mod mock;

pub use mock::{MockEngine, MockSpec};

use crate::config::{Config, EngineConfig};
use crate::data::TokenBatch;
use crate::util::Rng;
use anyhow::Result;

/// Statistics returned by every gradient computation — the raw material
/// of the adaptive-batching tests (paper Eqs. 8-12).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Mean loss over the batch.
    pub loss: f64,
    /// ||mean gradient||^2  (Eq. 10 denominator).
    pub grad_sq_norm: f64,
    /// Estimated per-sample gradient variance sigma^2_B (Eq. 8).
    pub sigma2: f64,
    /// Estimated Var_i(<grad_i, gbar>) (Eq. 12 numerator).
    pub ip_var: f64,
}

/// Mutable per-worker model state: flat parameters + AdamW moments.
/// The flat-vector convention (DESIGN.md) makes DoMerge and outer deltas
/// plain dense ops.
#[derive(Clone, Debug)]
pub struct ModelState {
    /// Flat parameter vector (length = `TrainEngine::param_count`).
    pub params: Vec<f32>,
    /// AdamW first-moment buffer (same length as `params`).
    pub m: Vec<f32>,
    /// AdamW second-moment buffer (same length as `params`).
    pub v: Vec<f32>,
    /// 1-based count of optimizer updates applied (AdamW bias correction).
    pub step: u64,
}

impl ModelState {
    /// Fresh state around `params` with zeroed moments and step count.
    pub fn zeros_like(params: Vec<f32>) -> Self {
        let n = params.len();
        ModelState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// Reset optimizer moments (used after merges when moments of the
    /// consumed trainers are dropped; the representative's are carried).
    pub fn reset_moments(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }
}

/// Compute substrate interface (see module docs).
///
/// Thread contract (DESIGN.md §6): engines are shared by reference across
/// the worker threads of the parallel runtime, so the trait requires
/// `Send + Sync` and every method takes `&self`. All *mutable* state an
/// engine call touches travels through its arguments (`ModelState`,
/// gradient buffers, RNG streams), which the coordinator hands out
/// per-worker — two workers never alias the same mutable argument.
/// Engines with interior caches (the PJRT lazy-compile tables) must
/// guard them with locks.
pub trait TrainEngine: Send + Sync {
    /// Human-readable engine identifier for logs/metrics.
    fn name(&self) -> String;

    /// Flat parameter vector length.
    fn param_count(&self) -> usize;

    /// Fresh model state. `seed` differentiates trainer initializations
    /// (the paper's MIT uses independent inits).
    fn init_state(&self, seed: u64) -> ModelState;

    /// Ascending list of batch sizes with a compiled executable (the
    /// AOT ladder). The coordinator rounds requested batches onto this.
    fn supported_batches(&self) -> &[usize];

    /// Largest executable batch (the paper's max_batch is then
    /// min(engine max, node max) — see the coordinator).
    fn max_batch(&self) -> usize {
        *self.supported_batches().last().expect("empty ladder")
    }

    /// Eval batch size the engine was compiled for.
    fn eval_batch(&self) -> usize;

    /// One fused inner step (forward, backward, stats, AdamW update).
    /// `batch.batch` must be a supported batch size. All stochastic
    /// draws must come from `noise` (see the module docs).
    fn train_step(
        &self,
        state: &mut ModelState,
        lr: f64,
        batch: &TokenBatch,
        noise: &mut Rng,
    ) -> Result<StepStats>;

    /// Gradient + stats at max_batch without applying an update
    /// (SwitchMode micro-step). Writes the mean gradient into `grad_out`.
    fn grad_step(
        &self,
        params: &[f32],
        batch: &TokenBatch,
        grad_out: &mut [f32],
        noise: &mut Rng,
    ) -> Result<StepStats>;

    /// Commit an (accumulated) gradient with AdamW (SwitchMode commit).
    fn apply_update(&self, state: &mut ModelState, lr: f64, grad: &[f32]) -> Result<()>;

    /// Mean loss over one eval batch (batch.batch == eval_batch()).
    fn eval_loss(&self, params: &[f32], batch: &TokenBatch, noise: &mut Rng) -> Result<f64>;
}

/// Shared AdamW update used by the MockEngine (the XlaEngine's AdamW is
/// fused into the HLO; `python/tests/test_model.py::test_adamw_against_
/// manual_numpy` pins both to the same arithmetic).
pub struct AdamWParams {
    /// First-moment decay rate.
    pub beta1: f64,
    /// Second-moment decay rate.
    pub beta2: f64,
    /// Denominator fuzz term.
    pub eps: f64,
    /// Decoupled weight-decay coefficient.
    pub weight_decay: f64,
}

impl Default for AdamWParams {
    fn default() -> Self {
        // matches python/compile/model.py ModelConfig defaults
        AdamWParams { beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// One AdamW update of `state` along `grad` (bias-corrected, decoupled
/// weight decay — the arithmetic the artifact HLO is pinned to).
pub fn adamw_step(state: &mut ModelState, grad: &[f32], lr: f64, p: &AdamWParams) {
    debug_assert_eq!(state.params.len(), grad.len());
    state.step += 1;
    let t = state.step as f64;
    let k = crate::util::vecmath::AdamCoeffs {
        beta1: p.beta1,
        beta2: p.beta2,
        eps: p.eps,
        weight_decay: p.weight_decay,
        bc1: 1.0 - p.beta1.powf(t),
        bc2: 1.0 - p.beta2.powf(t),
        lr,
    };
    // elementwise kernel — bit-identical to the old serial loop
    crate::util::vecmath::adamw_step_f32(&mut state.params, &mut state.m, &mut state.v, grad, &k);
}

/// Plain SGD update (what the paper's theorems assume for the outer/inner
/// analysis; the theory benches use it for clean Theorem 1/2 curves).
pub fn sgd_step(state: &mut ModelState, grad: &[f32], lr: f64) {
    state.step += 1;
    crate::util::vecmath::sgd_step_f32(&mut state.params, grad, lr);
}

/// Build an engine from config. XlaEngine construction lives in
/// `crate::runtime` (it owns the PJRT client); this factory dispatches.
pub fn build_engine(cfg: &Config) -> Result<Box<dyn TrainEngine>> {
    match &cfg.engine {
        EngineConfig::Mock { dim, noise, condition } => Ok(Box::new(MockEngine::new(
            MockSpec {
                dim: *dim,
                noise: *noise,
                condition: *condition,
                seed: cfg.seed ^ 0x5EED,
                ..MockSpec::default()
            },
        ))),
        EngineConfig::Xla { artifacts_dir, profile } => {
            let engine = crate::runtime::XlaEngine::load(artifacts_dir, profile)?;
            Ok(Box::new(engine))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_matches_reference_arithmetic() {
        // mirrors python/tests/test_model.py::test_adamw_against_manual_numpy
        let p = AdamWParams::default();
        let mut st = ModelState::zeros_like(vec![1.0, -2.0, 0.5]);
        st.m = vec![0.1, 0.0, -0.1];
        st.v = vec![0.01, 0.02, 0.0];
        let grad = [0.3f32, -0.6, 0.9];
        let lr = 2e-3;
        let before = st.clone();
        adamw_step(&mut st, &grad, lr, &p);
        assert_eq!(st.step, 1);
        for i in 0..3 {
            let g = grad[i] as f64;
            let m = 0.9 * before.m[i] as f64 + 0.1 * g;
            let v = 0.95 * before.v[i] as f64 + 0.05 * g * g;
            let mh = m / (1.0 - 0.9f64);
            let vh = v / (1.0 - 0.95f64);
            let x = before.params[i] as f64;
            let want = x - lr * (mh / (vh.sqrt() + 1e-8) + 0.1 * x);
            assert!((st.params[i] as f64 - want).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn sgd_step_basic() {
        let mut st = ModelState::zeros_like(vec![1.0, 1.0]);
        sgd_step(&mut st, &[0.5, -0.5], 0.1);
        assert!((st.params[0] - 0.95).abs() < 1e-6);
        assert!((st.params[1] - 1.05).abs() < 1e-6);
        assert_eq!(st.step, 1);
    }

    #[test]
    fn reset_moments() {
        let mut st = ModelState::zeros_like(vec![1.0]);
        adamw_step(&mut st, &[1.0], 0.01, &AdamWParams::default());
        assert_ne!(st.m[0], 0.0);
        st.reset_moments();
        assert_eq!(st.m[0], 0.0);
        assert_eq!(st.v[0], 0.0);
        assert_eq!(st.step, 0);
    }
}
