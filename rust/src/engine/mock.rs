//! MockEngine: a synthetic stochastic objective with controllable
//! gradient noise, used by unit/property tests and the theory benches
//! (which need 10^4-10^5 inner steps — far beyond interpret-mode Pallas).
//!
//! Objective: ill-conditioned quadratic
//!     F(x) = 1/2 (x - x*)^T A (x - x*) + loss_floor,
//! with diagonal A whose eigenvalues span [1/condition, 1] (so L = 1).
//! Per-sample gradients are  A(x - x*) + noise * z_i,  z_i ~ N(0, I_d/d)
//! (normalized so sigma^2_sample = noise^2 regardless of dimension).
//!
//! This is exactly the setting of the paper's Lemma 1/2 analysis: smooth,
//! bounded gradient-noise second moment, and a gradient norm that decays
//! as training progresses — which is what makes the norm-test batch grow
//! (Theorem 1) and communications thin out (Theorem 2).
//!
//! Sampling trick: rather than materializing per-sample gradients, the
//! engine draws the C *chunk-mean* noise vectors directly from
//! N(0, noise^2/(chunk_size * d) I) — statistically identical to averaging
//! chunk_size per-sample draws — and computes the same (s1, s2, ip)
//! statistics the Pallas `grad_stats` kernel produces for the real model.

use super::{adamw_step, sgd_step, AdamWParams, ModelState, StepStats, TrainEngine};
use crate::data::TokenBatch;
use crate::util::{vecmath, Rng};
use anyhow::{ensure, Result};

/// Generation parameters of the synthetic objective.
#[derive(Clone, Debug)]
pub struct MockSpec {
    /// Problem dimension d.
    pub dim: usize,
    /// Per-sample gradient noise std (sigma).
    pub noise: f64,
    /// Condition number of A (eigenvalues in [1/condition, 1]).
    pub condition: f64,
    /// Seed of the objective (eigen-directions, optimum, inits).
    pub seed: u64,
    /// Use plain SGD instead of AdamW for the inner update (the paper's
    /// theorems assume SGD; theory benches set this).
    pub use_sgd: bool,
    /// Multiplier applied to incoming learning rates (lets the same
    /// config drive both AdamW-scaled and SGD-scaled runs).
    pub lr_scale: f64,
    /// Std of the random initialization around the origin. Small values
    /// start training inside the noise-dominated regime where the norm
    /// test's request is immediately > 1 (used by the theory benches).
    pub init_scale: f64,
}

impl Default for MockSpec {
    fn default() -> Self {
        MockSpec {
            dim: 1000,
            noise: 1.0,
            condition: 10.0,
            seed: 0,
            use_sgd: false,
            lr_scale: 1.0,
            init_scale: 2.0,
        }
    }
}

/// Ladder mirrors what an AOT bundle would provide; the mock can execute
/// any of these directly.
const LADDER: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];
const EVAL_BATCH: usize = 16;
const LOSS_FLOOR: f64 = 1.0;
/// Max chunks used for the variance statistics (matches aot.py tiny/small).
const MAX_CHUNKS: usize = 8;

/// The synthetic engine. Construction is deterministic in the spec, and
/// the instance is immutable after construction — every method takes
/// `&self`, so one engine is freely shared across the parallel runtime's
/// worker threads (statistic scratch is thread-local, keeping the hot
/// path allocation-free without any cross-thread state).
pub struct MockEngine {
    spec: MockSpec,
    /// Diagonal of A.
    eig: Vec<f32>,
    /// Optimum x*.
    xstar: Vec<f32>,
    adamw: AdamWParams,
}

impl MockEngine {
    /// Build the objective (eigenspectrum + optimum) from `spec`.
    pub fn new(spec: MockSpec) -> Self {
        assert!(spec.dim >= 1);
        let mut rng = Rng::new(spec.seed);
        // log-uniform eigenvalue spread over [1/condition, 1]
        let eig: Vec<f32> = (0..spec.dim)
            .map(|i| {
                let t = i as f64 / (spec.dim.max(2) - 1) as f64;
                ((-t * spec.condition.ln()).exp()) as f32
            })
            .collect();
        let xstar: Vec<f32> = (0..spec.dim).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        MockEngine { spec, eig, xstar, adamw: AdamWParams::default() }
    }

    /// The generation parameters this engine was built from.
    pub fn spec(&self) -> &MockSpec {
        &self.spec
    }

    /// The objective's optimum x* (exposed for benches probing the
    /// near-convergence regime).
    pub fn optimum(&self) -> &[f32] {
        &self.xstar
    }

    /// True loss F(x) (no noise) — handy for tests/benches. Summation
    /// follows the fixed chunked order (DESIGN.md §12).
    pub fn true_loss(&self, x: &[f32]) -> f64 {
        vecmath::quad_loss_f32(&x[..self.spec.dim], &self.xstar, &self.eig) + LOSS_FLOOR
    }

    /// True gradient A(x - x*) into `out`; returns ||grad||^2 (the
    /// gradient elements are bit-identical to the old serial loop; only
    /// the norm reduction uses the chunked order).
    fn true_grad(&self, x: &[f32], out: &mut [f32]) -> f64 {
        let d = self.spec.dim;
        vecmath::quad_grad_f32(&x[..d], &self.xstar, &self.eig, &mut out[..d])
    }

    /// Gradient + statistics shared by train_step / grad_step. Fills
    /// gbar into `grad_out` and returns stats. All noise comes from the
    /// caller's stream (see the engine module's stochasticity contract).
    /// Scratch is thread-local, so the hot path stays allocation-free
    /// after each thread's first step while concurrent callers on
    /// different worker threads never contend — the thread contract of
    /// `TrainEngine` (DESIGN.md §6).
    fn compute_grad(
        &self,
        params: &[f32],
        batch: usize,
        grad_out: &mut [f32],
        noise: &mut Rng,
    ) -> StepStats {
        thread_local! {
            /// (gbar, flat [C * d] chunk-mean gradients), grown on demand.
            static SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
                const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
        }
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (gbar, chunk_buf) = &mut *scratch;
            self.compute_grad_with(params, batch, grad_out, noise, gbar, chunk_buf)
        })
    }

    /// `compute_grad` body over caller-provided scratch (every element
    /// used is overwritten before it is read, so stale contents from a
    /// previous step cannot leak into the statistics).
    fn compute_grad_with(
        &self,
        params: &[f32],
        batch: usize,
        grad_out: &mut [f32],
        noise: &mut Rng,
        gbar: &mut Vec<f32>,
        chunk_buf: &mut Vec<f32>,
    ) -> StepStats {
        let d = self.spec.dim;
        let chunks = batch.min(MAX_CHUNKS).max(1);
        let chunk_size = (batch as f64 / chunks as f64).max(1.0);
        // chunk-mean noise std so per-sample sigma^2 == noise^2 exactly:
        // each coordinate gets noise/sqrt(d * chunk_size).
        let coord_std = self.spec.noise / (d as f64 * chunk_size).sqrt();

        if gbar.len() < d {
            gbar.resize(d, 0.0);
        }
        let gbar = &mut gbar[..d];
        let true_nsq = self.true_grad(params, gbar);

        // build chunk gradients = true grad + chunk noise, flat [C * d]
        if chunk_buf.len() < chunks * d {
            chunk_buf.resize(chunks * d, 0.0);
        }
        let chunk_buf = &mut chunk_buf[..chunks * d];
        for c in 0..chunks {
            let buf = &mut chunk_buf[c * d..(c + 1) * d];
            for (b, g) in buf.iter_mut().zip(gbar.iter()) {
                *b = *g + noise.normal_ms(0.0, coord_std) as f32;
            }
        }
        // gbar = mean over chunks; s1 = ||gbar||^2. The per-element mean
        // keeps the old row order (so grad_out is bit-identical); the s1
        // reduction uses the chunked order (DESIGN.md §12).
        let s1 = vecmath::chunk_mean_norm_sq(chunk_buf, chunks, &mut grad_out[..d]);
        // s2 = sum_c ||g_c - gbar||^2 ; ip_c = <g_c, gbar> — fused per-row
        // kernel, both sums in the chunked order
        let mut s2 = 0.0f64;
        let mut ip = [0.0f64; MAX_CHUNKS];
        for c in 0..chunks {
            let buf = &chunk_buf[c * d..(c + 1) * d];
            let (acc, dotp) = vecmath::sq_diff_dot_f32(buf, &grad_out[..d]);
            s2 += acc;
            ip[c] = dotp;
        }
        let (sigma2, ip_var) = if chunks > 1 {
            let scale = batch as f64 / chunks as f64;
            let sigma2 = scale * s2 / (chunks - 1) as f64;
            let ip_mean = ip[..chunks].iter().sum::<f64>() / chunks as f64;
            let ip_ss = ip[..chunks].iter().map(|v| (v - ip_mean) * (v - ip_mean)).sum::<f64>();
            (sigma2, scale * ip_ss / (chunks - 1) as f64)
        } else {
            (0.0, 0.0)
        };

        // noisy loss observation: F(x) + noise/sqrt(b) * z
        let loss_noise = noise.normal_ms(0.0, self.spec.noise * 0.05 / (batch as f64).sqrt());
        let loss = self.true_loss(params) + loss_noise;
        let _ = true_nsq; // retained for debugging hooks

        StepStats { loss, grad_sq_norm: s1, sigma2, ip_var }
    }
}

impl TrainEngine for MockEngine {
    fn name(&self) -> String {
        format!(
            "mock(dim={}, noise={}, cond={}, opt={})",
            self.spec.dim,
            self.spec.noise,
            self.spec.condition,
            if self.spec.use_sgd { "sgd" } else { "adamw" }
        )
    }

    fn param_count(&self) -> usize {
        self.spec.dim
    }

    fn init_state(&self, seed: u64) -> ModelState {
        // Independent random init per trainer (MIT §4.1): offset from x*
        // with a deterministic per-seed direction.
        let mut rng = Rng::new(self.spec.seed ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let s = self.spec.init_scale;
        let params: Vec<f32> =
            (0..self.spec.dim).map(|_| rng.normal_ms(0.0, s) as f32).collect();
        ModelState::zeros_like(params)
    }

    fn supported_batches(&self) -> &[usize] {
        LADDER
    }

    fn eval_batch(&self) -> usize {
        EVAL_BATCH
    }

    fn train_step(
        &self,
        state: &mut ModelState,
        lr: f64,
        batch: &TokenBatch,
        noise: &mut Rng,
    ) -> Result<StepStats> {
        ensure!(
            LADDER.contains(&batch.batch),
            "mock: unsupported batch {}",
            batch.batch
        );
        // thread-local grad scratch, grown on demand — keeps the
        // non-accumulating hot path allocation-free after each worker
        // thread's first step (same thread contract as `compute_grad`'s
        // SCRATCH; `compute_grad` overwrites every element before any
        // read, so stale contents cannot leak into the update).
        thread_local! {
            static GRAD: std::cell::RefCell<Vec<f32>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        GRAD.with(|cell| {
            let mut grad = cell.borrow_mut();
            if grad.len() < self.spec.dim {
                grad.resize(self.spec.dim, 0.0);
            }
            let grad = &mut grad[..self.spec.dim];
            let stats = self.compute_grad(&state.params, batch.batch, grad, noise);
            let lr = lr * self.spec.lr_scale;
            if self.spec.use_sgd {
                sgd_step(state, grad, lr);
            } else {
                adamw_step(state, grad, lr, &self.adamw);
            }
            Ok(stats)
        })
    }

    fn grad_step(
        &self,
        params: &[f32],
        batch: &TokenBatch,
        grad_out: &mut [f32],
        noise: &mut Rng,
    ) -> Result<StepStats> {
        ensure!(grad_out.len() == self.spec.dim, "grad_out length mismatch");
        Ok(self.compute_grad(params, batch.batch, grad_out, noise))
    }

    fn apply_update(&self, state: &mut ModelState, lr: f64, grad: &[f32]) -> Result<()> {
        let lr = lr * self.spec.lr_scale;
        if self.spec.use_sgd {
            sgd_step(state, grad, lr);
        } else {
            adamw_step(state, grad, lr, &self.adamw);
        }
        Ok(())
    }

    fn eval_loss(&self, params: &[f32], batch: &TokenBatch, noise: &mut Rng) -> Result<f64> {
        // Evaluation sees the true objective plus small observation noise.
        let obs = noise.normal_ms(0.0, self.spec.noise * 0.01 / (batch.batch as f64).sqrt());
        Ok(self.true_loss(params) + obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(b: usize) -> TokenBatch {
        TokenBatch::new(b, 8)
    }

    fn engine() -> MockEngine {
        MockEngine::new(MockSpec { dim: 200, noise: 1.0, condition: 10.0, seed: 3, ..MockSpec::default() })
    }

    #[test]
    fn training_descends() {
        let e = engine();
        let mut noise = Rng::new(100);
        let mut st = e.init_state(0);
        let l0 = e.true_loss(&st.params);
        for _ in 0..300 {
            e.train_step(&mut st, 0.05, &batch(16), &mut noise).unwrap();
        }
        let l1 = e.true_loss(&st.params);
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1} did not descend");
    }

    #[test]
    fn sigma2_estimate_near_truth() {
        let e = engine();
        let mut noise = Rng::new(101);
        let st = e.init_state(0);
        let mut grad = vec![0.0f32; 200];
        let mut acc = 0.0;
        let n = 200;
        for _ in 0..n {
            let s = e.grad_step(&st.params, &batch(64), &mut grad, &mut noise).unwrap();
            acc += s.sigma2;
        }
        let mean = acc / n as f64;
        // sigma^2_sample should be ~ noise^2 = 1.0
        assert!((0.7..1.3).contains(&mean), "sigma2 estimate {mean}");
    }

    #[test]
    fn grad_noise_shrinks_with_batch() {
        let e = engine();
        let mut noise = Rng::new(102);
        let st = e.init_state(0);
        let mut grad = vec![0.0f32; 200];
        let mut var_small = 0.0;
        let mut var_big = 0.0;
        let mut tg = vec![0.0f32; 200];
        let true_nsq = e.true_grad(&st.params, &mut tg);
        for _ in 0..50 {
            e.grad_step(&st.params, &batch(1), &mut grad, &mut noise).unwrap();
            var_small += grad
                .iter()
                .zip(tg.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
            e.grad_step(&st.params, &batch(256), &mut grad, &mut noise).unwrap();
            var_big += grad
                .iter()
                .zip(tg.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
        }
        assert!(
            var_big < var_small / 4.0,
            "batch 256 noise {var_big} vs batch 1 {var_small}"
        );
        assert!(true_nsq > 0.0);
    }

    #[test]
    fn deterministic_given_equal_noise_streams() {
        let mk = || MockEngine::new(MockSpec { seed: 11, ..MockSpec::default() });
        let a = mk();
        let b = mk();
        let mut na = Rng::new(55);
        let mut nb = Rng::new(55);
        let mut sa = a.init_state(2);
        let mut sb = b.init_state(2);
        assert_eq!(sa.params, sb.params);
        let ra = a.train_step(&mut sa, 0.01, &batch(8), &mut na).unwrap();
        let rb = b.train_step(&mut sb, 0.01, &batch(8), &mut nb).unwrap();
        assert_eq!(sa.params, sb.params);
        assert_eq!(ra.loss, rb.loss);
        // distinct streams -> distinct noise -> distinct trajectories
        let mut nc = Rng::new(56);
        let mut sc = mk().init_state(2);
        let rc = mk().train_step(&mut sc, 0.01, &batch(8), &mut nc).unwrap();
        assert_ne!(ra.loss, rc.loss);
    }

    #[test]
    fn distinct_trainer_inits() {
        let e = engine();
        assert_ne!(e.init_state(0).params, e.init_state(1).params);
    }

    #[test]
    fn grad_then_apply_equals_train_step() {
        // SwitchMode invariant: grad_step + apply_update == train_step
        // when no accumulation happens, given identical noise draws.
        let spec = MockSpec { dim: 50, noise: 0.0, condition: 5.0, seed: 7, ..MockSpec::default() };
        let e1 = MockEngine::new(spec.clone());
        let e2 = MockEngine::new(spec);
        let mut n1 = Rng::new(9);
        let mut n2 = Rng::new(9);
        let mut s1 = e1.init_state(0);
        let mut s2 = e2.init_state(0);
        e1.train_step(&mut s1, 0.01, &batch(4), &mut n1).unwrap();
        let mut g = vec![0.0f32; 50];
        e2.grad_step(&s2.params, &batch(4), &mut g, &mut n2).unwrap();
        e2.apply_update(&mut s2, 0.01, &g).unwrap();
        for (a, b) in s1.params.iter().zip(s2.params.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rejects_unsupported_batch() {
        let e = engine();
        let mut noise = Rng::new(0);
        let mut st = e.init_state(0);
        assert!(e.train_step(&mut st, 0.01, &batch(3), &mut noise).is_err());
    }
}
