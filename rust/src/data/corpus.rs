//! Deterministic synthetic corpus generator (C4 stand-in; see module docs
//! in `data/mod.rs` and DESIGN.md §4 for the substitution rationale).

use crate::util::{Rng, ZipfTable};

/// Generation parameters for a synthetic corpus.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    /// Number of sequences to generate.
    pub sequences: usize,
    /// seq_len + 1 tokens per stored example.
    pub seq_width: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Zipf exponent of the unigram background.
    pub zipf_s: f64,
    /// Probability a position is drawn from the Markov chain rather than
    /// the unigram background (higher = more learnable structure).
    pub structure: f64,
    /// Number of distinct repeated templates woven into the corpus.
    pub templates: usize,
    /// Generation seed.
    pub seed: u64,
}

impl CorpusSpec {
    /// Spec with the default structure/template mix.
    pub fn new(sequences: usize, seq_len: usize, vocab: usize, zipf_s: f64, seed: u64) -> Self {
        CorpusSpec {
            sequences,
            seq_width: seq_len + 1,
            vocab,
            zipf_s,
            structure: 0.75,
            templates: 16,
            seed,
        }
    }
}

/// A fully-materialized token corpus (train or validation split).
#[derive(Clone)]
pub struct Corpus {
    /// The spec the corpus was generated from.
    pub spec: CorpusSpec,
    /// Row-major `[sequences, seq_width]`.
    tokens: Vec<i32>,
}

impl Corpus {
    /// Generate a corpus. Deterministic in `spec` (including the seed).
    pub fn generate(spec: CorpusSpec) -> Corpus {
        assert!(spec.vocab >= 4, "vocab too small");
        let mut rng = Rng::new(spec.seed);
        let zipf = ZipfTable::new(spec.vocab, spec.zipf_s);

        // Order-2 Markov chain over a hashed transition rule: cheap,
        // deterministic, and gives each (a, b) context a sharp next-token
        // distribution the model can learn.
        let chain = MarkovRule { vocab: spec.vocab as u64, salt: spec.seed ^ 0xC0FFEE };

        // Repeated templates: short token motifs inserted verbatim.
        let templates: Vec<Vec<i32>> = (0..spec.templates)
            .map(|_| {
                let len = 6 + rng.below(10) as usize;
                (0..len).map(|_| zipf.sample(&mut rng) as i32).collect()
            })
            .collect();

        let mut tokens = Vec::with_capacity(spec.sequences * spec.seq_width);
        for _ in 0..spec.sequences {
            let mut a = zipf.sample(&mut rng) as i32;
            let mut b = zipf.sample(&mut rng) as i32;
            let mut row: Vec<i32> = Vec::with_capacity(spec.seq_width);
            row.push(a);
            row.push(b);
            while row.len() < spec.seq_width {
                if !templates.is_empty() && rng.f64() < 0.05 {
                    // splice a template motif
                    let t = &templates[rng.below(templates.len() as u64) as usize];
                    for &tok in t.iter() {
                        if row.len() >= spec.seq_width {
                            break;
                        }
                        row.push(tok);
                    }
                } else if rng.f64() < spec.structure {
                    row.push(chain.next(a, b));
                } else {
                    row.push(zipf.sample(&mut rng) as i32);
                }
                b = row[row.len() - 1];
                a = row[row.len() - 2];
            }
            row.truncate(spec.seq_width);
            tokens.extend_from_slice(&row);
        }
        Corpus { spec, tokens }
    }

    /// Number of sequences.
    pub fn len(&self) -> usize {
        self.spec.sequences
    }

    /// True when the corpus holds no sequences.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tokens per sequence (seq_len + 1).
    pub fn width(&self) -> usize {
        self.spec.seq_width
    }

    /// Read-only view of sequence `i`.
    #[inline]
    pub fn sequence(&self, i: usize) -> &[i32] {
        let w = self.spec.seq_width;
        &self.tokens[i * w..(i + 1) * w]
    }
}

/// Hash-derived deterministic order-2 transition rule.
struct MarkovRule {
    vocab: u64,
    salt: u64,
}

impl MarkovRule {
    /// Next token for context (a, b): one of 4 context-determined modes,
    /// selected pseudo-randomly but *fixed* per context, so the mapping is
    /// learnable.
    #[inline]
    fn next(&self, a: i32, b: i32) -> i32 {
        let h = Self::mix(self.salt ^ ((a as u64) << 32 | (b as u64 & 0xFFFF_FFFF)));
        (h % self.vocab) as i32
    }

    #[inline]
    fn mix(mut z: u64) -> u64 {
        z = (z ^ (z >> 33)).wrapping_mul(0xFF51AFD7ED558CCD);
        z = (z ^ (z >> 33)).wrapping_mul(0xC4CEB9FE1A85EC53);
        z ^ (z >> 33)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CorpusSpec {
        CorpusSpec::new(200, 32, 128, 1.1, 42)
    }

    #[test]
    fn deterministic() {
        let a = Corpus::generate(spec());
        let b = Corpus::generate(spec());
        assert_eq!(a.tokens, b.tokens);
        let mut s2 = spec();
        s2.seed = 43;
        let c = Corpus::generate(s2);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::generate(spec());
        for i in 0..c.len() {
            for &t in c.sequence(i) {
                assert!((0..128).contains(&t));
            }
        }
    }

    #[test]
    fn shapes() {
        let c = Corpus::generate(spec());
        assert_eq!(c.len(), 200);
        assert_eq!(c.width(), 33);
        assert_eq!(c.sequence(0).len(), 33);
        assert_eq!(c.sequence(199).len(), 33);
    }

    #[test]
    fn unigram_is_heavy_tailed() {
        let c = Corpus::generate(CorpusSpec::new(500, 64, 256, 1.2, 1));
        let mut counts = vec![0usize; 256];
        for i in 0..c.len() {
            for &t in c.sequence(i) {
                counts[t as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = counts.iter().sum();
        let top10: usize = counts[..10].iter().sum();
        assert!(
            top10 as f64 / total as f64 > 0.25,
            "top-10 share {top10}/{total} not heavy-tailed"
        );
    }

    #[test]
    fn markov_structure_is_learnable() {
        // The same context (a, b) must usually produce the same next token
        // when the structural mode fires => conditional entropy is far
        // below the unigram entropy. Count repeated-context agreement.
        let c = Corpus::generate(CorpusSpec::new(2000, 32, 64, 1.1, 5));
        use std::collections::HashMap;
        let mut ctx: HashMap<(i32, i32), HashMap<i32, usize>> = HashMap::new();
        for i in 0..c.len() {
            let s = c.sequence(i);
            for w in s.windows(3) {
                *ctx.entry((w[0], w[1])).or_default().entry(w[2]).or_default() += 1;
            }
        }
        // aggregate: fraction of mass on each context's modal token
        let (mut modal, mut total) = (0usize, 0usize);
        for (_, dist) in ctx.iter() {
            let sum: usize = dist.values().sum();
            if sum < 5 {
                continue;
            }
            modal += dist.values().max().unwrap();
            total += sum;
        }
        assert!(total > 0);
        let frac = modal as f64 / total as f64;
        assert!(frac > 0.5, "modal fraction {frac:.3} — structure too weak");
    }
}
