//! Data sharding per the paper's §4.1: every trainer receives a random,
//! *possibly intersecting* subset `D_i ⊆ D`; workers inside a trainer
//! split that subset disjointly.

use crate::util::Rng;

/// A shard is a list of sequence indices into the shared corpus.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Sequence indices into the shared corpus.
    pub indices: Vec<usize>,
}

impl Shard {
    /// Number of sequences in the shard.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// True when the shard holds no sequences.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Split a trainer shard into `m` disjoint worker shards (round-robin
    /// to keep sizes within 1 of each other).
    pub fn split(&self, m: usize) -> Vec<Shard> {
        assert!(m >= 1);
        let mut out: Vec<Shard> = (0..m).map(|_| Shard { indices: Vec::new() }).collect();
        for (i, &ix) in self.indices.iter().enumerate() {
            out[i % m].indices.push(ix);
        }
        out
    }
}

/// Build `k` trainer shards over a corpus of `n` sequences.
///
/// `fraction` controls shard size: each shard holds `ceil(fraction * n)`
/// sequences drawn without replacement *within the shard* but
/// independently *across shards*, so shards intersect with the natural
/// hypergeometric overlap (the paper's "possibly intersecting random data
/// subset assigned to trainer i").
pub fn make_shards(n: usize, k: usize, fraction: f64, rng: &mut Rng) -> Vec<Shard> {
    assert!(n > 0 && k > 0);
    assert!((0.0..=1.0).contains(&fraction));
    let size = ((fraction * n as f64).ceil() as usize).clamp(1, n);
    (0..k)
        .map(|_| Shard { indices: rng.sample_indices(n, size) })
        .collect()
}

/// Merge shard index sets when trainers merge (the representative keeps
/// the union so no data assigned to the consumed trainers is lost).
pub fn union_shards(shards: &[&Shard]) -> Shard {
    let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
    all.sort_unstable();
    all.dedup();
    Shard { indices: all }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_sizes() {
        let mut rng = Rng::new(1);
        let shards = make_shards(100, 4, 0.5, &mut rng);
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.len(), 50);
            let mut d = s.indices.clone();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), 50, "indices within a shard must be distinct");
        }
    }

    #[test]
    fn shards_differ_and_intersect() {
        let mut rng = Rng::new(2);
        let shards = make_shards(1000, 2, 0.5, &mut rng);
        let a: std::collections::HashSet<_> = shards[0].indices.iter().collect();
        let b: std::collections::HashSet<_> = shards[1].indices.iter().collect();
        assert_ne!(a, b);
        // expected overlap ~ 0.25 * 1000 = 250; allow wide tolerance
        let inter = a.intersection(&b).count();
        assert!((100..400).contains(&inter), "overlap {inter}");
    }

    #[test]
    fn worker_split_disjoint_and_complete() {
        let mut rng = Rng::new(3);
        let shard = make_shards(97, 1, 1.0, &mut rng).pop().unwrap();
        let workers = shard.split(4);
        let sizes: Vec<usize> = workers.iter().map(|w| w.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 97);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        let mut all: Vec<usize> = workers.iter().flat_map(|w| w.indices.clone()).collect();
        all.sort();
        let mut orig = shard.indices.clone();
        orig.sort();
        assert_eq!(all, orig);
    }

    #[test]
    fn union_dedups() {
        let a = Shard { indices: vec![1, 2, 3] };
        let b = Shard { indices: vec![3, 4] };
        let u = union_shards(&[&a, &b]);
        assert_eq!(u.indices, vec![1, 2, 3, 4]);
    }

    #[test]
    fn fraction_one_is_full_coverage() {
        let mut rng = Rng::new(4);
        let s = &make_shards(50, 1, 1.0, &mut rng)[0];
        let mut ix = s.indices.clone();
        ix.sort();
        assert_eq!(ix, (0..50).collect::<Vec<_>>());
    }
}
