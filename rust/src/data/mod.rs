//! Synthetic corpus, sharding, and batch sampling.
//!
//! The paper pre-trains on C4-English; with no network access we substitute
//! a deterministic synthetic corpus that keeps the two properties the
//! coordination layer actually reacts to (DESIGN.md §4):
//!
//!   1. a *heavy-tailed unigram distribution* (Zipf) — gradient noise is
//!      dominated by rare tokens, which is what makes the norm-test
//!      statistic informative;
//!   2. *learnable sequential structure* — an order-2 Markov chain blended
//!      with repeated templates, so the model's loss genuinely decreases
//!      and the gradient signal-to-noise ratio falls over training
//!      (the regime where adaptive batching pays off).
//!
//! Sharding follows §4.1: each trainer gets a random, possibly
//! intersecting subset `D_i ⊆ D`, and workers within a trainer partition
//! that subset disjointly.

pub mod corpus;
pub mod sampler;
pub mod shard;

pub use corpus::{Corpus, CorpusSpec};
pub use sampler::{BatchSampler, SamplerState};
pub use shard::{make_shards, Shard};

/// A batch of token sequences, row-major `[batch, seq_len + 1]` i32 —
/// exactly the layout the PJRT `train_step` artifacts expect.
#[derive(Clone, Debug)]
pub struct TokenBatch {
    /// Row-major token storage, `batch * width` entries.
    pub tokens: Vec<i32>,
    /// Number of sequences (rows).
    pub batch: usize,
    /// Tokens per sequence (seq_len + 1).
    pub width: usize,
}

impl TokenBatch {
    /// Zero-filled batch of shape `[batch, width]`.
    pub fn new(batch: usize, width: usize) -> Self {
        TokenBatch { tokens: vec![0; batch * width], batch, width }
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [i32] {
        let w = self.width;
        &mut self.tokens[i * w..(i + 1) * w]
    }

    /// Read-only view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.width..(i + 1) * self.width]
    }
}
