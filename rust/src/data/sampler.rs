//! Batch sampling from a worker's shard.
//!
//! Epoch-shuffled sampling without replacement: each worker walks a
//! shuffled permutation of its shard and reshuffles when exhausted —
//! matching how the paper's trainer threads stream their data shard.
//! The sampler fills caller-provided `TokenBatch` buffers so the PJRT hot
//! path performs no allocation per step (see EXPERIMENTS.md §Perf).

use super::{Corpus, Shard, TokenBatch};
use crate::util::Rng;

/// Epoch-shuffled without-replacement sampler over one worker's shard.
pub struct BatchSampler {
    shard: Shard,
    cursor: usize,
    order: Vec<usize>,
    rng: Rng,
    /// Total sequences drawn since construction (epoch accounting).
    pub drawn: u64,
}

impl BatchSampler {
    /// Sampler over `shard` with its own shuffle stream.
    pub fn new(shard: Shard, rng: Rng) -> Self {
        let order: Vec<usize> = (0..shard.len()).collect();
        let mut s = BatchSampler { shard, cursor: 0, order, rng, drawn: 0 };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Size of the underlying shard.
    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    /// Number of full epochs completed so far.
    pub fn epochs(&self) -> u64 {
        if self.shard.is_empty() {
            0
        } else {
            self.drawn / self.shard.len() as u64
        }
    }

    /// Fill `out` (shape [batch, width]) with the next `batch` sequences.
    pub fn next_batch(&mut self, corpus: &Corpus, out: &mut TokenBatch) {
        assert_eq!(out.width, corpus.width(), "batch width != corpus width");
        assert!(!self.shard.is_empty(), "sampling from empty shard");
        for row in 0..out.batch {
            if self.cursor >= self.order.len() {
                self.reshuffle();
            }
            let seq_ix = self.shard.indices[self.order[self.cursor]];
            self.cursor += 1;
            self.drawn += 1;
            let dst = out.row_mut(row);
            dst.copy_from_slice(corpus.sequence(seq_ix));
        }
    }

    /// Allocate-and-fill convenience for non-hot-path callers.
    pub fn sample(&mut self, corpus: &Corpus, batch: usize) -> TokenBatch {
        let mut out = TokenBatch::new(batch, corpus.width());
        self.next_batch(corpus, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusSpec;
    use crate::data::make_shards;

    fn setup() -> (Corpus, BatchSampler) {
        let corpus = Corpus::generate(CorpusSpec::new(40, 16, 64, 1.1, 9));
        let mut rng = Rng::new(10);
        let shard = make_shards(40, 1, 1.0, &mut rng).pop().unwrap();
        (corpus, BatchSampler::new(shard, rng))
    }

    #[test]
    fn batch_shapes_and_contents() {
        let (corpus, mut s) = setup();
        let b = s.sample(&corpus, 8);
        assert_eq!(b.batch, 8);
        assert_eq!(b.width, 17);
        // every row must be an actual corpus sequence
        for i in 0..8 {
            let row = b.row(i);
            let found = (0..corpus.len()).any(|j| corpus.sequence(j) == row);
            assert!(found, "row {i} not from corpus");
        }
    }

    #[test]
    fn epoch_without_replacement() {
        let (corpus, mut s) = setup();
        // draw exactly one epoch (40 sequences) and check coverage
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            let b = s.sample(&corpus, 8);
            for i in 0..8 {
                seen.insert(b.row(i).to_vec());
            }
        }
        // corpus rows may collide textually; require most are covered
        assert!(seen.len() >= 35, "saw only {} distinct rows", seen.len());
        assert_eq!(s.epochs(), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let corpus = Corpus::generate(CorpusSpec::new(40, 16, 64, 1.1, 9));
        let mut r1 = Rng::new(5);
        let shard1 = make_shards(40, 1, 0.5, &mut r1).pop().unwrap();
        let mut r2 = Rng::new(5);
        let shard2 = make_shards(40, 1, 0.5, &mut r2).pop().unwrap();
        let mut s1 = BatchSampler::new(shard1, r1);
        let mut s2 = BatchSampler::new(shard2, r2);
        for _ in 0..4 {
            assert_eq!(s1.sample(&corpus, 4).tokens, s2.sample(&corpus, 4).tokens);
        }
    }

    #[test]
    fn reuses_buffer_without_allocation() {
        let (corpus, mut s) = setup();
        let mut buf = TokenBatch::new(4, corpus.width());
        let ptr = buf.tokens.as_ptr();
        for _ in 0..10 {
            s.next_batch(&corpus, &mut buf);
        }
        assert_eq!(ptr, buf.tokens.as_ptr(), "buffer must not reallocate");
    }
}
