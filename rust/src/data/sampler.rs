//! Batch sampling from a worker's shard.
//!
//! Epoch-shuffled sampling without replacement: each worker walks a
//! shuffled permutation of its shard and reshuffles when exhausted —
//! matching how the paper's trainer threads stream their data shard.
//! The sampler fills caller-provided `TokenBatch` buffers so the PJRT hot
//! path performs no allocation per step (see EXPERIMENTS.md §Perf).

use super::{Corpus, Shard, TokenBatch};
use crate::util::Rng;

/// A sampler's full position: shard indices, the current epoch's
/// shuffled order, the cursor into it, the draw count and the shuffle
/// stream — everything a checkpoint needs for the resumed sampler to
/// yield the exact batch sequence the saved one would have
/// (DESIGN.md §8 resume contract).
#[derive(Clone, Debug, PartialEq)]
pub struct SamplerState {
    /// Sequence indices of the underlying shard.
    pub shard: Vec<usize>,
    /// The current epoch's shuffled permutation of `0..shard.len()`.
    pub order: Vec<usize>,
    /// Position within `order`.
    pub cursor: usize,
    /// Total sequences drawn since construction.
    pub drawn: u64,
    /// Shuffle-stream state (`Rng::state`).
    pub rng: ([u64; 4], Option<f64>),
}

/// Epoch-shuffled without-replacement sampler over one worker's shard.
pub struct BatchSampler {
    shard: Shard,
    cursor: usize,
    order: Vec<usize>,
    rng: Rng,
    /// Total sequences drawn since construction (epoch accounting).
    pub drawn: u64,
}

impl BatchSampler {
    /// Sampler over `shard` with its own shuffle stream.
    pub fn new(shard: Shard, rng: Rng) -> Self {
        let order: Vec<usize> = (0..shard.len()).collect();
        let mut s = BatchSampler { shard, cursor: 0, order, rng, drawn: 0 };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Capture the sampler's position for a checkpoint.
    pub fn export_state(&self) -> SamplerState {
        SamplerState {
            shard: self.shard.indices.clone(),
            order: self.order.clone(),
            cursor: self.cursor,
            drawn: self.drawn,
            rng: self.rng.state(),
        }
    }

    /// Rebuild a sampler mid-epoch from a captured [`SamplerState`]
    /// (no reshuffle — the restored order and cursor are authoritative).
    pub fn from_state(st: SamplerState) -> BatchSampler {
        BatchSampler {
            shard: Shard { indices: st.shard },
            cursor: st.cursor,
            order: st.order,
            rng: Rng::from_state(st.rng.0, st.rng.1),
            drawn: st.drawn,
        }
    }

    /// Size of the underlying shard.
    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    /// Number of full epochs completed so far.
    pub fn epochs(&self) -> u64 {
        if self.shard.is_empty() {
            0
        } else {
            self.drawn / self.shard.len() as u64
        }
    }

    /// Fill `out` (shape [batch, width]) with the next `batch` sequences.
    pub fn next_batch(&mut self, corpus: &Corpus, out: &mut TokenBatch) {
        assert_eq!(out.width, corpus.width(), "batch width != corpus width");
        assert!(!self.shard.is_empty(), "sampling from empty shard");
        for row in 0..out.batch {
            if self.cursor >= self.order.len() {
                self.reshuffle();
            }
            let seq_ix = self.shard.indices[self.order[self.cursor]];
            self.cursor += 1;
            self.drawn += 1;
            let dst = out.row_mut(row);
            dst.copy_from_slice(corpus.sequence(seq_ix));
        }
    }

    /// Allocate-and-fill convenience for non-hot-path callers.
    pub fn sample(&mut self, corpus: &Corpus, batch: usize) -> TokenBatch {
        let mut out = TokenBatch::new(batch, corpus.width());
        self.next_batch(corpus, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusSpec;
    use crate::data::make_shards;

    fn setup() -> (Corpus, BatchSampler) {
        let corpus = Corpus::generate(CorpusSpec::new(40, 16, 64, 1.1, 9));
        let mut rng = Rng::new(10);
        let shard = make_shards(40, 1, 1.0, &mut rng).pop().unwrap();
        (corpus, BatchSampler::new(shard, rng))
    }

    #[test]
    fn batch_shapes_and_contents() {
        let (corpus, mut s) = setup();
        let b = s.sample(&corpus, 8);
        assert_eq!(b.batch, 8);
        assert_eq!(b.width, 17);
        // every row must be an actual corpus sequence
        for i in 0..8 {
            let row = b.row(i);
            let found = (0..corpus.len()).any(|j| corpus.sequence(j) == row);
            assert!(found, "row {i} not from corpus");
        }
    }

    #[test]
    fn epoch_without_replacement() {
        let (corpus, mut s) = setup();
        // draw exactly one epoch (40 sequences) and check coverage
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            let b = s.sample(&corpus, 8);
            for i in 0..8 {
                seen.insert(b.row(i).to_vec());
            }
        }
        // corpus rows may collide textually; require most are covered
        assert!(seen.len() >= 35, "saw only {} distinct rows", seen.len());
        assert_eq!(s.epochs(), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let corpus = Corpus::generate(CorpusSpec::new(40, 16, 64, 1.1, 9));
        let mut r1 = Rng::new(5);
        let shard1 = make_shards(40, 1, 0.5, &mut r1).pop().unwrap();
        let mut r2 = Rng::new(5);
        let shard2 = make_shards(40, 1, 0.5, &mut r2).pop().unwrap();
        let mut s1 = BatchSampler::new(shard1, r1);
        let mut s2 = BatchSampler::new(shard2, r2);
        for _ in 0..4 {
            assert_eq!(s1.sample(&corpus, 4).tokens, s2.sample(&corpus, 4).tokens);
        }
    }

    #[test]
    fn state_roundtrip_continues_the_batch_sequence() {
        let (corpus, mut s) = setup();
        // advance mid-epoch so cursor/drawn/rng are all non-trivial
        let _ = s.sample(&corpus, 12);
        let st = s.export_state();
        let mut restored = BatchSampler::from_state(st.clone());
        assert_eq!(restored.export_state(), st, "export/rebuild is an identity");
        // the restored sampler must produce the exact continuation,
        // across an epoch boundary (40-sequence shard, 3x16 crosses it)
        for _ in 0..3 {
            assert_eq!(s.sample(&corpus, 16).tokens, restored.sample(&corpus, 16).tokens);
        }
        assert_eq!(s.drawn, restored.drawn);
    }

    #[test]
    fn reuses_buffer_without_allocation() {
        let (corpus, mut s) = setup();
        let mut buf = TokenBatch::new(4, corpus.width());
        let ptr = buf.tokens.as_ptr();
        for _ in 0..10 {
            s.next_batch(&corpus, &mut buf);
        }
        assert_eq!(ptr, buf.tokens.as_ptr(), "buffer must not reallocate");
    }
}
