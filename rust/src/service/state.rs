//! The run registry (DESIGN.md §13): submission queue, lifecycle state
//! machine, and the executor pool's claim source.
//!
//! The state machine is Submitted → Running ⇄ Paused → Done / Failed /
//! Cancelled. Its transition relation is the pure function
//! [`transition_allowed`] so the property suite can enumerate it;
//! terminal states accept no transitions and no steering mutations, and
//! pause/resume/cancel/checkpoint are accepted only from Running or
//! Paused ([`RunState::accepts_mutation`]).
//!
//! Executor threads block in [`Registry::claim_next`]; submissions are
//! claimed strictly in id order and stamped with a monotonic
//! `started_order` under the registry lock, which is what makes the
//! queueing order deterministic (and testable) even with several
//! executors racing.

use super::api::ApiError;
use crate::config::Config;
use crate::coordinator::{BoundaryControl, BoundaryProgress};
use crate::util::JsonValue;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Lifecycle of a submitted run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunState {
    /// Accepted and queued; not yet claimed by an executor.
    Submitted,
    /// Executing on an executor thread.
    Running,
    /// Parked at an outer-round boundary (host wall-clock only;
    /// virtual time and records are untouched).
    Paused,
    /// Completed the full schedule (or hit its target) and produced a
    /// result.
    Done,
    /// The coordinator returned an error; see the entry's `error`.
    Failed,
    /// A cancel landed at an outer boundary; the result and records are
    /// the exact prefix of the uncancelled run.
    Cancelled,
}

impl RunState {
    /// Every state, for matrix-enumerating property tests.
    pub const ALL: [RunState; 6] = [
        RunState::Submitted,
        RunState::Running,
        RunState::Paused,
        RunState::Done,
        RunState::Failed,
        RunState::Cancelled,
    ];

    /// Canonical lowercase wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            RunState::Submitted => "submitted",
            RunState::Running => "running",
            RunState::Paused => "paused",
            RunState::Done => "done",
            RunState::Failed => "failed",
            RunState::Cancelled => "cancelled",
        }
    }

    /// Parse a wire name (client side).
    pub fn parse(s: &str) -> Option<RunState> {
        RunState::ALL.iter().copied().find(|st| st.as_str() == s)
    }

    /// Terminal states accept no further transitions or mutations.
    pub fn is_terminal(self) -> bool {
        matches!(self, RunState::Done | RunState::Failed | RunState::Cancelled)
    }

    /// Whether a steering mutation (pause/resume/cancel/checkpoint) may
    /// target a run in this state: only Running and Paused — a queued
    /// run has no boundary to land the mutation on, a terminal run has
    /// no future boundaries at all.
    pub fn accepts_mutation(self) -> bool {
        matches!(self, RunState::Running | RunState::Paused)
    }
}

/// The registry's transition relation, as a pure function so the
/// property suite can enumerate the full matrix. `Paused → Done/Failed`
/// exist because a pause request can land after the run's final
/// boundary already passed (the entry is marked Paused while the
/// coordinator is past every park point); the executor then
/// terminalizes the entry from Paused.
pub fn transition_allowed(from: RunState, to: RunState) -> bool {
    use RunState::*;
    matches!(
        (from, to),
        (Submitted, Running)
            | (Running, Paused | Done | Failed | Cancelled)
            | (Paused, Running | Done | Failed | Cancelled)
    )
}

/// An immutable wire-facing view of one run's registry row, taken under
/// the registry lock.
#[derive(Clone, Debug)]
pub struct RunSnapshot {
    /// Monotonic submission id (also the FIFO queue key).
    pub id: u64,
    /// Run name (the config's, possibly overridden at submit).
    pub name: String,
    /// Lifecycle state.
    pub state: RunState,
    /// Structural digest of the resolved config (DESIGN.md §10).
    pub config_digest: u64,
    /// Claim-order stamp: the nth run to start executing.
    pub started_order: Option<u64>,
    /// Error detail once Failed.
    pub error: Option<String>,
    /// Final result JSON once terminal (Done and Cancelled carry one).
    pub result: Option<JsonValue>,
    /// Latest boundary counters published by the coordinator.
    pub progress: BoundaryProgress,
    /// True once a cancel has been requested (it lands at the next
    /// boundary; the state flips to Cancelled when it does).
    pub cancel_requested: bool,
    /// Service checkpoints written so far, as `(outer_step, path)`.
    pub checkpoints: Vec<(u64, String)>,
    /// Canonical final records path (assembled when the run finishes).
    pub records_path: String,
    /// Live step-segment path while the run is executing.
    pub part_path: String,
}

/// A claimed execution unit handed to an executor thread.
pub struct Job {
    /// Registry id.
    pub id: u64,
    /// The resolved config (validated at submit).
    pub cfg: Config,
    /// Steering handle shared with the endpoints.
    pub control: Arc<BoundaryControl>,
    /// Canonical final records path (the streaming target).
    pub records_path: String,
    /// Eval-series CSV path written next to the records.
    pub csv_path: String,
}

struct RunEntry {
    id: u64,
    name: String,
    state: RunState,
    config_digest: u64,
    started_order: Option<u64>,
    error: Option<String>,
    result: Option<JsonValue>,
    cfg: Config,
    control: Arc<BoundaryControl>,
    dir: String,
    records_path: String,
    part_path: String,
    ckpt_seq: u64,
}

struct RegistryInner {
    runs: Vec<RunEntry>,
    next_started: u64,
    shutdown: bool,
}

/// The run registry: every submission's row, guarded by one lock, plus
/// the condvar executor threads block on.
pub struct Registry {
    root: String,
    inner: Mutex<RegistryInner>,
    cv: Condvar,
}

impl Registry {
    /// Empty registry writing run directories under `root`.
    pub fn new(root: &str) -> Registry {
        Registry {
            root: root.to_string(),
            inner: Mutex::new(RegistryInner {
                runs: Vec::new(),
                next_started: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, RegistryInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn entry_snapshot(e: &RunEntry) -> RunSnapshot {
        RunSnapshot {
            id: e.id,
            name: e.name.clone(),
            state: e.state,
            config_digest: e.config_digest,
            started_order: e.started_order,
            error: e.error.clone(),
            result: e.result.clone(),
            progress: e.control.progress(),
            cancel_requested: e.control.cancelled(),
            checkpoints: e.control.checkpoints(),
            records_path: e.records_path.clone(),
            part_path: e.part_path.clone(),
        }
    }

    fn transition(e: &mut RunEntry, to: RunState) {
        debug_assert!(
            transition_allowed(e.state, to),
            "illegal run-state transition {:?} -> {:?} (run {})",
            e.state,
            to,
            e.id
        );
        e.state = to;
    }

    /// Register a validated config; returns the new row's snapshot. The
    /// run starts once an executor slot frees up (FIFO by id).
    pub fn submit(&self, cfg: Config) -> RunSnapshot {
        let control = Arc::new(BoundaryControl::new());
        // pre-publish the schedule shape so observers see the total
        // before the first boundary reports progress
        control.publish(BoundaryProgress {
            outer_steps_total: cfg.algo.outer_steps as u64,
            ..BoundaryProgress::default()
        });
        let mut g = self.lock();
        let id = g.runs.len() as u64;
        let dir = format!("{}/{id}", self.root);
        let records_path = format!("{dir}/{}.jsonl", cfg.name);
        let part_path = crate::metrics::part_path_for(&records_path);
        let entry = RunEntry {
            id,
            name: cfg.name.clone(),
            state: RunState::Submitted,
            config_digest: cfg.structural_digest(),
            started_order: None,
            error: None,
            result: None,
            cfg,
            control,
            dir,
            records_path,
            part_path,
            ckpt_seq: 0,
        };
        let snap = Registry::entry_snapshot(&entry);
        g.runs.push(entry);
        drop(g);
        self.cv.notify_all();
        snap
    }

    /// Block until a queued run exists (claim it: Submitted → Running,
    /// stamped with the next `started_order`) or the registry shuts
    /// down (`None`). Claims are strictly in id order.
    pub fn claim_next(&self) -> Option<Job> {
        let mut g = self.lock();
        loop {
            if g.shutdown {
                return None;
            }
            if let Some(i) = g.runs.iter().position(|r| r.state == RunState::Submitted) {
                let order = g.next_started;
                g.next_started += 1;
                let e = &mut g.runs[i];
                Registry::transition(e, RunState::Running);
                e.started_order = Some(order);
                return Some(Job {
                    id: e.id,
                    cfg: e.cfg.clone(),
                    control: Arc::clone(&e.control),
                    records_path: e.records_path.clone(),
                    csv_path: format!("{}/{}.csv", e.dir, e.name),
                });
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Terminalize a claimed run with its outcome. `cancelled` wins
    /// over a clean result (a cancel that landed at a boundary still
    /// produces the truncated run's result).
    pub fn finish(&self, id: u64, outcome: Result<JsonValue, String>, cancelled: bool) {
        let mut g = self.lock();
        let Some(e) = g.runs.iter_mut().find(|r| r.id == id) else {
            return;
        };
        match outcome {
            Ok(result) => {
                e.result = Some(result);
                let to = if cancelled { RunState::Cancelled } else { RunState::Done };
                Registry::transition(e, to);
            }
            Err(msg) => {
                e.error = Some(msg);
                Registry::transition(e, RunState::Failed);
            }
        }
    }

    /// Snapshot one run.
    pub fn snapshot(&self, id: u64) -> Option<RunSnapshot> {
        let g = self.lock();
        g.runs.iter().find(|r| r.id == id).map(Registry::entry_snapshot)
    }

    /// Snapshot every run, in submission order.
    pub fn snapshots(&self) -> Vec<RunSnapshot> {
        let g = self.lock();
        g.runs.iter().map(Registry::entry_snapshot).collect()
    }

    fn mutable_entry<'g>(
        g: &'g mut MutexGuard<'_, RegistryInner>,
        id: u64,
    ) -> Result<&'g mut RunEntry, ApiError> {
        g.runs
            .iter_mut()
            .find(|r| r.id == id)
            .ok_or_else(|| ApiError::not_found(format!("unknown run id {id}")))
    }

    fn require_mutable(e: &RunEntry, what: &str) -> Result<(), ApiError> {
        if !e.state.accepts_mutation() {
            return Err(ApiError::invalid_state(format!(
                "run {} is {}; {what} is accepted only while running or paused",
                e.id,
                e.state.as_str()
            )));
        }
        Ok(())
    }

    /// Park the run at its next outer boundary (idempotent while
    /// paused). 404 on unknown id, 409 unless Running/Paused.
    pub fn request_pause(&self, id: u64) -> Result<RunSnapshot, ApiError> {
        let mut g = self.lock();
        let e = Registry::mutable_entry(&mut g, id)?;
        Registry::require_mutable(e, "pause")?;
        if e.state == RunState::Running {
            Registry::transition(e, RunState::Paused);
        }
        e.control.set_paused(true);
        Ok(Registry::entry_snapshot(e))
    }

    /// Release a paused run (idempotent while running). 404 on unknown
    /// id, 409 unless Running/Paused.
    pub fn request_resume(&self, id: u64) -> Result<RunSnapshot, ApiError> {
        let mut g = self.lock();
        let e = Registry::mutable_entry(&mut g, id)?;
        Registry::require_mutable(e, "resume")?;
        if e.state == RunState::Paused {
            Registry::transition(e, RunState::Running);
        }
        e.control.set_paused(false);
        Ok(Registry::entry_snapshot(e))
    }

    /// Request a stop at the run's next outer boundary. The state flips
    /// to Cancelled when the executor observes the honoured cancel. 404
    /// on unknown id, 409 unless Running/Paused.
    pub fn request_cancel(&self, id: u64) -> Result<RunSnapshot, ApiError> {
        let mut g = self.lock();
        let e = Registry::mutable_entry(&mut g, id)?;
        Registry::require_mutable(e, "cancel")?;
        e.control.request_cancel();
        Ok(Registry::entry_snapshot(e))
    }

    /// Request a v4 complete snapshot at the run's next outer boundary;
    /// returns the path it will be written to. 404 on unknown id, 409
    /// unless Running/Paused.
    pub fn request_checkpoint(&self, id: u64) -> Result<(RunSnapshot, String), ApiError> {
        let mut g = self.lock();
        let e = Registry::mutable_entry(&mut g, id)?;
        Registry::require_mutable(e, "checkpoint")?;
        let path = format!("{}/ckpt_{:03}.adlc", e.dir, e.ckpt_seq);
        e.ckpt_seq += 1;
        e.control.request_checkpoint(&path);
        Ok((Registry::entry_snapshot(e), path))
    }

    /// Per-state counts plus the grand total (`GET /runs` totals; the
    /// concurrency suite asserts conservation).
    pub fn totals(&self) -> Vec<(&'static str, usize)> {
        let g = self.lock();
        let mut out: Vec<(&'static str, usize)> = RunState::ALL
            .iter()
            .map(|s| (s.as_str(), g.runs.iter().filter(|r| r.state == *s).count()))
            .collect();
        out.push(("total", g.runs.len()));
        out
    }

    /// Stop claiming (executors drain and exit) and cancel every
    /// non-terminal run at its next boundary.
    pub fn shutdown(&self) {
        let mut g = self.lock();
        g.shutdown = true;
        for e in g.runs.iter() {
            if !e.state.is_terminal() {
                e.control.request_cancel();
                e.control.set_paused(false);
            }
        }
        drop(g);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn registry_submit_claim_finish_walks_the_state_machine() {
        let dir = std::env::temp_dir().join(format!("adloco_reg_{}", std::process::id()));
        let reg = Registry::new(dir.to_str().unwrap());
        let a = reg.submit(presets::quick());
        let b = reg.submit(presets::quick());
        assert_eq!((a.id, b.id), (0, 1));
        assert_eq!(a.state, RunState::Submitted);
        assert_eq!(a.progress.outer_steps_total, presets::quick().algo.outer_steps as u64);
        // mutations are rejected before the run starts
        let err = reg.request_cancel(0).unwrap_err();
        assert_eq!((err.status, err.code.as_str()), (409, "invalid_state"));
        assert_eq!(reg.request_pause(99).unwrap_err().status, 404);
        // claims are FIFO and stamped in order
        let j0 = reg.claim_next().unwrap();
        let j1 = reg.claim_next().unwrap();
        assert_eq!((j0.id, j1.id), (0, 1));
        assert_eq!(reg.snapshot(0).unwrap().started_order, Some(0));
        assert_eq!(reg.snapshot(1).unwrap().started_order, Some(1));
        // pause/resume flip the state; cancel leaves it for the executor
        assert_eq!(reg.request_pause(0).unwrap().state, RunState::Paused);
        assert_eq!(reg.request_resume(0).unwrap().state, RunState::Running);
        let snap = reg.request_cancel(0).unwrap();
        assert!(snap.cancel_requested);
        assert_eq!(snap.state, RunState::Running);
        reg.finish(0, Ok(JsonValue::Null), true);
        assert_eq!(reg.snapshot(0).unwrap().state, RunState::Cancelled);
        reg.finish(1, Err("boom".into()), false);
        let s1 = reg.snapshot(1).unwrap();
        assert_eq!(s1.state, RunState::Failed);
        assert_eq!(s1.error.as_deref(), Some("boom"));
        // terminal rows reject every mutation
        for id in [0u64, 1] {
            for res in [
                reg.request_pause(id),
                reg.request_resume(id),
                reg.request_cancel(id),
                reg.request_checkpoint(id).map(|(s, _)| s),
            ] {
                assert_eq!(res.unwrap_err().code, "invalid_state");
            }
        }
        let totals = reg.totals();
        let total = totals.iter().find(|(k, _)| *k == "total").unwrap().1;
        let by_state: usize =
            totals.iter().filter(|(k, _)| *k != "total").map(|(_, n)| n).sum();
        assert_eq!(total, 2);
        assert_eq!(by_state, total);
        // shutdown unblocks claimers
        reg.shutdown();
        assert!(reg.claim_next().is_none());
    }
}
