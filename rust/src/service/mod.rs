//! `adloco serve`: a long-lived daemon that accepts run submissions
//! over a hand-rolled HTTP/1.1 API and executes them on a bounded
//! executor pool (DESIGN.md §13).
//!
//! Layering, from the wire inward:
//!
//! - [`server`] — `std::net` listener, incremental request parser with
//!   typed rejects, router, executor pool.
//! - [`api`] — request/response schemas with strict
//!   deny-unknown-fields parsing and the [`ApiError`] envelope.
//! - [`state`] — the run [`Registry`]: FIFO queue, lifecycle state
//!   machine, and per-run steering handles.
//! - [`client`] — typed blocking [`Client`] used by the CLI and the
//!   black-box test suite.
//!
//! The determinism contract carries over unchanged: every steering
//! mutation (pause, checkpoint, cancel) lands at an outer-round
//! boundary through the coordinator's `BoundaryControl` hook, so a run
//! served over HTTP is bit-identical to the same config executed
//! one-shot via `run_experiment` — records, eval CSV, and all RunResult
//! fields except wall-clock.

pub mod api;
pub mod client;
pub mod server;
pub mod state;

pub use api::{ApiError, SubmitRequest};
pub use client::{Client, RecordsPage, RunSummary};
pub use server::{HttpLimits, Server};
pub use state::{transition_allowed, Registry, RunState};
