//! Typed in-crate client for the `adloco serve` API (DESIGN.md §13).
//!
//! One request per connection (`Connection: close`), blocking
//! `std::net` sockets, and the same [`ApiError`] envelope the server
//! emits: any non-2xx response is decoded back into a typed error, so
//! tests can assert exact `(status, code)` pairs through the client.

use super::api::{ApiError, SubmitRequest};
use super::state::RunState;
use crate::util::JsonValue;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// A run summary as returned by `GET /runs/{id}` and the mutation
/// endpoints.
#[derive(Clone, Debug)]
pub struct RunSummary {
    /// Registry id.
    pub id: u64,
    /// Run name.
    pub name: String,
    /// Lifecycle state.
    pub state: RunState,
    /// Structural config digest, zero-padded hex.
    pub config_digest: String,
    /// Outer boundaries completed so far.
    pub outer_steps_done: u64,
    /// Total outer steps in the schedule.
    pub outer_steps_total: u64,
    /// Live trainer instances at the last boundary.
    pub live_instances: u64,
    /// Simulated virtual time at the last boundary.
    pub virtual_time_s: f64,
    /// Samples consumed at the last boundary.
    pub total_samples: u64,
    /// Claim-order stamp once the run started.
    pub started_order: Option<u64>,
    /// Whether a cancel is pending or honoured.
    pub cancel_requested: bool,
    /// Service checkpoints written so far, as `(outer_step, path)`.
    pub checkpoints: Vec<(u64, String)>,
    /// Failure detail once Failed.
    pub error: Option<String>,
}

/// One page of `GET /runs/{id}/records?from=N`.
#[derive(Clone, Debug)]
pub struct RecordsPage {
    /// Echo of the requested cursor.
    pub from: usize,
    /// Cursor for the next page (== `from` when no new lines).
    pub next: usize,
    /// True once the run is terminal and `lines` come from the
    /// assembled canonical JSONL.
    pub complete: bool,
    /// `"live"` (part file) or `"final"` (assembled JSONL). Cursors are
    /// per-source: restart from 0 when this flips.
    pub source: String,
    /// Complete JSONL lines, newline stripped.
    pub lines: Vec<String>,
}

fn field<'v>(v: &'v JsonValue, key: &str) -> Result<&'v JsonValue> {
    v.get(key).with_context(|| format!("response is missing field {key:?}"))
}

fn field_u64(v: &JsonValue, key: &str) -> Result<u64> {
    Ok(field(v, key)?
        .as_f64()
        .with_context(|| format!("field {key:?} is not a number"))? as u64)
}

fn field_str(v: &JsonValue, key: &str) -> Result<String> {
    Ok(field(v, key)?
        .as_str()
        .with_context(|| format!("field {key:?} is not a string"))?
        .to_string())
}

fn parse_summary(v: &JsonValue) -> Result<RunSummary> {
    let state_str = field_str(v, "state")?;
    let state = RunState::parse(&state_str)
        .with_context(|| format!("unknown run state {state_str:?}"))?;
    let checkpoints = match v.get("checkpoints").and_then(|c| c.as_array()) {
        Some(items) => items
            .iter()
            .map(|c| Ok((field_u64(c, "outer_step")?, field_str(c, "path")?)))
            .collect::<Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    Ok(RunSummary {
        id: field_u64(v, "id")?,
        name: field_str(v, "name")?,
        state,
        config_digest: field_str(v, "config_digest")?,
        outer_steps_done: field_u64(v, "outer_steps_done")?,
        outer_steps_total: field_u64(v, "outer_steps_total")?,
        live_instances: field_u64(v, "live_instances")?,
        virtual_time_s: field(v, "virtual_time_s")?
            .as_f64()
            .context("field \"virtual_time_s\" is not a number")?,
        total_samples: field_u64(v, "total_samples")?,
        started_order: v.get("started_order").and_then(|o| o.as_f64()).map(|o| o as u64),
        cancel_requested: field(v, "cancel_requested")?
            .as_bool()
            .context("field \"cancel_requested\" is not a bool")?,
        checkpoints,
        error: v.get("error").and_then(|e| e.as_str()).map(str::to_string),
    })
}

/// Blocking HTTP client bound to one server address.
pub struct Client {
    addr: SocketAddr,
    timeout: Duration,
}

impl Client {
    /// Client for `addr` with a 10 s per-request timeout.
    pub fn new(addr: SocketAddr) -> Client {
        Client { addr, timeout: Duration::from_secs(10) }
    }

    /// Raw request: returns `(status, parsed body)` without mapping
    /// error statuses (negative-path tests assert on these directly).
    pub fn request(
        &self,
        method: &str,
        path: &str,
        body: Option<&JsonValue>,
    ) -> Result<(u16, JsonValue)> {
        let payload = body.map(|b| b.to_string()).unwrap_or_default();
        let mut stream = TcpStream::connect_timeout(&self.addr, self.timeout)
            .with_context(|| format!("connect to {}", self.addr))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: {}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
            self.addr,
            payload.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(payload.as_bytes())?;
        stream.flush()?;
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw)?;
        parse_response(&raw)
    }

    /// Raw request with non-2xx mapped to a typed [`ApiError`]
    /// (downcastable from the returned `anyhow::Error`).
    fn call(&self, method: &str, path: &str, body: Option<&JsonValue>) -> Result<JsonValue> {
        let (status, v) = self.request(method, path, body)?;
        if !(200..300).contains(&status) {
            return Err(ApiError::from_wire(status, &v).into());
        }
        Ok(v)
    }

    /// `GET /health`.
    pub fn health(&self) -> Result<bool> {
        let v = self.call("GET", "/health", None)?;
        Ok(v.get("ok").and_then(|b| b.as_bool()).unwrap_or(false))
    }

    /// `GET /version`.
    pub fn version(&self) -> Result<JsonValue> {
        self.call("GET", "/version", None)
    }

    /// `POST /runs`.
    pub fn submit(&self, req: &SubmitRequest) -> Result<RunSummary> {
        parse_summary(&self.call("POST", "/runs", Some(&req.to_json()))?)
    }

    /// `GET /runs`: every run plus the per-state totals object.
    pub fn runs(&self) -> Result<(Vec<RunSummary>, JsonValue)> {
        let v = self.call("GET", "/runs", None)?;
        let runs = field(&v, "runs")?
            .as_array()
            .context("field \"runs\" is not an array")?
            .iter()
            .map(parse_summary)
            .collect::<Result<Vec<_>>>()?;
        Ok((runs, field(&v, "totals")?.clone()))
    }

    /// `GET /runs/{id}`.
    pub fn run(&self, id: u64) -> Result<RunSummary> {
        parse_summary(&self.call("GET", &format!("/runs/{id}"), None)?)
    }

    /// `GET /runs/{id}/records?from=N`.
    pub fn records(&self, id: u64, from: usize) -> Result<RecordsPage> {
        let v = self.call("GET", &format!("/runs/{id}/records?from={from}"), None)?;
        Ok(RecordsPage {
            from: field_u64(&v, "from")? as usize,
            next: field_u64(&v, "next")? as usize,
            complete: field(&v, "complete")?
                .as_bool()
                .context("field \"complete\" is not a bool")?,
            source: field_str(&v, "source")?,
            lines: field(&v, "lines")?
                .as_array()
                .context("field \"lines\" is not an array")?
                .iter()
                .map(|l| {
                    l.as_str()
                        .map(str::to_string)
                        .context("records line is not a string")
                })
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// `GET /runs/{id}/result` (409 until terminal).
    pub fn result(&self, id: u64) -> Result<JsonValue> {
        self.call("GET", &format!("/runs/{id}/result"), None)
    }

    /// `POST /runs/{id}/pause`.
    pub fn pause(&self, id: u64) -> Result<RunSummary> {
        parse_summary(&self.call("POST", &format!("/runs/{id}/pause"), None)?)
    }

    /// `POST /runs/{id}/resume`.
    pub fn resume(&self, id: u64) -> Result<RunSummary> {
        parse_summary(&self.call("POST", &format!("/runs/{id}/resume"), None)?)
    }

    /// `POST /runs/{id}/cancel`.
    pub fn cancel(&self, id: u64) -> Result<RunSummary> {
        parse_summary(&self.call("POST", &format!("/runs/{id}/cancel"), None)?)
    }

    /// `POST /runs/{id}/checkpoint`: returns the path the v4 snapshot
    /// will be written to at the run's next outer boundary.
    pub fn checkpoint(&self, id: u64) -> Result<String> {
        let v = self.call("POST", &format!("/runs/{id}/checkpoint"), None)?;
        field_str(&v, "path")
    }

    /// Poll `GET /runs/{id}` until the run is terminal (10 ms cadence),
    /// returning the final summary.
    pub fn wait_terminal(&self, id: u64, timeout: Duration) -> Result<RunSummary> {
        let start = Instant::now();
        loop {
            let summary = self.run(id)?;
            if summary.state.is_terminal() {
                return Ok(summary);
            }
            if start.elapsed() > timeout {
                bail!(
                    "run {id} still {} after {timeout:?}",
                    summary.state.as_str()
                );
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

fn parse_response(raw: &[u8]) -> Result<(u16, JsonValue)> {
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .context("response has no header terminator")?;
    let head = std::str::from_utf8(&raw[..head_end]).context("response head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.splitn(3, ' ');
    let proto = parts.next().unwrap_or("");
    if !proto.starts_with("HTTP/1.") {
        bail!("malformed status line {status_line:?}");
    }
    let status: u16 = parts
        .next()
        .unwrap_or("")
        .parse()
        .with_context(|| format!("malformed status line {status_line:?}"))?;
    let mut content_length = None;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = Some(
                    value
                        .trim()
                        .parse::<usize>()
                        .with_context(|| format!("bad content-length {value:?}"))?,
                );
            }
        }
    }
    let body_start = head_end + 4;
    let body = match content_length {
        Some(n) => {
            if raw.len() < body_start + n {
                bail!("response body truncated: have {}, need {n}", raw.len() - body_start);
            }
            &raw[body_start..body_start + n]
        }
        None => &raw[body_start..],
    };
    if body.is_empty() {
        return Ok((status, JsonValue::Null));
    }
    let text = std::str::from_utf8(body).context("response body is not UTF-8")?;
    let v = JsonValue::parse(text).with_context(|| format!("response body is not JSON: {text:?}"))?;
    Ok((status, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_parser_reads_the_servers_own_wire_format() {
        let body = JsonValue::obj(vec![("ok", JsonValue::Bool(true))]);
        let raw = super::super::server::write_response(200, &body);
        let (status, v) = parse_response(&raw).unwrap();
        assert_eq!(status, 200);
        assert_eq!(v, body);
        let err = ApiError::not_found("nope");
        let raw = super::super::server::write_response(err.status, &err.to_json());
        let (status, v) = parse_response(&raw).unwrap();
        let round = ApiError::from_wire(status, &v);
        assert_eq!((round.status, round.code.as_str()), (404, "not_found"));
        assert_eq!(round.message, "nope");
    }

    #[test]
    fn summary_parser_rejects_missing_fields_with_context() {
        let v = JsonValue::obj(vec![("id", JsonValue::num(1.0))]);
        let err = parse_summary(&v).unwrap_err();
        assert!(format!("{err:#}").contains("state"), "got: {err:#}");
    }
}
