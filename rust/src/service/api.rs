//! Typed request/response schemas for the control plane (DESIGN.md §13).
//!
//! Every request body is parsed with the same strict deny-unknown-fields
//! discipline as the v4 checkpoint interchange (`StrictObj`, DESIGN.md
//! §10): each field is consumed exactly once and leftovers — which
//! include duplicate keys — are typed rejects, never silent ignores.
//! Every error the service can produce is an [`ApiError`]: an HTTP
//! status, a stable machine-readable code the tests pin, and a human
//! message. There are no untyped error paths.

use crate::config::{presets, Config};
use crate::coordinator::RunResult;
use crate::util::JsonValue;

/// A typed control-plane error: the HTTP status the response carries, a
/// stable machine code (`tests/service_api.rs` pins these), and a human
/// message. Serialized on the wire as
/// `{"error":{"code":"...","message":"..."}}`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ApiError {
    /// HTTP status code of the response.
    pub status: u16,
    /// Stable machine-readable code.
    pub code: String,
    /// Human-readable detail.
    pub message: String,
}

impl ApiError {
    /// Build from parts.
    pub fn new(status: u16, code: &str, message: impl Into<String>) -> ApiError {
        ApiError { status, code: code.to_string(), message: message.into() }
    }

    /// 400 `bad_request`: malformed HTTP surface (request line, header
    /// syntax, content-length).
    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(400, "bad_request", message)
    }

    /// 400 `invalid_json`: the body failed to parse, had trailing
    /// garbage, or a field had the wrong JSON type.
    pub fn invalid_json(message: impl Into<String>) -> ApiError {
        ApiError::new(400, "invalid_json", message)
    }

    /// 400 `unknown_field`: a body object carried a field the schema
    /// does not define (or a duplicate key).
    pub fn unknown_field(path: &str) -> ApiError {
        ApiError::new(400, "unknown_field", format!("unknown field {path}"))
    }

    /// 400 `missing_field`: a required field (or field group) is absent.
    pub fn missing_field(path: &str) -> ApiError {
        ApiError::new(400, "missing_field", format!("missing field {path}"))
    }

    /// 400 `unknown_preset`: `submit.preset` names no known preset.
    pub fn unknown_preset(name: &str) -> ApiError {
        ApiError::new(400, "unknown_preset", format!("unknown preset {name:?}"))
    }

    /// 400 `invalid_config`: the resolved config failed the same
    /// validation the CLI applies (the message is `Config::validate`'s).
    pub fn invalid_config(message: impl Into<String>) -> ApiError {
        ApiError::new(400, "invalid_config", message)
    }

    /// 400 `bad_query`: malformed or unknown query parameter.
    pub fn bad_query(message: impl Into<String>) -> ApiError {
        ApiError::new(400, "bad_query", message)
    }

    /// 404 `not_found`: unknown endpoint path or run id.
    pub fn not_found(message: impl Into<String>) -> ApiError {
        ApiError::new(404, "not_found", message)
    }

    /// 405 `method_not_allowed`: known path, wrong method.
    pub fn method_not_allowed(method: &str, path: &str) -> ApiError {
        ApiError::new(405, "method_not_allowed", format!("{method} not allowed on {path}"))
    }

    /// 409 `invalid_state`: the run's lifecycle state rejects the
    /// operation (e.g. cancel on a terminal run).
    pub fn invalid_state(message: impl Into<String>) -> ApiError {
        ApiError::new(409, "invalid_state", message)
    }

    /// 413 `payload_too_large`: body beyond `service.max_body_bytes`.
    pub fn payload_too_large(limit: usize) -> ApiError {
        ApiError::new(413, "payload_too_large", format!("body exceeds {limit} bytes"))
    }

    /// 431 `header_too_large`: head beyond `service.max_header_bytes`.
    pub fn header_too_large(limit: usize) -> ApiError {
        ApiError::new(431, "header_too_large", format!("request head exceeds {limit} bytes"))
    }

    /// 501 `unsupported`: a protocol feature the daemon deliberately
    /// does not implement (chunked transfer-encoding).
    pub fn unsupported(message: impl Into<String>) -> ApiError {
        ApiError::new(501, "unsupported", message)
    }

    /// 500 `internal`: an I/O failure while serving (not a client bug).
    pub fn internal(message: impl Into<String>) -> ApiError {
        ApiError::new(500, "internal", message)
    }

    /// The wire body.
    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj(vec![(
            "error",
            JsonValue::obj(vec![
                ("code", JsonValue::str(self.code.clone())),
                ("message", JsonValue::str(self.message.clone())),
            ]),
        )])
    }

    /// Parse a wire error back into a typed one (client side). A body
    /// that does not carry the error envelope still yields a usable
    /// `ApiError` with code `unknown`.
    pub fn from_wire(status: u16, body: &JsonValue) -> ApiError {
        let err = body.get("error");
        let code = err
            .and_then(|e| e.get("code"))
            .and_then(|c| c.as_str())
            .unwrap_or("unknown")
            .to_string();
        let message = err
            .and_then(|e| e.get("message"))
            .and_then(|m| m.as_str())
            .unwrap_or("(no message)")
            .to_string();
        ApiError { status, code, message }
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}: {}", self.status, self.code, self.message)
    }
}

impl std::error::Error for ApiError {}

/// Deny-unknown-fields JSON object reader: the v4 interchange's
/// `StrictObj` consumption-tracking discipline (DESIGN.md §10) rebased
/// onto [`ApiError`]. Every field must be consumed exactly once;
/// `finish` rejects leftovers, which also catches duplicate keys.
pub struct StrictBody<'a> {
    fields: &'a [(String, JsonValue)],
    taken: Vec<bool>,
    what: &'static str,
}

impl<'a> StrictBody<'a> {
    /// Wrap `v`, which must be a JSON object.
    pub fn new(v: &'a JsonValue, what: &'static str) -> Result<StrictBody<'a>, ApiError> {
        match v {
            JsonValue::Object(fields) => {
                Ok(StrictBody { fields, taken: vec![false; fields.len()], what })
            }
            _ => Err(ApiError::invalid_json(format!("{what} must be a JSON object"))),
        }
    }

    /// Consume an optional field (first unconsumed occurrence).
    pub fn take_opt(&mut self, key: &str) -> Option<&'a JsonValue> {
        for (i, (k, val)) in self.fields.iter().enumerate() {
            if k == key && !self.taken[i] {
                self.taken[i] = true;
                return Some(val);
            }
        }
        None
    }

    /// Every field must have been consumed; a leftover (unknown or
    /// duplicate key) is a typed reject.
    pub fn finish(self) -> Result<(), ApiError> {
        for (i, (k, _)) in self.fields.iter().enumerate() {
            if !self.taken[i] {
                return Err(ApiError::unknown_field(&format!("{}.{}", self.what, k)));
            }
        }
        Ok(())
    }
}

/// A validated `POST /runs` body: a preset name and/or a config overlay
/// object (the config-file format), optional dotted-path overrides
/// applied last, and an optional run-name override.
#[derive(Clone, Debug, Default)]
pub struct SubmitRequest {
    /// Preset base ([`presets::by_name`]); defaults to `mock_default`
    /// when only `config` is given.
    pub preset: Option<String>,
    /// Config overlay applied on the base via [`Config::apply_overlay`].
    pub config: Option<JsonValue>,
    /// `("dotted.path", value)` overrides applied after the overlay, in
    /// object order — the HTTP twin of the CLI's `--set`.
    pub overrides: Vec<(String, JsonValue)>,
    /// Run-name override (output file naming inside the run directory).
    pub name: Option<String>,
}

impl SubmitRequest {
    /// Preset-only shorthand.
    pub fn preset(name: &str) -> SubmitRequest {
        SubmitRequest { preset: Some(name.to_string()), ..SubmitRequest::default() }
    }

    /// Append one dotted-path override (builder style).
    pub fn with_override(mut self, path: &str, value: JsonValue) -> SubmitRequest {
        self.overrides.push((path.to_string(), value));
        self
    }

    /// Strict parse: deny unknown fields, typed errors throughout.
    pub fn parse(v: &JsonValue) -> Result<SubmitRequest, ApiError> {
        let mut b = StrictBody::new(v, "submit")?;
        let mut req = SubmitRequest::default();
        if let Some(p) = b.take_opt("preset") {
            match p.as_str() {
                Some(s) => req.preset = Some(s.to_string()),
                None => return Err(ApiError::invalid_json("submit.preset must be a string")),
            }
        }
        if let Some(c) = b.take_opt("config") {
            if c.as_object().is_none() {
                return Err(ApiError::invalid_json("submit.config must be an object"));
            }
            req.config = Some(c.clone());
        }
        if let Some(o) = b.take_opt("overrides") {
            match o.as_object() {
                Some(fields) => {
                    for (k, val) in fields {
                        req.overrides.push((k.clone(), val.clone()));
                    }
                }
                None => {
                    return Err(ApiError::invalid_json("submit.overrides must be an object"))
                }
            }
        }
        if let Some(n) = b.take_opt("name") {
            match n.as_str() {
                Some(s) => req.name = Some(s.to_string()),
                None => return Err(ApiError::invalid_json("submit.name must be a string")),
            }
        }
        if req.preset.is_none() && req.config.is_none() {
            return Err(ApiError::missing_field("submit.preset (or submit.config)"));
        }
        b.finish()?;
        Ok(req)
    }

    /// The wire form (client side).
    pub fn to_json(&self) -> JsonValue {
        let mut fields: Vec<(String, JsonValue)> = Vec::new();
        if let Some(p) = &self.preset {
            fields.push(("preset".to_string(), JsonValue::str(p.clone())));
        }
        if let Some(c) = &self.config {
            fields.push(("config".to_string(), c.clone()));
        }
        if !self.overrides.is_empty() {
            fields.push(("overrides".to_string(), JsonValue::Object(self.overrides.clone())));
        }
        if let Some(n) = &self.name {
            fields.push(("name".to_string(), JsonValue::str(n.clone())));
        }
        JsonValue::Object(fields)
    }

    /// Resolve to a validated [`Config`], surfacing the same typed
    /// messages as the CLI path (`Config::load` + `--set` + `validate`).
    pub fn resolve(&self) -> Result<Config, ApiError> {
        let mut cfg = match &self.preset {
            Some(name) => {
                presets::by_name(name).ok_or_else(|| ApiError::unknown_preset(name))?
            }
            None => presets::mock_default(),
        };
        if let Some(overlay) = &self.config {
            cfg.apply_overlay(overlay)
                .map_err(|e| ApiError::invalid_config(format!("{e:#}")))?;
        }
        for (path, value) in &self.overrides {
            // route dotted paths through the overlay machinery exactly
            // like the CLI's --set: nested one-key objects
            let mut leaf = value.clone();
            for key in path.split('.').rev() {
                leaf = JsonValue::Object(vec![(key.to_string(), leaf)]);
            }
            cfg.apply_overlay(&leaf)
                .map_err(|e| ApiError::invalid_config(format!("override {path}: {e:#}")))?;
        }
        if let Some(name) = &self.name {
            cfg.name = name.clone();
        }
        cfg.validate().map_err(|e| ApiError::invalid_config(format!("{e:#}")))?;
        Ok(cfg)
    }
}

/// `GET /version` body: crate version, the newest checkpoint
/// interchange format this build writes, and a capability flag for
/// config structural digests (DESIGN.md §10).
pub fn version_json() -> JsonValue {
    JsonValue::obj(vec![
        ("version", JsonValue::str(env!("CARGO_PKG_VERSION"))),
        ("checkpoint_format", JsonValue::num(crate::checkpoint::VERSION as f64)),
        ("config_digest", JsonValue::Bool(true)),
    ])
}

/// The full [`RunResult`] as a JSON object: every determinism-contract
/// field plus the two excluded ones (`wall_clock_s`, `threads` —
/// DESIGN.md §6). Comparing two results under the contract means
/// dropping those two keys first; the bit-identity suite does exactly
/// that.
pub fn run_result_json(r: &RunResult) -> JsonValue {
    let mut fields = vec![
        ("name", JsonValue::str(r.name.clone())),
        ("method", JsonValue::str(r.method.as_str())),
        ("best_ppl", JsonValue::num(r.best_ppl)),
        ("final_ppl", JsonValue::num(r.final_ppl)),
        ("total_inner_steps", JsonValue::num(r.total_inner_steps as f64)),
        ("total_samples", JsonValue::num(r.total_samples as f64)),
        ("comm_count", JsonValue::num(r.comm_count as f64)),
        ("comm_bytes", JsonValue::num(r.comm_bytes as f64)),
        ("wan_comm_bytes", JsonValue::num(r.wan_comm_bytes as f64)),
        ("virtual_time_s", JsonValue::num(r.virtual_time_s)),
        ("trainers_left", JsonValue::num(r.trainers_left as f64)),
        ("total_idle_s", JsonValue::num(r.total_idle_s)),
        ("mean_utilization", JsonValue::num(r.mean_utilization)),
        ("overlap_hidden_s", JsonValue::num(r.overlap_hidden_s)),
        ("spawn_count", JsonValue::num(r.spawn_count as f64)),
        ("mean_live_instances", JsonValue::num(r.mean_live_instances)),
        ("total_vacant_s", JsonValue::num(r.total_vacant_s)),
        ("wall_clock_s", JsonValue::num(r.wall_clock_s)),
        ("threads", JsonValue::num(r.threads as f64)),
    ];
    if let Some((step, time_s, comms)) = r.time_to_target {
        fields.push((
            "time_to_target",
            JsonValue::obj(vec![
                ("global_step", JsonValue::num(step as f64)),
                ("virtual_time_s", JsonValue::num(time_s)),
                ("comm_count", JsonValue::num(comms as f64)),
            ]),
        ));
    }
    JsonValue::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_body(text: &str) -> Result<SubmitRequest, ApiError> {
        SubmitRequest::parse(&JsonValue::parse(text).unwrap())
    }

    #[test]
    fn submit_parse_is_strict() {
        let req = parse_body(r#"{"preset":"quick"}"#).unwrap();
        assert_eq!(req.preset.as_deref(), Some("quick"));
        let err = parse_body(r#"{"preset":"quick","bogus":1}"#).unwrap_err();
        assert_eq!((err.status, err.code.as_str()), (400, "unknown_field"));
        assert!(err.message.contains("submit.bogus"), "{}", err.message);
        let err = parse_body(r#"{}"#).unwrap_err();
        assert_eq!((err.status, err.code.as_str()), (400, "missing_field"));
        let err = parse_body(r#"{"preset":1}"#).unwrap_err();
        assert_eq!((err.status, err.code.as_str()), (400, "invalid_json"));
        let err = SubmitRequest::parse(&JsonValue::num(3.0)).unwrap_err();
        assert_eq!(err.code, "invalid_json");
    }

    #[test]
    fn submit_resolve_matches_cli_semantics() {
        let req = SubmitRequest::preset("quick")
            .with_override("algo.outer_steps", JsonValue::num(2.0))
            .with_override("run.threads", JsonValue::num(4.0));
        let cfg = req.resolve().unwrap();
        assert_eq!(cfg.algo.outer_steps, 2);
        assert_eq!(cfg.run.threads, 4);
        let mut cli = presets::by_name("quick").unwrap();
        cli.apply_override("algo.outer_steps=2").unwrap();
        cli.apply_override("run.threads=4").unwrap();
        assert_eq!(cfg.structural_digest(), cli.structural_digest());

        let err = SubmitRequest::preset("nope").resolve().unwrap_err();
        assert_eq!((err.status, err.code.as_str()), (400, "unknown_preset"));
        // invalid configs fail with the CLI's own validate message
        let err = SubmitRequest::preset("quick")
            .with_override("algo.num_trainers", JsonValue::num(0.0))
            .resolve()
            .unwrap_err();
        assert_eq!((err.status, err.code.as_str()), (400, "invalid_config"));
        assert!(err.message.contains("num_trainers"), "{}", err.message);
    }

    #[test]
    fn submit_roundtrips_through_the_wire_form() {
        let req = SubmitRequest::preset("hetero_dynamic")
            .with_override("run.threads", JsonValue::num(1.0));
        let back = SubmitRequest::parse(&req.to_json()).unwrap();
        assert_eq!(back.preset.as_deref(), Some("hetero_dynamic"));
        assert_eq!(back.overrides.len(), 1);
        assert_eq!(
            back.resolve().unwrap().structural_digest(),
            req.resolve().unwrap().structural_digest()
        );
    }

    #[test]
    fn error_envelope_roundtrips() {
        let e = ApiError::invalid_state("run 3 is done");
        let back = ApiError::from_wire(e.status, &e.to_json());
        assert_eq!(back, e);
        assert_eq!(version_json().get("config_digest"), Some(&JsonValue::Bool(true)));
    }
}
