//! Hand-rolled HTTP/1.1 front end over `std::net` (DESIGN.md §13).
//!
//! The wire layer is deliberately tiny: one request per connection
//! (`Connection: close`), bodies bounded by `service.max_body_bytes`,
//! heads by `service.max_header_bytes`, and every malformed input maps
//! to a typed [`ApiError`] — the parser ([`parse_request`]) is a pure
//! function over a byte prefix so the property suite can truncate and
//! mutate it at every boundary without sockets.
//!
//! Execution happens on a fixed pool of `service.max_concurrent_runs`
//! executor threads draining the [`Registry`] FIFO; the accept loop
//! only parses, routes, and answers, so steering endpoints stay
//! responsive while runs execute.

use super::api::{self, ApiError, SubmitRequest};
use super::state::{Registry, RunSnapshot};
use crate::config::ServiceConfig;
use crate::coordinator::Coordinator;
use crate::util::JsonValue;
use anyhow::Result;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Parser bounds, lifted from [`ServiceConfig`] so the pure parser can
/// be exercised without a full config.
#[derive(Clone, Copy, Debug)]
pub struct HttpLimits {
    /// Maximum bytes for the request line + headers (431 beyond).
    pub max_header_bytes: usize,
    /// Maximum bytes for the body (413 beyond).
    pub max_body_bytes: usize,
}

/// A parsed request: method, split target, lowercased header names, and
/// the exact body bytes.
#[derive(Clone, Debug)]
pub struct Request {
    /// Uppercase method token (e.g. `GET`).
    pub method: String,
    /// Target path, query stripped.
    pub path: String,
    /// Raw query string after `?`, if any.
    pub query: Option<String>,
    /// Headers in arrival order; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    /// Body bytes (exactly `Content-Length` of them).
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a (lowercase) header name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }
}

fn head_limit(limits: &HttpLimits) -> usize {
    // the terminator itself is allowed past the cap
    limits.max_header_bytes + 4
}

/// Incremental HTTP/1.1 request parser over a received byte prefix.
///
/// Returns `Ok(None)` while the prefix is incomplete (more bytes may
/// still arrive), `Ok(Some((req, consumed)))` once a full request is
/// present, and a typed [`ApiError`] the moment the prefix is already
/// unsalvageable (bad request line, oversized head or body, unsupported
/// transfer encoding). A strict prefix of a valid request NEVER parses
/// as complete — the property suite enumerates this.
pub fn parse_request(
    buf: &[u8],
    limits: &HttpLimits,
) -> Result<Option<(Request, usize)>, ApiError> {
    let head_end = buf.windows(4).position(|w| w == b"\r\n\r\n");
    let Some(head_end) = head_end else {
        if buf.len() > head_limit(limits) {
            return Err(ApiError::header_too_large(limits.max_header_bytes));
        }
        return Ok(None);
    };
    if head_end > limits.max_header_bytes {
        return Err(ApiError::header_too_large(limits.max_header_bytes));
    }
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| ApiError::bad_request("request head is not valid UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let parts: Vec<&str> = request_line.split(' ').collect();
    if parts.len() != 3 {
        return Err(ApiError::bad_request(format!(
            "malformed request line {request_line:?}"
        )));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(ApiError::bad_request(format!("malformed method token {method:?}")));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(ApiError::bad_request(format!(
            "unsupported protocol version {version:?}"
        )));
    }
    if !target.starts_with('/') {
        return Err(ApiError::bad_request(format!("request target {target:?} must be absolute")));
    }
    let mut headers = Vec::new();
    let mut content_length: Option<usize> = None;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ApiError::bad_request(format!("malformed header line {line:?}")));
        };
        if name.is_empty() || name.contains(' ') {
            return Err(ApiError::bad_request(format!("malformed header name {name:?}")));
        }
        let name = name.to_ascii_lowercase();
        let value = value.trim().to_string();
        if name == "transfer-encoding" {
            return Err(ApiError::unsupported(
                "transfer-encoding is not supported; send Content-Length",
            ));
        }
        if name == "content-length" {
            let n: usize = value
                .parse()
                .map_err(|_| ApiError::bad_request(format!("bad content-length {value:?}")))?;
            if let Some(prev) = content_length {
                if prev != n {
                    return Err(ApiError::bad_request("conflicting content-length headers"));
                }
            }
            content_length = Some(n);
        }
        headers.push((name, value));
    }
    let body_len = content_length.unwrap_or(0);
    if body_len > limits.max_body_bytes {
        return Err(ApiError::payload_too_large(limits.max_body_bytes));
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + body_len {
        return Ok(None);
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    Ok(Some((
        Request {
            method: method.to_string(),
            path,
            query,
            headers,
            body: buf[body_start..body_start + body_len].to_vec(),
        },
        body_start + body_len,
    )))
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        _ => "Unknown",
    }
}

/// Serialize one JSON response with the fixed header set the in-crate
/// client expects (`Connection: close`, exact `Content-Length`).
pub fn write_response(status: u16, body: &JsonValue) -> Vec<u8> {
    let payload = body.to_string();
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        reason_phrase(status),
        payload.len()
    );
    let mut out = head.into_bytes();
    out.extend_from_slice(payload.as_bytes());
    out
}

fn run_summary_json(s: &RunSnapshot) -> JsonValue {
    let mut fields = vec![
        ("id", JsonValue::num(s.id as f64)),
        ("name", JsonValue::str(s.name.clone())),
        ("state", JsonValue::str(s.state.as_str())),
        ("config_digest", JsonValue::str(format!("{:016x}", s.config_digest))),
        ("outer_steps_done", JsonValue::num(s.progress.outer_steps_done as f64)),
        ("outer_steps_total", JsonValue::num(s.progress.outer_steps_total as f64)),
        ("live_instances", JsonValue::num(s.progress.live_instances as f64)),
        ("virtual_time_s", JsonValue::num(s.progress.virtual_time_s)),
        ("total_samples", JsonValue::num(s.progress.total_samples as f64)),
        ("cancel_requested", JsonValue::Bool(s.cancel_requested)),
        (
            "checkpoints",
            JsonValue::Array(
                s.checkpoints
                    .iter()
                    .map(|(step, path)| {
                        JsonValue::obj(vec![
                            ("outer_step", JsonValue::num(*step as f64)),
                            ("path", JsonValue::str(path.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some(order) = s.started_order {
        fields.push(("started_order", JsonValue::num(order as f64)));
    }
    if let Some(err) = &s.error {
        fields.push(("error", JsonValue::str(err.clone())));
    }
    JsonValue::obj(fields)
}

fn no_query(req: &Request) -> Result<(), ApiError> {
    match &req.query {
        Some(q) => Err(ApiError::bad_query(format!("unexpected query string {q:?}"))),
        None => Ok(()),
    }
}

fn no_body(req: &Request) -> Result<(), ApiError> {
    if req.body.is_empty() {
        Ok(())
    } else {
        Err(ApiError::invalid_json("this endpoint takes no request body"))
    }
}

fn parse_id(seg: &str) -> Result<u64, ApiError> {
    if seg.is_empty() || !seg.bytes().all(|b| b.is_ascii_digit()) {
        return Err(ApiError::not_found(format!("unknown run id {seg:?}")));
    }
    seg.parse().map_err(|_| ApiError::not_found(format!("unknown run id {seg:?}")))
}

fn parse_from_query(req: &Request) -> Result<usize, ApiError> {
    let Some(q) = &req.query else {
        return Ok(0);
    };
    let mut from = 0usize;
    for pair in q.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        if k != "from" {
            return Err(ApiError::bad_query(format!("unknown query key {k:?}")));
        }
        from = v
            .parse()
            .map_err(|_| ApiError::bad_query(format!("bad from value {v:?}")))?;
    }
    Ok(from)
}

fn snapshot_or_404(reg: &Registry, id: u64) -> Result<RunSnapshot, ApiError> {
    reg.snapshot(id).ok_or_else(|| ApiError::not_found(format!("unknown run id {id}")))
}

fn body_json(req: &Request) -> Result<JsonValue, ApiError> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| ApiError::invalid_json("body is not valid UTF-8"))?;
    JsonValue::parse(text).map_err(|e| ApiError::invalid_json(format!("{e}")))
}

fn records_json(reg: &Registry, id: u64, from: usize) -> Result<(u16, JsonValue), ApiError> {
    let snap = snapshot_or_404(reg, id)?;
    // live reads page the streamer's part file; once terminal the
    // assembled canonical JSONL is the source. Cursors are per-source:
    // when `source` flips to "final", re-fetch from 0.
    let (source, path, complete) = if snap.state.is_terminal() {
        ("final", snap.records_path.clone(), true)
    } else {
        ("live", snap.part_path.clone(), false)
    };
    let (lines, next) = crate::metrics::read_jsonl_lines_from(&path, from)
        .map_err(|e| ApiError::internal(format!("records read failed: {e:#}")))?;
    Ok((
        200,
        JsonValue::obj(vec![
            ("id", JsonValue::num(id as f64)),
            ("from", JsonValue::num(from as f64)),
            ("next", JsonValue::num(next as f64)),
            ("complete", JsonValue::Bool(complete)),
            ("source", JsonValue::str(source)),
            (
                "lines",
                JsonValue::Array(lines.into_iter().map(JsonValue::str).collect()),
            ),
        ]),
    ))
}

/// Route one parsed request against the registry. Pure with respect to
/// the socket: returns `(status, body)` and never panics on untrusted
/// input.
pub fn route(req: &Request, reg: &Registry) -> (u16, JsonValue) {
    match route_inner(req, reg) {
        Ok((status, body)) => (status, body),
        Err(e) => (e.status, e.to_json()),
    }
}

fn route_inner(req: &Request, reg: &Registry) -> Result<(u16, JsonValue), ApiError> {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let method = req.method.as_str();
    match segs.as_slice() {
        ["health"] => {
            require_method(req, "GET")?;
            no_query(req)?;
            Ok((200, JsonValue::obj(vec![("ok", JsonValue::Bool(true))])))
        }
        ["version"] => {
            require_method(req, "GET")?;
            no_query(req)?;
            Ok((200, api::version_json()))
        }
        ["runs"] => match method {
            "GET" => {
                no_query(req)?;
                let runs = reg.snapshots().iter().map(run_summary_json).collect();
                let totals = reg
                    .totals()
                    .into_iter()
                    .map(|(k, n)| (k, JsonValue::num(n as f64)))
                    .collect();
                Ok((
                    200,
                    JsonValue::obj(vec![
                        ("runs", JsonValue::Array(runs)),
                        ("totals", JsonValue::obj(totals)),
                    ]),
                ))
            }
            "POST" => {
                no_query(req)?;
                let v = body_json(req)?;
                let submit = SubmitRequest::parse(&v)?;
                let cfg = submit.resolve()?;
                let snap = reg.submit(cfg);
                Ok((201, run_summary_json(&snap)))
            }
            _ => Err(ApiError::method_not_allowed(method, &req.path)),
        },
        ["runs", id] => {
            require_method(req, "GET")?;
            no_query(req)?;
            let snap = snapshot_or_404(reg, parse_id(id)?)?;
            Ok((200, run_summary_json(&snap)))
        }
        ["runs", id, "records"] => {
            require_method(req, "GET")?;
            let id = parse_id(id)?;
            let from = parse_from_query(req)?;
            records_json(reg, id, from)
        }
        ["runs", id, "result"] => {
            require_method(req, "GET")?;
            no_query(req)?;
            let snap = snapshot_or_404(reg, parse_id(id)?)?;
            if !snap.state.is_terminal() {
                return Err(ApiError::invalid_state(format!(
                    "run {} is {}; the result exists only once the run is terminal",
                    snap.id,
                    snap.state.as_str()
                )));
            }
            let mut fields = vec![
                ("id", JsonValue::num(snap.id as f64)),
                ("state", JsonValue::str(snap.state.as_str())),
            ];
            if let Some(result) = snap.result {
                fields.push(("result", result));
            }
            if let Some(err) = snap.error {
                fields.push(("error", JsonValue::str(err)));
            }
            Ok((200, JsonValue::obj(fields)))
        }
        ["runs", id, action] if matches!(*action, "pause" | "resume" | "cancel") => {
            require_method(req, "POST")?;
            no_query(req)?;
            no_body(req)?;
            let id = parse_id(id)?;
            let snap = match *action {
                "pause" => reg.request_pause(id)?,
                "resume" => reg.request_resume(id)?,
                _ => reg.request_cancel(id)?,
            };
            Ok((200, run_summary_json(&snap)))
        }
        ["runs", id, "checkpoint"] => {
            require_method(req, "POST")?;
            no_query(req)?;
            no_body(req)?;
            let (snap, path) = reg.request_checkpoint(parse_id(id)?)?;
            Ok((
                202,
                JsonValue::obj(vec![
                    ("id", JsonValue::num(snap.id as f64)),
                    ("state", JsonValue::str(snap.state.as_str())),
                    ("path", JsonValue::str(path)),
                ]),
            ))
        }
        _ => Err(ApiError::not_found(format!("no such endpoint {}", req.path))),
    }
}

fn require_method(req: &Request, expect: &str) -> Result<(), ApiError> {
    if req.method == expect {
        Ok(())
    } else {
        Err(ApiError::method_not_allowed(&req.method, &req.path))
    }
}

fn execute(job: &super::state::Job) -> Result<JsonValue, String> {
    let run = || -> Result<JsonValue> {
        let engine = crate::engine::build_engine(&job.cfg)?;
        let mut coord = Coordinator::new(job.cfg.clone(), engine)?;
        coord.set_boundary_control(Arc::clone(&job.control));
        coord.enable_record_streaming(&job.records_path)?;
        let result = coord.run()?;
        coord.finish_record_streaming()?;
        coord.recorder.write_eval_csv(&job.csv_path)?;
        Ok(api::run_result_json(&result))
    };
    run().map_err(|e| format!("{e:#}"))
}

fn executor_loop(reg: Arc<Registry>) {
    while let Some(job) = reg.claim_next() {
        let outcome = execute(&job);
        let cancelled = job.control.cancelled();
        reg.finish(job.id, outcome, cancelled);
    }
}

fn handle_connection(mut stream: TcpStream, reg: &Registry, limits: HttpLimits) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let hard_cap = head_limit(&limits) + limits.max_body_bytes + 1;
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let response = loop {
        match parse_request(&buf, &limits) {
            Ok(Some((req, _consumed))) => {
                let (status, body) = route(&req, reg);
                break write_response(status, &body);
            }
            Err(e) => break write_response(e.status, &e.to_json()),
            Ok(None) => {}
        }
        if buf.len() >= hard_cap {
            let e = ApiError::payload_too_large(limits.max_body_bytes);
            break write_response(e.status, &e.to_json());
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                if buf.is_empty() {
                    return; // idle probe (health-check connect), nothing to answer
                }
                let e = ApiError::bad_request("connection closed mid-request");
                break write_response(e.status, &e.to_json());
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => {
                let e = ApiError::bad_request("read timed out mid-request");
                break write_response(e.status, &e.to_json());
            }
        }
    };
    let _ = stream.write_all(&response);
    let _ = stream.flush();
}

fn bind_with_retry(addr: &str, port: u16, attempts: usize) -> Result<TcpListener> {
    let mut last = None;
    for i in 0..attempts.max(1) {
        match TcpListener::bind((addr, port)) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse && i + 1 < attempts => {
                // loopback port collisions are transient (CI runs suites
                // in parallel); back off briefly and retry
                std::thread::sleep(Duration::from_millis(25));
                last = Some(e);
            }
            Err(e) => return Err(e.into()),
        }
    }
    Err(last.expect("bind attempted at least once").into())
}

/// The long-lived daemon: a bound listener, its accept thread, and the
/// executor pool. Dropping (or calling [`Server::shutdown`]) cancels
/// every live run at its next boundary and joins all threads.
pub struct Server {
    addr: SocketAddr,
    registry: Arc<Registry>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `service.addr:service.port` (0 picks an ephemeral port) and
    /// spawn the accept thread plus `service.max_concurrent_runs`
    /// executors. Run artifacts land under `root_dir/<id>/`.
    pub fn start(service: ServiceConfig, root_dir: &str) -> Result<Server> {
        let listener = bind_with_retry(&service.addr, service.port, 10)?;
        let addr = listener.local_addr()?;
        let registry = Arc::new(Registry::new(root_dir));
        let stop = Arc::new(AtomicBool::new(false));
        let limits = HttpLimits {
            max_header_bytes: service.max_header_bytes,
            max_body_bytes: service.max_body_bytes,
        };
        let workers: Vec<JoinHandle<()>> = (0..service.max_concurrent_runs)
            .map(|_| {
                let reg = Arc::clone(&registry);
                std::thread::spawn(move || executor_loop(reg))
            })
            .collect();
        let accept = {
            let reg = Arc::clone(&registry);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let reg = Arc::clone(&reg);
                    std::thread::spawn(move || handle_connection(stream, &reg, limits));
                }
            })
        };
        Ok(Server {
            addr,
            registry,
            stop,
            accept: Some(accept),
            workers,
        })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared registry (in-process steering and tests).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Stop accepting, cancel live runs at their next boundary, drain
    /// the executor pool, and join every thread.
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        self.registry.shutdown();
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LIMITS: HttpLimits = HttpLimits { max_header_bytes: 16 * 1024, max_body_bytes: 1 << 20 };

    #[test]
    fn parser_handles_split_arrival_and_rejects_malformed_heads() {
        let raw = b"POST /runs HTTP/1.1\r\ncontent-length: 2\r\n\r\n{}";
        for cut in 0..raw.len() {
            assert!(
                parse_request(&raw[..cut], &LIMITS).unwrap().is_none(),
                "strict prefix of length {cut} must be incomplete"
            );
        }
        let (req, consumed) = parse_request(raw, &LIMITS).unwrap().unwrap();
        assert_eq!(consumed, raw.len());
        assert_eq!((req.method.as_str(), req.path.as_str()), ("POST", "/runs"));
        assert_eq!(req.body, b"{}");
        assert_eq!(req.header("content-length"), Some("2"));

        let bad = parse_request(b"GET /health HTTP/2\r\n\r\n", &LIMITS).unwrap_err();
        assert_eq!((bad.status, bad.code.as_str()), (400, "bad_request"));
        let te = parse_request(
            b"POST /runs HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
            &LIMITS,
        )
        .unwrap_err();
        assert_eq!((te.status, te.code.as_str()), (501, "unsupported"));
        let tiny = HttpLimits { max_header_bytes: 8, max_body_bytes: 4 };
        let big_head = parse_request(b"GET /health HTTP/1.1\r\n\r\n", &tiny).unwrap_err();
        assert_eq!(big_head.status, 431);
        let big_body =
            parse_request(b"POST /runs HTTP/1.1\r\ncontent-length: 5\r\n\r\n", &tiny);
        // head alone exceeds the tiny cap, so 431 wins; retry with a
        // roomier head cap to see the 413
        assert_eq!(big_body.unwrap_err().status, 431);
        let roomy = HttpLimits { max_header_bytes: 256, max_body_bytes: 4 };
        let big_body =
            parse_request(b"POST /runs HTTP/1.1\r\ncontent-length: 5\r\n\r\n", &roomy).unwrap_err();
        assert_eq!((big_body.status, big_body.code.as_str()), (413, "payload_too_large"));
    }

    #[test]
    fn query_and_target_split_is_exact() {
        let raw = b"GET /runs/0/records?from=12 HTTP/1.1\r\n\r\n";
        let (req, _) = parse_request(raw, &LIMITS).unwrap().unwrap();
        assert_eq!(req.path, "/runs/0/records");
        assert_eq!(req.query.as_deref(), Some("from=12"));
        assert_eq!(parse_from_query(&req).unwrap(), 12);
        let bad = Request { query: Some("start=3".into()), ..req.clone() };
        assert_eq!(parse_from_query(&bad).unwrap_err().code, "bad_query");
    }
}
